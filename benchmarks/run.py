"""Benchmark harness — one function per paper table/figure.

Outputs CSV lines ``name,us_per_call,derived`` (derived = the table's own
metrics as key=value pairs).

Default sizes are REDUCED for this 1-core CPU container (the paper used a
20-layer target on an RTX-4090; see DESIGN.md section 5). ``--paper-scale``
restores the paper's 8-head/20-layer target and 1-head/1-layer draft.
Quality metrics (likelihood discrepancy, KS, Wasserstein) are
scale-independent claims and are verified at both scales.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--paper-scale]
                                          [--only table1,...]
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TPPConfig, paper_draft, paper_target
from repro.core import thinning as thin
from repro.data import synthetic as ds
from repro import metrics as M
from repro.sampling import SamplerSpec, build_sampler
from repro.train import trainer

RESULTS: List[str] = []


def emit(name: str, us_per_call: float, derived: str):
    line = f"{name},{us_per_call:.1f},{derived}"
    RESULTS.append(line)
    print(line, flush=True)


_INTERP_WARNED = False


def accel_meta() -> Dict[str, object]:
    """Backend/interpret stamp for every BENCH_*.json entry, so a row
    measured under pallas-interpret on CPU can never be compared against
    a compiled-TPU row as if they shared hardware."""
    from repro.kernels.policy import on_tpu
    return {"backend": jax.default_backend(), "interpret": not on_tpu()}


def stamp_bench(rows: Dict) -> Dict:
    """Stamp ``accel_meta`` onto the table dict AND every per-entry
    sub-dict; print one warning row when the numbers come from
    pallas-interpret on CPU (correctness-path cost, not hardware speed
    — e.g. the known paged-vs-dense CPU gap is an interpret artifact,
    not a perf trajectory)."""
    global _INTERP_WARNED
    meta = accel_meta()
    for v in rows.values():
        if isinstance(v, dict):
            v.update(meta)
    rows.update(meta)
    if meta["interpret"] and not _INTERP_WARNED:
        _INTERP_WARNED = True
        emit("warning/pallas_interpret", 0.0,
             f"backend={meta['backend']};interpret=True;"
             "note=pallas kernels ran in interpret mode (no TPU): "
             "timings measure the correctness path and must not be "
             "read as a hardware perf trajectory")
    return rows


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def make_cfgs(encoder: str, num_marks: int, paper_scale: bool):
    if paper_scale:
        return (paper_target(encoder, num_marks),
                paper_draft(encoder, num_marks))
    t = TPPConfig(name=f"t-{encoder}", encoder=encoder, num_layers=4,
                  num_heads=2, d_model=32, d_ff=64, num_marks=num_marks,
                  num_mix=16)
    return t, t.replace(name=f"d-{encoder}", num_layers=1, num_heads=1)


_TRAIN_CACHE: Dict = {}


def trained_pair(dataset, encoder, paper_scale, epochs):
    key = (dataset.name, encoder, paper_scale, epochs)
    if key not in _TRAIN_CACHE:
        cfg_t, cfg_d = make_cfgs(encoder, dataset.num_marks, paper_scale)
        tcfg = trainer.TPPTrainConfig(max_epochs=epochs, batch_size=16,
                                      patience=4)
        pt, _ = trainer.train_tpp(cfg_t, dataset, tcfg)
        pd, _ = trainer.train_tpp(cfg_d, dataset, tcfg)
        _TRAIN_CACHE[key] = (cfg_t, cfg_d, pt, pd)
    return _TRAIN_CACHE[key]


def timed(fn, *args, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(jax.tree.leaves(out))
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    jax.block_until_ready(jax.tree.leaves(out))
    return out, time.perf_counter() - t0


def sample_both(cfg_t, cfg_d, pt, pd, t_end, gamma, emax, B, seed=0):
    """(ar_seqs, sd_seqs, T_ar, T_sd, alpha, sd_result) via the engine's
    vmap executors (built samplers are compilation-cached per spec)."""
    ar_fn = build_sampler(
        SamplerSpec(method="ar", execution="vmap", t_end=t_end,
                    max_events=emax, batch=B), cfg_t, pt)
    sd_fn = build_sampler(
        SamplerSpec(method="sd", execution="vmap", t_end=t_end, gamma=gamma,
                    max_events=emax, batch=B), cfg_t, pt, cfg_d, pd)
    ra, t_ar = timed(ar_fn, jax.random.PRNGKey(seed))
    rs, t_sd = timed(sd_fn, jax.random.PRNGKey(seed + 1))
    return (ra.to_seqs(), rs.to_seqs(), t_ar, t_sd,
            rs.stats().acceptance_rate, rs)


def host_speedup(cfg_t, cfg_d, pt, pd, t_end, gamma, emax, n_seq=2, seed=0):
    """Paper-faithful host-loop wall times (one sync per event / round)."""
    ar_fn = build_sampler(
        SamplerSpec(method="ar", execution="host", t_end=t_end,
                    max_events=emax), cfg_t, pt)
    sd_fn = build_sampler(
        SamplerSpec(method="sd", execution="host", t_end=t_end, gamma=gamma,
                    max_events=emax), cfg_t, pt, cfg_d, pd)
    ar_fn(jax.random.PRNGKey(99))
    t0 = time.perf_counter()
    for i in range(n_seq):
        ar_fn(jax.random.PRNGKey(seed + i))
    t_ar = time.perf_counter() - t0
    sd_fn(jax.random.PRNGKey(98))
    t0 = time.perf_counter()
    for i in range(n_seq):
        sd_fn(jax.random.PRNGKey(seed + 10 + i))
    t_sd = time.perf_counter() - t0
    return t_ar, t_sd


# ---------------------------------------------------------------------------
# Table 1: synthetic datasets x encoders
# ---------------------------------------------------------------------------

def table1_synthetic(args):
    encoders = ["thp"] if args.quick else ["thp", "sahp", "attnhp"]
    datasets = ["hawkes"] if args.quick else ["poisson", "hawkes",
                                              "multihawkes"]
    for dname in datasets:
        data = ds.make_dataset(dname, n_seqs=args.n_seqs, t_end=args.t_end)
        gt_ll = M.mean_gt_loglik(data.process, data.test, data.t_end)
        for enc in encoders:
            cfg_t, cfg_d, pt, pd = trained_pair(data, enc, args.paper_scale,
                                                args.epochs)
            ar, sd, t_ar, t_sd, alpha, rs = sample_both(
                cfg_t, cfg_d, pt, pd, data.t_end, args.gamma, args.emax,
                args.batch)
            # paper Sec 5.1: |L_gt(Eq.1) - L_model(Eq.2)| on the SAME
            # generated samples, per sampler
            dl_ar = abs(M.mean_gt_loglik(data.process, ar, data.t_end)
                        - trainer.model_loglik(cfg_t, pt, ar, data.t_end))
            dl_sd = abs(M.mean_gt_loglik(data.process, sd, data.t_end)
                        - trainer.model_loglik(cfg_t, pt, sd, data.t_end))
            ks_ar = M.ks_for_samples(data.process, ar)
            ks_sd = M.ks_for_samples(data.process, sd)
            th_ar, th_sd = host_speedup(cfg_t, cfg_d, pt, pd, data.t_end,
                                        args.gamma, args.emax)
            # hardware-independent speedup mechanism: events committed per
            # TARGET forward (AR = 1.0 by construction)
            epf = (sum(len(t) for t, _ in sd)
                   / max(1.0, float(np.sum(np.array(rs.rounds)))))
            emit(f"table1/{dname}/{enc}", t_sd / max(args.batch, 1) * 1e6,
                 f"dL_ar={dl_ar:.3f};dL_sd={dl_sd:.3f};ks_ar={ks_ar:.3f};"
                 f"ks_sd={ks_sd:.3f};T_ar={t_ar:.2f}s;T_sd={t_sd:.2f}s;"
                 f"speedup_jit={t_ar / t_sd:.2f};alpha={alpha:.2f};"
                 f"ev_per_target_fwd={epf:.2f};"
                 f"T_ar_host={th_ar:.2f}s;T_sd_host={th_sd:.2f}s;"
                 f"speedup_host={th_ar / max(th_sd, 1e-9):.2f}")


# ---------------------------------------------------------------------------
# Table 2: real(-like) datasets
# ---------------------------------------------------------------------------

def _ar_next_event(cfg, params, hist_t, hist_k, n_rep):
    """N repetitions of sampling the (M+1)-th event via AR (Sec. 5.1)."""
    from repro.models import tpp as tppm
    Kbos = cfg.num_marks
    enc_t = jnp.concatenate([jnp.zeros(1),
                             jnp.asarray(hist_t, jnp.float32)])
    enc_k = jnp.concatenate([jnp.full((1,), Kbos, jnp.int32),
                             jnp.asarray(hist_k, jnp.int32)])
    cache = tppm.init_cache(cfg, len(hist_t) + 2)
    h, _ = tppm.extend(cfg, params, cache, enc_t, enc_k)
    mix = tppm.interval_params(cfg, params, h[-1])
    logits = tppm.type_logits(cfg, params, h[-1])

    def one(r):
        r1, r2 = jax.random.split(r)
        return (tppm.sample_interval(r1, mix),
                jax.random.categorical(r2, logits))

    taus, ks = jax.vmap(one)(jax.random.split(jax.random.PRNGKey(3), n_rep))
    return np.array(taus) + float(hist_t[-1]), np.array(ks)


def _sd_next_event(cfg_t, cfg_d, pt, pd, hist_t, hist_k, n_rep, gamma=4):
    """The next event after a fixed history via one SD round, vmapped."""
    from repro.models import tpp as tppm
    from repro.sampling.loops import SDState, sd_round
    Kb = cfg_t.num_marks
    enc_t = jnp.concatenate([jnp.zeros(1),
                             jnp.asarray(hist_t[:-1], jnp.float32)])
    enc_k = jnp.concatenate([jnp.full((1,), Kb, jnp.int32),
                             jnp.asarray(hist_k[:-1], jnp.int32)])

    def one(r):
        cache_t = tppm.init_cache(cfg_t, len(hist_t) + gamma + 8)
        cache_d = tppm.init_cache(cfg_d, len(hist_t) + gamma + 8)
        _, cache_t = tppm.extend(cfg_t, pt, cache_t, enc_t, enc_k)
        _, cache_d = tppm.extend(cfg_d, pd, cache_d, enc_t, enc_k)
        st = SDState(jnp.zeros(gamma + 2), jnp.zeros(gamma + 2, jnp.int32),
                     jnp.int32(0), jnp.float32(hist_t[-1]),
                     jnp.int32(hist_k[-1]), cache_t, cache_d, r,
                     jnp.int32(0), jnp.int32(0), jnp.int32(0))
        st = sd_round(cfg_t, cfg_d, pt, pd, gamma, st)
        return st.times[0], st.types[0]

    ts, ks = jax.vmap(one)(jax.random.split(jax.random.PRNGKey(7), n_rep))
    return np.array(ts), np.array(ks)


def table2_real_like(args):
    encoders = ["thp"] if args.quick else ["thp", "sahp", "attnhp"]
    datasets = (["taxi_like"] if args.quick
                else ["taobao_like", "amazon_like", "taxi_like",
                      "stackoverflow_like"])
    for dname in datasets:
        data = ds.make_dataset(dname, n_seqs=args.n_seqs, t_end=args.t_end)
        for enc in encoders:
            cfg_t, cfg_d, pt, pd = trained_pair(data, enc, args.paper_scale,
                                                args.epochs)
            ar, sd, t_ar, t_sd, alpha, _ = sample_both(
                cfg_t, cfg_d, pt, pd, data.t_end, args.gamma, args.emax,
                args.batch)
            ar2, _, _, _, _, _ = sample_both(
                cfg_t, cfg_d, pt, pd, data.t_end, args.gamma, args.emax,
                args.batch, seed=100)
            ll_ar = trainer.model_loglik(cfg_t, pt, ar, data.t_end)
            ll_sd = trainer.model_loglik(cfg_t, pt, sd, data.t_end)
            ll_ar2 = trainer.model_loglik(cfg_t, pt, ar2, data.t_end)
            hist_t, hist_k = data.test[0]
            m = max(2, min(len(hist_t), 50))
            ta, ka = _ar_next_event(cfg_t, pt, hist_t[:m], hist_k[:m], 100)
            ts, ksd = _sd_next_event(cfg_t, cfg_d, pt, pd, hist_t[:m],
                                     hist_k[:m], 100)
            dws_t = M.wasserstein_1d(ta, ts)
            dws_k = M.type_emd(ka, ksd, data.num_marks)
            emit(f"table2/{dname}/{enc}", t_sd / max(args.batch, 1) * 1e6,
                 f"dL={abs(ll_ar - ll_sd):.3f};"
                 f"dL_self={abs(ll_ar - ll_ar2):.3f};"
                 f"dws_t={dws_t:.3f};dws_k={dws_k:.3f};"
                 f"T_ar={t_ar:.2f}s;T_sd={t_sd:.2f}s;"
                 f"speedup_jit={t_ar / t_sd:.2f};alpha={alpha:.2f}")


# ---------------------------------------------------------------------------
# Table 3/4: draft-model size ablation
# ---------------------------------------------------------------------------

def table3_draft_size(args):
    data = ds.make_dataset("multihawkes", n_seqs=args.n_seqs,
                           t_end=args.t_end)
    enc = "thp" if args.quick else "attnhp"
    sizes = [(1, 1), (2, 2)] if args.quick else [(1, 1), (2, 4), (4, 6)]
    cfg_t, _, pt, _ = trained_pair(data, enc, args.paper_scale, args.epochs)
    gt_ll = M.mean_gt_loglik(data.process, data.test, data.t_end)
    for heads, layers in sizes:
        cfg_d = cfg_t.replace(name=f"d{heads}x{layers}", num_heads=heads,
                              num_layers=layers)
        tcfg = trainer.TPPTrainConfig(max_epochs=args.epochs, batch_size=16)
        pd, _ = trainer.train_tpp(cfg_d, data, tcfg)
        ar, sd, t_ar, t_sd, alpha, _ = sample_both(
            cfg_t, cfg_d, pt, pd, data.t_end, args.gamma, args.emax,
            args.batch)
        dl = abs(M.mean_gt_loglik(data.process, sd, data.t_end)
                 - trainer.model_loglik(cfg_t, pt, sd, data.t_end))
        ks_sd = M.ks_for_samples(data.process, sd)
        emit(f"table3/draft{heads}h{layers}l",
             t_sd / max(args.batch, 1) * 1e6,
             f"dL={dl:.3f};ks={ks_sd:.3f};alpha={alpha:.2f};"
             f"T_ar={t_ar:.2f}s;T_sd={t_sd:.2f}s;"
             f"speedup={t_ar / t_sd:.2f}")


# ---------------------------------------------------------------------------
# Fig 3: draft-length (gamma) sweep
# ---------------------------------------------------------------------------

def fig3_gamma_sweep(args):
    data = ds.make_dataset("hawkes", n_seqs=args.n_seqs, t_end=args.t_end)
    cfg_t, cfg_d, pt, pd = trained_pair(data, "thp", args.paper_scale,
                                        args.epochs)
    gt_ll = M.mean_gt_loglik(data.process, data.test, data.t_end)
    gammas = [1, 4, 10] if args.quick else [1, 2, 5, 10, 20, 40]
    for g in gammas:
        ar, sd, t_ar, t_sd, alpha, _ = sample_both(
            cfg_t, cfg_d, pt, pd, data.t_end, g, args.emax, args.batch)
        dl = abs(M.mean_gt_loglik(data.process, sd, data.t_end)
                 - trainer.model_loglik(cfg_t, pt, sd, data.t_end))
        ks_sd = M.ks_for_samples(data.process, sd)
        emit(f"fig3/gamma{g}", t_sd / max(args.batch, 1) * 1e6,
             f"dL={dl:.3f};ks={ks_sd:.3f};alpha={alpha:.2f};"
             f"T_ar={t_ar:.2f}s;T_sd={t_sd:.2f}s;"
             f"speedup={t_ar / t_sd:.2f}")


# ---------------------------------------------------------------------------
# App. D.1 / Sec 4.1: thinning vs SD verify-call accounting
# ---------------------------------------------------------------------------

def appendix_d1_thinning(args):
    """Structural comparison: proposals per accepted event for classical
    thinning vs target-forwards per event for TPP-SD."""
    data = ds.make_dataset("hawkes", n_seqs=args.n_seqs, t_end=args.t_end)
    proc = data.process
    rng = np.random.default_rng(0)
    n_events = n_proposals = 0
    t0 = time.perf_counter()
    for _ in range(8):
        t = 0.0
        times, marks = [], []
        while True:
            lam_bar = proc.bound(t, times, marks)
            t += rng.exponential(1.0 / lam_bar)
            if t > args.t_end:
                break
            n_proposals += 1
            lam = proc.intensity(t, times, marks)
            if rng.uniform() < lam.sum() / lam_bar:
                times.append(t)
                marks.append(0)
        n_events += len(times)
    t_thin = time.perf_counter() - t0
    cfg_t, cfg_d, pt, pd = trained_pair(data, "thp", args.paper_scale,
                                        args.epochs)
    _, sd, _, t_sd, alpha, rs = sample_both(cfg_t, cfg_d, pt, pd,
                                            args.t_end, args.gamma,
                                            args.emax, 8)
    sd_events = sum(len(t) for t, _ in sd)
    sd_rounds = float(np.sum(np.array(rs.rounds)))
    # CIF-based thinning ON THE NEURAL MODEL (App. D.1's rejected design):
    # every proposal costs a target forward
    thin_fn = build_sampler(
        SamplerSpec(method="thinning", execution="host", t_end=args.t_end,
                    max_events=args.emax), cfg_t, pt)
    nf = ne = 0
    for i in range(4):
        st = thin_fn(jax.random.PRNGKey(50 + i)).stats()
        nf += st.rounds
        ne += st.events
    emit("appendix_d1/verify_calls",
         t_thin / max(n_events, 1) * 1e6,
         f"gt_thinning_proposals_per_event={n_proposals / max(n_events, 1):.2f};"
         f"neural_cif_thinning_forwards_per_event={nf / max(ne, 1):.2f};"
         f"sd_target_forwards_per_event={sd_rounds / max(sd_events, 1):.2f};"
         f"alpha={alpha:.2f}")


# ---------------------------------------------------------------------------
# Kernel microbenchmarks: pallas vs ref, paged vs dense -> BENCH_kernels.json
# ---------------------------------------------------------------------------

def kernels_microbench(args):
    """``--only kernels``: per-kernel wall times — spec-verify attention
    (Pallas-paged vs ref-paged-gather vs the dense naive baseline) for
    gamma in {2, 4, 8}, flash attention, and the fused log-normal-mixture
    logpdf/logsf — written to ``BENCH_kernels.json`` so the perf
    trajectory has per-kernel data points. Off-TPU the Pallas numbers
    are ``interpret=True`` (correctness-path cost, not hardware speed);
    the JSON records the backend so rows stay comparable."""
    import json

    from repro.kernels import ref as kref
    from repro.kernels.lognorm_mix import (lognorm_mix_logpdf_pallas,
                                           lognorm_mix_logsf_pallas)
    from repro.kernels.policy import on_tpu
    from repro.kernels.spec_verify_attention import (
        spec_verify_attention_pallas, spec_verify_attention_ref)

    rng = jax.random.PRNGKey(0)
    interp = not on_tpu()
    rows = {"backend": jax.default_backend(), "interpret": interp}

    # --- spec-verify attention over a paged cache (the serving hot path)
    S, H, KV, Dh, page = 4, 8, 2, 64, 16
    NB = 16                                        # 256-token cache
    P = S * NB + 1
    ks = jax.random.split(rng, 3)
    k_pages = jax.random.normal(ks[1], (P, page, KV, Dh))
    v_pages = jax.random.normal(ks[2], (P, page, KV, Dh))
    bt = jnp.arange(1, S * NB + 1, dtype=jnp.int32).reshape(S, NB)
    lens = jnp.full((S,), NB * page - 12, jnp.int32)
    k_dense = k_pages[bt].reshape(S, NB * page, KV, Dh)
    v_dense = v_pages[bt].reshape(S, NB * page, KV, Dh)
    kv_pos = jnp.broadcast_to(jnp.arange(NB * page), (S, NB * page))
    for gamma in (2, 4, 8):
        C = gamma + 1
        q = jax.random.normal(ks[0], (S, C, H, Dh))
        q_pos = lens[:, None] + jnp.arange(C)
        _, t_pal = timed(spec_verify_attention_pallas, q, k_pages, v_pages,
                         bt, lens, interpret=interp)
        _, t_ref = timed(jax.jit(spec_verify_attention_ref), q, k_pages,
                         v_pages, bt, lens)
        _, t_dense = timed(jax.jit(kref.naive_attention), q, k_dense,
                           v_dense, q_pos, kv_pos)
        rows[f"spec_verify/gamma{gamma}"] = {
            "us_pallas": t_pal * 1e6, "us_ref_paged": t_ref * 1e6,
            "us_dense_naive": t_dense * 1e6,
            "S": S, "H": H, "KV": KV, "Dh": Dh, "page": page,
            "cache": NB * page}
        emit(f"kernels/spec_verify/gamma{gamma}", t_pal * 1e6,
             f"us_pallas={t_pal * 1e6:.0f};us_ref_paged={t_ref * 1e6:.0f};"
             f"us_dense_naive={t_dense * 1e6:.0f};"
             f"cache={NB * page};S={S}")

    # --- flash attention (prefill path)
    Sq = 512 if args.quick else 1024
    q = jax.random.normal(ks[0], (1, Sq, H, Dh))
    k = jax.random.normal(ks[1], (1, Sq, KV, Dh))
    v = jax.random.normal(ks[2], (1, Sq, KV, Dh))
    pos = jnp.arange(Sq)[None]
    from repro.kernels.flash_attention import flash_attention_pallas
    _, t_pal = timed(flash_attention_pallas, q, k, v, pos, pos,
                     bq=128, bk=128, interpret=interp)
    _, t_ref = timed(jax.jit(
        lambda *a: kref.flash_attention_ref(*a, 0, 0.0, 128, 128)),
        q, k, v, pos, pos)
    rows["flash/S%d" % Sq] = {"us_pallas": t_pal * 1e6,
                              "us_ref": t_ref * 1e6}
    emit(f"kernels/flash/S{Sq}", t_pal * 1e6,
         f"us_pallas={t_pal * 1e6:.0f};us_ref={t_ref * 1e6:.0f}")

    # --- fused log-normal mixture (verify densities / thinning bound)
    N, M = 4096, 64
    ks = jax.random.split(rng, 4)
    tau = jax.random.uniform(ks[0], (N,), jnp.float32, 1e-3, 10.0)
    log_w = jax.nn.log_softmax(jax.random.normal(ks[1], (N, M)))
    mu = jax.random.normal(ks[2], (N, M))
    sigma = jnp.exp(jax.random.normal(ks[3], (N, M)) * 0.4)
    for name, pal, rf in (
            ("logpdf", lognorm_mix_logpdf_pallas,
             kref.lognorm_mix_logpdf_ref),
            ("logsf", lognorm_mix_logsf_pallas,
             kref.lognorm_mix_logsf_ref)):
        _, t_pal = timed(pal, tau, log_w, mu, sigma, interpret=interp)
        _, t_ref = timed(jax.jit(rf), tau, log_w, mu, sigma)
        rows[f"lognorm_{name}/N{N}xM{M}"] = {
            "us_pallas": t_pal * 1e6, "us_ref": t_ref * 1e6}
        emit(f"kernels/lognorm_{name}", t_pal * 1e6,
             f"us_pallas={t_pal * 1e6:.0f};us_ref={t_ref * 1e6:.0f};"
             f"N={N};M={M}")

    with open("BENCH_kernels.json", "w") as f:
        json.dump(stamp_bench(rows), f, indent=2, sort_keys=True)
    print("# wrote BENCH_kernels.json")


# ---------------------------------------------------------------------------
# Serving throughput: continuous-batching LLM speculative serving
# ---------------------------------------------------------------------------

def serving_throughput(args):
    """tokens/sec + tokens/target-forward of ``repro.serving`` on the
    smoke LLM config, single-request vs continuous batching — the line
    that makes BENCH_*.json track serving throughput over time. Runs the
    legacy dense+ref layout (the historical row), the production
    paged+Pallas layout, AND a long-prompt admission workload that
    reports TTFT p50/p95 + prefill tok/s for chunked-paged prefill vs
    the dense staging buffer. All rows land in ``BENCH_serving.json``."""
    import json

    from repro.configs import get_arch, smoke_variant
    from repro.models import registry as zoo
    from repro.serving import ServeRequest, ServingEngine

    cfg_t = smoke_variant(get_arch("llama3.2-1b")).replace(num_layers=4)
    cfg_d = cfg_t.replace(num_layers=1)
    pt = zoo.get_model(cfg_t).init_params(jax.random.PRNGKey(0))
    pd = zoo.get_model(cfg_d).init_params(jax.random.PRNGKey(1))
    prompt = jnp.arange(8, dtype=jnp.int32)
    new_tokens = 16 if args.quick else 32
    gamma = 4   # fixed smoke setting so BENCH rows stay comparable
    bench = {"backend": jax.default_backend(), "gamma": gamma}

    def run(max_batch, n_req, plen=8, **kw):
        eng = ServingEngine(cfg_t, pt, cfg_d, pd, max_batch=max_batch,
                            max_len=256, gamma=gamma, **kw)
        p = (prompt if plen == 8
             else jnp.arange(plen, dtype=jnp.int32) % cfg_t.vocab_size)
        for i in range(n_req):
            eng.submit(ServeRequest(prompt=p,
                                    max_new_tokens=new_tokens, rng=100 + i))
        res = eng.run()
        return eng.stats(), res

    for tag, kw in (("", dict(kv_layout="dense", kernel="ref")),
                    ("_paged", dict(kv_layout="paged"))):
        run(1, 1, **kw)          # compile
        s1, _ = run(1, 2, **kw)
        run(4, 1, **kw)          # compile the batched round
        sb, _ = run(4, 8, **kw)
        bench[f"llm_sd{tag}"] = {
            "tok_per_sec_b1": s1.tokens_per_sec,
            "tok_per_sec_b4": sb.tokens_per_sec,
            "tok_per_fwd_b4": sb.tokens_per_forward,
            "alpha": sb.acceptance_rate}
        emit(f"serving/llm_sd{tag}", 1e6 / max(sb.tokens_per_sec, 1e-9),
             f"tok_per_sec_b1={s1.tokens_per_sec:.1f};"
             f"tok_per_sec_b4={sb.tokens_per_sec:.1f};"
             f"tok_per_fwd_b1={s1.tokens_per_forward:.2f};"
             f"tok_per_fwd_b4={sb.tokens_per_forward:.2f};"
             f"alpha={sb.acceptance_rate:.2f};"
             f"gamma={gamma};requests=8;max_batch=4")

    # --- long-prompt admission: TTFT + prefill throughput, chunked
    # prefill THROUGH the paged pool vs the dense staging buffer
    plen = 96 if args.quick else 160
    n_req = 6
    for tag, kw in (
            ("staging", dict(kv_layout="paged")),
            ("chunked", dict(kv_layout="paged", prefill_chunk=32)),
            ("chunked_budget", dict(kv_layout="paged", prefill_chunk=32,
                                    prefill_budget=64))):
        run(4, 2, plen=plen, **kw)      # compile
        st, res = run(4, n_req, plen=plen, **kw)
        tt = np.sort(np.array([r.ttft_s for r in res]))
        p50 = float(np.percentile(tt, 50))
        p95 = float(np.percentile(tt, 95))
        ptok = st.prefill_tokens_per_sec
        bench[f"longprompt_{tag}"] = {
            "prompt_len": plen, "requests": n_req,
            "ttft_p50_ms": p50 * 1e3, "ttft_p95_ms": p95 * 1e3,
            "prefill_tok_per_sec": ptok,
            "prefill_tokens": st.prefill_tokens,
            "tok_per_sec": st.tokens_per_sec}
        emit(f"serving/longprompt_{tag}", p50 * 1e6,
             f"ttft_p50_ms={p50 * 1e3:.1f};ttft_p95_ms={p95 * 1e3:.1f};"
             f"prefill_tok_per_sec={ptok:.0f};"
             f"tok_per_sec={st.tokens_per_sec:.1f};"
             f"prompt_len={plen};requests={n_req}")

    _merge_bench_serving(bench)


def _merge_bench_serving(rows: Dict) -> None:
    """Merge (not overwrite) rows into ``BENCH_serving.json`` so the
    serving and prefix workloads can run as separate ``--only`` legs
    and still land in one file."""
    import json
    import os
    out = {}
    if os.path.exists("BENCH_serving.json"):
        try:
            with open("BENCH_serving.json") as f:
                out = json.load(f)
        except (OSError, ValueError):
            out = {}
    out.update(stamp_bench(rows))
    with open("BENCH_serving.json", "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    print("# wrote BENCH_serving.json")


# ---------------------------------------------------------------------------
# Prefix cache + scenario fan-out: prefill tokens saved by sharing
# ---------------------------------------------------------------------------

def prefix_fanout(args):
    """``--only prefix``: a shared-prompt forecasting-style workload —
    groups of fanout-K rollouts over one 96-token prompt — run with the
    radix prefix cache on vs off. Reports ``prefix_hit_tokens`` (prompt
    tokens served from shared COW pages instead of prefilled) and the
    prefill tokens saved by turning the cache on; rows merge into
    ``BENCH_serving.json`` next to the serving-throughput entries."""
    from repro.configs import get_arch, smoke_variant
    from repro.models import registry as zoo
    from repro.serving import ServeRequest, ServingEngine

    cfg_t = smoke_variant(get_arch("llama3.2-1b")).replace(num_layers=4)
    cfg_d = cfg_t.replace(num_layers=1)
    pt = zoo.get_model(cfg_t).init_params(jax.random.PRNGKey(0))
    pd = zoo.get_model(cfg_d).init_params(jax.random.PRNGKey(1))
    plen, fanout, n_groups = 96, 4, 2          # 8 requests total
    prompt = jnp.arange(plen, dtype=jnp.int32) % cfg_t.vocab_size
    new_tokens = 16 if args.quick else 32
    gamma = 4

    def run(cache_on):
        eng = ServingEngine(cfg_t, pt, cfg_d, pd, max_batch=4, max_len=256,
                            gamma=gamma, kv_layout="paged",
                            prefill_chunk=32, prefix_cache=cache_on)
        for g in range(n_groups):
            eng.submit(ServeRequest(prompt=prompt,
                                    max_new_tokens=new_tokens,
                                    rng=100 + g), fanout=fanout)
        res = eng.run()
        return eng, eng.stats(), res

    run(True)                                   # compile
    eng_on, on, res_on = run(True)
    _, off, res_off = run(False)
    # fan-out forking is on in BOTH runs (it rides the COW pool, not the
    # cache); the cache adds CROSS-group sharing, so cache-on must
    # prefill strictly fewer prompt tokens
    saved = off.prefill_tokens - on.prefill_tokens
    toks_on = sorted(tuple(map(int, r.tokens)) for r in res_on)
    toks_off = sorted(tuple(map(int, r.tokens)) for r in res_off)
    assert toks_on == toks_off, \
        "prefix cache changed the sampled streams (bitwise contract)"
    assert on.prefix_hit_tokens > 0, "prefix workload produced no hits"
    assert saved > 0, "prefix cache saved no prefill tokens"
    bench = {"prefix_fanout": {
        "prompt_len": plen, "requests": n_groups * fanout,
        "fanout": fanout, "gamma": gamma,
        "prefix_hit_tokens": on.prefix_hit_tokens,
        "prefix_hit_rate": on.prefix_hit_rate,
        "prefill_tokens_cache_on": on.prefill_tokens,
        "prefill_tokens_cache_off": off.prefill_tokens,
        "prefill_tokens_saved": saved,
        "cow_copies": eng_on.pool_t.cow_copies,
        "tok_per_sec": on.tokens_per_sec}}
    emit("serving/prefix_fanout", 1e6 / max(on.tokens_per_sec, 1e-9),
         f"prefix_hit_tokens={on.prefix_hit_tokens};"
         f"prefix_hit_rate={on.prefix_hit_rate:.2f};"
         f"prefill_saved={saved};"
         f"prefill_on={on.prefill_tokens};prefill_off={off.prefill_tokens};"
         f"cow_copies={eng_on.pool_t.cow_copies};"
         f"prompt_len={plen};requests={n_groups * fanout};fanout={fanout}")
    _merge_bench_serving(bench)


# ---------------------------------------------------------------------------
# Forecasting at fan-out scale: wave-scheduled rollouts -> BENCH_forecast.json
# ---------------------------------------------------------------------------

def forecast_fanout(args):
    """``--only forecast``: the long-horizon forecasting workload — ONE
    observed event history fanned into >= 1000 Monte-Carlo rollouts
    through the serving engine in pool-sized waves (the paged pool is
    deliberately sized to hold about one wave), reduced on device to
    per-bin count quantiles. Headline metric: rollouts/s. A second
    speculative row compares sd vs ar rollout throughput at equal
    settings. Rows land in ``BENCH_forecast.json``."""
    import json

    from repro.forecast import build_forecaster
    from repro.models import tpp as tppm
    from repro.sampling import ForecastSpec

    cfg_t = TPPConfig(name="fc-t", encoder="thp", num_layers=2,
                      num_heads=2, d_model=32, d_ff=64, num_marks=5,
                      num_mix=16)
    cfg_d = cfg_t.replace(name="fc-d", num_layers=1, num_heads=1)
    pt = tppm.init_params(cfg_t, jax.random.PRNGKey(0))
    pd = tppm.init_params(cfg_d, jax.random.PRNGKey(1))
    r = np.random.default_rng(0)
    hist_t = np.cumsum(r.exponential(0.5, size=8)).astype(np.float32)
    hist_k = r.integers(0, 5, size=8).astype(np.int32)
    horizon, bins, budget = 4.0, 8, 8
    qs = (0.1, 0.5, 0.9)
    bench: Dict = {}

    def run(method, n_rollouts, gamma=4):
        spec = SamplerSpec(
            domain="tpp", method=method, gamma=gamma, batch=16,
            max_events=budget,
            max_len=len(hist_k) + budget + (gamma if method == "sd"
                                            else 0),
            forecast=ForecastSpec(horizon=horizon, n_rollouts=n_rollouts,
                                  bins=bins, quantiles=qs))
        # n_pages sized to hold roughly ONE wave: the executor must
        # retire and re-fork to cover the fan-out
        fc = build_forecaster(spec, cfg_t, pt,
                              cfg_d if method == "sd" else None,
                              pd if method == "sd" else None,
                              page_size=4, n_pages=40)
        fc(hist_t, hist_k, n_rollouts=min(32, n_rollouts))   # compile
        return fc, fc(hist_t, hist_k, rng=jax.random.PRNGKey(7))

    # --- headline: >= 1000 rollouts through waves (ar = densest rounds)
    n_main = 1000
    fc, res = run("ar", n_main)
    assert res.n_waves > 1, "pool held the whole fan-out: no waves"
    assert res.n_rollouts >= 1000
    st = fc.engine.stats()
    bench["forecast_waves"] = {
        "method": "ar", "n_rollouts": res.n_rollouts,
        "waves": res.n_waves, "wave_size_max": max(res.wave_sizes),
        "history_len": int(len(hist_k)), "horizon": horizon,
        "bins": bins, "events": res.events,
        "rollouts_per_sec": res.rollouts_per_sec,
        "quantile_levels": list(qs),
        "bin_quantiles": res.quantiles.tolist(),
        "bin_mean": res.mean.tolist(),
        "prefix_hit_tokens": st.prefix_hit_tokens}
    emit("forecast/waves", 1e6 / max(res.rollouts_per_sec, 1e-9),
         f"rollouts={res.n_rollouts};waves={res.n_waves};"
         f"rollouts_per_sec={res.rollouts_per_sec:.1f};"
         f"events={res.events};bins={bins};"
         f"q50_total={sum(res.quantiles[1])};"
         f"prefix_hit_tokens={st.prefix_hit_tokens}")

    # --- sd vs ar at equal settings: events/target-forward is where
    # speculation pays
    n_cmp = 128 if args.quick else 256
    row = {}
    for method in ("sd", "ar"):
        fc, res = run(method, n_cmp)
        st = fc.engine.stats()
        row[method] = {
            "rollouts_per_sec": res.rollouts_per_sec,
            "events_per_fwd": res.events / max(1, st.target_forwards),
            "alpha": st.acceptance_rate}
        emit(f"forecast/{method}", 1e6 / max(res.rollouts_per_sec, 1e-9),
             f"rollouts={n_cmp};rollouts_per_sec="
             f"{res.rollouts_per_sec:.1f};"
             f"events_per_fwd={row[method]['events_per_fwd']:.2f};"
             f"alpha={st.acceptance_rate:.2f}")
    bench["forecast_sd_vs_ar"] = {
        "n_rollouts": n_cmp, "gamma": 4, **{
            f"{m}_{k}": v for m, d in row.items() for k, v in d.items()}}

    with open("BENCH_forecast.json", "w") as f:
        json.dump(stamp_bench(bench), f, indent=2, sort_keys=True)
    print("# wrote BENCH_forecast.json")


# ---------------------------------------------------------------------------
# Sharded fan-out: sequences/sec and tokens/sec vs device count
# ---------------------------------------------------------------------------

_SHARDED_WORKER = """
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count={n}")
import json, time
import jax, jax.numpy as jnp
from repro.configs.base import TPPConfig, ModelConfig
from repro.launch.mesh import make_debug_mesh
from repro.models import tpp, registry
from repro.sampling import SamplerSpec, build_sampler
from repro.serving import ServeRequest, ServingEngine

mesh = make_debug_mesh(data={n}, model=1)
out = {{"devices": {n}}}

# TPP sharded sampling: whole-sequence fan-out
cfg_t = TPPConfig(name="bt", encoder="thp", num_layers=4, num_heads=2,
                  d_model=32, d_ff=64, num_marks=5, num_mix=16)
cfg_d = cfg_t.replace(name="bd", num_layers=1, num_heads=1)
pt = tpp.init_params(cfg_t, jax.random.PRNGKey(0))
pd = tpp.init_params(cfg_d, jax.random.PRNGKey(1))
fn = build_sampler(SamplerSpec(method="sd", execution="sharded",
                               t_end={t_end}, gamma={gamma},
                               max_events={emax}, batch={batch}),
                   cfg_t, pt, cfg_d, pd, mesh=mesh)
b = fn(jax.random.PRNGKey(0))                       # compile
jax.block_until_ready(jax.tree.leaves(b))
t0 = time.perf_counter()
b = fn(jax.random.PRNGKey(1))
jax.block_until_ready(jax.tree.leaves(b))
dt = time.perf_counter() - t0
out["seq_per_sec"] = {batch} / dt
out["events_per_sec"] = int(b.stats().events) / dt

# serving: slot pool sharded over data
scfg_t = ModelConfig(name="st", family="dense", num_layers=4, d_model=64,
                     num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                     dtype="float32", param_dtype="float32", remat=False)
scfg_d = scfg_t.replace(name="sd", num_layers=1)
spt = registry.get_model(scfg_t).init_params(jax.random.PRNGKey(0))
spd = registry.get_model(scfg_d).init_params(jax.random.PRNGKey(1))
prompt = jnp.arange(8, dtype=jnp.int32)

def serve():
    eng = ServingEngine(scfg_t, spt, scfg_d, spd, max_batch={batch},
                        max_len=128, gamma={gamma}, mesh=mesh)
    for i in range({batch} * 2):
        eng.submit(ServeRequest(prompt=prompt, max_new_tokens={new_tokens},
                                rng=100 + i))
    eng.run()
    return eng.stats()

serve()                                             # compile
st = serve()
out["tok_per_sec"] = st.tokens_per_sec
out["tok_per_fwd"] = st.tokens_per_forward
print(json.dumps(out))
"""


def sharded_scaling(args):
    """Sharded fan-out vs forced host device count: `--only sharded`
    emits one row per device count with sequences/sec (TPP sharded
    sampling, batch over the data axis) and tokens/sec (serving with the
    slot pool sharded over data). Run on real accelerators by dropping
    the XLA host-device forcing."""
    import json as _json
    import os as _os
    import subprocess as _sp
    import sys as _sys
    counts = [1, 4] if args.quick else [1, 2, 4]
    src = _os.path.join(_os.path.dirname(_os.path.dirname(
        _os.path.abspath(__file__))), "src")
    gamma = min(args.gamma, 4)
    for n in counts:
        script = _SHARDED_WORKER.format(
            n=n, t_end=args.t_end, gamma=gamma, emax=args.emax,
            batch=max(args.batch, 8), new_tokens=16)
        env = dict(_os.environ,
                   PYTHONPATH=src + _os.pathsep
                   + _os.environ.get("PYTHONPATH", ""))
        try:
            r = _sp.run([_sys.executable, "-c", script],
                        capture_output=True, text=True, env=env,
                        timeout=900)
        except _sp.TimeoutExpired:
            emit(f"sharded/devices{n}", 0.0, "error=timeout(900s)")
            continue
        if r.returncode != 0:
            err = (r.stderr.strip().splitlines() or ["<no stderr>"])[-1]
            emit(f"sharded/devices{n}", 0.0, f"error={err[:120]}")
            continue
        o = _json.loads(r.stdout.strip().splitlines()[-1])
        emit(f"sharded/devices{n}", 1e6 / max(o["seq_per_sec"], 1e-9),
             f"seq_per_sec={o['seq_per_sec']:.2f};"
             f"events_per_sec={o['events_per_sec']:.0f};"
             f"tok_per_sec={o['tok_per_sec']:.1f};"
             f"tok_per_fwd={o['tok_per_fwd']:.2f};"
             f"batch={max(args.batch, 8)};gamma={gamma}")


TABLES = {
    "table1": table1_synthetic,
    "table2": table2_real_like,
    "table3": table3_draft_size,
    "fig3": fig3_gamma_sweep,
    "appendix_d1": appendix_d1_thinning,
    "kernels": kernels_microbench,
    "serving": serving_throughput,
    "prefix": prefix_fanout,
    "forecast": forecast_fanout,
    "sharded": sharded_scaling,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="single dataset/encoder per table")
    ap.add_argument("--paper-scale", action="store_true",
                    help="paper's 8h/20L target + 1h/1L draft")
    ap.add_argument("--only", default="")
    ap.add_argument("--t-end", type=float, default=20.0)
    ap.add_argument("--n-seqs", type=int, default=120)
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--gamma", type=int, default=10)
    ap.add_argument("--emax", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(TABLES)
    t0 = time.time()
    print("name,us_per_call,derived")
    for name in names:
        TABLES[name](args)
    print(f"# total {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
