"""Open-loop Poisson load generator for the serving + forecast engines.

Closed-loop drivers (submit, drain, repeat — the benchmark legs in
``run.py``) measure capacity; an OPEN-loop driver measures what a rate
actually feels like: arrivals are drawn up front from a Poisson process
and submitted on schedule whether or not the engine has caught up, so
queueing delay shows up in latency instead of silently throttling the
offered rate. The arrival stream comes from the repo's own classical
thinning sampler (``repro.core.thinning``) over a homogeneous process —
the same machinery the paper benchmarks TPP-SD against, here generating
the traffic instead of serving it.

Each arrival is one QUERY: a fanout-K scenario group for the forecast
target (K rollouts of a shared event history through the wave-serving
TPP engine) or a prompt completion for the token serving target. The
report is sustained queries/s + rollouts/s against the offered rate,
with completion-latency percentiles (p50/p95/p99), per-status counts,
and GOODPUT (tokens delivered by in-deadline "ok" requests per second
of the active window) — under ``--deadline``/``--shed-queue`` overload
the engine trades completions for latency, and goodput is the number
that shows whether the trade paid.

  PYTHONPATH=src python -m benchmarks.loadgen --target forecast \
      --rate 2 --queries 12 --fanout 8
  PYTHONPATH=src python -m benchmarks.loadgen --target serving --rate 4
  # overload leg: offered rate far above capacity, bounded queue —
  # sheds the tail, keeps serving the head
  PYTHONPATH=src python -m benchmarks.loadgen --target serving \
      --rate 50 --queries 24 --shed-queue 2 --deadline 30 --bench-json
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.thinning import InhomPoisson, thinning_sample


def poisson_arrivals(rate: float, n: int, seed: int = 0) -> np.ndarray:
    """First ``n`` arrival times of a rate-``rate`` homogeneous Poisson
    process, sampled with the repo's thinning sampler (omega=0 makes
    ``InhomPoisson`` exactly homogeneous: lambda = A * b)."""
    proc = InhomPoisson(A=rate, b=1.0, omega=0.0)
    rng = np.random.default_rng(seed)
    horizon, times = 4.0 * n / max(rate, 1e-9), np.empty(0)
    while times.size < n:
        times, _ = thinning_sample(proc, horizon, np.random.default_rng(
            rng.integers(1 << 31)), max_events=4 * n)
        horizon *= 2
    return times[:n]


@dataclass
class _Query:
    qid: int
    arrival_s: float
    member_ids: List[str]
    submit_s: float = 0.0
    done_s: float = 0.0
    pending: set = field(default_factory=set)


def drive(engine, queries: List[Dict], rate: float, seed: int = 0,
          loop: str = "sync"):
    """Open-loop drive: submit query i at its Poisson arrival offset,
    stepping the engine in between; returns (per-query records,
    per-status result counts, wall).

    ``loop="async"`` runs the engine's pipelined step: the device round
    is dispatched non-blocking and arrivals are polled INSIDE the
    overlap window (while the device is busy), so under overload the
    async loop admits sooner and wastes no host time idling at the
    transfer barrier."""
    arrivals = poisson_arrivals(rate, len(queries), seed)
    recs: List[_Query] = []
    statuses: Dict[str, int] = {}
    next_q = 0
    t0 = time.perf_counter()

    def submit_due():
        nonlocal next_q
        now = time.perf_counter() - t0
        while next_q < len(queries) and arrivals[next_q] <= now:
            ids = engine.submit(**queries[next_q])
            ids = ids if isinstance(ids, list) else [ids]
            recs.append(_Query(qid=next_q, arrival_s=arrivals[next_q],
                               member_ids=ids, submit_s=now,
                               pending=set(ids)))
            next_q += 1

    overlap = (engine.async_overlap(poll=submit_due)
               if loop == "async" else None)
    while next_q < len(queries) or engine.scheduler.has_work():
        submit_due()
        if engine.scheduler.has_work():
            for res in engine.step(overlap=overlap):
                statuses[res.status] = statuses.get(res.status, 0) + 1
                for q in recs:
                    if res.request_id in q.pending:
                        q.pending.discard(res.request_id)
                        if not q.pending:
                            q.done_s = time.perf_counter() - t0
        elif next_q < len(queries):
            # idle gap until the next scheduled arrival
            now = time.perf_counter() - t0
            time.sleep(min(0.01, max(0.0, arrivals[next_q] - now)))
    return recs, statuses, time.perf_counter() - t0


def build_forecast_engine(args):
    from repro.configs.base import TPPConfig
    from repro.models import tpp
    from repro.serving import ServingEngine

    cfg_t = TPPConfig(name="lg-t", encoder="thp", num_layers=2,
                      num_heads=2, d_model=32, d_ff=64, num_marks=5,
                      num_mix=16)
    cfg_d = cfg_t.replace(name="lg-d", num_layers=1, num_heads=1)
    pt = tpp.init_params(cfg_t, jax.random.PRNGKey(0))
    pd = tpp.init_params(cfg_d, jax.random.PRNGKey(1))
    eng = ServingEngine(cfg_t, pt, cfg_d, pd, method="sd",
                        max_batch=args.max_batch, gamma=2,
                        max_len=8 + args.budget + 2, page_size=4,
                        sched="grouped", prefix_cache=True,
                        shed_queue=_shed(args))
    r = np.random.default_rng(args.seed)
    hist_t = np.cumsum(r.exponential(0.5, size=8)).astype(np.float32)
    hist_k = r.integers(0, 5, size=8).astype(np.int32)
    queries = [dict(prompt=hist_k, times=hist_t,
                    t_end=float(hist_t[-1]) + 4.0,
                    max_new_tokens=args.budget,
                    rng=jax.random.PRNGKey(100 + i), fanout=args.fanout,
                    deadline_s=args.deadline or None)
               for i in range(args.queries)]
    return eng, queries


def build_serving_engine(args):
    from repro.configs import get_arch, smoke_variant
    from repro.models import registry
    from repro.serving import ServingEngine

    cfg_t = smoke_variant(get_arch("llama3.2-1b")).replace(num_layers=2)
    cfg_d = cfg_t.replace(num_layers=1)
    pt = registry.get_model(cfg_t).init_params(jax.random.PRNGKey(0))
    pd = registry.get_model(cfg_d).init_params(jax.random.PRNGKey(1))
    eng = ServingEngine(cfg_t, pt, cfg_d, pd, method="sd",
                        max_batch=args.max_batch, max_len=64, gamma=2,
                        shed_queue=_shed(args))
    queries = [dict(prompt=jnp.arange(8, dtype=jnp.int32),
                    max_new_tokens=args.budget, rng=100 + i,
                    deadline_s=args.deadline or None)
               for i in range(args.queries)]
    return eng, queries


def _shed(args):
    return args.shed_queue if args.shed_queue >= 0 else None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--target", default="forecast",
                    choices=["forecast", "serving"])
    ap.add_argument("--rate", type=float, default=2.0,
                    help="offered arrival rate, queries/s (open loop)")
    ap.add_argument("--queries", type=int, default=12)
    ap.add_argument("--fanout", type=int, default=8,
                    help="rollouts per forecast query")
    ap.add_argument("--budget", type=int, default=8,
                    help="events/tokens per rollout")
    ap.add_argument("--max-batch", dest="max_batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="per-query deadline_s (0 = none): queries the "
                         "engine cannot finish in time retire "
                         "status='deadline' and drop out of goodput")
    ap.add_argument("--shed-queue", dest="shed_queue", type=int,
                    default=-1,
                    help="bound the pending queue: after each step's "
                         "admissions the backlog past this depth is "
                         "shed (status='shed'); -1 = never shed")
    ap.add_argument("--loop", default="sync", choices=["sync", "async"],
                    help="sync = blocking step; async = pipelined step "
                         "(arrival polling rides the overlap window)")
    ap.add_argument("--bench-json", dest="bench_json",
                    action="store_true",
                    help="merge an overload row into BENCH_serving.json")
    args = ap.parse_args()

    eng, queries = (build_forecast_engine(args) if args.target == "forecast"
                    else build_serving_engine(args))
    # warm the compile caches outside the timed window, then reset
    # (deadline stripped: the warm-up must run to completion)
    eng.submit(**{**queries[0], "deadline_s": None})
    eng.run()
    eng.reset()

    recs, statuses, wall = drive(eng, queries, args.rate, args.seed,
                                 loop=args.loop)
    st = eng.stats()
    lat = np.sort(np.array([q.done_s - q.arrival_s for q in recs]))
    # sustained rate over the active window (first arrival -> last
    # completion); compare against the REALIZED arrival rate of this
    # finite Poisson draw, not the asymptotic --rate
    window = max(1e-9, max(q.done_s for q in recs) - recs[0].arrival_s)
    sustained = len(recs) / window
    span = max(1e-9, recs[-1].arrival_s - recs[0].arrival_s)
    offered = (len(recs) - 1) / span if len(recs) > 1 else args.rate
    goodput = st.goodput_tokens / window
    p50, p95, p99 = (float(np.percentile(lat, q)) for q in (50, 95, 99))
    print(f"target={args.target} loop={args.loop} rate={args.rate:.2f} "
          f"(realized {offered:.2f}) q/s queries={len(recs)} fanout="
          f"{args.fanout if args.target == 'forecast' else 1}")
    print(f"breakdown host_ms={st.host_ms:.0f} device_ms={st.device_ms:.0f} "
          f"overlap_ms={st.overlap_ms:.1f}")
    print(f"sustained={sustained:.2f} queries/s | "
          f"rollouts/s={st.rollouts / window:.1f} | "
          f"tokens={st.tokens} | wall={wall:.1f}s")
    print(f"latency p50={p50:.2f}s p95={p95:.2f}s p99={p99:.2f}s "
          f"max={lat[-1]:.2f}s"
          + ("" if sustained >= 0.9 * offered else
             "  [engine saturated below the offered rate]"))
    print("statuses " + " ".join(
        f"{k}={statuses.get(k, 0)}"
        for k in ("ok", "failed", "cancelled", "deadline", "shed"))
        + f" | goodput_tok_s={goodput:.1f}")
    if args.bench_json:
        from benchmarks.run import _merge_bench_serving  # heavy: lazy
        row = {"offered_rate_qps": round(offered, 3),
               "sustained_qps": round(sustained, 3),
               "p50_s": round(p50, 4), "p95_s": round(p95, 4),
               "p99_s": round(p99, 4),
               "goodput_tok_s": round(goodput, 1),
               "loop": args.loop,
               "host_ms": round(st.host_ms, 1),
               "device_ms": round(st.device_ms, 1),
               "overlap_ms": round(st.overlap_ms, 1),
               "backend": jax.default_backend(),
               "deadline_s": args.deadline or None,
               "shed_queue": args.shed_queue
               if args.shed_queue >= 0 else None}
        row.update({f"n_{k}": statuses.get(k, 0)
                    for k in ("ok", "deadline", "shed")})
        key = (f"loadgen_{args.target}_overload"
               if (args.shed_queue >= 0 or args.deadline) else
               f"loadgen_{args.target}")
        if args.loop == "async":
            key += "_async"
        _merge_bench_serving({key: row})


if __name__ == "__main__":
    main()
