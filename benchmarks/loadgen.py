"""Open-loop Poisson load generator for the serving + forecast engines.

Closed-loop drivers (submit, drain, repeat — the benchmark legs in
``run.py``) measure capacity; an OPEN-loop driver measures what a rate
actually feels like: arrivals are drawn up front from a Poisson process
and submitted on schedule whether or not the engine has caught up, so
queueing delay shows up in latency instead of silently throttling the
offered rate. The arrival stream comes from the repo's own classical
thinning sampler (``repro.core.thinning``) over a homogeneous process —
the same machinery the paper benchmarks TPP-SD against, here generating
the traffic instead of serving it.

Each arrival is one QUERY: a fanout-K scenario group for the forecast
target (K rollouts of a shared event history through the wave-serving
TPP engine) or a prompt completion for the token serving target. The
report is sustained queries/s + rollouts/s against the offered rate,
with completion-latency percentiles.

  PYTHONPATH=src python -m benchmarks.loadgen --target forecast \
      --rate 2 --queries 12 --fanout 8
  PYTHONPATH=src python -m benchmarks.loadgen --target serving --rate 4
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.thinning import InhomPoisson, thinning_sample


def poisson_arrivals(rate: float, n: int, seed: int = 0) -> np.ndarray:
    """First ``n`` arrival times of a rate-``rate`` homogeneous Poisson
    process, sampled with the repo's thinning sampler (omega=0 makes
    ``InhomPoisson`` exactly homogeneous: lambda = A * b)."""
    proc = InhomPoisson(A=rate, b=1.0, omega=0.0)
    rng = np.random.default_rng(seed)
    horizon, times = 4.0 * n / max(rate, 1e-9), np.empty(0)
    while times.size < n:
        times, _ = thinning_sample(proc, horizon, np.random.default_rng(
            rng.integers(1 << 31)), max_events=4 * n)
        horizon *= 2
    return times[:n]


@dataclass
class _Query:
    qid: int
    arrival_s: float
    member_ids: List[str]
    submit_s: float = 0.0
    done_s: float = 0.0
    pending: set = field(default_factory=set)


def drive(engine, queries: List[Dict], rate: float, seed: int = 0):
    """Open-loop drive: submit query i at its Poisson arrival offset,
    stepping the engine in between; returns (per-query records, wall)."""
    arrivals = poisson_arrivals(rate, len(queries), seed)
    recs: List[_Query] = []
    next_q = 0
    t0 = time.perf_counter()
    while next_q < len(queries) or engine.scheduler.has_work():
        now = time.perf_counter() - t0
        while next_q < len(queries) and arrivals[next_q] <= now:
            ids = engine.submit(**queries[next_q])
            ids = ids if isinstance(ids, list) else [ids]
            recs.append(_Query(qid=next_q, arrival_s=arrivals[next_q],
                               member_ids=ids, submit_s=now,
                               pending=set(ids)))
            next_q += 1
        if engine.scheduler.has_work():
            for res in engine.step():
                for q in recs:
                    if res.request_id in q.pending:
                        q.pending.discard(res.request_id)
                        if not q.pending:
                            q.done_s = time.perf_counter() - t0
        elif next_q < len(queries):
            # idle gap until the next scheduled arrival
            time.sleep(min(0.01, max(0.0, arrivals[next_q] - now)))
    return recs, time.perf_counter() - t0


def build_forecast_engine(args):
    from repro.configs.base import TPPConfig
    from repro.models import tpp
    from repro.serving import ServingEngine

    cfg_t = TPPConfig(name="lg-t", encoder="thp", num_layers=2,
                      num_heads=2, d_model=32, d_ff=64, num_marks=5,
                      num_mix=16)
    cfg_d = cfg_t.replace(name="lg-d", num_layers=1, num_heads=1)
    pt = tpp.init_params(cfg_t, jax.random.PRNGKey(0))
    pd = tpp.init_params(cfg_d, jax.random.PRNGKey(1))
    eng = ServingEngine(cfg_t, pt, cfg_d, pd, method="sd",
                        max_batch=args.max_batch, gamma=2,
                        max_len=8 + args.budget + 2, page_size=4,
                        sched="grouped", prefix_cache=True)
    r = np.random.default_rng(args.seed)
    hist_t = np.cumsum(r.exponential(0.5, size=8)).astype(np.float32)
    hist_k = r.integers(0, 5, size=8).astype(np.int32)
    queries = [dict(prompt=hist_k, times=hist_t,
                    t_end=float(hist_t[-1]) + 4.0,
                    max_new_tokens=args.budget,
                    rng=jax.random.PRNGKey(100 + i), fanout=args.fanout)
               for i in range(args.queries)]
    return eng, queries


def build_serving_engine(args):
    from repro.configs import get_arch, smoke_variant
    from repro.models import registry
    from repro.serving import ServingEngine

    cfg_t = smoke_variant(get_arch("llama3.2-1b")).replace(num_layers=2)
    cfg_d = cfg_t.replace(num_layers=1)
    pt = registry.get_model(cfg_t).init_params(jax.random.PRNGKey(0))
    pd = registry.get_model(cfg_d).init_params(jax.random.PRNGKey(1))
    eng = ServingEngine(cfg_t, pt, cfg_d, pd, method="sd",
                        max_batch=args.max_batch, max_len=64, gamma=2)
    queries = [dict(prompt=jnp.arange(8, dtype=jnp.int32),
                    max_new_tokens=args.budget, rng=100 + i)
               for i in range(args.queries)]
    return eng, queries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--target", default="forecast",
                    choices=["forecast", "serving"])
    ap.add_argument("--rate", type=float, default=2.0,
                    help="offered arrival rate, queries/s (open loop)")
    ap.add_argument("--queries", type=int, default=12)
    ap.add_argument("--fanout", type=int, default=8,
                    help="rollouts per forecast query")
    ap.add_argument("--budget", type=int, default=8,
                    help="events/tokens per rollout")
    ap.add_argument("--max-batch", dest="max_batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    eng, queries = (build_forecast_engine(args) if args.target == "forecast"
                    else build_serving_engine(args))
    # warm the compile caches outside the timed window, then reset
    eng.submit(**queries[0])
    eng.run()
    eng.reset()

    recs, wall = drive(eng, queries, args.rate, args.seed)
    st = eng.stats()
    lat = np.sort(np.array([q.done_s - q.arrival_s for q in recs]))
    # sustained rate over the active window (first arrival -> last
    # completion); compare against the REALIZED arrival rate of this
    # finite Poisson draw, not the asymptotic --rate
    window = max(1e-9, max(q.done_s for q in recs) - recs[0].arrival_s)
    sustained = len(recs) / window
    span = max(1e-9, recs[-1].arrival_s - recs[0].arrival_s)
    offered = (len(recs) - 1) / span if len(recs) > 1 else args.rate
    print(f"target={args.target} rate={args.rate:.2f} "
          f"(realized {offered:.2f}) q/s queries={len(recs)} fanout="
          f"{args.fanout if args.target == 'forecast' else 1}")
    print(f"sustained={sustained:.2f} queries/s | "
          f"rollouts/s={st.rollouts / window:.1f} | "
          f"tokens={st.tokens} | wall={wall:.1f}s")
    print(f"latency p50={np.percentile(lat, 50):.2f}s "
          f"p95={np.percentile(lat, 95):.2f}s max={lat[-1]:.2f}s"
          + ("" if sustained >= 0.9 * offered else
             "  [engine saturated below the offered rate]"))


if __name__ == "__main__":
    main()
