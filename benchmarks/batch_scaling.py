"""Beyond-paper: whole-sequence vmap batching throughput of the jitted
TPP-SD sampler (the paper samples one sequence at a time).

  PYTHONPATH=src python -m benchmarks.batch_scaling
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import TPPConfig
from repro.data import synthetic as ds
from repro.sampling import SamplerSpec, build_sampler
from repro.train import trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--t-end", type=float, default=20.0)
    ap.add_argument("--gamma", type=int, default=10)
    ap.add_argument("--emax", type=int, default=256)
    ap.add_argument("--epochs", type=int, default=5)
    args = ap.parse_args()
    data = ds.make_dataset("hawkes", n_seqs=100, t_end=args.t_end)
    cfg_t = TPPConfig(encoder="thp", num_layers=4, num_heads=2, d_model=32,
                      d_ff=64, num_marks=1, num_mix=16)
    cfg_d = cfg_t.replace(num_layers=1, num_heads=1)
    tcfg = trainer.TPPTrainConfig(max_epochs=args.epochs)
    pt, _ = trainer.train_tpp(cfg_t, data, tcfg)
    pd, _ = trainer.train_tpp(cfg_d, data, tcfg)
    print("name,us_per_call,derived")
    for B in (1, 4, 16, 64):
        fn = build_sampler(
            SamplerSpec(method="sd", execution="vmap", t_end=args.t_end,
                        gamma=args.gamma, max_events=args.emax, batch=B),
            cfg_t, pt, cfg_d, pd)
        out = fn(jax.random.PRNGKey(0))
        jax.block_until_ready(out.times)
        t0 = time.perf_counter()
        out = fn(jax.random.PRNGKey(0))
        jax.block_until_ready(out.times)
        dt = time.perf_counter() - t0
        ev = out.stats().events
        print(f"batch_scaling/B{B},{dt / B * 1e6:.1f},"
              f"events={ev};events_per_sec={ev / dt:.0f};"
              f"seconds={dt:.3f}")


if __name__ == "__main__":
    main()
