"""Aggregate dry-run JSONs into the roofline table (EXPERIMENTS.md
section Roofline). Single-pod mesh only, per the spec; multi-pod runs are
summarized separately in section Dry-run.

  PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ARCH_ORDER = [
    "falcon-mamba-7b", "mistral-nemo-12b", "recurrentgemma-9b",
    "internvl2-26b", "seamless-m4t-medium", "llama3-405b",
    "granite-moe-1b-a400m", "phi3.5-moe-42b-a6.6b", "qwen2.5-32b",
    "llama3.2-1b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

IMPROVE_HINT = {
    "compute_s": "raise MXU utilization: larger per-chip tiles / fewer "
                 "pad-waste dims, or shard the dominant matmul wider",
    "memory_s": "cut HBM traffic: fuse elementwise chains, remat policy, "
                "bf16 intermediates, or shard activations (seq/context "
                "parallelism)",
    "collective_s": "reduce bytes on ICI: stop gathering FSDP weights per "
                    "step (2D weight sharding / replicate small params), "
                    "overlap collectives with compute, or reshard "
                    "activations instead of weights",
}


def load(dirname: str, mesh: str = "single"):
    rows = {}
    for f in glob.glob(os.path.join(dirname, "*.json")):
        d = json.load(open(f))
        if d.get("ok") and d.get("mesh") == mesh and \
                d.get("variant", "baseline") == "baseline":
            rows[(d["arch"], d["shape"])] = d
    return rows


def fmt_s(x):
    if x == 0:
        return "0"
    for unit, scale in [("s", 1.0), ("ms", 1e-3), ("us", 1e-6), ("ns", 1e-9)]:
        if x >= scale:
            return f"{x / scale:.2f}{unit}"
    return f"{x:.1e}s"


def table(rows, markdown=True):
    hdr = ["arch", "shape", "compute", "memory", "collective", "dominant",
           "MODEL_FLOPs/HLO", "HBM GiB/dev"]
    lines = []
    if markdown:
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    else:
        lines.append(",".join(hdr))
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = rows.get((arch, shape))
            if d is None:
                continue
            r = d["roofline"]
            mem_gib = (d["mem"]["argument_bytes"] + d["mem"]["temp_bytes"]
                       + d["mem"]["output_bytes"]) / 2**30
            row = [arch, shape, fmt_s(r["compute_s"]), fmt_s(r["memory_s"]),
                   fmt_s(r["collective_s"]),
                   d["dominant"].replace("_s", ""),
                   f"{d['useful_flops_ratio']:.3f}", f"{mem_gib:.1f}"]
            if markdown:
                lines.append("| " + " | ".join(row) + " |")
            else:
                lines.append(",".join(row))
    return "\n".join(lines)


def notes(rows):
    out = []
    for (arch, shape), d in sorted(rows.items()):
        dom = d["dominant"]
        out.append(f"- **{arch} x {shape}**: dominant={dom.replace('_s','')}"
                   f" -> {IMPROVE_HINT[dom]}.")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    rows = load(args.dir)
    print(table(rows, markdown=not args.csv))
    total = len(rows)
    doms = {}
    for d in rows.values():
        doms[d["dominant"]] = doms.get(d["dominant"], 0) + 1
    print(f"\n{total} single-pod baselines; dominant-term histogram: {doms}")


if __name__ == "__main__":
    main()
