"""End-to-end training driver: dataset -> target+draft training with
early stopping -> checkpoints -> evaluation -> AR vs TPP-SD sampling
report. This is the paper's full pipeline as one command.

  PYTHONPATH=src python examples/train_tpp.py --dataset multihawkes \
      --encoder attnhp --epochs 30 --gamma 10 --outdir runs/demo
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import json
import time

import jax
import numpy as np

from repro.configs.base import TPPConfig, paper_draft, paper_target
from repro.data import synthetic as ds
from repro import metrics as M
from repro.sampling import SamplerSpec, build_sampler
from repro.train import checkpoint, trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="hawkes",
                    choices=["poisson", "hawkes", "multihawkes",
                             "taobao_like", "amazon_like", "taxi_like",
                             "stackoverflow_like"])
    ap.add_argument("--encoder", default="thp",
                    choices=["thp", "sahp", "attnhp"])
    ap.add_argument("--paper-scale", action="store_true",
                    help="8-head/20-layer target (paper Sec. 5)")
    ap.add_argument("--epochs", type=int, default=15)
    ap.add_argument("--n-seqs", type=int, default=200)
    ap.add_argument("--t-end", type=float, default=20.0)
    ap.add_argument("--gamma", type=int, default=10)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--outdir", default="runs/tpp")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    data = ds.make_dataset(args.dataset, n_seqs=args.n_seqs,
                           t_end=args.t_end)
    if args.paper_scale:
        cfg_t = paper_target(args.encoder, data.num_marks)
        cfg_d = paper_draft(args.encoder, data.num_marks)
    else:
        cfg_t = TPPConfig(encoder=args.encoder, num_layers=4, num_heads=2,
                          d_model=32, d_ff=64, num_marks=data.num_marks,
                          num_mix=16)
        cfg_d = cfg_t.replace(num_layers=1, num_heads=1)

    tcfg = trainer.TPPTrainConfig(max_epochs=args.epochs,
                                  batch_size=args.batch)
    print(f"== training target ({cfg_t.num_layers}L/{cfg_t.num_heads}H) on "
          f"{args.dataset} ==")
    t0 = time.time()
    params_t, hist_t = trainer.train_tpp(cfg_t, data, tcfg, verbose=True)
    print(f"== training draft ({cfg_d.num_layers}L/{cfg_d.num_heads}H) ==")
    params_d, hist_d = trainer.train_tpp(cfg_d, data, tcfg, verbose=True)
    train_s = time.time() - t0
    checkpoint.save(os.path.join(args.outdir, "target.msgpack"), params_t)
    checkpoint.save(os.path.join(args.outdir, "draft.msgpack"), params_d)

    test_ll_t = trainer.model_loglik(cfg_t, params_t, data.test, data.t_end)
    test_ll_d = trainer.model_loglik(cfg_d, params_d, data.test, data.t_end)
    print(f"test loglik/seq: target {test_ll_t:.3f}  draft {test_ll_d:.3f}")

    B, EMAX = 16, 512
    base = SamplerSpec(execution="vmap", t_end=data.t_end, max_events=EMAX,
                       batch=B)
    ra = build_sampler(base.replace(method="ar"),
                       cfg_t, params_t)(jax.random.PRNGKey(1))
    rs = build_sampler(base.replace(method="sd", gamma=args.gamma),
                       cfg_t, params_t, cfg_d, params_d)(jax.random.PRNGKey(2))
    seqs_sd = rs.to_seqs()
    sd_stats = rs.stats()
    report = {
        "dataset": args.dataset, "encoder": args.encoder,
        "train_seconds": round(train_s, 1),
        "test_ll_target": test_ll_t, "test_ll_draft": test_ll_d,
        "mean_events_ar": float(np.mean(np.array(ra.lengths))),
        "mean_events_sd": float(np.mean(np.array(rs.lengths))),
        "alpha": sd_stats.acceptance_rate,
        "events_per_target_forward": sd_stats.events_per_forward,
    }
    if data.process is not None:
        report["ks_sd"] = M.ks_for_samples(data.process, seqs_sd)
    print(json.dumps(report, indent=2))
    with open(os.path.join(args.outdir, "report.json"), "w") as f:
        json.dump(report, f, indent=2)


if __name__ == "__main__":
    main()
