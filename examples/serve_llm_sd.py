"""Speculative serving of an LLM from the architecture zoo: the same
propose-verify engine as TPP-SD, discrete-token special case.

Serves a reduced llama3.2-1b-family target with a 1-layer draft and
reports acceptance rate + target-forwards-per-token.

  PYTHONPATH=src python examples/serve_llm_sd.py [--arch llama3.2-1b]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, smoke_variant
from repro.models import registry
from repro.sampling import SamplerSpec, build_sampler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=sorted(ARCHS))
    ap.add_argument("--gamma", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=48)
    args = ap.parse_args()

    cfg_t = smoke_variant(ARCHS[args.arch]).replace(num_layers=4)
    cfg_d = cfg_t.replace(num_layers=1)
    print(f"target: {cfg_t.name} 4L  draft: 1L  family={cfg_t.family}")
    mt, md = registry.get_model(cfg_t), registry.get_model(cfg_d)
    pt = mt.init_params(jax.random.PRNGKey(0))
    pd = md.init_params(jax.random.PRNGKey(1))
    prompt = jnp.arange(8, dtype=jnp.int32)

    base = SamplerSpec(domain="token", execution="host",
                       max_events=args.new_tokens, max_len=256)
    ar_fn = build_sampler(base.replace(method="ar"), cfg_t, pt)
    sd_fn = build_sampler(base.replace(method="sd", gamma=args.gamma),
                          cfg_t, pt, cfg_d, pd)
    t0 = time.time()
    ar = ar_fn(jax.random.PRNGKey(2), prompt).stats()
    t_ar = time.time() - t0
    t0 = time.time()
    sd = sd_fn(jax.random.PRNGKey(2), prompt).stats()
    t_sd = time.time() - t0
    print(f"AR : {ar.events} tokens in {t_ar:.2f}s "
          f"({ar.events} target forwards)")
    print(f"SD : {sd.events} tokens in {t_sd:.2f}s "
          f"({sd.rounds} target forwards, alpha={sd.acceptance_rate:.2f}, "
          f"{sd.events_per_forward:.2f} tokens/target-forward)")
    print("note: on this 1-core CPU the wall-clock gain tracks dispatch "
          "latency, not FLOPs; tokens/target-forward is the "
          "hardware-independent gain (= the GPU/TPU speedup driver).")


if __name__ == "__main__":
    main()
