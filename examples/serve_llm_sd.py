"""Speculative serving of an LLM from the architecture zoo: the same
propose-verify engine as TPP-SD, discrete-token special case — served
through the ``repro.serving`` continuous-batching engine.

Compares single-request AR vs SD, then streams a batch of concurrent
requests through the scheduler to show the continuous-batching win.

  PYTHONPATH=src python examples/serve_llm_sd.py [--arch llama3.2-1b]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, smoke_variant
from repro.models import registry
from repro.serving import ServeRequest, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=sorted(ARCHS))
    ap.add_argument("--gamma", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=48)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg_t = smoke_variant(ARCHS[args.arch]).replace(num_layers=4)
    cfg_d = cfg_t.replace(num_layers=1)
    print(f"target: {cfg_t.name} 4L  draft: 1L  family={cfg_t.family}")
    mt, md = registry.get_model(cfg_t), registry.get_model(cfg_d)
    pt = mt.init_params(jax.random.PRNGKey(0))
    pd = md.init_params(jax.random.PRNGKey(1))
    prompt = jnp.arange(8, dtype=jnp.int32)

    def serve(method, max_batch, n_req, cfg_d_=None, pd_=None):
        eng = ServingEngine(cfg_t, pt, cfg_d_, pd_, method=method,
                            max_batch=max_batch, max_len=256,
                            gamma=args.gamma)
        for i in range(n_req):
            eng.submit(ServeRequest(prompt=prompt,
                                    max_new_tokens=args.new_tokens,
                                    rng=100 + i))
        eng.run()
        return eng.stats()

    ar = serve("ar", 1, 1)
    sd = serve("sd", 1, 1, cfg_d, pd)
    print(f"AR 1-req : {ar.tokens} tokens in {ar.wall_s:.2f}s "
          f"({ar.target_forwards} target forwards)")
    print(f"SD 1-req : {sd.tokens} tokens in {sd.wall_s:.2f}s "
          f"({sd.target_forwards} target forwards, "
          f"alpha={sd.acceptance_rate:.2f}, "
          f"{sd.tokens_per_forward:.2f} tokens/target-forward)")
    batched = serve("sd", args.max_batch, args.requests, cfg_d, pd)
    print(f"SD {args.requests}-req continuous batching "
          f"(max_batch={args.max_batch}): {batched.tokens} tokens in "
          f"{batched.wall_s:.2f}s ({batched.target_forwards} target "
          f"forwards, {batched.tokens_per_forward:.2f} tokens/target-"
          f"forward, {batched.tokens_per_sec:.1f} tokens/sec)")
    print("note: on this 1-core CPU the wall-clock gain tracks dispatch "
          "latency, not FLOPs; tokens/target-forward is the "
          "hardware-independent gain (= the GPU/TPU speedup driver).")


if __name__ == "__main__":
    main()
