"""Quickstart: simulate a Hawkes process, train a CDF-based Transformer
TPP target + draft, then sample with AR and TPP-SD and compare.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import TPPConfig
from repro.data import synthetic as ds
from repro import metrics as M
from repro.sampling import SamplerSpec, build_sampler
from repro.train import trainer


def main():
    print("1) simulating Hawkes dataset via thinning ...")
    data = ds.make_dataset("hawkes", n_seqs=80, t_end=10.0, seed=0)
    print(f"   {len(data.train)} train sequences, "
          f"{np.mean([len(t) for t, _ in data.train]):.1f} events each")

    print("2) training target (4L) and draft (1L) models ...")
    cfg_t = TPPConfig(encoder="thp", num_layers=4, num_heads=2, d_model=32,
                      d_ff=64, num_marks=1, num_mix=16)
    cfg_d = cfg_t.replace(num_layers=1, num_heads=1)
    tcfg = trainer.TPPTrainConfig(max_epochs=5, batch_size=16)
    params_t, hist = trainer.train_tpp(cfg_t, data, tcfg, verbose=True)
    params_d, _ = trainer.train_tpp(cfg_d, data, tcfg)

    print("3) sampling 16 sequences with AR and TPP-SD (gamma=8) ...")
    B, EMAX = 16, 256
    base = SamplerSpec(execution="vmap", t_end=data.t_end, max_events=EMAX,
                       batch=B)
    ar_fn = build_sampler(base.replace(method="ar"), cfg_t, params_t)
    sd_fn = build_sampler(base.replace(method="sd", gamma=8),
                          cfg_t, params_t, cfg_d, params_d)
    ra = ar_fn(jax.random.PRNGKey(1))
    rs = sd_fn(jax.random.PRNGKey(2))
    seqs_ar, seqs_sd = ra.to_seqs(), rs.to_seqs()

    print("4) quality (time-rescaling KS vs ground truth):")
    n_ar = ra.stats().events
    sd_stats = rs.stats()
    n_sd = sd_stats.events
    print(f"   AR:     KS={M.ks_for_samples(data.process, seqs_ar):.4f} "
          f"(95% band {M.ks_confidence_band(n_ar):.4f}, n={n_ar})")
    print(f"   TPP-SD: KS={M.ks_for_samples(data.process, seqs_sd):.4f} "
          f"(95% band {M.ks_confidence_band(n_sd):.4f}, n={n_sd})")
    print(f"5) speed mechanism: acceptance rate "
          f"alpha={sd_stats.acceptance_rate:.2f}, "
          f"{sd_stats.events_per_forward:.2f} events per target forward "
          f"(AR = 1.0)")


if __name__ == "__main__":
    main()
