"""Quickstart: simulate a Hawkes process, train a CDF-based Transformer
TPP target + draft, then sample with AR and TPP-SD and compare.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import TPPConfig
from repro.core import sampler
from repro.data import synthetic as ds
from repro import metrics as M
from repro.train import trainer


def main():
    print("1) simulating Hawkes dataset via thinning ...")
    data = ds.make_dataset("hawkes", n_seqs=80, t_end=10.0, seed=0)
    print(f"   {len(data.train)} train sequences, "
          f"{np.mean([len(t) for t, _ in data.train]):.1f} events each")

    print("2) training target (4L) and draft (1L) models ...")
    cfg_t = TPPConfig(encoder="thp", num_layers=4, num_heads=2, d_model=32,
                      d_ff=64, num_marks=1, num_mix=16)
    cfg_d = cfg_t.replace(num_layers=1, num_heads=1)
    tcfg = trainer.TPPTrainConfig(max_epochs=5, batch_size=16)
    params_t, hist = trainer.train_tpp(cfg_t, data, tcfg, verbose=True)
    params_d, _ = trainer.train_tpp(cfg_d, data, tcfg)

    print("3) sampling 16 sequences with AR and TPP-SD (gamma=8) ...")
    B, EMAX = 16, 256
    ra = sampler.sample_ar_batch(cfg_t, params_t, jax.random.PRNGKey(1),
                                 data.t_end, EMAX, B)
    rs = sampler.sample_sd_batch(cfg_t, cfg_d, params_t, params_d,
                                 jax.random.PRNGKey(2), data.t_end, 8,
                                 EMAX, B)
    seqs_ar = [(np.array(ra.times[i, :ra.n[i]]),
                np.array(ra.types[i, :ra.n[i]])) for i in range(B)]
    seqs_sd = [(np.array(rs.times[i, :rs.n[i]]),
                np.array(rs.types[i, :rs.n[i]])) for i in range(B)]

    print("4) quality (time-rescaling KS vs ground truth):")
    n_ar = sum(len(t) for t, _ in seqs_ar)
    n_sd = sum(len(t) for t, _ in seqs_sd)
    print(f"   AR:     KS={M.ks_for_samples(data.process, seqs_ar):.4f} "
          f"(95% band {M.ks_confidence_band(n_ar):.4f}, n={n_ar})")
    print(f"   TPP-SD: KS={M.ks_for_samples(data.process, seqs_sd):.4f} "
          f"(95% band {M.ks_confidence_band(n_sd):.4f}, n={n_sd})")
    alpha = float(np.sum(np.array(rs.accepted))) / max(
        1, int(np.sum(np.array(rs.drafted))))
    epf = n_sd / max(1, int(np.sum(np.array(rs.rounds))))
    print(f"5) speed mechanism: acceptance rate alpha={alpha:.2f}, "
          f"{epf:.2f} events per target forward (AR = 1.0)")


if __name__ == "__main__":
    main()
