import os
import sys

# NOTE: do NOT set --xla_force_host_platform_device_count here; smoke tests
# and benches must see 1 device (only launch/dryrun.py forces 512).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)

try:
    import hypothesis  # noqa: F401
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

#: fuzz suites that silently vanish from the run when hypothesis is absent
_FUZZ_SUITES = ("test_property", "test_prefix_fuzz", "test_chaos_fuzz")


def pytest_terminal_summary(terminalreporter, exitstatus, config):
    if not _HAVE_HYPOTHESIS:
        terminalreporter.write_line(
            "repro: hypothesis not installed — fuzz suites skipped: "
            + ", ".join(_FUZZ_SUITES)
            + " (pip install -e .[dev] to enable)",
            yellow=True)
