"""Async double-buffered serving: ``run_async()`` == ``run()`` bitwise.

The pinned contract: the pipelined loop (dispatch round N, overlap host
staging, ONE batched fetch, commit at the fault barrier) only reorders
HOST work — round composition and every ``fold_in(rng, round_idx)``
draw are untouched — so ``run_async()`` commits token streams bitwise
identical to the synchronous ``run()``:

  - across the matrix method (ar | sd) x layout (paged | dense) x
    kernel (ref | pallas-interpret), chunked prefill on the paged legs
    (the deferred-first-token path rides the decode round as a lazy
    device scalar on BOTH loops);
  - under an injected ``step_error`` FaultPlan (the retry contract is
    loop-agnostic);
  - in the TPP (event-sequence) domain;
  - with the per-phase wall breakdown observable: the async loop books
    nonzero ``overlap_ms``, the sync loop books zero.

Streaming: ``ServeRequest.on_tokens`` chunks arrive in commit order and
concatenate to exactly the final ``ServeResult.tokens``, on both loops.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, TPPConfig
from repro.models import registry, tpp
from repro.serving import (FaultPlan, FaultSpec, ServeRequest,
                           ServingEngine)

RNG = jax.random.PRNGKey(0)


def _dense(num_layers=2, vocab=31, name="t", **kw):
    base = dict(name=name, family="dense", num_layers=num_layers,
                d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                vocab_size=vocab, dtype="float32", param_dtype="float32",
                remat=False)
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def pair():
    cfg_t, cfg_d = _dense(2), _dense(1, name="d")
    mt, md = registry.get_model(cfg_t), registry.get_model(cfg_d)
    return (cfg_t, cfg_d, mt.init_params(RNG),
            md.init_params(jax.random.PRNGKey(1)))


def _engine(pair, method, layout, kernel, **kw):
    cfg_t, cfg_d, pt, pd = pair
    kw.setdefault("max_len", 32)
    if layout == "paged":
        # chunked admission on the paged legs: prompts complete
        # mid-step and their first tokens take the DEFERRED path
        kw.setdefault("prefill_chunk", 3)
    if method == "ar":
        return ServingEngine(cfg_t, pt, method="ar", max_batch=3,
                             kv_layout=layout, kernel=kernel, **kw)
    return ServingEngine(cfg_t, pt, cfg_d, pd, method="sd", max_batch=3,
                         gamma=2, kv_layout=layout, kernel=kernel, **kw)


def _submit_all(eng, n_req=4, cb=None):
    return [eng.submit(ServeRequest(
        prompt=jnp.arange(5, dtype=jnp.int32), max_new_tokens=5 + i,
        rng=100 + i, temperature=1.0 + 0.1 * (i % 3), on_tokens=cb))
        for i in range(n_req)]


def _by_id(results):
    return {r.request_id: r for r in results}


MATRIX = [
    ("ar", "paged", "ref"),
    ("ar", "paged", "pallas"),
    ("ar", "dense", "ref"),
    ("sd", "paged", "ref"),
    ("sd", "paged", "pallas"),
    ("sd", "dense", "ref"),
]


@pytest.mark.parametrize("method,layout,kernel", MATRIX)
def test_async_bitwise_equals_sync(pair, method, layout, kernel):
    eng_s = _engine(pair, method, layout, kernel)
    order = _submit_all(eng_s)
    sync = _by_id(eng_s.run())

    eng_a = _engine(pair, method, layout, kernel)
    _submit_all(eng_a)
    polled = []
    got = _by_id(eng_a.run_async(poll=lambda: polled.append(1)))

    assert len(got) == len(sync) == len(order)
    for rid_s, rid_a in zip(sorted(sync), sorted(got)):
        assert sync[rid_s].ok and got[rid_a].ok
        np.testing.assert_array_equal(np.asarray(sync[rid_s].tokens),
                                      np.asarray(got[rid_a].tokens))
    assert polled, "the overlap window never ran the poll callback"
    # the breakdown is observable: async books overlap, sync books none
    assert eng_a.stats().overlap_ms > 0
    assert eng_a.stats().device_ms > 0
    assert eng_s.stats().overlap_ms == 0.0


@pytest.mark.parametrize("layout", ["paged", "dense"])
def test_async_bitwise_under_faults(pair, layout):
    """A step_error retried mid-run commits the same streams on both
    loops — the rollback contract is loop-agnostic."""
    base = _engine(pair, "sd", layout, "ref", fixed_window=True)
    _submit_all(base)
    want = [np.asarray(r.tokens) for r in sorted(base.run(),
                                                 key=lambda r: r.request_id)]
    for loop in ("sync", "async"):
        plan = FaultPlan(FaultSpec(kind="step_error", step=2, times=2))
        eng = _engine(pair, "sd", layout, "ref", fixed_window=True,
                      faults=plan)
        _submit_all(eng)
        res = sorted(eng.run() if loop == "sync" else eng.run_async(),
                     key=lambda r: r.request_id)
        assert plan.injected >= 1
        assert eng.stats().retries >= 1
        for r, w in zip(res, want):
            assert r.ok, r.error
            np.testing.assert_array_equal(np.asarray(r.tokens), w)


def test_async_bitwise_tpp():
    cfg_t = TPPConfig(name="as-t", encoder="thp", num_layers=2,
                      num_heads=2, d_model=16, d_ff=32, num_marks=3,
                      num_mix=4)
    cfg_d = cfg_t.replace(name="as-d", num_layers=1, num_heads=1)
    pt = tpp.init_params(cfg_t, jax.random.PRNGKey(0))
    pd = tpp.init_params(cfg_d, jax.random.PRNGKey(1))
    r = np.random.default_rng(3)
    times = np.cumsum(r.exponential(0.5, size=4)).astype(np.float32)
    marks = r.integers(0, 3, size=4).astype(np.int32)

    def go(loop):
        eng = ServingEngine(cfg_t, pt, cfg_d, pd, method="sd",
                            max_batch=2, max_len=24, gamma=2)
        for i in range(3):
            eng.submit(prompt=marks, times=times, max_new_tokens=6,
                       rng=50 + i)
        res = eng.run() if loop == "sync" else eng.run_async()
        return sorted(res, key=lambda x: x.request_id)

    for a, b in zip(go("sync"), go("async")):
        assert a.ok and b.ok
        np.testing.assert_array_equal(np.asarray(a.tokens),
                                      np.asarray(b.tokens))
        np.testing.assert_array_equal(np.asarray(a.times),
                                      np.asarray(b.times))


# ---------------------------------------------------------------------------
# streaming callbacks
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("loop", ["sync", "async"])
@pytest.mark.parametrize("method,layout", [("sd", "paged"),
                                           ("ar", "paged"),
                                           ("sd", "dense")])
def test_streaming_chunks_prefix_of_final(pair, loop, method, layout):
    """on_tokens chunks arrive in commit order; their concatenation IS
    the final token stream (including the deferred first token on the
    chunked paged legs)."""
    chunks = {}

    def cb(rid, toks):
        assert toks, "empty streaming chunk"
        chunks.setdefault(rid, []).append(list(toks))

    eng = _engine(pair, method, layout, "ref")
    _submit_all(eng, cb=cb)
    res = _by_id(eng.run() if loop == "sync" else eng.run_async())
    assert set(chunks) == set(res)
    for rid, r in res.items():
        assert r.ok
        streamed = [t for c in chunks[rid] for t in c]
        np.testing.assert_array_equal(np.asarray(streamed, np.int32),
                                      np.asarray(r.tokens))
        # every chunk was a prefix extension: cumulative lengths grow
        lens = np.cumsum([len(c) for c in chunks[rid]])
        assert lens[-1] == r.n and all(lens[:-1] < r.n)


def test_streaming_fanout_members_get_callbacks(pair):
    chunks = {}

    def cb(rid, toks):
        chunks.setdefault(rid, []).append(list(toks))

    eng = _engine(pair, "sd", "paged", "ref")
    ids = eng.submit(ServeRequest(
        prompt=jnp.arange(5, dtype=jnp.int32), max_new_tokens=5,
        rng=7, on_tokens=cb), fanout=3)
    res = _by_id(eng.run_async())
    assert set(chunks) == set(ids)
    for rid in ids:
        streamed = [t for c in chunks[rid] for t in c]
        np.testing.assert_array_equal(np.asarray(streamed, np.int32),
                                      np.asarray(res[rid].tokens))
