"""Chunked-paged prefill == dense-staging prefill, token-bitwise.

PR 5 routes admission THROUGH the paged pool: prompts stream in as
fixed-size chunks (``transformer.prefill_paged``) instead of staging a
dense batch-1 prefill and scattering it. These tests pin that the
committed token streams do not move by a bit — on the reference kernels
AND in Pallas interpret mode, including MoE (capacity never binding),
rejection-driven rollback right after a chunked admission, per-step
prefill budgets (mixed prefill+decode rounds), and deferral under page
pressure with long prompts. Also pins the kernel's query-block tiling:
a tiled chunk computes exactly the untiled chunk.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import registry
from repro.serving import ServeRequest, ServingEngine

RNG = jax.random.PRNGKey(0)


def _dense(num_layers=2, vocab=31, name="t", **kw):
    base = dict(name=name, family="dense", num_layers=num_layers,
                d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                vocab_size=vocab, dtype="float32", param_dtype="float32",
                remat=False)
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def dense_pair():
    cfg_t, cfg_d = _dense(2), _dense(1, name="d")
    mt, md = registry.get_model(cfg_t), registry.get_model(cfg_d)
    return (cfg_t, cfg_d, mt.init_params(RNG),
            md.init_params(jax.random.PRNGKey(1)))


def _serve(cfg_t, cfg_d, pt, pd, n_req=8, max_batch=4, max_len=64,
           gamma=4, plen=5, **engine_kw):
    """The standard mixed-budget workload; tokens by submit order."""
    eng = ServingEngine(cfg_t, pt, cfg_d, pd, max_batch=max_batch,
                        max_len=max_len, gamma=gamma, **engine_kw)
    order = []
    for i in range(n_req):
        order.append(eng.submit(ServeRequest(
            prompt=jnp.arange(plen, dtype=jnp.int32),
            max_new_tokens=5 + i, rng=100 + i,
            temperature=1.0 + 0.1 * (i % 3))))
    by_id = {r.request_id: r for r in eng.run()}
    return eng, [np.asarray(by_id[rid].tokens) for rid in order], \
        [by_id[rid] for rid in order]


# ---------------------------------------------------------------------------
# chunked == one-shot staging, token-bitwise
# ---------------------------------------------------------------------------

def test_chunked_ref_matches_staging_bitwise(dense_pair):
    """chunk=3 over 5-token prompts (one full + one padded partial
    chunk) must commit EXACTLY the staging engine's streams — the
    workload has draft != target, so rejection rollback runs right
    after chunked admissions."""
    cfg_t, cfg_d, pt, pd = dense_pair
    eng_s, toks_s, _ = _serve(cfg_t, cfg_d, pt, pd, kv_layout="paged",
                              kernel="ref")
    eng_c, toks_c, _ = _serve(cfg_t, cfg_d, pt, pd, kv_layout="paged",
                              kernel="ref", prefill_chunk=3)
    for a, b in zip(toks_s, toks_c):
        np.testing.assert_array_equal(a, b)
    assert eng_s.stats().accepted == eng_c.stats().accepted
    # the staging buffer is gone: no dense prefill compiled, yet the
    # prefill token accounting agrees
    assert eng_c.stats().prefill_tokens == 8 * 5
    assert len(eng_c.pool_t.free) == eng_c.pool_t.n_pages - 1


def test_chunked_matches_dense_layout_bitwise(dense_pair):
    """Transitivity made explicit: chunked-paged == the legacy dense
    per-slot layout (the PR4 oracle), not just == paged staging."""
    cfg_t, cfg_d, pt, pd = dense_pair
    _, toks_d, _ = _serve(cfg_t, cfg_d, pt, pd, kv_layout="dense",
                          kernel="ref")
    _, toks_c, _ = _serve(cfg_t, cfg_d, pt, pd, kv_layout="paged",
                          kernel="ref", prefill_chunk=2)
    for a, b in zip(toks_d, toks_c):
        np.testing.assert_array_equal(a, b)


def test_chunked_pallas_matches_staging_pallas(dense_pair):
    """The production configuration: chunked admission under the Pallas
    spec-verify kernel (interpret on CPU) == one-shot staging under the
    same kernel, bitwise."""
    cfg_t, cfg_d, pt, pd = dense_pair
    _, toks_s, _ = _serve(cfg_t, cfg_d, pt, pd, kv_layout="paged",
                          kernel="pallas")
    eng_c, toks_c, _ = _serve(cfg_t, cfg_d, pt, pd, kv_layout="paged",
                              kernel="pallas", prefill_chunk=3)
    assert eng_c.policy.use_pallas
    for a, b in zip(toks_s, toks_c):
        np.testing.assert_array_equal(a, b)


def test_chunked_moe_matches_staging(dense_pair):
    """MoE: per-sequence dispatch + non-binding capacity
    (capacity_factor >= E/K) keeps chunked == one-shot bitwise — the
    drop pattern is the only group-shape-dependent quantity."""
    kw = dict(family="moe", num_experts=4, num_experts_per_tok=2,
              capacity_factor=2.0)
    cfg_t = _dense(2, name="moe-ct", **kw)
    cfg_d = _dense(1, name="moe-cd", **kw)
    pt = registry.get_model(cfg_t).init_params(RNG)
    pd = registry.get_model(cfg_d).init_params(jax.random.PRNGKey(1))
    _, toks_s, _ = _serve(cfg_t, cfg_d, pt, pd, n_req=4, kv_layout="paged",
                          kernel="ref")
    _, toks_c, _ = _serve(cfg_t, cfg_d, pt, pd, n_req=4, kv_layout="paged",
                          kernel="ref", prefill_chunk=2)
    for a, b in zip(toks_s, toks_c):
        np.testing.assert_array_equal(a, b)


def test_ar_chunked_matches_staging(dense_pair):
    cfg_t, _, pt, _ = dense_pair

    def run(**kw):
        eng = ServingEngine(cfg_t, pt, method="ar", max_batch=2,
                            max_len=64, kv_layout="paged", kernel="ref",
                            **kw)
        order = [eng.submit(ServeRequest(
            prompt=jnp.arange(7, dtype=jnp.int32), max_new_tokens=6,
            rng=7 + i)) for i in range(3)]
        by_id = {r.request_id: r for r in eng.run()}
        return [np.asarray(by_id[rid].tokens) for rid in order]

    for a, b in zip(run(), run(prefill_chunk=4)):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# mixed rounds under a prefill budget
# ---------------------------------------------------------------------------

def test_prefill_budget_spreads_ttft_not_lengths(dense_pair):
    """A tight per-step budget makes long prompts take several steps to
    admit (mixed prefill+decode rounds): TTFT moves, every budget is
    still honored, and the prefill-token accounting is identical.
    (Streams are NOT compared bitwise here: a budget changes which
    slots share a round, and the batch window clamp — max remaining
    budget over the batch — legitimately shifts round boundaries; the
    per-request rng contract keeps the distribution identical, which
    test_serving.py pins.)"""
    cfg_t, cfg_d, pt, pd = dense_pair
    kw = dict(max_batch=2, max_len=64, gamma=3, plen=16, n_req=4,
              kv_layout="paged", kernel="ref")
    eng_f, toks_fast, res_fast = _serve(cfg_t, cfg_d, pt, pd,
                                        prefill_chunk=4, **kw)
    eng_s, toks_slow, res_slow = _serve(cfg_t, cfg_d, pt, pd,
                                        prefill_chunk=4, prefill_budget=4,
                                        **kw)
    for i, (a, b) in enumerate(zip(toks_fast, toks_slow)):
        assert len(a) == len(b) == 5 + i
    assert eng_f.stats().prefill_tokens == eng_s.stats().prefill_tokens \
        == 4 * 16
    # unbudgeted: a prompt admits within its admission step
    assert res_fast[0].ttft_rounds == 1
    # budget 4 tok/step over a 16-token prompt: >= 4 steps of chunk
    # work before the first token of request 0
    assert res_slow[0].ttft_rounds > res_fast[0].ttft_rounds
    assert res_slow[0].ttft_rounds >= 4


def test_decode_rounds_run_beside_prefilling_slots(dense_pair):
    """While one slot is still streaming its prompt (budgeted), the
    other slot must keep committing tokens — the mixed-round core."""
    cfg_t, cfg_d, pt, pd = dense_pair
    eng = ServingEngine(cfg_t, pt, cfg_d, pd, max_batch=2, max_len=64,
                        gamma=3, kv_layout="paged", kernel="ref",
                        prefill_chunk=4, prefill_budget=4)
    fast = eng.submit(ServeRequest(prompt=jnp.arange(4, dtype=jnp.int32),
                                   max_new_tokens=12, rng=1))
    slow = eng.submit(ServeRequest(prompt=jnp.arange(24, dtype=jnp.int32),
                                   max_new_tokens=4, rng=2))
    progressed_together = False
    while eng.scheduler.has_work():
        eng.step()
        phases = {st.request.request_id: st.phase
                  for _, st in eng.scheduler.active()}
        outs = {st.request.request_id: len(st.out)
                for _, st in eng.scheduler.active()}
        if (phases.get(slow) == "prefill" and outs.get(fast, 0) > 1):
            progressed_together = True
    assert progressed_together
    by_id = {r.request_id: r for r in eng._results}
    assert by_id[fast].n == 12 and by_id[slow].n == 4
    # the long prompt took several budgeted steps to reach token 1
    assert by_id[slow].ttft_rounds >= 24 // 4 - 1


# ---------------------------------------------------------------------------
# long prompts under page pressure (deferral regression)
# ---------------------------------------------------------------------------

def test_long_prompts_under_page_pressure_defer_and_complete(dense_pair):
    """Under-provisioned pool + long prompts + chunked admission: the
    lifetime reservation still caps concurrency, deferred requests land
    as pages free, the pool drains clean — and because chunked and
    staged admission produce the SAME deferral schedule (reservations
    are taken before any prefill work on both paths), the tight chunked
    engine is token-bitwise the tight staged engine."""
    cfg_t, cfg_d, pt, pd = dense_pair
    kw = dict(max_batch=4, max_len=64, gamma=3, kv_layout="paged",
              kernel="ref", page_size=8, n_pages=9)   # 8 usable pages

    def run(**extra):
        eng = ServingEngine(cfg_t, pt, cfg_d, pd, **kw, **extra)
        order = [eng.submit(ServeRequest(
            prompt=jnp.arange(24, dtype=jnp.int32), max_new_tokens=8,
            rng=50 + i)) for i in range(5)]
        max_active = 0
        while eng.scheduler.has_work():
            eng.step()
            max_active = max(max_active, len(eng.scheduler.active()))
        by_id = {r.request_id: r for r in eng._results}
        return eng, [np.asarray(by_id[rid].tokens) for rid in order], \
            max_active

    eng_stg, toks_stg, act_stg = run()
    eng_chk, toks_chk, act_chk = run(prefill_chunk=8)
    # each request reserves ceil((24+8)/8) = 4 pages -> 2 concurrent
    assert act_stg == act_chk == 2
    for a, b in zip(toks_stg, toks_chk):
        np.testing.assert_array_equal(a, b)
    for eng in (eng_stg, eng_chk):
        assert len(eng.pool_t.free) == eng.pool_t.n_pages - 1
        assert len(eng.pool_d.free) == eng.pool_d.n_pages - 1
        assert len(eng._results) == 5
        for r in eng._results:
            assert r.n == 8


# ---------------------------------------------------------------------------
# accounting + validation
# ---------------------------------------------------------------------------

def test_prefill_token_and_ttft_accounting(dense_pair):
    cfg_t, cfg_d, pt, pd = dense_pair
    eng, _, results = _serve(cfg_t, cfg_d, pt, pd, n_req=6, plen=5,
                             kv_layout="paged", kernel="ref",
                             prefill_chunk=3)
    st = eng.stats()
    assert st.prefills == 6
    assert st.prefill_tokens == 6 * 5
    assert st.prefill_s > 0.0
    assert st.prefill_tokens_per_sec > 0.0
    for r in results:
        assert r.ttft_rounds >= 1
        assert r.ttft_s > 0.0
    # staging path accounts the same token figure
    eng_s, _, _ = _serve(cfg_t, cfg_d, pt, pd, n_req=6, plen=5,
                         kv_layout="paged", kernel="ref")
    assert eng_s.stats().prefill_tokens == 6 * 5


def test_chunked_requires_paged_layout(dense_pair):
    cfg_t, cfg_d, pt, pd = dense_pair
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(cfg_t, pt, cfg_d, pd, kv_layout="dense",
                      prefill_chunk=4)
    with pytest.raises(ValueError, match="prefill_chunk"):
        ServingEngine(cfg_t, pt, cfg_d, pd, prefill_chunk=0)


def test_sched_policies_thread_through_engine(dense_pair):
    """Policies change completion ORDER, never a request's tokens: under
    sjf with one slot the short job must finish first even when
    submitted last, and both streams equal their fifo twins."""
    cfg_t, cfg_d, pt, pd = dense_pair

    def run(sched):
        eng = ServingEngine(cfg_t, pt, cfg_d, pd, max_batch=1, max_len=64,
                            gamma=3, kv_layout="paged", kernel="ref",
                            sched=sched)
        long_id = eng.submit(ServeRequest(
            prompt=jnp.arange(5, dtype=jnp.int32), max_new_tokens=12,
            rng=11))
        short_id = eng.submit(ServeRequest(
            prompt=jnp.arange(5, dtype=jnp.int32), max_new_tokens=3,
            rng=12, priority=7))
        res = eng.run()
        by_id = {r.request_id: r for r in res}
        first = "long" if res[0].request_id == long_id else "short"
        return first, (np.asarray(by_id[long_id].tokens),
                       np.asarray(by_id[short_id].tokens))

    first_fifo, toks_fifo = run("fifo")
    first_sjf, toks_sjf = run("sjf")
    first_prio, toks_prio = run("priority")
    assert first_fifo == "long"           # fifo: submission order
    assert first_sjf == "short"           # sjf runs the short job first
    assert first_prio == "short"          # priority=7 also jumps ahead
    for a, b in zip(toks_fifo, toks_sjf):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(toks_fifo, toks_prio):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# kernel: query-block tiling is exact
# ---------------------------------------------------------------------------

def test_spec_verify_kernel_query_tiling_is_exact():
    """Tiling the query axis (long prefill chunks) must not move a bit:
    every query sweeps the same pages in the same order."""
    from repro.kernels.spec_verify_attention import (
        spec_verify_attention_pallas, spec_verify_attention_ref)
    S, C, H, KV, Dh, page, NB = 2, 12, 4, 2, 8, 4, 8
    P = S * NB + 1
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (S, C, H, Dh))
    k_pages = jax.random.normal(ks[1], (P, page, KV, Dh))
    v_pages = jax.random.normal(ks[2], (P, page, KV, Dh))
    bt = jnp.arange(1, S * NB + 1, dtype=jnp.int32).reshape(S, NB)
    lens = jnp.asarray([5, 11], jnp.int32)
    full = spec_verify_attention_pallas(q, k_pages, v_pages, bt, lens,
                                        interpret=True)
    for bq in (4, 5, 16):                 # divides, ragged, over-sized
        tiled = spec_verify_attention_pallas(q, k_pages, v_pages, bt, lens,
                                             interpret=True, bq=bq)
        np.testing.assert_array_equal(np.asarray(full), np.asarray(tiled))
    ref = spec_verify_attention_ref(q, k_pages, v_pages, bt, lens)
    np.testing.assert_allclose(np.asarray(full), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
