"""Production-mesh sharded sampling.

In-process: mesh resolution and rule plumbing on however many devices
the test process sees. Subprocess (device count must be set before JAX
initializes): a forced 4-host-device mesh where ``execution="sharded"``
must (a) place params via the logical-axis rules, (b) shard the seed
batch over the data axis, and (c) produce the SAME output as the vmap
executor — event streams bitwise (lengths + types), times to kernel
tolerance (partitioned kernels tile floats differently; the replicated
non-divisible fallback — which must warn instead of silently
replicating — stays fully bitwise).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_resolve_sample_mesh_has_data_and_model_axes():
    from repro.launch.mesh import resolve_sample_mesh
    mesh = resolve_sample_mesh()
    assert set(mesh.axis_names) >= {"data", "model"}
    assert mesh.size == min(__import__("jax").device_count(), 256)


def test_sharded_fn_exposes_mesh_and_rules(tiny_tpp_pair):
    """The built sharded sampler carries its mesh/rules/seed-sharding so
    callers (benchmarks, tests) can audit the placement."""
    from repro.sampling import SamplerSpec, build_sampler
    cfg_t, cfg_d, pt, pd = tiny_tpp_pair
    fn = build_sampler(SamplerSpec(method="sd", execution="sharded",
                                   t_end=2.0, gamma=3, max_events=16,
                                   batch=4), cfg_t, pt, cfg_d, pd)
    assert fn.mesh is not None and "data" in fn.mesh.axis_names
    assert fn.rules.rule_axis_size("batch") >= 1
    # the seed sharding was built through the "batch" rule
    assert fn.in_sharding.mesh.axis_names == fn.mesh.axis_names


@pytest.fixture(scope="module")
def tiny_tpp_pair():
    import jax
    from repro.configs.base import TPPConfig
    from repro.models import tpp
    cfg_t = TPPConfig(encoder="thp", num_layers=2, num_heads=2, d_model=16,
                      d_ff=32, num_marks=3, num_mix=4)
    cfg_d = cfg_t.replace(num_layers=1, num_heads=1)
    return (cfg_t, cfg_d, tpp.init_params(cfg_t, jax.random.PRNGKey(0)),
            tpp.init_params(cfg_d, jax.random.PRNGKey(1)))


_FORCED_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import warnings
    import jax
    import numpy as np
    from repro.configs.base import TPPConfig
    from repro.launch.mesh import make_debug_mesh, resolve_sample_mesh
    from repro.models import tpp
    from repro.sampling import SamplerSpec, build_sampler

    assert jax.device_count() == 4
    cfg_t = TPPConfig(encoder="thp", num_layers=2, num_heads=2, d_model=16,
                      d_ff=32, num_marks=3, num_mix=4)
    cfg_d = cfg_t.replace(num_layers=1, num_heads=1)
    pt = tpp.init_params(cfg_t, jax.random.PRNGKey(0))
    pd = tpp.init_params(cfg_d, jax.random.PRNGKey(1))
    out = {}

    def stream_parity(bv, bs):
        ns = np.array(bv.lengths)
        prefix_types = all(
            np.array_equal(np.array(bv.types[i, :n]),
                           np.array(bs.types[i, :n]))
            for i, n in enumerate(ns))
        prefix_times = all(
            np.allclose(np.array(bv.times[i, :n]),
                        np.array(bs.times[i, :n]), rtol=2e-5, atol=1e-5)
            for i, n in enumerate(ns))
        return {
            "lengths_bitwise": bool(np.array_equal(ns,
                                                   np.array(bs.lengths))),
            "types_bitwise": prefix_types,
            "times_close": prefix_times,
            "times_bitwise": bool(np.array_equal(np.array(bv.times),
                                                 np.array(bs.times))),
        }

    # data-only 4-way mesh: whole-sequence fan-out, stream parity
    mesh = make_debug_mesh(data=4, model=1)
    for method in ("ar", "sd"):
        kw = (cfg_d, pd) if method == "sd" else ()
        base = SamplerSpec(method=method, t_end=2.0, gamma=3, max_events=16,
                           batch=4)
        bv = build_sampler(base.replace(execution="vmap"),
                           cfg_t, pt, *kw)(jax.random.PRNGKey(3))
        fs = build_sampler(base.replace(execution="sharded"),
                           cfg_t, pt, *kw, mesh=mesh)
        bs = fs(jax.random.PRNGKey(3))
        out[method] = stream_parity(bv, bs)
        out[f"{method}_seed_spec"] = [
            None if a is None else str(a) for a in fs.in_sharding.spec]
    # params went through the logical-axis rules: the heads dim of wq is
    # mapped to the mesh's model axis (kept because 2 % 1 == 0)
    wq_spec = fs.rules.spec(("layers", None, "heads", "qkv"),
                            dims=tuple(pt["layers"]["wq"].shape))
    out["wq_spec"] = [None if a is None else str(a) for a in wq_spec]

    # non-divisible batch: warn + replicate fallback, output still exact
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        base = SamplerSpec(method="sd", t_end=2.0, gamma=3, max_events=16,
                           batch=6)
        f6 = build_sampler(base.replace(execution="sharded"),
                           cfg_t, pt, cfg_d, pd, mesh=mesh)
    out["nondiv_warned"] = any("does not divide" in str(x.message)
                               for x in w)
    out["nondiv_seed_spec"] = [
        None if a is None else str(a) for a in f6.in_sharding.spec]
    b6s = f6(jax.random.PRNGKey(5))
    b6v = build_sampler(base.replace(execution="vmap"),
                        cfg_t, pt, cfg_d, pd)(jax.random.PRNGKey(5))
    # replicated fallback keeps the vmap kernel shapes -> fully bitwise
    out["nondiv_bitwise"] = bool(
        np.array_equal(np.array(b6v.times), np.array(b6s.times)))

    # default resolution on 4 devices: the (2, 2) debug mesh — params
    # genuinely model-sharded; streams must agree with vmap exactly
    mesh_auto = resolve_sample_mesh()
    out["auto_shape"] = {k: int(v) for k, v in
                         dict(mesh_auto.shape).items()}
    base = SamplerSpec(method="sd", t_end=2.0, gamma=3, max_events=16,
                       batch=4)
    fa = build_sampler(base.replace(execution="sharded"),
                       cfg_t, pt, cfg_d, pd)       # mesh=None -> resolved
    ba = fa(jax.random.PRNGKey(3))
    bv = build_sampler(base.replace(execution="vmap"),
                       cfg_t, pt, cfg_d, pd)(jax.random.PRNGKey(3))
    out["auto_lengths_equal"] = bool(np.array_equal(
        np.array(bv.lengths), np.array(ba.lengths)))
    out["auto_types_equal"] = bool(np.array_equal(
        np.array(bv.types), np.array(ba.types)))
    out["auto_times_close"] = bool(np.allclose(
        np.array(bv.times), np.array(ba.times), rtol=1e-4, atol=1e-4))
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def forced_mesh_out():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run([sys.executable, "-c", _FORCED_MESH_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("method", ["ar", "sd"])
@pytest.mark.slow
def test_sharded_equals_vmap_on_4_devices(forced_mesh_out, method):
    """Acceptance bar: sharded output == vmap output with the seed batch
    actually partitioned over the data axis. The event STREAMS are
    bitwise (identical lengths and event types — every discrete choice
    agrees); event times agree to kernel tolerance only, because a
    4-way-partitioned batch runs B=1 matmul kernels per device whose
    float tiling differs ~1e-6 from the vmap executor's B=4 kernels (the
    replicated non-divisible fallback below, which keeps vmap's kernel
    shapes, IS fully bitwise — pinning that the difference is kernel
    tiling, not streams)."""
    assert forced_mesh_out[method]["lengths_bitwise"] is True
    assert forced_mesh_out[method]["types_bitwise"] is True
    assert forced_mesh_out[method]["times_close"] is True
    assert forced_mesh_out[f"{method}_seed_spec"][0] == "data"


@pytest.mark.slow
def test_params_placed_via_logical_rules(forced_mesh_out):
    assert forced_mesh_out["wq_spec"][2] == "model"


@pytest.mark.slow
def test_nondivisible_batch_warns_and_replicates(forced_mesh_out):
    assert forced_mesh_out["nondiv_warned"] is True
    # replicate fallback: no axis on the seed's batch dim
    assert forced_mesh_out["nondiv_seed_spec"][0] is None
    assert forced_mesh_out["nondiv_bitwise"] is True


@pytest.mark.slow
def test_default_mesh_resolution_on_4_devices(forced_mesh_out):
    """mesh=None resolves the (2, 2) debug mesh; model-sharded params
    must not perturb the sampled streams (types/lengths exact; times to
    partitioned-matmul tolerance)."""
    assert forced_mesh_out["auto_shape"] == {"data": 2, "model": 2}
    assert forced_mesh_out["auto_lengths_equal"] is True
    assert forced_mesh_out["auto_types_equal"] is True
    assert forced_mesh_out["auto_times_close"] is True
