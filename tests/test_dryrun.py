"""Dry-run launch-path regression tests (subprocess: device count must be
set before JAX initializes)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _dryrun(*extra):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", *extra,
           "--no-calibrate"]
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=540)
    assert r.returncode == 0, r.stderr[-2000:]
    return json.loads(r.stdout)


@pytest.mark.slow
def test_decode_dryrun_single_pod():
    d = _dryrun("--arch", "llama3.2-1b", "--shape", "decode_32k",
                "--mesh", "single")
    assert d["ok"] and d["chips"] == 256
    assert d["flops_per_dev"] > 0 and d["coll_bytes_per_dev"] > 0


@pytest.mark.slow
def test_decode_dryrun_serving_mesh_kills_cache_reshard():
    """EXPERIMENTS §Perf pair 3: the (data,kv,tp) serving mesh must keep
    the KV cache in place — collective bytes drop by >100x vs baseline."""
    base = _dryrun("--arch", "llama3.2-1b", "--shape", "decode_32k",
                   "--mesh", "single")
    serve = _dryrun("--arch", "llama3.2-1b", "--shape", "decode_32k",
                    "--mesh", "serve")
    assert serve["coll_bytes_per_dev"] * 100 < base["coll_bytes_per_dev"]
    assert serve["roofline"]["memory_s"] < base["roofline"]["memory_s"]


@pytest.mark.slow
def test_train_dryrun_multi_pod():
    d = _dryrun("--arch", "llama3.2-1b", "--shape", "train_4k",
                "--mesh", "multi")
    assert d["ok"] and d["chips"] == 512
    assert "all-reduce" in d["coll_by_type"]
