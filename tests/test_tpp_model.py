"""CDF-based Transformer TPP model tests (paper Sec. 4.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TPPConfig, paper_draft, paper_target
from repro.models import tpp

RNG = jax.random.PRNGKey(0)
ENCODERS = ["thp", "sahp", "attnhp"]


def _cfg(enc, **kw):
    base = dict(encoder=enc, num_layers=2, num_heads=2, d_model=16, d_ff=32,
                num_marks=3, num_mix=4)
    base.update(kw)
    return TPPConfig(**base)


def _seq(n=10):
    times = jnp.cumsum(jax.random.uniform(RNG, (n,), minval=0.1, maxval=1.0))
    types = jax.random.randint(jax.random.fold_in(RNG, 1), (n,), 0, 3)
    return times, types


@pytest.mark.parametrize("enc", ENCODERS)
def test_incremental_extend_matches_full_encode(enc):
    cfg = _cfg(enc)
    p = tpp.init_params(cfg, RNG)
    times, types = _seq()
    enc_t = jnp.concatenate([jnp.zeros(1), times])
    enc_k = jnp.concatenate([jnp.full((1,), 3, jnp.int32), types])
    h_full = tpp.encode(cfg, p, enc_t, enc_k)
    cache = tpp.init_cache(cfg, 16)
    h1, cache = tpp.extend(cfg, p, cache, enc_t[:4], enc_k[:4])
    h2, cache = tpp.extend(cfg, p, cache, enc_t[4:], enc_k[4:])
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2])),
                               np.asarray(h_full), atol=1e-5)


@pytest.mark.parametrize("enc", ENCODERS)
def test_loglik_finite_grads(enc):
    cfg = _cfg(enc)
    p = tpp.init_params(cfg, RNG)
    times, types = _seq()
    mask = jnp.ones_like(times)
    ll = tpp.loglik(cfg, p, times, types, mask, 12.0)
    assert bool(jnp.isfinite(ll))
    g = jax.grad(lambda p: -tpp.loglik(cfg, p, times, types, mask, 12.0))(p)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))


def test_loglik_respects_mask():
    """padding events must not change the likelihood."""
    cfg = _cfg("thp")
    p = tpp.init_params(cfg, RNG)
    times, types = _seq(6)
    mask = jnp.ones(6)
    ll1 = tpp.loglik(cfg, p, times, types, mask, 10.0)
    times_pad = jnp.concatenate([times, jnp.zeros(3)])
    types_pad = jnp.concatenate([types, jnp.zeros(3, jnp.int32)])
    mask_pad = jnp.concatenate([mask, jnp.zeros(3)])
    ll2 = tpp.loglik(cfg, p, times_pad, types_pad, mask_pad, 10.0)
    # survival term reads h[n]; the BOS+masked-causal encoder makes the
    # padded-history states identical at the valid positions
    np.testing.assert_allclose(float(ll1), float(ll2), rtol=1e-5)


def test_survival_term_decreases_loglik_for_longer_horizon():
    cfg = _cfg("thp")
    p = tpp.init_params(cfg, RNG)
    times, types = _seq(5)
    mask = jnp.ones(5)
    ll_short = tpp.loglik(cfg, p, times, types, mask, float(times[-1]) + 0.1)
    ll_long = tpp.loglik(cfg, p, times, types, mask, float(times[-1]) + 50.0)
    assert float(ll_long) <= float(ll_short)


def test_interval_params_sigma_clipped():
    cfg = _cfg("thp", sigma_min=1e-2, sigma_max=5.0)
    p = tpp.init_params(cfg, RNG)
    h = jax.random.normal(RNG, (7, cfg.d_model)) * 100.0
    mix = tpp.interval_params(cfg, p, h)
    assert float(mix.sigma.min()) >= 1e-2 - 1e-6
    assert float(mix.sigma.max()) <= 5.0 + 1e-6
    np.testing.assert_allclose(np.asarray(jnp.exp(mix.log_w).sum(-1)), 1.0,
                               rtol=1e-5)


def test_paper_configs():
    t = paper_target("attnhp", num_marks=5)
    d = paper_draft("attnhp", num_marks=5)
    assert t.num_layers == 20 and t.num_heads == 8
    assert d.num_layers == 1 and d.num_heads == 1
    assert t.d_model == 64 and t.num_mix == 64  # paper Sec. C.2


@pytest.mark.parametrize("enc", ENCODERS)
def test_temporal_encoding_shapes_and_finiteness(enc):
    cfg = _cfg(enc)
    p = tpp.init_params(cfg, RNG)
    t = jnp.array([0.0, 0.5, 100.0, 1e4])
    z = tpp.temporal_encoding(cfg, p, t)
    assert z.shape == (4, cfg.d_model)
    assert bool(jnp.isfinite(z).all())
    assert float(jnp.abs(z).max()) <= 1.0 + 1e-5
