"""The ``repro.serving`` continuous-batching engine: scheduler policy
units, end-to-end serving over the model zoo, and the central invariant
— batched serving samples the SAME per-request distribution as
single-request serving (which both equal target AR sampling)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _stats import chisq as _chisq

from repro.configs.base import ModelConfig
from repro.models import registry
from repro.serving import (ServeRequest, ServingEngine, Scheduler)

RNG = jax.random.PRNGKey(0)


def _dense(num_layers=2, vocab=31, name="t"):
    return ModelConfig(name=name, family="dense", num_layers=num_layers,
                       d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                       vocab_size=vocab, dtype="float32",
                       param_dtype="float32", remat=False)


@pytest.fixture(scope="module")
def dense_pair():
    cfg_t, cfg_d = _dense(2), _dense(1, name="d")
    mt, md = registry.get_model(cfg_t), registry.get_model(cfg_d)
    return (cfg_t, cfg_d, mt.init_params(RNG),
            md.init_params(jax.random.PRNGKey(1)))


def _req(i, n=8, plen=5):
    return ServeRequest(prompt=jnp.arange(plen, dtype=jnp.int32),
                        max_new_tokens=n, rng=100 + i)


# ---------------------------------------------------------------------------
# scheduler units (pure bookkeeping, no models)
# ---------------------------------------------------------------------------

def test_scheduler_fifo_admission_order():
    s = Scheduler(max_batch=2, max_len=64)
    reqs = [_req(i) for i in range(5)]
    for r in reqs:
        s.submit(r)
    placed = s.admit()
    assert [st.request.request_id for _, st in placed] \
        == [reqs[0].request_id, reqs[1].request_id]
    assert s.pending_count == 3
    # nothing more fits until a slot frees
    assert s.admit() == []


def test_scheduler_slot_reuse_on_completion():
    s = Scheduler(max_batch=2, max_len=64)
    for i in range(4):
        s.submit(_req(i))
    first = s.admit()
    freed_slot = first[0][0]
    done = s.retire(freed_slot)
    assert done.request.request_id == first[0][1].request.request_id
    nxt = s.admit()
    # exactly one free slot -> exactly one admission, into the freed slot
    assert len(nxt) == 1 and nxt[0][0] == freed_slot
    assert {i for i, _ in s.active()} == {0, 1}


def test_scheduler_mixed_lengths_and_validation():
    s = Scheduler(max_batch=4, max_len=32)
    s.submit(_req(0, n=4, plen=8))
    s.submit(_req(1, n=24, plen=8))
    with pytest.raises(ValueError, match="max_len"):
        s.submit(_req(2, n=25, plen=8))
    placed = s.admit()
    assert len(placed) == 2
    assert s.has_work()
    for i, _ in list(s.active()):
        s.retire(i)
    assert not s.has_work()


# ---------------------------------------------------------------------------
# engine end-to-end
# ---------------------------------------------------------------------------

def test_continuous_batching_serves_more_requests_than_slots(dense_pair):
    """The acceptance bar: max_batch=4 serving 8 concurrent requests with
    continuous batching at tokens/target-forward > 1.5."""
    cfg_t, cfg_d, pt, pd = dense_pair
    eng = ServingEngine(cfg_t, pt, cfg_d, pd, max_batch=4, max_len=64,
                        gamma=4)
    budgets = {}
    for i in range(8):
        rid = eng.submit(_req(i, n=6 + i))
        budgets[rid] = 6 + i
    results = eng.run()
    assert len(results) == 8
    for r in results:
        assert r.n == budgets[r.request_id]
    st = eng.stats()
    assert st.requests_completed == 8 and st.prefills == 8
    assert st.tokens == sum(budgets.values())
    assert st.tokens_per_forward > 1.5
    # more requests than slots => slots were reused across the run
    assert st.target_forwards < sum(budgets.values())


def test_mixed_budgets_admit_midstream(dense_pair):
    """A short request retiring mid-run must hand its slot to the queue
    without draining the batch."""
    cfg_t, cfg_d, pt, pd = dense_pair
    eng = ServingEngine(cfg_t, pt, cfg_d, pd, max_batch=2, max_len=64,
                        gamma=3)
    rid_short = eng.submit(_req(0, n=2))
    rid_long = eng.submit(_req(1, n=20))
    rid_queued = eng.submit(_req(2, n=4))  # admitted when the short retires
    seen = []
    while eng.scheduler.has_work():
        seen.extend(r.request_id for r in eng.step())
    assert seen[0] == rid_short
    assert seen[-1] == rid_long
    assert set(seen) == {rid_short, rid_long, rid_queued}
    st = eng.stats()
    assert st.tokens == 2 + 20 + 4


def test_tight_max_len_budget_stays_in_bounds(dense_pair):
    """prompt + max_new_tokens == max_len with a wide draft window: the
    engine must clamp the window near the end instead of letting the
    cache's modulo slot indexing wrap over the prompt's KV entries."""
    cfg_t, cfg_d, pt, pd = dense_pair
    eng = ServingEngine(cfg_t, pt, cfg_d, pd, max_batch=2, max_len=32,
                        gamma=4)
    for i in range(3):
        eng.submit(ServeRequest(prompt=jnp.arange(4, dtype=jnp.int32),
                                max_new_tokens=28, rng=40 + i))
    results = eng.run()
    assert len(results) == 3
    for r in results:
        assert r.n == 28
        assert np.all(np.asarray(r.tokens) < cfg_t.vocab_size)
    # every slot's cache length stayed within the buffer
    assert int(np.max(np.asarray(eng.pool_t.lens))) <= 32


def test_draft_forward_counter_matches_host_loop_convention(dense_pair):
    """EngineStats bugfix: a round drafts gamma tokens, so it counts
    gamma draft forwards (the trailing cache-maintenance extend is not a
    drafting forward) — the same convention as the host loops' `drafted`
    counter in sampling/loops.py. For a single-slot engine the two
    counters must therefore be EQUAL, and in general draft_forwards is
    the per-round sum of the (shared) batched window."""
    cfg_t, cfg_d, pt, pd = dense_pair
    eng = ServingEngine(cfg_t, pt, cfg_d, pd, max_batch=1, max_len=64,
                        gamma=3)
    eng.submit(_req(0, n=10))
    eng.run()
    st = eng.stats()
    assert st.draft_forwards == st.drafted > 0
    # batched: per-request drafted splits the shared window across slots
    engb = ServingEngine(cfg_t, pt, cfg_d, pd, max_batch=2, max_len=64,
                         gamma=3)
    for i in range(2):
        engb.submit(_req(i, n=10))
    results = engb.run()
    stb = engb.stats()
    assert stb.drafted == sum(r.drafted for r in results)
    assert stb.draft_forwards <= stb.drafted   # == gamma * rounds, not
    assert stb.draft_forwards > 0              # gamma+1 per round


def test_engine_reset_reuses_pool_and_replays_identically(dense_pair):
    """reset() drops request state but keeps the allocated pools; the
    same submissions then produce the same tokens."""
    cfg_t, cfg_d, pt, pd = dense_pair
    eng = ServingEngine(cfg_t, pt, cfg_d, pd, max_batch=2, max_len=64,
                        gamma=3)
    eng.submit(_req(0, n=8))
    first = [int(t) for t in eng.run()[0].tokens]
    pool_t = eng.pool_t
    eng.reset()
    assert eng.pool_t is pool_t
    if eng.kv_layout == "paged":
        assert eng.pool_t.pages is not None    # page arrays kept
    else:
        assert eng.pool_t.tree is not None
    assert eng.stats().tokens == 0
    eng.submit(_req(0, n=8))
    assert [int(t) for t in eng.run()[0].tokens] == first


def test_identical_models_accept_everything_batched(dense_pair):
    cfg_t, _, pt, _ = dense_pair
    eng = ServingEngine(cfg_t, pt, cfg_t, pt, max_batch=3, max_len=64,
                        gamma=4)
    for i in range(5):
        eng.submit(_req(i, n=12))
    for r in eng.run():
        assert r.accepted == r.drafted
    assert eng.stats().acceptance_rate == 1.0


@pytest.mark.parametrize("family,extra", [
    ("ssm", dict(num_heads=0, num_kv_heads=0, d_ff=0, ssm_state=8)),
    ("hybrid", dict(block_pattern=("rec", "rec", "attn"), lru_width=24,
                    sliding_window=16, num_kv_heads=1, num_layers=4)),
])
def test_replay_families_batched_serving(family, extra):
    """Recurrent-state families roll back by replay; the pool must stay
    correct across slots (identical models => zero rejections)."""
    kw = dict(name="x", family=family, num_layers=2, d_model=32, num_heads=4,
              num_kv_heads=2, d_ff=64, vocab_size=31, dtype="float32",
              param_dtype="float32", remat=False)
    kw.update(extra)
    cfg = ModelConfig(**kw)
    p = registry.get_model(cfg).init_params(RNG)
    eng = ServingEngine(cfg, p, cfg, p, max_batch=2, max_len=64, gamma=3)
    for i in range(3):
        eng.submit(_req(i, n=8))
    for r in eng.run():
        assert r.n == 8 and r.accepted == r.drafted


# ---------------------------------------------------------------------------
# distribution equivalence: batched == single-request == target AR
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_pair_with_marginals():
    """Small-vocab pair + the analytic first/second-token marginals of
    TARGET AR sampling after the fixed prompt."""
    V = 13
    cfg_t = _dense(2, vocab=V)
    cfg_d = _dense(1, vocab=V, name="d")
    mt, md = registry.get_model(cfg_t), registry.get_model(cfg_d)
    pt = mt.init_params(RNG)
    pd = md.init_params(jax.random.PRNGKey(9))
    prompt = jnp.arange(4, dtype=jnp.int32)
    lt, cache = mt.prefill(pt, {"tokens": prompt[None]}, 32)
    p0 = np.array(jax.nn.softmax(lt[0, -1]))
    p1 = np.zeros(V)
    for k in range(V):
        lg, _ = mt.extend(pt, cache, jnp.array([[k]], jnp.int32))
        p1 += p0[k] * np.array(jax.nn.softmax(lg[0, -1]))
    return cfg_t, cfg_d, pt, pd, prompt, p0, p1


def _first_two_tokens(cfg_t, cfg_d, pt, pd, prompt, seeds, *, max_batch,
                      draft_policy="fixed"):
    # budget 4 so the first round runs a full gamma=2 window (the engine
    # clamps the draft window to the remaining budget)
    if max_batch == 1:
        out = []
        for s in seeds:
            eng = ServingEngine(cfg_t, pt, cfg_d, pd, max_batch=1,
                                max_len=32, gamma=2,
                                draft_policy=draft_policy)
            eng.submit(ServeRequest(prompt=prompt, max_new_tokens=4, rng=s))
            out.append(eng.run()[0])
        return np.array([[r.tokens[0], r.tokens[1]] for r in out])
    eng = ServingEngine(cfg_t, pt, cfg_d, pd, max_batch=max_batch,
                        max_len=32, gamma=2, draft_policy=draft_policy)
    ids = [eng.submit(ServeRequest(prompt=prompt, max_new_tokens=4, rng=s))
           for s in seeds]
    res = {r.request_id: r for r in eng.run()}
    return np.array([[res[i].tokens[0], res[i].tokens[1]] for i in ids])


def test_batched_matches_single_request_distribution(
        tiny_pair_with_marginals):
    """Fixed per-request rngs: the batched engine must sample the same
    distribution as single-request serving — both are chi-squared
    against the ANALYTIC target-AR marginals for the first two
    generated tokens (the second token exercises the full
    draft/verify/bonus path)."""
    cfg_t, cfg_d, pt, pd, prompt, p0, p1 = tiny_pair_with_marginals
    V = len(p0)
    N = 300
    seeds = [1000 + i for i in range(N)]
    single = _first_two_tokens(cfg_t, cfg_d, pt, pd, prompt, seeds,
                               max_batch=1)
    batched = _first_two_tokens(cfg_t, cfg_d, pt, pd, prompt, seeds,
                                max_batch=4)
    for toks, probs in [(single[:, 0], p0), (batched[:, 0], p0),
                        (single[:, 1], p1), (batched[:, 1], p1)]:
        cnt = np.bincount(toks.astype(int), minlength=V)
        assert _chisq(cnt, probs).pvalue > 1e-3, (cnt / N, probs)
    # per-request rng streams are independent of batch composition, so
    # the two paths agree far beyond distribution (allow a small slack
    # for platform-dependent batched-matmul numerics)
    assert np.mean(single == batched) > 0.95


def test_adaptive_policy_preserves_distribution(tiny_pair_with_marginals):
    """draft_policy='adaptive' changes only the window schedule, never
    the sampled distribution."""
    cfg_t, cfg_d, pt, pd, prompt, p0, p1 = tiny_pair_with_marginals
    V = len(p0)
    N = 250
    toks = _first_two_tokens(cfg_t, cfg_d, pt, pd, prompt,
                             [5000 + i for i in range(N)], max_batch=4,
                             draft_policy="adaptive")
    cnt1 = np.bincount(toks[:, 1].astype(int), minlength=V)
    assert _chisq(cnt1, p1).pvalue > 1e-3, (cnt1 / N, p1)


def test_temperature_is_per_request(dense_pair):
    """Temperature ~0 must make a request greedy even when it shares a
    batch with temperature-1 requests."""
    cfg_t, cfg_d, pt, pd = dense_pair
    greedy = []
    for trial in range(3):
        eng = ServingEngine(cfg_t, pt, cfg_d, pd, max_batch=3, max_len=64,
                            gamma=2)
        eng.submit(ServeRequest(prompt=jnp.arange(5, dtype=jnp.int32),
                                max_new_tokens=4, temperature=1e-4,
                                rng=70 + trial))
        for i in range(2):
            eng.submit(ServeRequest(prompt=jnp.arange(5, dtype=jnp.int32),
                                    max_new_tokens=4, temperature=1.0,
                                    rng=80 + 10 * trial + i))
        res = sorted(eng.run(), key=lambda r: r.request_id)
        greedy.append(tuple(int(t) for t in res[0].tokens))
    assert greedy[0] == greedy[1] == greedy[2]
