"""Chaos fuzz (hypothesis): random fault plans + cancel schedules.

Two properties, checked over randomized schedules rather than the
hand-picked ones in ``test_chaos``:

  - pool lifecycle: random ensure/truncate/free/fork schedules
    interleaved with the fault harness's ``seize_free``/``restore_free``
    cycles keep the page bookkeeping airtight — every page's refcount
    equals the number of block-table entries holding it, and
    free list + seized list together hold exactly the refcount-0 pages
    (each once);
  - engine chaos: under ANY generated ``FaultPlan`` plus an optional
    mid-flight cancellation, ``run()`` never raises, every result
    carries a legal status, "ok" streams are BITWISE the fault-free
    baseline's, non-"ok" streams are bitwise prefixes of it, and the
    pools leak zero pages.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import registry
from repro.serving import (FAULT_KINDS, DisaggServingEngine, FaultPlan,
                           FaultSpec, RESULT_STATUSES, ServeRequest,
                           ServingEngine)
from repro.serving.kv_pool import PagedKVCachePool

settings.register_profile("chaos", max_examples=10, deadline=None)
settings.load_profile("chaos")

PAGE, SLOTS, MAXLEN = 4, 3, 16


def _cfg(num_layers=2, name="t"):
    return ModelConfig(name=name, family="dense", num_layers=num_layers,
                       d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                       vocab_size=31, dtype="float32",
                       param_dtype="float32", remat=False)


# ---------------------------------------------------------------------------
# pool lifecycle under seize/restore cycles
# ---------------------------------------------------------------------------

def _check_books(pool, seized):
    """Refcounts == table entries; free+seized == the refcount-0 pages."""
    counts = np.zeros(pool.n_pages, np.int64)
    for s in range(SLOTS):
        for b in range(int(pool.n_blocks[s])):
            counts[pool.tables[s, b]] += 1
    counts[0] = 0                              # null page: never counted
    np.testing.assert_array_equal(counts, pool.refcount)
    out = sorted(list(pool.free) + [p for ps in seized for p in ps])
    want = sorted(p for p in range(1, pool.n_pages)
                  if pool.refcount[p] == 0)
    assert out == want, "free+seized != refcount-0 pages"


_POOL_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("ensure"), st.integers(0, SLOTS - 1),
                  st.integers(1, MAXLEN)),
        st.tuples(st.just("truncate"), st.integers(0, SLOTS - 1),
                  st.integers(0, MAXLEN)),
        st.tuples(st.just("free"), st.integers(0, SLOTS - 1)),
        st.tuples(st.just("fork"), st.integers(0, SLOTS - 1),
                  st.integers(0, SLOTS - 1)),
        st.tuples(st.just("transfer"), st.integers(0, SLOTS - 1),
                  st.integers(0, SLOTS - 1)),
        st.tuples(st.just("seize")),
        st.tuples(st.just("restore")),
    ),
    min_size=1, max_size=40)


@given(ops=_POOL_OPS)
def test_pool_books_exact_under_seize_cycles(ops):
    pool = PagedKVCachePool(SLOTS, _cfg(1), page_size=PAGE,
                            max_len=MAXLEN)
    seized = []
    for op in ops:
        kind = op[0]
        if kind == "ensure":
            _, slot, n = op
            need = -(-n // PAGE) - int(pool.n_blocks[slot])
            try:
                pool.ensure_blocks(slot, n)
            except RuntimeError:
                # legal only when the free list really can't cover it
                # (e.g. mid-seize) — anything else is a leak/deadlock
                assert need > len(pool.free)
        elif kind == "truncate":
            _, slot, n = op
            pool.truncate(slot, min(n, int(pool.lens[slot])))
        elif kind == "free":
            pool.free_slot(op[1])
        elif kind == "fork":
            _, src, dst = op
            if src != dst and int(pool.lens[dst]) == 0 \
                    and int(pool.n_blocks[dst]) == 0 \
                    and int(pool.lens[src]) > 0:
                pool.fork(src, dst, int(pool.lens[src]))
        elif kind == "transfer":
            _, src, dst = op
            if src != dst and int(pool.lens[dst]) == 0 \
                    and int(pool.n_blocks[dst]) == 0:
                pool.transfer_slot(src, dst)
        elif kind == "seize":
            seized.append(pool.seize_free())
        elif kind == "restore":
            if seized:
                pool.restore_free(seized.pop())
        _check_books(pool, seized)
    while seized:                              # harness end_step contract
        pool.restore_free(seized.pop())
    _check_books(pool, seized)


# ---------------------------------------------------------------------------
# engine chaos: random plans + cancellation, survivors bitwise
# ---------------------------------------------------------------------------

N_REQ = 4
_STATE = {}


def _pair():
    if "pair" not in _STATE:
        cfg_t, cfg_d = _cfg(2), _cfg(1, name="d")
        mt, md = registry.get_model(cfg_t), registry.get_model(cfg_d)
        _STATE["pair"] = (cfg_t, cfg_d,
                          mt.init_params(jax.random.PRNGKey(0)),
                          md.init_params(jax.random.PRNGKey(1)))
    return _STATE["pair"]


def _run(faults=None, cancel_idx=None, disagg=False):
    cfg_t, cfg_d, pt, pd = _pair()
    if disagg:
        # prefill worker on slot 0, decode on 1-2: the handoff barrier
        # is live, so handoff_error specs actually fire
        eng = DisaggServingEngine(cfg_t, pt, cfg_d, pd, max_batch=3,
                                  max_len=32, gamma=2, kv_layout="paged",
                                  kernel="ref", fixed_window=True,
                                  prefill_slots=1, faults=faults)
    else:
        eng = ServingEngine(cfg_t, pt, cfg_d, pd, max_batch=3, max_len=32,
                            gamma=2, kv_layout="paged", kernel="ref",
                            fixed_window=True, faults=faults)
    order = [eng.submit(ServeRequest(
        prompt=jnp.arange(5, dtype=jnp.int32), max_new_tokens=5 + i,
        rng=100 + i, temperature=1.0 + 0.1 * (i % 3)))
        for i in range(N_REQ)]
    results = []
    if cancel_idx is not None:
        results += eng.step()
        c = eng.cancel(order[cancel_idx])
        if c is not None:
            results.append(c)
    results += eng.run()
    return eng, order, {r.request_id: r for r in results}


def _baseline(disagg=False):
    key = "base_disagg" if disagg else "base"
    if key not in _STATE:
        _, order, by_id = _run(disagg=disagg)
        _STATE[key] = [np.asarray(by_id[rid].tokens) for rid in order]
    return _STATE[key]


_SPEC = st.builds(
    FaultSpec,
    kind=st.sampled_from(FAULT_KINDS),
    step=st.integers(1, 4),
    times=st.integers(1, 2),
    slot=st.integers(0, 2),
    seconds=st.just(0.001))


def _assert_chaos_contract(specs, cancel_idx, disagg):
    ref = _baseline(disagg=disagg)
    plan = FaultPlan(*specs)
    eng, order, by_id = _run(faults=plan, cancel_idx=cancel_idx,
                             disagg=disagg)
    for i, rid in enumerate(order):
        res = by_id.get(rid)
        assert res is not None, "request vanished without a result"
        assert res.status in RESULT_STATUSES
        got = np.asarray(res.tokens)
        if res.ok:
            np.testing.assert_array_equal(got, ref[i])
        else:
            # failed/cancelled/deadline streams stop early but never
            # diverge: a bitwise prefix of the fault-free stream
            assert got.shape[0] <= ref[i].shape[0]
            np.testing.assert_array_equal(got, ref[i][:got.shape[0]])
    for pool in (eng.pool_t, eng.pool_d):
        assert int(pool.refcount.sum()) == 0
        assert len(pool.free) == pool.n_pages - 1
    if disagg:
        assert len(eng._handoffs) == 0, "parked handoff leaked"


@given(specs=st.lists(_SPEC, min_size=1, max_size=2),
       cancel_idx=st.one_of(st.none(), st.integers(0, N_REQ - 1)))
def test_engine_survivors_bitwise_under_random_chaos(specs, cancel_idx):
    _assert_chaos_contract(specs, cancel_idx, disagg=False)


@given(specs=st.lists(_SPEC, min_size=1, max_size=2),
       cancel_idx=st.one_of(st.none(), st.integers(0, N_REQ - 1)))
def test_disagg_survivors_bitwise_under_random_chaos(specs, cancel_idx):
    """Same property with the prefill/decode split engaged; the fault
    alphabet (``FAULT_KINDS``) now includes ``handoff_error``, which is
    only live here — the handoff barrier is a disagg-only fault
    point."""
    _assert_chaos_contract(specs, cancel_idx, disagg=True)
