"""Sharding-rule unit tests + a small-mesh integration test that lowers a
sharded train step in a subprocess (device count must be set before JAX
initializes, so it cannot run in-process)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _mesh_stub(sizes):
    class M:
        axis_names = tuple(sizes)
        shape = dict(sizes)
    return M()


def test_rules_divisible_or_replicate():
    from repro.distributed.sharding import Rules
    mesh = _mesh_stub({"data": 16, "model": 16})
    r = Rules.__new__(Rules)
    r.mesh = mesh
    r.rules = {"heads": ("model",), "batch": ("data",), "vocab": ("model",)}
    # divisible -> sharded
    assert r.spec(("batch", "heads"), (256, 32)) == \
        __import__("jax").sharding.PartitionSpec("data", "model")
    # 40 heads % 16 != 0 -> replicated fallback
    assert r.spec(("batch", "heads"), (256, 40))[1] is None
    # odd vocab -> replicated
    assert r.spec((None, "vocab"), (1, 49155))[1] is None
    # batch=1 -> replicated
    assert r.spec(("batch", None), (1, 5))[0] is None


def test_rules_no_axis_reuse():
    """one mesh axis must not shard two dims of the same array."""
    from repro.distributed.sharding import Rules
    mesh = _mesh_stub({"data": 4, "model": 4})
    r = Rules.__new__(Rules)
    r.mesh = mesh
    r.rules = {"heads": ("model",), "mlp": ("model",)}
    spec = r.spec(("heads", "mlp"), (16, 16))
    used = [s for s in spec if s is not None]
    assert len(set(used)) == len(used)


@pytest.mark.slow
def test_small_mesh_lower_compile_with_collectives():
    """8 forced host devices, 2x4 mesh: a sharded train step must lower,
    compile, and contain cross-device collectives."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.configs import ARCHS, smoke_variant
        from repro.distributed.sharding import Rules
        from repro.launch.dryrun import build, collective_bytes
        # NOTE: importing repro.launch.dryrun resets XLA_FLAGS to 512
        # host devices before JAX initializes; just take the first 8.
        devices = np.asarray(jax.devices()[:8]).reshape(2, 4)
        mesh = jax.sharding.Mesh(devices, ("data", "model"))
        from repro.models import registry
        from repro.train import optimizer as opt
        cfg = smoke_variant(ARCHS["llama3.2-1b"]).replace(
            vocab_size=512, num_layers=2)
        model = registry.get_model(cfg)
        rules = Rules(mesh, fsdp=True)
        params_s = registry.abstract_params(cfg)
        from repro.launch.dryrun import shardings_for
        p_shard = shardings_for(rules, model.logical_axes(), params_s)
        optim = opt.adam(1e-3)
        state_s = jax.eval_shape(optim.init, params_s)
        from jax.sharding import NamedSharding, PartitionSpec as P
        repl = NamedSharding(mesh, P())
        s_shard = type(state_s)(repl, p_shard, p_shard)
        B, S = 8, 16
        batch_s = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                   "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        b_shard = {k: NamedSharding(mesh, P("data", None)) for k in batch_s}
        def train_step(params, state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: model.loss_fn(p, batch))(params)
            params, state = optim.update(grads, state, params)
            return params, state, loss
        with mesh:
            jitted = jax.jit(train_step, in_shardings=(p_shard, s_shard,
                                                       b_shard),
                             out_shardings=(p_shard, s_shard, repl))
            compiled = jitted.lower(params_s, state_s, batch_s).compile()
        total, by_type = collective_bytes(compiled.as_text())
        print(json.dumps({"coll_bytes": total,
                          "types": sorted(by_type)}))
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=420)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["coll_bytes"] > 0
    assert "all-gather" in out["types"] or "all-reduce" in out["types"]
