"""Training-stack tests: optimizer, TPP trainer, checkpointing, data."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TPPConfig
from repro.data import synthetic as ds
from repro.train import checkpoint, optimizer as opt, trainer


def test_adam_converges_on_quadratic():
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.5)}
    target = {"w": jnp.array([1.0, 1.0]), "b": jnp.array(0.0)}
    optim = opt.adam(0.1)
    state = optim.init(params)

    def loss(p):
        return (jnp.sum((p["w"] - target["w"]) ** 2)
                + (p["b"] - target["b"]) ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state = optim.update(g, state, params)
    assert float(loss(params)) < 1e-4


def test_adam_clip_limits_update():
    params = {"w": jnp.zeros(3)}
    optim = opt.adam(1.0, clip_norm=1e-3)
    state = optim.init(params)
    g = {"w": jnp.full(3, 1e6)}
    p2, _ = optim.update(g, state, params)
    # clipped grad -> bounded first step (~lr since adam normalizes)
    assert float(jnp.abs(p2["w"]).max()) <= 1.1


def test_cosine_warmup_schedule():
    sched = opt.cosine_warmup(10, 100)
    assert float(sched(jnp.int32(0))) == 0.0
    assert abs(float(sched(jnp.int32(10))) - 1.0) < 1e-6
    assert float(sched(jnp.int32(100))) <= 0.11


def test_dataset_simulation_and_batching():
    data = ds.make_dataset("multihawkes", n_seqs=20, t_end=5.0, seed=1)
    assert data.num_marks == 2
    assert len(data.train) == 16 and len(data.val) == 2 and len(data.test) == 2
    b = next(ds.batches(data.train, 4, 32))
    assert b["times"].shape == (4, 32)
    assert set(b) == {"times", "types", "mask"}
    # masked positions zero, valid times increasing
    valid = b["mask"][0].astype(bool)
    t = b["times"][0][valid]
    assert np.all(np.diff(t) > 0)


def test_real_like_datasets_have_assigned_cardinality():
    for name, K in [("taobao_like", 17), ("amazon_like", 16),
                    ("taxi_like", 10), ("stackoverflow_like", 22)]:
        d = ds.make_dataset(name, n_seqs=4, t_end=3.0, seed=0)
        assert d.num_marks == K


def test_tpp_training_reduces_nll():
    data = ds.make_dataset("hawkes", n_seqs=40, t_end=8.0, seed=0)
    cfg = TPPConfig(encoder="thp", num_layers=1, num_heads=1, d_model=16,
                    d_ff=32, num_marks=1, num_mix=4)
    tcfg = trainer.TPPTrainConfig(max_epochs=3, batch_size=16)
    params, hist = trainer.train_tpp(cfg, data, tcfg)
    assert hist["train"][-1] < hist["train"][0]


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.array(3, jnp.int32)}}
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "ckpt.msgpack")
        checkpoint.save(path, tree)
        back = checkpoint.load(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_model_loglik_matches_direct_eval():
    data = ds.make_dataset("hawkes", n_seqs=10, t_end=5.0, seed=0)
    cfg = TPPConfig(encoder="thp", num_layers=1, num_heads=1, d_model=16,
                    d_ff=32, num_marks=1, num_mix=4)
    params = __import__("repro.models.tpp", fromlist=["tpp"]).init_params(
        cfg, jax.random.PRNGKey(0))
    ll = trainer.model_loglik(cfg, params, data.test, data.t_end)
    assert np.isfinite(ll)
