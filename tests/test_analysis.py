"""Tests for the repo-native static-analysis pass (``repro.analysis``).

Every rule is exercised against a bad fixture (must flag) and a good
fixture (must stay clean); suppression semantics, the SARIF renderer,
the CLI exit codes, and the shared-alignment-spec pin (the lint rule and
``validate_block_size`` move together when the table changes) each get
their own test. Fixtures live in ``tests/analysis_fixtures/`` and are
globally excluded from the repo's default analysis config — the bad
snippets are lint violations ON PURPOSE.
"""
import json
import pathlib

import pytest

import repro.analysis.rules  # noqa: F401  (populate the registry)
from repro.analysis import (RULES, render_sarif, run_analysis,
                            unrestricted_config)
from repro.analysis.__main__ import main as cli_main
from repro.analysis.core import BARE_IGNORE

FIXTURES = pathlib.Path(__file__).resolve().parent / "analysis_fixtures"
REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]

#: (rule id, fixture stem) — one bad + one good file per rule
PAIRS = [
    ("rng-key-reuse", "rng_key_reuse"),
    ("rng-raw-prngkey", "rng_raw_prngkey"),
    ("trace-unsafe-branch", "trace_unsafe_branch"),
    ("host-sync-in-hot-path", "host_sync"),
    ("pallas-block-align", "pallas_block_align"),
    ("refcount-pairing", "refcount_pairing"),
]


def _run(name, rules=None):
    return run_analysis([str(FIXTURES / name)],
                        config=unrestricted_config(), rules=rules)


def test_every_rule_has_a_fixture_pair():
    assert sorted(r for r, _ in PAIRS) == sorted(RULES)


@pytest.mark.parametrize("rule,stem", PAIRS)
def test_bad_fixture_flags(rule, stem):
    rep = _run(f"{stem}_bad.py", rules=[rule])
    hits = [f for f in rep.findings if f.rule == rule]
    assert hits, f"{stem}_bad.py produced no {rule} findings"
    for f in hits:
        assert f.line >= 1 and f.col >= 1 and f.message


@pytest.mark.parametrize("rule,stem", PAIRS)
def test_good_fixture_clean(rule, stem):
    rep = _run(f"{stem}_good.py", rules=[rule])
    assert not rep.findings, [f.render() for f in rep.findings]


def test_bad_fixtures_flag_multiple_sites():
    # the bad fixtures each contain several distinct violations; the
    # rules must report every site, not bail after the first
    rep = _run("trace_unsafe_branch_bad.py", rules=["trace-unsafe-branch"])
    assert len(rep.findings) >= 4          # if / while / assert / float-item
    rep = _run("pallas_block_align_bad.py", rules=["pallas-block-align"])
    kinds = {("BlockSpec" in f.message, "index_map" in f.message,
              "knob" in f.message) for f in rep.findings}
    assert len(rep.findings) >= 3 and len(kinds) >= 3


def test_host_loop_per_element_transfers_flagged():
    # the host-loop sub-check: np.asarray(x[i]) / x[i].item() /
    # jax.device_get(x[i]) inside a for loop each flag — one finding
    # per call site, none for the traced-function sites' lines
    rep = _run("host_sync_bad.py", rules=["host-sync-in-hot-path"])
    loop_hits = [f for f in rep.findings if "host loop" in f.message]
    assert len(loop_hits) == 3, [f.render() for f in rep.findings]
    # the good fixture's loop (one batched device_get, whole-array
    # asarray, plain numpy indexing) stays clean
    rep = _run("host_sync_good.py", rules=["host-sync-in-hot-path"])
    assert not rep.findings, [f.render() for f in rep.findings]


# -- suppression semantics --------------------------------------------------

def test_suppression_with_reason_moves_finding():
    rep = _run("suppressed.py")
    assert rep.ok and not rep.findings
    assert len(rep.suppressed) == 2        # trailing + standalone comment
    for f, sup in rep.suppressed:
        assert f.rule == "rng-raw-prngkey"
        assert sup.reason and sup.used


def test_bare_ignore_does_not_suppress():
    rep = _run("bare_ignore.py")
    rules = sorted(f.rule for f in rep.findings)
    assert "rng-raw-prngkey" in rules      # original finding survives
    assert BARE_IGNORE in rules            # and the bare ignore is flagged
    assert not rep.suppressed


def test_unknown_rule_id_in_suppression_flagged():
    rep = _run("unknown_rule.py")
    assert [f.rule for f in rep.findings] == [BARE_IGNORE]
    assert "no-such-rule" in rep.findings[0].message


def test_fixture_corpus_excluded_by_default_config(monkeypatch):
    # the repo config must skip the corpus entirely, or CI's clean-tree
    # gate would trip over the intentionally-bad snippets
    monkeypatch.chdir(REPO_ROOT)
    rep = run_analysis(["tests/analysis_fixtures"])
    assert rep.ok and not rep.suppressed


def test_repo_src_tree_is_clean(monkeypatch):
    monkeypatch.chdir(REPO_ROOT)
    rep = run_analysis(["src"])
    assert rep.ok, [f.render() for f in rep.findings]
    # intentional exceptions exist and every one carries a reason
    assert rep.suppressed
    assert all(sup.reason for _, sup in rep.suppressed)


# -- output formats ---------------------------------------------------------

def test_sarif_schema_and_suppressions():
    rep = _run("suppressed.py", rules=["rng-raw-prngkey"])
    doc = json.loads(render_sarif(rep))
    assert doc["version"] == "2.1.0"
    assert "sarif-schema-2.1.0" in doc["$schema"]
    run = doc["runs"][0]
    driver = run["tool"]["driver"]
    assert driver["name"] == "repro.analysis"
    ids = {r["id"] for r in driver["rules"]}
    assert set(RULES) <= ids and BARE_IGNORE in ids
    notes = [r for r in run["results"] if r["level"] == "note"]
    assert len(notes) == 2
    for n in notes:
        assert n["suppressions"][0]["kind"] == "inSource"
        assert n["suppressions"][0]["justification"]
        region = n["locations"][0]["physicalLocation"]["region"]
        assert region["startLine"] >= 1 and region["startColumn"] >= 1


def test_sarif_findings_carry_locations():
    rep = _run("rng_raw_prngkey_bad.py", rules=["rng-raw-prngkey"])
    doc = json.loads(render_sarif(rep))
    results = doc["runs"][0]["results"]
    assert results and all(r["level"] == "error" for r in results)
    uris = {r["locations"][0]["physicalLocation"]["artifactLocation"]["uri"]
            for r in results}
    assert all(u.endswith("rng_raw_prngkey_bad.py") for u in uris)


# -- CLI --------------------------------------------------------------------

def test_cli_exit_codes(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text("import jax\nKEY = jax.random.PRNGKey(0)\n")
    good = tmp_path / "good.py"
    good.write_text("import jax\n\n\ndef f(rng):\n"
                    "    return jax.random.normal(rng, (2,))\n")
    assert cli_main([str(bad), "--rules", "rng-raw-prngkey"]) == 1
    assert cli_main([str(good), "--rules", "rng-raw-prngkey"]) == 0
    out = capsys.readouterr().out
    assert "rng-raw-prngkey" in out


def test_cli_sarif_output_file(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("import jax\nKEY = jax.random.PRNGKey(0)\n")
    report_path = tmp_path / "report.sarif"
    rc = cli_main([str(bad), "--format", "sarif",
                   "--output", str(report_path)])
    assert rc == 1
    doc = json.loads(report_path.read_text())
    assert doc["version"] == "2.1.0"
    assert any(r["ruleId"] == "rng-raw-prngkey"
               for r in doc["runs"][0]["results"])


# -- shared alignment spec (tentpole acceptance pin) ------------------------

def test_alignment_table_is_shared(monkeypatch, tmp_path):
    """Changing kernels.alignment.BLOCK_PARAM_ALIGN must move BOTH the
    runtime validator and the lint rule — one spec, two consumers."""
    from repro.kernels import alignment
    from repro.kernels.policy import validate_block_size

    knob = tmp_path / "knob.py"
    knob.write_text("def build(attn):\n    return attn(bq=8)\n")

    # default table: bq aligns to the sublane quantum, 8 is fine
    assert validate_block_size("t", "bq", 8) == 8
    rep = run_analysis([str(knob)], config=unrestricted_config(),
                       rules=["pallas-block-align"])
    assert not rep.findings

    monkeypatch.setitem(alignment.BLOCK_PARAM_ALIGN, "bq", 32)
    with pytest.warns(UserWarning):
        assert validate_block_size("t2", "bq", 8) == 32
    rep = run_analysis([str(knob)], config=unrestricted_config(),
                       rules=["pallas-block-align"])
    assert [f.rule for f in rep.findings] == ["pallas-block-align"]
    assert "32" in rep.findings[0].message
