"""Per-architecture smoke tests (required deliverable f): a REDUCED
variant of each assigned architecture runs one forward + one train step on
CPU, asserting output shapes and finiteness; decode families additionally
check prefill+decode consistency against the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_variant
from repro.models import registry
from repro.train import optimizer as opt
from repro.train.trainer import make_train_step

RNG = jax.random.PRNGKey(0)
B, S = 2, 24


def _batch(cfg):
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            RNG, (B, cfg.vision_prefix_len, cfg.d_model))
    if cfg.family == "encdec":
        batch["enc_frames"] = jax.random.normal(RNG, (B, 8, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_forward_and_train_step(name):
    cfg = smoke_variant(ARCHS[name])
    model = registry.get_model(cfg)
    params = model.init_params(RNG)
    batch = _batch(cfg)
    logits, _ = model.forward(params, batch)
    exp_len = S + (cfg.vision_prefix_len if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_len, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), "NaN in forward"
    optim = opt.adam(1e-3)
    state = optim.init(params)
    step = jax.jit(make_train_step(cfg, optim))
    params2, _, loss = step(params, state, batch)
    assert bool(jnp.isfinite(loss)), "NaN loss"
    # params actually changed
    delta = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("name", ["llama3.2-1b", "falcon-mamba-7b",
                                  "recurrentgemma-9b", "granite-moe-1b-a400m",
                                  "seamless-m4t-medium", "internvl2-26b"])
def test_arch_decode_matches_forward(name):
    """prefill + single-token decode == full forward (per family).

    MoE: capacity dispatch is batch-context-dependent (token drops depend
    on the dispatch grouping), so exact decode==forward equality only
    holds in the drop-free regime -> capacity_factor = num_experts."""
    cfg = smoke_variant(ARCHS[name])
    if cfg.is_moe:
        cfg = cfg.replace(capacity_factor=float(cfg.num_experts))
    model = registry.get_model(cfg)
    params = model.init_params(RNG)
    batch = _batch(cfg)
    toks = batch["tokens"]
    full_batch = dict(batch)
    full_batch["tokens"] = jnp.concatenate([toks, toks[:, :1]], 1)
    full_batch["labels"] = jnp.roll(full_batch["tokens"], -1, 1)
    want, _ = model.forward(params, full_batch)
    if cfg.family == "encdec":
        _, cache = model.prefill(params, batch, S + 4)
    elif cfg.family == "vlm":
        _, cache = model.prefill(params, batch, S + 4
                                 + cfg.vision_prefix_len)
    else:
        _, cache = model.prefill(params, batch, S + 4)
    got, _ = model.extend(params, cache, toks[:, :1])
    np.testing.assert_allclose(np.asarray(got[:, 0]),
                               np.asarray(want[:, -1]), atol=3e-4, rtol=1e-3)


def test_speculative_verify_chunk_matches_forward():
    """gamma-token extend (the SD verification forward) == full forward."""
    cfg = smoke_variant(ARCHS["llama3.2-1b"])
    model = registry.get_model(cfg)
    params = model.init_params(RNG)
    batch = _batch(cfg)
    toks = batch["tokens"]
    _, cache = model.prefill(params, batch, S + 8)
    got, _ = model.extend(params, cache, toks[:, :5])
    full = dict(batch)
    full["tokens"] = jnp.concatenate([toks, toks[:, :5]], 1)
    want, _ = model.forward(params, full)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want[:, -5:]),
                               atol=3e-4, rtol=1e-3)


def test_long_context_ring_window_decode():
    """The long_500k serving variant: sliding-window ring cache must match
    the full forward after many wraps (here S=96 >> W=16)."""
    cfg = smoke_variant(ARCHS["mistral-nemo-12b"]).replace(sliding_window=16)
    model = registry.get_model(cfg)
    params = model.init_params(RNG)
    S_long = 96
    toks = jax.random.randint(RNG, (1, S_long), 0, cfg.vocab_size)
    want, _ = model.forward(params, {"tokens": toks})
    _, cache = model.prefill(params, {"tokens": toks[:, :-8]}, S_long)
    got = []
    for i in range(8):
        lg, cache = model.extend(params, cache, toks[:, S_long - 8 + i:
                                                     S_long - 8 + i + 1])
        got.append(lg[:, 0])
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want[:, -8:]),
                               atol=3e-4, rtol=1e-3)
