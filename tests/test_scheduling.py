"""Model-free ``SchedulingPolicy`` units: admission ordering, the
priority policy's aging starvation bound, SJF tie-breaking, and
deferral interplay. No JAX arrays beyond ``ServeRequest`` prompts —
the scheduler never touches models, which is what keeps these fast."""
import jax.numpy as jnp
import pytest

from repro.serving import ServeRequest
from repro.serving.scheduler import (FifoPolicy, PriorityPolicy, Scheduler,
                                     SchedulingPolicy, SJFPolicy,
                                     resolve_sched_policy)


def _req(i, n=4, plen=5, priority=0):
    return ServeRequest(prompt=jnp.arange(plen, dtype=jnp.int32),
                        max_new_tokens=n, rng=i, priority=priority)


# ---------------------------------------------------------------------------
# policy resolution
# ---------------------------------------------------------------------------

def test_resolve_policy_names_and_passthrough():
    assert isinstance(resolve_sched_policy("fifo"), FifoPolicy)
    assert isinstance(resolve_sched_policy("priority"), PriorityPolicy)
    assert isinstance(resolve_sched_policy("sjf"), SJFPolicy)
    pol = PriorityPolicy(aging=3)
    assert resolve_sched_policy(pol) is pol
    with pytest.raises(ValueError, match="scheduling policy"):
        resolve_sched_policy("lifo")
    with pytest.raises(ValueError, match="aging"):
        PriorityPolicy(aging=0)
    assert isinstance(resolve_sched_policy("fifo"), SchedulingPolicy)


# ---------------------------------------------------------------------------
# fifo: submission order, deferral ahead of the queue
# ---------------------------------------------------------------------------

def test_fifo_is_submission_order():
    s = Scheduler(2, 64, policy="fifo")
    reqs = [_req(i) for i in range(5)]
    for r in reqs:
        s.submit(r)
    placed = s.admit()
    assert [st.request.request_id for _, st in placed] == \
        [reqs[0].request_id, reqs[1].request_id]
    # deferral puts them back ahead of the queue, original order
    s.defer(placed[0][0])
    s.defer(placed[1][0])
    nxt = s.admit()
    assert [st.request.request_id for _, st in nxt] == \
        [reqs[0].request_id, reqs[1].request_id]
    assert s.pending_count == 3


# ---------------------------------------------------------------------------
# priority: ordering, FIFO among equals, aging starvation bound
# ---------------------------------------------------------------------------

def test_priority_orders_by_priority_then_fifo():
    s = Scheduler(3, 64, policy="priority")
    low = _req(0, priority=0)
    hi_a = _req(1, priority=5)
    hi_b = _req(2, priority=5)
    for r in (low, hi_a, hi_b):          # low submitted FIRST
        s.submit(r)
    placed = s.admit()
    assert [st.request.request_id for _, st in placed] == \
        [hi_a.request_id, hi_b.request_id, low.request_id]


def test_priority_aging_starvation_bound():
    """A priority-0 request facing a steady stream of priority-3
    arrivals must be admitted within gap*aging steps of submission:
    effective priority rises by 1 every ``aging`` steps, and FIFO
    breaks the tie the moment it draws level."""
    aging, gap = 4, 3
    s = Scheduler(1, 64, policy=PriorityPolicy(aging=aging))
    low = _req(0, priority=0)
    s.submit(low)
    admitted_at = None
    for step in range(1, 40):
        s.tick()
        s.submit(_req(100 + step, priority=gap))
        placed = s.admit()
        assert len(placed) == 1
        if placed[0][1].request.request_id == low.request_id:
            admitted_at = step
            break
        s.retire(placed[0][0])           # 1-step jobs
    assert admitted_at is not None, "priority-0 request starved"
    # the bound: level with priority 3 after 3*aging steps (tie -> FIFO)
    assert admitted_at <= gap * aging
    # and it genuinely waited (fresh high-priority arrivals won early)
    assert admitted_at > aging


def test_priority_never_reorders_tokens_only_admission():
    """Sanity on the contract: the policy ranks queue entries only —
    SlotState/rng bookkeeping is untouched, so per-request streams
    cannot depend on it."""
    s = Scheduler(1, 64, policy="priority")
    a, b = _req(0, priority=1), _req(1, priority=9)
    s.submit(a)
    s.submit(b)
    placed = s.admit()
    st = placed[0][1]
    assert st.request.request_id == b.request_id
    assert st.round_idx == 1 and st.out == [] and st.phase == "decode"


# ---------------------------------------------------------------------------
# sjf: shortest prompt+budget first, FIFO tie-break
# ---------------------------------------------------------------------------

def test_sjf_shortest_job_first_with_fifo_tiebreak():
    s = Scheduler(4, 128, policy="sjf")
    big = _req(0, n=50, plen=20)         # job 70, submitted first
    sml_a = _req(1, n=4, plen=5)         # job 9
    sml_b = _req(2, n=4, plen=5)         # job 9, same length: FIFO
    mid = _req(3, n=20, plen=10)         # job 30
    for r in (big, sml_a, sml_b, mid):
        s.submit(r)
    placed = s.admit()
    assert [st.request.request_id for _, st in placed] == \
        [sml_a.request_id, sml_b.request_id, mid.request_id,
         big.request_id]


def test_sjf_deferred_keeps_rank_among_equals():
    s = Scheduler(1, 64, policy="sjf")
    a, b = _req(0, n=4, plen=5), _req(1, n=4, plen=5)
    s.submit(a)
    s.submit(b)
    placed = s.admit()
    s.defer(placed[0][0])                # a deferred; equal-length b waits
    nxt = s.admit()
    assert nxt[0][1].request.request_id == a.request_id


# ---------------------------------------------------------------------------
# scheduler mechanics shared by every policy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["fifo", "priority", "sjf"])
def test_continuous_refill_and_has_work(policy):
    s = Scheduler(2, 64, policy=policy)
    for i in range(3):
        s.submit(_req(i))
    placed = s.admit()
    assert len(placed) == 2 and s.pending_count == 1
    s.retire(placed[0][0])
    nxt = s.admit()
    assert len(nxt) == 1 and s.pending_count == 0
    assert s.has_work()
    for i, _ in list(s.active()):
        s.retire(i)
    assert not s.has_work()


def test_deferred_entries_keep_submit_stamps_for_aging():
    """defer() must preserve the original submit step so aging keeps
    accruing across deferrals (otherwise page pressure could reset a
    request's starvation clock forever)."""
    s = Scheduler(1, 64, policy=PriorityPolicy(aging=2))
    old = _req(0, priority=0)
    s.submit(old)
    placed = s.admit()
    for _ in range(6):
        s.tick()
    s.defer(placed[0][0])
    entry = s.pending[0]
    assert entry.submit_step == 0 and entry.deferred
    # aged 6 steps -> effective priority 3 beats a fresh priority-2
    s.submit(_req(1, priority=2))
    nxt = s.admit()
    assert nxt[0][1].request.request_id == old.request_id
