"""Serving slot-pool sharding: the ``ServingEngine`` pooled round on a
real mesh (subprocess — the forced host-device count must be set before
JAX initializes).

On a forced 4-device mesh with the KV-cache pools' slot axis sharded
over "data", batched serving must produce exactly the tokens the
unsharded engine produces (the per-request rng contract makes this
bitwise), while the pool leaves actually carry the data-axis placement.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs.base import ModelConfig
    from repro.launch.mesh import make_debug_mesh, serving_rules_for
    from repro.models import registry
    from repro.serving import ServeRequest, ServingEngine

    assert jax.device_count() == 4

    def dense(num_layers, name):
        return ModelConfig(name=name, family="dense", num_layers=num_layers,
                           d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                           vocab_size=31, dtype="float32",
                           param_dtype="float32", remat=False)

    cfg_t, cfg_d = dense(2, "t"), dense(1, "d")
    pt = registry.get_model(cfg_t).init_params(jax.random.PRNGKey(0))
    pd = registry.get_model(cfg_d).init_params(jax.random.PRNGKey(1))

    def serve(mesh, n_req=6):
        eng = ServingEngine(cfg_t, pt, cfg_d, pd, max_batch=4, max_len=64,
                            gamma=3, mesh=mesh)
        ids = [eng.submit(ServeRequest(
                   prompt=jnp.arange(5, dtype=jnp.int32),
                   max_new_tokens=6 + i, rng=100 + i))
               for i in range(n_req)]
        res = {r.request_id: r for r in eng.run()}
        toks = [[int(t) for t in res[i].tokens] for i in ids]
        return eng, toks

    out = {}
    mesh = make_debug_mesh(data=4, model=1)
    e_ref, t_ref = serve(None)
    e_sh, t_sh = serve(mesh)
    out["tokens_equal"] = t_ref == t_sh
    spec = e_sh.pool_t.tree["k"].sharding.spec
    out["pool_slot_axis"] = None if len(spec) == 0 else str(spec[0])
    out["stats_equal"] = (
        e_ref.stats().tokens == e_sh.stats().tokens
        and e_ref.stats().target_forwards == e_sh.stats().target_forwards
        and e_ref.stats().accepted == e_sh.stats().accepted)

    # serving-rules mesh with a kv axis: cache kv_heads dim sharded too
    kv_mesh = jax.sharding.Mesh(
        np.asarray(jax.devices()).reshape(2, 2, 1), ("data", "kv", "tp"))
    rules = serving_rules_for(kv_mesh)
    kspec = rules.spec(("batch", "layers", None, "cache_seq", "kv_heads",
                        "qkv"), dims=(4, 2, 1, 64, 2, 8))
    out["kv_rule"] = [None if a is None else str(a) for a in kspec]
    e_kv, t_kv = serve(kv_mesh)
    out["kv_tokens_equal"] = t_ref == t_kv
    kv_pool_spec = [None if a is None else str(a)
                    for a in e_kv.pool_t.shardings["k"].spec]
    out["kv_pool_spec"] = kv_pool_spec
    print(json.dumps(out))
""")


pytestmark = pytest.mark.slow  # subprocess + 4-device GSPMD compiles


@pytest.fixture(scope="module")
def sharded_serving_out():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_sharded_pool_serving_matches_unsharded(sharded_serving_out):
    assert sharded_serving_out["tokens_equal"] is True
    assert sharded_serving_out["stats_equal"] is True


def test_pool_slot_axis_sharded_over_data(sharded_serving_out):
    assert sharded_serving_out["pool_slot_axis"] == "data"


def test_serving_rules_shard_kv_heads_on_kv_mesh(sharded_serving_out):
    """SERVING_RULES on a (data, kv, tp) mesh: the pool's slot axis maps
    to data and the kv_heads cache dim to the kv axis."""
    assert sharded_serving_out["kv_rule"][0] == "data"
    assert sharded_serving_out["kv_rule"][4] == "kv"
    assert sharded_serving_out["kv_pool_spec"][0] == "data"
    assert sharded_serving_out["kv_tokens_equal"] is True
