"""BAD: root-key construction inside library code."""
import jax


def make_noise(shape):
    key = jax.random.PRNGKey(0)
    return jax.random.normal(key, shape)


def new_style(seed):
    return jax.random.key(seed)
