"""GOOD: retain paired with release in the same class; the counter is
only mutated by the class that defines retain()/release()."""


class Cache:
    def __init__(self):
        self.pages = []

    def insert(self, pool, pid):
        pool.retain(pid)
        self.pages.append(pid)

    def evict(self, pool, pid):
        self.pages.remove(pid)
        pool.release(pid)


class Pool:
    def __init__(self, n):
        self.refcount = [0] * n

    def retain(self, pid):
        self.refcount[pid] += 1

    def release(self, pid):
        self.refcount[pid] -= 1
