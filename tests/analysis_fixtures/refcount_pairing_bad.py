"""BAD: retain with no release path; refcount poked from outside."""


class LeakyHolder:
    def __init__(self):
        self.pages = []

    def grab(self, pool, pid):
        pool.retain(pid)
        self.pages.append(pid)


def poke(pool, pid):
    pool.refcount[pid] += 1
