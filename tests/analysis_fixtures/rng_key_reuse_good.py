"""GOOD: every consumer gets its own split/fold_in stream."""
import jax


def sample_pair(rng):
    k1, k2 = jax.random.split(rng)
    a = jax.random.normal(k1, (4,))
    b = jax.random.uniform(k2, (4,))
    return a + b


def loop_fold(rng, n):
    total = 0.0
    for i in range(n):
        total = total + jax.random.normal(jax.random.fold_in(rng, i), ())
    return total


def branch_either(rng, flag):
    # mutually exclusive branches: each consumes the key at most once
    if flag:
        return jax.random.normal(rng, ())
    return jax.random.uniform(rng, ())
