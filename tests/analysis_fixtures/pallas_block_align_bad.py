"""BAD: misaligned block shape, bad knob literal, grid-arity mismatch."""
from jax.experimental import pallas as pl


def misaligned(x, kernel):
    return pl.pallas_call(
        kernel,
        grid=(4, 2),
        in_specs=[pl.BlockSpec((12, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((16, 128), lambda i, j: (i, 0)),
    )(x)


def bad_knob(policy_cls):
    return policy_cls(bq=100, bk=48)
