"""GOOD: aligned shapes, arity matches grid + scalar prefetch."""
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def aligned(x, kernel):
    return pl.pallas_call(
        kernel,
        grid=(4, 2),
        in_specs=[pl.BlockSpec((16, 128), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((1, 128), lambda i, j: (i, 0)),
    )(x)


def prefetch(x, kernel):
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(8,),
            in_specs=[pl.BlockSpec((8, 128), lambda s, i: (i, 0))],
        ),
    )(x)


def good_knob(policy_cls):
    return policy_cls(bq=128, page_size=8)
