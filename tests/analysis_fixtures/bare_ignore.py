"""A reasonless ignore suppresses nothing and is itself flagged."""
import jax

KEY = jax.random.PRNGKey(0)  # repro: ignore[rng-raw-prngkey]
