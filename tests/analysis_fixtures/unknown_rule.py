"""Suppressing a rule id that does not exist is flagged."""
X = 1  # repro: ignore[no-such-rule] -- typo'd rule ids must not pass silently
