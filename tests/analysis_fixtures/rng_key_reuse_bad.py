"""BAD: one PRNG key feeds two consumers without split/fold_in."""
import jax


def sample_pair(rng):
    a = jax.random.normal(rng, (4,))
    b = jax.random.uniform(rng, (4,))
    return a + b


def loop_reuse(rng, n):
    total = 0.0
    for _ in range(n):
        total = total + jax.random.normal(rng, ())
    return total
