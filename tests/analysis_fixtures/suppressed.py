"""Suppressions with written reasons: findings move to the suppressed
list (trailing-comment and standalone-comment forms)."""
import jax

KEY = jax.random.PRNGKey(0)  # repro: ignore[rng-raw-prngkey] -- fixture: demonstrates a justified trailing suppression

# repro: ignore[rng-raw-prngkey] -- fixture: a standalone comment governs the next code line
KEY2 = jax.random.PRNGKey(1)
