"""GOOD: device-side math only; host staging stays outside jit."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def round_fn(x):
    jax.debug.print("round {}", x)
    return jnp.tanh(x)


def driver(x):
    # not traced: host staging here is fine
    return np.asarray(round_fn(x))


def commit_loop(out, slots):
    # ONE batched fetch; the per-slot reads hit host memory
    host = jax.device_get(out)
    rows = []
    for slot in slots:
        rows.append(np.asarray(host))   # whole-array coercion: legal
        rows.append(int(host[slot]))
    return rows
