"""GOOD: device-side math only; host staging stays outside jit."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def round_fn(x):
    jax.debug.print("round {}", x)
    return jnp.tanh(x)


def driver(x):
    # not traced: host staging here is fine
    return np.asarray(round_fn(x))
