"""BAD: host round-trips inside a jitted hot-path function."""
import time

import jax
import numpy as np


@jax.jit
def round_fn(x):
    t0 = time.perf_counter()
    y = np.asarray(x)
    print("round took", t0)
    x.block_until_ready()
    return y


def commit_loop(out, slots):
    # one device sync PER SLOT — the packed-fetch antipattern
    toks = []
    for slot in slots:
        toks.append(np.asarray(out[slot]))
        toks.append(out[slot].item())
        toks.append(jax.device_get(out[slot]))
    return toks
