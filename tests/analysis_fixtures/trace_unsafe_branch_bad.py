"""BAD: Python control flow / coercion on tracer values inside jit."""
import jax


@jax.jit
def relu_branch(x):
    if x > 0:
        return x
    return 0.0 * x


@jax.jit
def count_down(x):
    n = 0
    while x > 0:
        x = x - 1
        n = n + 1
    return n


@jax.jit
def checked(x):
    assert x >= 0
    return x


@jax.jit
def to_host(x):
    return float(x.item())
