"""GOOD: streams derived from a caller-provided key."""
import jax


def make_noise(rng, shape):
    return jax.random.normal(jax.random.fold_in(rng, 7), shape)
