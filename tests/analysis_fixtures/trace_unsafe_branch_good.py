"""GOOD: shape-derived branching, lax select, static knobs."""
from functools import partial

import jax
import jax.numpy as jnp


@jax.jit
def relu_where(x):
    return jnp.where(x > 0, x, 0.0)


@jax.jit
def pad_if_ragged(x):
    if x.shape[0] % 8:
        x = jnp.pad(x, (0, 8 - x.shape[0] % 8))
    return x


@jax.jit
def rank_branch(x):
    if len(x.shape) == 1:
        x = x[None, :]
    return x


@partial(jax.jit, static_argnames=("n",))
def repeat(x, n):
    if n > 2:
        x = x * 2.0
    for _ in range(n):
        x = x + 1.0
    return x
