"""Serving under failure: the chaos-harness contracts.

The one invariant everything here pins: under ANY ``FaultPlan`` plus any
cancel schedule, every SURVIVING request's committed tokens are bitwise
the fault-free run's, and the pools leak zero pages. Failures are
per-request data (``ServeResult.status``), never exceptions out of
``run()``. Coverage:

  - survivor-bitwise under step_error / page_exhaustion / slow_step on
    the paged pool with ref AND Pallas(interpret) kernels, plus dense;
  - nan_lane quarantine fails exactly the poisoned request (its partial
    tokens a bitwise PREFIX of its fault-free stream);
  - cancellation mid-flight (active slot, queued request, fan-out
    sibling) frees refcounted pages with zero leak;
  - deadlines (``max_wall_rounds`` deterministic, ``deadline_s`` wall
    clock) retire partial prefixes with status "deadline";
  - load shedding under overload keeps goodput nonzero;
  - ``run()`` survives retry exhaustion (the PR's stranded-slot
    regression) and the engine serves fresh traffic afterwards;
  - the TPP domain + forecast retry pass: quantiles bitwise equal the
    fault-free forecast after per-member resubmission.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, TPPConfig
from repro.forecast import Forecaster, ForecastRequest
from repro.models import registry, tpp
from repro.serving import (FaultPlan, FaultSpec, InjectedFault,
                           ServeRequest, ServingEngine)

RNG = jax.random.PRNGKey(0)


def _dense(num_layers=2, vocab=31, name="t", **kw):
    base = dict(name=name, family="dense", num_layers=num_layers,
                d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                vocab_size=vocab, dtype="float32", param_dtype="float32",
                remat=False)
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def dense_pair():
    cfg_t, cfg_d = _dense(2), _dense(1, name="d")
    mt, md = registry.get_model(cfg_t), registry.get_model(cfg_d)
    return (cfg_t, cfg_d, mt.init_params(RNG),
            md.init_params(jax.random.PRNGKey(1)))


N_REQ = 4


def _engine(pair, layout, kernel, **kw):
    cfg_t, cfg_d, pt, pd = pair
    kw.setdefault("fixed_window", True)
    kw.setdefault("max_len", 32)
    return ServingEngine(cfg_t, pt, cfg_d, pd, max_batch=3, gamma=2,
                         kv_layout=layout, kernel=kernel, **kw)


def _submit_all(eng, n_req=N_REQ):
    return [eng.submit(ServeRequest(
        prompt=jnp.arange(5, dtype=jnp.int32), max_new_tokens=5 + i,
        rng=100 + i, temperature=1.0 + 0.1 * (i % 3)))
        for i in range(n_req)]


def _run_workload(pair, layout, kernel, **kw):
    eng = _engine(pair, layout, kernel, **kw)
    order = _submit_all(eng)
    by_id = {r.request_id: r for r in eng.run()}
    return eng, order, by_id


_BASELINES = {}


def _baseline(pair, layout, kernel):
    """Fault-free tokens by submit index, computed once per layout."""
    key = (layout, kernel)
    if key not in _BASELINES:
        _, order, by_id = _run_workload(pair, layout, kernel)
        _BASELINES[key] = [np.asarray(by_id[rid].tokens)
                           for rid in order]
    return _BASELINES[key]


def _assert_leak_free(eng):
    for pool in (eng.pool_t, eng.pool_d):
        if pool is not None and hasattr(pool, "refcount"):
            assert int(pool.refcount.sum()) == 0, "leaked page refcounts"
            assert len(pool.free) == pool.n_pages - 1, "leaked free pages"


def _assert_prefix(partial, full):
    partial, full = np.asarray(partial), np.asarray(full)
    assert partial.shape[0] <= full.shape[0]
    np.testing.assert_array_equal(partial, full[:partial.shape[0]])


# ---------------------------------------------------------------------------
# survivor-bitwise under injected faults (ref AND pallas-interpret)
# ---------------------------------------------------------------------------

_PLANS = {
    "step_error": lambda: FaultPlan(FaultSpec(kind="step_error", step=2,
                                              times=2)),
    "page_exhaustion": lambda: FaultPlan(FaultSpec(kind="page_exhaustion",
                                                   step=2, times=2)),
    "slow_step": lambda: FaultPlan(FaultSpec(kind="slow_step", step=1,
                                             times=2, seconds=0.002)),
}


@pytest.mark.parametrize("kind", sorted(_PLANS))
@pytest.mark.parametrize("kernel", ["ref", "pallas"])
def test_survivors_bitwise_paged(dense_pair, kind, kernel):
    """Every request completes "ok" with tokens bitwise the fault-free
    run's, under each fault kind, on both kernels."""
    plan = _PLANS[kind]()
    eng, order, by_id = _run_workload(dense_pair, "paged", kernel,
                                      faults=plan)
    assert plan.injected >= 1, "fault never fired"
    assert plan.injected_of(kind) == plan.injected
    ref = _baseline(dense_pair, "paged", kernel)
    for i, rid in enumerate(order):
        assert by_id[rid].ok, by_id[rid].error
        np.testing.assert_array_equal(np.asarray(by_id[rid].tokens),
                                      ref[i])
    if kind == "step_error":
        assert eng.stats().retries >= 1
    _assert_leak_free(eng)


def test_step_error_dense_survivors_bitwise(dense_pair):
    plan = FaultPlan(FaultSpec(kind="step_error", step=2))
    eng, order, by_id = _run_workload(dense_pair, "dense", "ref",
                                      faults=plan)
    assert plan.injected == 1 and eng.stats().retries >= 1
    ref = _baseline(dense_pair, "dense", "ref")
    for i, rid in enumerate(order):
        assert by_id[rid].ok
        np.testing.assert_array_equal(np.asarray(by_id[rid].tokens),
                                      ref[i])


def test_page_exhaustion_inapplicable_on_dense(dense_pair):
    """The dense pool has no free list to seize: the plan is a no-op
    (injects nothing) and the run is clean."""
    plan = FaultPlan(FaultSpec(kind="page_exhaustion", step=1, times=3))
    _, order, by_id = _run_workload(dense_pair, "dense", "ref",
                                    faults=plan)
    assert plan.injected == 0
    ref = _baseline(dense_pair, "dense", "ref")
    for i, rid in enumerate(order):
        assert by_id[rid].ok
        np.testing.assert_array_equal(np.asarray(by_id[rid].tokens),
                                      ref[i])


# ---------------------------------------------------------------------------
# nan_lane quarantine: one failed request, survivors bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel", ["ref", "pallas"])
def test_nan_lane_quarantines_one_request(dense_pair, kernel):
    plan = FaultPlan(FaultSpec(kind="nan_lane", step=2, slot=1))
    eng, order, by_id = _run_workload(dense_pair, "paged", kernel,
                                      faults=plan)
    assert plan.injected == 1
    ref = _baseline(dense_pair, "paged", kernel)
    statuses = [by_id[rid].status for rid in order]
    assert statuses.count("failed") == 1
    for i, rid in enumerate(order):
        res = by_id[rid]
        if res.ok:
            np.testing.assert_array_equal(np.asarray(res.tokens), ref[i])
        else:
            assert "non-finite logits" in res.error
            # the poisoned lane keeps its pre-fault commits: a bitwise
            # PREFIX of its own fault-free stream
            _assert_prefix(res.tokens, ref[i])
    st = eng.stats()
    assert st.failed == 1 and st.requests_completed == N_REQ - 1
    _assert_leak_free(eng)


# ---------------------------------------------------------------------------
# cancellation: active slot, queued request, fan-out sibling
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel", ["ref", "pallas"])
def test_cancel_active_and_queued_under_faults(dense_pair, kernel):
    """Cancel one decoding slot and one still-queued request while a
    step_error plan is firing: cancelled streams are prefixes, the
    survivors stay bitwise, nothing leaks."""
    plan = FaultPlan(FaultSpec(kind="step_error", step=3))
    eng = _engine(dense_pair, "paged", kernel, faults=plan)
    order = _submit_all(eng)            # max_batch=3: order[3] queues
    results = list(eng.step())
    c_active = eng.cancel(order[1])
    c_queued = eng.cancel(order[3])
    assert eng.cancel(10 ** 9) is None  # unknown id
    results += eng.run()
    by_id = {r.request_id: r for r in results}
    ref = _baseline(dense_pair, "paged", kernel)
    assert c_active.status == "cancelled"
    _assert_prefix(c_active.tokens, ref[1])
    assert c_queued.status == "cancelled" and c_queued.n == 0
    for i in (0, 2):
        assert by_id[order[i]].ok
        np.testing.assert_array_equal(np.asarray(by_id[order[i]].tokens),
                                      ref[i])
    st = eng.stats()
    assert st.cancellations == 2 and plan.injected == 1
    _assert_leak_free(eng)


def test_cancel_fanout_sibling(dense_pair):
    """Cancelling one copy-on-write sibling mid-flight releases its
    refcounted pages and leaves the other siblings bitwise."""
    cfg_t, cfg_d, pt, pd = dense_pair

    def fan(cancel_one):
        eng = ServingEngine(cfg_t, pt, cfg_d, pd, max_batch=3, max_len=32,
                            gamma=2, kv_layout="paged", kernel="ref",
                            fixed_window=True)
        ids = eng.submit(prompt=jnp.arange(5, dtype=jnp.int32),
                         max_new_tokens=6, rng=7, fanout=3)
        out = []
        if cancel_one:
            out += eng.step()
            out.append(eng.cancel(ids[1]))
        out += eng.run()
        return eng, ids, {r.request_id: r for r in out}

    eng_r, ids_r, ref = fan(cancel_one=False)
    eng_c, ids_c, got = fan(cancel_one=True)
    assert got[ids_c[1]].status == "cancelled"
    _assert_prefix(got[ids_c[1]].tokens, ref[ids_r[1]].tokens)
    for j in (0, 2):
        assert got[ids_c[j]].ok
        np.testing.assert_array_equal(np.asarray(got[ids_c[j]].tokens),
                                      np.asarray(ref[ids_r[j]].tokens))
    _assert_leak_free(eng_r)
    _assert_leak_free(eng_c)


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------

def test_max_wall_rounds_deadline_is_bitwise_prefix(dense_pair):
    cfg_t, cfg_d, pt, pd = dense_pair
    eng = _engine(dense_pair, "paged", "ref")
    rid = eng.submit(prompt=jnp.arange(5, dtype=jnp.int32),
                     max_new_tokens=8, rng=100, max_wall_rounds=1)
    res = {r.request_id: r for r in eng.run()}[rid]
    assert res.status == "deadline" and 0 < res.n < 8
    eng2 = _engine(dense_pair, "paged", "ref")
    rid2 = eng2.submit(prompt=jnp.arange(5, dtype=jnp.int32),
                       max_new_tokens=8, rng=100)
    full = {r.request_id: r for r in eng2.run()}[rid2]
    _assert_prefix(res.tokens, full.tokens)
    assert eng.stats().deadline_misses == 1
    _assert_leak_free(eng)


def test_deadline_s_expires_queued_request(dense_pair):
    """A queued request whose wall-clock deadline passes before a slot
    frees retires "deadline" with zero tokens, from the queue."""
    eng = _engine(dense_pair, "paged", "ref")
    order = _submit_all(eng, n_req=3)   # fills all 3 slots
    late = eng.submit(ServeRequest(prompt=jnp.arange(5, dtype=jnp.int32),
                                   max_new_tokens=5, rng=9,
                                   deadline_s=1e-6))
    by_id = {r.request_id: r for r in eng.run()}
    assert by_id[late].status == "deadline" and by_id[late].n == 0
    assert all(by_id[rid].ok for rid in order)
    assert eng.stats().deadline_misses >= 1
    _assert_leak_free(eng)


def test_slow_step_forces_active_deadline(dense_pair):
    """slow_step stalls past an active request's deadline_s: it retires
    "deadline" mid-flight with a bitwise-prefix stream."""
    plan = FaultPlan(FaultSpec(kind="slow_step", step=1, times=4,
                               seconds=0.05))
    eng = _engine(dense_pair, "paged", "ref", faults=plan)
    rid = eng.submit(prompt=jnp.arange(5, dtype=jnp.int32),
                     max_new_tokens=12, rng=100, deadline_s=0.01)
    res = {r.request_id: r for r in eng.run()}[rid]
    assert res.status == "deadline" and res.n < 12
    ref = _baseline(dense_pair, "paged", "ref")
    _assert_prefix(res.tokens, ref[0])  # same prompt/rng as workload[0]
    assert plan.injected >= 1
    _assert_leak_free(eng)


# ---------------------------------------------------------------------------
# load shedding
# ---------------------------------------------------------------------------

def test_shed_queue_drops_overload_keeps_goodput(dense_pair):
    cfg_t, cfg_d, pt, pd = dense_pair
    eng = ServingEngine(cfg_t, pt, cfg_d, pd, max_batch=2, max_len=32,
                        gamma=2, kv_layout="paged", kernel="ref",
                        fixed_window=True, shed_queue=0)
    order = _submit_all(eng, n_req=5)
    by_id = {r.request_id: r for r in eng.run()}
    statuses = [by_id[rid].status for rid in order]
    assert statuses.count("shed") >= 1
    assert statuses.count("ok") >= 2
    for rid in order:
        if by_id[rid].status == "shed":
            assert by_id[rid].n == 0
    st = eng.stats()
    assert st.shed == statuses.count("shed")
    assert st.goodput_tokens > 0 and st.goodput > 0
    _assert_leak_free(eng)


# ---------------------------------------------------------------------------
# run() survives retry exhaustion and keeps serving (the stranded-slot
# regression this PR fixes)
# ---------------------------------------------------------------------------

def test_run_survives_retry_exhaustion_then_recovers(dense_pair):
    plan = FaultPlan(FaultSpec(kind="step_error", step=1))
    eng = _engine(dense_pair, "paged", "ref", faults=plan,
                  max_round_retries=0)
    order = _submit_all(eng, n_req=3)
    by_id = {r.request_id: r for r in eng.run()}   # must NOT raise
    for rid in order:
        assert by_id[rid].status == "failed"
        assert "injected device-step failure" in by_id[rid].error
    _assert_leak_free(eng)
    # the engine is still healthy: a fresh request (plan expired) runs
    # to completion and matches a clean engine bitwise
    rid = eng.submit(prompt=jnp.arange(5, dtype=jnp.int32),
                     max_new_tokens=5, rng=100)
    res = {r.request_id: r for r in eng.run()}[rid]
    assert res.ok
    ref = _baseline(dense_pair, "paged", "ref")
    np.testing.assert_array_equal(np.asarray(res.tokens), ref[0][:5])
    _assert_leak_free(eng)


def test_injected_fault_is_runtime_error():
    assert issubclass(InjectedFault, RuntimeError)
    with pytest.raises(ValueError, match="kind"):
        FaultSpec(kind="meteor", step=1)
    with pytest.raises(ValueError, match="1-based"):
        FaultSpec(kind="step_error", step=0)


# ---------------------------------------------------------------------------
# fixed_window validation + stats surface
# ---------------------------------------------------------------------------

def test_fixed_window_needs_static_policy_and_room(dense_pair):
    cfg_t, cfg_d, pt, pd = dense_pair
    with pytest.raises(ValueError, match="fixed_window"):
        ServingEngine(cfg_t, pt, cfg_d, pd, gamma=2, fixed_window=True,
                      draft_policy="adaptive")
    eng = _engine(dense_pair, "paged", "ref", max_len=16)
    with pytest.raises(ValueError, match="fixed speculative window"):
        # 5 prompt + 10 budget + 2 margin > 16
        eng.submit(prompt=jnp.arange(5, dtype=jnp.int32),
                   max_new_tokens=10, rng=0)


def test_stats_goodput_and_describe(dense_pair):
    eng, order, by_id = _run_workload(dense_pair, "paged", "ref")
    st = eng.stats()
    assert st.goodput_tokens == sum(by_id[rid].n for rid in order)
    text = st.describe()
    for field in ("retries=", "failed=", "cancelled=", "deadline_misses=",
                  "shed=", "faults=", "goodput_tok_s="):
        assert field in text


# ---------------------------------------------------------------------------
# TPP domain + forecast retry pass
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tpp_pair():
    cfg_t = TPPConfig(name="ch-t", encoder="thp", num_layers=2,
                      num_heads=2, d_model=16, d_ff=32, num_marks=3,
                      num_mix=4)
    cfg_d = cfg_t.replace(name="ch-d", num_layers=1, num_heads=1)
    pt = tpp.init_params(cfg_t, jax.random.PRNGKey(0))
    pd = tpp.init_params(cfg_d, jax.random.PRNGKey(1))
    return cfg_t, cfg_d, pt, pd


def _history(n=4, seed=3):
    r = np.random.default_rng(seed)
    times = np.cumsum(r.exponential(0.5, size=n)).astype(np.float32)
    marks = r.integers(0, 3, size=n).astype(np.int32)
    return times, marks


_TPP_KW = dict(method="sd", max_batch=4, max_len=16, gamma=2,
               kernel="ref", sched="grouped", page_size=4)


def test_tpp_step_error_survivors_bitwise(tpp_pair):
    cfg_t, cfg_d, pt, pd = tpp_pair
    times, marks = _history()

    def run(faults):
        eng = ServingEngine(cfg_t, pt, cfg_d, pd, faults=faults,
                            **_TPP_KW)
        ids = eng.submit(prompt=marks, times=times,
                         t_end=float(times[-1]) + 6.0, max_new_tokens=6,
                         rng=jax.random.PRNGKey(42), fanout=4)
        return eng, ids, {r.request_id: r for r in eng.run()}

    plan = FaultPlan(FaultSpec(kind="step_error", step=2))
    _, ids_r, ref = run(None)
    eng, ids_c, got = run(plan)
    assert plan.injected == 1 and eng.stats().retries >= 1
    for a, b in zip(ids_c, ids_r):
        assert got[a].ok
        np.testing.assert_array_equal(np.asarray(got[a].tokens),
                                      np.asarray(ref[b].tokens))
        np.testing.assert_array_equal(np.asarray(got[a].times),
                                      np.asarray(ref[b].times))
    _assert_leak_free(eng)


def test_forecast_retry_recovers_quarantined_rollout(tpp_pair):
    """A nan_lane fault quarantines one wave member; the Forecaster's
    retry pass resubmits it at its member offset, so the final
    quantiles are BITWISE the fault-free forecast's."""
    cfg_t, cfg_d, pt, pd = tpp_pair
    times, marks = _history()
    req = ForecastRequest(history_times=times, history_marks=marks,
                          horizon=6.0, n_rollouts=5, bins=4,
                          max_events=6, rng=jax.random.PRNGKey(42))

    eng0 = ServingEngine(cfg_t, pt, cfg_d, pd, **_TPP_KW)
    res0 = Forecaster(eng0).forecast(req)

    plan = FaultPlan(FaultSpec(kind="nan_lane", step=2, slot=1))
    eng1 = ServingEngine(cfg_t, pt, cfg_d, pd, faults=plan, **_TPP_KW)
    res1 = Forecaster(eng1).forecast(req)

    assert plan.injected >= 1, "fault never fired"
    assert res1.failed_rollouts == 0, "retry pass did not recover"
    np.testing.assert_array_equal(res0.quantiles, res1.quantiles)
    np.testing.assert_array_equal(res0.mean, res1.mean)
    assert res1.events == res0.events
    _assert_leak_free(eng1)
