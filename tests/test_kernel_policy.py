"""KernelPolicy threading: the TPP sd/ar samplers must produce the SAME
event streams whether the hot path runs the Pallas kernels (spec-verify
attention + fused mixture densities, interpret on CPU) or the jnp
references — lengths/types bitwise, times to kernel tolerance — across
the host/jit/vmap executors. Plus policy resolution rules and the
thinning hazard routed through the fused log-survival kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TPPConfig
from repro.kernels.policy import KernelPolicy
from repro.models import tpp
from repro.sampling import SamplerSpec, build_sampler

RNG = jax.random.PRNGKey(0)
TIME_TOL = 2e-5      # kernel numerics tolerance (same as sharded tests)


def _tiny_pair(K=3):
    cfg_t = TPPConfig(encoder="thp", num_layers=2, num_heads=2, d_model=16,
                      d_ff=32, num_marks=K, num_mix=4)
    cfg_d = cfg_t.replace(num_layers=1, num_heads=1)
    pt = tpp.init_params(cfg_t, jax.random.PRNGKey(0))
    pd = tpp.init_params(cfg_d, jax.random.PRNGKey(1))
    return cfg_t, cfg_d, pt, pd


# ---------------------------------------------------------------------------
# resolution rules
# ---------------------------------------------------------------------------

def test_policy_resolution_rules():
    auto = KernelPolicy()
    assert auto.backend == "auto" and auto.interpret is None
    ser = auto.resolve(default_backend="pallas")
    assert ser.backend in ("pallas", "ref") and ser.interpret is not None
    if jax.default_backend() != "tpu":
        assert ser.backend == "pallas" and ser.interpret      # serving auto
        assert tpp.resolve_policy(
            TPPConfig()).backend == "ref"                     # TPP auto
    forced = KernelPolicy(backend="pallas", interpret=False)
    assert forced.resolve().interpret is False
    # resolve() is idempotent; resolved policies hash into jit caches
    assert ser.resolve() == ser
    hash(ser)
    with pytest.raises(ValueError, match="backend"):
        KernelPolicy(backend="cuda")


def test_spec_validates_kernel_knobs():
    from repro.sampling.spec import SpecError
    with pytest.raises(SpecError, match="kernel"):
        SamplerSpec(kernel="fast").validate()
    with pytest.raises(SpecError, match="kv_layout"):
        SamplerSpec(kv_layout="ragged").validate()
    with pytest.raises(SpecError, match="token"):
        SamplerSpec(kv_layout="paged").validate()


# ---------------------------------------------------------------------------
# sd pallas == ref across executors (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["sd", "ar"])
@pytest.mark.parametrize("execution", ["host", "jit", "vmap"])
def test_tpp_pallas_stream_matches_ref(method, execution):
    cfg_t, cfg_d, pt, pd = _tiny_pair()
    batch = 3 if execution == "vmap" else 1
    base = SamplerSpec(method=method, execution=execution, t_end=2.5,
                       gamma=3, max_events=32, batch=batch)
    args = (cfg_t, pt) + ((cfg_d, pd) if method == "sd" else ())
    br = build_sampler(base.replace(kernel="ref"), *args)(
        jax.random.PRNGKey(11))
    bp = build_sampler(base.replace(kernel="pallas"), *args)(
        jax.random.PRNGKey(11))
    np.testing.assert_array_equal(np.array(br.lengths), np.array(bp.lengths))
    for b in range(batch):
        n = int(br.lengths[b])
        np.testing.assert_array_equal(np.array(br.types[b, :n]),
                                      np.array(bp.types[b, :n]))
        np.testing.assert_allclose(np.array(br.times[b, :n]),
                                   np.array(bp.times[b, :n]),
                                   atol=TIME_TOL, rtol=TIME_TOL)


def test_tpp_pallas_host_jit_identical():
    """With the SAME (pallas) policy, host and jit stay stream-equal —
    the policy rides the configs, not the executor. Types are bitwise;
    times agree to kernel tolerance (XLA fuses the interpret-mode kernel
    ops differently inside the device loop's while_loop)."""
    cfg_t, cfg_d, pt, pd = _tiny_pair()
    base = SamplerSpec(method="sd", t_end=2.0, gamma=3, max_events=32,
                       kernel="pallas")
    rh = build_sampler(base.replace(execution="host"),
                       cfg_t, pt, cfg_d, pd)(jax.random.PRNGKey(6))
    rj = build_sampler(base.replace(execution="jit"),
                       cfg_t, pt, cfg_d, pd)(jax.random.PRNGKey(6))
    n = int(rh.lengths[0])
    assert n == int(rj.lengths[0])
    np.testing.assert_array_equal(np.array(rh.types[0, :n]),
                                  np.array(rj.types[0, :n]))
    np.testing.assert_allclose(np.array(rh.times[0, :n]),
                               np.array(rj.times[0, :n]),
                               atol=TIME_TOL, rtol=TIME_TOL)


def test_attnhp_keeps_reference_attention():
    """The AttNHP +1-denominator attention has no kernel form; a pallas
    policy must still sample correctly through the reference."""
    cfg_t = TPPConfig(encoder="attnhp", num_layers=1, num_heads=2,
                      d_model=16, d_ff=32, num_marks=2, num_mix=4,
                      kernel_policy=KernelPolicy(backend="pallas"))
    pt = tpp.init_params(cfg_t, jax.random.PRNGKey(0))
    res = build_sampler(SamplerSpec(method="ar", execution="jit", t_end=2.0,
                                    max_events=16),
                        cfg_t, pt)(jax.random.PRNGKey(2))
    assert int(res.lengths[0]) >= 0
    t = np.array(res.times[0, :int(res.lengths[0])])
    assert np.all(np.diff(t) > 0) or len(t) < 2


# ---------------------------------------------------------------------------
# thinning bound through the fused log-survival kernel
# ---------------------------------------------------------------------------

def test_thinning_hazard_pallas_matches_ref():
    cfg = TPPConfig(encoder="thp", num_layers=1, num_heads=2, d_model=16,
                    d_ff=32, num_marks=2, num_mix=4)
    p = tpp.init_params(cfg, RNG)
    h = jax.random.normal(jax.random.PRNGKey(4), (cfg.d_model,))
    taus = jnp.linspace(1e-3, 2.0, 8)
    from repro.core.cif_thinning import _hazard
    ref_h = _hazard(cfg, p, h, taus)
    cfgp = cfg.replace(kernel_policy=KernelPolicy(backend="pallas"))
    pal_h = _hazard(cfgp, p, h, taus)
    assert bool(jnp.isfinite(pal_h).all())
    np.testing.assert_allclose(np.asarray(pal_h), np.asarray(ref_h),
                               atol=1e-4, rtol=1e-4)


def test_thinning_sampler_runs_with_pallas_policy():
    cfg = TPPConfig(encoder="thp", num_layers=1, num_heads=2, d_model=16,
                    d_ff=32, num_marks=2, num_mix=4,
                    kernel_policy=KernelPolicy(backend="pallas"))
    p = tpp.init_params(cfg, RNG)
    fn = build_sampler(SamplerSpec(method="thinning", execution="host",
                                   t_end=2.0, max_events=16), cfg, p)
    batch = fn(jax.random.PRNGKey(5))
    assert int(batch.lengths[0]) >= 0
