"""Property fuzz over the COW pool + radix cache lifecycle (hypothesis).

Random admit/fork/append/rollback/retire/evict schedules drive a
``PagedKVCachePool`` + ``PrefixCache`` pair through the same moves the
serving engine makes, checking after EVERY operation that the page
bookkeeping is airtight:

  - no leak / no double-free: every page's refcount equals the number
    of block-table entries plus cache nodes actually holding it, the
    free list holds exactly the refcount-0 pages (each once), and the
    null page 0 is never allocated or freed;
  - no write into a shared page: after ``cow_for_append``, the page
    under a slot's write frontier always has refcount 1;
  - admission accounting never deadlocks: operating strictly inside
    the lifetime reservations (``can_admit`` with adopted/COW budgets,
    as the engine does), ``ensure_blocks``/``cow_for_append`` must
    never run out of pages — an unexpected RuntimeError IS the bug.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig
from repro.serving.kv_pool import PagedKVCachePool
from repro.serving.prefix_cache import PrefixCache

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

PAGE, SLOTS, MAXLEN = 4, 3, 16

# overlapping prompts so the radix tree actually shares pages
_PROMPTS = [
    (0, 1, 2, 3, 0, 1, 2, 3, 0, 1),
    (0, 1, 2, 3, 0, 1, 2, 3, 2, 2, 1),
    (0, 1, 2, 3, 3, 3, 3, 3, 1),
    (1, 1, 1, 2, 2),
    (0, 1, 2, 3),
]


def _cfg():
    return ModelConfig(name="t", family="dense", num_layers=1, d_model=32,
                       num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=7,
                       dtype="float32", param_dtype="float32", remat=False)


class _Slot:
    """Host mirror of one admitted request: its committed tokens, the
    original prompt length (rollback floor / donation extent), and the
    reserved lifetime total."""

    def __init__(self, tokens, prompt_len, total):
        self.tokens = list(tokens)
        self.prompt_len = prompt_len
        self.total = total


def _check(pool, cache, note):
    """The no-leak / no-double-free invariant, from first principles."""
    owners = np.zeros(pool.n_pages, np.int64)
    for s in range(pool.n_slots):
        for b in range(int(pool.n_blocks[s])):
            pid = int(pool.tables[s, b])
            assert pid > 0, f"{note}: null page in a live table"
            owners[pid] += 1
    for nd in cache._nodes():
        owners[int(nd.pages["t"])] += 1
    assert np.array_equal(owners, np.asarray(pool.refcount, np.int64)), \
        f"{note}: refcounts drifted from actual owners"
    free = pool.free
    assert 0 not in free and len(set(free)) == len(free), \
        f"{note}: corrupt free list"
    assert all(int(pool.refcount[p]) == 0 for p in free), \
        f"{note}: freed page still has owners"
    assert len(free) + int((owners > 0).sum()) == pool.n_pages - 1, \
        f"{note}: page leaked (neither free nor owned)"


def _append_one(pool, slot, tok, slots):
    pool.cow_for_append(slot)
    n = int(pool.lens[slot])
    pool.ensure_blocks(slot, n + 1)
    frontier = int(pool.tables[slot, n // PAGE])
    assert int(pool.refcount[frontier]) == 1, "write into a SHARED page"
    pool.lens[slot] = n + 1
    slots[slot].tokens.append(tok)


@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 7),
                          st.integers(0, 7)),
                min_size=1, max_size=60))
def test_random_lifecycle_never_leaks_or_shares_writes(ops):
    pool = PagedKVCachePool(SLOTS, _cfg(), page_size=PAGE, max_len=MAXLEN)
    cache = PrefixCache(PAGE, {"t": pool})
    slots = {}                                  # slot -> _Slot

    for i, (op, a, b) in enumerate(ops):
        note = f"op {i} ({op},{a},{b})"
        if op == 0:                             # ADMIT via the cache
            free_slots = [s for s in range(SLOTS) if s not in slots]
            if not free_slots:
                continue
            slot = free_slots[a % len(free_slots)]
            prompt = _PROMPTS[b % len(_PROMPTS)]
            total = min(len(prompt) + 1 + a % 5, MAXLEN)
            hit, runs = cache.match(np.asarray(prompt), len(prompt) - 1)
            if not pool.can_admit(total, adopted_blocks=hit // PAGE):
                continue
            pool.reserve(slot, total)
            if hit:
                pool.adopt(slot, runs["t"])
            slots[slot] = _Slot(prompt[:hit], len(prompt), total)
            while int(pool.lens[slot]) < len(prompt):
                _append_one(pool, slot,
                            prompt[int(pool.lens[slot])], slots)
        elif op == 1:                           # FORK a live slot
            live = sorted(slots)
            free_slots = [s for s in range(SLOTS) if s not in slots]
            if not live or not free_slots:
                continue
            src = live[a % len(live)]
            dst = free_slots[b % len(free_slots)]
            upto = int(pool.lens[src])
            if upto == 0:
                continue
            total = min(upto + 1 + b % 5, MAXLEN)
            cow = 0
            if upto % PAGE != 0:
                pid = int(pool.tables[src, upto // PAGE])
                cow = 1 + (1 if int(pool.refcount[pid]) == 1 else 0)
            adopted = pool._blocks_for(upto)
            if not pool.can_admit(total, adopted_blocks=adopted,
                                  cow_pages=cow):
                continue
            pool.reserve(dst, total)
            pool.fork(src, dst, upto)
            slots[dst] = _Slot(slots[src].tokens[:upto],
                               slots[src].prompt_len, total)
        elif op == 2:                           # APPEND inside reservation
            live = sorted(slots)
            if not live:
                continue
            slot = live[a % len(live)]
            if int(pool.lens[slot]) >= slots[slot].total:
                continue
            _append_one(pool, slot, b % 7, slots)
        elif op == 3:                           # ROLLBACK (never the prompt)
            live = sorted(slots)
            if not live:
                continue
            slot = live[a % len(live)]
            floor = min(slots[slot].prompt_len, int(pool.lens[slot]))
            new_len = max(floor, int(pool.lens[slot]) - (b % 3 + 1))
            pool.truncate(slot, new_len)
            del slots[slot].tokens[new_len:]
        elif op == 4:                           # RETIRE + donate prompt
            live = sorted(slots)
            if not live:
                continue
            slot = live[a % len(live)]
            state = slots.pop(slot)
            full = min(state.prompt_len, int(pool.lens[slot])) // PAGE
            if full:
                pages = [int(pool.tables[slot, j]) for j in range(full)]
                cache.insert(np.asarray(state.tokens[:full * PAGE]),
                             {"t": pages})
            pool.free_slot(slot)
        else:                                   # EVICT
            cache.evict("t", a % 3 + 1)
        _check(pool, cache, note)

    # drain: retire everything, then drop the cache — all pages return
    for slot, state in list(slots.items()):
        pool.free_slot(slot)
    cache.clear()
    assert int(pool.refcount.sum()) == 0
    assert len(pool.free) == pool.n_pages - 1
