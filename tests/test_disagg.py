"""Disaggregated prefill/decode serving (``repro.serving.disagg``).

The pinned contracts:

  - the split is bitwise-neutral: with admission pinned to the prefill
    worker and completed prompts handed to decode slots by block-table
    transfer, the committed streams equal the unified engine's under
    ``method="ar"`` and ``method="sd", fixed_window=True`` (the handoff
    delays WHEN a request decodes, never WHAT it samples — same
    ``fold_in(rng, round_idx)`` streams);
  - the handoff barrier is a fault point: an injected ``handoff_error``
    fires BEFORE any ownership moves, so retried handoffs replay
    nothing (survivors bitwise), and a request whose retry budget is
    spent fails alone with zero leaked pages;
  - ``PagedKVCachePool.transfer_slot`` is pure bookkeeping: page ids
    move ``src``→``dst``, net refcounts unchanged, free list untouched,
    shared (forked) pages stay shared;
  - a parked request (prompt done, no free decode slot) can be
    cancelled: its queue entry is purged and its pages freed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, TPPConfig
from repro.models import registry, tpp
from repro.serving import (DisaggServingEngine, FaultPlan, FaultSpec,
                           ServeRequest, ServingEngine)
from repro.serving.kv_pool import PagedKVCachePool

RNG = jax.random.PRNGKey(0)


def _dense(num_layers=2, name="t", **kw):
    base = dict(name=name, family="dense", num_layers=num_layers,
                d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                vocab_size=31, dtype="float32", param_dtype="float32",
                remat=False)
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def pair():
    cfg_t, cfg_d = _dense(2), _dense(1, name="d")
    mt, md = registry.get_model(cfg_t), registry.get_model(cfg_d)
    return (cfg_t, cfg_d, mt.init_params(RNG),
            md.init_params(jax.random.PRNGKey(1)))


def _kw(method, **kw):
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_len", 32)
    kw.setdefault("prefill_chunk", 3)
    kw.setdefault("kv_layout", "paged")
    kw.setdefault("kernel", "ref")
    if method == "sd":
        kw.setdefault("gamma", 2)
        kw.setdefault("fixed_window", True)
    return kw


def _unified(pair, method, **kw):
    cfg_t, cfg_d, pt, pd = pair
    kw = _kw(method, **kw)
    if method == "ar":
        return ServingEngine(cfg_t, pt, method="ar", **kw)
    return ServingEngine(cfg_t, pt, cfg_d, pd, method="sd", **kw)


def _disagg(pair, method, **kw):
    cfg_t, cfg_d, pt, pd = pair
    kw = _kw(method, **kw)
    if method == "ar":
        return DisaggServingEngine(cfg_t, pt, method="ar", **kw)
    return DisaggServingEngine(cfg_t, pt, cfg_d, pd, method="sd", **kw)


def _submit_all(eng, n_req=4):
    return [eng.submit(ServeRequest(
        prompt=jnp.arange(5, dtype=jnp.int32), max_new_tokens=5 + i,
        rng=100 + i, temperature=1.0 + 0.1 * (i % 3)))
        for i in range(n_req)]


def _tokens_by_id(results):
    return {r.request_id: np.asarray(r.tokens) for r in results}


def _assert_leak_free(eng):
    for pool in (eng.pool_t, eng.pool_d):
        if pool is None:
            continue
        assert int(pool.refcount.sum()) == 0
        assert len(pool.free) == pool.n_pages - 1
    assert len(eng._handoffs) == 0


# ---------------------------------------------------------------------------
# bitwise parity with the unified engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["ar", "sd"])
@pytest.mark.parametrize("prefill_slots", [1, 2])
def test_disagg_bitwise_equals_unified(pair, method, prefill_slots):
    base = _unified(pair, method)
    order = _submit_all(base)
    want = _tokens_by_id(base.run())

    eng = _disagg(pair, method, prefill_slots=prefill_slots)
    _submit_all(eng)
    got = _tokens_by_id(eng.run())

    assert len(got) == len(want) == len(order)
    for rid_w, rid_g in zip(sorted(want), sorted(got)):
        np.testing.assert_array_equal(want[rid_w], got[rid_g])
    assert eng.stats().handoffs == len(order)
    assert eng.prefill_worker.slots == tuple(range(prefill_slots))
    assert eng.decode_worker.slots == tuple(range(prefill_slots, 3))
    _assert_leak_free(eng)


def test_disagg_async_loop_bitwise(pair):
    """The two tentpole halves compose: run_async() on the disagg
    engine still equals the unified sync run."""
    base = _unified(pair, "sd")
    _submit_all(base)
    want = _tokens_by_id(base.run())

    eng = _disagg(pair, "sd", prefill_slots=1)
    _submit_all(eng)
    got = _tokens_by_id(eng.run_async())
    for rid_w, rid_g in zip(sorted(want), sorted(got)):
        np.testing.assert_array_equal(want[rid_w], got[rid_g])
    assert eng.stats().overlap_ms > 0
    _assert_leak_free(eng)


# ---------------------------------------------------------------------------
# the handoff barrier as a fault point
# ---------------------------------------------------------------------------

def test_handoff_fault_retries_bitwise(pair):
    base = _unified(pair, "sd")
    _submit_all(base)
    want = _tokens_by_id(base.run())

    # prompt(5) in chunks of 3 completes at step 2; the first drain
    # attempt is step 3's — fail it twice, the third attempt lands
    plan = FaultPlan(FaultSpec(kind="handoff_error", step=3, times=2))
    eng = _disagg(pair, "sd", prefill_slots=1, faults=plan)
    _submit_all(eng)
    got = {r.request_id: r for r in eng.run()}

    assert plan.injected_of("handoff_error") >= 1
    assert eng.stats().retries >= 1
    assert eng.stats().handoffs == len(want)
    for rid_w, rid_g in zip(sorted(want), sorted(got)):
        assert got[rid_g].ok, got[rid_g].error
        np.testing.assert_array_equal(want[rid_w],
                                      np.asarray(got[rid_g].tokens))
    _assert_leak_free(eng)


def test_handoff_retry_exhaustion_fails_head_only(pair):
    base = _unified(pair, "sd")
    base_order = _submit_all(base)
    want = _tokens_by_id(base.run())

    plan = FaultPlan(FaultSpec(kind="handoff_error", step=3, times=4))
    eng = _disagg(pair, "sd", prefill_slots=1, max_round_retries=1,
                  faults=plan)
    order = _submit_all(eng)
    results = {r.request_id: r for r in eng.run()}

    failed = [r for r in results.values() if not r.ok]
    assert len(failed) == 1
    # r0's handoff lands at step 2, before the fault window opens; r1
    # is the queue HEAD while the window is live, so it alone is
    # charged — once per failed drain — until its budget is spent
    assert failed[0].request_id == order[1]
    assert failed[0].status == "failed"
    assert "handoff" in failed[0].error
    assert len(failed[0].tokens) == 0      # never reached a decode slot
    # survivors are bitwise the unified streams for THEIR requests
    for i in (0, 2, 3):
        r = results[order[i]]
        assert r.ok, r.error
        np.testing.assert_array_equal(want[base_order[i]],
                                      np.asarray(r.tokens))
    _assert_leak_free(eng)


# ---------------------------------------------------------------------------
# transfer_slot bookkeeping
# ---------------------------------------------------------------------------

def test_transfer_slot_moves_references_not_pages():
    pool = PagedKVCachePool(3, _dense(1), page_size=4, max_len=16)
    pool.reserve(0, 10)
    pool.ensure_blocks(0, 10)
    pool.lens[0] = 10
    pages = [int(pool.tables[0, b]) for b in range(int(pool.n_blocks[0]))]
    free_before = sorted(pool.free)
    rc_before = pool.refcount.copy()

    nb = pool.transfer_slot(0, 2)

    assert nb == len(pages) == 3
    assert [int(pool.tables[2, b]) for b in range(3)] == pages
    assert int(pool.lens[2]) == 10
    assert int(pool.n_blocks[2]) == 3
    assert int(pool.reserved[2]) == 3
    # src fully vacated
    assert int(pool.lens[0]) == 0
    assert int(pool.n_blocks[0]) == 0
    assert int(pool.reserved[0]) == 0
    # zero net effect on the allocator: refcounts and free list exact
    np.testing.assert_array_equal(pool.refcount, rc_before)
    assert sorted(pool.free) == free_before


def test_transfer_slot_rejects_nonempty_dst():
    pool = PagedKVCachePool(3, _dense(1), page_size=4, max_len=16)
    pool.ensure_blocks(0, 4)
    pool.lens[0] = 4
    pool.ensure_blocks(1, 4)
    pool.lens[1] = 4
    with pytest.raises(ValueError, match="not empty"):
        pool.transfer_slot(0, 1)


def test_transfer_slot_keeps_forked_pages_shared():
    pool = PagedKVCachePool(3, _dense(1), page_size=4, max_len=16)
    pool.ensure_blocks(0, 8)
    pool.lens[0] = 8
    pool.fork(0, 1, 8)                     # slots 0 and 1 share 2 pages
    shared = [int(pool.tables[0, b]) for b in range(2)]
    assert all(int(pool.refcount[p]) == 2 for p in shared)

    pool.transfer_slot(0, 2)
    # the fork partner's view is untouched; refcounts still 2
    assert [int(pool.tables[1, b]) for b in range(2)] == shared
    assert [int(pool.tables[2, b]) for b in range(2)] == shared
    assert all(int(pool.refcount[p]) == 2 for p in shared)


# ---------------------------------------------------------------------------
# parked-request lifecycle
# ---------------------------------------------------------------------------

def test_cancel_parked_request_purges_queue(pair):
    # 2 prefill slots, 1 decode slot: both prompts finish together but
    # only one can be adopted — the other stays parked in the queue
    eng = _disagg(pair, "sd", prefill_slots=2)
    order = _submit_all(eng, n_req=2)
    done = []
    for _ in range(3):
        done.extend(eng.step())
    assert len(eng._handoffs) == 1
    parked = eng._handoffs.peek().state.request.request_id
    assert parked == order[1]              # FIFO: oldest adopted first

    res = eng.cancel(parked)
    assert res is not None and res.status == "cancelled"
    assert len(eng._handoffs) == 0

    done.extend(eng.run())
    by_id = {r.request_id: r for r in done}
    assert by_id[order[0]].ok
    _assert_leak_free(eng)


# ---------------------------------------------------------------------------
# constructor validation
# ---------------------------------------------------------------------------

def test_rejects_bad_prefill_slots(pair):
    for bad in (0, 3, 7):
        with pytest.raises(ValueError, match="prefill_slots"):
            _disagg(pair, "ar", prefill_slots=bad)


def test_rejects_dense_layout(pair):
    with pytest.raises(ValueError):
        _disagg(pair, "ar", kv_layout="dense")


def test_rejects_tpp_domain():
    cfg_t = TPPConfig(name="dg-t", encoder="thp", num_layers=1,
                      num_heads=1, d_model=16, d_ff=32, num_marks=3,
                      num_mix=4)
    cfg_d = cfg_t.replace(name="dg-d")
    pt = tpp.init_params(cfg_t, jax.random.PRNGKey(0))
    pd = tpp.init_params(cfg_d, jax.random.PRNGKey(1))
    with pytest.raises(ValueError):
        DisaggServingEngine(cfg_t, pt, cfg_d, pd, method="sd",
                            max_batch=3, max_len=24, gamma=2)
