"""Token-level speculative decoding over the architecture zoo."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from scipy import stats


from _stats import chisq as _chisq

from repro.configs.base import ModelConfig
from repro.core import llm_sd
from repro.models import registry

RNG = jax.random.PRNGKey(0)


def _dense(num_layers=2, vocab=31):
    return ModelConfig(name="t", family="dense", num_layers=num_layers,
                       d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                       vocab_size=vocab, dtype="float32",
                       param_dtype="float32", remat=False)


def test_same_model_accepts_all_drafts():
    cfg = _dense()
    m = registry.get_model(cfg)
    p = m.init_params(RNG)
    st = llm_sd.serve_speculative(cfg, cfg, p, p, m, m,
                                  jnp.arange(5, dtype=jnp.int32),
                                  jax.random.PRNGKey(1), max_new_tokens=12,
                                  gamma=4, max_len=64)
    assert st.accepted == st.drafted
    assert st.n == 12


@pytest.mark.parametrize("family,extra", [
    ("ssm", dict(num_heads=0, num_kv_heads=0, d_ff=0, ssm_state=8)),
    ("hybrid", dict(block_pattern=("rec", "rec", "attn"), lru_width=24,
                    sliding_window=16, num_kv_heads=1, num_layers=4)),
])
def test_replay_families_speculative_serving(family, extra):
    kw = dict(name="x", family=family, num_layers=2, d_model=32, num_heads=4,
              num_kv_heads=2, d_ff=64, vocab_size=31, dtype="float32",
              param_dtype="float32", remat=False)
    kw.update(extra)
    cfg = ModelConfig(**kw)
    m = registry.get_model(cfg)
    p = m.init_params(RNG)
    st = llm_sd.serve_speculative(cfg, cfg, p, p, m, m,
                                  jnp.arange(5, dtype=jnp.int32),
                                  jax.random.PRNGKey(1), max_new_tokens=8,
                                  gamma=3, max_len=64)
    assert st.accepted == st.drafted  # identical models: zero rejections
    assert st.n == 8


def test_sd_token_distribution_matches_ar():
    """First generated token over many seeds: SD dist == AR dist (both must
    equal the target model's softmax)."""
    cfg_t = _dense(num_layers=2, vocab=13)
    cfg_d = _dense(num_layers=1, vocab=13)
    mt, md = registry.get_model(cfg_t), registry.get_model(cfg_d)
    pt, pd = mt.init_params(RNG), md.init_params(jax.random.PRNGKey(9))
    prompt = jnp.arange(4, dtype=jnp.int32)
    lt, _ = mt.prefill(pt, {"tokens": prompt[None]}, 32)
    target_p = np.array(jax.nn.softmax(lt[0, -1]))
    N = 400
    toks = []
    for i in range(N):
        st = llm_sd.serve_speculative(cfg_t, cfg_d, pt, pd, mt, md, prompt,
                                      jax.random.PRNGKey(100 + i),
                                      max_new_tokens=1, gamma=2, max_len=32)
        toks.append(int(st.tokens[0]))
    cnt = np.bincount(np.array(toks), minlength=13)
    res = _chisq(cnt, target_p)
    assert res.pvalue > 1e-3, (cnt / N, target_p)


def test_speedup_accounting():
    """SD must use fewer target forwards than AR for the same tokens."""
    cfg = _dense()
    m = registry.get_model(cfg)
    p = m.init_params(RNG)
    st = llm_sd.serve_speculative(cfg, cfg, p, p, m, m,
                                  jnp.arange(5, dtype=jnp.int32),
                                  jax.random.PRNGKey(1), max_new_tokens=20,
                                  gamma=4, max_len=64)
    # with all-accept, rounds ~ ceil(20 / (gamma+1)) << 20 AR steps
    assert st.rounds <= 20 // 4 + 1
