"""Data-pipeline and metrics coverage + dry-run collective parser units."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from scipy import stats

from repro.core import thinning as thin
from repro.data import synthetic as ds
from repro import metrics as M


def test_inhom_poisson_compensator_matches_quadrature():
    proc = thin.InhomPoisson()
    a, b = 3.0, 17.0
    grid = np.linspace(a, b, 20001)
    lam = np.array([proc.intensity(t, [], [])[0] for t in grid])
    quad = np.trapezoid(lam, grid)
    assert abs(proc.compensator(a, b, [], []) - quad) < 1e-3


def test_hawkes_compensator_matches_quadrature():
    proc = thin.Hawkes()
    hist = [0.5, 1.2, 2.0]
    marks = [0, 0, 0]
    a, b = 2.0, 6.0
    grid = np.linspace(a + 1e-9, b, 20001)
    lam = np.array([proc.intensity(t, hist, marks)[0] for t in grid])
    quad = np.trapezoid(lam, grid)
    assert abs(proc.compensator(a, b, hist, marks) - quad) < 1e-3


def test_multihawkes_stability_enforced():
    d = ds.make_dataset("stackoverflow_like", n_seqs=2, t_end=5.0)
    proc = d.process
    B = proc.alpha / proc.beta
    assert abs(np.linalg.eigvals(B)).max() < 1.0


def test_ground_truth_loglik_favors_true_process():
    """GT loglik of Hawkes samples must beat a wrong-parameter Hawkes."""
    proc = thin.Hawkes()
    wrong = thin.Hawkes(mu=0.5, alpha=0.2, beta=4.0)
    rng = np.random.default_rng(0)
    lls_true = lls_wrong = 0.0
    for _ in range(5):
        t, k = thin.thinning_sample(proc, 10.0, rng)
        lls_true += thin.ground_truth_loglik(proc, t, k, 10.0)
        lls_wrong += thin.ground_truth_loglik(wrong, t, k, 10.0)
    assert lls_true > lls_wrong


def test_pad_batch_shapes_and_masks():
    seqs = [(np.array([0.5, 1.0]), np.array([0, 1])),
            (np.array([0.2]), np.array([1]))]
    b = ds.pad_batch(seqs, 4)
    assert b["times"].shape == (2, 4)
    np.testing.assert_array_equal(b["mask"], [[1, 1, 0, 0], [1, 0, 0, 0]])
    np.testing.assert_array_equal(b["types"][0, :2], [0, 1])


def test_batches_drop_last_and_determinism():
    seqs = [(np.arange(1, 3, dtype=float), np.zeros(2, int))] * 10
    bs = list(ds.batches(seqs, 4, 8, drop_last=True, seed=3))
    assert len(bs) == 2
    a = list(ds.batches(seqs, 4, 8, seed=5))
    b = list(ds.batches(seqs, 4, 8, seed=5))
    np.testing.assert_array_equal(a[0]["times"], b[0]["times"])


def test_ks_statistic_calibrated():
    rng = np.random.default_rng(0)
    z = rng.exponential(1.0, 5000)
    assert M.ks_statistic(z) < M.ks_confidence_band(5000)
    z_bad = rng.exponential(2.0, 5000)  # wrong rate -> fails
    assert M.ks_statistic(z_bad) > M.ks_confidence_band(5000)


def test_wasserstein_matches_scipy():
    rng = np.random.default_rng(1)
    a, b = rng.normal(0, 1, 300), rng.normal(0.7, 1.3, 400)
    ours = M.wasserstein_1d(a, b)
    theirs = stats.wasserstein_distance(a, b)
    assert abs(ours - theirs) < 0.05


def test_collective_parser_counts_and_multiplies():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ag = bf16[4,128]{1,0} all-gather(%x), replica_groups=[2,4]<=[8]
  %ar = f32[16]{0} all-reduce(%y), to_apply=%sum
  %cp = (f32[8]{0}, f32[8]{0}) collective-permute(%z, %w)
  %not_a_coll = f32[999]{0} add(%a, %b)
"""
    total, by_type = collective_bytes(hlo)
    assert by_type["all-gather"]["bytes"] == 4 * 128 * 2
    assert by_type["all-reduce"]["bytes"] == 16 * 4 * 2   # counted 2x
    assert by_type["collective-permute"]["bytes"] == 2 * 8 * 4
    assert total == sum(v["bytes"] for v in by_type.values())


def test_smoke_variant_invariants():
    from repro.configs import ARCHS, smoke_variant
    for cfg in ARCHS.values():
        s = smoke_variant(cfg)
        assert s.family == cfg.family
        assert s.num_layers <= 4 and s.d_model <= 512
        if s.is_moe:
            assert s.num_experts <= 4
