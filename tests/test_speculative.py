"""Correctness of the speculative-decoding primitives and the full
TPP-SD sampler: the output distribution must EQUAL target AR sampling
(paper's central claim, App. A.2/A.3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from scipy import stats


from _stats import chisq as _chisq

from repro.configs.base import TPPConfig
from repro.core import speculative as spec
from repro.models import tpp
from repro.sampling import SamplerSpec, build_sampler

RNG = jax.random.PRNGKey(0)


def test_adjusted_discrete_exact():
    """draft-sample + accept/resample must reproduce the target pmf."""
    logp_t = jax.nn.log_softmax(jnp.array([0.5, -0.2, 1.0, -1.0]))
    logp_d = jax.nn.log_softmax(jnp.array([-0.5, 0.8, 0.1, 0.3]))
    B = 100_000

    def one(r):
        r1, r2, r3 = jax.random.split(r, 3)
        k = jax.random.categorical(r1, logp_d)
        acc = spec.accept_logratio(r2, logp_t[k], logp_d[k])
        k_adj = spec.adjusted_discrete(r3, logp_t, logp_d)
        return jnp.where(acc, k, k_adj)

    ks = np.array(jax.vmap(one)(jax.random.split(RNG, B)))
    counts = np.bincount(ks, minlength=4)
    p = np.exp(np.array(logp_t))
    res = _chisq(counts, p)
    assert res.pvalue > 1e-3, (counts / B, p)


def test_adjusted_discrete_identical_dists_fallback():
    lp = jax.nn.log_softmax(jnp.array([0.1, 0.2, 0.3]))
    k = spec.adjusted_discrete(RNG, lp, lp)
    assert int(k) in (0, 1, 2)


def test_adjusted_continuous_matches_adjusted_density():
    """Theorem 1 sampler vs numerically-normalized max(0, g_T - g_D)."""
    mix_t = tpp.MixParams(jnp.log(jnp.array([0.6, 0.4])),
                          jnp.array([0.0, 1.0]), jnp.array([0.5, 0.3]))
    mix_d = tpp.MixParams(jnp.log(jnp.array([0.5, 0.5])),
                          jnp.array([0.3, 1.2]), jnp.array([0.6, 0.4]))
    B = 30_000
    taus = np.array(jax.vmap(
        lambda r: spec.adjusted_continuous(r, mix_t, mix_d))(
            jax.random.split(RNG, B)))
    # numeric CDF of the adjusted density on a grid
    grid = np.linspace(1e-4, 20.0, 20_000)

    def pdf(mix, x):
        return np.exp(np.array(tpp.interval_logpdf(mix, jnp.asarray(x))))

    adj = np.maximum(0.0, pdf(mix_t, grid) - pdf(mix_d, grid))
    Z = np.trapezoid(adj, grid)
    cdf_vals = np.cumsum(adj) * (grid[1] - grid[0]) / Z

    def cdf(x):
        return np.interp(x, grid, np.clip(cdf_vals, 0, 1))

    res = stats.kstest(taus, cdf)
    assert res.pvalue > 1e-3, res


def _tiny_pair(K=3):
    cfg_t = TPPConfig(encoder="thp", num_layers=2, num_heads=2, d_model=16,
                      d_ff=32, num_marks=K, num_mix=4)
    cfg_d = cfg_t.replace(num_layers=1, num_heads=1)
    pt = tpp.init_params(cfg_t, jax.random.PRNGKey(0))
    pd = tpp.init_params(cfg_d, jax.random.PRNGKey(1))
    return cfg_t, cfg_d, pt, pd


@pytest.mark.parametrize("gamma", [1, 3])
def test_sd_first_event_matches_analytic_target(gamma):
    """The first SD event's (tau, k) must follow the target model's own
    heads exactly — compared against the ANALYTIC distributions."""
    cfg_t, cfg_d, pt, pd = _tiny_pair()
    K = cfg_t.num_marks
    cache = tpp.init_cache(cfg_t, 4)
    h, _ = tpp.extend(cfg_t, pt, cache, jnp.zeros(1),
                      jnp.full((1,), K, jnp.int32))
    target_pk = np.array(jax.nn.softmax(tpp.type_logits(cfg_t, pt, h[0])))
    mix = tpp.interval_params(cfg_t, pt, h[0])

    B = 15_000
    fn = build_sampler(SamplerSpec(method="sd", execution="vmap", t_end=1e9,
                                   gamma=gamma, max_events=3, batch=B),
                       cfg_t, pt, cfg_d, pd)
    rs = fn(jax.random.PRNGKey(7))
    ts, ks = np.array(rs.times[:, 0]), np.array(rs.types[:, 0])
    cnt = np.bincount(ks, minlength=K)
    chi = _chisq(cnt, target_pk)
    assert chi.pvalue > 1e-3, (cnt / B, target_pk)

    def mix_cdf(x):
        z = ((np.log(np.maximum(x, 1e-30))[..., None] - np.array(mix.mu))
             / np.array(mix.sigma))
        return (np.exp(np.array(mix.log_w)) * stats.norm.cdf(z)).sum(-1)

    assert stats.kstest(ts, mix_cdf).pvalue > 1e-3


def test_sd_same_model_accepts_everything():
    cfg_t, _, pt, _ = _tiny_pair()
    res = build_sampler(SamplerSpec(method="sd", execution="jit", t_end=3.0,
                                    gamma=4, max_events=64),
                        cfg_t, pt, cfg_t, pt)(jax.random.PRNGKey(3))
    st = res.stats()
    assert st.accepted == st.drafted


def test_sd_sequence_dist_matches_ar():
    """Whole-sequence statistics AR vs SD (two-sample tests)."""
    cfg_t, cfg_d, pt, pd = _tiny_pair()
    B, T_END, EMAX = 400, 2.0, 64
    base = SamplerSpec(execution="vmap", t_end=T_END, max_events=EMAX,
                       batch=B)
    ra = build_sampler(base.replace(method="ar"),
                       cfg_t, pt)(jax.random.PRNGKey(4))
    rs = build_sampler(base.replace(method="sd", gamma=4),
                       cfg_t, pt, cfg_d, pd)(jax.random.PRNGKey(5))
    na, ns = np.array(ra.n), np.array(rs.n)
    assert stats.ks_2samp(na, ns).pvalue > 1e-3
    fa = np.array(ra.times[:, 0])[na > 0]
    fs = np.array(rs.times[:, 0])[ns > 0]
    assert stats.ks_2samp(fa, fs).pvalue > 1e-3


def test_sd_host_and_jit_agree_in_distribution():
    cfg_t, cfg_d, pt, pd = _tiny_pair()
    base = SamplerSpec(method="sd", t_end=2.0, gamma=3, max_events=32)
    rj = build_sampler(base.replace(execution="jit"),
                       cfg_t, pt, cfg_d, pd)(jax.random.PRNGKey(6))
    rh = build_sampler(base.replace(execution="host"),
                       cfg_t, pt, cfg_d, pd)(jax.random.PRNGKey(6))
    # identical rng stream + identical round function => identical output
    nj = int(rj.lengths[0])
    assert nj == int(rh.lengths[0])
    np.testing.assert_allclose(np.array(rj.times[0, :nj]),
                               np.array(rh.times[0, :nj]), rtol=1e-6)


def _sd_jit(cfg_t, cfg_d, pt, pd, t_end, gamma, emax, rng):
    return build_sampler(SamplerSpec(method="sd", execution="jit",
                                     t_end=t_end, gamma=gamma,
                                     max_events=emax),
                         cfg_t, pt, cfg_d, pd)(rng)


def test_sd_gamma_one_and_tiny_budget_edges():
    """gamma=1 and max_events smaller than one window must stay correct."""
    cfg_t, cfg_d, pt, pd = _tiny_pair()
    r1 = _sd_jit(cfg_t, cfg_d, pt, pd, 5.0, 1, 2, jax.random.PRNGKey(0))
    n1 = int(r1.lengths[0])
    assert 0 <= n1 <= 2
    assert bool(jnp.all(jnp.diff(r1.times[0, :n1]) > 0)) or n1 < 2
    # large gamma vs small horizon: overshooting events are truncated
    r2 = _sd_jit(cfg_t, cfg_d, pt, pd, 0.05, 8, 32, jax.random.PRNGKey(1))
    assert bool(jnp.all(r2.times[0, :int(r2.lengths[0])] <= 0.05))


def test_sd_times_strictly_increasing():
    cfg_t, cfg_d, pt, pd = _tiny_pair()
    res = _sd_jit(cfg_t, cfg_d, pt, pd, 4.0, 5, 128, jax.random.PRNGKey(2))
    t = np.array(res.times[0, :int(res.lengths[0])])
    assert np.all(np.diff(t) > 0), "event times must be strictly increasing"
