"""Per-kernel validation: Pallas (interpret=True) against the pure-jnp
ref.py oracles, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.lognorm_mix import lognorm_mix_logpdf_pallas

RNG = jax.random.PRNGKey(0)


def _attn_inputs(B, Sq, Sk, H, KV, Dh, dtype, valid_frac=0.7, offset=True):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, Dh), dtype)
    k = jax.random.normal(ks[1], (B, Sk, KV, Dh), dtype)
    v = jax.random.normal(ks[2], (B, Sk, KV, Dh), dtype)
    n_valid = max(1, int(Sk * valid_frac))
    kv_pos = jnp.where(jnp.arange(Sk) < n_valid, jnp.arange(Sk),
                       jnp.iinfo(jnp.int32).max)[None].repeat(B, 0)
    start = n_valid - Sq // 2 if offset else 0
    q_pos = (max(start, 0) + jnp.arange(Sq))[None].repeat(B, 0)
    return q, k, v, q_pos, kv_pos


@pytest.mark.parametrize("shape", [
    (1, 16, 16, 2, 2, 8), (2, 70, 90, 4, 2, 16), (2, 128, 128, 8, 2, 32),
    (1, 33, 257, 4, 4, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window,softcap", [(0, 0.0), (32, 0.0), (0, 20.0)])
def test_flash_attention_pallas_vs_oracle(shape, dtype, window, softcap):
    B, Sq, Sk, H, KV, Dh = shape
    q, k, v, qp, kp = _attn_inputs(B, Sq, Sk, H, KV, Dh, dtype)
    out = flash_attention_pallas(q, k, v, qp, kp, window=window,
                                 softcap=softcap, bq=16, bk=32,
                                 interpret=True)
    want = ref.naive_attention(q, k, v, qp, kp, window=window,
                               softcap=softcap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("shape", [
    (1, 4, 2, 8, 64), (3, 8, 2, 16, 200), (2, 16, 4, 32, 513),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 64])
def test_decode_attention_pallas_vs_oracle(shape, dtype, window):
    B, H, KV, Dh, Sk = shape
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (B, H, Dh), dtype)
    k = jax.random.normal(ks[1], (B, Sk, KV, Dh), dtype)
    v = jax.random.normal(ks[2], (B, Sk, KV, Dh), dtype)
    lens = jnp.arange(1, B + 1) * (Sk // (B + 1)) + 1
    kv_pos = jnp.where(jnp.arange(Sk)[None] < lens[:, None],
                       jnp.arange(Sk)[None],
                       jnp.iinfo(jnp.int32).max)
    out = decode_attention_pallas(q, k, v, lens, kv_pos, window=window,
                                  bk=64, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lens, kv_pos, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("N,M", [(1, 4), (100, 64), (257, 16), (1000, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_lognorm_mix_pallas_vs_oracle(N, M, dtype):
    ks = jax.random.split(RNG, 4)
    tau = jax.random.uniform(ks[0], (N,), dtype, 1e-3, 10.0)
    log_w = jax.nn.log_softmax(jax.random.normal(ks[1], (N, M), dtype))
    mu = jax.random.normal(ks[2], (N, M), dtype)
    sigma = jnp.exp(jax.random.normal(ks[3], (N, M), dtype) * 0.4)
    out = lognorm_mix_logpdf_pallas(tau, log_w, mu, sigma, interpret=True)
    want = ref.lognorm_mix_logpdf_ref(tau, log_w, mu, sigma)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5,
                               rtol=1e-5)


# ---- the jnp flash (used by the models on CPU / in the dry-run) ----

@pytest.mark.parametrize("window,softcap", [(0, 0.0), (16, 0.0), (0, 5.0),
                                            (32, 10.0)])
def test_flash_ref_matches_naive_with_grads(window, softcap):
    q, k, v, qp, kp = _attn_inputs(2, 70, 90, 4, 2, 16, jnp.float32)
    o1 = ref.naive_attention(q, k, v, qp, kp, window=window, softcap=softcap)
    o2 = ref.flash_attention_ref(q, k, v, qp, kp, window, softcap, 16, 32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)
    f1 = lambda q, k, v: (ref.naive_attention(
        q, k, v, qp, kp, window=window, softcap=softcap) ** 2).sum()
    f2 = lambda q, k, v: (ref.flash_attention_ref(
        q, k, v, qp, kp, window, softcap, 16, 32) ** 2).sum()
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert bool(jnp.isfinite(b).all())
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_lognorm_logsf_stable_tails():
    """log-survival must stay finite (and differentiable) deep in the tail."""
    log_w = jnp.log(jnp.array([0.5, 0.5]))
    mu = jnp.array([0.0, -1.0])
    sigma = jnp.array([0.1, 0.05])

    def f(mu):
        return ref.lognorm_mix_logsf_ref(jnp.float32(50.0), log_w, mu, sigma)

    val = f(mu)
    grad = jax.grad(f)(mu)
    assert bool(jnp.isfinite(val))
    assert bool(jnp.isfinite(grad).all())


@pytest.mark.parametrize("shape", [
    (1, 4, 8, 4), (2, 12, 24, 8), (2, 16, 100, 16), (1, 32, 512, 16),
])
def test_selective_scan_pallas_vs_oracle(shape):
    from repro.kernels.ref import selective_scan_ref
    from repro.kernels.selective_scan import selective_scan_pallas
    B, C, di, N = shape
    ks = jax.random.split(RNG, 6)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, C, di))) * 0.1
    Bc = jax.random.normal(ks[1], (B, C, N))
    Cc = jax.random.normal(ks[2], (B, C, N))
    u = jax.random.normal(ks[3], (B, C, di))
    A = -jnp.exp(jax.random.normal(ks[4], (di, N)) * 0.2)
    D = jnp.ones(di)
    h0 = jax.random.normal(ks[5], (B, di, N)) * 0.3
    y1, h1 = selective_scan_pallas(dt, Bc, Cc, u, A, D, h0, bi=16,
                                   interpret=True)
    y2, h2 = selective_scan_ref(dt, Bc, Cc, u, A, D, h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5,
                               rtol=1e-5)


def test_selective_scan_matches_mamba_model_chunk():
    """The kernel's math must agree with the model's _ssm_inner path."""
    from repro.configs.base import ModelConfig
    from repro.models import mamba
    from repro.kernels.ref import selective_scan_ref
    cfg = ModelConfig(name="m", family="ssm", num_layers=1, d_model=16,
                      num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=11,
                      ssm_state=4, d_inner=8, dt_rank=4, dtype="float32",
                      param_dtype="float32", remat=False)
    p = jax.tree.map(lambda a: a[0],
                     mamba.init_params(cfg, RNG)["layers"])
    B, C = 2, 6
    u = jax.nn.silu(jax.random.normal(RNG, (B, C, cfg.d_inner)))
    h0 = jnp.zeros((B, cfg.d_inner, cfg.ssm_state))
    y_model, h_model = mamba._ssm_inner(cfg, p, u, h0)
    # reproduce the projections, then run the kernel-path oracle
    proj = jnp.einsum("bci,ie->bce", u, p["x_proj"])
    dt_r, Bc, Cc = jnp.split(proj, [cfg.dt_rank, cfg.dt_rank
                                    + cfg.ssm_state], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bcr,ri->bci", dt_r, p["dt_proj"])
                         + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y_k, h_k = selective_scan_ref(dt, Bc, Cc, u, A, p["D"], h0)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_k),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_model), np.asarray(h_k),
                               atol=1e-5)
