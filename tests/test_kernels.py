"""Per-kernel validation: Pallas (interpret=True) against the pure-jnp
ref.py oracles, swept over shapes and dtypes."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.lognorm_mix import (lognorm_mix_logpdf_pallas,
                                       lognorm_mix_logsf_pallas)
from repro.kernels.policy import KernelPolicy, validate_block_size
from repro.kernels.spec_verify_attention import (
    spec_verify_attention_pallas, spec_verify_attention_ref,
    spec_verify_attention_seq_pallas)

RNG = jax.random.PRNGKey(0)


def _attn_inputs(B, Sq, Sk, H, KV, Dh, dtype, valid_frac=0.7, offset=True):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, Dh), dtype)
    k = jax.random.normal(ks[1], (B, Sk, KV, Dh), dtype)
    v = jax.random.normal(ks[2], (B, Sk, KV, Dh), dtype)
    n_valid = max(1, int(Sk * valid_frac))
    kv_pos = jnp.where(jnp.arange(Sk) < n_valid, jnp.arange(Sk),
                       jnp.iinfo(jnp.int32).max)[None].repeat(B, 0)
    start = n_valid - Sq // 2 if offset else 0
    q_pos = (max(start, 0) + jnp.arange(Sq))[None].repeat(B, 0)
    return q, k, v, q_pos, kv_pos


@pytest.mark.parametrize("shape", [
    (1, 16, 16, 2, 2, 8), (2, 70, 90, 4, 2, 16), (2, 128, 128, 8, 2, 32),
    (1, 33, 257, 4, 4, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window,softcap", [(0, 0.0), (32, 0.0), (0, 20.0)])
def test_flash_attention_pallas_vs_oracle(shape, dtype, window, softcap):
    B, Sq, Sk, H, KV, Dh = shape
    q, k, v, qp, kp = _attn_inputs(B, Sq, Sk, H, KV, Dh, dtype)
    out = flash_attention_pallas(q, k, v, qp, kp, window=window,
                                 softcap=softcap, bq=16, bk=32,
                                 interpret=True)
    want = ref.naive_attention(q, k, v, qp, kp, window=window,
                               softcap=softcap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("shape", [
    (1, 4, 2, 8, 64), (3, 8, 2, 16, 200), (2, 16, 4, 32, 513),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 64])
def test_decode_attention_pallas_vs_oracle(shape, dtype, window):
    B, H, KV, Dh, Sk = shape
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (B, H, Dh), dtype)
    k = jax.random.normal(ks[1], (B, Sk, KV, Dh), dtype)
    v = jax.random.normal(ks[2], (B, Sk, KV, Dh), dtype)
    lens = jnp.arange(1, B + 1) * (Sk // (B + 1)) + 1
    kv_pos = jnp.where(jnp.arange(Sk)[None] < lens[:, None],
                       jnp.arange(Sk)[None],
                       jnp.iinfo(jnp.int32).max)
    out = decode_attention_pallas(q, k, v, lens, kv_pos, window=window,
                                  bk=64, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lens, kv_pos, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("N,M", [(1, 4), (100, 64), (257, 16), (1000, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_lognorm_mix_pallas_vs_oracle(N, M, dtype):
    ks = jax.random.split(RNG, 4)
    tau = jax.random.uniform(ks[0], (N,), dtype, 1e-3, 10.0)
    log_w = jax.nn.log_softmax(jax.random.normal(ks[1], (N, M), dtype))
    mu = jax.random.normal(ks[2], (N, M), dtype)
    sigma = jnp.exp(jax.random.normal(ks[3], (N, M), dtype) * 0.4)
    out = lognorm_mix_logpdf_pallas(tau, log_w, mu, sigma, interpret=True)
    want = ref.lognorm_mix_logpdf_ref(tau, log_w, mu, sigma)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5,
                               rtol=1e-5)


# ---- spec-verify attention (paged, gamma+1 queries) ----

def _paged_inputs(S, C, H, KV, Dh, page, NB, dtype=jnp.float32, seed=0):
    """Random pages + SCATTERED per-slot block tables + mixed lengths."""
    ks = jax.random.split(jax.random.fold_in(RNG, seed), 3)
    P = S * NB + 1
    q = jax.random.normal(ks[0], (S, C, H, Dh), dtype)
    k_pages = jax.random.normal(ks[1], (P, page, KV, Dh), dtype)
    v_pages = jax.random.normal(ks[2], (P, page, KV, Dh), dtype)
    perm = np.random.default_rng(seed).permutation(np.arange(1, P))
    bt = jnp.asarray(perm[:S * NB].reshape(S, NB), jnp.int32)
    lens = jnp.asarray(
        np.linspace(1, NB * page - C, S).astype(np.int32))
    return q, k_pages, v_pages, bt, lens


@pytest.mark.parametrize("shape", [
    # (S, C, H, KV, Dh, page, NB): GQA grids, gamma in {2, 4, 8}
    (2, 3, 4, 2, 16, 8, 4),
    (3, 5, 8, 2, 32, 16, 3),
    (1, 9, 4, 4, 64, 8, 6),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window,softcap", [(0, 0.0), (24, 0.0), (0, 20.0)])
def test_spec_verify_pallas_vs_flash_ref(shape, dtype, window, softcap):
    """Kernel parity against ``ref.flash_attention_ref`` on the dense
    gather of the same pages (and against the paged oracle)."""
    S, C, H, KV, Dh, page, NB = shape
    q, kp, vp, bt, lens = _paged_inputs(S, C, H, KV, Dh, page, NB, dtype)
    out = spec_verify_attention_pallas(q, kp, vp, bt, lens, window=window,
                                       softcap=softcap, interpret=True)
    # dense gather of each slot's pages == the logical cache
    k = kp[bt].reshape(S, NB * page, KV, Dh)
    v = vp[bt].reshape(S, NB * page, KV, Dh)
    q_pos = lens[:, None] + jnp.arange(C)
    kv_pos = jnp.broadcast_to(jnp.arange(NB * page), (S, NB * page))
    want = ref.flash_attention_ref(q, k, v, q_pos, kv_pos, window, softcap,
                                   16, 32)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)
    want2 = spec_verify_attention_ref(q, kp, vp, bt, lens, window=window,
                                      softcap=softcap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want2, np.float32), atol=tol,
                               rtol=tol)


def test_spec_verify_ref_max_kv_matches_dense_bitwise():
    """The gather-and-slice oracle is BITWISE a dense cache of the same
    contents — the contract behind paged==dense serving equivalence."""
    S, C, H, KV, Dh, page, NB = 2, 3, 4, 2, 16, 8, 4
    q, kp, vp, bt, lens = _paged_inputs(S, C, H, KV, Dh, page, NB)
    max_kv = 24                               # < NB * page
    out = spec_verify_attention_ref(q, kp, vp, bt, lens, max_kv=max_kv)
    k = kp[bt].reshape(S, NB * page, KV, Dh)[:, :max_kv]
    v = vp[bt].reshape(S, NB * page, KV, Dh)[:, :max_kv]
    q_pos = lens[:, None] + jnp.arange(C)
    kv_pos = jnp.broadcast_to(jnp.arange(max_kv), (S, max_kv))
    want = ref.naive_attention(q, k, v, q_pos, kv_pos)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_spec_verify_seq_form_vmaps():
    """The dense single-sequence wrapper (TPP verify path) under vmap:
    every lane must equal its own unbatched call."""
    C, H, Dh, N, B = 4, 2, 16, 40, 3
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (B, C, H, Dh))
    k = jax.random.normal(ks[1], (B, N, H, Dh))
    v = jax.random.normal(ks[2], (B, N, H, Dh))
    starts = jnp.array([3, 17, N - C], jnp.int32)
    f = lambda q1, k1, v1, s1: spec_verify_attention_seq_pallas(
        q1, k1, v1, s1, bk=16, interpret=True)
    batched = jax.vmap(f)(q, k, v, starts)
    for b in range(B):
        single = f(q[b], k[b], v[b], starts[b])
        np.testing.assert_array_equal(np.asarray(batched[b]),
                                      np.asarray(single))
        want = ref.naive_attention(
            q[b][None], k[b][None], v[b][None],
            (starts[b] + jnp.arange(C))[None], jnp.arange(N)[None])[0]
        np.testing.assert_allclose(np.asarray(single), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


# ---- fused log-survival (thinning upper bound) ----

@pytest.mark.parametrize("N,M", [(1, 4), (100, 64), (257, 16)])
def test_lognorm_logsf_pallas_vs_oracle(N, M):
    ks = jax.random.split(RNG, 4)
    tau = jax.random.uniform(ks[0], (N,), jnp.float32, 1e-3, 10.0)
    log_w = jax.nn.log_softmax(jax.random.normal(ks[1], (N, M)))
    mu = jax.random.normal(ks[2], (N, M))
    sigma = jnp.exp(jax.random.normal(ks[3], (N, M)) * 0.4)
    out = lognorm_mix_logsf_pallas(tau, log_w, mu, sigma, interpret=True)
    want = ref.lognorm_mix_logsf_ref(tau, log_w, mu, sigma)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5,
                               rtol=1e-5)


def test_lognorm_logsf_pallas_broadcast_and_tails():
    """One mixture against a tau grid (the thinning bound's call shape)
    + deep-tail stability."""
    log_w = jnp.log(jnp.array([0.5, 0.5]))
    mu = jnp.array([0.0, -1.0])
    sigma = jnp.array([0.1, 0.05])
    taus = jnp.array([0.5, 2.0, 50.0], jnp.float32)
    out = lognorm_mix_logsf_pallas(taus, log_w, mu, sigma, interpret=True)
    want = ref.lognorm_mix_logsf_ref(taus, log_w, mu, sigma)
    assert bool(jnp.isfinite(out).all())
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4,
                               rtol=1e-4)


# ---- block-size validation (ops entry points) ----

def test_block_size_autorounds_and_warns_once():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert validate_block_size("op_x", "bq", 100) == 104
        assert validate_block_size("op_x", "bq", 100) == 104  # same site
    assert sum("auto-rounded" in str(x.message) for x in w) == 1
    # capping to the array extent is the normal small-input case: silent
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert validate_block_size("op_y", "bk", 128, total=16) == 16
    assert not w
    with pytest.raises(ValueError, match=">= 1"):
        validate_block_size("op_z", "bq", 0)


def test_ops_policy_dispatch_misaligned_block():
    """A misaligned policy block size must be rounded by the entry point
    instead of failing inside pallas_call."""
    q, k, v, qp, kp = _attn_inputs(1, 16, 32, 2, 2, 8, jnp.float32)
    pol = KernelPolicy(backend="pallas", interpret=True, bq=10, bk=12)
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        out = ops.flash_attention(q, k, v, qp, kp, policy=pol)
    want = ref.naive_attention(q, k, v, qp, kp)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


# ---- the jnp flash (used by the models on CPU / in the dry-run) ----

@pytest.mark.parametrize("window,softcap", [(0, 0.0), (16, 0.0), (0, 5.0),
                                            (32, 10.0)])
def test_flash_ref_matches_naive_with_grads(window, softcap):
    q, k, v, qp, kp = _attn_inputs(2, 70, 90, 4, 2, 16, jnp.float32)
    o1 = ref.naive_attention(q, k, v, qp, kp, window=window, softcap=softcap)
    o2 = ref.flash_attention_ref(q, k, v, qp, kp, window, softcap, 16, 32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)
    f1 = lambda q, k, v: (ref.naive_attention(
        q, k, v, qp, kp, window=window, softcap=softcap) ** 2).sum()
    f2 = lambda q, k, v: (ref.flash_attention_ref(
        q, k, v, qp, kp, window, softcap, 16, 32) ** 2).sum()
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert bool(jnp.isfinite(b).all())
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_lognorm_logsf_stable_tails():
    """log-survival must stay finite (and differentiable) deep in the tail."""
    log_w = jnp.log(jnp.array([0.5, 0.5]))
    mu = jnp.array([0.0, -1.0])
    sigma = jnp.array([0.1, 0.05])

    def f(mu):
        return ref.lognorm_mix_logsf_ref(jnp.float32(50.0), log_w, mu, sigma)

    val = f(mu)
    grad = jax.grad(f)(mu)
    assert bool(jnp.isfinite(val))
    assert bool(jnp.isfinite(grad).all())


@pytest.mark.parametrize("shape", [
    (1, 4, 8, 4), (2, 12, 24, 8), (2, 16, 100, 16), (1, 32, 512, 16),
])
def test_selective_scan_pallas_vs_oracle(shape):
    from repro.kernels.ref import selective_scan_ref
    from repro.kernels.selective_scan import selective_scan_pallas
    B, C, di, N = shape
    ks = jax.random.split(RNG, 6)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, C, di))) * 0.1
    Bc = jax.random.normal(ks[1], (B, C, N))
    Cc = jax.random.normal(ks[2], (B, C, N))
    u = jax.random.normal(ks[3], (B, C, di))
    A = -jnp.exp(jax.random.normal(ks[4], (di, N)) * 0.2)
    D = jnp.ones(di)
    h0 = jax.random.normal(ks[5], (B, di, N)) * 0.3
    y1, h1 = selective_scan_pallas(dt, Bc, Cc, u, A, D, h0, bi=16,
                                   interpret=True)
    y2, h2 = selective_scan_ref(dt, Bc, Cc, u, A, D, h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5,
                               rtol=1e-5)


def test_selective_scan_matches_mamba_model_chunk():
    """The kernel's math must agree with the model's _ssm_inner path."""
    from repro.configs.base import ModelConfig
    from repro.models import mamba
    from repro.kernels.ref import selective_scan_ref
    cfg = ModelConfig(name="m", family="ssm", num_layers=1, d_model=16,
                      num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=11,
                      ssm_state=4, d_inner=8, dt_rank=4, dtype="float32",
                      param_dtype="float32", remat=False)
    p = jax.tree.map(lambda a: a[0],
                     mamba.init_params(cfg, RNG)["layers"])
    B, C = 2, 6
    u = jax.nn.silu(jax.random.normal(RNG, (B, C, cfg.d_inner)))
    h0 = jnp.zeros((B, cfg.d_inner, cfg.ssm_state))
    y_model, h_model = mamba._ssm_inner(cfg, p, u, h0)
    # reproduce the projections, then run the kernel-path oracle
    proj = jnp.einsum("bci,ie->bce", u, p["x_proj"])
    dt_r, Bc, Cc = jnp.split(proj, [cfg.dt_rank, cfg.dt_rank
                                    + cfg.ssm_state], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bcr,ri->bci", dt_r, p["dt_proj"])
                         + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y_k, h_k = selective_scan_ref(dt, Bc, Cc, u, A, p["D"], h0)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_k),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_model), np.asarray(h_k),
                               atol=1e-5)
