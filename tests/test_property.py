"""Property-based tests (hypothesis) on system invariants."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import speculative as spec, thinning as thin
from repro.kernels import ref
from repro.metrics import ks_statistic, type_emd, wasserstein_1d
from repro.models import common as cm, tpp
from repro.models.tpp import MixParams

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

floats = st.floats(min_value=-3.0, max_value=3.0, allow_nan=False)


@st.composite
def mixtures(draw, M=4):
    w = draw(st.lists(st.floats(0.05, 1.0), min_size=M, max_size=M))
    mu = draw(st.lists(floats, min_size=M, max_size=M))
    sg = draw(st.lists(st.floats(0.05, 2.0), min_size=M, max_size=M))
    w = np.array(w) / np.sum(w)
    return MixParams(jnp.log(jnp.asarray(w, jnp.float32)),
                     jnp.asarray(mu, jnp.float32),
                     jnp.asarray(sg, jnp.float32))


@given(mixtures())
def test_lognorm_mix_integrates_to_one(mix):
    """quadrature of exp(logpdf) over (0, inf) ~ 1."""
    grid = np.exp(np.linspace(-14, 8, 8000))
    pdf = np.exp(np.array(tpp.interval_logpdf(mix, jnp.asarray(grid))))
    Z = np.trapezoid(pdf, grid)
    assert abs(Z - 1.0) < 5e-3


@given(mixtures(), st.floats(0.01, 20.0))
def test_logsf_is_log_of_tail_integral(mix, tau):
    grid = np.exp(np.linspace(-14, 9, 8000))
    pdf = np.exp(np.array(tpp.interval_logpdf(mix, jnp.asarray(grid))))
    tail = np.trapezoid(pdf[grid >= tau], grid[grid >= tau])
    lsf = float(tpp.interval_logsf(mix, jnp.float32(tau)))
    assert abs(math.exp(lsf) - tail) < 2e-2


@given(mixtures(), st.integers(0, 10_000))
def test_sample_interval_positive(mix, seed):
    tau = tpp.sample_interval(jax.random.PRNGKey(seed), mix)
    assert float(tau) > 0.0


@given(st.integers(0, 1000))
def test_adjusted_discrete_support(seed):
    """adjusted sample must land where p_T > p_D (true support of g')."""
    r = jax.random.PRNGKey(seed)
    logits_t = jax.random.normal(jax.random.fold_in(r, 0), (6,))
    logits_d = jax.random.normal(jax.random.fold_in(r, 1), (6,))
    lp_t = jax.nn.log_softmax(logits_t)
    lp_d = jax.nn.log_softmax(logits_d)
    k = int(spec.adjusted_discrete(jax.random.fold_in(r, 2), lp_t, lp_d))
    assert float(lp_t[k]) > float(lp_d[k])


@given(st.integers(0, 200), st.integers(1, 4))
def test_thinning_events_sorted_within_horizon(seed, m):
    proc = thin.MultiHawkes() if m > 1 else thin.Hawkes()
    t, k = thin.thinning_sample(proc, 5.0, np.random.default_rng(seed))
    assert np.all(np.diff(t) > 0)
    assert np.all(t <= 5.0)
    assert np.all((k >= 0) & (k < proc.num_marks))


@given(st.integers(0, 100))
def test_compensator_additive_and_monotone(seed):
    proc = thin.Hawkes()
    rng = np.random.default_rng(seed)
    t, k = thin.thinning_sample(proc, 5.0, rng)
    hist_t, hist_k = list(t[:2]), list(k[:2])
    a = float(t[1]) if len(t) > 1 else 1.0
    full = proc.compensator(a, a + 2.0, hist_t, hist_k)
    half = (proc.compensator(a, a + 1.0, hist_t, hist_k)
            + proc.compensator(a + 1.0, a + 2.0, hist_t, hist_k))
    assert full >= 0
    assert abs(full - half) < 1e-8


@given(st.lists(st.floats(0.0, 10.0), min_size=3, max_size=40))
def test_wasserstein_identity_and_symmetry(xs):
    a = np.array(xs)
    assert wasserstein_1d(a, a) < 1e-9
    b = a + 1.0
    assert abs(wasserstein_1d(a, b) - 1.0) < 1e-6


@given(st.lists(st.integers(0, 4), min_size=2, max_size=50),
       st.lists(st.integers(0, 4), min_size=2, max_size=50))
def test_type_emd_nonneg_symmetric(a, b):
    a, b = np.array(a), np.array(b)
    assert type_emd(a, b, 5) >= 0
    assert abs(type_emd(a, b, 5) - type_emd(b, a, 5)) < 1e-9


@given(st.integers(0, 50))
def test_rescaled_intervals_exp1(seed):
    """time-rescaling of thinning samples must look Exp(1) (KS in band).

    The band is set far beyond the 95% level (c=2.5 ~ p<1e-5) because
    hypothesis samples many seeds — this is a correctness property, not a
    calibrated statistical test (that lives in test_data_metrics)."""
    proc = thin.Hawkes()
    rng = np.random.default_rng(seed)
    zs = []
    for _ in range(6):
        t, k = thin.thinning_sample(proc, 20.0, rng)
        zs.append(thin.rescaled_intervals(proc, t, k))
    z = np.concatenate(zs)
    assert ks_statistic(z) < 2.5 / math.sqrt(len(z))


@given(st.integers(0, 30), st.integers(1, 3))
def test_moe_capacity_mass_conservation(seed, k):
    """combine weights sum to <= 1 per token (drops allowed, no creation)."""
    from repro.configs.base import ModelConfig
    cfg = ModelConfig(name="x", family="moe", num_layers=1, d_model=8,
                      num_heads=2, num_kv_heads=2, d_ff=16, vocab_size=10,
                      num_experts=4, num_experts_per_tok=k,
                      moe_group_size=8, dtype="float32",
                      param_dtype="float32")
    rng = jax.random.PRNGKey(seed)
    p = cm.moe_init(rng, cfg, jnp.float32)
    x = jax.random.normal(rng, (2, 12, 8))
    y, aux = cm.moe_ffn(cfg, p, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux) >= 0.99  # switch aux loss >= 1 at balance~


@given(st.integers(0, 100), st.integers(1, 64))
def test_rope_preserves_norm(seed, pos):
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 1, 2, 16))
    pos_arr = jnp.full((1, 1), pos, jnp.int32)
    y = cm.apply_rope(x, pos_arr, 10_000.0)
    np.testing.assert_allclose(float(jnp.linalg.norm(x)),
                               float(jnp.linalg.norm(y)), rtol=1e-5)


@given(st.integers(0, 100))
def test_tpp_cache_rollback_reproduces_prefix(seed):
    cfg = tpp.TPPConfig = None  # silence lint; use direct import below
    from repro.configs.base import TPPConfig
    cfg = TPPConfig(encoder="thp", num_layers=1, num_heads=1, d_model=8,
                    d_ff=16, num_marks=2, num_mix=2)
    params = tpp.init_params(cfg, jax.random.PRNGKey(seed))
    times = jnp.cumsum(jax.random.uniform(jax.random.PRNGKey(seed + 1),
                                          (6,), minval=0.1, maxval=1.0))
    types = jax.random.randint(jax.random.PRNGKey(seed + 2), (6,), 0, 2)
    cache = tpp.init_cache(cfg, 10)
    h_all, cache = tpp.extend(cfg, params, cache, times, types)
    cache_rb = tpp.rollback(cache, 3)
    h_new, _ = tpp.extend(cfg, params, cache_rb, times[3:5], types[3:5])
    np.testing.assert_allclose(np.asarray(h_new), np.asarray(h_all[3:5]),
                               atol=1e-5)
