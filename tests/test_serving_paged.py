"""Paged KV pool == dense pool equivalence on the serving suite.

The block-table pool + spec-verify Pallas attention is the production
hot path; these tests pin that switching the layout/kernel changes NO
committed token: paged+ref is bitwise the dense+ref engine (same
shapes => same XLA reductions), and paged+Pallas(interpret) matches it
on every committed stream too (kernel numerics stay under the sampling
decision thresholds). Coverage includes rejection-driven rollback
(draft != target), slot reuse after finish, per-request temperatures,
MoE capacity dispatch, replay-family fallback, admission deferral under
page pressure, and pool bookkeeping units.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import registry
from repro.serving import ServeRequest, ServingEngine
from repro.serving.kv_pool import PagedKVCachePool, paged_supported

RNG = jax.random.PRNGKey(0)


def _dense(num_layers=2, vocab=31, name="t", **kw):
    base = dict(name=name, family="dense", num_layers=num_layers,
                d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                vocab_size=vocab, dtype="float32", param_dtype="float32",
                remat=False)
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def dense_pair():
    cfg_t, cfg_d = _dense(2), _dense(1, name="d")
    mt, md = registry.get_model(cfg_t), registry.get_model(cfg_d)
    return (cfg_t, cfg_d, mt.init_params(RNG),
            md.init_params(jax.random.PRNGKey(1)))


def _serve(cfg_t, cfg_d, pt, pd, n_req=8, max_batch=4, max_len=64,
           gamma=4, **engine_kw):
    """Run the standard mixed-budget workload; tokens by submit order."""
    eng = ServingEngine(cfg_t, pt, cfg_d, pd, max_batch=max_batch,
                        max_len=max_len, gamma=gamma, **engine_kw)
    order = []
    for i in range(n_req):
        order.append(eng.submit(ServeRequest(
            prompt=jnp.arange(5, dtype=jnp.int32),
            max_new_tokens=5 + i, rng=100 + i,
            temperature=1.0 + 0.1 * (i % 3))))
    by_id = {r.request_id: r for r in eng.run()}
    return eng, [np.asarray(by_id[rid].tokens) for rid in order]


# ---------------------------------------------------------------------------
# pool bookkeeping units (no engine)
# ---------------------------------------------------------------------------

def test_paged_pool_alloc_truncate_free():
    pool = PagedKVCachePool(2, _dense(1), page_size=4, max_len=16)
    assert pool.n_pages == 2 * 4 + 1          # full provisioning + null
    total_free = pool.n_pages - 1
    assert len(pool.free) == total_free
    pool.ensure_blocks(0, 9)                   # 3 pages of 4
    assert pool.n_blocks[0] == 3 and len(pool.free) == total_free - 3
    assert all(pool.tables[0, :3] > 0)         # never the null page
    pool.truncate(0, 5)                        # rollback to 2 pages
    assert pool.n_blocks[0] == 2 and pool.lens[0] == 5
    assert len(pool.free) == total_free - 2
    assert pool.tables[0, 2] == 0              # freed entry points at null
    pool.free_slot(0)
    assert len(pool.free) == total_free and pool.lens[0] == 0
    # reuse: freed pages are handed out again
    pool.ensure_blocks(1, 16)
    assert pool.n_blocks[1] == 4


def test_paged_pool_rejects_unsupported_families():
    ssm = ModelConfig(name="s", family="ssm", num_layers=1, d_model=16,
                      num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=11,
                      ssm_state=4, dtype="float32", param_dtype="float32",
                      remat=False)
    assert not paged_supported(ssm)
    with pytest.raises(ValueError, match="paged"):
        PagedKVCachePool(2, ssm, page_size=4, max_len=16)
    ring = _dense(1, sliding_window=8)
    assert not paged_supported(ring)


# ---------------------------------------------------------------------------
# paged == dense token-bitwise
# ---------------------------------------------------------------------------

def test_paged_ref_matches_dense_ref_bitwise(dense_pair):
    """Same contents, same shapes, same ops: with the reference kernels
    the paged engine must commit EXACTLY the dense engine's tokens —
    including rollback rounds (draft != target => rejections) and slots
    reused across the 8-requests/4-slots run."""
    cfg_t, cfg_d, pt, pd = dense_pair
    eng_d, toks_d = _serve(cfg_t, cfg_d, pt, pd, kv_layout="dense",
                           kernel="ref")
    eng_p, toks_p = _serve(cfg_t, cfg_d, pt, pd, kv_layout="paged",
                           kernel="ref")
    assert eng_d.kv_layout == "dense" and eng_p.kv_layout == "paged"
    for a, b in zip(toks_d, toks_p):
        np.testing.assert_array_equal(a, b)
    # acceptance accounting identical => identical random streams
    assert eng_d.stats().accepted == eng_p.stats().accepted
    # finish returned every page
    assert len(eng_p.pool_t.free) == eng_p.pool_t.n_pages - 1
    assert len(eng_p.pool_d.free) == eng_p.pool_d.n_pages - 1


def test_paged_pallas_matches_dense_ref_bitwise(dense_pair):
    """The production configuration (paged + Pallas spec-verify kernel,
    interpret on CPU) against the legacy dense+ref path."""
    cfg_t, cfg_d, pt, pd = dense_pair
    _, toks_d = _serve(cfg_t, cfg_d, pt, pd, kv_layout="dense",
                       kernel="ref")
    eng_p, toks_p = _serve(cfg_t, cfg_d, pt, pd, kv_layout="paged",
                           kernel="pallas")
    assert eng_p.policy.use_pallas
    for a, b in zip(toks_d, toks_p):
        np.testing.assert_array_equal(a, b)


def test_paged_is_the_default_for_mask_families(dense_pair):
    cfg_t, cfg_d, pt, pd = dense_pair
    eng = ServingEngine(cfg_t, pt, cfg_d, pd)
    assert eng.kv_layout == "paged"
    assert eng.policy.backend == "pallas"


def test_paged_moe_matches_dense(dense_pair):
    """MoE capacity dispatch (per-sequence groups) must not change under
    the paged batched extend."""
    cfg_t = _dense(2, name="moe-t", family="moe", num_experts=4,
                   num_experts_per_tok=2)
    cfg_d = _dense(1, name="moe-d", family="moe", num_experts=4,
                   num_experts_per_tok=2)
    pt = registry.get_model(cfg_t).init_params(RNG)
    pd = registry.get_model(cfg_d).init_params(jax.random.PRNGKey(1))
    _, toks_d = _serve(cfg_t, cfg_d, pt, pd, n_req=4, kv_layout="dense",
                       kernel="ref")
    _, toks_p = _serve(cfg_t, cfg_d, pt, pd, n_req=4, kv_layout="paged",
                       kernel="pallas")
    for a, b in zip(toks_d, toks_p):
        np.testing.assert_array_equal(a, b)


def test_ar_paged_matches_dense(dense_pair):
    cfg_t, _, pt, _ = dense_pair
    def run(layout):
        eng = ServingEngine(cfg_t, pt, method="ar", max_batch=2,
                            max_len=64, kv_layout=layout, kernel="ref")
        order = [eng.submit(ServeRequest(
            prompt=jnp.arange(4, dtype=jnp.int32), max_new_tokens=7,
            rng=7 + i)) for i in range(3)]
        by_id = {r.request_id: r for r in eng.run()}
        return [np.asarray(by_id[rid].tokens) for rid in order]
    for a, b in zip(run("dense"), run("paged")):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# fallbacks / pressure / reset
# ---------------------------------------------------------------------------

def test_replay_family_falls_back_to_dense():
    ssm = ModelConfig(name="s", family="ssm", num_layers=1, d_model=16,
                      num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=11,
                      ssm_state=4, dtype="float32", param_dtype="float32",
                      remat=False)
    p = registry.get_model(ssm).init_params(RNG)
    eng = ServingEngine(ssm, p, ssm, p, max_batch=2, max_len=32, gamma=2)
    assert eng.kv_layout == "dense"
    eng.submit(ServeRequest(prompt=jnp.arange(4, dtype=jnp.int32),
                            max_new_tokens=5, rng=3))
    res = eng.run()
    assert res[0].n == 5
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(ssm, p, ssm, p, kv_layout="paged")


def test_admission_defers_under_page_pressure(dense_pair):
    """An under-provisioned pool keeps serving: lifetime reservations
    admit only what the free list can back end-to-end (here ~2 of 4
    slots), deferred requests land as finishing ones return pages, and
    no round can run the pool dry mid-stream."""
    cfg_t, cfg_d, pt, pd = dense_pair
    eng = ServingEngine(cfg_t, pt, cfg_d, pd, max_batch=4, max_len=64,
                        gamma=3, kv_layout="paged", kernel="ref",
                        page_size=8, n_pages=9)
    # each request reserves ceil((5 + 20)/8) = 4 of the 8 usable pages
    budgets = {}
    for i in range(5):
        rid = eng.submit(ServeRequest(prompt=jnp.arange(5, dtype=jnp.int32),
                                      max_new_tokens=20, rng=50 + i))
        budgets[rid] = 20
    max_active = 0
    while eng.scheduler.has_work():
        eng.step()
        max_active = max(max_active, len(eng.scheduler.active()))
    results = eng._results
    assert len(results) == 5
    for r in results:
        assert r.n == budgets[r.request_id]
    assert max_active == 2                 # reservations capped concurrency
    assert len(eng.pool_t.free) == eng.pool_t.n_pages - 1


def test_mixed_budgets_shrink_window_instead_of_exhausting_pool(dense_pair):
    """Regression: with mixed budgets the batch window (max over alive
    remaining budgets) can over-ask a short request's lifetime
    reservation; the engine must shrink gamma to the free list instead
    of raising mid-stream."""
    cfg_t, cfg_d, pt, pd = dense_pair
    eng = ServingEngine(cfg_t, pt, cfg_d, pd, max_batch=2, max_len=32,
                        gamma=12, kv_layout="paged", kernel="ref",
                        page_size=4, n_pages=9)
    ra = eng.submit(ServeRequest(prompt=jnp.arange(5, dtype=jnp.int32),
                                 max_new_tokens=2, rng=1))
    rb = eng.submit(ServeRequest(prompt=jnp.arange(5, dtype=jnp.int32),
                                 max_new_tokens=13, rng=2))
    by_id = {r.request_id: r for r in eng.run()}
    assert by_id[ra].n == 2 and by_id[rb].n == 13
    assert len(eng.pool_t.free) == eng.pool_t.n_pages - 1


def test_scheduler_defer_preserves_fifo():
    from repro.serving import Scheduler
    s = Scheduler(max_batch=2, max_len=64)
    reqs = [_mkreq(i) for i in range(4)]
    for r in reqs:
        s.submit(r)
    placed = s.admit()                      # r0, r1
    s.defer(placed[0][0])
    s.defer(placed[1][0])                   # both deferred, same step
    nxt = s.admit()                         # must come back r0, r1 — not
    assert [st.request.request_id for _, st in nxt] \
        == [reqs[0].request_id, reqs[1].request_id]   # reversed
    assert s.pending_count == 2             # r2, r3 still queued behind


def _mkreq(i):
    return ServeRequest(prompt=jnp.arange(5, dtype=jnp.int32),
                        max_new_tokens=4, rng=i)


def test_reset_keeps_pages_frees_slots(dense_pair):
    cfg_t, cfg_d, pt, pd = dense_pair
    eng, _ = _serve(cfg_t, cfg_d, pt, pd, n_req=2, kv_layout="paged",
                    kernel="ref")
    pages_before = eng.pool_t.pages["k"]
    eng.reset()
    assert eng.pool_t.pages["k"] is pages_before   # no reallocation
    assert len(eng.pool_t.free) == eng.pool_t.n_pages - 1
    # and the engine still serves after the reset
    eng.submit(ServeRequest(prompt=jnp.arange(5, dtype=jnp.int32),
                            max_new_tokens=4, rng=9))
    assert eng.run()[0].n == 4
