"""End-to-end behaviour test of the paper's pipeline (reduced scale):

  simulate Hawkes -> train target + draft CDF-TPPs -> sample with AR and
  TPP-SD -> both sample sets must (a) pass the time-rescaling KS test
  against the GROUND-TRUTH process within the 95% band and (b) agree with
  each other; SD must use fewer target forwards per event than AR.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from scipy import stats

from repro.configs.base import TPPConfig
from repro.core import thinning as thin
from repro.data import synthetic as ds
from repro.metrics import ks_confidence_band, ks_for_samples
from repro.sampling import SamplerSpec, build_sampler
from repro.train import trainer


@pytest.fixture(scope="module")
def trained_pair():
    data = ds.make_dataset("hawkes", n_seqs=80, t_end=10.0, seed=0)
    cfg_t = TPPConfig(encoder="thp", num_layers=2, num_heads=2, d_model=32,
                      d_ff=64, num_marks=1, num_mix=8)
    cfg_d = cfg_t.replace(num_layers=1, num_heads=1)
    tcfg = trainer.TPPTrainConfig(max_epochs=8, batch_size=16, patience=3)
    pt, _ = trainer.train_tpp(cfg_t, data, tcfg)
    pd, _ = trainer.train_tpp(cfg_d, data, tcfg)
    return data, cfg_t, cfg_d, pt, pd


def _to_seqs(result):
    out = []
    times = np.array(result.times)
    types = np.array(result.types)
    ns = np.array(result.n)
    for i in range(times.shape[0]):
        n = int(ns[i])
        out.append((times[i, :n], types[i, :n]))
    return out


def test_end_to_end_sampling_quality_and_speed(trained_pair):
    data, cfg_t, cfg_d, pt, pd = trained_pair
    B, EMAX, GAMMA = 48, 128, 8
    base = SamplerSpec(execution="vmap", t_end=data.t_end, max_events=EMAX,
                       batch=B)
    ra = build_sampler(base.replace(method="ar"),
                       cfg_t, pt)(jax.random.PRNGKey(1))
    rs = build_sampler(base.replace(method="sd", gamma=GAMMA),
                       cfg_t, pt, cfg_d, pd)(jax.random.PRNGKey(2))
    seqs_ar, seqs_sd = _to_seqs(ra), _to_seqs(rs)
    n_ar = sum(len(t) for t, _ in seqs_ar)
    n_sd = sum(len(t) for t, _ in seqs_sd)
    assert n_ar > 100 and n_sd > 100

    # (a) both within (a generous multiple of) the KS band vs ground truth
    ks_ar = ks_for_samples(data.process, seqs_ar)
    ks_sd = ks_for_samples(data.process, seqs_sd)
    band_sd = ks_confidence_band(n_sd)
    # the model is only briefly trained; AR and SD must be EQUALLY good
    assert ks_sd < max(3 * band_sd, ks_ar * 1.5 + band_sd)

    # (b) AR vs SD two-sample agreement on event counts
    na = np.array(ra.n)
    ns = np.array(rs.n)
    assert stats.ks_2samp(na, ns).pvalue > 1e-3

    # (c) speedup mechanism: target forwards per committed event < 1
    rounds = float(np.array(rs.rounds).sum())
    events = float(ns.sum())
    assert rounds < events, "SD must verify multiple events per forward"
    alpha = float(np.array(rs.accepted).sum()) / max(
        1.0, float(np.array(rs.drafted).sum()))
    assert 0.0 < alpha <= 1.0


def test_thinning_baseline_matches_ground_truth():
    proc = thin.Hawkes()
    rng = np.random.default_rng(0)
    seqs = [thin.thinning_sample(proc, 30.0, rng) for _ in range(20)]
    ks = ks_for_samples(proc, seqs)
    n = sum(len(t) for t, _ in seqs)
    assert ks < ks_confidence_band(n) * 1.5


def test_cif_thinning_neural_baseline_matches_ar():
    """App. D.1: CIF thinning on the neural model samples the same
    distribution as AR but needs >> 1 target forwards per event."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import TPPConfig
    from repro.core import cif_thinning

    cfg = TPPConfig(encoder="thp", num_layers=1, num_heads=1, d_model=16,
                    d_ff=32, num_marks=2, num_mix=4)
    params = __import__("repro.models.tpp", fromlist=["x"]).init_params(
        cfg, jax.random.PRNGKey(0))
    firsts = []
    forwards = events = 0
    for i in range(40):
        r = cif_thinning.sample_thinning_host(
            cfg, params, jax.random.PRNGKey(100 + i), 3.0, 32)
        forwards += int(r.forwards)
        events += int(r.n)
        if int(r.n):
            firsts.append(float(r.times[0]))
    assert forwards / max(events, 1) > 1.0, "thinning must cost >1 fwd/event"
    ra = build_sampler(SamplerSpec(method="ar", execution="vmap", t_end=3.0,
                                   max_events=32, batch=200),
                       cfg, params)(jax.random.PRNGKey(7))
    na = np.array(ra.n)
    fa = np.array(ra.times[:, 0])[na > 0]
    assert stats.ks_2samp(np.array(firsts), fa).pvalue > 1e-3
