"""``repro.forecast``: wave-scheduled fan-out + on-device aggregation.

The contracts pinned here:

  (i)   the on-device count-histogram aggregator is EXACT — its
        quantiles/means equal numpy computed on the concatenated
        rollouts (``inverted_cdf``), independent of wave splits;
  (ii)  wave scheduling is invisible in the sampled events — a forecast
        split into pool-bounded waves commits BITWISE the rollouts a
        single fanout=n submission produces on a fully provisioned
        pool (same max_batch; only n_pages differs);
  (iii) the "grouped" admission policy makes fan-out siblings share
        target forwards — strictly fewer forwards than the same
        rollouts submitted ungrouped under FIFO, with identical
        committed streams;
  (iv)  the TPP event-history prefix cache serves hits bitwise equal
        to cold misses.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, TPPConfig
from repro.forecast import (ForecastAggregator, Forecaster, ForecastRequest,
                            build_forecaster)
from repro.models import registry, tpp
from repro.sampling import ForecastSpec, SamplerSpec, SpecError
from repro.serving import ServingEngine


@pytest.fixture(scope="module")
def tpp_pair():
    cfg_t = TPPConfig(name="fc-t", encoder="thp", num_layers=2,
                      num_heads=2, d_model=16, d_ff=32, num_marks=3,
                      num_mix=4)
    cfg_d = cfg_t.replace(name="fc-d", num_layers=1, num_heads=1)
    pt = tpp.init_params(cfg_t, jax.random.PRNGKey(0))
    pd = tpp.init_params(cfg_d, jax.random.PRNGKey(1))
    return cfg_t, cfg_d, pt, pd


def _history(n=4, seed=3):
    r = np.random.default_rng(seed)
    times = np.cumsum(r.exponential(0.5, size=n)).astype(np.float32)
    marks = r.integers(0, 3, size=n).astype(np.int32)
    return times, marks


# ---------------------------------------------------------------------------
# (i) aggregator == numpy on the concatenated rollouts
# ---------------------------------------------------------------------------

def _ref_counts(times, n_valid, t0, t1, bins):
    """Per-rollout per-bin counts, left-open bins (t0, t1]."""
    edges = np.linspace(t0, t1, bins + 1)
    K = times.shape[0]
    out = np.zeros((K, bins), np.int64)
    for k in range(K):
        ts = times[k, :n_valid[k]]
        for b in range(bins):
            out[k, b] = np.sum((ts > edges[b]) & (ts <= edges[b + 1]))
    return out


def test_aggregator_matches_numpy_quantiles():
    """Streaming histogram quantiles == np.quantile(inverted_cdf) on the
    full count matrix, regardless of how rollouts split into waves."""
    rng = np.random.default_rng(0)
    t0, t1, bins, max_count = 1.5, 9.5, 7, 10
    waves = []
    for n in (5, 8, 1, 6):                       # uneven wave sizes
        nv = rng.integers(0, max_count + 1, size=n).astype(np.int32)
        ts = np.zeros((n, max_count), np.float32)
        for k in range(n):
            ts[k, :nv[k]] = np.sort(
                rng.uniform(t0 - 0.5, t1 + 0.5, size=nv[k]))
        waves.append((ts, nv))

    agg = ForecastAggregator(bins, t0, t1, max_count)
    for ts, nv in waves:
        agg.fold(ts, nv)

    all_counts = np.concatenate(
        [_ref_counts(ts, nv, t0, t1, bins) for ts, nv in waves])
    assert agg.n_rollouts == all_counts.shape[0] == 20
    # non-integer q*n everywhere (n=20): no interpolation-boundary
    # ambiguity between conventions
    qs = (0.11, 0.33, 0.52, 0.77, 0.94)
    want = np.stack([np.quantile(all_counts, q, axis=0,
                                 method="inverted_cdf") for q in qs])
    np.testing.assert_array_equal(agg.quantiles(qs), want)
    np.testing.assert_allclose(agg.mean(), all_counts.mean(axis=0),
                               rtol=1e-12)
    # histogram really is on device until asked
    assert agg.counts().sum() == 20 * bins


def test_aggregator_validation():
    with pytest.raises(ValueError, match="bins"):
        ForecastAggregator(0, 0.0, 1.0, 4)
    agg = ForecastAggregator(2, 0.0, 1.0, 4)
    with pytest.raises(ValueError, match="no rollouts"):
        agg.quantiles((0.5,))
    agg.fold(np.zeros((1, 4), np.float32), np.zeros((1,), np.int32))
    with pytest.raises(ValueError, match="outside"):
        agg.quantiles((1.5,))


# ---------------------------------------------------------------------------
# (ii) wave parity: pool-bounded waves == one fanout=n submission
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method,kernel", [("sd", "ref"), ("ar", "ref"),
                                           ("sd", "pallas")])
def test_waves_bitwise_equal_single_fanout(tpp_pair, method, kernel):
    """n_rollouts > what one wave holds: the wave executor (starved
    n_pages) commits bitwise the rollouts of a single fanout=n
    submission on a fully provisioned pool with the SAME max_batch."""
    cfg_t, cfg_d, pt, pd = tpp_pair
    times, marks = _history(4)
    n_roll, budget, gamma = 5, 6, 2
    kw = dict(method=method, max_batch=4, max_len=16, gamma=gamma,
              kernel=kernel, sched="grouped", page_size=4,
              prefix_cache=True)
    if method == "ar":
        cfg_d = pd = None

    # starved pool: waves must be smaller than n_rollouts
    eng_w = ServingEngine(cfg_t, pt, cfg_d, pd, n_pages=12, **kw)
    req = ForecastRequest(history_times=times, history_marks=marks,
                          horizon=6.0, n_rollouts=n_roll, bins=4,
                          max_events=budget, rng=jax.random.PRNGKey(42))
    res = Forecaster(eng_w).forecast(req, collect=True)
    assert res.n_waves > 1, "pool was not starved enough to force waves"
    assert sum(res.wave_sizes) == n_roll

    # reference: one submission, fully provisioned pool, same max_batch
    eng_1 = ServingEngine(cfg_t, pt, cfg_d, pd, **kw)
    ids = eng_1.submit(prompt=marks, times=times, t_end=req.t_last + 6.0,
                       max_new_tokens=budget, rng=jax.random.PRNGKey(42),
                       fanout=n_roll)
    ref = {r.request_id: r for r in eng_1.run()}
    assert len(ref) == n_roll

    for j, rid in enumerate(ids):
        w_marks, w_times = res.rollouts[j]
        np.testing.assert_array_equal(w_marks, np.asarray(ref[rid].tokens))
        np.testing.assert_array_equal(w_times, np.asarray(ref[rid].times))

    # and the on-device quantiles agree with numpy over the collected
    # rollouts (executor -> aggregator wiring)
    buf = np.zeros((n_roll, budget), np.float32)
    nv = np.zeros((n_roll,), np.int32)
    for j, (_, ts) in enumerate(res.rollouts):
        buf[j, :len(ts)] = ts
        nv[j] = len(ts)
    counts = _ref_counts(buf, nv, req.t_last, req.t_last + 6.0, 4)
    want = np.stack([np.quantile(counts, q, axis=0, method="inverted_cdf")
                     for q in req.quantiles])
    np.testing.assert_array_equal(res.quantiles, want)


def test_forecaster_async_loop_bitwise(tpp_pair):
    """Forecaster(loop="async") drains waves with run_async(); the
    quantile surface and collected rollouts are bitwise the sync
    executor's."""
    cfg_t, cfg_d, pt, pd = tpp_pair
    times, marks = _history(4)
    kw = dict(method="sd", max_batch=4, max_len=16, gamma=2,
              kernel="ref", sched="grouped", page_size=4, n_pages=12)
    req = ForecastRequest(history_times=times, history_marks=marks,
                          horizon=6.0, n_rollouts=5, bins=4,
                          max_events=6, rng=jax.random.PRNGKey(42))

    def go(loop):
        eng = ServingEngine(cfg_t, pt, cfg_d, pd, **kw)
        return Forecaster(eng, loop=loop).forecast(req, collect=True)

    a, b = go("sync"), go("async")
    np.testing.assert_array_equal(a.quantiles, b.quantiles)
    for (mk_a, ts_a), (mk_b, ts_b) in zip(a.rollouts, b.rollouts):
        np.testing.assert_array_equal(mk_a, mk_b)
        np.testing.assert_array_equal(ts_a, ts_b)
    with pytest.raises(ValueError, match="loop"):
        Forecaster(ServingEngine(cfg_t, pt, cfg_d, pd, **kw), loop="bogus")


def test_forecaster_requires_tpp_and_idle_engine(tpp_pair):
    cfg_t, cfg_d, pt, pd = tpp_pair
    tok = ModelConfig(name="tk", family="dense", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=11,
                      dtype="float32", param_dtype="float32", remat=False)
    ptok = registry.get_model(tok).init_params(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="TPP"):
        Forecaster(ServingEngine(tok, ptok, method="ar", max_batch=2,
                                 max_len=32))
    eng = ServingEngine(cfg_t, pt, cfg_d, pd, max_batch=2, max_len=16,
                        gamma=2)
    times, marks = _history(3)
    eng.submit(prompt=marks, times=times, t_end=10.0, max_new_tokens=4,
               rng=0)
    with pytest.raises(RuntimeError, match="busy"):
        Forecaster(eng).forecast(ForecastRequest(
            history_times=times, history_marks=marks, horizon=2.0,
            n_rollouts=2, max_events=4))
    eng.run()


# ---------------------------------------------------------------------------
# (iii) grouped policy: siblings share target forwards
# ---------------------------------------------------------------------------

def test_grouped_policy_shares_forwards_vs_ungrouped_fifo():
    """Under page pressure, fan-out groups admit ALL siblings (forks
    reuse the prompt's pages) where ungrouped FIFO can only co-batch
    two full-footprint copies — strictly fewer target forwards for
    bitwise the same streams."""
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=31,
                      dtype="float32", param_dtype="float32", remat=False)
    pt = registry.get_model(cfg).init_params(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(7), (16,), 0,
                                31).astype(jnp.int32)
    base = jax.random.PRNGKey(0)
    kw = dict(method="ar", max_batch=3, max_len=32, kv_layout="paged",
              page_size=4, n_pages=15)

    eng_g = ServingEngine(cfg, pt, sched="grouped", **kw)
    eng_g.submit(prompt=prompt, max_new_tokens=4, rng=base, fanout=3)
    res_g = eng_g.run()

    eng_u = ServingEngine(cfg, pt, sched="fifo", **kw)
    for k in range(3):                # same streams, no group
        eng_u.submit(prompt=prompt, max_new_tokens=4,
                     rng=jax.random.fold_in(base, k))
    res_u = eng_u.run()

    st_g, st_u = eng_g.stats(), eng_u.stats()
    assert st_g.target_forwards < st_u.target_forwards
    sharing = (sum(st_g.group_member_rounds.values())
               / max(1, sum(st_g.group_forwards.values())))
    assert sharing > 1.0
    assert st_g.rollouts == 3         # group members count as rollouts
    toks_g = sorted(tuple(np.asarray(r.tokens)) for r in res_g)
    toks_u = sorted(tuple(np.asarray(r.tokens)) for r in res_u)
    assert toks_g == toks_u


# ---------------------------------------------------------------------------
# (iv) TPP event-history prefix cache: hit bitwise == cold miss
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel", ["ref", "pallas"])
def test_tpp_prefix_cache_hit_bitwise_equal_cold(tpp_pair, kernel):
    cfg_t, cfg_d, pt, pd = tpp_pair
    times, marks = _history(9, seed=11)
    eng = ServingEngine(cfg_t, pt, cfg_d, pd, method="sd", max_batch=2,
                        max_len=32, gamma=2, kernel=kernel, page_size=4,
                        prefix_cache=True)

    def go():
        eng.submit(prompt=marks, times=times, t_end=float(times[-1]) + 8.0,
                   max_new_tokens=6, rng=jax.random.PRNGKey(5))
        (r,) = eng.run()
        return np.asarray(r.tokens), np.asarray(r.times)

    cold_marks, cold_times = go()
    assert eng.stats().prefix_hits == 0
    warm_marks, warm_times = go()
    st = eng.stats()
    assert st.prefix_hits == 1 and st.prefix_hit_tokens > 0
    np.testing.assert_array_equal(cold_marks, warm_marks)
    np.testing.assert_array_equal(cold_times, warm_times)


# ---------------------------------------------------------------------------
# spec plumbing
# ---------------------------------------------------------------------------

def test_forecast_spec_validation():
    ok = SamplerSpec(domain="tpp", forecast=ForecastSpec(horizon=2.0))
    ok.validate()
    SamplerSpec(domain="tpp", sched="grouped",
                forecast=ForecastSpec()).validate()
    with pytest.raises(SpecError, match="domain='tpp'"):
        SamplerSpec(domain="token", forecast=ForecastSpec()).validate()
    with pytest.raises(SpecError, match="thinning"):
        SamplerSpec(domain="tpp", method="thinning", execution="host",
                    forecast=ForecastSpec()).validate()
    with pytest.raises(SpecError, match="horizon"):
        SamplerSpec(domain="tpp",
                    forecast=ForecastSpec(horizon=-1.0)).validate()
    with pytest.raises(SpecError, match="paged"):
        SamplerSpec(domain="tpp", kv_layout="dense",
                    forecast=ForecastSpec()).validate()
    # serving knobs stay token/forecast-only for plain TPP specs
    with pytest.raises(SpecError, match="sched"):
        SamplerSpec(domain="tpp", sched="grouped").validate()
    with pytest.raises(SpecError, match="needs a spec"):
        build_forecaster(SamplerSpec(domain="tpp"), None, None)


def test_build_forecaster_runs_spec(tpp_pair):
    cfg_t, cfg_d, pt, pd = tpp_pair
    spec = SamplerSpec(domain="tpp", method="sd", gamma=2, batch=2,
                       max_events=5, max_len=24,
                       forecast=ForecastSpec(horizon=4.0, n_rollouts=3,
                                             bins=3,
                                             quantiles=(0.25, 0.75)))
    fc = build_forecaster(spec, cfg_t, pt, cfg_d, pd)
    times, marks = _history(4)
    res = fc(times, marks, rng=jax.random.PRNGKey(1))
    assert res.n_rollouts == 3 and res.quantiles.shape == (2, 3)
    assert res.rollouts_per_sec > 0
    assert fc.engine.stats().rollouts == 3
    assert fc.engine.scheduler.policy.name == "grouped"
