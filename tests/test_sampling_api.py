"""Tests for the unified ``repro.sampling`` engine: spec validation,
strategy registry round-trip, AR-vs-SD distribution agreement through the
engine (the paper's central claim via the new API), and batched/sharded
execution smoke."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from scipy import stats

from repro.configs.base import TPPConfig
from repro.models import tpp
from repro.sampling import (ENGINE, FixedGamma, SampleBatch, SamplerSpec,
                            SpecError, build_sampler, get_strategy,
                            register_strategy, strategy_names)


@pytest.fixture(scope="module")
def tiny_pair():
    cfg_t = TPPConfig(encoder="thp", num_layers=2, num_heads=2, d_model=16,
                      d_ff=32, num_marks=3, num_mix=4)
    cfg_d = cfg_t.replace(num_layers=1, num_heads=1)
    pt = tpp.init_params(cfg_t, jax.random.PRNGKey(0))
    pd = tpp.init_params(cfg_d, jax.random.PRNGKey(1))
    return cfg_t, cfg_d, pt, pd


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw,match", [
    (dict(method="nope"), "unknown method"),
    (dict(execution="nope"), "unknown execution"),
    (dict(method="thinning", execution="jit"), "host-only"),
    (dict(execution="jit", batch=4), "single sequence"),
    (dict(t_end=0.0), "t_end"),
    (dict(max_events=0), "max_events"),
    (dict(batch=0), "batch"),
    (dict(method="sd", gamma=0), "gamma"),
    (dict(domain="nope"), "unknown domain"),
    (dict(domain="token", method="thinning", execution="host"),
     "no token-domain analogue"),
    (dict(domain="token", method="sd", execution="vmap"), "host-only"),
    (dict(domain="token", method="sd", execution="host",
          max_events=64, max_len=32), "max_len"),
])
def test_spec_validation_errors(kw, match):
    with pytest.raises(SpecError, match=match):
        SamplerSpec(**kw).validate()


def test_spec_valid_combinations_pass():
    for method in ("ar", "sd"):
        for execution in ("host", "jit", "vmap", "sharded"):
            s = SamplerSpec(method=method, execution=execution,
                            batch=1 if execution == "jit" else 4)
            assert s.validate() is s
    SamplerSpec(method="thinning", execution="host").validate()


def test_engine_requires_draft_for_sd(tiny_pair):
    cfg_t, _, pt, _ = tiny_pair
    with pytest.raises(SpecError, match="draft"):
        ENGINE.build(SamplerSpec(method="sd", execution="jit", t_end=1.0,
                                 max_events=8), cfg_t, pt)


# ---------------------------------------------------------------------------
# registry round-trip
# ---------------------------------------------------------------------------

def test_registry_roundtrip():
    for name in ("ar", "sd", "thinning"):
        assert name in strategy_names()
        assert get_strategy(name) is get_strategy(name)
    with pytest.raises(KeyError, match="no sampling strategy"):
        get_strategy("does-not-exist")


def test_registry_accepts_new_strategy(tiny_pair):
    cfg_t, _, pt, _ = tiny_pair

    @register_strategy("_test_constant")
    class ConstantStrategy:
        """Degenerate strategy: no events, one round."""

        def build_device(self, spec, bundle):
            from repro.sampling.result import SeqResult
            E = spec.max_events
            return lambda rng: SeqResult(
                jnp.zeros((E,)), jnp.zeros((E,), jnp.int32), jnp.int32(0),
                jnp.int32(0), jnp.int32(0), jnp.int32(1))

        def build_host(self, spec, bundle):
            return self.build_device(spec, bundle)

    assert "_test_constant" in strategy_names()
    strat = get_strategy("_test_constant")
    spec = SamplerSpec(method="ar", execution="jit", t_end=1.0, max_events=4)
    res = strat.build_device(spec, None)(jax.random.PRNGKey(0))
    assert int(res.n) == 0 and int(res.rounds) == 1


def test_draft_policy_registry():
    from repro.sampling import draft_policy_names, get_draft_policy
    assert "fixed" in draft_policy_names()
    assert "adaptive" in draft_policy_names()
    pol = get_draft_policy("fixed")(5)
    assert isinstance(pol, FixedGamma)
    assert pol.round_gamma(0) == 5 and pol.max_gamma == 5 and pol.is_static


def test_adaptive_policy_schedule():
    """Acceptance feedback: grow on fully-accepted rounds, shrink on a
    rejection, clamp to [1, gamma]."""
    from repro.sampling import get_draft_policy
    pol = get_draft_policy("adaptive")(6)
    assert not pol.is_static and pol.max_gamma == 6
    s = pol.init_state()
    g0 = pol.gamma(s)
    assert 1 <= g0 <= 6
    s = pol.update(s, drafted=g0, accepted=g0)       # full accept
    assert pol.gamma(s) == min(6, g0 + 1)
    s = pol.update(s, drafted=pol.gamma(s), accepted=0)  # early rejection
    assert pol.gamma(s) == min(6, g0 + 1) - 1
    for _ in range(20):                               # clamps at 1
        s = pol.update(s, drafted=3, accepted=0)
    assert pol.gamma(s) == 1
    for _ in range(20):                               # clamps at max
        s = pol.update(s, drafted=pol.gamma(s), accepted=pol.gamma(s))
    assert pol.gamma(s) == 6


# ---------------------------------------------------------------------------
# execution paths
# ---------------------------------------------------------------------------

def test_vmap_batched_smoke(tiny_pair):
    cfg_t, cfg_d, pt, pd = tiny_pair
    fn = build_sampler(SamplerSpec(method="sd", execution="vmap", t_end=2.0,
                                   gamma=3, max_events=32, batch=8),
                       cfg_t, pt, cfg_d, pd)
    b = fn(jax.random.PRNGKey(0))
    assert isinstance(b, SampleBatch)
    assert b.times.shape == (8, 32) and b.lengths.shape == (8,)
    seqs = b.to_seqs()
    assert len(seqs) == 8
    for (t, k), n in zip(seqs, np.array(b.lengths)):
        assert len(t) == len(k) == n
        assert np.all(np.diff(t) > 0) or n < 2
        assert np.all(t <= 2.0)
    st = b.stats()
    assert st.drafted >= st.accepted >= 0
    assert 0.0 < st.acceptance_rate <= 1.0


def test_sharded_matches_vmap(tiny_pair):
    """Sharded execution = vmap + device placement; same seeds, same
    sequences (1-device CPU degrades to replicate fallback)."""
    cfg_t, cfg_d, pt, pd = tiny_pair
    base = SamplerSpec(method="sd", t_end=2.0, gamma=3, max_events=16,
                       batch=4)
    bv = build_sampler(base.replace(execution="vmap"),
                       cfg_t, pt, cfg_d, pd)(jax.random.PRNGKey(3))
    bs = build_sampler(base.replace(execution="sharded"),
                       cfg_t, pt, cfg_d, pd)(jax.random.PRNGKey(3))
    np.testing.assert_array_equal(np.array(bv.lengths), np.array(bs.lengths))
    np.testing.assert_allclose(np.array(bv.times), np.array(bs.times),
                               rtol=1e-6)


@pytest.mark.parametrize("method", ["ar", "sd"])
def test_host_matches_vmap_exactly_at_batch1(tiny_pair, method):
    """RNG-parity bugfix: the host executor ALWAYS splits the seed, so
    batch=1 host execution consumes the same lane key as the vmap (and
    jit) executors. Stream equivalence is exact — identical lengths and
    event types; times agree to kernel tolerance only, because XLA
    lowers batched and unbatched matmuls differently (the valid prefix
    is compared: buffer entries past t_end are never committed)."""
    cfg_t, cfg_d, pt, pd = tiny_pair
    kw = (cfg_d, pd) if method == "sd" else ()
    base = SamplerSpec(method=method, t_end=2.0, gamma=3, max_events=16,
                       batch=1)
    for seed in (0, 7):
        bh = build_sampler(base.replace(execution="host"),
                           cfg_t, pt, *kw)(jax.random.PRNGKey(seed))
        bv = build_sampler(base.replace(execution="vmap"),
                           cfg_t, pt, *kw)(jax.random.PRNGKey(seed))
        n = int(bh.lengths[0])
        assert n == int(bv.lengths[0])
        np.testing.assert_array_equal(np.array(bh.types[0, :n]),
                                      np.array(bv.types[0, :n]))
        np.testing.assert_allclose(np.array(bh.times[0, :n]),
                                   np.array(bv.times[0, :n]),
                                   rtol=2e-5, atol=1e-5)


def test_host_and_jit_agree_through_engine(tiny_pair):
    cfg_t, cfg_d, pt, pd = tiny_pair
    base = SamplerSpec(method="sd", t_end=2.0, gamma=3, max_events=32)
    bj = build_sampler(base.replace(execution="jit"),
                       cfg_t, pt, cfg_d, pd)(jax.random.PRNGKey(6))
    bh = build_sampler(base.replace(execution="host"),
                       cfg_t, pt, cfg_d, pd)(jax.random.PRNGKey(6))
    assert int(bj.lengths[0]) == int(bh.lengths[0])
    np.testing.assert_allclose(np.array(bj.times), np.array(bh.times),
                               rtol=1e-6)


def test_thinning_through_engine(tiny_pair):
    cfg_t, _, pt, _ = tiny_pair
    fn = build_sampler(SamplerSpec(method="thinning", execution="host",
                                   t_end=2.0, max_events=32), cfg_t, pt)
    st = fn(jax.random.PRNGKey(1)).stats()
    # every proposal costs a target forward: the App. D.1 structural point
    assert st.rounds >= st.events


# ---------------------------------------------------------------------------
# AR vs SD distribution agreement through the engine (central claim)
# ---------------------------------------------------------------------------

def test_ar_and_sd_specs_agree_in_distribution(tiny_pair):
    cfg_t, cfg_d, pt, pd = tiny_pair
    B, T_END, EMAX = 400, 2.0, 64
    base = SamplerSpec(execution="vmap", t_end=T_END, max_events=EMAX,
                       batch=B)
    ra = build_sampler(base.replace(method="ar"),
                       cfg_t, pt)(jax.random.PRNGKey(4))
    rs = build_sampler(base.replace(method="sd", gamma=4),
                       cfg_t, pt, cfg_d, pd)(jax.random.PRNGKey(5))
    na, ns = np.array(ra.lengths), np.array(rs.lengths)
    assert stats.ks_2samp(na, ns).pvalue > 1e-3
    fa = np.array(ra.times[:, 0])[na > 0]
    fs = np.array(rs.times[:, 0])[ns > 0]
    assert stats.ks_2samp(fa, fs).pvalue > 1e-3
    # the SD run must also report a meaningful acceptance rate
    st = rs.stats()
    assert 0.0 < st.acceptance_rate <= 1.0
    assert st.events_per_forward > 1.0, \
        "SD must commit more than one event per target forward"
    ar_st = ra.stats()
    assert ar_st.drafted == 0 and ar_st.events_per_forward <= 1.0


# ---------------------------------------------------------------------------
# adaptive draft policy through the engine
# ---------------------------------------------------------------------------

def test_adaptive_policy_requires_host_execution(tiny_pair):
    cfg_t, cfg_d, pt, pd = tiny_pair
    with pytest.raises(SpecError, match="adapts gamma"):
        ENGINE.build(SamplerSpec(method="sd", execution="jit", t_end=1.0,
                                 gamma=4, max_events=8,
                                 draft_policy="adaptive"),
                     cfg_t, pt, cfg_d, pd)


def test_adaptive_policy_tpp_host_sampling(tiny_pair):
    """The host SD executor follows the adaptive schedule (one compiled
    round per distinct gamma) and still produces a valid sequence with
    meaningful acceptance accounting."""
    cfg_t, cfg_d, pt, pd = tiny_pair
    fn = build_sampler(SamplerSpec(method="sd", execution="host", t_end=2.0,
                                   gamma=4, max_events=32,
                                   draft_policy="adaptive"),
                       cfg_t, pt, cfg_d, pd)
    b = fn(jax.random.PRNGKey(11))
    assert isinstance(b, SampleBatch)
    n = int(b.lengths[0])
    t = np.array(b.times[0, :n])
    assert np.all(np.diff(t) > 0) or n < 2
    assert np.all(t <= 2.0)
    st = b.stats()
    assert st.drafted >= st.accepted >= 0
    assert st.rounds >= 1


def test_token_sampler_reuses_engine_and_pool():
    """Build-cache bugfix: a domain='token' sampler keeps ONE
    ServingEngine for its lifetime — repeated calls reset request state
    but reuse the allocated KV pools (and therefore every compilation)
    instead of constructing a fresh engine per call."""
    from repro.configs.base import ModelConfig
    from repro.models import registry as zoo
    cfg_t = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                        num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=31,
                        dtype="float32", param_dtype="float32", remat=False)
    cfg_d = cfg_t.replace(name="d", num_layers=1)
    pt = zoo.get_model(cfg_t).init_params(jax.random.PRNGKey(0))
    pd = zoo.get_model(cfg_d).init_params(jax.random.PRNGKey(1))
    fn = build_sampler(SamplerSpec(domain="token", method="sd",
                                   execution="host", batch=2, max_events=6,
                                   max_len=32, gamma=2),
                       cfg_t, pt, cfg_d, pd)
    prompt = jnp.arange(4, dtype=jnp.int32)
    b1 = fn(jax.random.PRNGKey(0), prompt)
    engine, pool_t, pool_d = fn.engine, fn.engine.pool_t, fn.engine.pool_d
    if engine.kv_layout == "paged":
        assert pool_t.pages is not None   # page arrays allocated
    else:
        assert pool_t.tree is not None    # allocated by the first call
    b2 = fn(jax.random.PRNGKey(0), prompt)
    assert fn.engine is engine
    assert fn.engine.pool_t is pool_t and fn.engine.pool_d is pool_d
    # reset correctness: same seed => identical output across calls
    np.testing.assert_array_equal(np.array(b1.types), np.array(b2.types))
    np.testing.assert_array_equal(np.array(b1.lengths), np.array(b2.lengths))


def test_core_sampler_shims_are_gone():
    """ROADMAP cleanup: the deprecated ``core.sampler`` module was
    deleted once nothing imported it."""
    with pytest.raises(ImportError):
        from repro.core import sampler  # noqa: F401
