"""Shared statistical test helpers."""
import numpy as np
from scipy import stats


def chisq(counts, probs):
    """Chi-square GoF against probs, exactly renormalized to counts."""
    f_exp = np.asarray(probs, float)
    f_exp = f_exp / f_exp.sum() * counts.sum()
    f_exp *= counts.sum() / f_exp.sum()   # exact renormalization
    try:
        return stats.chisquare(counts, f_exp, sum_check=False)
    except TypeError:  # scipy < 1.16 has no sum_check (sums match anyway)
        return stats.chisquare(counts, f_exp)
