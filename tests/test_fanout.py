"""``SamplerSpec(fanout=K)``: scenario fan-out in both domains.

The contract fanout must keep: it NEVER changes any member's sampled
distribution — member k of base lane b is bitwise the stream of the
single-sequence sampler seeded with ``fold_in(split(rng, batch)[b], k)``
(the TPP executors fan the lane keys; the token domain submits one
shared-prefix group per prompt and the serving engine forks the
admitted KV pages). Only the executor wiring and the prefill cost
change.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, TPPConfig
from repro.models import registry, tpp
from repro.sampling import (ENGINE, SamplerSpec, SpecError, build_sampler,
                            get_strategy)
from repro.sampling.strategies import ModelBundle


@pytest.fixture(scope="module")
def tiny_pair():
    cfg_t = TPPConfig(encoder="thp", num_layers=2, num_heads=2, d_model=16,
                      d_ff=32, num_marks=3, num_mix=4)
    cfg_d = cfg_t.replace(num_layers=1, num_heads=1)
    pt = tpp.init_params(cfg_t, jax.random.PRNGKey(0))
    pd = tpp.init_params(cfg_d, jax.random.PRNGKey(1))
    return cfg_t, cfg_d, pt, pd


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw,match", [
    (dict(fanout=0), "fanout"),
    (dict(fanout=-2), "fanout"),
    (dict(execution="jit", fanout=3), "single sequence"),
])
def test_fanout_spec_validation(kw, match):
    with pytest.raises(SpecError, match=match):
        SamplerSpec(**kw).validate()


def test_fanout_one_is_the_default_and_valid_everywhere():
    for ex in ("host", "vmap", "sharded"):
        SamplerSpec(execution=ex, fanout=1).validate()
    SamplerSpec(execution="jit", fanout=1).validate()


# ---------------------------------------------------------------------------
# TPP domain: lane-key derivation and executor agreement
# ---------------------------------------------------------------------------

def test_tpp_fanout_host_matches_vmap(tiny_pair):
    """batch=2 x fanout=3 -> 6 lanes, identical across executors (types
    exact, times to the repo's cross-executor kernel tolerance)."""
    cfg_t, cfg_d, pt, pd = tiny_pair
    spec = SamplerSpec(method="sd", execution="host", t_end=2.0, gamma=3,
                       max_events=16, batch=2, fanout=3)
    rng = jax.random.PRNGKey(42)
    bh = build_sampler(spec, cfg_t, pt, cfg_d, pd)(rng)
    bv = build_sampler(spec.replace(execution="vmap"),
                       cfg_t, pt, cfg_d, pd)(rng)
    assert bh.times.shape[0] == bv.times.shape[0] == 6
    np.testing.assert_array_equal(np.array(bh.lengths),
                                  np.array(bv.lengths))
    for lane in range(6):
        n = int(bh.lengths[lane])
        np.testing.assert_array_equal(np.array(bh.types[lane, :n]),
                                      np.array(bv.types[lane, :n]))
        np.testing.assert_allclose(np.array(bh.times[lane, :n]),
                                   np.array(bv.times[lane, :n]),
                                   rtol=2e-5, atol=1e-5)


def test_tpp_fanout_member_is_bitwise_the_folded_key_stream(tiny_pair):
    """Member (b, k) of the fanout batch == the strategy's single
    sampler called with fold_in(split(rng, B)[b], k): fanout is pure
    key fan-out, nothing about a member's stream depends on K."""
    cfg_t, cfg_d, pt, pd = tiny_pair
    spec = SamplerSpec(method="ar", execution="host", t_end=2.0,
                       max_events=16, batch=2, fanout=3)
    rng = jax.random.PRNGKey(9)
    batch = build_sampler(spec, cfg_t, pt)(rng)
    sampler = get_strategy("ar").build_host(spec, ModelBundle(cfg_t, pt))
    base = jax.random.split(rng, 2)
    for b in range(2):
        for k in range(3):
            lane = b * 3 + k
            single = sampler(jax.random.fold_in(base[b], k))
            n = int(single.n)
            assert n == int(batch.lengths[lane])
            np.testing.assert_array_equal(
                np.array(batch.types[lane, :n]),
                np.array(single.types[:n]))
            np.testing.assert_array_equal(
                np.array(batch.times[lane, :n]),
                np.array(single.times[:n]))


def test_tpp_fanout_one_keeps_historical_lane_keys(tiny_pair):
    """fanout=1 must stay bitwise the pre-fanout engine: raw
    split(rng, batch) lane keys, no fold_in wrapping."""
    cfg_t, cfg_d, pt, pd = tiny_pair
    spec = SamplerSpec(method="ar", execution="vmap", t_end=2.0,
                       max_events=16, batch=4)
    rng = jax.random.PRNGKey(3)
    b_default = build_sampler(spec, cfg_t, pt)(rng)
    b_explicit = build_sampler(spec.replace(fanout=1), cfg_t, pt)(rng)
    np.testing.assert_array_equal(np.array(b_default.times),
                                  np.array(b_explicit.times))
    np.testing.assert_array_equal(np.array(b_default.types),
                                  np.array(b_explicit.types))


def test_tpp_fanout_sharded_matches_vmap(tiny_pair):
    """sharded = vmap + placement at fanout too (1-device CPU falls
    back to replication; lane count batch*fanout drives the data-axis
    divisibility check)."""
    cfg_t, cfg_d, pt, pd = tiny_pair
    spec = SamplerSpec(method="ar", t_end=2.0, max_events=16, batch=2,
                       fanout=2)
    rng = jax.random.PRNGKey(5)
    bv = build_sampler(spec.replace(execution="vmap"), cfg_t, pt)(rng)
    bs = build_sampler(spec.replace(execution="sharded"), cfg_t, pt)(rng)
    assert bs.times.shape[0] == 4
    np.testing.assert_array_equal(np.array(bv.lengths),
                                  np.array(bs.lengths))
    np.testing.assert_allclose(np.array(bv.times), np.array(bs.times),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# token domain: fanout groups ride the serving engine's COW forks
# ---------------------------------------------------------------------------

def _dense(num_layers=2, vocab=31, name="t", **kw):
    base = dict(name=name, family="dense", num_layers=num_layers,
                d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                vocab_size=vocab, dtype="float32", param_dtype="float32",
                remat=False)
    base.update(kw)
    return ModelConfig(**base)


def test_token_fanout_single_prompt_yields_k_rollouts():
    cfg_t, cfg_d = _dense(2), _dense(1, name="d")
    pt = registry.get_model(cfg_t).init_params(jax.random.PRNGKey(0))
    pd = registry.get_model(cfg_d).init_params(jax.random.PRNGKey(1))
    spec = SamplerSpec(method="sd", execution="host", domain="token",
                       batch=4, max_events=8, max_len=64, gamma=3,
                       kernel="ref", fanout=3)
    fn = ENGINE.build(spec, cfg_t, pt, cfg_d, pd)
    out = fn(jax.random.PRNGKey(5), np.arange(10) % 31)
    # one prompt -> ONE group of 3 rollouts (no batch broadcast at
    # fanout>1), each with its own stream
    assert out.times.shape[0] == 3
    st = fn.engine.stats()
    # both siblings forked the admitted 10-token prompt
    assert st.prefix_hit_tokens == 20
    assert st.prefix_hits == 2 and st.prefix_lookups >= 2
