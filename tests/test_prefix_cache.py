"""COW KV pages, the radix prefix cache, and scenario fan-out.

Pins, bottom-up: (1) pool bookkeeping — ``fork`` shares pages by
refcount bump, the first divergent append copies exactly the boundary
page (``cow_for_append``), truncate/retire free a shared page only at
refcount 0, and ``can_admit`` budgets adopted pages and the COWs an
admission creates; (2) the radix cache — page-aligned longest-prefix
match capped at ``prompt_len - 1``, retire-time donation with
ownership transfer, LRU leaf eviction, and composition with the
lifetime-reservation admission (cache-retained pages count as headroom
and evict synchronously when the free list runs dry); (3) the engine
contracts — a ``submit(fanout=K)`` group's members are token-BITWISE
the independently-submitted requests carrying the same
``fold_in(rng, k)`` keys, and a warm cache-hit admission is
token-bitwise both the cold miss and the cache-off engine, on the ref
and Pallas(interpret) backends, including rollback after a fork; and
(4) nothing leaks — after every request retires, non-cached pools are
fully free and cached pools hold exactly one page per radix node.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import registry
from repro.serving import ServeRequest, ServingEngine
from repro.serving.kv_pool import PagedKVCachePool
from repro.serving.prefix_cache import PrefixCache

RNG = jax.random.PRNGKey(0)


def _dense(num_layers=2, vocab=31, name="t", **kw):
    base = dict(name=name, family="dense", num_layers=num_layers,
                d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                vocab_size=vocab, dtype="float32", param_dtype="float32",
                remat=False)
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def dense_pair():
    cfg_t, cfg_d = _dense(2), _dense(1, name="d")
    mt, md = registry.get_model(cfg_t), registry.get_model(cfg_d)
    return (cfg_t, cfg_d, mt.init_params(RNG),
            md.init_params(jax.random.PRNGKey(1)))


# ---------------------------------------------------------------------------
# pool units: fork / COW / refcounts (no engine, no model forward)
# ---------------------------------------------------------------------------

def test_fork_shares_pages_and_cow_isolates_the_boundary():
    pool = PagedKVCachePool(3, _dense(1), page_size=4, max_len=16)
    pool.ensure_blocks(0, 6)                    # 2 pages, frontier mid-page
    pool.lens[0] = 6
    assert pool.fork(0, 1, 6) == 2
    assert pool.lens[1] == 6 and pool.n_blocks[1] == 2
    assert np.array_equal(pool.tables[1, :2], pool.tables[0, :2])
    assert all(int(pool.refcount[pool.tables[0, b]]) == 2 for b in range(2))
    # both slots' next append must copy the shared mid-page boundary
    assert pool._cow_pending(0) == 1 and pool._cow_pending(1) == 1
    old = int(pool.tables[1, 1])
    assert pool.cow_for_append(1)
    new = int(pool.tables[1, 1])
    assert new != old and pool.cow_copies == 1
    assert int(pool.refcount[old]) == 1 and int(pool.refcount[new]) == 1
    # the FULL page 0 stays shared — COW never touches it
    assert pool.tables[1, 0] == pool.tables[0, 0]
    assert int(pool.refcount[pool.tables[0, 0]]) == 2
    # slot 1's frontier is now private: second call is a no-op
    assert not pool.cow_for_append(1)
    # ... and the copy UNSHARED the boundary, so the source owes nothing
    assert pool._cow_pending(0) == 0
    assert not pool.cow_for_append(0)
    assert int(pool.refcount[old]) == 1          # still slot 0's page


def test_fork_page_aligned_never_needs_cow():
    pool = PagedKVCachePool(2, _dense(1), page_size=4, max_len=16)
    pool.ensure_blocks(0, 8)
    pool.lens[0] = 8
    pool.fork(0, 1, 8)
    assert pool._cow_pending(0) == 0 and pool._cow_pending(1) == 0
    assert not pool.cow_for_append(1)
    # first append draws a FRESH page; shared ones are behind the frontier
    pool.ensure_blocks(1, 9)
    assert pool.tables[1, 2] != pool.tables[0, 2]


def test_fork_validates_target_and_coverage():
    pool = PagedKVCachePool(2, _dense(1), page_size=4, max_len=16)
    pool.ensure_blocks(0, 5)
    pool.lens[0] = 5
    with pytest.raises(ValueError, match="covers 5"):
        pool.fork(0, 1, 9)                       # src holds only 5 positions
    pool.fork(0, 1, 5)
    with pytest.raises(ValueError, match="not empty"):
        pool.fork(0, 1, 5)                       # dst already populated


def test_truncate_frees_shared_pages_only_at_refcount_zero():
    pool = PagedKVCachePool(2, _dense(1), page_size=4, max_len=16)
    total_free = pool.n_pages - 1
    pool.ensure_blocks(0, 8)
    pool.lens[0] = 8
    pool.fork(0, 1, 8)
    pool.free_slot(0)                            # pages survive in slot 1
    assert len(pool.free) == total_free - 2
    assert all(int(pool.refcount[pool.tables[1, b]]) == 1 for b in range(2))
    pool.free_slot(1)                            # last owner: all back
    assert len(pool.free) == total_free
    assert int(pool.refcount.sum()) == 0


def test_adopt_refcounts_and_resumes_page_aligned():
    pool = PagedKVCachePool(2, _dense(1), page_size=4, max_len=16)
    pool.ensure_blocks(0, 8)
    pool.lens[0] = 8
    run = [int(pool.tables[0, b]) for b in range(2)]
    pool.adopt(1, run)
    assert pool.lens[1] == 8 and pool.n_blocks[1] == 2
    assert all(int(pool.refcount[p]) == 2 for p in run)
    assert pool._cow_pending(1) == 0             # page-aligned: no COW debt
    with pytest.raises(ValueError, match="not empty"):
        pool.adopt(1, run)


def test_retain_release_reject_unallocated_pages():
    pool = PagedKVCachePool(1, _dense(1), page_size=4, max_len=16)
    for bad in (0, 1):                           # null page / never allocated
        with pytest.raises(ValueError, match="retain"):
            pool.retain(bad)
        with pytest.raises(ValueError, match="release"):
            pool.release(bad)
    pool.ensure_blocks(0, 2)
    pid = int(pool.tables[0, 0])
    pool.retain(pid)
    assert not pool.release(pid)                 # still owned by the table
    assert pool.release(pid)                     # now free
    pool.tables[0, 0] = 0
    pool.n_blocks[0] = 0                         # keep bookkeeping honest


def test_can_admit_budgets_adopted_blocks_and_created_cows():
    # 2 slots x 2 blocks + null page, page 4: 4 usable pages
    pool = PagedKVCachePool(2, _dense(1), page_size=4, max_len=8)
    pool.reserve(0, 8)
    pool.ensure_blocks(0, 8)
    pool.lens[0] = 8
    # plain admission of 8 more positions needs 2 pages: exactly fits
    assert pool.can_admit(8)
    pool.reserve(1, 8)
    pool.ensure_blocks(1, 4)
    pool.lens[1] = 4
    # slot 1 still owes 1 reserved block; a 2-page admission now overdraws
    assert not pool.can_admit(8)
    # ... unless the pages arrive shared (fork/cache adoption)
    assert pool.can_admit(8, adopted_blocks=2)
    # ... and each COW the admission creates costs a free page again
    assert not pool.can_admit(8, adopted_blocks=2, cow_pages=1)


# ---------------------------------------------------------------------------
# radix cache units
# ---------------------------------------------------------------------------

def _donate(pool, cache, slot, tokens):
    """Simulate the engine's retire-time donation for a retiring slot
    whose committed prompt is ``tokens``: insert the FULL prompt pages,
    then free the slot (ownership transfers to the cache)."""
    full = len(tokens) // pool.page
    pages = {"t": [int(pool.tables[slot, b]) for b in range(full)]}
    new = cache.insert(np.asarray(tokens), pages)
    pool.free_slot(slot)
    return new


def test_cache_match_donation_and_prompt_minus_one_cap():
    pool = PagedKVCachePool(2, _dense(1), page_size=4, max_len=16)
    cache = PrefixCache(4, {"t": pool})
    prompt = np.arange(10) % 7                  # 2 full pages + 2 tail
    pool.reserve(0, 10)
    pool.ensure_blocks(0, 10)
    pool.lens[0] = 10
    donated_run = [int(pool.tables[0, b]) for b in range(2)]
    assert _donate(pool, cache, 0, prompt) == 2
    # cache is the sole owner now; pages did NOT return to the free list
    assert all(int(pool.refcount[p]) == 1 for p in donated_run)
    assert cache.n_nodes == 2
    hit, runs = cache.match(prompt, len(prompt) - 1)
    assert hit == 8 and runs["t"] == donated_run
    # the prompt_len-1 cap: a 8-token prompt may only adopt 1 page (7//4)
    hit, runs = cache.match(prompt[:8], 7)
    assert hit == 4 and runs["t"] == donated_run[:1]
    # diverging second page stops the walk after one node
    other = np.concatenate([prompt[:4], (prompt[4:8] + 1) % 7])
    hit, runs = cache.match(other, len(other))
    assert hit == 4 and runs["t"] == donated_run[:1]
    # re-donating the same prompt keeps the existing nodes: no new pages
    pool.reserve(1, 10)
    pool.ensure_blocks(1, 10)
    pool.lens[1] = 10
    assert _donate(pool, cache, 1, prompt) == 0
    assert cache.n_nodes == 2


def test_cache_lru_eviction_drops_leaves_first():
    pool = PagedKVCachePool(2, _dense(1), page_size=4, max_len=16)
    cache = PrefixCache(4, {"t": pool})
    long = np.arange(8)                         # nodes A -> B
    pool.ensure_blocks(0, 8)
    pool.lens[0] = 8
    _donate(pool, cache, 0, long)
    short = np.concatenate([np.arange(4), np.arange(4) + 20])  # A -> C
    pool.ensure_blocks(0, 8)
    pool.lens[0] = 8
    _donate(pool, cache, 0, short)
    assert cache.n_nodes == 3                   # shared root page A
    cache.match(long, 8)                        # B is now most recent
    free_before = len(pool.free)
    assert cache.evict("t", 1) == 1             # LRU leaf = C
    assert cache.n_nodes == 2
    assert len(pool.free) == free_before + 1
    hit, _ = cache.match(long, 8)
    assert hit == 8                             # A -> B survived
    hit, _ = cache.match(short, 8)
    assert hit == 4                             # C gone, shared A remains
    cache.clear()
    assert int(pool.refcount.sum()) == 0
    assert len(pool.free) == pool.n_pages - 1


def test_cache_retained_pages_count_as_admission_headroom():
    # 1 slot x 2 blocks + null: 2 usable pages, all of them cached
    pool = PagedKVCachePool(1, _dense(1), page_size=4, max_len=8)
    cache = PrefixCache(4, {"t": pool})
    prompt = np.arange(8)
    pool.reserve(0, 8)
    pool.ensure_blocks(0, 8)
    pool.lens[0] = 8
    _donate(pool, cache, 0, prompt)
    assert len(pool.free) == 0
    assert cache.evictable("t") == 2
    # the PR 4 invariant survives retained pages: a fresh full-lifetime
    # admission is still admissible because eviction can reclaim them
    assert pool._headroom() == 2
    assert pool.can_admit(8)
    # ... and ensure_blocks reclaims synchronously through the evictor
    pool.reserve(0, 8)
    pool.ensure_blocks(0, 8)
    assert pool.n_blocks[0] == 2
    assert cache.n_nodes == 0                   # both nodes evicted
    assert cache.stats.evicted_pages == 2
    # adopted pages are NOT evictable: they are pinned by a live slot
    pool.lens[0] = 8
    _donate(pool, cache, 0, prompt)
    hit, runs = cache.match(prompt, 8)
    pool.adopt(0, runs["t"])
    assert cache.evictable("t") == 0


def test_cache_eviction_keeps_live_adoptions_alive():
    pool = PagedKVCachePool(2, _dense(1), page_size=4, max_len=8)
    cache = PrefixCache(4, {"t": pool})
    prompt = np.arange(8)
    pool.ensure_blocks(0, 8)
    pool.lens[0] = 8
    _donate(pool, cache, 0, prompt)
    hit, runs = cache.match(prompt, 8)
    pool.adopt(1, runs["t"])                    # live slot shares the run
    freed = cache.evict("t", 2)
    assert freed == 0                           # cache ref dropped, not freed
    assert cache.n_nodes == 0
    assert all(int(pool.refcount[p]) == 1 for p in runs["t"])
    pool.free_slot(1)                           # last owner frees them
    assert int(pool.refcount.sum()) == 0


# ---------------------------------------------------------------------------
# engine contracts: fan-out forks and cache hits are bitwise invisible
# ---------------------------------------------------------------------------

def _engine(pair, **kw):
    cfg_t, cfg_d, pt, pd = pair
    kw.setdefault("kernel", "ref")
    kw.setdefault("max_batch", 4)
    return ServingEngine(cfg_t, pt, cfg_d, pd, max_len=128, gamma=3, **kw)


_PROMPT = np.arange(20) % 31


def _tokens_by_id(results):
    return [list(map(int, r.tokens))
            for r in sorted(results, key=lambda r: r.request_id)]


@pytest.mark.parametrize("kernel", ["ref", "pallas"])
def test_fanout_members_bitwise_match_independent_requests(dense_pair,
                                                           kernel):
    """submit(fanout=K) == K independent submissions with the folded
    keys: sharing the prompt's KV pages via fork + COW changes NO
    sampled token (gamma=3 SD rounds exercise rollback after fork)."""
    eng = _engine(dense_pair, kernel=kernel)
    eng.submit(prompt=_PROMPT, max_new_tokens=12, temperature=0.9, rng=7,
               fanout=3)
    res_fan = eng.run()
    hits = [r.prefix_hit_tokens
            for r in sorted(res_fan, key=lambda r: r.request_id)]

    eng2 = _engine(dense_pair, kernel=kernel)
    base = jax.random.PRNGKey(7)
    for k in range(3):
        eng2.submit(prompt=_PROMPT, max_new_tokens=12, temperature=0.9,
                    rng=jax.random.fold_in(base, k))
    assert _tokens_by_id(res_fan) == _tokens_by_id(eng2.run())
    # the source prefilled; every sibling forked the whole prompt
    assert hits[0] == 0 and hits[1:] == [len(_PROMPT)] * 2
    assert eng.pool_t.cow_copies > 0            # 20 tokens: mid-page fork
    assert eng.stats().prefix_hit_tokens == 2 * len(_PROMPT)
    # no pages leak once everything retired
    for e in (eng, eng2):
        assert int(e.pool_t.refcount.sum()) == 0
        assert len(e.pool_t.free) == e.pool_t.n_pages - 1


@pytest.mark.parametrize("kernel", ["ref", "pallas"])
def test_prefix_cache_hit_bitwise_matches_cold_and_cache_off(dense_pair,
                                                             kernel):
    """A warm radix-cache admission (adopt pages, prefill the tail) is
    token-bitwise the cold admission AND the cache-off engine."""
    eng = _engine(dense_pair, max_batch=2, kernel=kernel,
                  prefix_cache=True)
    eng.submit(prompt=_PROMPT, max_new_tokens=10, rng=11)
    cold = eng.run()[0]
    eng.submit(prompt=_PROMPT, max_new_tokens=10, rng=11)
    warm = eng.run()[0]
    assert list(cold.tokens) == list(warm.tokens)
    assert cold.prefix_hit_tokens == 0
    # 20-token prompt, page 16, cap at 19 tokens -> one full page
    assert warm.prefix_hit_tokens == 16
    assert eng.stats().prefix_hits == 1

    off = _engine(dense_pair, max_batch=2, kernel=kernel)
    off.submit(prompt=_PROMPT, max_new_tokens=10, rng=11)
    assert list(off.run()[0].tokens) == list(warm.tokens)
    # the cache engine's pools hold exactly one page per radix node
    held = int((eng.pool_t.refcount > 0).sum())
    assert held == eng.prefix_cache.n_nodes
    assert len(eng.pool_t.free) == eng.pool_t.n_pages - 1 - held


def test_prefix_cache_requires_paged_layout(dense_pair):
    with pytest.raises(ValueError, match="paged"):
        _engine(dense_pair, kv_layout="dense", prefix_cache=True)


def test_fanout_composes_with_prefix_cache(dense_pair):
    """Fan-out groups and cross-request cache hits stack: the second
    group's source adopts the first group's donated pages, its siblings
    fork, and every stream stays bitwise the cache-off run. max_batch=2
    serializes the groups so the second one sees a warm cache."""
    def run(cache_on):
        eng = _engine(dense_pair, max_batch=2, prefix_cache=cache_on)
        for g in range(2):
            eng.submit(prompt=_PROMPT, max_new_tokens=8, rng=50 + g,
                       fanout=2)
        return eng, _tokens_by_id(eng.run())

    eng_on, toks_on = run(True)
    _, toks_off = run(False)
    assert toks_on == toks_off
    st = eng_on.stats()
    # 2 sibling forks (20 tok each) + the second source's 16-token hit
    assert st.prefix_hit_tokens == 2 * len(_PROMPT) + 16
    assert eng_on.prefix_cache.stats.hit_tokens == 16


def test_engine_reset_clears_cache_and_fork_state(dense_pair):
    eng = _engine(dense_pair, prefix_cache=True)
    eng.submit(prompt=_PROMPT, max_new_tokens=6, rng=3, fanout=2)
    eng.run()
    assert eng.prefix_cache.n_nodes > 0
    eng.reset(force=True)
    assert eng.prefix_cache.n_nodes == 0
    assert eng._fork_sources == {}
    assert len(eng.pool_t.free) == eng.pool_t.n_pages - 1
    # post-reset admissions start cold and still work
    eng.submit(prompt=_PROMPT, max_new_tokens=6, rng=3)
    assert eng.run()[0].prefix_hit_tokens == 0
