"""CIF-based thinning for the NEURAL model (paper App. D.1).

The paper argues CIF-based speculative decoding is impractical; this
module implements the strongest available CIF-side baseline — classical
Ogata thinning driven by the CDF-model's implied intensity

    lambda*(t) = g(tau | h) / (1 - G(tau | h)),   tau = t - t_last

with an adaptive upper bound (scan the hazard on a short grid ahead,
multiply by a safety factor, re-raise on violation). It demonstrates
App. D.1's two failure modes concretely:

  1. the bound must be guessed (violations force restarts),
  2. each proposal needs a target forward, and a proposal is accepted
     with probability lambda*/lambda_bar < 1 — i.e. MORE than one target
     forward per event, vs TPP-SD's 1/(events-per-round) < 1.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..models import tpp


class ThinningResult(NamedTuple):
    times: jnp.ndarray
    types: jnp.ndarray
    n: jnp.ndarray
    proposals: jnp.ndarray     # candidate timestamps drawn
    forwards: jnp.ndarray      # target hazard evaluations
    bound_violations: jnp.ndarray


def _hazard(cfg, params, h, tau):
    """log lambda*(tau | h) = log g - log(1 - G).

    The adaptive upper bound evaluates this grid x M wide per accepted
    event; both densities route through the fused Pallas kernels when
    the config's kernel policy allows (``log_sf`` gained one alongside
    ``log_pdf`` precisely for this call)."""
    pol = tpp.resolve_policy(cfg)
    mix = tpp.interval_params(cfg, params, h)
    return (tpp.interval_logpdf(mix, tau, policy=pol)
            - tpp.interval_logsf(mix, tau, policy=pol))


def sample_thinning_host(cfg, params, rng, t_end: float, max_events: int,
                         *, safety: float = 2.0, grid: int = 8,
                         horizon: float = 2.0) -> ThinningResult:
    """Host-loop neural thinning (one forward per proposal)."""
    hazard = jax.jit(lambda h, tau: _hazard(cfg, params, h, tau))
    extend = jax.jit(lambda c, t, k: tpp.extend(cfg, params, c, t, k))
    heads = jax.jit(lambda h: tpp.type_logits(cfg, params, h))

    cache = tpp.init_cache(cfg, max_events + 2)
    h, cache = extend(cache, jnp.zeros(1),
                      jnp.full((1,), cfg.num_marks, jnp.int32))
    h = h[0]
    times, types = [], []
    t_last = 0.0
    t = 0.0
    proposals = forwards = violations = 0
    # adaptive bound: max hazard on a grid ahead of the current time
    taus_grid = jnp.linspace(1e-3, horizon, grid)

    def bound(h):
        return float(jnp.exp(jnp.max(hazard(h, taus_grid)))) * safety

    lam_bar = bound(h)
    forwards += 1
    rng_np = jax.random.split(rng, 1)[0]
    seed = int(jax.random.randint(rng_np, (), 0, 2**31 - 1))
    import numpy as np
    rnp = np.random.default_rng(seed)
    while t < t_end and len(times) < max_events:
        t = t + rnp.exponential(1.0 / lam_bar)
        if t > t_end:
            break
        proposals += 1
        forwards += 1
        lam = float(jnp.exp(hazard(h, jnp.float32(t - t_last))))
        if lam > lam_bar:  # bound violated: re-raise and restart from t_last
            violations += 1
            lam_bar = lam * safety
            t = t_last
            continue
        if rnp.uniform() < lam / lam_bar:
            k = int(jax.random.categorical(
                jax.random.fold_in(rng, proposals), heads(h)))
            times.append(float(t))
            types.append(k)
            h_new, cache = extend(cache, jnp.float32(t)[None],
                                  jnp.int32(k)[None])
            h = h_new[0]
            t_last = t
            lam_bar = bound(h)
            forwards += 1
    ta = jnp.zeros((max_events,), jnp.float32)
    ka = jnp.zeros((max_events,), jnp.int32)
    n = len(times)
    if n:
        ta = ta.at[:n].set(jnp.array(times))
        ka = ka.at[:n].set(jnp.array(types))
    return ThinningResult(ta, ka, jnp.int32(n), jnp.int32(proposals),
                          jnp.int32(forwards), jnp.int32(violations))
