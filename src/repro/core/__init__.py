"""The paper's primary contribution: speculative decoding for TPP sampling
(propose-verify engine, thinning baseline, AR + SD samplers, LLM-token SD)."""
from . import llm_sd, sampler, speculative, thinning
from .sampler import (SampleResult, sample_ar_batch, sample_ar_host,
                      sample_ar_jit, sample_sd_batch, sample_sd_host,
                      sample_sd_jit)
