"""The paper's primary contribution: speculative decoding for TPP sampling
(propose-verify engine, thinning baseline, LLM-token SD).

The old ``core.sampler`` shim module (``sample_{ar,sd}_{host,jit,batch}``)
is gone — build samplers through ``repro.sampling``:

    from repro.sampling import SamplerSpec, build_sampler
"""
from . import llm_sd, speculative, thinning
