"""Speculative-decoding primitives (paper Sec. 4.3 + App. A.2/A.3).

The discrete case is the Leviathan-et-al. adjusted distribution computed
exactly; the continuous case is Theorem 1's acceptance-rejection scheme:
draw tau ~ g_T, accept with probability max(0, 1 - g_D(tau)/g_T(tau)).

A note on Algorithm 1 line 11-12: the paper's shorthand resamples *both*
components from their adjusted distributions at the first rejected index
L = min(l1, l2). The provably-correct composition (App. A.2 proves each
component separately) distinguishes which component failed:

  - tau rejected at L  -> tau' ~ adjusted g', and the drafted k at L was
    never tested, so k' ~ f_T directly;
  - tau accepted, k rejected at L -> keep the accepted tau, k' ~ adjusted f'.

We implement the latter; tests verify the output distribution equals
target AR sampling either way.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..models import tpp
from ..models.tpp import MixParams


def accept_logratio(rng, logp_target, logp_draft):
    """Token/interval-level rejection test: u < min(1, p_T/p_D)."""
    u = jax.random.uniform(rng, logp_target.shape)
    return jnp.log(u) < (logp_target - logp_draft)


def adjusted_discrete(rng, logp_t, logp_d):
    """Sample from norm(max(0, p_T - p_D)) (Eq. 4). Shapes: [K]."""
    p = jnp.maximum(0.0, jnp.exp(logp_t) - jnp.exp(logp_d))
    total = jnp.sum(p)
    # p_T == p_D exactly => adjusted dist degenerate; fall back to p_T
    safe = jnp.where(total > 1e-12, p, jnp.exp(logp_t))
    return jax.random.categorical(rng, jnp.log(safe + 1e-38))


def adjusted_continuous(rng, mix_t: MixParams, mix_d: MixParams,
                        max_iters: int = 64):
    """Theorem 1: sample tau ~ g' = norm(max(0, g_T - g_D)).

    Repeatedly draw tau ~ g_T and accept with probability
    max(0, 1 - g_D(tau)/g_T(tau)). Bounded iterations; on exhaustion the
    last g_T draw is returned (only reachable when g_T ~= g_D everywhere,
    where the bias vanishes).
    """

    def body(state):
        rng, _, _, it = state
        rng, r1, r2 = jax.random.split(rng, 3)
        tau = tpp.sample_interval(r1, mix_t)
        logp = tpp.interval_logpdf(mix_t, tau)
        logq = tpp.interval_logpdf(mix_d, tau)
        alpha = jnp.maximum(0.0, 1.0 - jnp.exp(logq - logp))
        ok = jax.random.uniform(r2, ()) < alpha
        return rng, tau, ok, it + 1

    def cond(state):
        _, _, ok, it = state
        return jnp.logical_and(~ok, it < max_iters)

    rng, tau0, ok0, it0 = body((rng, jnp.float32(0.0), jnp.bool_(False),
                                jnp.int32(0)))
    _, tau, _, _ = lax.while_loop(cond, body, (rng, tau0, ok0, it0))
    return tau


class VerifyResult(NamedTuple):
    num_accepted: jnp.ndarray      # A in [0, gamma]
    all_accepted: jnp.ndarray      # bool
    tau_rejected: jnp.ndarray      # bool: the failing component was tau


def verify_events(rng, d_tau, d_k, logq_tau, logq_k_full, mix_t: MixParams,
                  logp_k_full, policy=None) -> VerifyResult:
    """Vector accept/reject over a drafted window (Alg. 1 lines 8-10).

    d_tau: [g] drafted intervals; d_k: [g] drafted marks.
    logq_tau: [g] draft interval log-densities at d_tau.
    logq_k_full / logp_k_full: [g, K] full log-pmfs (draft / target).
    mix_t: target MixParams at the g history positions.
    policy: resolved ``KernelPolicy`` for the gamma x M accept-ratio
    density (the round's widest pointwise evaluation); None = reference.
    """
    g = d_tau.shape[0]
    r_tau, r_k = jax.random.split(rng)
    logp_tau = tpp.interval_logpdf(mix_t, d_tau, policy=policy)
    logp_k = jnp.take_along_axis(logp_k_full, d_k[:, None], -1)[:, 0]
    logq_k = jnp.take_along_axis(logq_k_full, d_k[:, None], -1)[:, 0]
    acc_tau = accept_logratio(r_tau, logp_tau, logq_tau)
    acc_k = accept_logratio(r_k, logp_k, logq_k)
    acc = jnp.logical_and(acc_tau, acc_k)
    prefix = jnp.cumprod(acc.astype(jnp.int32))
    A = jnp.sum(prefix)
    all_acc = A == g
    Ac = jnp.minimum(A, g - 1)
    return VerifyResult(A, all_acc, ~acc_tau[Ac])
