"""Ogata thinning (Lewis & Shedler 1979; Ogata 1981).

Used for (a) simulating the paper's synthetic ground-truth processes
(App. B.1) and (b) as the classical sequential sampling baseline that
TPP-SD is structurally compared against (Sec. 4.1).

Host-side numpy: data simulation is a one-off preprocessing step.
Each process also exposes its analytic compensator Λ(a, b | history) for
the time-rescaling / KS evaluation (App. A.4).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np


class PointProcess:
    """Interface: conditional intensity + a local upper bound."""

    num_marks: int = 1

    def intensity(self, t: float, times: Sequence[float],
                  marks: Sequence[int]) -> np.ndarray:
        """Per-mark intensity vector at time t given strict history."""
        raise NotImplementedError

    def bound(self, t: float, times: Sequence[float],
              marks: Sequence[int]) -> float:
        """Upper bound of total intensity on [t, inf) given history."""
        raise NotImplementedError

    def compensator(self, a: float, b: float, times: Sequence[float],
                    marks: Sequence[int]) -> float:
        """Integral of the total intensity over (a, b] given history
        (history = events with t_i <= a; valid while no event in (a, b])."""
        raise NotImplementedError


@dataclass
class InhomPoisson(PointProcess):
    """lambda(t) = A (b + sin(omega * pi * t)); paper: A=5, b=1, w=1/50."""
    A: float = 5.0
    b: float = 1.0
    omega: float = 1.0 / 50.0
    num_marks: int = 1

    def intensity(self, t, times, marks):
        return np.array([self.A * (self.b + math.sin(self.omega * math.pi * t))])

    def bound(self, t, times, marks):
        return self.A * (self.b + 1.0)

    def compensator(self, a, b, times, marks):
        w = self.omega * math.pi
        return self.A * (self.b * (b - a)
                         + (math.cos(w * a) - math.cos(w * b)) / w)


@dataclass
class Hawkes(PointProcess):
    """lambda(t) = mu + sum alpha exp(-beta (t - t_i)); paper: 2.5, 1, 2."""
    mu: float = 2.5
    alpha: float = 1.0
    beta: float = 2.0
    num_marks: int = 1

    def intensity(self, t, times, marks):
        ts = np.asarray(times)
        ts = ts[ts < t]
        return np.array([self.mu
                         + self.alpha * np.exp(-self.beta * (t - ts)).sum()])

    def bound(self, t, times, marks):
        # intensity decays between events; value just after t bounds it
        return float(self.intensity(t + 1e-12, times, marks)[0]) + self.alpha

    def compensator(self, a, b, times, marks):
        ts = np.asarray(times)
        ts = ts[ts <= a]
        decay = (np.exp(-self.beta * (a - ts))
                 - np.exp(-self.beta * (b - ts))).sum()
        return self.mu * (b - a) + self.alpha / self.beta * decay


@dataclass
class MultiHawkes(PointProcess):
    """M-dimensional Hawkes (App. B.1 Multi-Hawkes)."""
    mu: np.ndarray = None
    alpha: np.ndarray = None   # alpha[i, j]: influence of mark j on mark i
    beta: np.ndarray = None

    def __post_init__(self):
        if self.mu is None:
            self.mu = np.array([0.4, 0.4])
            self.alpha = np.array([[1.0, 0.5], [0.1, 1.0]])
            self.beta = np.full((2, 2), 2.0)
        self.mu = np.asarray(self.mu, float)
        self.alpha = np.asarray(self.alpha, float)
        self.beta = np.asarray(self.beta, float)
        self.num_marks = len(self.mu)

    def intensity(self, t, times, marks):
        lam = self.mu.copy()
        for ti, ki in zip(times, marks):
            if ti < t:
                lam += self.alpha[:, ki] * np.exp(-self.beta[:, ki] * (t - ti))
        return lam

    def bound(self, t, times, marks):
        return float(self.intensity(t + 1e-12, times, marks).sum()
                     + self.alpha.max() * self.num_marks)

    def compensator(self, a, b, times, marks):
        out = self.mu.sum() * (b - a)
        for ti, ki in zip(times, marks):
            if ti <= a:
                d = (np.exp(-self.beta[:, ki] * (a - ti))
                     - np.exp(-self.beta[:, ki] * (b - ti)))
                out += (self.alpha[:, ki] / self.beta[:, ki] * d).sum()
        return out


def thinning_sample(proc: PointProcess, t_end: float,
                    rng: np.random.Generator,
                    t_start: float = 0.0,
                    max_events: int = 100_000) -> Tuple[np.ndarray, np.ndarray]:
    """Classical sequential thinning: one candidate per verify step."""
    times: List[float] = []
    marks: List[int] = []
    t = t_start
    while len(times) < max_events:
        lam_bar = proc.bound(t, times, marks)
        if lam_bar <= 0:
            break
        t = t + rng.exponential(1.0 / lam_bar)
        if t > t_end:
            break
        lam = proc.intensity(t, times, marks)
        total = lam.sum()
        if rng.uniform() < total / lam_bar:
            k = int(rng.choice(proc.num_marks, p=lam / total))
            times.append(t)
            marks.append(k)
    return np.asarray(times), np.asarray(marks, dtype=np.int64)


def simulate_dataset(proc: PointProcess, n_seqs: int, t_end: float,
                     seed: int = 0) -> List[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    return [thinning_sample(proc, t_end, rng) for _ in range(n_seqs)]


def rescaled_intervals(proc: PointProcess, times: np.ndarray,
                       marks: np.ndarray, t_start: float = 0.0) -> np.ndarray:
    """Time-rescaling theorem (App. A.4): z_i = Lambda(t_{i-1}, t_i) are
    iid Exp(1) when the intensity is correct."""
    zs = []
    prev = t_start
    hist_t: List[float] = []
    hist_k: List[int] = []
    for t, k in zip(times, marks):
        zs.append(proc.compensator(prev, float(t), hist_t, hist_k))
        hist_t.append(float(t))
        hist_k.append(int(k))
        prev = float(t)
    return np.asarray(zs)


def ground_truth_loglik(proc: PointProcess, times: np.ndarray,
                        marks: np.ndarray, t_end: float) -> float:
    """CIF-form log-likelihood (Eq. 1) under the true process."""
    ll = 0.0
    hist_t: List[float] = []
    hist_k: List[int] = []
    prev = 0.0
    for t, k in zip(times, marks):
        lam = proc.intensity(float(t), hist_t, hist_k)
        ll += math.log(max(lam[int(k)], 1e-300))
        ll -= proc.compensator(prev, float(t), hist_t, hist_k)
        hist_t.append(float(t))
        hist_k.append(int(k))
        prev = float(t)
    ll -= proc.compensator(prev, t_end, hist_t, hist_k)
    return ll
