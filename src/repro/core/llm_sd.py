"""Token-level speculative decoding for the architecture zoo.

The same propose-verify engine as TPP-SD restricted to the discrete
component — i.e. Leviathan et al. — applied to any model in
``repro.models.registry`` that exposes ``extend``/``prefill``.

Since the ``repro.serving`` redesign there is ONE serving code path:
these functions are thin batch-1 wrappers over ``ServingEngine``, so a
single request runs exactly the same batched draft/verify/rollback
round (with the batch dimension = 1) as production continuous-batching
traffic. Cache rollback strategies per family:

  - mask   : transformer / vlm / encdec — rollback-by-counter (O(1)).
  - replay : ssm / hybrid — recurrent states cannot be length-masked;
    the engine keeps the round's entry cache (a cheap checkpoint, held
    automatically because JAX caches are immutable values) and
    re-extends the accepted prefix. Cost: one extra draft-side forward
    of <= gamma tokens per round, amortized by acceptance.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class ServeStats(NamedTuple):
    tokens: jnp.ndarray
    n: int
    drafted: int
    accepted: int
    rounds: int


def _run_single(cfg_t, params_t, cfg_d, params_d, prompt, rng, *,
                method: str, max_new_tokens: int, gamma: int, max_len: int,
                temperature: float) -> ServeStats:
    from ..serving import ServeRequest, ServingEngine
    engine = ServingEngine(cfg_t, params_t, cfg_d, params_d, method=method,
                           max_batch=1, max_len=max_len, gamma=gamma)
    engine.submit(ServeRequest(prompt=prompt, max_new_tokens=max_new_tokens,
                               temperature=temperature, rng=rng))
    res = engine.run()[0]
    return ServeStats(jnp.asarray(res.tokens, jnp.int32), res.n,
                      res.drafted, res.accepted, res.rounds)


def serve_speculative(cfg_t, cfg_d, params_t, params_d, model_t, model_d,
                      prompt, rng, *, max_new_tokens: int, gamma: int,
                      max_len: int, temperature: float = 1.0) -> ServeStats:
    """Speculative serving of one sequence (batch-1 ``ServingEngine``).

    prompt: [P] int32. Returns generated tokens + accounting. The
    ``model_t``/``model_d`` arguments are accepted for backward
    compatibility; the engine resolves (and memoizes) the registry
    models from the configs.
    """
    del model_t, model_d
    return _run_single(cfg_t, params_t, cfg_d, params_d, prompt, rng,
                       method="sd", max_new_tokens=max_new_tokens,
                       gamma=gamma, max_len=max_len, temperature=temperature)


def serve_autoregressive(cfg, params, model, prompt, rng, *,
                         max_new_tokens: int, max_len: int,
                         temperature: float = 1.0) -> ServeStats:
    del model
    return _run_single(cfg, params, None, None, prompt, rng, method="ar",
                       max_new_tokens=max_new_tokens, gamma=1,
                       max_len=max_len, temperature=temperature)
