"""Token-level speculative decoding for the architecture zoo.

The same propose-verify engine as TPP-SD restricted to the discrete
component — i.e. Leviathan et al. — applied to any model in
``repro.models.registry`` that exposes ``extend``/``prefill``.

Cache rollback strategies per family:
  - mask   : transformer / vlm / encdec — rollback-by-counter (O(1)).
  - replay : ssm / hybrid — recurrent states cannot be length-masked; we
    keep the round's entry cache (a cheap O(d_state) checkpoint, held
    automatically because JAX caches are immutable values) and re-extend
    the accepted prefix. Cost: one extra draft-side forward of <= gamma
    tokens per round, amortized by acceptance.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import registry
from ..models import transformer as tfm
from ..models import encdec as edc
from . import speculative as spec

_MASK_FAMILIES = {"dense", "moe", "vlm"}

# jit wrappers cached by callable identity so repeated serve calls with the
# same model bundle reuse compilations
_JIT_CACHE = {}


def _jit(fn):
    key = id(fn)
    if key not in _JIT_CACHE:
        _JIT_CACHE[key] = (fn, jax.jit(fn))
    return _JIT_CACHE[key][1]


def _jit_prefill(fn, max_len: int):
    key = (id(fn), max_len)
    if key not in _JIT_CACHE:
        _JIT_CACHE[key] = (fn, jax.jit(
            lambda params, batch: fn(params, batch, max_len)))
    return _JIT_CACHE[key][1]


class ServeStats(NamedTuple):
    tokens: jnp.ndarray
    n: int
    drafted: int
    accepted: int
    rounds: int


def _rollback(cfg, model, params, cache_before, cache_after, tokens_committed):
    if cfg.family in _MASK_FAMILIES:
        return tfm.rollback(cache_after,
                            cache_before["len"] + tokens_committed.shape[0])
    if cfg.family == "encdec":
        new_len = cache_before["len"] + tokens_committed.shape[0]
        out = dict(cache_after)
        out["pos"] = jnp.where(cache_after["pos"] < new_len,
                               cache_after["pos"], jnp.iinfo(jnp.int32).max)
        out["len"] = jnp.asarray(new_len, jnp.int32)
        return out
    # replay: recompute states from the round-entry checkpoint
    if tokens_committed.shape[0] == 0:
        return cache_before
    _, cache = _jit(model.extend)(params, cache_before,
                                  tokens_committed[None, :])
    return cache


def serve_speculative(cfg_t, cfg_d, params_t, params_d, model_t, model_d,
                      prompt, rng, *, max_new_tokens: int, gamma: int,
                      max_len: int, temperature: float = 1.0) -> ServeStats:
    """Host-loop speculative serving of one sequence (batch dim = 1).

    prompt: [P] int32. Returns generated tokens + accounting.
    """
    def logp(logits):
        return jax.nn.log_softmax(logits / temperature, axis=-1)

    # prefill both models on the prompt
    prefill_t = _jit_prefill(model_t.prefill, max_len)
    prefill_d = _jit_prefill(model_d.prefill, max_len)
    lt, cache_t = prefill_t(params_t, {"tokens": prompt[None, :]})
    ld, cache_d = prefill_d(params_d, {"tokens": prompt[None, :]})
    lp_last = logp(lt[0, -1])
    lp_last_d = logp(ld[0, -1])
    out = []
    drafted = accepted = rounds = 0

    extend_t = _jit(model_t.extend)
    extend_d = _jit(model_d.extend)

    while len(out) < max_new_tokens:
        rounds += 1
        rng, r_d, r_v, r_a, r_b = jax.random.split(rng, 5)
        # ---- draft gamma tokens autoregressively (from the DRAFT's dist)
        cache_d_in = cache_d
        d_toks, d_logps = [], []
        lp_d = lp_last_d
        cd = cache_d
        for i in range(gamma):
            tok = int(jax.random.categorical(jax.random.fold_in(r_d, i),
                                             lp_d))
            d_toks.append(tok)
            d_logps.append(lp_d)
            ldd, cd = extend_d(params_d, cd, jnp.array([[tok]], jnp.int32))
            lp_d = logp(ldd[0, -1])
        d_toks_a = jnp.array(d_toks, jnp.int32)
        # ---- verify in one target forward
        lt, cache_t_after = extend_t(params_t, cache_t,
                                     d_toks_a[None, :])
        lp_t_all = jnp.concatenate([lp_last[None], logp(lt[0])], axis=0)
        # accept tests
        A = 0
        for i, tok in enumerate(d_toks):
            u = jax.random.uniform(jax.random.fold_in(r_v, i), ())
            if float(jnp.log(u)) < float(lp_t_all[i, tok]
                                         - d_logps[i][tok]):
                A += 1
            else:
                break
        drafted += gamma
        accepted += A
        committed = list(d_toks[:A])
        if A == gamma:  # bonus token from the target's extra distribution
            bonus = int(jax.random.categorical(r_b, lp_t_all[gamma]))
            committed.append(bonus)
        else:
            tok_adj = int(spec.adjusted_discrete(r_a, lp_t_all[A],
                                                 d_logps[A]))
            committed.append(tok_adj)
        # ---- commit + rollback
        comm = jnp.array(committed[:-1], jnp.int32)  # in target cache already
        cache_t = _rollback(cfg_t, model_t, params_t, cache_t, cache_t_after,
                            comm)
        cache_d = _rollback(cfg_d, model_d, params_d, cache_d_in, cd, comm)
        # ingest the final committed token into both caches to obtain lp_last
        last = jnp.array([[committed[-1]]], jnp.int32)
        lt2, cache_t = extend_t(params_t, cache_t, last)
        ld2, cache_d = extend_d(params_d, cache_d, last)
        lp_last = logp(lt2[0, -1])
        lp_last_d = logp(ld2[0, -1])
        out.extend(committed)
    toks = jnp.array(out[:max_new_tokens], jnp.int32)
    return ServeStats(toks, len(out[:max_new_tokens]), drafted, accepted,
                      rounds)


def serve_autoregressive(cfg, params, model, prompt, rng, *,
                         max_new_tokens: int, max_len: int,
                         temperature: float = 1.0) -> ServeStats:
    lt, cache = model.prefill(params, {"tokens": prompt[None, :]}, max_len)
    extend = _jit(model.extend)
    lp = jax.nn.log_softmax(lt[0, -1] / temperature)
    out = []
    for i in range(max_new_tokens):
        rng, r = jax.random.split(rng)
        tok = int(jax.random.categorical(r, lp))
        out.append(tok)
        lt, cache = extend(params, cache, jnp.array([[tok]], jnp.int32))
        lp = jax.nn.log_softmax(lt[0, -1] / temperature)
    return ServeStats(jnp.array(out, jnp.int32), len(out), 0, 0,
                      max_new_tokens)
