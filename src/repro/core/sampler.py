"""TPP samplers: naive autoregressive (Sec. 4.2) and TPP-SD (Sec. 4.3).

Two execution styles for each:

  - ``*_host``: the paper-faithful host loop (one device sync per event /
    per propose-verify round, as in the paper's PyTorch implementation).
  - ``*_jit``:  the TPU-adapted sampler — the whole loop lives inside one
    ``lax.while_loop`` (fixed shapes, cache rollback by counter), so a
    full sequence is one device call, and ``jax.vmap`` batches whole
    sequences with per-lane lengths. This is the beyond-paper fast path
    recorded separately in EXPERIMENTS.md §Perf.

All samplers operate on a single sequence; batch via vmap.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..models import tpp
from . import speculative as spec


class SampleResult(NamedTuple):
    times: jnp.ndarray     # [max_events]
    types: jnp.ndarray     # [max_events]
    n: jnp.ndarray         # valid count (times <= t_end)
    drafted: jnp.ndarray   # events proposed by the draft model
    accepted: jnp.ndarray  # drafted events accepted by verification
    rounds: jnp.ndarray    # propose-verify rounds (== target forwards)


def _bos(cfg):
    return jnp.float32(0.0), jnp.int32(cfg.num_marks)


def _sample_event(cfg, params, rng, h, t_cur):
    r1, r2 = jax.random.split(rng)
    mix = tpp.interval_params(cfg, params, h)
    tau = tpp.sample_interval(r1, mix)
    logits = tpp.type_logits(cfg, params, h)
    k = jax.random.categorical(r2, logits)
    return t_cur + tau, k.astype(jnp.int32)


# ---------------------------------------------------------------------------
# autoregressive sampling
# ---------------------------------------------------------------------------

class _ARState(NamedTuple):
    times: jnp.ndarray
    types: jnp.ndarray
    n: jnp.ndarray
    t_last: jnp.ndarray
    h: jnp.ndarray
    cache: dict
    rng: jnp.ndarray


@functools.partial(jax.jit, static_argnums=(0, 3, 4))
def sample_ar_jit(cfg, params, rng, t_end: float, max_events: int
                  ) -> SampleResult:
    t0, k0 = _bos(cfg)
    cache = tpp.init_cache(cfg, max_events + 2)
    h, cache = tpp.extend(cfg, params, cache, t0[None], k0[None])

    def cond(s: _ARState):
        return jnp.logical_and(s.t_last < t_end, s.n < max_events)

    def body(s: _ARState):
        rng, r = jax.random.split(s.rng)
        t_new, k_new = _sample_event(cfg, params, r, s.h, s.t_last)
        h, cache = tpp.extend(cfg, params, s.cache, t_new[None], k_new[None])
        times = s.times.at[s.n].set(t_new)
        types = s.types.at[s.n].set(k_new)
        return _ARState(times, types, s.n + 1, t_new, h[0], cache, rng)

    init = _ARState(jnp.zeros((max_events,), jnp.float32),
                    jnp.zeros((max_events,), jnp.int32),
                    jnp.int32(0), t0, h[0], cache, rng)
    s = lax.while_loop(cond, body, init)
    valid = jnp.sum((jnp.arange(max_events) < s.n)
                    & (s.times <= t_end)).astype(jnp.int32)
    return SampleResult(s.times, s.types, valid, jnp.int32(0), jnp.int32(0),
                        s.n)


def sample_ar_host(cfg, params, rng, t_end: float, max_events: int
                   ) -> SampleResult:
    """Paper-style host loop: one jitted model call (and one host sync)
    per generated event."""
    extend = jax.jit(lambda c, t, k: tpp.extend(cfg, params, c, t, k))
    sample = jax.jit(lambda r, h, t: _sample_event(cfg, params, r, h, t))
    t0, k0 = _bos(cfg)
    cache = tpp.init_cache(cfg, max_events + 2)
    h, cache = extend(cache, t0[None], k0[None])
    times, types = [], []
    t_last = 0.0
    steps = 0
    while t_last < t_end and len(times) < max_events:
        rng, r = jax.random.split(rng)
        t_new, k_new = sample(r, h[0], jnp.float32(t_last))
        t_new = float(t_new)
        h, cache = extend(cache, jnp.float32(t_new)[None],
                          jnp.int32(k_new)[None])
        times.append(t_new)
        types.append(int(k_new))
        t_last = t_new
        steps += 1
    times_a = jnp.zeros((max_events,), jnp.float32)
    types_a = jnp.zeros((max_events,), jnp.int32)
    keep = [(t, k) for t, k in zip(times, types) if t <= t_end]
    n = len(keep)
    if n:
        times_a = times_a.at[:n].set(jnp.array([t for t, _ in keep]))
        types_a = types_a.at[:n].set(jnp.array([k for _, k in keep]))
    return SampleResult(times_a, types_a, jnp.int32(n), jnp.int32(0),
                        jnp.int32(0), jnp.int32(steps))


# ---------------------------------------------------------------------------
# TPP-SD (Algorithm 1)
# ---------------------------------------------------------------------------

class _SDState(NamedTuple):
    times: jnp.ndarray
    types: jnp.ndarray
    n: jnp.ndarray
    t_pend: jnp.ndarray
    k_pend: jnp.ndarray
    cache_t: dict
    cache_d: dict
    rng: jnp.ndarray
    drafted: jnp.ndarray
    accepted: jnp.ndarray
    rounds: jnp.ndarray


def _draft_window(cfg_d, params_d, rng, cache_d, t_pend, k_pend, gamma):
    """Draft gamma events autoregressively; record densities (Alg.1 l.4-6).

    The pending event is ingested first (it is committed but not yet in
    either cache).
    """
    h, cache_d = tpp.extend(cfg_d, params_d, cache_d, t_pend[None],
                            k_pend[None])

    def step(carry, r):
        h, cache_d, t_cur = carry
        r1, r2 = jax.random.split(r)
        mix = tpp.interval_params(cfg_d, params_d, h)
        tau = tpp.sample_interval(r1, mix)
        logits = jax.nn.log_softmax(tpp.type_logits(cfg_d, params_d, h))
        k = jax.random.categorical(r2, logits).astype(jnp.int32)
        t_new = t_cur + tau
        h2, cache_d = tpp.extend(cfg_d, params_d, cache_d, t_new[None],
                                 k[None])
        out = (tau, k, t_new, mix.log_w, mix.mu, mix.sigma, logits)
        return (h2[0], cache_d, t_new), out

    (h_last, cache_d, _), outs = lax.scan(
        step, (h[0], cache_d, t_pend), jax.random.split(rng, gamma))
    d_tau, d_k, d_t, d_logw, d_mu, d_sigma, d_logits = outs
    d_mix = tpp.MixParams(d_logw, d_mu, d_sigma)
    return cache_d, d_tau, d_k, d_t, d_mix, d_logits


def _sd_round(cfg_t, cfg_d, params_t, params_d, gamma, s: _SDState
              ) -> _SDState:
    rng, r_draft, r_ver, r_new1, r_new2, r_new3 = jax.random.split(s.rng, 6)
    # --- draft ---
    cache_d, d_tau, d_k, d_t, d_mix, d_logits = _draft_window(
        cfg_d, params_d, r_draft, s.cache_d, s.t_pend, s.k_pend, gamma)
    # --- verify: target processes pending + drafts in ONE parallel forward
    ver_t = jnp.concatenate([s.t_pend[None], d_t])
    ver_k = jnp.concatenate([s.k_pend[None], d_k])
    h_t, cache_t = tpp.extend(cfg_t, params_t, s.cache_t, ver_t, ver_k)
    mix_t_all = tpp.interval_params(cfg_t, params_t, h_t)     # [g+1, M]
    logits_t_all = jax.nn.log_softmax(
        tpp.type_logits(cfg_t, params_t, h_t))                # [g+1, K]
    mix_hist = jax.tree.map(lambda x: x[:gamma], mix_t_all)
    res = spec.verify_events(r_ver, d_tau, d_k,
                             tpp.interval_logpdf(d_mix, d_tau), d_logits,
                             mix_hist, logits_t_all[:gamma])
    A, all_acc = res.num_accepted, res.all_accepted
    Ac = jnp.minimum(A, gamma - 1)

    # --- replacement / bonus event from h at the first non-accepted slot
    mix_A = jax.tree.map(lambda x: x[A], mix_t_all)
    logits_A = logits_t_all[A]
    d_mix_A = jax.tree.map(lambda x: x[Ac], d_mix)
    tau_adj = spec.adjusted_continuous(r_new1, mix_A, d_mix_A)
    tau_direct = tpp.sample_interval(r_new2, mix_A)
    new_tau = jnp.where(all_acc, tau_direct,
                        jnp.where(res.tau_rejected, tau_adj, d_tau[Ac]))
    k_adj = spec.adjusted_discrete(r_new3, logits_A, d_logits[Ac])
    k_direct = jax.random.categorical(jax.random.fold_in(r_new3, 1),
                                      logits_A).astype(jnp.int32)
    new_k = jnp.where(all_acc | res.tau_rejected, k_direct,
                      k_adj.astype(jnp.int32))
    base_t = jnp.where(A > 0, d_t[jnp.maximum(A - 1, 0)], s.t_pend)
    new_t = base_t + new_tau

    # --- commit accepted prefix + the new event
    g_idx = jnp.arange(gamma)
    idx = s.n + g_idx
    times = s.times.at[idx].set(
        jnp.where(g_idx < A, d_t, s.times[idx]))
    types = s.types.at[idx].set(
        jnp.where(g_idx < A, d_k, s.types[idx]))
    times = times.at[s.n + A].set(new_t)
    types = types.at[s.n + A].set(new_k)
    n_new = s.n + A + 1

    # --- cache rollback (mask-by-counter; cache length invariant == n)
    cache_t = tpp.rollback(cache_t, n_new)
    cache_d = tpp.rollback(cache_d, n_new)
    return _SDState(times, types, n_new, new_t, new_k, cache_t, cache_d,
                    rng, s.drafted + gamma, s.accepted + A, s.rounds + 1)


@functools.partial(jax.jit, static_argnums=(0, 1, 4, 5, 6))
def sample_sd_jit(cfg_t, cfg_d, params_t, params_d, t_end: float,
                  gamma: int, max_events: int, rng=None) -> SampleResult:
    t0, k0 = _bos(cfg_t)
    cache_size = max_events + gamma + 2
    init = _SDState(
        jnp.zeros((max_events + gamma + 1,), jnp.float32),
        jnp.zeros((max_events + gamma + 1,), jnp.int32),
        jnp.int32(0), t0, k0,
        tpp.init_cache(cfg_t, cache_size), tpp.init_cache(cfg_d, cache_size),
        rng, jnp.int32(0), jnp.int32(0), jnp.int32(0))

    def cond(s: _SDState):
        return jnp.logical_and(s.t_pend < t_end, s.n < max_events)

    body = functools.partial(_sd_round, cfg_t, cfg_d, params_t, params_d,
                             gamma)
    s = lax.while_loop(cond, body, init)
    E = s.times.shape[0]
    n_eff = jnp.minimum(s.n, max_events)
    valid = jnp.sum((jnp.arange(E) < n_eff) & (s.times <= t_end)
                    ).astype(jnp.int32)
    return SampleResult(s.times[:max_events], s.types[:max_events], valid,
                        s.drafted, s.accepted, s.rounds)


def sample_sd_host(cfg_t, cfg_d, params_t, params_d, rng, t_end: float,
                   gamma: int, max_events: int) -> SampleResult:
    """Paper-faithful host loop: one device sync per propose-verify round."""
    round_fn = jax.jit(functools.partial(_sd_round, cfg_t, cfg_d, params_t,
                                         params_d, gamma))
    t0, k0 = _bos(cfg_t)
    cache_size = max_events + gamma + 2
    s = _SDState(
        jnp.zeros((max_events + gamma + 1,), jnp.float32),
        jnp.zeros((max_events + gamma + 1,), jnp.int32),
        jnp.int32(0), t0, k0,
        tpp.init_cache(cfg_t, cache_size), tpp.init_cache(cfg_d, cache_size),
        rng, jnp.int32(0), jnp.int32(0), jnp.int32(0))
    while float(s.t_pend) < t_end and int(s.n) < max_events:
        s = round_fn(s)
    E = s.times.shape[0]
    n_eff = jnp.minimum(s.n, max_events)
    valid = jnp.sum((jnp.arange(E) < n_eff) & (s.times <= t_end)
                    ).astype(jnp.int32)
    return SampleResult(s.times[:max_events], s.types[:max_events], valid,
                        s.drafted, s.accepted, s.rounds)


# ---------------------------------------------------------------------------
# batched sampling (beyond-paper): vmap whole samplers over a seed batch
# ---------------------------------------------------------------------------

def sample_ar_batch(cfg, params, rng, t_end: float, max_events: int,
                    batch: int) -> SampleResult:
    rngs = jax.random.split(rng, batch)
    fn = lambda r: sample_ar_jit(cfg, params, r, t_end, max_events)
    return jax.vmap(fn)(rngs)


def sample_sd_batch(cfg_t, cfg_d, params_t, params_d, rng, t_end: float,
                    gamma: int, max_events: int, batch: int) -> SampleResult:
    rngs = jax.random.split(rng, batch)
    fn = lambda r: sample_sd_jit(cfg_t, cfg_d, params_t, params_d, t_end,
                                 gamma, max_events, rng=r)
    return jax.vmap(fn)(rngs)
