"""DEPRECATED: thin shims over ``repro.sampling``.

The ``sample_{ar,sd}_{host,jit,batch}`` function zoo moved into the
config-driven engine::

    from repro.sampling import SamplerSpec, build_sampler
    fn = build_sampler(SamplerSpec(method="sd", execution="vmap",
                                   t_end=t_end, gamma=gamma,
                                   max_events=max_events, batch=B),
                       cfg_t, params_t, cfg_d, params_d)
    batch = fn(rng)   # SampleBatch: [B, E] + acceptance stats

These wrappers keep the old signatures (and rng streams) alive for
existing callers and will be removed once nothing imports them.
"""
from __future__ import annotations

import warnings

import jax

from ..sampling import loops as _loops
from ..sampling.result import SeqResult as SampleResult  # noqa: F401 (bc)

# Backward-compatible aliases for code that reached into the internals.
# Resolved lazily (PEP 562): this module can be imported while
# ``sampling.loops`` is still mid-initialization in the core<->sampling
# import cycle.
_LAZY_ALIASES = {
    "_ARState": "ARState", "_SDState": "SDState", "_sd_round": "sd_round",
    "_draft_window": "draft_window", "_sample_event": "sample_event",
    "_bos": "bos_event",
}


def __getattr__(name):
    if name in _LAZY_ALIASES:
        return getattr(_loops, _LAZY_ALIASES[name])
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def _warn(old: str, spec_hint: str):
    warnings.warn(
        f"repro.core.sampler.{old} is deprecated; use "
        f"repro.sampling.build_sampler(SamplerSpec({spec_hint}), ...)",
        DeprecationWarning, stacklevel=3)


def sample_ar_jit(cfg, params, rng, t_end: float, max_events: int
                  ) -> SampleResult:
    _warn("sample_ar_jit", "method='ar', execution='jit'")
    return _loops.run_ar_device(cfg, params, rng, t_end, max_events)


def sample_ar_host(cfg, params, rng, t_end: float, max_events: int
                   ) -> SampleResult:
    _warn("sample_ar_host", "method='ar', execution='host'")
    return _loops.run_ar_host(cfg, params, rng, t_end, max_events)


def sample_sd_jit(cfg_t, cfg_d, params_t, params_d, t_end: float,
                  gamma: int, max_events: int, rng=None) -> SampleResult:
    _warn("sample_sd_jit", "method='sd', execution='jit'")
    if rng is None:  # the old default crashed at trace time; default safely
        rng = jax.random.PRNGKey(0)
    return _loops.run_sd_device(cfg_t, cfg_d, params_t, params_d, rng,
                                t_end, gamma, max_events)


def sample_sd_host(cfg_t, cfg_d, params_t, params_d, rng, t_end: float,
                   gamma: int, max_events: int) -> SampleResult:
    _warn("sample_sd_host", "method='sd', execution='host'")
    return _loops.run_sd_host(cfg_t, cfg_d, params_t, params_d, rng, t_end,
                              gamma, max_events)


def sample_ar_batch(cfg, params, rng, t_end: float, max_events: int,
                    batch: int) -> SampleResult:
    _warn("sample_ar_batch", "method='ar', execution='vmap'")
    rngs = jax.random.split(rng, batch)
    fn = lambda r: _loops.run_ar_device(cfg, params, r, t_end, max_events)
    return jax.vmap(fn)(rngs)


def sample_sd_batch(cfg_t, cfg_d, params_t, params_d, rng, t_end: float,
                    gamma: int, max_events: int, batch: int) -> SampleResult:
    _warn("sample_sd_batch", "method='sd', execution='vmap'")
    rngs = jax.random.split(rng, batch)
    fn = lambda r: _loops.run_sd_device(cfg_t, cfg_d, params_t, params_d, r,
                                        t_end, gamma, max_events)
    return jax.vmap(fn)(rngs)
