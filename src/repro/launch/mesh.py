"""Production mesh builders.

Functions (not module-level constants) so importing this module never
touches JAX device state; ``dryrun.py`` sets the host-device XLA flag
before calling them.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e: 16x16 = 256 chips/pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(devices, axes)


def make_serving_mesh(kv: int = 8):
    """Serving re-axing of the SAME 256 chips: (data, kv, tp).

    GQA decode wants the KV cache sharded over kv_heads; with the flat
    16-way `model` axis and kv_heads=8 the divisibility fallback
    replicates the cache and GSPMD re-shards + gathers it every step
    (see EXPERIMENTS.md §Perf pair 3). Splitting the model axis into
    (kv=8, tp=2) makes kv_heads shardable natively."""
    shape = (16, kv, 16 // kv)
    devices = np.asarray(jax.devices()[:256]).reshape(shape)
    return jax.sharding.Mesh(devices, ("data", "kv", "tp"))


SERVING_RULES = {
    "batch": ("data",),
    "p_embed": (),                 # no FSDP at serve time
    "vocab": ("kv", "tp"),
    "heads": ("kv", "tp"),
    "kv_heads": ("kv",),
    "qkv": (),
    "mlp": ("kv", "tp"),
    "experts": ("kv", "tp"),
    "inner": ("kv", "tp"),
}


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small mesh over forced host devices for sharding unit tests."""
    n = data * model
    devices = np.asarray(jax.devices()[:n]).reshape(data, model)
    return jax.sharding.Mesh(devices, ("data", "model"))


def resolve_sample_mesh():
    """The mesh ``execution="sharded"`` sampling uses when none is given.

    Whole-sequence fan-out wants the data axis as large as the visible
    device set allows:

      >= 256 devices : the production pod mesh (16 data x 16 model)
      >= 4, even     : ``make_debug_mesh(data=n//2, model=2)`` — the
                       forced-host-device shape the sharding tests use
      otherwise      : every device on a (n, 1) (data, model) mesh, so
                       the same logical-axis rules apply degenerately
                       (model-sharded params stay whole on 1 device)
    """
    n = jax.device_count()
    if n >= 256:
        return make_production_mesh()
    if n >= 4 and n % 2 == 0:
        return make_debug_mesh(data=n // 2, model=2)
    devices = np.asarray(jax.devices()).reshape(n, 1)
    return jax.sharding.Mesh(devices, ("data", "model"))


def resolve_serving_mesh():
    """The mesh sharded serving uses when none is given: the kv-axis
    serving mesh when a full pod is visible, else the same fallback as
    sampling (``resolve_sample_mesh``)."""
    if jax.device_count() >= 256:
        return make_serving_mesh()
    return resolve_sample_mesh()


def serving_rules_for(mesh):
    """Logical-axis rules for serving on ``mesh``.

    On a serving mesh (a "kv" axis is present) the ``SERVING_RULES``
    re-axing applies — KV-cache head axes shard over the kv axis so GQA
    decode never regathers the cache. On data/model meshes the default
    rules apply with FSDP off (params are read-only at serve time; the
    slot axis maps to "data" through the "batch" rule either way).
    """
    from ..distributed.sharding import Rules
    if "kv" in mesh.axis_names:
        return Rules(mesh, rules=SERVING_RULES, fsdp=False)
    return Rules(mesh, fsdp=False)
