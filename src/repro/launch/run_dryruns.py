"""Orchestrate the full dry-run sweep: every (arch x shape x mesh) as a
separate subprocess (fresh XLA device state per combo), JSON per combo,
skipping combos whose JSON already exists.

  PYTHONPATH=src python -m repro.launch.run_dryruns --outdir results/dryrun
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCH_ORDER = [
    "llama3.2-1b", "granite-moe-1b-a400m", "seamless-m4t-medium",
    "falcon-mamba-7b", "recurrentgemma-9b", "mistral-nemo-12b",
    "internvl2-26b", "qwen2.5-32b", "phi3.5-moe-42b-a6.6b", "llama3-405b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="results/dryrun")
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--archs", default=",".join(ARCH_ORDER))
    ap.add_argument("--shapes", default=",".join(SHAPES))
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)
    combos = [(a, s, m)
              for m in args.meshes.split(",")
              for a in args.archs.split(",")
              for s in args.shapes.split(",")]
    for arch, shape, mesh in combos:
        tag = f"{arch}_{shape}_{mesh}".replace(".", "_")
        out = os.path.join(args.outdir, tag + ".json")
        if os.path.exists(out):
            print(f"skip {tag}")
            continue
        t0 = time.time()
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", mesh, "--out", out]
        print(f"RUN {tag} ...", flush=True)
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout,
                               env={**os.environ, "PYTHONPATH": "src"})
            if r.returncode != 0:
                with open(out, "w") as f:
                    json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                               "ok": False,
                               "error": r.stderr[-4000:]}, f, indent=2)
                print(f"FAIL {tag} ({time.time()-t0:.0f}s)", flush=True)
                print(r.stderr[-1500:], flush=True)
            else:
                print(f"OK   {tag} ({time.time()-t0:.0f}s)", flush=True)
        except subprocess.TimeoutExpired:
            with open(out, "w") as f:
                json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                           "ok": False, "error": "timeout"}, f, indent=2)
            print(f"TIMEOUT {tag}", flush=True)


if __name__ == "__main__":
    main()
