"""ShapeDtypeStruct input stand-ins for every (arch x input-shape) combo.

No device allocation: everything here is abstract (weak-type-correct,
shardable). Decode shapes build the serve-step cache struct; the audio /
VLM modality frontends are stubs that provide embedding-shaped inputs.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..configs.shapes import InputShape
from ..models import registry

S = jax.ShapeDtypeStruct


def serving_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Apply the long-context sub-quadratic variant where required."""
    if shape.name == "long_500k" and cfg.family in ("dense", "moe", "vlm",
                                                    "encdec"):
        # sliding-window decode variant (DESIGN.md long_500k policy)
        return cfg.replace(sliding_window=cfg.long_context_window)
    return cfg


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict:
    """Abstract model inputs for the step selected by ``shape.kind``."""
    B, L = shape.global_batch, shape.seq_len
    tok = lambda b, s: S((b, s), jnp.int32)
    if shape.kind == "train":
        batch = {"tokens": tok(B, L), "labels": tok(B, L)}
        if cfg.family == "vlm":
            P = cfg.vision_prefix_len
            batch = {"tokens": tok(B, L - P), "labels": tok(B, L - P),
                     "vision_embeds": S((B, P, cfg.d_model), jnp.bfloat16)}
        if cfg.family == "encdec":
            Se = min(L // cfg.enc_seq_divisor, cfg.max_enc_len)
            batch["enc_frames"] = S((B, Se, cfg.d_model), jnp.bfloat16)
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {"tokens": tok(B, L)}
        if cfg.family == "vlm":
            P = cfg.vision_prefix_len
            batch = {"tokens": tok(B, L - P),
                     "vision_embeds": S((B, P, cfg.d_model), jnp.bfloat16)}
        if cfg.family == "encdec":
            Se = min(L // cfg.enc_seq_divisor, cfg.max_enc_len)
            batch["enc_frames"] = S((B, Se, cfg.d_model), jnp.bfloat16)
        return {"batch": batch}
    # decode: ONE new token against a seq_len cache
    scfg = serving_config(cfg, shape)
    model = registry.get_model(scfg)
    kw = {}
    if scfg.family == "encdec":
        kw["enc_len"] = min(L // scfg.enc_seq_divisor, scfg.max_enc_len)
    cache = jax.eval_shape(lambda: model.init_cache(B, L, **kw))
    return {"cache": cache, "tokens": tok(B, 1)}


def abstract_state(cfg: ModelConfig) -> Tuple:
    """(params, adam mu, adam nu) shape trees."""
    params = registry.abstract_params(cfg)
    return params
