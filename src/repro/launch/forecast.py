"""Forecast launcher: long-horizon quantile forecasts at fan-out scale.

Drives ``repro.forecast`` end-to-end: one observed event history fans
out into ``--rollouts`` Monte-Carlo continuations through the serving
engine in pool-sized waves (copy-on-write KV forks + the "grouped"
admission policy), the on-device aggregator reduces them to per-bin
count quantiles, and the headline metric is rollouts/s.

  PYTHONPATH=src python -m repro.launch.forecast --horizon 8 \
      --rollouts 1000 --bins 16 --quantiles 0.1,0.5,0.9
  PYTHONPATH=src python -m repro.launch.forecast --method ar \
      --rollouts 200 --n-pages 48    # pool holds ~one wave: many waves
"""
from __future__ import annotations

import argparse

import numpy as np
import jax

from ..configs.base import TPPConfig
from ..forecast import build_forecaster
from ..models import tpp
from ..sampling import ForecastSpec, SamplerSpec


def synth_history(n: int, num_marks: int, seed: int = 0):
    """A deterministic synthetic observed history: exponential(1)
    inter-event times, uniform marks."""
    r = np.random.default_rng(seed)
    times = np.cumsum(r.exponential(1.0, size=n)).astype(np.float32)
    marks = r.integers(0, num_marks, size=n).astype(np.int32)
    return times, marks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="sd", choices=["sd", "ar"])
    ap.add_argument("--encoder", default="thp", choices=["thp", "sahp"])
    ap.add_argument("--gamma", type=int, default=4)
    ap.add_argument("--draft-layers", type=int, default=1)
    ap.add_argument("--horizon", type=float, default=8.0,
                    help="forecast window beyond the last observed event")
    ap.add_argument("--rollouts", type=int, default=1000,
                    help="Monte-Carlo continuations of the history")
    ap.add_argument("--bins", type=int, default=16,
                    help="time bins the horizon splits into")
    ap.add_argument("--quantiles", default="0.1,0.25,0.5,0.75,0.9",
                    help="CSV of per-bin count quantile levels")
    ap.add_argument("--history", type=int, default=12,
                    help="length of the synthetic observed history")
    ap.add_argument("--max-events", dest="max_events", type=int, default=48,
                    help="per-rollout event budget")
    ap.add_argument("--max-batch", dest="max_batch", type=int, default=8,
                    help="engine slots = per-wave fan-out ceiling")
    ap.add_argument("--n-pages", dest="n_pages", type=int, default=None,
                    help="paged-pool size; small values force more, "
                         "smaller waves (None = fully provisioned)")
    ap.add_argument("--kernel", default="auto",
                    choices=["auto", "pallas", "ref"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    qs = tuple(float(q) for q in args.quantiles.split(","))
    cfg_t = TPPConfig(name="fc-t", encoder=args.encoder, num_layers=4,
                      num_heads=2, d_model=32, d_ff=64, num_marks=5,
                      num_mix=16)
    pt = tpp.init_params(cfg_t, jax.random.PRNGKey(0))
    cfg_d = pd = None
    if args.method == "sd":
        cfg_d = cfg_t.replace(name="fc-d", num_layers=args.draft_layers,
                              num_heads=1)
        pd = tpp.init_params(cfg_d, jax.random.PRNGKey(1))

    spec = SamplerSpec(
        domain="tpp", method=args.method, gamma=args.gamma,
        kernel=args.kernel, batch=args.max_batch,
        max_events=args.max_events,
        max_len=args.history + args.max_events + args.gamma + 1,
        forecast=ForecastSpec(horizon=args.horizon,
                              n_rollouts=args.rollouts, bins=args.bins,
                              quantiles=qs))
    fc = build_forecaster(spec, cfg_t, pt, cfg_d, pd,
                          n_pages=args.n_pages)
    times, marks = synth_history(args.history, cfg_t.num_marks, args.seed)

    print(f"forecasting {cfg_t.name} ({args.encoder}, "
          f"method={args.method}, gamma={args.gamma}) | history "
          f"n={args.history} t_last={times[-1]:.2f} | horizon "
          f"{args.horizon} x {args.bins} bins | {args.rollouts} rollouts "
          f"on max_batch={args.max_batch} "
          f"n_pages={args.n_pages or 'full'}")
    res = fc(times, marks, rng=args.seed)
    print(res.describe())

    edges = res.bin_edges
    hdr = "bin".ljust(18) + "".join(f"q{q:g}".rjust(7) for q in qs) \
        + "mean".rjust(8)
    print(hdr)
    print("-" * len(hdr))
    for b in range(args.bins):
        row = f"({edges[b]:6.2f},{edges[b + 1]:6.2f}]".ljust(18)
        row += "".join(str(int(res.quantiles[i, b])).rjust(7)
                       for i in range(len(qs)))
        row += f"{res.mean[b]:8.2f}"
        print(row)

    st = fc.engine.stats()
    sharing = (sum(st.group_member_rounds.values())
               / max(1, sum(st.group_forwards.values())))
    print(f"rollouts/s={res.rollouts_per_sec:.1f} | waves={res.n_waves} "
          f"sizes={res.wave_sizes} | events={res.events} | "
          f"events/target-forward="
          f"{res.events / max(1, st.target_forwards):.2f} | "
          f"group sharing={sharing:.2f} | "
          f"prefix hit tokens={st.prefix_hit_tokens}")


if __name__ == "__main__":
    main()
