"""Training launcher for the architecture zoo.

Runs real steps of a (reduced or full) architecture on synthetic token
data. On this CPU container use ``--smoke`` (reduced config, real
optimization); the full configs are exercised via ``dryrun.py``.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 20 --batch 2 --seq 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, get_arch, smoke_variant
from ..models import registry
from ..train import optimizer as opt
from ..train.trainer import make_train_step


def synthetic_batch(cfg, rng, batch, seq):
    # distinct fold_in stream per draw: reusing one key would correlate
    # the vision/encoder noise with the token stream
    toks = jax.random.randint(
        jax.random.fold_in(rng, 0), (batch, seq), 0, cfg.vocab_size)
    out = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.family == "vlm":
        out["vision_embeds"] = jax.random.normal(
            jax.random.fold_in(rng, 1),
            (batch, cfg.vision_prefix_len, cfg.d_model))
    if cfg.family == "encdec":
        out["enc_frames"] = jax.random.normal(
            jax.random.fold_in(rng, 2),
            (batch, max(4, seq // cfg.enc_seq_divisor), cfg.d_model))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    model = registry.get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init_params(rng)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.1f}M params, family={cfg.family}")
    optim = opt.adam(args.lr, schedule=opt.cosine_warmup(5, args.steps))
    state = optim.init(params)
    step = jax.jit(make_train_step(cfg, optim))
    losses = []
    t0 = time.time()
    for i in range(args.steps):
        batch = synthetic_batch(cfg, jax.random.fold_in(rng, i),
                                args.batch, args.seq)
        params, state, loss = step(params, state, batch)
        losses.append(float(loss))
        print(f"step {i:3d} loss {losses[-1]:.4f}", flush=True)
    dt = time.time() - t0
    print(f"{args.steps} steps in {dt:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    if args.steps >= 20:  # too noisy to assert on a handful of steps
        assert min(losses[-3:]) < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
