import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: lower + compile every (arch x input-shape x mesh)
combination against the production mesh, with ShapeDtypeStruct inputs
(no allocation), and extract memory / cost / collective statistics.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k --mesh single --out out.json [--seq-shard] [--no-fsdp]
"""
import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, get_arch, get_shape
from ..distributed.sharding import Rules
from ..launch import specs as sp
from ..launch.mesh import make_production_mesh
from ..models import registry
from ..train import optimizer as opt

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],{}\s]+\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.IGNORECASE)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str):
    """Sum per-device output bytes of every collective op, by type.

    all-reduce traffic counted 2x (ring reduce-scatter + all-gather)."""
    per_type = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        lhs, op = m.group(1), m.group(2).lower()
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(lhs):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        mult = 2 if op == "all-reduce" else 1
        rec = per_type.setdefault(op, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += nbytes * mult
    total = sum(r["bytes"] for r in per_type.values())
    return total, per_type


def shardings_for(rules: Rules, logical_tree, shape_tree):
    def one(logical, shaped):
        return rules.sharding(logical, tuple(shaped.shape))
    return jax.tree.map(
        one, logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def resolved_config(arch: str, shape_name: str):
    return sp.serving_config(get_arch(arch), get_shape(shape_name))


def build(cfg, shape_name: str, mesh, *, fsdp=True, seq_shard=False,
          extra_rules=None):
    """Returns (step_fn, abstract_args, in_shardings, out_shardings)."""
    shape = get_shape(shape_name)
    model = registry.get_model(cfg)
    rules = Rules(mesh, rules=extra_rules, fsdp=fsdp)
    params_s = registry.abstract_params(cfg)
    p_shard = shardings_for(rules, model.logical_axes(), params_s)
    ins = sp.input_specs(cfg, shape)
    repl = NamedSharding(mesh, P())

    def batch_shardings(batch):
        out = {}
        for k, v in batch.items():
            out[k] = rules.sharding(("batch",) + (None,) * (len(v.shape) - 1),
                                    tuple(v.shape))
        return out

    seq_rule = None
    if seq_shard:
        sspec = rules.spec(("batch", "seq_model", "embed"))
        # shard the residual-stream sequence dim over the model axis
        sspec = P(sspec[0], "model", None)
        seq_rule = lambda x: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, sspec))

    if shape.kind == "train":
        optim = opt.adam(1e-4)
        state_s = jax.eval_shape(optim.init, params_s)
        s_shard = type(state_s)(repl, p_shard, p_shard)

        def train_step(params, state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: model.loss_fn(p, batch, seq_rule=seq_rule))(params)
            params, state = optim.update(grads, state, params)
            return params, state, loss

        args = (params_s, state_s, ins["batch"])
        in_sh = (p_shard, s_shard, batch_shardings(ins["batch"]))
        out_sh = (p_shard, s_shard, repl)
        return train_step, args, in_sh, out_sh, cfg

    def logits_sharding(batch_dim, seq_dim):
        return rules.sharding(("batch", None, "vocab"),
                              (batch_dim, seq_dim, cfg.vocab_size))

    if shape.kind == "prefill":
        c_shard = shardings_for(rules, model.cache_axes(),
                                jax.eval_shape(
                                    lambda: model.init_cache(
                                        shape.global_batch, shape.seq_len)))

        def prefill_step(params, batch):
            return model.prefill(params, batch, shape.seq_len)

        args = (params_s, ins["batch"])
        in_sh = (p_shard, batch_shardings(ins["batch"]))
        text_len = ins["batch"]["tokens"].shape[1]
        out_sh = (logits_sharding(shape.global_batch, text_len), c_shard)
        return prefill_step, args, in_sh, out_sh, cfg

    # decode
    cache_s = ins["cache"]
    c_shard = shardings_for(rules, model.cache_axes(), cache_s)

    def serve_step(params, cache, tokens):
        return model.extend(params, cache, tokens)

    args = (params_s, cache_s, ins["tokens"])
    in_sh = (p_shard, c_shard,
             rules.sharding(("batch", None), tuple(ins["tokens"].shape)))
    out_sh = (logits_sharding(shape.global_batch, 1), c_shard)
    return serve_step, args, in_sh, out_sh, cfg


# TPU v5e constants (per chip)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def _lower_costs(cfg, shape_name, mesh, fsdp, seq_shard, extra_rules=None):
    """(flops, bytes, coll_bytes, coll_by_type, mem, timings, compiled)."""
    t0 = time.time()
    step, args, in_sh, out_sh, _ = build(cfg, shape_name, mesh, fsdp=fsdp,
                                         seq_shard=seq_shard,
                                         extra_rules=extra_rules)
    kind = get_shape(shape_name).kind
    donate = {"train": (0, 1), "decode": (1,), "prefill": ()}[kind]
    with mesh:
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax wraps it in a list
        cost = cost[0] if cost else {}
    coll_total, coll_by_type = collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            coll_total, coll_by_type, mem, (t_lower, t_compile))


def _calib_pair(cfg):
    """Two small UNROLLED variants used to extrapolate per-layer cost
    (XLA's HloCostAnalysis counts a while-loop body once, so scanned
    stacks under-report; see DESIGN.md section 6)."""
    if cfg.family == "hybrid":
        u = len(cfg.block_pattern or ("rec", "rec", "attn"))
        mk = lambda L: cfg.replace(num_layers=L, scan_layers=False)
        return mk(u), mk(2 * u), u, 2 * u, cfg.num_layers
    if cfg.family == "encdec":
        mk = lambda L: cfg.replace(enc_layers=L, dec_layers=L,
                                   scan_layers=False)
        return mk(1), mk(2), 1, 2, cfg.enc_layers or cfg.num_layers
    mk = lambda L: cfg.replace(num_layers=L, scan_layers=False)
    return mk(1), mk(2), 1, 2, cfg.num_layers


def run_one(arch: str, shape_name: str, mesh_kind: str, *, fsdp=True,
            seq_shard=False, variant="baseline", calibrate=True,
            extra_rules=None):
    if mesh_kind == "serve":
        from .mesh import SERVING_RULES, make_serving_mesh
        mesh = make_serving_mesh()
        extra_rules = dict(SERVING_RULES, **(extra_rules or {}))
        fsdp = False
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.size
    cfg = resolved_config(arch, shape_name)
    shape = get_shape(shape_name)
    (flops, bytes_acc, coll_total, coll_by_type, mem,
     (t_lower, t_compile)) = _lower_costs(cfg, shape_name, mesh, fsdp,
                                          seq_shard, extra_rules)
    corrected = {}
    if calibrate:
        c1, c2, L1, L2, L = _calib_pair(cfg)
        f1, b1, k1, _, _, _ = _lower_costs(c1, shape_name, mesh, fsdp,
                                           seq_shard, extra_rules)
        f2, b2, k2, _, _, _ = _lower_costs(c2, shape_name, mesh, fsdp,
                                           seq_shard, extra_rules)
        ext = lambda a, b: a + (L - L1) / (L2 - L1) * (b - a)
        corrected = {"flops_per_dev": ext(f1, f2),
                     "bytes_per_dev": ext(b1, b2),
                     "coll_bytes_per_dev": ext(k1, k2),
                     "calib_layers": [L1, L2, L]}
        flops = max(flops, corrected["flops_per_dev"])
        bytes_acc = max(bytes_acc, corrected["bytes_per_dev"])
        coll_total = max(coll_total, corrected["coll_bytes_per_dev"])
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind in ("train", "prefill") else shape.global_batch)
    mf = (6 if shape.kind == "train" else 2) * cfg.n_active_params * tokens
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "variant": variant, "chips": chips, "kind": shape.kind,
        "ok": True,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        # per-device numbers (the compiled module is the per-device program)
        # flops/bytes/coll are max(raw scanned HLO, unrolled extrapolation)
        "flops_per_dev": flops,
        "bytes_per_dev": bytes_acc,
        "coll_bytes_per_dev": coll_total,
        "scan_calibration": corrected,
        "coll_by_type": coll_by_type,
        "mem": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        "roofline": {
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": bytes_acc / HBM_BW,
            "collective_s": coll_total / LINK_BW,
        },
        "model_flops_global": mf,
        "model_flops_per_dev": mf / chips,
        "useful_flops_ratio": (mf / chips) / flops if flops else 0.0,
        "n_params": cfg.n_params,
        "n_active_params": cfg.n_active_params,
    }
    r = result["roofline"]
    result["dominant"] = max(("compute_s", "memory_s", "collective_s"),
                             key=lambda k: r[k])
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "serve"])
    ap.add_argument("--out", default="")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()
    res = run_one(args.arch, args.shape, args.mesh, fsdp=not args.no_fsdp,
                  seq_shard=args.seq_shard, variant=args.variant,
                  calibrate=not args.no_calibrate)
    print(json.dumps(res, indent=2, default=str))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=2, default=str)


if __name__ == "__main__":
    main()
