"""Speculative-serving launcher: a thin CLI over ``repro.serving``.

Serves a target architecture with a smaller same-family draft via
continuous-batching token-level speculative decoding: requests stream
through a policy-ordered queue (``--sched fifo|priority|sjf``) into
``--max-batch`` KV-cache slots, prompts prefill through the paged pool
in ``--prefill-chunk`` token chunks under a per-step
``--prefill-budget``, and every engine step verifies gamma drafted
tokens for all decoding slots in one batched target forward.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 8 --new-tokens 32 --gamma 4 --max-batch 4
  PYTHONPATH=src python -m repro.launch.serve --prompt-len 96 \
      --prefill-chunk 32 --sched priority --priorities 0,2,1
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from ..configs import ARCHS, get_arch, smoke_variant
from ..models import registry
from ..serving import DisaggServingEngine, ServeRequest, ServingEngine


def build_engine(args):
    cfg_t = smoke_variant(get_arch(args.arch)).replace(num_layers=4)
    pt = registry.get_model(cfg_t).init_params(jax.random.PRNGKey(0))
    cfg_d = pd = None
    if args.method == "sd":
        cfg_d = cfg_t.replace(num_layers=args.draft_layers)
        pd = registry.get_model(cfg_d).init_params(jax.random.PRNGKey(1))
    mesh = None
    if args.sharded:
        from .mesh import resolve_serving_mesh
        mesh = resolve_serving_mesh()
        print(f"sharded serving on mesh {dict(mesh.shape)}")
    kw = dict(
        method=args.method, max_batch=args.max_batch,
        max_len=args.max_len, gamma=args.gamma,
        draft_policy=args.draft_policy, mesh=mesh,
        kv_layout=args.kv_layout, kernel=args.kernel,
        page_size=args.page_size, sched=args.sched,
        prefill_chunk=args.prefill_chunk or None,
        prefill_budget=args.prefill_budget or None,
        prefix_cache=args.prefix_cache == "on",
        shed_queue=args.shed if args.shed >= 0 else None)
    if args.disagg:
        kw["kv_layout"] = "paged" if args.kv_layout == "auto" \
            else args.kv_layout
        return cfg_t, DisaggServingEngine(
            cfg_t, pt, cfg_d, pd, prefill_slots=args.prefill_slots, **kw)
    return cfg_t, ServingEngine(cfg_t, pt, cfg_d, pd, **kw)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--method", default="sd", choices=["sd", "ar"])
    ap.add_argument("--draft-layers", type=int, default=1)
    ap.add_argument("--draft-policy", default="fixed",
                    choices=["fixed", "adaptive"])
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gamma", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--kv-layout", dest="kv_layout", default="auto",
                    choices=["auto", "paged", "dense"],
                    help="KV pool: paged block tables + Pallas "
                         "spec-verify attention (default where "
                         "supported) or dense per-slot caches")
    ap.add_argument("--kernel", default="auto",
                    choices=["auto", "pallas", "ref"],
                    help="kernel backend (auto = Pallas; compiled on "
                         "TPU, interpret elsewhere)")
    ap.add_argument("--page-size", dest="page_size", type=int, default=None,
                    help="KV block size of the paged pool")
    ap.add_argument("--sched", default="fifo",
                    choices=["fifo", "priority", "sjf"],
                    help="admission policy: fifo (default), priority "
                         "(per-request priority + aging), sjf "
                         "(shortest job first)")
    ap.add_argument("--prefill-chunk", dest="prefill_chunk", type=int,
                    default=0,
                    help="stream prompts through the paged pool in "
                         "chunks of N tokens (0 = one-shot dense-staging "
                         "admission)")
    ap.add_argument("--prefill-budget", dest="prefill_budget", type=int,
                    default=0,
                    help="max prefill tokens per engine step across all "
                         "admitting slots (0 = unlimited)")
    ap.add_argument("--fanout", type=int, default=1,
                    help="scenario rollouts per submitted prompt: each "
                         "request fans into K members that FORK the "
                         "admitted prompt's KV pages (copy-on-write) "
                         "with independent fold_in rng streams")
    ap.add_argument("--prefix-cache", dest="prefix_cache", default="off",
                    choices=["on", "off"],
                    help="radix prefix cache over retired prompt pages: "
                         "admissions adopt the longest cached page run "
                         "and prefill only the tail (requires the paged "
                         "KV layout; implies --prefill-chunk 32 when "
                         "chunking is off)")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="per-request wall-clock deadline in seconds "
                         "(0 = none): requests the engine cannot finish "
                         "in time retire status='deadline' with their "
                         "partial stream")
    ap.add_argument("--cancel-after", dest="cancel_after", type=int,
                    default=0,
                    help="after N engine steps, cancel the youngest "
                         "still-incomplete request mid-flight (0 = "
                         "never) — frees its slot and refcounted pages "
                         "immediately")
    ap.add_argument("--shed", type=int, default=-1,
                    help="bound the pending queue: after each step's "
                         "admissions, backlog past this depth is shed "
                         "(status='shed'); -1 = never shed")
    ap.add_argument("--priorities", default="0",
                    help="CSV of request priorities, cycled across "
                         "--requests (ranked by --sched priority)")
    ap.add_argument("--loop", default="sync", choices=["sync", "async"],
                    help="sync = blocking step; async = pipelined step "
                         "(dispatch round N, stage round N+1's host "
                         "work in the overlap window, one batched "
                         "device fetch, commit at the fault barrier)")
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregated prefill/decode workers: "
                         "admission pinned to --prefill-slots slots, "
                         "completed prompts handed to decode slots by "
                         "block-table transfer (paged layout + chunked "
                         "admission)")
    ap.add_argument("--prefill-slots", dest="prefill_slots", type=int,
                    default=1,
                    help="slots the prefill worker owns under --disagg "
                         "(the remaining max_batch - N slots decode)")
    ap.add_argument("--sharded", action="store_true",
                    help="place the slot pool + params on a device mesh "
                         "(the serving mesh when 256+ devices are "
                         "visible; run under XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N to "
                         "try it on CPU)")
    args = ap.parse_args()

    cfg_t, engine = build_engine(args)
    prios = [int(p) for p in args.priorities.split(",")]
    print(f"serving {cfg_t.name} (target 4L, draft {args.draft_layers}L, "
          f"method={args.method}, gamma={args.gamma}, "
          f"policy={args.draft_policy}, sched={args.sched}, "
          f"loop={args.loop}, "
          f"prefill_chunk={args.prefill_chunk or 'off'}, "
          f"prefix_cache={args.prefix_cache}, fanout={args.fanout}, "
          f"max_batch={args.max_batch}, requests={args.requests})")
    if args.disagg:
        print(f"disaggregated: prefill worker slots="
              f"{list(engine.prefill_worker.slots)} decode worker slots="
              f"{list(engine.decode_worker.slots)}")
    submitted = []
    for r in range(args.requests):
        prompt = jax.random.randint(
            jax.random.PRNGKey(10 + r), (args.prompt_len,), 0,
            cfg_t.vocab_size).astype(jnp.int32)
        ids = engine.submit(ServeRequest(
            prompt=prompt, max_new_tokens=args.new_tokens, rng=100 + r,
            priority=prios[r % len(prios)],
            deadline_s=args.deadline or None), fanout=args.fanout)
        submitted.extend(ids if isinstance(ids, list) else [ids])
    results = []
    steps = 0
    overlap = engine.async_overlap() if args.loop == "async" else None
    while engine.scheduler.has_work():
        for res in engine.step(overlap=overlap):
            results.append(res)
            print(f"request {res.request_id}: {res.n} tokens, "
                  f"{res.rounds} rounds, alpha={res.acceptance_rate:.2f}, "
                  f"ttft={res.ttft_s * 1e3:.0f}ms/"
                  f"{res.ttft_rounds}r"
                  + (f" [{res.status}]" if res.status != "ok" else ""))
        steps += 1
        if args.cancel_after and steps == args.cancel_after:
            done_ids = {r.request_id for r in results}
            live = [rid for rid in submitted if rid not in done_ids]
            if live:
                res = engine.cancel(live[-1])
                results.append(res)
                print(f"request {res.request_id}: cancelled mid-flight "
                      f"after step {steps} ({res.n} tokens kept)")
    st = engine.stats()
    ttfts = sorted(r.ttft_s for r in results)
    p50 = ttfts[len(ttfts) // 2] if ttfts else 0.0
    p95 = ttfts[min(len(ttfts) - 1, int(0.95 * len(ttfts)))] if ttfts \
        else 0.0
    print(f"served {st.tokens} tokens in {st.wall_s:.1f}s | "
          f"alpha={st.acceptance_rate:.2f} | "
          f"tokens/target-forward={st.tokens_per_forward:.2f} "
          f"(AR = ~{args.max_batch}.0 at this batch) | "
          f"tokens/sec={st.tokens_per_sec:.1f}")
    print(f"admission: prefill_tokens={st.prefill_tokens} "
          f"prefill_tok_per_sec={st.prefill_tokens_per_sec:.0f} "
          f"ttft_p50={p50 * 1e3:.0f}ms ttft_p95={p95 * 1e3:.0f}ms")
    print(f"step breakdown: host_ms={st.host_ms:.0f} "
          f"device_ms={st.device_ms:.0f} overlap_ms={st.overlap_ms:.1f} "
          f"handoffs={st.handoffs}")
    print(f"prefix sharing: hit_rate={st.prefix_hit_rate:.2f} "
          f"({st.prefix_hits}/{st.prefix_lookups} admissions) "
          f"prefix_hit_tokens={st.prefix_hit_tokens}")
    counts = {}
    for r in results:
        counts[r.status] = counts.get(r.status, 0) + 1
    print("failure semantics: " + " ".join(
        f"{k}={counts.get(k, 0)}"
        for k in ("ok", "failed", "cancelled", "deadline", "shed"))
        + f" | retries={st.retries} deadline_misses={st.deadline_misses} "
          f"goodput_tok_s={st.goodput:.1f}")


if __name__ == "__main__":
    main()
