"""Speculative-serving launcher (the paper's technique as the serving
layer of the framework).

Serves a target architecture with a smaller same-family draft via
token-level speculative decoding, reporting acceptance and
tokens-per-target-forward.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 4 --new-tokens 32 --gamma 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import ARCHS, get_arch, smoke_variant
from ..models import registry
from ..sampling import SamplerSpec, build_sampler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--draft-layers", type=int, default=1)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--gamma", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args()

    cfg_t = smoke_variant(get_arch(args.arch)).replace(num_layers=4)
    cfg_d = cfg_t.replace(num_layers=args.draft_layers)
    mt, md = registry.get_model(cfg_t), registry.get_model(cfg_d)
    pt = mt.init_params(jax.random.PRNGKey(0))
    pd = md.init_params(jax.random.PRNGKey(1))
    print(f"serving {cfg_t.name} (target 4L, draft {args.draft_layers}L, "
          f"gamma={args.gamma})")
    serve_fn = build_sampler(
        SamplerSpec(domain="token", method="sd", execution="host",
                    max_events=args.new_tokens, gamma=args.gamma,
                    max_len=args.max_len),
        cfg_t, pt, cfg_d, pd)
    tot_tok = tot_fwd = tot_acc = tot_drafted = 0
    t0 = time.time()
    for r in range(args.requests):
        prompt = jax.random.randint(jax.random.PRNGKey(10 + r), (8,), 0,
                                    cfg_t.vocab_size).astype(jnp.int32)
        st = serve_fn(jax.random.PRNGKey(100 + r), prompt).stats()
        tot_tok += st.events
        tot_fwd += st.rounds
        tot_acc += st.accepted
        tot_drafted += st.drafted
        print(f"request {r}: {st.events} tokens, {st.rounds} target "
              f"forwards")
    dt = time.time() - t0
    print(f"served {tot_tok} tokens in {dt:.1f}s | alpha="
          f"{tot_acc / max(tot_drafted, 1):.2f} | tokens/target-forward="
          f"{tot_tok / max(tot_fwd, 1):.2f} (AR = 1.0)")


if __name__ == "__main__":
    main()
