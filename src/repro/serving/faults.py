"""Deterministic fault injection for the serving engine (chaos harness).

A ``FaultPlan`` is a list of ``FaultSpec``s the engine consults at fixed
points of every ``step()``; faults fire by the scheduler's step index,
never by wall clock or randomness, so a chaos run is exactly
reproducible — CI asserts on it like on any other run. Five kinds:

  ``step_error``       raise ``InjectedFault`` out of the device round
                       AFTER the forward synchronizes but BEFORE any
                       host commit — the worst-placed failure the
                       engine's rollback-and-retry must absorb.
  ``nan_lane``         poison ONE slot's round inputs (the token
                       domain's temperature, the TPP domain's pending
                       event time) so that lane's logits go non-finite;
                       the engine's per-lane quarantine must fail that
                       single request and keep every other stream
                       bitwise intact.
  ``page_exhaustion``  seize the paged pools' free lists for the step,
                       so admissions defer and in-round page growth
                       hits the pool's out-of-pages error; restored at
                       step end (pages freed DURING the fault stay
                       free — no page is ever lost to the harness).
  ``slow_step``        sleep before the step's work — deadline and
                       goodput accounting under a stalled device.
  ``handoff_error``    raise ``InjectedFault`` at the disaggregated
                       engine's prefill→decode handoff barrier — a
                       prefill worker dying mid-transfer. Retried with
                       the same rollback contract as ``step_error``
                       (the handoff re-runs, pages still owned by the
                       prefill slot); a unified engine never reaches
                       the barrier, so the spec is inert there.

The injection contract the chaos tests pin: under any plan plus any
cancel schedule, every SURVIVING request's committed tokens are bitwise
the fault-free run's (same ``fold_in`` streams — a retried round re-runs
with the same ``round_idx``), and the pools leak zero pages.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

FAULT_KINDS = ("step_error", "nan_lane", "page_exhaustion", "slow_step",
               "handoff_error")


class InjectedFault(RuntimeError):
    """Raised by a ``step_error`` spec at the engine's fault barrier."""


@dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    kind    : one of ``FAULT_KINDS``.
    step    : first engine step (1-based, the scheduler's post-``tick``
              index) the fault fires on.
    times   : consecutive steps to keep firing (default 1).
    slot    : ``nan_lane`` only — the lane to poison (ignored unless a
              decoding request occupies it that step).
    pool    : ``page_exhaustion`` only — "t" | "d" | "both".
    seconds : ``slow_step`` only — stall length.
    """

    kind: str
    step: int
    times: int = 1
    slot: int = 0
    pool: str = "both"
    seconds: float = 0.02
    message: str = "injected device-step failure"

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; expected "
                             f"one of {FAULT_KINDS}")
        if self.step < 1 or self.times < 1:
            raise ValueError("fault step and times must be >= 1 (steps "
                             "are 1-based engine step indices)")
        if self.pool not in ("t", "d", "both"):
            raise ValueError("pool must be 't', 'd' or 'both'")

    def active(self, step: int) -> bool:
        return self.step <= step < self.step + self.times


class FaultPlan:
    """A deterministic schedule of ``FaultSpec``s plus its firing log.

    The engine drives it: ``begin_step``/``end_step`` bracket every
    ``step()`` (exhaustion seizure + slow-step stalls), the round-input
    builders ask ``nan_lane_slot``, and every decode/prefill commit
    point passes through ``maybe_raise_step_error``. ``log`` records
    ``(step, kind)`` per actual injection — a nan_lane spec aimed at an
    empty slot injects nothing and logs nothing.
    """

    def __init__(self, *specs: FaultSpec):
        self.specs: List[FaultSpec] = list(specs)
        self.log: List[Tuple[int, str]] = []
        self._seized: List[Tuple[Any, List[int]]] = []

    @property
    def injected(self) -> int:
        return len(self.log)

    def injected_of(self, kind: str) -> int:
        return sum(1 for _, k in self.log if k == kind)

    def reset(self) -> None:
        """Clear the firing log for a fresh run of the same plan."""
        if self._seized:
            raise RuntimeError("reset() inside a seized step")
        self.log.clear()

    # -- engine hooks ------------------------------------------------------
    def _active(self, kind: str, step: int) -> Optional[FaultSpec]:
        for sp in self.specs:
            if sp.kind == kind and sp.active(step):
                return sp
        return None

    def _record(self, step: int, kind: str, engine) -> None:
        self.log.append((step, kind))
        engine._stats.faults_injected += 1

    def _pools(self, engine, which: str):
        out = []
        if which in ("t", "both"):
            out.append(engine.pool_t)
        if which in ("d", "both") and engine.pool_d is not None:
            out.append(engine.pool_d)
        return [p for p in out if hasattr(p, "seize_free")]

    def begin_step(self, engine, step: int) -> None:
        sp = self._active("slow_step", step)
        if sp is not None:
            time.sleep(sp.seconds)
            self._record(step, "slow_step", engine)
        sp = self._active("page_exhaustion", step)
        if sp is not None:
            pools = self._pools(engine, sp.pool)
            for pool in pools:
                self._seized.append((pool, pool.seize_free()))
            if pools:
                self._record(step, "page_exhaustion", engine)

    def end_step(self, engine, step: int) -> None:
        while self._seized:
            pool, pages = self._seized.pop()
            pool.restore_free(pages)

    def exhaustion_active(self, step: int) -> bool:
        """True while a seized free list makes admission failures
        transient (the engine defers instead of declaring the pool too
        small for a single request)."""
        return self._active("page_exhaustion", step) is not None

    def nan_lane_slot(self, step: int) -> Optional[int]:
        sp = self._active("nan_lane", step)
        return None if sp is None else sp.slot

    def note_nan_injected(self, step: int, engine) -> None:
        """The engine confirms the poisoned lane actually rode a round."""
        self._record(step, "nan_lane", engine)

    def maybe_raise_step_error(self, step: int, engine) -> None:
        sp = self._active("step_error", step)
        if sp is not None:
            self._record(step, "step_error", engine)
            raise InjectedFault(f"{sp.message} (step {step})")

    def maybe_raise_handoff_error(self, step: int, engine) -> None:
        """Fires at the disaggregated prefill→decode handoff barrier
        (``serving/disagg.py``), BEFORE any ownership moves — the retry
        finds the pages still on the prefill slot. Unified engines
        never call this, so a ``handoff_error`` spec injects (and logs)
        nothing there."""
        sp = self._active("handoff_error", step)
        if sp is not None:
            self._record(step, "handoff_error", engine)
            raise InjectedFault(
                f"injected prefill-worker handoff failure (step {step})")
