"""Jitted TPP (event-sequence) rounds of the serving engine.

The token engine's paged rounds commit integer tokens; the TPP domain
commits (time, mark) events, so its round functions carry a float
pending-time lane next to the pending-mark lane and route the decoder
heads (log-normal mixture + type logits) instead of an LM head. The
propose-verify math is ``sampling.loops.sd_round`` verbatim — drafted
window, one c = gamma+1 target forward, ``spec.verify_events``,
adjusted/bonus replacement event — re-hosted onto the paged KV pool and
vmapped over slots, which is what lets thousands of forecast rollouts
ride the same continuous batch.

Per-request rng contract (the batch-composition-independence property
the serving tests pin): every draw of round ``r`` of a request derives
from ``split(fold_in(request.rng, r), 5)`` ->
(r_draft, r_ver, r_new1, r_new2, r_new3); draft step ``i`` uses
``split(fold_in(r_draft, i))``. Slot placement and batch neighbors
never enter the stream.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..core import speculative as spec
from ..models import tpp as tppm

#: jit caches keyed by (kind, cfgs, gamma/chunk, policy, max_kv) — the
#: same idiom as ``engine._FN_CACHE``, kept separate so resetting one
#: domain's cache never evicts the other's.
_FN_CACHE: Dict[Tuple, Any] = {}


def clear_fn_cache() -> None:
    _FN_CACHE.clear()


def tpp_prefill_chunk_fn(cfg_t, cfg_d, chunk: int, policy, max_kv: int):
    """Chunked event-history prefill into the paged pools.

    Writes ``nvalid[s]`` of ``chunk`` (time, mark) pairs per sequence at
    logical positions ``lens[s]..``; no hidden states leave the device —
    the TPP first "token" is the history's own last event, so (unlike
    the LM path) prefill produces no logits to sample from.
    """
    key = ("tpp_prefill", cfg_t, cfg_d, chunk, policy, max_kv)
    if key not in _FN_CACHE:
        def fn(params_t, params_d, pg_t, bt_t, pg_d, bt_d, lens, times,
               types, nvalid):
            _, pg_t = tppm.prefill_paged(cfg_t, params_t, pg_t, bt_t,
                                         lens, times, types, nvalid,
                                         policy=policy, max_kv=max_kv)
            if cfg_d is not None:
                _, pg_d = tppm.prefill_paged(cfg_d, params_d, pg_d, bt_d,
                                             lens, times, types, nvalid,
                                             policy=policy, max_kv=max_kv)
            return pg_t, pg_d
        _FN_CACHE[key] = jax.jit(fn)
    return _FN_CACHE[key]


def tpp_ar_round_paged_fn(cfg_t, policy, max_kv: int):
    """One committed event per sequence: ingest the pending (t, k) pair,
    sample the next from the target heads (``loops.sample_event``'s rng
    order: r1 interval, r2 mark)."""
    key = ("tpp_ar_round", cfg_t, policy, max_kv)
    if key not in _FN_CACHE:
        def fn(params_t, pg_t, bt_t, lens_t, t_pend, k_pend, keys, ridx):
            h, pg_t = tppm.extend_paged(cfg_t, params_t, pg_t, bt_t,
                                        lens_t, t_pend[:, None],
                                        k_pend[:, None], policy=policy,
                                        max_kv=max_kv)
            h = h[:, 0]
            r = jax.vmap(jax.random.fold_in)(keys, ridx)
            rs = jax.vmap(lambda k: jax.random.split(k))(r)
            mix = tppm.interval_params(cfg_t, params_t, h)
            tau = jax.vmap(tppm.sample_interval)(rs[:, 0], mix)
            logits = tppm.type_logits(cfg_t, params_t, h)
            kk = jax.vmap(jax.random.categorical)(rs[:, 1], logits)
            new_t = t_pend + tau
            # per-lane health: a NaN event time or NaN type logits mean
            # this lane's round is unusable (the engine quarantines it)
            ok = ~(jnp.isnan(new_t) | jnp.any(jnp.isnan(logits), axis=-1))
            # pack the int lanes so the host fetch is one [S,2] + one
            # [S] array per round (engine commits from a single
            # batched device_get)
            packed_i = jnp.stack(
                [kk.astype(jnp.int32), ok.astype(jnp.int32)], axis=1)
            return pg_t, packed_i, new_t
        _FN_CACHE[key] = jax.jit(fn)
    return _FN_CACHE[key]


def tpp_sd_round_paged_fn(cfg_t, cfg_d, gamma: int, policy, max_kv: int):
    """One batched propose-verify round (Algorithm 1 on the paged pool).

    Returns (pg_t, pg_d, packed_i [S,g+3] int32 = d_k ‖ A ‖ new_k ‖ ok,
    packed_f [S,g+1] float32 = d_t ‖ new_t); the host commits
    ``d_t/d_k[:A]`` plus the replacement event and truncates both pools
    to ``len0 + 1 + A`` (lanes with ``ok == False`` are quarantined
    instead). The int/float packing keeps the round's host-bound
    scalars to exactly two device arrays for the engine's single
    batched fetch.
    """
    key = ("tpp_sd_round", cfg_t, cfg_d, gamma, policy, max_kv)
    if key not in _FN_CACHE:
        def fn(params_t, params_d, pg_t, pg_d, bt_t, lens_t, bt_d, lens_d,
               t_pend, k_pend, keys, ridx):
            ks = jax.vmap(lambda k, r: jax.random.split(
                jax.random.fold_in(k, r), 5))(keys, ridx)
            r_draft, r_ver = ks[:, 0], ks[:, 1]
            r_new1, r_new2, r_new3 = ks[:, 2], ks[:, 3], ks[:, 4]

            # --- draft gamma events (pending ingested first: it is
            # committed but not yet in either cache)
            h, pg_d = tppm.extend_paged(cfg_d, params_d, pg_d, bt_d,
                                        lens_d, t_pend[:, None],
                                        k_pend[:, None], policy=policy,
                                        max_kv=max_kv)
            h = h[:, 0]
            lens_cur = lens_d + 1
            t_cur = t_pend
            taus, marks, times, mixes, lgts = [], [], [], [], []
            for i in range(gamma):
                ri = jax.vmap(jax.random.fold_in, (0, None))(r_draft, i)
                rs = jax.vmap(lambda k: jax.random.split(k))(ri)
                mix = tppm.interval_params(cfg_d, params_d, h)
                tau = jax.vmap(tppm.sample_interval)(rs[:, 0], mix)
                logits = jax.nn.log_softmax(
                    tppm.type_logits(cfg_d, params_d, h), axis=-1)
                k_i = jax.vmap(jax.random.categorical)(rs[:, 1], logits)
                k_i = k_i.astype(jnp.int32)
                t_cur = t_cur + tau
                taus.append(tau); marks.append(k_i); times.append(t_cur)
                mixes.append(mix); lgts.append(logits)
                h, pg_d = tppm.extend_paged(cfg_d, params_d, pg_d, bt_d,
                                            lens_cur, t_cur[:, None],
                                            k_i[:, None], policy=policy,
                                            max_kv=max_kv)
                h = h[:, 0]
                lens_cur = lens_cur + 1
            d_tau = jnp.stack(taus, 1)                        # [S, g]
            d_k = jnp.stack(marks, 1)
            d_t = jnp.stack(times, 1)
            d_mix = tppm.MixParams(
                jnp.stack([m.log_w for m in mixes], 1),
                jnp.stack([m.mu for m in mixes], 1),
                jnp.stack([m.sigma for m in mixes], 1))       # [S, g, M]
            d_logits = jnp.stack(lgts, 1)                     # [S, g, K]

            # --- verify: target processes pending + drafts in ONE
            # c = gamma+1 parallel forward
            ver_t = jnp.concatenate([t_pend[:, None], d_t], axis=1)
            ver_k = jnp.concatenate([k_pend[:, None], d_k], axis=1)
            h_t, pg_t = tppm.extend_paged(cfg_t, params_t, pg_t, bt_t,
                                          lens_t, ver_t, ver_k,
                                          policy=policy, max_kv=max_kv)
            mix_t_all = tppm.interval_params(cfg_t, params_t, h_t)
            logits_t_all = jax.nn.log_softmax(
                tppm.type_logits(cfg_t, params_t, h_t), axis=-1)

            # --- per-lane accept/reject + replacement event; the lane
            # body is loops.sd_round's verify section verbatim (ref
            # densities inside vmap; the attention above already ran
            # under the engine's kernel policy)
            def lane(rv, r1, r2, r3, dtau, dk, dmix, dlg, dt,
                     mix_all, lg_all, tp):
                mix_hist = jax.tree.map(lambda x: x[:gamma], mix_all)
                res = spec.verify_events(
                    rv, dtau, dk, tppm.interval_logpdf(dmix, dtau), dlg,
                    mix_hist, lg_all[:gamma])
                A, all_acc = res.num_accepted, res.all_accepted
                Ac = jnp.minimum(A, gamma - 1)
                mix_A = jax.tree.map(lambda x: x[A], mix_all)
                logits_A = lg_all[A]
                d_mix_A = jax.tree.map(lambda x: x[Ac], dmix)
                tau_adj = spec.adjusted_continuous(r1, mix_A, d_mix_A)
                tau_direct = tppm.sample_interval(r2, mix_A)
                new_tau = jnp.where(
                    all_acc, tau_direct,
                    jnp.where(res.tau_rejected, tau_adj, dtau[Ac]))
                k_adj = spec.adjusted_discrete(r3, logits_A, dlg[Ac])
                k_direct = jax.random.categorical(
                    jax.random.fold_in(r3, 1), logits_A).astype(jnp.int32)
                new_k = jnp.where(all_acc | res.tau_rejected, k_direct,
                                  k_adj.astype(jnp.int32))
                base_t = jnp.where(A > 0, dt[jnp.maximum(A - 1, 0)], tp)
                return A, base_t + new_tau, new_k

            A, new_t, new_k = jax.vmap(lane)(
                r_ver, r_new1, r_new2, r_new3, d_tau, d_k, d_mix,
                d_logits, d_t, mix_t_all, logits_t_all, t_pend)
            # per-lane health (NaN anywhere in this lane's round)
            ok = ~(jnp.any(jnp.isnan(logits_t_all), axis=(1, 2))
                   | jnp.isnan(new_t) | jnp.any(jnp.isnan(d_t), axis=1))
            packed_i = jnp.concatenate(
                [d_k, A[:, None].astype(jnp.int32),
                 new_k[:, None].astype(jnp.int32),
                 ok.astype(jnp.int32)[:, None]], axis=1)
            packed_f = jnp.concatenate([d_t, new_t[:, None]], axis=1)
            return pg_t, pg_d, packed_i, packed_f
        _FN_CACHE[key] = jax.jit(fn)
    return _FN_CACHE[key]
