"""Continuous-batching speculative serving (request/scheduler API).

The serving layer turns the paper's single-sequence propose-verify loop
into a system that takes traffic: requests enter a FIFO queue, a
scheduler slots them into a pooled per-slot KV cache, and every engine
step runs ONE batched draft+verify round for all active slots — so a
single target forward verifies gamma drafted tokens for every request
in flight.
"""
from .engine import ServingEngine
from .kv_pool import (KVCachePool, PagedKVCachePool, paged_supported,
                      rollback_kind)
from .request import EngineStats, ServeRequest, ServeResult
from .scheduler import Scheduler, SlotState

__all__ = ["ServingEngine", "ServeRequest", "ServeResult", "EngineStats",
           "Scheduler", "SlotState", "KVCachePool", "PagedKVCachePool",
           "paged_supported", "rollback_kind"]
