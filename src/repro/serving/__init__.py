"""Continuous-batching speculative serving (request/scheduler API).

The serving layer turns the paper's single-sequence propose-verify loop
into a system that takes traffic: requests enter a policy-ordered queue
(FIFO / priority+aging / SJF), a scheduler slots them into a paged KV
pool, prompts prefill THROUGH the pool in chunks under a per-step token
budget, and every engine step runs ONE batched draft+verify round for
all decoding slots — so a single target forward verifies gamma drafted
tokens for every request in flight while newly admitted prompts stream
in beside them.

Failure semantics: every request retires with a structured
``ServeResult.status`` (``RESULT_STATUSES``) — failures, deadline
expiries, cancellations (``ServingEngine.cancel``) and overload shedding
are per-request results, never exceptions out of ``run()``. The
``faults`` module is the deterministic chaos harness that exercises
those paths in CI.
"""
from .disagg import (DecodeWorker, DisaggServingEngine, Handoff,
                     HandoffQueue, PrefillWorker)
from .engine import AdmissionImpossible, ServingEngine
from .faults import FAULT_KINDS, FaultPlan, FaultSpec, InjectedFault
from .kv_pool import (KVCachePool, PagedKVCachePool, paged_supported,
                      rollback_kind)
from .prefix_cache import PrefixCache, tpp_history_key
from .request import (RESULT_STATUSES, EngineStats, ServeRequest,
                      ServeResult)
from .scheduler import (FifoPolicy, GroupedPolicy, PriorityPolicy,
                        Scheduler, SchedulingPolicy, SJFPolicy, SlotState,
                        resolve_sched_policy)

__all__ = ["ServingEngine", "ServeRequest", "ServeResult", "EngineStats",
           "RESULT_STATUSES", "AdmissionImpossible",
           "DisaggServingEngine", "PrefillWorker", "DecodeWorker",
           "Handoff", "HandoffQueue",
           "FaultPlan", "FaultSpec", "InjectedFault", "FAULT_KINDS",
           "Scheduler", "SlotState", "SchedulingPolicy", "FifoPolicy",
           "PriorityPolicy", "SJFPolicy", "GroupedPolicy",
           "resolve_sched_policy", "KVCachePool", "PagedKVCachePool",
           "paged_supported", "rollback_kind", "PrefixCache",
           "tpp_history_key"]
