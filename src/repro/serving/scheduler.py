"""Continuous-batching scheduler: a policy-ordered queue feeding
``max_batch`` KV-cache slots.

The scheduler is pure bookkeeping — it never touches models or device
arrays, so its policies (admission order, deferral, aging) are
unit-testable without JAX. Admission order is pluggable through
``SchedulingPolicy``:

  - ``fifo``     — submission order; engine-deferred re-admissions rank
                   ahead of the queue in their original order (the
                   bitwise default: identical to the historical
                   FIFO-with-deferral behavior).
  - ``priority`` — higher ``ServeRequest.priority`` first, FIFO among
                   equals, with aging: a waiting request's effective
                   priority rises by one every ``aging`` scheduler
                   steps, so a request ``g`` levels below the steady
                   arrival priority is admitted within ``g * aging``
                   steps of becoming the oldest waiter (the starvation
                   bound the unit tests pin).
  - ``sjf``      — shortest job (prompt + budget tokens) first, FIFO
                   tie-break.

The engine drives it:

    tick()                          at the top of every step
    admit() -> [(slot, SlotState)]  policy-ordered placements
    active() -> [(slot, SlotState)]
    defer(slot)                     undo an admission (no pages yet)
    retire(slot) -> SlotState       when a request's budget is spent
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple, Union

from .request import ServeRequest

#: Slot phases: a slot is PREFILLING while its prompt streams into the
#: paged pool in chunks, DECODING once the first token is committed.
PREFILLING = "prefill"
DECODING = "decode"


@dataclass
class SlotState:
    """Host-side generation state of one occupied slot."""

    request: ServeRequest
    slot: int
    out: List[int] = field(default_factory=list)  # committed new tokens
    pending: int = 0      # last committed token, not yet in the caches
    round_idx: int = 1    # next fold_in index of the request's rng stream
    drafted: int = 0
    accepted: int = 0
    rounds: int = 0
    # chunked-prefill admission: the prompt streams into the paged pool
    # in chunks while phase == PREFILLING; ``prefilled`` counts prompt
    # tokens already committed to the pool
    phase: str = DECODING
    prefilled: int = 0
    # prompt tokens served from shared pages (prefix-cache hit or
    # fan-out fork) instead of being prefilled by this slot
    prefix_hit_tokens: int = 0
    # accounting carried over from the queue entry
    seq: int = 0          # admission-order stamp (policy tie-break)
    submit_step: int = 0
    submit_t: float = 0.0
    ttft_rounds: int = 0  # engine steps from submission to first token
    ttft_s: float = 0.0
    # pipelined steps: the slot finished prefilling this step and its
    # first token is still a lazy device scalar riding the decode round
    # (committed at the step's single batched fetch)
    first_pending: bool = False
    # TPP (event-sequence) domain: the pending event is a (time, mark)
    # pair and generation also stops once it passes the horizon
    t_pend: float = 0.0   # absolute time of the pending event
    horizon: Optional[float] = None   # request.t_end (None = budget only)
    out_times: List[float] = field(default_factory=list)

    @property
    def done(self) -> bool:
        if self.phase != DECODING:
            return False
        if len(self.out) >= self.request.max_new_tokens:
            return True
        return self.horizon is not None and self.t_pend > self.horizon


@dataclass
class _QueueEntry:
    """One queued (or engine-deferred) request with its policy inputs."""

    request: ServeRequest
    seq: int
    submit_step: int
    submit_t: float
    deferred: bool = False


class SchedulingPolicy:
    """Admission-ordering policy: a pure sort key over queue entries.

    ``key(entry, step)`` returns a tuple; entries sort ascending and the
    smallest key is admitted first. Policies are stateless — everything
    they rank on lives in the entry (request, seq, submit_step, deferred
    flag) and the scheduler's step counter, which is what keeps them
    model-free and unit-testable.
    """

    name = "base"

    def key(self, entry: _QueueEntry, step: int) -> Tuple:
        raise NotImplementedError

    def key_ctx(self, entry: _QueueEntry, step: int, ctx: dict) -> Tuple:
        """Context-aware sort key. ``ctx`` carries what the plain key
        cannot see: which ``prefix_group``s currently occupy slots
        (``active_groups``) and each pending group's oldest seq stamp
        (``anchors``). The default ignores it, so every existing policy
        keeps its exact ordering."""
        return self.key(entry, step)


class FifoPolicy(SchedulingPolicy):
    """Strict submission order; deferred re-admissions first, in their
    original order (they are always older than anything still queued,
    so this reproduces the historical deferred-then-queue behavior
    bitwise)."""

    name = "fifo"

    def key(self, entry: _QueueEntry, step: int) -> Tuple:
        return (0 if entry.deferred else 1, entry.seq)


class PriorityPolicy(SchedulingPolicy):
    """Highest ``request.priority`` first, FIFO among equals, with
    aging as the starvation bound: effective priority grows by one per
    ``aging`` steps waited, so no request waits more than
    ``(gap to the highest steady arrival priority) * aging`` steps once
    it is the oldest waiter."""

    name = "priority"

    def __init__(self, aging: int = 8):
        if aging < 1:
            raise ValueError("aging must be >= 1")
        self.aging = aging

    def key(self, entry: _QueueEntry, step: int) -> Tuple:
        waited = max(0, step - entry.submit_step)
        effective = entry.request.priority + waited // self.aging
        return (-effective, 0 if entry.deferred else 1, entry.seq)


class SJFPolicy(SchedulingPolicy):
    """Shortest job first — job length = prompt + token budget (the
    slot-occupancy a request will cost) — with FIFO tie-break."""

    name = "sjf"

    def key(self, entry: _QueueEntry, step: int) -> Tuple:
        req = entry.request
        return (req.prompt_len + req.max_new_tokens,
                0 if entry.deferred else 1, entry.seq)


class GroupedPolicy(SchedulingPolicy):
    """Fan-out-aware admission: co-batch ``prefix_group`` siblings.

    Orders the queue so group members land in the SAME decode rounds —
    members of a group that already occupies slots jump the queue (they
    fork live pages and their rounds share the group's target
    forwards), and pending groups admit contiguously in arrival order
    via their oldest member's seq stamp as a shared anchor. Ungrouped
    traffic ranks by its own seq, so pure-ungrouped workloads reduce to
    FIFO exactly (the fallback the policy tests pin). Like every
    policy, it never changes any request's sampled events/tokens (the
    per-request rng contract) — only which requests share a batch.
    """

    name = "grouped"

    def key(self, entry: _QueueEntry, step: int) -> Tuple:
        # context-free fallback: plain FIFO
        return (0 if entry.deferred else 1, 0, entry.seq, entry.seq)

    def key_ctx(self, entry: _QueueEntry, step: int, ctx: dict) -> Tuple:
        g = entry.request.prefix_group
        anchor = entry.seq
        joins_active = False
        if g is not None:
            anchor = ctx.get("anchors", {}).get(g, entry.seq)
            joins_active = g in ctx.get("active_groups", ())
        return (0 if entry.deferred else 1, 0 if joins_active else 1,
                anchor, entry.seq)


POLICIES = {"fifo": FifoPolicy, "priority": PriorityPolicy,
            "sjf": SJFPolicy, "grouped": GroupedPolicy}


def resolve_sched_policy(
        policy: Union[str, SchedulingPolicy]) -> SchedulingPolicy:
    """A ``SchedulingPolicy`` instance from a name or a pass-through."""
    if isinstance(policy, SchedulingPolicy):
        return policy
    if policy in POLICIES:
        return POLICIES[policy]()
    raise ValueError(f"unknown scheduling policy {policy!r}; expected "
                     f"one of {sorted(POLICIES)} or a SchedulingPolicy")


class Scheduler:
    """Policy-ordered admission into a fixed pool of ``max_batch`` slots.

    A request is admitted the moment a slot is free (continuous
    batching): slots freed by a completed request are refilled at the
    next ``admit()`` call, so the batch stays as full as the queue
    allows instead of draining between "generations". One pending list
    holds queued and engine-deferred requests alike; the policy's sort
    key decides who lands next (deferral is just a flag the key may
    rank on).
    """

    def __init__(self, max_batch: int, max_len: int,
                 policy: Union[str, SchedulingPolicy] = "fifo"):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = max_batch
        self.max_len = max_len
        self.policy = resolve_sched_policy(policy)
        self.pending: List[_QueueEntry] = []
        self.slots: List[Optional[SlotState]] = [None] * max_batch
        self.step_idx = 0
        self._seq = itertools.count()

    def tick(self) -> int:
        """Advance the step counter (aging input); one call per engine
        step, before ``admit()``."""
        self.step_idx += 1
        return self.step_idx

    # -- queue side --------------------------------------------------------
    def submit(self, req: ServeRequest) -> int:
        """Validate and enqueue; returns the request id."""
        if req.prompt_len + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.request_id}: prompt ({req.prompt_len}) + "
                f"max_new_tokens ({req.max_new_tokens}) exceeds the "
                f"engine's max_len ({self.max_len})")
        self.pending.append(_QueueEntry(
            request=req, seq=next(self._seq), submit_step=self.step_idx,
            submit_t=time.perf_counter()))
        return req.request_id

    @property
    def pending_count(self) -> int:
        return len(self.pending)

    def cancel_pending(self, request_id: int) -> Optional[_QueueEntry]:
        """Remove and return the queued entry for ``request_id`` (None if
        it is not in the queue — it may be active, finished or unknown)."""
        for i, e in enumerate(self.pending):
            if e.request.request_id == request_id:
                return self.pending.pop(i)
        return None

    def take_expired(self, now: float) -> List[_QueueEntry]:
        """Remove and return pending entries whose ``deadline_s`` elapsed
        while they waited in the queue (they never get a slot)."""
        expired = [e for e in self.pending
                   if e.request.deadline_s is not None
                   and now - e.submit_t > e.request.deadline_s]
        if expired:
            gone = {id(e) for e in expired}
            self.pending = [e for e in self.pending if id(e) not in gone]
        return expired

    def shed_over(self, depth: int) -> List[_QueueEntry]:
        """Drop and return the policy-ranked tail of the queue beyond
        ``depth`` entries (overload shedding: the policy's sort key is
        the SAME order admission uses, so what sheds is exactly what
        would have been admitted last — lowest priority under
        "priority", longest job under "sjf", newest under FIFO)."""
        if depth < 0:
            raise ValueError("shed depth must be >= 0")
        if len(self.pending) <= depth:
            return []
        ctx = self._policy_ctx()
        self.pending.sort(
            key=lambda e: self.policy.key_ctx(e, self.step_idx, ctx))
        shed, self.pending = self.pending[depth:], self.pending[:depth]
        return shed

    # -- slot side ---------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _policy_ctx(self) -> dict:
        """The context the policies' ``key_ctx`` ranks on: which groups
        hold slots now, and each pending group's oldest seq anchor."""
        ctx = {"active_groups": {
                   s.request.prefix_group for s in self.slots
                   if s is not None and s.request.prefix_group is not None},
               "anchors": {}}
        for e in self.pending:
            g = e.request.prefix_group
            if g is not None:
                prev = ctx["anchors"].get(g, e.seq)
                ctx["anchors"][g] = min(prev, e.seq)
        return ctx

    def admit(self, allowed: Optional[Sequence[int]] = None,
              ) -> List[Tuple[int, SlotState]]:
        """Fill free slots in policy order (one sort per call; the keys
        only depend on the current step and the slot/queue snapshot).
        ``allowed`` restricts which slot indices admissions may land in
        (disaggregated engines admit only into prefill-worker slots)."""
        placed = []
        free = self.free_slots()
        if allowed is not None:
            ok = set(allowed)
            free = [i for i in free if i in ok]
        if not free or not self.pending:
            return placed
        ctx = self._policy_ctx()
        self.pending.sort(
            key=lambda e: self.policy.key_ctx(e, self.step_idx, ctx))
        for i in free:
            if not self.pending:
                break
            e = self.pending.pop(0)
            self.slots[i] = SlotState(
                request=e.request, slot=i, seq=e.seq,
                submit_step=e.submit_step, submit_t=e.submit_t)
            placed.append((i, self.slots[i]))
        return placed

    def active(self) -> List[Tuple[int, SlotState]]:
        return [(i, s) for i, s in enumerate(self.slots) if s is not None]

    def find_slot(self, request_id: int) -> Optional[int]:
        """The slot ``request_id`` currently occupies, or None."""
        for i, s in enumerate(self.slots):
            if s is not None and s.request.request_id == request_id:
                return i
        return None

    def retire(self, slot: int) -> SlotState:
        state = self.slots[slot]
        if state is None:
            raise ValueError(f"slot {slot} is not occupied")
        self.slots[slot] = None
        return state

    def defer(self, slot: int) -> None:
        """Undo an admission: the engine could not back the slot with
        resources (e.g. the paged KV pool is momentarily out of pages).
        The request re-enters the pending list flagged ``deferred`` with
        its original stamps, so FIFO re-admits it ahead of the queue in
        original order and aging policies keep its accumulated wait."""
        state = self.retire(slot)
        self.pending.append(_QueueEntry(
            request=state.request, seq=state.seq,
            submit_step=state.submit_step, submit_t=state.submit_t,
            deferred=True))

    def has_work(self) -> bool:
        return (bool(self.pending)
                or any(s is not None for s in self.slots))
