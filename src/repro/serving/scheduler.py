"""Continuous-batching scheduler: a FIFO queue feeding ``max_batch``
KV-cache slots.

The scheduler is pure bookkeeping — it never touches models or device
arrays, so its policies (admission order, slot reuse, per-slot budgets)
are unit-testable without JAX. The engine drives it:

    admit() -> [(slot, request)]   at the top of every step
    active() -> [(slot, SlotState)]
    retire(slot) -> SlotState      when a request's budget is spent
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional, Tuple

from .request import ServeRequest


@dataclass
class SlotState:
    """Host-side generation state of one occupied slot."""

    request: ServeRequest
    slot: int
    out: List[int] = field(default_factory=list)  # committed new tokens
    pending: int = 0      # last committed token, not yet in the caches
    round_idx: int = 1    # next fold_in index of the request's rng stream
    drafted: int = 0
    accepted: int = 0
    rounds: int = 0

    @property
    def done(self) -> bool:
        return len(self.out) >= self.request.max_new_tokens


class Scheduler:
    """FIFO admission into a fixed pool of ``max_batch`` slots.

    A request is admitted the moment a slot is free (continuous
    batching): slots freed by a completed request are refilled at the
    next ``admit()`` call, so the batch stays as full as the queue
    allows instead of draining between "generations".
    """

    def __init__(self, max_batch: int, max_len: int):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = max_batch
        self.max_len = max_len
        self.queue: Deque[ServeRequest] = deque()
        # admissions the engine undid (e.g. no KV pages free yet); they
        # are older than anything in ``queue`` and re-admit first, in
        # their original order
        self.deferred: Deque[ServeRequest] = deque()
        self.slots: List[Optional[SlotState]] = [None] * max_batch

    # -- queue side --------------------------------------------------------
    def submit(self, req: ServeRequest) -> int:
        """Validate and enqueue; returns the request id."""
        if req.prompt_len + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.request_id}: prompt ({req.prompt_len}) + "
                f"max_new_tokens ({req.max_new_tokens}) exceeds the "
                f"engine's max_len ({self.max_len})")
        self.queue.append(req)
        return req.request_id

    @property
    def pending_count(self) -> int:
        return len(self.queue) + len(self.deferred)

    # -- slot side ---------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def admit(self) -> List[Tuple[int, SlotState]]:
        """Fill free slots — deferred re-admissions first, then the
        queue head (strict FIFO across both)."""
        placed = []
        for i in self.free_slots():
            if self.deferred:
                req = self.deferred.popleft()
            elif self.queue:
                req = self.queue.popleft()
            else:
                break
            self.slots[i] = SlotState(request=req, slot=i)
            placed.append((i, self.slots[i]))
        return placed

    def active(self) -> List[Tuple[int, SlotState]]:
        return [(i, s) for i, s in enumerate(self.slots) if s is not None]

    def retire(self, slot: int) -> SlotState:
        state = self.slots[slot]
        if state is None:
            raise ValueError(f"slot {slot} is not occupied")
        self.slots[slot] = None
        return state

    def defer(self, slot: int) -> None:
        """Undo an admission: the engine could not back the slot with
        resources (e.g. the paged KV pool is momentarily out of pages).
        The request joins the deferred list — ahead of the queue and in
        original order even when several admissions defer in one step —
        and retries when pages free up."""
        state = self.retire(slot)
        self.deferred.append(state.request)

    def has_work(self) -> bool:
        return (bool(self.queue) or bool(self.deferred)
                or any(s is not None for s in self.slots))
