"""Cross-request radix prefix cache over retained KV pages.

Millions of users share system prompts and common event histories; the
dominant redundant serving cost is re-prefilling those shared prefixes
for every request. This cache closes the loop the copy-on-write pool
opens: when a request retires, the FULL pages holding its prompt's K/V
are donated into a radix tree keyed by the prompt tokens (the cache
becomes an owner through ``PagedKVCachePool.retain``); when a new
request is admitted, its prompt walks the tree page by page and every
matched page is adopted straight into the new slot's block table —
prefill restarts at the divergence point, so a fully-cached prompt
costs (almost) zero prefill tokens.

Structure: a radix tree at PAGE granularity. Every edge is labelled by
one page's worth of token ids (``page_size`` tokens) and every node
pins exactly one physical page per pool (the target pool, plus the
draft pool under speculative decoding — both prefilled the same
prompt, so they hit and miss together). Page granularity keeps
adoption a pure block-table splice: a matched node's page slots
directly into the new table, and because matches are always
page-aligned the adopting slot's first write lands in a FRESH page —
cache adoption never needs a copy-on-write.

Eviction is LRU over leaves (deepest-first by construction: a node can
only be dropped once its children are gone, which releases pages in
longest-prefix-first order). The pool calls back into ``evict`` when
its free list runs dry and counts ``evictable`` pages as admission
headroom, so retaining pages NEVER reduces the pool capacity the
PR 4 lifetime-reservation admission reasons about: any page held only
by the cache (refcount 1) is reclaimable synchronously inside
``ensure_blocks``/``can_admit``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["PrefixCache", "PrefixCacheStats", "tpp_history_key",
           "TPP_DT_QUANTUM", "TPP_DT_LEVELS"]

# TPP event-history key quantization: inter-event times are bucketed at
# this resolution before entering the radix tree. Histories whose
# inter-event gaps differ by less than a quantum collide onto the same
# key — an approximation the engine never *relies* on for correctness
# (forecast queries over the same history array produce identical keys,
# which is the sharing the workload needs; a sub-quantum-different
# history adopting the page reuses K/V of an epsilon-shifted twin).
TPP_DT_QUANTUM = 1e-6
TPP_DT_LEVELS = 1 << 21


def tpp_history_key(times, marks, *, dt: float = TPP_DT_QUANTUM,
                    levels: int = TPP_DT_LEVELS) -> np.ndarray:
    """Radix-tree keys for a TPP event history.

    The tree matches runs of ints, so the TPP domain keys each encoder
    position by ``mark * levels + quantized inter-event gap``. Because
    the encoder input anchors at the BOS sentinel (t = 0), the gap
    sequence determines every absolute time: equal key runs => equal
    (quantized) encoder inputs => equal K/V pages.

    ``times``/``marks``: [N] absolute times / int marks of the ENCODER
    input (BOS + history[:-1] in the serving engine's convention).
    Returns [N] int64 keys.
    """
    t = np.asarray(times, np.float64).reshape(-1)
    m = np.asarray(marks, np.int64).reshape(-1)
    if t.shape != m.shape:
        raise ValueError("times and marks must have matching lengths")
    gaps = np.diff(t, prepend=0.0)
    q = np.minimum(np.round(gaps / dt).astype(np.int64), levels - 1)
    q = np.maximum(q, 0)
    return m * np.int64(levels) + q


class PrefixCacheStats:
    """Counters the engine folds into ``EngineStats``."""

    def __init__(self):
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.inserted_pages = 0
        self.evicted_pages = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / max(1, self.lookups)

    def describe(self) -> str:
        return (f"lookups={self.lookups} hits={self.hits} "
                f"hit_rate={self.hit_rate:.2f} "
                f"hit_tokens={self.hit_tokens} "
                f"inserted_pages={self.inserted_pages} "
                f"evicted_pages={self.evicted_pages}")


class _Node:
    """One radix node == one cached page per pool. ``tokens`` is the
    page-sized token run labelling the edge from the parent."""

    __slots__ = ("tokens", "pages", "children", "parent", "last_used")

    def __init__(self, tokens: Tuple[int, ...], pages: Dict[str, int],
                 parent: Optional["_Node"], clock: int):
        self.tokens = tokens
        self.pages = pages            # pool key -> physical page id
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.parent = parent
        self.last_used = clock


class PrefixCache:
    """Radix tree mapping token prefixes to retained page runs.

    ``pools`` maps a short key ("t" target, "d" draft) to the
    ``PagedKVCachePool`` whose pages the tree pins. All pools must use
    the same ``page_size`` (they prefill the same prompts in lockstep).

    The tree is agnostic to what the ints MEAN: the token domain passes
    prompt token ids, the TPP domain passes ``tpp_history_key`` outputs
    (mark x quantized inter-event gap per encoder position), so
    repeated forecast queries over a shared event history hit the same
    nodes token prompts do.
    """

    def __init__(self, page_size: int, pools: Dict[str, object]):
        if not pools:
            raise ValueError("PrefixCache needs at least one pool")
        self.page = page_size
        self.pools = dict(pools)
        self.root = _Node((), {}, None, 0)
        self._clock = 0
        self.stats = PrefixCacheStats()
        for key, pool in self.pools.items():
            pool.evictor = (lambda n, k=key: self.evict(k, n))
            pool.evictable = (lambda k=key: self.evictable(k))

    # -- introspection -----------------------------------------------------
    def _nodes(self) -> List[_Node]:
        out, stack = [], list(self.root.children.values())
        while stack:
            n = stack.pop()
            out.append(n)
            stack.extend(n.children.values())
        return out

    @property
    def n_nodes(self) -> int:
        return len(self._nodes())

    def evictable(self, pool_key: str) -> int:
        """Pages of ``pool_key`` the cache alone still holds (refcount
        1): every one of them is reclaimable by (possibly cascaded)
        leaf eviction, so admission may count them as headroom."""
        pool = self.pools[pool_key]
        return sum(1 for n in self._nodes()
                   if pool_key in n.pages
                   and int(pool.refcount[n.pages[pool_key]]) == 1)

    # -- lookup ------------------------------------------------------------
    def match(self, tokens, max_tokens: int):
        """Longest page-aligned prefix match.

        Returns ``(hit_tokens, {pool_key: [page_id, ...]})`` where
        ``hit_tokens`` is a multiple of the page size, capped at
        ``max_tokens`` (callers pass ``prompt_len - 1`` so at least one
        prompt token always remains to prefill — the token that
        produces the first-sample logits). Matched nodes' LRU stamps
        are refreshed; adoption refcounts are the CALLER's move
        (``PagedKVCachePool.adopt``)."""
        toks = np.asarray(tokens).reshape(-1)
        n_pages = min(len(toks), max(0, max_tokens)) // self.page
        node = self.root
        runs: Dict[str, List[int]] = {k: [] for k in self.pools}
        hit = 0
        self._clock += 1
        self.stats.lookups += 1
        for i in range(n_pages):
            key = tuple(int(t) for t in toks[i * self.page:
                                             (i + 1) * self.page])
            child = node.children.get(key)
            if child is None:
                break
            node = child
            node.last_used = self._clock
            for k in runs:
                runs[k].append(node.pages[k])
            hit += self.page
        if hit:
            self.stats.hits += 1
            self.stats.hit_tokens += hit
        return hit, runs

    # -- donation ----------------------------------------------------------
    def insert(self, tokens, pages: Dict[str, List[int]]) -> int:
        """Donate a retiring slot's FULL prompt pages into the tree.

        ``pages[pool_key][i]`` is the physical page holding tokens
        ``[i*page, (i+1)*page)``. Nodes that already exist keep their
        own (identical-content) pages — the donor's copies are released
        by the caller's ``free_slot`` as usual; new nodes RETAIN the
        donated pages (refcount bump), so the subsequent ``free_slot``
        hands ownership to the cache instead of freeing. Returns the
        number of newly retained pages (per pool)."""
        toks = np.asarray(tokens).reshape(-1)
        n_pages = min(len(toks) // self.page,
                      *(len(v) for v in pages.values()))
        node = self.root
        self._clock += 1
        new_pages = 0
        for i in range(n_pages):
            key = tuple(int(t) for t in toks[i * self.page:
                                             (i + 1) * self.page])
            child = node.children.get(key)
            if child is None:
                own = {k: int(pages[k][i]) for k in self.pools}
                for k, pid in own.items():
                    self.pools[k].retain(pid)
                child = _Node(key, own, node, self._clock)
                node.children[key] = child
                new_pages += 1
                self.stats.inserted_pages += 1
            else:
                child.last_used = self._clock
            node = child
        return new_pages

    # -- eviction ----------------------------------------------------------
    def evict(self, pool_key: str, n: int) -> int:
        """Drop LRU leaves until >= ``n`` pages of ``pool_key`` went
        back to that pool's free list (or the tree is empty). Evicting
        a node releases its pages in EVERY pool; pages still adopted by
        a live slot (refcount > 1) just lose the cache's reference and
        free later when the slot retires. Returns pages actually freed
        for ``pool_key``."""
        freed = 0
        while freed < n:
            leaves = [nd for nd in self._nodes() if not nd.children]
            if not leaves:
                break
            victim = min(leaves, key=lambda nd: nd.last_used)
            for k, pid in victim.pages.items():
                if self.pools[k].release(pid) and k == pool_key:
                    freed += 1
                self.stats.evicted_pages += 1
            parent = victim.parent
            del parent.children[victim.tokens]
        return freed

    def clear(self, release: bool = True) -> None:
        """Drop every node. ``release=True`` returns the cache's page
        references to the pools; the engine's ``reset`` passes False
        because the pools rebuild their free lists wholesale."""
        if release:
            for nd in self._nodes():
                for k, pid in nd.pages.items():
                    self.pools[k].release(pid)
        self.root = _Node((), {}, None, 0)
