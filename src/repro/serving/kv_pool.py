"""Pooled per-slot KV caches for continuous batching.

Every model family in ``repro.models.registry`` serves single requests
through ``prefill``/``extend`` on a batch-1 cache. The pool stacks
``max_batch`` such caches on a new leading slot axis, so one
``jax.vmap``-ped ``extend`` runs a target forward for every active slot
simultaneously — each slot keeping its own length counter (``len``
becomes a per-slot array under the stack), which is what lets requests
of different ages share one device call.

Rollback after a speculative round is family-dependent, mirroring
``core.llm_sd``:

  - ``mask`` (dense / moe / vlm) and ``encdec``: O(1) per slot — stale
    entries are invalidated through the position buffer, vmapped over
    the pool with per-slot new lengths.
  - ``replay`` (ssm / hybrid): recurrent states cannot be length-masked;
    the engine re-extends the committed prefix from the round-entry
    checkpoint (the immutable pool tree itself) per slot.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..models import transformer as tfm

_MASK_FAMILIES = {"dense", "moe", "vlm"}


def rollback_kind(cfg) -> str:
    """"mask" | "encdec" | "replay" — how this family rolls back."""
    if cfg.family in _MASK_FAMILIES:
        return "mask"
    if cfg.family == "encdec":
        return "encdec"
    return "replay"


def rollback_one(cfg, cache, new_len):
    """Mask-style rollback of ONE slot's cache to ``new_len`` entries.

    Only valid for mask/encdec kinds; vmap over (cache, new_len) to roll
    back a whole pool. Replay kinds re-extend instead (see engine).
    """
    kind = rollback_kind(cfg)
    if kind == "mask":
        return tfm.rollback(cache, new_len)
    if kind == "encdec":
        out = dict(cache)
        out["pos"] = jnp.where(cache["pos"] < new_len, cache["pos"],
                               jnp.iinfo(jnp.int32).max)
        out["len"] = jnp.asarray(new_len, jnp.int32)
        return out
    raise ValueError(f"family {cfg.family!r} rolls back by replay")


def select_slots(mask, new_tree, old_tree):
    """Per-slot where(): keep ``new`` rows where ``mask`` is True.

    Used to discard the garbage a batched forward writes into idle
    slots (padding lanes run the model on stale data).
    """
    def pick(new, old):
        m = mask.reshape(mask.shape + (1,) * (new.ndim - 1))
        return jnp.where(m, new, old)
    return jax.tree.map(pick, new_tree, old_tree)


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


class KVCachePool:
    """``max_batch`` stacked batch-1 caches with slot read/write.

    The pool tree is allocated lazily from the first prefilled cache (so
    one pool class covers every family's cache pytree, including the
    encoder-decoder cross caches). Leaves are ``[slot, ...]``; reads and
    writes are functional index ops on the immutable tree.

    Pass ``rules`` (``distributed.sharding.Rules``) plus the family's
    ``cache_axes`` tree to allocate the pool SHARDED on the rules' mesh:
    the slot axis is placed through the "batch" rule (the data axis) and
    each cache dim through its own logical axis (e.g. kv_heads over the
    serving mesh's kv axis), so the engine's batched round runs as one
    GSPMD program partitioned over slots. Host-side slot reads/writes
    stay functional index ops — GSPMD gathers what they touch.
    """

    def __init__(self, n_slots: int, rules=None, cache_axes=None):
        self.n_slots = n_slots
        self.tree: Optional[Any] = None
        self._rules = rules
        self._axes = cache_axes
        self.shardings: Optional[Any] = None

    def ensure(self, template_cache) -> None:
        """Allocate the pool from a batch-1 cache's shapes/dtypes."""
        if self.tree is not None:
            return
        if self._rules is None:
            self.tree = jax.tree.map(
                lambda a: jnp.zeros((self.n_slots,) + jnp.shape(a),
                                    jnp.asarray(a).dtype),
                template_cache)
            return

        def alloc(axes, a):
            shape = (self.n_slots,) + tuple(jnp.shape(a))
            # leading slot dim maps through "batch" -> data; the cache's
            # own batch-1 dim (also logical "batch") is dropped by the
            # rules' no-axis-reuse guard and stays whole
            sh = self._rules.sharding(("batch",) + tuple(axes), dims=shape)
            return jax.device_put(
                jnp.zeros(shape, jnp.asarray(a).dtype), sh)

        self.tree = jax.tree.map(alloc, self._axes, template_cache,
                                 is_leaf=_is_axes_leaf)
        self.shardings = jax.tree.map(lambda a: a.sharding, self.tree)

    def write(self, slot: int, cache) -> None:
        self.tree = jax.tree.map(
            lambda pool, c: pool.at[slot].set(jnp.asarray(
                c, pool.dtype)), self.tree, cache)

    def read(self, slot: int):
        return jax.tree.map(lambda pool: pool[slot], self.tree)

    @property
    def lens(self) -> jnp.ndarray:
        """Per-slot valid lengths ([n_slots] int32)."""
        return self.tree["len"]
