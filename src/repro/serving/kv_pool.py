"""Pooled per-slot KV caches for continuous batching.

Every model family in ``repro.models.registry`` serves single requests
through ``prefill``/``extend`` on a batch-1 cache. The pool stacks
``max_batch`` such caches on a new leading slot axis, so one
``jax.vmap``-ped ``extend`` runs a target forward for every active slot
simultaneously — each slot keeping its own length counter (``len``
becomes a per-slot array under the stack), which is what lets requests
of different ages share one device call.

Rollback after a speculative round is family-dependent, mirroring
``core.llm_sd``:

  - ``mask`` (dense / moe / vlm) and ``encdec``: O(1) per slot — stale
    entries are invalidated through the position buffer, vmapped over
    the pool with per-slot new lengths.
  - ``replay`` (ssm / hybrid): recurrent states cannot be length-masked;
    the engine re-extends the committed prefix from the round-entry
    checkpoint (the immutable pool tree itself) per slot.
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as tfm

_MASK_FAMILIES = {"dense", "moe", "vlm"}


def rollback_kind(cfg) -> str:
    """"mask" | "encdec" | "replay" — how this family rolls back."""
    if cfg.family in _MASK_FAMILIES:
        return "mask"
    if cfg.family == "encdec":
        return "encdec"
    return "replay"


def paged_supported(cfg) -> bool:
    """Paged KV needs the transformer mask families with a non-ring
    cache: slot == position is what makes rollback a pure block-table
    truncation. Ring buffers (sliding windows), recurrent replay
    families and the enc-dec cross caches stay on the dense pool."""
    return rollback_kind(cfg) == "mask" and cfg.sliding_window == 0


def rollback_one(cfg, cache, new_len):
    """Mask-style rollback of ONE slot's cache to ``new_len`` entries.

    Only valid for mask/encdec kinds; vmap over (cache, new_len) to roll
    back a whole pool. Replay kinds re-extend instead (see engine).
    """
    kind = rollback_kind(cfg)
    if kind == "mask":
        return tfm.rollback(cache, new_len)
    if kind == "encdec":
        out = dict(cache)
        out["pos"] = jnp.where(cache["pos"] < new_len, cache["pos"],
                               jnp.iinfo(jnp.int32).max)
        out["len"] = jnp.asarray(new_len, jnp.int32)
        return out
    raise ValueError(f"family {cfg.family!r} rolls back by replay")


def select_slots(mask, new_tree, old_tree):
    """Per-slot where(): keep ``new`` rows where ``mask`` is True.

    Used to discard the garbage a batched forward writes into idle
    slots (padding lanes run the model on stale data).
    """
    def pick(new, old):
        m = mask.reshape(mask.shape + (1,) * (new.ndim - 1))
        return jnp.where(m, new, old)
    return jax.tree.map(pick, new_tree, old_tree)


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


class KVCachePool:
    """``max_batch`` stacked batch-1 caches with slot read/write.

    The pool tree is allocated lazily from the first prefilled cache (so
    one pool class covers every family's cache pytree, including the
    encoder-decoder cross caches). Leaves are ``[slot, ...]``; reads and
    writes are functional index ops on the immutable tree.

    Pass ``rules`` (``distributed.sharding.Rules``) plus the family's
    ``cache_axes`` tree to allocate the pool SHARDED on the rules' mesh:
    the slot axis is placed through the "batch" rule (the data axis) and
    each cache dim through its own logical axis (e.g. kv_heads over the
    serving mesh's kv axis), so the engine's batched round runs as one
    GSPMD program partitioned over slots. Host-side slot reads/writes
    stay functional index ops — GSPMD gathers what they touch.
    """

    def __init__(self, n_slots: int, rules=None, cache_axes=None):
        self.n_slots = n_slots
        self.tree: Optional[Any] = None
        self._rules = rules
        self._axes = cache_axes
        self.shardings: Optional[Any] = None

    def ensure(self, template_cache) -> None:
        """Allocate the pool from a batch-1 cache's shapes/dtypes."""
        if self.tree is not None:
            return
        if self._rules is None:
            self.tree = jax.tree.map(
                lambda a: jnp.zeros((self.n_slots,) + jnp.shape(a),
                                    jnp.asarray(a).dtype),
                template_cache)
            return

        def alloc(axes, a):
            shape = (self.n_slots,) + tuple(jnp.shape(a))
            # leading slot dim maps through "batch" -> data; the cache's
            # own batch-1 dim (also logical "batch") is dropped by the
            # rules' no-axis-reuse guard and stays whole
            sh = self._rules.sharding(("batch",) + tuple(axes), dims=shape)
            return jax.device_put(
                jnp.zeros(shape, jnp.asarray(a).dtype), sh)

        self.tree = jax.tree.map(alloc, self._axes, template_cache,
                                 is_leaf=_is_axes_leaf)
        self.shardings = jax.tree.map(lambda a: a.sharding, self.tree)

    def write(self, slot: int, cache) -> None:
        self.tree = jax.tree.map(
            lambda pool, c: pool.at[slot].set(jnp.asarray(
                c, pool.dtype)), self.tree, cache)

    def read(self, slot: int):
        return jax.tree.map(lambda pool: pool[slot], self.tree)

    @property
    def lens(self) -> jnp.ndarray:
        """Per-slot valid lengths ([n_slots] int32)."""
        return self.tree["len"]

    def reset(self) -> None:
        """Nothing to do: slot contents are stale after an engine reset
        and admission overwrites a slot's cache before it is read."""


class PagedKVCachePool:
    """Block-table paged KV pool (transformer mask families).

    Physical pages ``pages = {"k","v"} [L, P, page, KV, Dh]`` are shared
    by every slot; each slot owns an ordered list of pages (its block
    table) covering positions ``0..len-1``. Page 0 is a reserved null
    page: free slots' tables point at it, so the batched round's writes
    for idle lanes land in sacrificial memory and no ``select_slots``
    restore pass is needed.

    Pages are REFCOUNTED and copy-on-write. ``fork(src, dst, upto)``
    shares every page covering ``[0, upto)`` between the two block
    tables (a table copy plus refcount bumps — no K/V movement), which
    is what makes K-way scenario fan-out and the cross-request prefix
    cache near-free: a forked continuation pays pages only for its
    divergent tail. Writes always land at positions ``>= lens[slot]``,
    so at most ONE shared page per slot is ever writable — the boundary
    page ``lens // page`` when ``lens`` is mid-page; ``cow_for_append``
    copies it to a fresh page on first divergent write (callers invoke
    it before every append). A page returns to the free list only when
    its refcount reaches 0 (``truncate``/``free_slot`` release, never
    blind-free). The prefix cache holds references of its own through
    ``retain``/``release``; when the free list runs dry the pool asks
    the cache to evict (``evictor``/``evictable`` hooks), so
    cache-retained pages still count as admissible headroom and the
    PR 4 lifetime-reservation invariant survives retained pages.

    Allocation is by actual lengths — admission reserves a request's
    lifetime need up front (``can_admit``/``reserve``) but draws pages
    only as content arrives: chunked prefill grows the table one chunk
    at a time (``ensure_blocks`` per chunk, always inside the
    reservation, so a partially-prefilled slot can never be starved by
    its batch-mates), every decode round grows just enough for its
    gamma+1 writes, finish returns everything — so total page memory
    can be provisioned below ``n_slots * max_len`` (``n_pages=``);
    admission defers when the pool is momentarily out of pages.
    Rollback after a rejected window is a block-table truncation:
    lengths shrink, surplus pages are released, and the stale K/V left
    behind is causally invisible (logical position > any live query)
    until overwritten.

    Host-side state (tables, lengths, refcounts, free list) is numpy;
    only the page arrays live on device.
    """

    def __init__(self, n_slots: int, cfg, *, page_size: int = 16,
                 max_len: int = 256, n_pages: Optional[int] = None,
                 init_pages=None):
        if init_pages is None and not paged_supported(cfg):
            raise ValueError(f"family {cfg.family!r} (window="
                             f"{cfg.sliding_window}) cannot use the paged "
                             "pool")
        self.n_slots = n_slots
        self.cfg = cfg
        self.page = page_size
        self.capacity = max_len                 # logical positions per slot
        self.blocks_per_slot = -(-max_len // page_size)
        if n_pages is None:
            n_pages = n_slots * self.blocks_per_slot + 1
        if n_pages < self.blocks_per_slot + 1:
            raise ValueError("n_pages must cover at least one full slot")
        self.n_pages = n_pages
        # page-array factory: the transformer layout by default; other
        # domains (the TPP encoder) pass their own ``init_pages`` — the
        # host-side table/refcount machinery is layout-agnostic
        factory = tfm.init_kv_pages if init_pages is None else init_pages
        self.pages = factory(cfg, n_pages, page_size)
        self.tables = np.zeros((n_slots, self.blocks_per_slot), np.int32)
        self.lens = np.zeros((n_slots,), np.int32)
        self.n_blocks = np.zeros((n_slots,), np.int32)
        # lifetime reservation per slot (blocks), set at admission
        self.reserved = np.zeros((n_slots,), np.int32)
        # owners per page: slot tables holding it + (0/1) cache retain
        self.refcount = np.zeros((n_pages,), np.int32)
        self.free: List[int] = list(range(n_pages - 1, 0, -1))  # 0 = null
        # prefix-cache reclaim hooks: evictor(n) frees >= n pages of this
        # pool if it can (LRU cache eviction); evictable() counts pages
        # only the cache still holds (refcount 1) — admissible headroom
        self.evictor = None     # Optional[Callable[[int], int]]
        self.evictable = None   # Optional[Callable[[], int]]
        self.cow_copies = 0     # lifetime copy-on-write page copies

    # -- host bookkeeping --------------------------------------------------
    def _blocks_for(self, length: int) -> int:
        return -(-max(length, 0) // self.page)

    def _headroom(self) -> int:
        """Pages drawable right now: the free list plus whatever LRU
        cache eviction could hand back synchronously."""
        extra = self.evictable() if self.evictable is not None else 0
        return len(self.free) + extra

    def _cow_pending(self, slot: int) -> int:
        """1 iff this slot's next append must copy a shared boundary
        page first (its write frontier sits mid-page in a page with
        refcount > 1). Counted into the shortfall so reservations stay
        honest under sharing."""
        length = int(self.lens[slot])
        if length % self.page == 0:
            return 0
        b = length // self.page
        if b >= int(self.n_blocks[slot]):
            return 0
        return 1 if int(self.refcount[self.tables[slot, b]]) > 1 else 0

    def _shortfall(self) -> int:
        """Blocks the admitted slots may still claim against their
        reservations, plus one page per pending copy-on-write (a COW
        swaps a shared page for a fresh one without growing the table,
        so it draws from the free list outside ``reserved - n_blocks``).
        """
        out = int(np.maximum(self.reserved - self.n_blocks, 0).sum())
        return out + sum(self._cow_pending(s) for s in range(self.n_slots))

    def can_admit(self, total_len: int, *, adopted_blocks: int = 0,
                  cow_pages: int = 0) -> bool:
        """Admission check against the request's WHOLE lifetime need
        (prompt + budget, clamped to capacity), on top of every
        already-admitted slot's outstanding reservation. Conservative on
        purpose: once admitted under a reservation, a gamma=1 round's
        growth always fits (the engine shrinks larger batch windows to
        the free list), so an under-provisioned pool admits fewer
        concurrent requests instead of deadlocking mid-stream.

        ``adopted_blocks`` pages arrive shared (prefix-cache hit or
        fork) and are never drawn from the free list; ``cow_pages``
        budgets the copy-on-write pages the admission CREATES — a fork
        whose shared prefix ends mid-page makes the forked slot's first
        append a COW, and (when the boundary page was unshared before)
        turns the source's own next append into one too, so callers
        pass the number of NEW pending COWs this admission introduces
        (the standing ones are already in ``_shortfall``).

        Adopted pages are discounted from the EVICTABLE side of the
        headroom too: adopting a cache-held page bumps it to refcount 2,
        so it stops being reclaimable the moment this admission lands —
        counting it as headroom for this request's own tail would admit
        a request whose prefill then finds the free list dry.
        (Conservative for forks, whose adopted pages were never
        cache-evictable; an over-tight check only defers.)"""
        need = self._blocks_for(min(total_len, self.capacity))
        need = max(0, need - adopted_blocks) + cow_pages
        extra = self.evictable() if self.evictable is not None else 0
        headroom = len(self.free) + max(0, extra - adopted_blocks)
        return headroom >= self._shortfall() + need

    def reserve(self, slot: int, total_len: int) -> None:
        self.reserved[slot] = self._blocks_for(min(total_len,
                                                   self.capacity))

    def _alloc_page(self) -> int:
        if not self.free and self.evictor is not None:
            self.evictor(1)
        if not self.free:
            raise RuntimeError(
                "paged KV pool out of pages; raise n_pages or lower "
                "max_batch")
        pid = self.free.pop()
        self.refcount[pid] = 1
        return pid

    def retain(self, pid: int) -> None:
        """Add an owner to an allocated page (fork adoption / prefix
        cache donation)."""
        if pid <= 0 or self.refcount[pid] < 1:
            raise ValueError(f"retain of unallocated page {pid}")
        self.refcount[pid] += 1

    def release(self, pid: int) -> bool:
        """Drop one owner; returns True when the page went back to the
        free list (refcount reached 0)."""
        if pid <= 0 or self.refcount[pid] < 1:
            raise ValueError(f"release of unallocated page {pid}")
        self.refcount[pid] -= 1
        if self.refcount[pid] == 0:
            self.free.append(pid)
            return True
        return False

    def ensure_blocks(self, slot: int, new_len: int) -> None:
        """Grow the slot's table to cover ``new_len`` positions."""
        need = self._blocks_for(min(new_len, self.capacity))
        have = int(self.n_blocks[slot])
        if need <= have:
            return
        if self._headroom() < need - have:
            raise RuntimeError(
                f"paged KV pool out of pages ({len(self.free)} free, "
                f"{need - have} needed); raise n_pages or lower max_batch")
        for b in range(have, need):
            self.tables[slot, b] = self._alloc_page()
        self.n_blocks[slot] = need

    def cow_for_append(self, slot: int) -> bool:
        """Copy-on-first-divergent-write: if the slot's write frontier
        sits mid-page inside a SHARED page, copy that page's K/V to a
        fresh page and swap the table entry, so the upcoming append
        never mutates another owner's prefix. Callers run this before
        every append (decode round growth / prefill chunk); all other
        shared pages are strictly behind the frontier and are never
        written again, so one boundary check is complete."""
        if not self._cow_pending(slot):
            return False
        b = int(self.lens[slot]) // self.page
        old = int(self.tables[slot, b])
        new = self._alloc_page()
        self.pages = {
            name: arr.at[:, new].set(arr[:, old])
            for name, arr in self.pages.items()}
        self.refcount[old] -= 1         # was > 1: never frees here
        self.tables[slot, b] = new
        self.cow_copies += 1
        return True

    def fork(self, src: int, dst: int, upto_len: int) -> int:
        """Share ``src``'s pages covering positions ``[0, upto_len)``
        into empty slot ``dst`` (block-table copy + refcount bumps; no
        K/V moves). ``dst`` continues from ``upto_len``; its first
        append copy-on-writes the boundary page if ``upto_len`` is
        mid-page. Returns the number of shared pages."""
        if int(self.n_blocks[dst]) != 0 or int(self.lens[dst]) != 0:
            raise ValueError(f"fork target slot {dst} is not empty")
        upto_len = min(upto_len, self.capacity)
        nb = self._blocks_for(upto_len)
        if nb > int(self.n_blocks[src]) or upto_len > int(self.lens[src]):
            raise ValueError(
                f"fork: source slot {src} covers {int(self.lens[src])} "
                f"positions, cannot share {upto_len}")
        for b in range(nb):
            pid = int(self.tables[src, b])
            self.tables[dst, b] = pid
            self.retain(pid)
        self.n_blocks[dst] = nb
        self.lens[dst] = upto_len
        return nb

    def transfer_slot(self, src: int, dst: int) -> int:
        """Move ``src``'s whole cache to empty slot ``dst`` — the
        disaggregated prefill→decode handoff. Pure block-table
        transfer: each page is retained into ``dst`` then released
        from ``src`` (``free_slot``), so net refcounts are unchanged,
        the free list is untouched, and zero K/V bytes move. Shared
        pages (fork/prefix-cache) stay shared — ownership of ``src``'s
        REFERENCES moves, not the pages themselves. Returns the number
        of pages transferred."""
        if int(self.n_blocks[dst]) != 0 or int(self.lens[dst]) != 0:
            raise ValueError(f"transfer target slot {dst} is not empty")
        nb = int(self.n_blocks[src])
        for b in range(nb):
            pid = int(self.tables[src, b])
            self.retain(pid)
            self.tables[dst, b] = pid
        self.n_blocks[dst] = nb
        self.lens[dst] = int(self.lens[src])
        self.reserved[dst] = int(self.reserved[src])
        self.free_slot(src)
        return nb

    def adopt(self, slot: int, page_ids: List[int]) -> None:
        """Adopt a prefix-cache run of FULL pages into an empty slot:
        the matched prefix is already resident, prefill resumes at
        ``len(page_ids) * page``."""
        if int(self.n_blocks[slot]) != 0 or int(self.lens[slot]) != 0:
            raise ValueError(f"adopt target slot {slot} is not empty")
        for b, pid in enumerate(page_ids):
            self.retain(int(pid))
            self.tables[slot, b] = int(pid)
        self.n_blocks[slot] = len(page_ids)
        self.lens[slot] = len(page_ids) * self.page

    def truncate(self, slot: int, new_len: int) -> None:
        """Rollback/commit: set the committed length, release surplus
        pages (freed only at refcount 0 — shared pages survive in their
        other owners' tables; no K/V rewrite either way)."""
        keep = self._blocks_for(new_len)
        for b in range(keep, int(self.n_blocks[slot])):
            self.release(int(self.tables[slot, b]))
            self.tables[slot, b] = 0
        self.n_blocks[slot] = keep
        self.lens[slot] = new_len

    def free_slot(self, slot: int) -> None:
        self.truncate(slot, 0)
        self.reserved[slot] = 0

    def seize_free(self) -> List[int]:
        """Take the whole free list (fault injection: forced page
        exhaustion). The pool keeps running — allocations fail or fall
        back to cache eviction until ``restore_free`` hands the pages
        back; pages released while seized join the (empty) list as
        usual, so seize/restore never loses or duplicates a page."""
        pages, self.free = self.free, []
        return pages

    def restore_free(self, pages: List[int]) -> None:
        """Return pages taken by ``seize_free``."""
        self.free.extend(pages)

    def reset(self) -> None:
        """Return every page; keep the allocated page arrays (stale
        contents are overwritten before being readable). Rebuilds the
        free list wholesale, so cache-retained pages come back too —
        callers clear the prefix cache alongside."""
        self.tables[:] = 0
        self.lens[:] = 0
        self.n_blocks[:] = 0
        self.reserved[:] = 0
        self.refcount[:] = 0
        self.free = list(range(self.n_pages - 1, 0, -1))

    # -- device views ------------------------------------------------------
    def device_tables(self) -> jnp.ndarray:
        return jnp.asarray(self.tables)

    def device_lens(self) -> jnp.ndarray:
        return jnp.asarray(self.lens)

    # -- admission ---------------------------------------------------------
    def write_prefill(self, slot: int, cache) -> None:
        """Staging fallback: scatter a dense batch-1 prefilled cache
        into freshly allocated pages. The production admission path
        prefills THROUGH the pool in chunks (``transformer.prefill_paged``
        + per-chunk ``ensure_blocks`` — no dense staging buffer); this
        remains for chunking disabled, requests with extra prefill
        fields (VLM vision prefixes), and as the engine's
        chunked == staged equivalence oracle."""
        length = min(int(cache["len"]), self.capacity)
        self.ensure_blocks(slot, length)
        nb = self._blocks_for(length)
        if nb == 0:
            self.lens[slot] = 0
            return
        k = cache["k"][:, 0]                       # [L, max_len, KV, Dh]
        v = cache["v"][:, 0]
        pad = nb * self.page - k.shape[1]
        if pad > 0:
            widths = ((0, 0), (0, pad), (0, 0), (0, 0))
            k = jnp.pad(k, widths)
            v = jnp.pad(v, widths)
        L, _, KV, Dh = k.shape
        ids = jnp.asarray(self.tables[slot, :nb])
        kb = k[:, :nb * self.page].reshape(L, nb, self.page, KV, Dh)
        vb = v[:, :nb * self.page].reshape(L, nb, self.page, KV, Dh)
        self.pages = {
            "k": self.pages["k"].at[:, ids].set(kb.astype(
                self.pages["k"].dtype)),
            "v": self.pages["v"].at[:, ids].set(vb.astype(
                self.pages["v"].dtype)),
        }
        self.lens[slot] = length
