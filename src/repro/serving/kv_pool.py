"""Pooled per-slot KV caches for continuous batching.

Every model family in ``repro.models.registry`` serves single requests
through ``prefill``/``extend`` on a batch-1 cache. The pool stacks
``max_batch`` such caches on a new leading slot axis, so one
``jax.vmap``-ped ``extend`` runs a target forward for every active slot
simultaneously — each slot keeping its own length counter (``len``
becomes a per-slot array under the stack), which is what lets requests
of different ages share one device call.

Rollback after a speculative round is family-dependent, mirroring
``core.llm_sd``:

  - ``mask`` (dense / moe / vlm) and ``encdec``: O(1) per slot — stale
    entries are invalidated through the position buffer, vmapped over
    the pool with per-slot new lengths.
  - ``replay`` (ssm / hybrid): recurrent states cannot be length-masked;
    the engine re-extends the committed prefix from the round-entry
    checkpoint (the immutable pool tree itself) per slot.
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as tfm

_MASK_FAMILIES = {"dense", "moe", "vlm"}


def rollback_kind(cfg) -> str:
    """"mask" | "encdec" | "replay" — how this family rolls back."""
    if cfg.family in _MASK_FAMILIES:
        return "mask"
    if cfg.family == "encdec":
        return "encdec"
    return "replay"


def paged_supported(cfg) -> bool:
    """Paged KV needs the transformer mask families with a non-ring
    cache: slot == position is what makes rollback a pure block-table
    truncation. Ring buffers (sliding windows), recurrent replay
    families and the enc-dec cross caches stay on the dense pool."""
    return rollback_kind(cfg) == "mask" and cfg.sliding_window == 0


def rollback_one(cfg, cache, new_len):
    """Mask-style rollback of ONE slot's cache to ``new_len`` entries.

    Only valid for mask/encdec kinds; vmap over (cache, new_len) to roll
    back a whole pool. Replay kinds re-extend instead (see engine).
    """
    kind = rollback_kind(cfg)
    if kind == "mask":
        return tfm.rollback(cache, new_len)
    if kind == "encdec":
        out = dict(cache)
        out["pos"] = jnp.where(cache["pos"] < new_len, cache["pos"],
                               jnp.iinfo(jnp.int32).max)
        out["len"] = jnp.asarray(new_len, jnp.int32)
        return out
    raise ValueError(f"family {cfg.family!r} rolls back by replay")


def select_slots(mask, new_tree, old_tree):
    """Per-slot where(): keep ``new`` rows where ``mask`` is True.

    Used to discard the garbage a batched forward writes into idle
    slots (padding lanes run the model on stale data).
    """
    def pick(new, old):
        m = mask.reshape(mask.shape + (1,) * (new.ndim - 1))
        return jnp.where(m, new, old)
    return jax.tree.map(pick, new_tree, old_tree)


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


class KVCachePool:
    """``max_batch`` stacked batch-1 caches with slot read/write.

    The pool tree is allocated lazily from the first prefilled cache (so
    one pool class covers every family's cache pytree, including the
    encoder-decoder cross caches). Leaves are ``[slot, ...]``; reads and
    writes are functional index ops on the immutable tree.

    Pass ``rules`` (``distributed.sharding.Rules``) plus the family's
    ``cache_axes`` tree to allocate the pool SHARDED on the rules' mesh:
    the slot axis is placed through the "batch" rule (the data axis) and
    each cache dim through its own logical axis (e.g. kv_heads over the
    serving mesh's kv axis), so the engine's batched round runs as one
    GSPMD program partitioned over slots. Host-side slot reads/writes
    stay functional index ops — GSPMD gathers what they touch.
    """

    def __init__(self, n_slots: int, rules=None, cache_axes=None):
        self.n_slots = n_slots
        self.tree: Optional[Any] = None
        self._rules = rules
        self._axes = cache_axes
        self.shardings: Optional[Any] = None

    def ensure(self, template_cache) -> None:
        """Allocate the pool from a batch-1 cache's shapes/dtypes."""
        if self.tree is not None:
            return
        if self._rules is None:
            self.tree = jax.tree.map(
                lambda a: jnp.zeros((self.n_slots,) + jnp.shape(a),
                                    jnp.asarray(a).dtype),
                template_cache)
            return

        def alloc(axes, a):
            shape = (self.n_slots,) + tuple(jnp.shape(a))
            # leading slot dim maps through "batch" -> data; the cache's
            # own batch-1 dim (also logical "batch") is dropped by the
            # rules' no-axis-reuse guard and stays whole
            sh = self._rules.sharding(("batch",) + tuple(axes), dims=shape)
            return jax.device_put(
                jnp.zeros(shape, jnp.asarray(a).dtype), sh)

        self.tree = jax.tree.map(alloc, self._axes, template_cache,
                                 is_leaf=_is_axes_leaf)
        self.shardings = jax.tree.map(lambda a: a.sharding, self.tree)

    def write(self, slot: int, cache) -> None:
        self.tree = jax.tree.map(
            lambda pool, c: pool.at[slot].set(jnp.asarray(
                c, pool.dtype)), self.tree, cache)

    def read(self, slot: int):
        return jax.tree.map(lambda pool: pool[slot], self.tree)

    @property
    def lens(self) -> jnp.ndarray:
        """Per-slot valid lengths ([n_slots] int32)."""
        return self.tree["len"]

    def reset(self) -> None:
        """Nothing to do: slot contents are stale after an engine reset
        and admission overwrites a slot's cache before it is read."""


class PagedKVCachePool:
    """Block-table paged KV pool (transformer mask families).

    Physical pages ``pages = {"k","v"} [L, P, page, KV, Dh]`` are shared
    by every slot; each slot owns an ordered list of pages (its block
    table) covering positions ``0..len-1``. Page 0 is a reserved null
    page: free slots' tables point at it, so the batched round's writes
    for idle lanes land in sacrificial memory and no ``select_slots``
    restore pass is needed.

    Allocation is by actual lengths — admission reserves a request's
    lifetime need up front (``can_admit``/``reserve``) but draws pages
    only as content arrives: chunked prefill grows the table one chunk
    at a time (``ensure_blocks`` per chunk, always inside the
    reservation, so a partially-prefilled slot can never be starved by
    its batch-mates), every decode round grows just enough for its
    gamma+1 writes, finish returns everything — so total page memory
    can be provisioned below ``n_slots * max_len`` (``n_pages=``);
    admission defers when the pool is momentarily out of pages.
    Rollback after a rejected window is a block-table truncation:
    lengths shrink, surplus pages return to the free list, and the
    stale K/V left behind is causally invisible (logical position > any
    live query) until overwritten.

    Host-side state (tables, lengths, free list) is numpy; only the page
    arrays live on device.
    """

    def __init__(self, n_slots: int, cfg, *, page_size: int = 16,
                 max_len: int = 256, n_pages: Optional[int] = None):
        if not paged_supported(cfg):
            raise ValueError(f"family {cfg.family!r} (window="
                             f"{cfg.sliding_window}) cannot use the paged "
                             "pool")
        self.n_slots = n_slots
        self.cfg = cfg
        self.page = page_size
        self.capacity = max_len                 # logical positions per slot
        self.blocks_per_slot = -(-max_len // page_size)
        if n_pages is None:
            n_pages = n_slots * self.blocks_per_slot + 1
        if n_pages < self.blocks_per_slot + 1:
            raise ValueError("n_pages must cover at least one full slot")
        self.n_pages = n_pages
        self.pages = tfm.init_kv_pages(cfg, n_pages, page_size)
        self.tables = np.zeros((n_slots, self.blocks_per_slot), np.int32)
        self.lens = np.zeros((n_slots,), np.int32)
        self.n_blocks = np.zeros((n_slots,), np.int32)
        # lifetime reservation per slot (blocks), set at admission
        self.reserved = np.zeros((n_slots,), np.int32)
        self.free: List[int] = list(range(n_pages - 1, 0, -1))  # 0 = null

    # -- host bookkeeping --------------------------------------------------
    def _blocks_for(self, length: int) -> int:
        return -(-max(length, 0) // self.page)

    def _shortfall(self) -> int:
        """Blocks the admitted slots may still claim against their
        reservations."""
        return int(np.maximum(self.reserved - self.n_blocks, 0).sum())

    def can_admit(self, total_len: int) -> bool:
        """Admission check against the request's WHOLE lifetime need
        (prompt + budget, clamped to capacity), on top of every
        already-admitted slot's outstanding reservation. Conservative on
        purpose: once admitted under a reservation, a gamma=1 round's
        growth always fits (the engine shrinks larger batch windows to
        the free list), so an under-provisioned pool admits fewer
        concurrent requests instead of deadlocking mid-stream."""
        need = self._blocks_for(min(total_len, self.capacity))
        return len(self.free) >= self._shortfall() + need

    def reserve(self, slot: int, total_len: int) -> None:
        self.reserved[slot] = self._blocks_for(min(total_len,
                                                   self.capacity))

    def ensure_blocks(self, slot: int, new_len: int) -> None:
        """Grow the slot's table to cover ``new_len`` positions."""
        need = self._blocks_for(min(new_len, self.capacity))
        have = int(self.n_blocks[slot])
        if need <= have:
            return
        if len(self.free) < need - have:
            raise RuntimeError(
                f"paged KV pool out of pages ({len(self.free)} free, "
                f"{need - have} needed); raise n_pages or lower max_batch")
        for b in range(have, need):
            self.tables[slot, b] = self.free.pop()
        self.n_blocks[slot] = need

    def truncate(self, slot: int, new_len: int) -> None:
        """Rollback/commit: set the committed length, free surplus pages
        (no K/V rewrite — this is the whole point of paging)."""
        keep = self._blocks_for(new_len)
        for b in range(keep, int(self.n_blocks[slot])):
            self.free.append(int(self.tables[slot, b]))
            self.tables[slot, b] = 0
        self.n_blocks[slot] = keep
        self.lens[slot] = new_len

    def free_slot(self, slot: int) -> None:
        self.truncate(slot, 0)
        self.reserved[slot] = 0

    def reset(self) -> None:
        """Return every page; keep the allocated page arrays (stale
        contents are overwritten before being readable)."""
        for s in range(self.n_slots):
            self.free_slot(s)

    # -- device views ------------------------------------------------------
    def device_tables(self) -> jnp.ndarray:
        return jnp.asarray(self.tables)

    def device_lens(self) -> jnp.ndarray:
        return jnp.asarray(self.lens)

    # -- admission ---------------------------------------------------------
    def write_prefill(self, slot: int, cache) -> None:
        """Staging fallback: scatter a dense batch-1 prefilled cache
        into freshly allocated pages. The production admission path
        prefills THROUGH the pool in chunks (``transformer.prefill_paged``
        + per-chunk ``ensure_blocks`` — no dense staging buffer); this
        remains for chunking disabled, requests with extra prefill
        fields (VLM vision prefixes), and as the engine's
        chunked == staged equivalence oracle."""
        length = min(int(cache["len"]), self.capacity)
        self.ensure_blocks(slot, length)
        nb = self._blocks_for(length)
        if nb == 0:
            self.lens[slot] = 0
            return
        k = cache["k"][:, 0]                       # [L, max_len, KV, Dh]
        v = cache["v"][:, 0]
        pad = nb * self.page - k.shape[1]
        if pad > 0:
            widths = ((0, 0), (0, pad), (0, 0), (0, 0))
            k = jnp.pad(k, widths)
            v = jnp.pad(v, widths)
        L, _, KV, Dh = k.shape
        ids = jnp.asarray(self.tables[slot, :nb])
        kb = k[:, :nb * self.page].reshape(L, nb, self.page, KV, Dh)
        vb = v[:, :nb * self.page].reshape(L, nb, self.page, KV, Dh)
        self.pages = {
            "k": self.pages["k"].at[:, ids].set(kb.astype(
                self.pages["k"].dtype)),
            "v": self.pages["v"].at[:, ids].set(vb.astype(
                self.pages["v"].dtype)),
        }
        self.lens[slot] = length
