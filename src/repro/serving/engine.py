"""``ServingEngine``: continuous-batching speculative serving.

    engine = ServingEngine(cfg_t, params_t, cfg_d, params_d,
                           max_batch=4, max_len=256, gamma=4)
    engine.submit(ServeRequest(prompt, max_new_tokens=32, rng=7))
    ...
    results = engine.run()          # [ServeResult], acceptance per request
    print(engine.stats().describe())  # tokens/fwd, tokens/sec

Each ``step()`` is one scheduler round:

  1. admit queued requests into free KV-cache slots in the scheduling
     policy's order (fifo / priority / sjf). With ``prefill_chunk``
     set, admission just reserves pages and parks the slot PREFILLING;
     otherwise the staging path prefills target + draft at batch 1,
     samples the first new token from the prefill logits, and writes
     the caches into the pool;
  2. stream one or more prompt chunks for every PREFILLING slot
     through the paged pool (``prefill_paged`` — no dense staging
     buffer), bounded by the per-step ``prefill_budget``; slots whose
     prompt completes sample their first token from the final chunk's
     logits and flip to DECODING;
  3. run ONE batched propose-verify round for every decoding slot — the
     draft drafts gamma tokens (gamma+1 batched c=1 forwards), the
     target verifies pending+drafts in a single c=gamma+1 forward, and
     acceptance/rollback is computed per slot inside the same jitted
     call (mask families; replay families re-extend on the host);
  4. commit accepted prefixes + the bonus/adjusted token, retire
     requests whose budget is spent (their slots refill at the next
     step's admission).

All randomness a request consumes comes from ``fold_in(request.rng,
round_idx)``, so the output distribution is independent of the batch a
request happens to share — the batch-1 engine IS the single-request
serving path (``core.llm_sd`` and ``SamplerSpec(domain="token")`` both
route here).
"""
from __future__ import annotations

import itertools
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import speculative as sdp
from ..kernels.policy import KernelPolicy
from ..models import registry
from ..models import tpp as tppm
from ..models import transformer as tfm
from . import tpp_rounds
from .faults import FaultPlan
from .kv_pool import (KVCachePool, PagedKVCachePool, paged_supported,
                      rollback_kind, rollback_one, select_slots)
from .prefix_cache import PrefixCache, tpp_history_key
from .request import EngineStats, ServeRequest, ServeResult, _as_key
from .scheduler import DECODING, PREFILLING, Scheduler, SlotState


class AdmissionImpossible(RuntimeError):
    """The paged pool can never hold this request (an EMPTY engine's
    free list is too small for its lifetime reservation). Unlike a
    transient out-of-pages condition this is not retryable: the engine
    fails the request (``status="failed"``) instead of deferring it
    forever."""

# Jitted closures cached per (role, cfg..., static dims). Configs are
# frozen dataclasses (hashable), so the cache survives across engine
# instances — a fresh ServingEngine per call reuses all compilations.
_FN_CACHE: Dict[Any, Any] = {}
_MODELS: Dict[Any, Any] = {}


def _model_for(cfg):
    if cfg not in _MODELS:
        _MODELS[cfg] = registry.get_model(cfg)
    return _MODELS[cfg]


def _prefill_fn(cfg, max_len: int):
    key = ("prefill", cfg, max_len)
    if key not in _FN_CACHE:
        model = _model_for(cfg)
        _FN_CACHE[key] = jax.jit(
            lambda params, batch: model.prefill(params, batch, max_len))
    return _FN_CACHE[key]


def _single_extend_fn(cfg):
    """Batch-1 extend (replay-family rollback re-extends through this)."""
    key = ("extend1", cfg)
    if key not in _FN_CACHE:
        model = _model_for(cfg)
        _FN_CACHE[key] = jax.jit(
            lambda params, cache, toks: model.extend(params, cache, toks))
    return _FN_CACHE[key]


def _pool_extend(model, params, pool_tree, toks):
    """One batched forward: extend every slot's batch-1 cache by
    ``toks[slot]`` in a single vmapped call. toks: [S, c]."""
    def one(cache, t):
        logits, cache2 = model.extend(params, cache, t[None, :])
        return logits[0], cache2
    return jax.vmap(one)(pool_tree, toks)


def _ar_round_fn(cfg_t):
    """Batched decode: ingest each slot's pending token, sample the next."""
    key = ("ar_round", cfg_t)
    if key not in _FN_CACHE:
        model_t = _model_for(cfg_t)

        def fn(params_t, pt_tree, pending, keys, ridx, temps, active):
            logits, pt2 = _pool_extend(model_t, params_t, pt_tree,
                                       pending[:, None])
            lp = jax.nn.log_softmax(logits[:, -1] / temps[:, None], axis=-1)
            rks = jax.vmap(jax.random.fold_in)(keys, ridx)
            tok = jax.vmap(jax.random.categorical)(rks, lp).astype(jnp.int32)
            # per-lane health: NaN (inf logits go NaN through
            # log_softmax; -inf alone is a legal zero-probability)
            ok = ~jnp.any(jnp.isnan(lp), axis=-1)
            packed = jnp.stack([tok, ok.astype(jnp.int32)], axis=1)
            return select_slots(active, pt2, pt_tree), packed

        _FN_CACHE[key] = jax.jit(fn)
    return _FN_CACHE[key]


def _draft_tokens(gamma, r_d, temps, ingest, pending):
    """Shared draft loop of one batched sd round — the SAME sampling ops
    for the dense and the paged layout (``ingest(toks [S,1]) -> logits``
    is the only difference: a vmapped dense extend or a paged one,
    advancing its cache through the closure). Keeping the fold_in /
    categorical sequence in ONE place is what upholds the paged==dense
    token-bitwise guarantee. Returns (d_toks [S,g], d_logps [S,g,V])."""
    logits = ingest(pending[:, None])
    lp_d = jax.nn.log_softmax(logits[:, -1] / temps[:, None], -1)
    d_toks, d_logps = [], []
    for i in range(gamma):
        ki = jax.vmap(lambda k: jax.random.fold_in(k, i))(r_d)
        tok = jax.vmap(jax.random.categorical)(ki, lp_d)
        d_toks.append(tok.astype(jnp.int32))
        d_logps.append(lp_d)
        logits = ingest(tok[:, None].astype(jnp.int32))
        lp_d = jax.nn.log_softmax(logits[:, -1] / temps[:, None], -1)
    return jnp.stack(d_toks, axis=1), jnp.stack(d_logps, axis=1)


def _sd_verdict(gamma, r_v, r_a, r_b, d_toks, d_logps, lp_t_all):
    """Shared accept/bonus/adjusted sampling of one batched sd round —
    the SAME ops for the dense and the paged round, so the two layouts
    consume identical random streams and commit identical tokens.

    d_toks: [S, g]; d_logps: [S, g, V]; lp_t_all: [S, g+1, V].
    Returns (A [S], extra [S])."""
    u = jax.vmap(lambda k: jax.vmap(
        lambda i: jax.random.uniform(jax.random.fold_in(k, i)))(
            jnp.arange(gamma)))(r_v)            # [S, g]
    lp_t_tok = jnp.take_along_axis(
        lp_t_all[:, :gamma], d_toks[..., None], -1)[..., 0]
    lp_d_tok = jnp.take_along_axis(
        d_logps, d_toks[..., None], -1)[..., 0]
    acc = jnp.log(u) < (lp_t_tok - lp_d_tok)
    A = jnp.sum(jnp.cumprod(acc.astype(jnp.int32), axis=1), axis=1)
    all_acc = A == gamma

    bonus = jax.vmap(jax.random.categorical)(r_b, lp_t_all[:, gamma])
    Ac = jnp.minimum(A, gamma - 1)
    lp_t_A = jax.vmap(lambda l, a: l[a])(lp_t_all, A)
    lp_d_A = jax.vmap(lambda l, a: l[a])(d_logps, Ac)
    adj = jax.vmap(sdp.adjusted_discrete)(r_a, lp_t_A, lp_d_A)
    extra = jnp.where(all_acc, bonus, adj).astype(jnp.int32)
    return A, extra


def _sd_round_fn(cfg_t, cfg_d, gamma: int):
    """One batched propose-verify round (static draft window ``gamma``).

    Returns (pool_t', pool_d', packed [S, g+3]) where packed is the
    int32 concatenation ``d_toks ‖ A ‖ extra ‖ ok`` — every host-bound
    scalar of the round in ONE array, so committing costs a single
    device→host fetch. For mask families the returned pools are already
    rolled back to the committed prefix (and idle slots restored);
    replay families get the post-forward pools back and the engine
    re-extends on the host.
    """
    key = ("sd_round", cfg_t, cfg_d, gamma)
    if key not in _FN_CACHE:
        model_t, model_d = _model_for(cfg_t), _model_for(cfg_d)
        kind_t, kind_d = rollback_kind(cfg_t), rollback_kind(cfg_d)

        def fn(params_t, params_d, pt_tree, pd_tree, pending, keys, ridx,
               temps, active):
            ks = jax.vmap(lambda k, r: jax.random.split(
                jax.random.fold_in(k, r), 4))(keys, ridx)
            r_d, r_v, r_a, r_b = ks[:, 0], ks[:, 1], ks[:, 2], ks[:, 3]
            len0_t, len0_d = pt_tree["len"], pd_tree["len"]

            # ---- draft gamma tokens (pending ingested first)
            st = {"pd": pd_tree}

            def ingest(toks):
                logits, st["pd"] = _pool_extend(model_d, params_d,
                                                st["pd"], toks)
                return logits

            d_toks, d_logps = _draft_tokens(gamma, r_d, temps, ingest,
                                            pending)
            pd2 = st["pd"]

            # ---- verify pending + drafts in ONE target forward (c=g+1)
            ver = jnp.concatenate([pending[:, None], d_toks], axis=1)
            lg_t, pt2 = _pool_extend(model_t, params_t, pt_tree, ver)
            lp_t_all = jax.nn.log_softmax(
                lg_t / temps[:, None, None], axis=-1)   # [S, g+1, V]

            # ---- acceptance tests (same streams as the batch-1 path)
            A, extra = _sd_verdict(gamma, r_v, r_a, r_b, d_toks, d_logps,
                                   lp_t_all)
            ok = ~(jnp.any(jnp.isnan(lp_t_all), axis=(1, 2))
                   | jnp.any(jnp.isnan(d_logps), axis=(1, 2)))

            # ---- rollback to committed prefix (mask families, in-jit)
            if kind_t == "replay":
                pt_out = pt2
            else:
                rolled = jax.vmap(lambda c, n: rollback_one(cfg_t, c, n))(
                    pt2, len0_t + 1 + A)
                pt_out = select_slots(active, rolled, pt_tree)
            if kind_d == "replay":
                pd_out = pd2
            else:
                rolled = jax.vmap(lambda c, n: rollback_one(cfg_d, c, n))(
                    pd2, len0_d + 1 + A)
                pd_out = select_slots(active, rolled, pd_tree)
            packed = jnp.concatenate(
                [d_toks, A[:, None], extra[:, None],
                 ok.astype(jnp.int32)[:, None]], axis=1)
            return pt_out, pd_out, packed

        _FN_CACHE[key] = jax.jit(fn)
    return _FN_CACHE[key]


def _sd_round_paged_fn(cfg_t, cfg_d, gamma: int, policy: KernelPolicy,
                       max_kv: int):
    """One batched propose-verify round over PAGED pools.

    Identical random streams and sampling ops as ``_sd_round_fn`` — the
    layouts differ only in how KV is stored and scored (block-table
    pages + the spec-verify attention kernel instead of a vmapped dense
    extend). No in-jit rollback: commit/rollback is the host's
    block-table truncation after the round.
    """
    key = ("sd_round_paged", cfg_t, cfg_d, gamma, policy, max_kv)
    if key not in _FN_CACHE:

        def fn(params_t, params_d, pg_t, pg_d, bt_t, lens_t, bt_d, lens_d,
               pending, keys, ridx, temps):
            ks = jax.vmap(lambda k, r: jax.random.split(
                jax.random.fold_in(k, r), 4))(keys, ridx)
            r_d, r_v, r_a, r_b = ks[:, 0], ks[:, 1], ks[:, 2], ks[:, 3]

            # ---- draft gamma tokens (pending ingested first)
            st = {"pg": pg_d, "len": lens_d}

            def ingest(toks):
                logits, st["pg"] = tfm.extend_paged(
                    cfg_d, params_d, st["pg"], bt_d, st["len"], toks,
                    policy=policy, max_kv=max_kv)
                st["len"] = st["len"] + toks.shape[1]
                return logits

            d_toks, d_logps = _draft_tokens(gamma, r_d, temps, ingest,
                                            pending)
            pg_d = st["pg"]

            # ---- verify pending + drafts: ONE c=g+1 paged forward whose
            # attention is a single spec-verify kernel pass per layer
            ver = jnp.concatenate([pending[:, None], d_toks], axis=1)
            lg_t, pg_t = tfm.extend_paged(
                cfg_t, params_t, pg_t, bt_t, lens_t, ver, policy=policy,
                max_kv=max_kv)
            lp_t_all = jax.nn.log_softmax(
                lg_t / temps[:, None, None], axis=-1)   # [S, g+1, V]

            A, extra = _sd_verdict(gamma, r_v, r_a, r_b, d_toks, d_logps,
                                   lp_t_all)
            ok = ~(jnp.any(jnp.isnan(lp_t_all), axis=(1, 2))
                   | jnp.any(jnp.isnan(d_logps), axis=(1, 2)))
            packed = jnp.concatenate(
                [d_toks, A[:, None], extra[:, None],
                 ok.astype(jnp.int32)[:, None]], axis=1)
            return pg_t, pg_d, packed

        _FN_CACHE[key] = jax.jit(fn)
    return _FN_CACHE[key]


def _prefill_chunk_fn(cfg_t, cfg_d, chunk: int, policy: KernelPolicy,
                      max_kv: int):
    """One batched prefill chunk THROUGH the paged pools: write the
    chunk's K/V into the target (and draft) pages and return the target
    logits of each lane's LAST VALID position — the only row the host
    ever consumes (first-token sampling + fork-source logits), gathered
    in-jit so the per-step fetch is [S, V] instead of [S, chunk, V].
    Lanes with ``nvalid == 0`` (idle / decoding slots sharing the batch)
    write the null page and are untouched. One compilation per engine
    (the chunk length is static; partial final chunks ride the same
    program right-padded)."""
    key = ("prefill_chunk", cfg_t, cfg_d, chunk, policy, max_kv)
    if key not in _FN_CACHE:

        def fn(params_t, params_d, pg_t, bt_t, pg_d, bt_d, lens, tokens,
               nvalid):
            lg, pg_t = tfm.prefill_paged(
                cfg_t, params_t, pg_t, bt_t, lens, tokens, nvalid,
                policy=policy, max_kv=max_kv)
            if cfg_d is not None:
                _, pg_d = tfm.prefill_paged(
                    cfg_d, params_d, pg_d, bt_d, lens, tokens, nvalid,
                    policy=policy, max_kv=max_kv)
            last = jnp.maximum(nvalid - 1, 0)
            lg_last = lg[jnp.arange(lg.shape[0]), last]
            return lg_last, pg_t, pg_d

        _FN_CACHE[key] = jax.jit(fn)
    return _FN_CACHE[key]


def _ar_round_paged_fn(cfg_t, policy: KernelPolicy, max_kv: int):
    """Batched paged decode: ingest pending, sample the next token."""
    key = ("ar_round_paged", cfg_t, policy, max_kv)
    if key not in _FN_CACHE:

        def fn(params_t, pg_t, bt_t, lens_t, pending, keys, ridx, temps):
            logits, pg_t = tfm.extend_paged(
                cfg_t, params_t, pg_t, bt_t, lens_t, pending[:, None],
                policy=policy, max_kv=max_kv)
            lp = jax.nn.log_softmax(logits[:, -1] / temps[:, None], axis=-1)
            rks = jax.vmap(jax.random.fold_in)(keys, ridx)
            tok = jax.vmap(jax.random.categorical)(rks, lp).astype(jnp.int32)
            ok = ~jnp.any(jnp.isnan(lp), axis=-1)
            packed = jnp.stack([tok, ok.astype(jnp.int32)], axis=1)
            return pg_t, packed

        _FN_CACHE[key] = jax.jit(fn)
    return _FN_CACHE[key]


class _InflightRound:
    """A dispatched-but-uncommitted decode round.

    ``arrays`` is the pytree of un-fetched device outputs (JAX async
    dispatch returns them immediately); ``commit`` is the host
    continuation that consumes the fetched numpy pytree and returns the
    round's quarantined results. ``step()`` fetches every inflight
    array — round outputs plus any deferred first tokens — in ONE
    ``jax.device_get`` at its commit point, which is both the
    batched-transfer fast path of the synchronous loop and the seam the
    async double-buffer overlaps host work into."""

    __slots__ = ("arrays", "commit")

    def __init__(self, arrays, commit):
        self.arrays = arrays
        self.commit = commit


class ServingEngine:
    """Request-queue serving over the model zoo (method "sd" or "ar").

    Pass ``mesh`` (e.g. ``launch.mesh.make_serving_mesh()`` or a debug
    mesh) to run the pooled round sharded: params are placed by their
    logical axes (``SERVING_RULES`` when the mesh has a kv axis), the
    KV-cache pools are allocated with the SLOT axis sharded over "data"
    (cache head axes over kv where divisible), and the per-round slot
    vectors are placed over data too — so the batched draft+verify round
    is one GSPMD program partitioned across devices. All host-side
    bookkeeping (scheduler, commits, replay re-extend) is mesh-agnostic.
    """

    def __init__(self, cfg_t, params_t, cfg_d=None, params_d=None, *,
                 method: str = "sd", max_batch: int = 4, max_len: int = 256,
                 gamma: int = 4, draft_policy: str = "fixed", mesh=None,
                 kv_layout: str = "auto", kernel="auto",
                 page_size: Optional[int] = None,
                 n_pages: Optional[int] = None,
                 sched="fifo", prefill_chunk: Optional[int] = None,
                 prefill_budget: Optional[int] = None,
                 prefix_cache: bool = False,
                 faults: Optional[FaultPlan] = None,
                 max_round_retries: int = 3,
                 retry_backoff_s: float = 0.0,
                 shed_queue: Optional[int] = None,
                 fixed_window: bool = False):
        """``kv_layout``: "paged" (block-table pool + spec-verify Pallas
        attention — the production hot path), "dense" (per-slot dense
        caches + vmapped extend), or "auto" (paged whenever the families
        support it: transformer mask families, no sliding window, no
        mesh). ``kernel``: a ``KernelPolicy`` or one of
        "auto"|"pallas"|"ref" — "auto" runs Pallas, compiled on TPU and
        ``interpret=True`` elsewhere. ``page_size``/``n_pages`` size the
        paged pool (n_pages=None fully provisions max_batch x max_len;
        smaller values admit under memory pressure by deferring).

        ``sched``: admission policy — "fifo" (default, bitwise the
        historical behavior), "priority" (``ServeRequest.priority`` +
        aging), "sjf" (shortest job first), or a ``SchedulingPolicy``.
        ``prefill_chunk``: stream admitted prompts into the paged pool
        in chunks of this many tokens instead of staging a dense
        batch-1 prefill (None = staging). Chunked slots sit in the
        PREFILLING phase and share steps with decoding slots. With no
        budget the round schedule is exactly the staging engine's and
        the committed streams are token-BITWISE identical (same
        per-request rng, same masked reductions).
        ``prefill_budget``: max prompt tokens prefilled per engine step
        across all PREFILLING slots (None = unlimited: an admitted
        prompt finishes prefilling in its admission step, like
        staging). A budget delays admission, which changes which slots
        share a round and hence the batch window clamp — round
        boundaries shift, so streams match staging in DISTRIBUTION
        (the per-request rng contract) rather than bitwise.
        ``prefix_cache``: keep a cross-request radix cache of retired
        prompts' KV pages (``serving/prefix_cache.py``); admissions
        adopt the longest page-aligned prefix match and prefill only
        from the divergence point. Requires the paged layout; implies
        chunked admission (cache hits resume prefill mid-prompt), so
        ``prefill_chunk`` defaults to 32 when unset — a bitwise-neutral
        default, since unbudgeted chunked admission is token-bitwise
        the staging path. Cache-hit admissions are token-bitwise equal
        to cold ones: adopted pages hold exactly the K/V the skipped
        prefill would have written, and every sampled draw still comes
        from ``fold_in(request.rng, round_idx)``.

        Failure semantics (see ``serving/faults.py`` for the chaos
        harness that exercises them):
        ``faults``: a ``FaultPlan`` to inject deterministically.
        ``max_round_retries``: bounded per-request retry budget — a
        failed round/prefill/admission is rolled back (block-table
        truncation; replay-family checkpoints) and re-run next step
        with the SAME ``round_idx``, so a retried round commits bitwise
        identical tokens; past the budget the request retires
        ``status="failed"``. ``retry_backoff_s``: base of the
        exponential (2**n, capped) backoff sleep between consecutive
        failed steps (0 = none — the deterministic-test default).
        ``shed_queue``: overload control — after each step's
        admissions the still-pending queue is trimmed to this depth,
        shedding the policy-ranked tail (``status="shed"``); None =
        never shed.
        ``fixed_window``: pin the sd draft window to the constructor's
        ``gamma`` (requires a static draft policy) and reserve
        prompt + budget + gamma positions per request, exactly like the
        TPP domain. Removes the one batch-composition-dependent knob
        (the budget/page-pressure gamma clamp), making every request's
        token stream bitwise independent of WHO shares its rounds —
        the survivor-bitwise contract the chaos tests pin."""
        if method not in ("ar", "sd"):
            raise ValueError(f"method must be 'ar' or 'sd', got {method!r}")
        if method == "sd" and (cfg_d is None or params_d is None):
            raise ValueError("method='sd' needs a draft model "
                             "(cfg_d, params_d)")
        self.cfg_t, self.params_t = cfg_t, params_t
        self.cfg_d, self.params_d = cfg_d, params_d
        self.method = method
        self.max_batch, self.max_len = max_batch, max_len
        # event-sequence (TPP) domain: a config without a token-LM
        # ``family`` attribute is a TPPConfig — the engine then commits
        # (time, mark) events through the paged TPP rounds and "auto"
        # follows the TPP kernel convention (reference off-TPU, like
        # ``tpp.resolve_policy``)
        self.domain = "tpp" if not hasattr(cfg_t, "family") else "token"
        pol = kernel if isinstance(kernel, KernelPolicy) \
            else KernelPolicy(backend=kernel)
        self.policy = pol.resolve(
            default_backend="ref" if self.domain == "tpp" else "pallas")
        if page_size is not None:
            self.policy = self.policy.replace(page_size=page_size)
        self.n_pages = n_pages
        if self.domain == "tpp":
            paged_ok = (mesh is None and cfg_t.encoder in ("thp", "sahp")
                        and (method == "ar"
                             or cfg_d.encoder in ("thp", "sahp")))
            if kv_layout == "dense" or not paged_ok:
                raise ValueError(
                    "the TPP domain serves through the paged pool only: "
                    "kv_layout 'auto'/'paged', softmax encoders "
                    "(thp/sahp) and no mesh")
            kv_layout = "paged"
            if prefill_chunk is None:
                # TPP admission is always chunked — the staging prefill
                # is a token-LM path (it samples a first token from
                # logits; TPP histories produce none)
                prefill_chunk = 32
        else:
            paged_ok = (mesh is None and paged_supported(cfg_t)
                        and (method == "ar" or paged_supported(cfg_d)))
        if kv_layout == "auto":
            kv_layout = "paged" if paged_ok else "dense"
        elif kv_layout == "paged" and not paged_ok:
            raise ValueError(
                "kv_layout='paged' needs transformer mask families with "
                "no sliding window and no mesh (replay/encdec/ring "
                "families roll back by other means)")
        elif kv_layout not in ("paged", "dense"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}")
        self.kv_layout = kv_layout
        explicit_pallas = (kernel.backend if isinstance(kernel, KernelPolicy)
                           else kernel) == "pallas"
        if explicit_pallas and self.kv_layout == "dense":
            import warnings
            warnings.warn(
                "kernel='pallas' only accelerates the paged rounds today; "
                "the dense layout keeps the families' reference extend "
                "path", UserWarning, stacklevel=2)
        if prefix_cache:
            if self.kv_layout != "paged":
                raise ValueError(
                    "prefix_cache retains KV pages across requests; it "
                    "requires kv_layout='paged'")
            if prefill_chunk is None:
                prefill_chunk = 32
        if prefill_chunk is not None:
            if prefill_chunk < 1:
                raise ValueError("prefill_chunk must be >= 1 (or None to "
                                 "disable chunked admission)")
            if self.kv_layout != "paged":
                raise ValueError(
                    "prefill_chunk streams prompts THROUGH the paged pool; "
                    "it requires kv_layout='paged' (dense layouts and "
                    "meshes keep the staging prefill)")
        if prefill_budget is not None and prefill_budget < 1:
            raise ValueError("prefill_budget must be >= 1 (or None for "
                             "unlimited)")
        if prefill_budget is not None and prefill_chunk is None:
            raise ValueError("prefill_budget paces chunked admission; set "
                             "prefill_chunk too")
        self.prefill_chunk = prefill_chunk
        self.prefill_budget = prefill_budget
        self.mesh, self.rules = mesh, None
        if mesh is not None:
            from ..launch.mesh import serving_rules_for
            self.rules = serving_rules_for(mesh)
            self.params_t = jax.device_put(
                params_t, self.rules.tree_shardings(
                    _model_for(cfg_t).logical_axes(), params_t))
            if method == "sd":
                self.params_d = jax.device_put(
                    params_d, self.rules.tree_shardings(
                        _model_for(cfg_d).logical_axes(), params_d))
        self.scheduler = Scheduler(max_batch, max_len, policy=sched)
        self.pool_t = self._make_pool(cfg_t)
        self.pool_d = self._make_pool(cfg_d) if method == "sd" else None
        self.prefix_cache: Optional[PrefixCache] = None
        if prefix_cache:
            pools = {"t": self.pool_t}
            if self.pool_d is not None:
                pools["d"] = self.pool_d
            self.prefix_cache = PrefixCache(self.pool_t.page, pools)
        # scenario fan-out: group id -> the group's live source entry
        # {"slot", "state", "logits"} (logits None while still
        # prefilling); siblings fork the source's prompt pages instead
        # of prefilling their own copy
        self._fork_sources: Dict[int, Dict[str, Any]] = {}
        self._group_ids = itertools.count()
        if method == "sd":
            from ..sampling.policies import resolve_policy_by_name
            self.draft_policy = resolve_policy_by_name(draft_policy, gamma)
            self._policy_state = self.draft_policy.init_state()
        else:
            self.draft_policy = None
        # TPP rounds keep the constructor's FIXED window (no adaptive or
        # clamped gamma): a fixed window keeps every request's event
        # stream bitwise independent of batch and wave composition — the
        # forecast executor's reproducibility contract — and the
        # admission-time reservation of prompt + budget + gamma
        # positions is what guarantees the transient window always fits
        self.tpp_gamma = gamma
        self._tpp_margin = gamma if method == "sd" else 0
        if max_round_retries < 0:
            raise ValueError("max_round_retries must be >= 0")
        if retry_backoff_s < 0:
            raise ValueError("retry_backoff_s must be >= 0")
        if shed_queue is not None and shed_queue < 0:
            raise ValueError("shed_queue must be >= 0 (or None)")
        self.faults = faults
        self.max_round_retries = max_round_retries
        self.retry_backoff_s = retry_backoff_s
        self.shed_queue = shed_queue
        self.fixed_window = bool(fixed_window)
        self._margin = 0
        if self.fixed_window and self.domain == "token" and method == "sd":
            if not getattr(self.draft_policy, "is_static", False):
                raise ValueError(
                    "fixed_window pins the draft window, so it needs a "
                    "static draft policy (e.g. 'fixed'); adaptive "
                    "policies resize by batch history")
            self._margin = gamma
        self._retries: Dict[int, int] = {}   # request_id -> failed steps
        self._round_fail_streak = 0          # consecutive failed steps
        # admission slot filter: None = any free slot; the disaggregated
        # engine restricts admission to its prefill worker's slots
        self._admit_slots: Optional[Tuple[int, ...]] = None
        # first tokens sampled as LAZY device scalars by chunked prefill
        # this step, committed at the step's single batched fetch; each
        # entry: {"state", "slot", "tok0", "row"} (row = last-position
        # logits kept only for fork sources, else None). Always fully
        # drained before step() returns.
        self._deferred: List[Dict[str, Any]] = []
        self._stats = EngineStats()
        self._results: List[ServeResult] = []

    def _make_pool(self, cfg):
        if self.kv_layout == "paged":
            init = tppm.init_kv_pages if self.domain == "tpp" else None
            return PagedKVCachePool(self.max_batch, cfg,
                                    page_size=self.policy.page_size,
                                    max_len=self.max_len,
                                    n_pages=self.n_pages,
                                    init_pages=init)
        if self.rules is None:
            return KVCachePool(self.max_batch)
        return KVCachePool(self.max_batch, rules=self.rules,
                           cache_axes=_model_for(cfg).cache_axes())

    def reset(self, force: bool = False) -> None:
        """Drop all request state but KEEP the allocated KV pools and
        (via the process-wide ``_FN_CACHE``) every compilation — the
        build-cache contract for callers that reuse one engine across
        independent serving runs. Slot contents are stale after a reset;
        admission overwrites a slot's cache before it is ever read.

        Refuses to discard queued/active requests unless ``force=True``
        (callers that own the whole run — e.g. the token-domain sampler
        recovering from an interrupted previous call — pass it)."""
        if self.scheduler.has_work() and not force:
            raise RuntimeError("reset() with requests still queued/active; "
                               "pass force=True to discard them")
        self.scheduler = Scheduler(self.max_batch, self.max_len,
                                   policy=self.scheduler.policy)
        self._fork_sources = {}
        if self.prefix_cache is not None:
            # pool.reset() rebuilds the free lists wholesale, so the
            # cache just drops its tree without per-page releases
            self.prefix_cache.clear(release=False)
        self.pool_t.reset()
        if self.pool_d is not None:
            self.pool_d.reset()
        if self.draft_policy is not None:
            self._policy_state = self.draft_policy.init_state()
        self._retries = {}
        self._round_fail_streak = 0
        self._deferred = []
        if self.faults is not None:
            self.faults.reset()
        self._stats = EngineStats()
        self._results = []

    # -- public API --------------------------------------------------------
    def submit(self, req: ServeRequest = None, *, prompt=None,
               max_new_tokens: int = 32, temperature: float = 1.0,
               rng=0, extra=None, priority: int = 0, fanout: int = 1,
               fanout_offset: int = 0, times=None, t_end=None,
               deadline_s: Optional[float] = None,
               max_wall_rounds: Optional[int] = None):
        """Queue a request (either a ``ServeRequest`` or its fields).

        ``fanout=K`` queues K scenario rollouts of the request: one
        prefix_group whose members share the prompt and draw from
        independent ``fold_in(rng, k)`` streams. On the paged layout
        the engine admits the prefix once and FORKS the other K-1
        members onto the same copy-on-write pages; each member's
        committed tokens are bitwise what K independent submissions
        with those rng keys would produce. Returns the list of K
        request ids (a single id when fanout == 1 and no offset).

        ``fanout_offset`` shifts the members' rng folds: member k draws
        from ``fold_in(rng, fanout_offset + k)``, so successive WAVES
        of submissions (the forecast executor's bounded-pool loop) tile
        one contiguous stream — wave w submitting K rollouts at offset
        w*K commits bitwise the same sequences a single
        fanout=n_rollouts submission would at members [w*K, (w+1)*K).
        A nonzero offset takes the group path even for K == 1.

        TPP (event-sequence) requests pass ``times`` (+ optional
        ``t_end``); their lifetime reservation additionally holds the
        speculative window, so history + budget + gamma must fit
        ``max_len``."""
        if req is None:
            req = ServeRequest(prompt=prompt, max_new_tokens=max_new_tokens,
                               temperature=temperature, rng=rng, extra=extra,
                               priority=priority, times=times, t_end=t_end,
                               deadline_s=deadline_s,
                               max_wall_rounds=max_wall_rounds)
        if req.is_tpp != (self.domain == "tpp"):
            raise ValueError(
                "request/engine domain mismatch: TPP engines (built from "
                "a TPPConfig) take event-history requests (times=); "
                "token engines take token prompts")
        if req.is_tpp and (req.prompt_len + req.max_new_tokens
                           + self._tpp_margin > self.max_len):
            raise ValueError(
                f"request {req.request_id}: history ({req.prompt_len}) + "
                f"max events ({req.max_new_tokens}) + speculative window "
                f"({self._tpp_margin}) exceeds the engine's max_len "
                f"({self.max_len})")
        if (not req.is_tpp and self._margin
                and req.prompt_len + req.max_new_tokens + self._margin
                > self.max_len):
            raise ValueError(
                f"request {req.request_id}: prompt ({req.prompt_len}) + "
                f"max_new_tokens ({req.max_new_tokens}) + fixed "
                f"speculative window ({self._margin}) exceeds the "
                f"engine's max_len ({self.max_len})")
        if fanout < 1:
            raise ValueError("fanout must be >= 1")
        if fanout_offset < 0:
            raise ValueError("fanout_offset must be >= 0")
        if fanout == 1 and fanout_offset == 0:
            return self.scheduler.submit(req)
        gid = next(self._group_ids)
        return [self.scheduler.submit(ServeRequest(
            prompt=req.prompt, max_new_tokens=req.max_new_tokens,
            temperature=req.temperature,
            rng=jax.random.fold_in(req.rng, fanout_offset + k),
            extra=req.extra, priority=req.priority, prefix_group=gid,
            times=req.times, t_end=req.t_end, deadline_s=req.deadline_s,
            max_wall_rounds=req.max_wall_rounds, on_tokens=req.on_tokens))
            for k in range(fanout)]

    def step(self, *, overlap=None) -> List[ServeResult]:
        """One scheduler round; returns requests completed this round.

        A mixed round: admission (policy-ordered), then chunked-prefill
        work for PREFILLING slots under the per-step token budget, then
        ONE batched draft+verify (or decode) round for the DECODING
        slots. Slots that finish prefilling inside this step join the
        same step's decode round — with no budget the schedule is
        exactly the staging engine's. The step is PIPELINED: the round
        is dispatched without blocking (chunked first tokens ride it as
        lazy device scalars), every host-bound output is fetched in ONE
        ``jax.device_get``, and only then does the host commit — so the
        synchronous loop already pays a single device sync per step.

        ``overlap``: optional zero-arg callable run in the double-buffer
        window — after the round (and any deferred first-token draws)
        has been dispatched, BEFORE the batched fetch that commits it.
        Host work done there (input staging, arrival polling; see
        ``run_async``/``async_overlap``) hides behind device compute.
        The window never touches scheduler state that feeds round
        composition, so ``step(overlap=...)`` commits bitwise what
        ``step()`` commits.

        A failed phase never raises out of here: admission, prefill and
        the decode round each run under the retry wrapper, which rolls
        the failed phase back (the slots re-run it NEXT step with the
        same ``round_idx`` streams — bitwise the un-failed round) and
        retires requests whose retry budget is spent as
        ``status="failed"``. The deadline sweep runs first (a doomed
        request costs no device work in the step that expires it); the
        shed sweep runs right after admission, trimming only the
        backlog the slots could not absorb."""
        t0 = time.perf_counter()
        dev0, ov0 = self._stats.device_ms, self._stats.overlap_ms
        step_idx = self.scheduler.tick()
        done: List[ServeResult] = []
        if self.faults is not None:
            self.faults.begin_step(self, step_idx)
        try:
            done.extend(self._sweep_lifecycle())
            blocked = False
            for slot, state in self.scheduler.admit(
                    allowed=self._admit_slots):
                if blocked:
                    # admission-order under page pressure: once one
                    # admission defers, later placements wait behind it
                    self.scheduler.defer(slot)
                    continue
                try:
                    blocked = not self._admit(slot, state)
                except Exception as e:
                    blocked = True
                    done.extend(self._on_admit_failure(slot, state, e))
            done.extend(self._shed_sweep())
            done.extend(self._drain_handoffs())
            if self.prefill_chunk is not None:
                pref = [(s, st) for s, st in self.scheduler.active()
                        if st.phase == PREFILLING]
                if pref:
                    try:
                        (self._tpp_prefill_step if self.domain == "tpp"
                         else self._prefill_step)()
                    except Exception as e:
                        done.extend(self._on_phase_failure(
                            pref, e, phase="prefill"))
                    else:
                        for _, st in pref:
                            self._retries.pop(st.request.request_id, None)
            # requests whose whole budget was the prefill token
            alive: List[Tuple[int, SlotState]] = []
            for slot, state in self.scheduler.active():
                if state.phase == PREFILLING:
                    continue        # still consuming chunk budget
                if state.done:
                    done.append(self._retire(slot))
                else:
                    alive.append((slot, state))
            inflight: Optional[_InflightRound] = None
            round_exc: Optional[Exception] = None
            if alive:
                try:
                    inflight = self._dispatch_round(alive)
                except Exception as e:
                    round_exc = e
            if overlap is not None and (inflight is not None
                                        or self._deferred):
                t_ov = time.perf_counter()
                try:
                    overlap()
                finally:
                    self._stats.overlap_ms += \
                        (time.perf_counter() - t_ov) * 1e3
            round_host = None
            if inflight is not None or self._deferred:
                t_dev = time.perf_counter()
                first_host, round_host = jax.device_get(
                    ([(d["tok0"], d["row"]) for d in self._deferred],
                     inflight.arrays if inflight is not None else None))
                self._stats.device_ms += \
                    (time.perf_counter() - t_dev) * 1e3
                # first tokens commit before the round barrier — the
                # order the staging path (prefill then round) produces
                done.extend(self._commit_first_tokens(first_host))
            if round_exc is not None:
                done.extend(self._on_phase_failure(
                    alive, round_exc, phase="round"))
            elif inflight is not None:
                try:
                    self._fault_barrier()
                    quarantined = inflight.commit(round_host)
                except Exception as e:
                    done.extend(self._on_phase_failure(
                        alive, e, phase="round"))
                else:
                    done.extend(quarantined)
                    self._round_fail_streak = 0
                    for _, st in alive:
                        self._retries.pop(st.request.request_id, None)
                    for slot, state in alive:
                        # quarantined slots are already gone; only
                        # still-seated states retire here
                        if (self.scheduler.slots[slot] is state
                                and state.done):
                            done.append(self._retire(slot))
        finally:
            if self.faults is not None:
                self.faults.end_step(self, step_idx)
        wall = time.perf_counter() - t0
        self._stats.wall_s += wall
        self._stats.host_ms += max(
            0.0, wall * 1e3 - (self._stats.device_ms - dev0)
            - (self._stats.overlap_ms - ov0))
        self._results.extend(done)
        return done

    def _dispatch_round(self, alive) -> _InflightRound:
        """Build and dispatch the step's decode round WITHOUT blocking:
        the jitted call returns un-fetched device arrays (JAX async
        dispatch), packaged with the host commit continuation.
        ``step()`` fetches everything at its single commit point."""
        if self.domain == "tpp":
            return (self._tpp_sd_dispatch if self.method == "sd"
                    else self._tpp_ar_dispatch)(alive)
        if self.method == "sd":
            return (self._sd_dispatch_paged if self.kv_layout == "paged"
                    else self._sd_dispatch)(alive)
        return (self._ar_dispatch_paged if self.kv_layout == "paged"
                else self._ar_dispatch)(alive)

    def _drain_handoffs(self) -> List[ServeResult]:
        """Disaggregated engines move completed prompts from prefill
        slots to decode slots here (``serving/disagg.py``); the unified
        engine has nothing to drain."""
        return []

    def _fault_barrier(self) -> None:
        """Chaos hook, called after a round's device work synchronized
        and BEFORE any host commit: a ``step_error`` fault raises here,
        so the retry re-runs the round with the same ``round_idx``
        streams and commits bitwise what the un-failed round would."""
        if self.faults is not None:
            self.faults.maybe_raise_step_error(self.scheduler.step_idx,
                                               self)

    # -- deferred first tokens + streaming ---------------------------------
    def _defer_first_token(self, st: SlotState, slot: int, tok0,
                           row) -> None:
        """Park a freshly-prefilled slot's first token as a LAZY device
        scalar: the slot flips to DECODING now (it joins this step's
        round, which ingests ``tok0`` on device via
        ``_inject_deferred``), but the host integer only materializes at
        the step's single batched fetch. TTFT is stamped here — the
        wall moment the prompt completed, same as the eager path."""
        st.phase = DECODING
        st.first_pending = True
        st.ttft_rounds = self.scheduler.step_idx - st.submit_step
        st.ttft_s = time.perf_counter() - st.submit_t
        self._deferred.append({"state": st, "slot": slot, "tok0": tok0,
                               "row": row})

    def _commit_first_tokens(self, first_host) -> List[ServeResult]:
        """Commit the step's deferred first tokens from the batched
        fetch. Runs BEFORE the round's fault barrier and commit: the
        staging schedule commits first tokens in the prefill phase, and
        a round retry must find them already in ``out``. Always drains
        ``_deferred`` completely — deferral never crosses a step."""
        out: List[ServeResult] = []
        for d, (tok0, row) in zip(self._deferred, first_host):
            st = d["state"]
            st.first_pending = False
            if self.scheduler.slots[d["slot"]] is not st:
                continue            # retired mid-step; nothing to commit
            if row is not None:
                src = self._fork_sources.get(st.request.prefix_group)
                if src is not None and src["state"] is st:
                    src["logits"] = np.asarray(row)
            tok0 = int(tok0)
            st.out.append(tok0)
            st.pending = tok0
            self._stats.prefills += 1
            self._stats.tokens += 1
            self._stream(st, 0)
        self._deferred = []
        return out

    def _inject_deferred(self, pending):
        """Splice this step's deferred first tokens (device scalars)
        into the round's pending lane — the decode round chains on the
        prefill output with no host sync in between."""
        for d in self._deferred:
            st = d["state"]
            if st.first_pending and self.scheduler.slots[d["slot"]] is st:
                pending = pending.at[d["slot"]].set(d["tok0"])
        return pending

    def _stream(self, st: SlotState, before: int) -> None:
        """Feed the request's incremental ``on_tokens`` callback with
        the tokens this commit delivered inside the budget, in commit
        order: the concatenation of every chunk a request receives is a
        prefix of its final ``ServeResult.tokens`` (TPP callbacks carry
        marks; horizon trimming at retire may drop a streamed tail).
        Callbacks must not mutate the engine — they run mid-commit."""
        cb = st.request.on_tokens
        if cb is None:
            return
        budget = st.request.max_new_tokens
        lo, hi = min(before, budget), min(len(st.out), budget)
        if hi > lo:
            cb(st.request.request_id, [int(t) for t in st.out[lo:hi]])

    def _sweep_lifecycle(self) -> List[ServeResult]:
        """Deadline expiry (queued AND active)."""
        out: List[ServeResult] = []
        now = time.perf_counter()
        for e in self.scheduler.take_expired(now):
            self._stats.deadline_misses += 1
            out.append(self._queue_result(e.request, "deadline"))
        for slot, st in self.scheduler.active():
            req = st.request
            expired = (req.deadline_s is not None
                       and now - st.submit_t > req.deadline_s)
            if not expired and req.max_wall_rounds is not None:
                expired = (self.scheduler.step_idx - st.submit_step
                           > req.max_wall_rounds)
            if expired:
                self._stats.deadline_misses += 1
                out.append(self._retire(slot, status="deadline"))
        return out

    def _shed_sweep(self) -> List[ServeResult]:
        """Overload control, run AFTER this step's admissions: whatever
        the slots could not absorb is the backlog, and entries past
        ``shed_queue`` of it (lowest scheduling priority first) are
        dropped as ``status="shed"`` — so shed_queue=0 means "serve
        what fits, queue nothing"."""
        out: List[ServeResult] = []
        if self.shed_queue is not None:
            for e in self.scheduler.shed_over(self.shed_queue):
                self._stats.shed += 1
                out.append(self._queue_result(e.request, "shed"))
        return out

    def _queue_result(self, req: ServeRequest, status: str,
                      error: Optional[str] = None) -> ServeResult:
        """A terminal result for a request that never held a slot."""
        return ServeResult(
            request_id=req.request_id, tokens=np.zeros((0,), np.int32),
            prompt_len=req.prompt_len, drafted=0, accepted=0, rounds=0,
            times=np.zeros((0,), np.float32) if req.is_tpp else None,
            status=status, error=error)

    def _on_admit_failure(self, slot: int, state: SlotState,
                          exc: Exception) -> List[ServeResult]:
        """An admission raised mid-backing (page exhaustion inside the
        staging prefill, an injected fault, an impossible fit): release
        whatever the slot already holds, then retry-or-fail."""
        if self.kv_layout == "paged":
            self.pool_t.free_slot(slot)
            if self.pool_d is not None:
                self.pool_d.free_slot(slot)
        req = state.request
        src = (self._fork_sources.get(req.prefix_group)
               if req.prefix_group is not None else None)
        if src is not None and src["state"] is state:
            del self._fork_sources[req.prefix_group]
        if isinstance(exc, AdmissionImpossible):
            return [self._retire(slot, status="failed", error=str(exc))]
        self._round_fail_streak += 1
        rid = req.request_id
        n = self._retries.get(rid, 0) + 1
        if n > self.max_round_retries:
            self._retries.pop(rid, None)
            return [self._retire(
                slot, status="failed",
                error=f"admission failed after {n - 1} retries: {exc}")]
        self._retries[rid] = n
        self._stats.retries += 1
        self.scheduler.defer(slot)
        return []

    def _on_phase_failure(self, items, exc: Exception, *,
                          phase: str) -> List[ServeResult]:
        """A batched prefill/decode phase raised: roll every rider back
        to its last committed length (block-table truncation — the
        paged pools' ``lens`` only ever advance at host commit, AFTER
        the device sync, so truncating to ``lens`` releases exactly the
        failed round's page growth), then retry-or-fail each request.
        Surviving retries re-run next step with unchanged host state —
        same ``round_idx``, hence bitwise-identical commits."""
        out: List[ServeResult] = []
        if self.kv_layout == "paged":
            pools = [self.pool_t] + ([self.pool_d]
                                     if self.pool_d is not None else [])
            for slot, _ in items:
                for pool in pools:
                    pool.truncate(slot, int(pool.lens[slot]))
        self._round_fail_streak += 1
        if self.retry_backoff_s > 0:
            time.sleep(self.retry_backoff_s
                       * (2.0 ** min(self._round_fail_streak - 1, 4)))
        for slot, st in items:
            rid = st.request.request_id
            n = self._retries.get(rid, 0) + 1
            if n > self.max_round_retries:
                self._retries.pop(rid, None)
                out.append(self._retire(
                    slot, status="failed",
                    error=f"{phase} failed after {n - 1} retries: {exc}"))
            else:
                self._retries[rid] = n
                self._stats.retries += 1
        return out

    def cancel(self, request_id: int) -> Optional[ServeResult]:
        """Cancel a queued or in-flight request.

        Queued: the entry leaves the pending list untouched-by-silicon.
        In-flight: the slot retires mid-stream — PREFILLING or DECODING,
        fork-group anchor or prefix-cache adoptee alike — returning its
        (possibly shared, refcounted) pages to the pool and keeping the
        tokens it already committed. Returns the terminal
        ``status="cancelled"`` result (also appended to the engine's
        result log), or None when the id is unknown/finished. Never
        perturbs any OTHER request's stream — the survivor-bitwise
        contract."""
        e = self.scheduler.cancel_pending(request_id)
        if e is not None:
            self._stats.cancellations += 1
            res = self._queue_result(e.request, "cancelled")
            self._results.append(res)
            return res
        slot = self.scheduler.find_slot(request_id)
        if slot is None:
            return None
        self._stats.cancellations += 1
        res = self._retire(slot, status="cancelled")
        self._results.append(res)
        return res

    def run(self, max_steps: Optional[int] = None) -> List[ServeResult]:
        """Step until the queue and every slot are drained."""
        out: List[ServeResult] = []
        steps = 0
        while self.scheduler.has_work():
            out.extend(self.step())
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return out

    def _overlap_stage(self) -> None:
        """Host work safe to run while a round is in flight on device:
        materialize the host-side prompt copies the NEXT step's prefill
        staging and admission matching will need. Reads scheduler state
        but never mutates it — round composition is already fixed when
        this runs, so the pipelined step stays bitwise the sync step."""
        for _, st in self.scheduler.active():
            if st.phase == PREFILLING:
                st.request.prompt_np()
        for e in self.scheduler.pending[:self.max_batch]:
            if not e.request.is_tpp:
                e.request.prompt_np()

    def async_overlap(self, poll=None):
        """The double-buffer window body for ``step(overlap=...)``:
        warm next-step host state (``_overlap_stage``), then run the
        caller's ``poll`` (arrival intake, stream draining) — all while
        the dispatched round is still computing on device."""
        def window():
            self._overlap_stage()
            if poll is not None:
                poll()
        return window

    def run_async(self, max_steps: Optional[int] = None, *,
                  poll=None) -> List[ServeResult]:
        """``run()`` with the double-buffered pipeline engaged: each
        step dispatches its round, then overlaps next-step host staging
        (and the optional ``poll`` callback) with device compute before
        the single batched fetch commits the round. Token streams are
        bitwise ``run()``'s — same ``fold_in(rng, round_idx)`` streams,
        same commit order; only host wall-time moves."""
        ov = self.async_overlap(poll)
        out: List[ServeResult] = []
        steps = 0
        while self.scheduler.has_work():
            out.extend(self.step(overlap=ov))
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return out

    def stats(self) -> EngineStats:
        return self._stats

    # -- internals ---------------------------------------------------------
    def _admit_impossible(self, total: int) -> None:
        """Raise when a reservation that does not fit NOW can never fit:
        no active slot will ever free pages. Suppressed while a
        ``page_exhaustion`` fault holds the free list — that shortage
        is transient by construction (the pages return at step end), so
        the admission defers instead. Raised BEFORE the caller defers,
        so the slot is still seated and ``_on_admit_failure`` can
        retire it cleanly."""
        if any(self.scheduler.active()):
            return
        if (self.faults is not None
                and self.faults.exhaustion_active(self.scheduler.step_idx)):
            return
        raise AdmissionImpossible(
            "paged KV pool cannot hold a single request "
            f"(need {total} positions); raise n_pages")

    def _admit(self, slot: int, state: SlotState) -> bool:
        """Back the slot with cache memory and start (or finish) its
        prefill. Returns False when a paged pool cannot back the
        request yet (deferred — no prefill wasted: the lifetime need is
        known from the request).

        With ``prefill_chunk`` set, admission only reserves pages and
        parks the slot in the PREFILLING phase — the prompt streams
        into the pool chunk by chunk in ``_prefill_step``. Without it
        (and for requests carrying extra prefill fields, e.g. VLM
        vision prefixes, and for the dense layout) the historical
        staging path runs: one dense batch-1 prefill scattered into the
        pool via ``write_prefill``."""
        req = state.request
        if self.domain == "tpp":
            return self._tpp_admit(slot, state)
        prefix = 0
        if req.extra and req.extra.get("vision_embeds") is not None:
            prefix = int(req.extra["vision_embeds"].shape[1])
        hit, runs = 0, None
        if self.kv_layout == "paged":
            # fixed_window reserves the pinned speculative window too
            # (zero unless fixed_window — the TPP path has its own)
            total = (prefix + req.prompt_len + req.max_new_tokens
                     + self._margin)
            # -- scenario fan-out: a group sibling forks the source's
            # prompt pages instead of prefilling its own copy
            src = self._fork_source_for(req)
            if src is not None:
                if not src["ready"]:
                    # the group's source is still prefilling — wait for
                    # it rather than paying a duplicate prefill
                    self.scheduler.defer(slot)
                    return False
                return self._admit_fork(slot, state, src, total)
            # -- cross-request prefix cache: adopt the longest
            # page-aligned match and prefill from the divergence point
            if (self.prefix_cache is not None and not req.extra
                    and self.prefill_chunk is not None):
                hit, runs = self.prefix_cache.match(
                    req.prompt_np(), req.prompt_len - 1)
            adopted = hit // self.pool_t.page
            # admission under memory pressure: reserve the request's
            # WHOLE lifetime (prefix + prompt + budget) up front, so
            # per-round growth of admitted slots can never exhaust the
            # free list; defer when the reservation does not fit now.
            # Adopted (shared) pages are counted once — they are
            # already allocated, so only the tail past the match draws
            # from the free list
            ok = self.pool_t.can_admit(total, adopted_blocks=adopted)
            if ok and self.method == "sd":
                ok = self.pool_d.can_admit(total, adopted_blocks=adopted)
            if not ok:
                self._admit_impossible(total)
                self.scheduler.defer(slot)
                return False
            self.pool_t.reserve(slot, total)
            if self.method == "sd":
                self.pool_d.reserve(slot, total)
            if (self.prefix_cache is not None and not req.extra
                    and self.prefill_chunk is not None):
                self._stats.prefix_lookups += 1
            if hit:
                self.pool_t.adopt(slot, runs["t"])
                if self.method == "sd":
                    self.pool_d.adopt(slot, runs["d"])
                state.prefix_hit_tokens = hit
                self._stats.prefix_hits += 1
                self._stats.prefix_hit_tokens += hit
        if (self.prefill_chunk is not None and self.kv_layout == "paged"
                and not req.extra):
            state.phase = PREFILLING
            state.prefilled = hit
            self._register_fork_source(state, slot, logits=None)
            return True
        t0 = time.perf_counter()
        batch = {"tokens": req.prompt[None, :]}
        if req.extra:
            batch.update(req.extra)
        logits, cache_t = _prefill_fn(self.cfg_t, self.max_len)(
            self.params_t, batch)
        cache_d = None
        if self.method == "sd":
            _, cache_d = _prefill_fn(self.cfg_d, self.max_len)(
                self.params_d, batch)
        if self.kv_layout == "paged":
            self.pool_t.write_prefill(slot, cache_t)
            if cache_d is not None:
                self.pool_d.write_prefill(slot, cache_d)
        else:
            self.pool_t.ensure(cache_t)
            self.pool_t.write(slot, cache_t)
            if cache_d is not None:
                self.pool_d.ensure(cache_d)
                self.pool_d.write(slot, cache_d)
        lp = jax.nn.log_softmax(logits[0, -1] / req.temperature)
        tok0 = int(jax.random.categorical(
            jax.random.fold_in(req.rng, 0), lp))
        self._first_token(state, tok0)
        self._register_fork_source(state, slot,
                                   logits=np.asarray(logits[0, -1]))
        self._stats.prefill_tokens += prefix + req.prompt_len
        self._stats.prefill_s += time.perf_counter() - t0
        return True

    def _fork_source_for(self, req: ServeRequest):
        """The live fork source of ``req``'s fan-out group, if any."""
        if req.prefix_group is None or req.extra:
            return None
        src = self._fork_sources.get(req.prefix_group)
        if src is None:
            return None
        # entries are dropped at retire time; be defensive about a slot
        # that was reassigned anyway (e.g. a deferred source)
        if self.scheduler.slots[src["slot"]] is not src["state"]:
            del self._fork_sources[req.prefix_group]
            return None
        return src

    def _register_fork_source(self, state: SlotState, slot: int,
                              logits, ready: Optional[bool] = None) -> None:
        """Make this slot its fan-out group's fork source (first
        admitted member wins; later members fork it). ``logits`` is the
        prompt's last-position TEMPERATURE-FREE logits row — what a
        forked sibling samples its first token from — or None while the
        source is still prefilling (``_prefill_step`` fills it in).
        ``ready`` flags whether siblings may fork NOW; it defaults to
        "logits are present" and is set explicitly by the TPP domain,
        whose forks need the source's prefilled pages but no logits."""
        req = state.request
        if (req.prefix_group is None or req.extra
                or self.kv_layout != "paged"
                or req.prefix_group in self._fork_sources):
            return
        self._fork_sources[req.prefix_group] = {
            "slot": slot, "state": state, "logits": logits,
            "ready": (logits is not None) if ready is None else ready}

    def _admit_fork(self, slot: int, state: SlotState, src, total: int) -> bool:
        """Admit a fan-out sibling by FORKING the source's prompt pages:
        the block tables share every page over [0, prompt_len) and the
        first divergent write triggers a copy-on-write of at most the
        one mid-page boundary page. No prefill forward runs at all; the
        first token is sampled from the source's stored prompt logits
        with this sibling's own ``fold_in(rng, 0)`` — bitwise what an
        independent admission of the same request would draw."""
        req = state.request
        plen = req.prompt_len
        adopted = self.pool_t._blocks_for(plen)
        cow = 0
        if plen % self.pool_t.page != 0:
            # the fork's first append COWs the mid-page boundary page;
            # when that page was unshared until now, the SOURCE's next
            # append becomes a COW too — budget both new pendings
            b = plen // self.pool_t.page
            pid = int(self.pool_t.tables[src["slot"], b])
            cow = 1 + (1 if int(self.pool_t.refcount[pid]) == 1 else 0)
        ok = self.pool_t.can_admit(total, adopted_blocks=adopted,
                                   cow_pages=cow)
        if ok and self.method == "sd":
            ok = self.pool_d.can_admit(total, adopted_blocks=adopted,
                                       cow_pages=cow)
        if not ok:
            self._admit_impossible(total)
            self.scheduler.defer(slot)
            return False
        self.pool_t.reserve(slot, total)
        self.pool_t.fork(src["slot"], slot, plen)
        if self.method == "sd":
            self.pool_d.reserve(slot, total)
            self.pool_d.fork(src["slot"], slot, plen)
        state.prefix_hit_tokens = plen
        self._stats.prefix_lookups += 1
        self._stats.prefix_hits += 1
        self._stats.prefix_hit_tokens += plen
        if req.is_tpp:
            # no first-token draw: the TPP pending event is the shared
            # history's own last event
            state.horizon = req.t_end
            self._tpp_first_event(state)
            return True
        lp = jax.nn.log_softmax(jnp.asarray(src["logits"])
                                / req.temperature)
        tok0 = int(jax.random.categorical(
            jax.random.fold_in(req.rng, 0), lp))
        self._first_token(state, tok0)
        return True

    def _first_token(self, state: SlotState, tok0: int) -> None:
        """Commit a freshly prefilled slot's first token (sampled from
        the prompt's last-position logits with fold_in(rng, 0) — the
        same draw on every admission path) and flip it to DECODING."""
        state.out.append(tok0)
        state.pending = tok0
        state.phase = DECODING
        state.ttft_rounds = self.scheduler.step_idx - state.submit_step
        state.ttft_s = time.perf_counter() - state.submit_t
        self._stats.prefills += 1
        self._stats.tokens += 1
        self._stream(state, 0)

    def _prefill_step(self) -> None:
        """Chunked-prefill work for this step: batched ``prefill_paged``
        calls over every PREFILLING slot, one chunk per slot per call,
        until the per-step token budget (or the prompts) run out. Page
        growth is per chunk, always inside the slot's admission-time
        reservation, so it can never exhaust the free list. A slot
        whose prompt completes samples its first token from the final
        chunk's last valid row — bitwise the staging path's draw — as a
        LAZY device draw the step's single commit fetch materializes
        (``_on_prompt_complete``)."""
        budget = self.prefill_budget or (1 << 30)
        chunk = self.prefill_chunk
        t0 = time.perf_counter()
        sd = self.method == "sd"
        while budget > 0:
            pref = [(s, st) for s, st in self.scheduler.active()
                    if st.phase == PREFILLING]
            if not pref:
                break
            S = self.max_batch
            tokens = np.zeros((S, chunk), np.int32)
            nvalid = np.zeros((S,), np.int32)
            lens = np.zeros((S,), np.int32)
            work = []
            for slot, st in pref:
                n = min(chunk, st.request.prompt_len - st.prefilled, budget)
                if n <= 0:
                    continue                     # budget spent this call
                tokens[slot, :n] = \
                    st.request.prompt_np()[st.prefilled:st.prefilled + n]
                nvalid[slot] = n
                lens[slot] = st.prefilled
                budget -= n
                # a prefilling slot's boundary page is never actually
                # shared (cache adoption is page-aligned), but keep the
                # write-barrier uniform: COW before any pool write
                self.pool_t.cow_for_append(slot)
                self.pool_t.ensure_blocks(slot, st.prefilled + n)
                if sd:
                    self.pool_d.cow_for_append(slot)
                    self.pool_d.ensure_blocks(slot, st.prefilled + n)
                work.append((slot, st, n))
            if not work:
                break
            fn = _prefill_chunk_fn(self.cfg_t, self.cfg_d if sd else None,
                                   chunk, self.policy, self.max_len)
            lg_last, pg_t, pg_d = fn(
                self.params_t, self.params_d, self.pool_t.pages,
                self.pool_t.device_tables(),
                self.pool_d.pages if sd else None,
                self.pool_d.device_tables() if sd else None,
                jnp.asarray(lens), jnp.asarray(tokens), jnp.asarray(nvalid))
            self.pool_t.pages = pg_t
            if sd:
                self.pool_d.pages = pg_d
            self._fault_barrier()
            for slot, st, n in work:
                st.prefilled += n
                self.pool_t.lens[slot] = st.prefilled    # commit the chunk
                if sd:
                    self.pool_d.lens[slot] = st.prefilled
                self._stats.prefill_tokens += n
                if st.prefilled == st.request.prompt_len:
                    self._on_prompt_complete(slot, st, lg_last[slot])
        self._stats.prefill_s += time.perf_counter() - t0

    def _on_prompt_complete(self, slot: int, st: SlotState, row) -> None:
        """A chunked slot's prompt is fully in the pool; ``row`` is the
        final chunk's last-valid-position logits as a LAZY device row.
        The first token is the same ``fold_in(rng, 0)`` draw as the
        staging path, built here as un-fetched device ops so the step's
        single batched fetch materializes it with the round outputs —
        the decode round ingests it as a device value, so a slot that
        completes prefill still joins this step's round, exactly the
        synchronous schedule. The disaggregated engine overrides this
        to park the slot for handoff to a decode worker instead."""
        req = st.request
        src = (self._fork_sources.get(req.prefix_group)
               if req.prefix_group is not None else None)
        is_src = src is not None and src["state"] is st
        if is_src:
            # the group's siblings sample THEIR first token from this
            # temperature-free row; it materializes at the commit fetch,
            # before any sibling's next-step admission reads it
            src["ready"] = True
        lp = jax.nn.log_softmax(row / req.temperature)
        tok0 = jax.random.categorical(jax.random.fold_in(req.rng, 0), lp)
        if req.max_new_tokens == 1:
            # the whole budget is the first token: commit eagerly so the
            # slot retires (freeing its pages) BEFORE this step's round,
            # the schedule the staging path produces
            if is_src:
                src["logits"] = np.asarray(row)
            self._first_token(st, int(tok0))
            return
        self._defer_first_token(st, slot, tok0, row if is_src else None)

    # -- TPP (event-sequence) serving --------------------------------------
    def _tpp_enc(self, req: ServeRequest):
        """The encoder input a TPP request PREFILLS: [BOS@t=0] +
        history[:-1] (length == prompt_len). The history's LAST event is
        the pending event the first decode round ingests — the same
        cache-trails-committed-by-one convention as the sampling loops,
        so the cache length invariant ``len == prompt_len + len(out)``
        holds from admission onward."""
        n = req.prompt_len
        enc_t = np.zeros((n,), np.float32)
        enc_k = np.full((n,), int(self.cfg_t.num_marks), np.int32)
        if n > 1:
            enc_t[1:] = req.times[:-1]
            enc_k[1:] = req.prompt_np()[:-1]
        return enc_t, enc_k

    def _tpp_admit(self, slot: int, state: SlotState) -> bool:
        """Paged TPP admission: reserve history + budget + gamma, adopt
        any ``tpp_history_key`` radix-cache match, then park the slot
        PREFILLING (or straight to DECODING for an empty history —
        there is nothing to prefill; the rollout starts at the BOS
        sentinel event)."""
        req = state.request
        total = req.prompt_len + req.max_new_tokens + self._tpp_margin
        src = self._fork_source_for(req)
        if src is not None:
            if not src["ready"]:
                # the group's source history is still prefilling — wait
                # for its pages rather than paying a duplicate prefill
                self.scheduler.defer(slot)
                return False
            state.horizon = req.t_end
            return self._admit_fork(slot, state, src, total)
        hit, runs = 0, None
        if self.prefix_cache is not None and req.prompt_len > 0:
            enc_t, enc_k = self._tpp_enc(req)
            hit, runs = self.prefix_cache.match(
                tpp_history_key(enc_t, enc_k), req.prompt_len - 1)
        adopted = hit // self.pool_t.page
        ok = self.pool_t.can_admit(total, adopted_blocks=adopted)
        if ok and self.method == "sd":
            ok = self.pool_d.can_admit(total, adopted_blocks=adopted)
        if not ok:
            self._admit_impossible(total)
            self.scheduler.defer(slot)
            return False
        self.pool_t.reserve(slot, total)
        if self.method == "sd":
            self.pool_d.reserve(slot, total)
        if self.prefix_cache is not None and req.prompt_len > 0:
            self._stats.prefix_lookups += 1
        if hit:
            self.pool_t.adopt(slot, runs["t"])
            if self.method == "sd":
                self.pool_d.adopt(slot, runs["d"])
            state.prefix_hit_tokens = hit
            self._stats.prefix_hits += 1
            self._stats.prefix_hit_tokens += hit
        state.horizon = req.t_end
        state.prefilled = hit
        if req.prompt_len == 0:
            self._tpp_first_event(state)
            self._register_fork_source(state, slot, logits=None, ready=True)
        else:
            state.phase = PREFILLING
            self._register_fork_source(state, slot, logits=None,
                                       ready=False)
        return True

    def _tpp_first_event(self, state: SlotState) -> None:
        """Flip a slot whose encoder history is in the pool to DECODING.
        The TPP "first token" is the history's own last event (or the
        BOS sentinel at t=0 for an empty history) — it becomes the
        pending event round 1 ingests; nothing is sampled, so unlike
        the LM path admission consumes no ``fold_in(rng, 0)`` draw
        (round indices start at 1 on both domains either way)."""
        req = state.request
        if req.prompt_len > 0:
            state.t_pend = float(req.times[-1])
            state.pending = int(req.prompt_np()[-1])
        else:
            state.t_pend = 0.0
            state.pending = int(self.cfg_t.num_marks)
        state.phase = DECODING
        state.ttft_rounds = self.scheduler.step_idx - state.submit_step
        state.ttft_s = time.perf_counter() - state.submit_t
        self._stats.prefills += 1

    def _tpp_prefill_step(self) -> None:
        """Chunked (time, mark) history prefill — ``_prefill_step`` with
        a float time lane and no logits/first-token sampling: a slot
        whose history completes flips to DECODING with its last history
        event pending, and its fan-out group (if any) becomes forkable."""
        budget = self.prefill_budget or (1 << 30)
        chunk = self.prefill_chunk
        t0 = time.perf_counter()
        sd = self.method == "sd"
        while budget > 0:
            pref = [(s, st) for s, st in self.scheduler.active()
                    if st.phase == PREFILLING]
            if not pref:
                break
            S = self.max_batch
            times = np.zeros((S, chunk), np.float32)
            types = np.zeros((S, chunk), np.int32)
            nvalid = np.zeros((S,), np.int32)
            lens = np.zeros((S,), np.int32)
            work = []
            for slot, st in pref:
                n = min(chunk, st.request.prompt_len - st.prefilled, budget)
                if n <= 0:
                    continue                     # budget spent this call
                enc_t, enc_k = self._tpp_enc(st.request)
                times[slot, :n] = enc_t[st.prefilled:st.prefilled + n]
                types[slot, :n] = enc_k[st.prefilled:st.prefilled + n]
                nvalid[slot] = n
                lens[slot] = st.prefilled
                budget -= n
                self.pool_t.cow_for_append(slot)
                self.pool_t.ensure_blocks(slot, st.prefilled + n)
                if sd:
                    self.pool_d.cow_for_append(slot)
                    self.pool_d.ensure_blocks(slot, st.prefilled + n)
                work.append((slot, st, n))
            if not work:
                break
            fn = tpp_rounds.tpp_prefill_chunk_fn(
                self.cfg_t, self.cfg_d if sd else None, chunk,
                self.policy, self.max_len)
            pg_t, pg_d = fn(
                self.params_t, self.params_d, self.pool_t.pages,
                self.pool_t.device_tables(),
                self.pool_d.pages if sd else None,
                self.pool_d.device_tables() if sd else None,
                jnp.asarray(lens), jnp.asarray(times), jnp.asarray(types),
                jnp.asarray(nvalid))
            self.pool_t.pages = pg_t
            if sd:
                self.pool_d.pages = pg_d
            self._fault_barrier()
            for slot, st, n in work:
                st.prefilled += n
                self.pool_t.lens[slot] = st.prefilled
                if sd:
                    self.pool_d.lens[slot] = st.prefilled
                self._stats.prefill_tokens += n
                if st.prefilled == st.request.prompt_len:
                    src = (self._fork_sources.get(st.request.prefix_group)
                           if st.request.prefix_group is not None else None)
                    if src is not None and src["state"] is st:
                        src["ready"] = True
                    self._tpp_first_event(st)
        self._stats.prefill_s += time.perf_counter() - t0

    def _tpp_round_inputs(self, alive):
        S = self.max_batch
        t_pend = np.zeros((S,), np.float32)
        k_pend = np.zeros((S,), np.int32)
        ridx = np.zeros((S,), np.int32)
        keys = [_as_key(0)] * S
        for slot, st in alive:
            t_pend[slot] = st.t_pend
            k_pend[slot] = st.pending
            ridx[slot] = st.round_idx
            keys[slot] = _as_key(st.request.rng)
        if self.faults is not None:
            bad = self.faults.nan_lane_slot(self.scheduler.step_idx)
            if bad is not None and any(s == bad for s, _ in alive):
                # poison ONE lane's pending event time; the round's
                # per-lane ok flag quarantines exactly that request
                t_pend[bad] = np.nan
                self.faults.note_nan_injected(self.scheduler.step_idx,
                                              self)
        return (jnp.asarray(t_pend), jnp.asarray(k_pend), jnp.stack(keys),
                jnp.asarray(ridx))

    def _tpp_sd_dispatch(self, alive) -> _InflightRound:
        """Dispatch one paged TPP propose-verify round (fixed window —
        see the constructor note). Commit is append + block-table
        truncation, exactly like the token path, plus the float
        event-time lane; all host-bound scalars arrive as one int32
        [S, g+3] + one float32 [S, g+1] packed pair."""
        gamma = self.tpp_gamma
        len0_t, len0_d = {}, {}
        for slot, _ in alive:
            len0_t[slot] = int(self.pool_t.lens[slot])
            len0_d[slot] = int(self.pool_d.lens[slot])
            self.pool_t.cow_for_append(slot)
            self.pool_d.cow_for_append(slot)
            self.pool_t.ensure_blocks(slot, len0_t[slot] + gamma + 1)
            self.pool_d.ensure_blocks(slot, len0_d[slot] + gamma + 1)
        t_pend, k_pend, keys, ridx = self._tpp_round_inputs(alive)
        fn = tpp_rounds.tpp_sd_round_paged_fn(
            self.cfg_t, self.cfg_d, gamma, self.policy, self.max_len)
        pg_t, pg_d, packed_i, packed_f = fn(
            self.params_t, self.params_d, self.pool_t.pages,
            self.pool_d.pages, self.pool_t.device_tables(),
            self.pool_t.device_lens(), self.pool_d.device_tables(),
            self.pool_d.device_lens(), t_pend, k_pend, keys, ridx)
        self.pool_t.pages, self.pool_d.pages = pg_t, pg_d

        def commit(host) -> List[ServeResult]:
            pk_i, pk_f = host
            d_k, A = pk_i[:, :gamma], pk_i[:, gamma]
            new_k, okl = pk_i[:, gamma + 1], pk_i[:, gamma + 2].astype(bool)
            d_t, new_t = pk_f[:, :gamma], pk_f[:, gamma]
            good = [(s, st) for s, st in alive if bool(okl[s])]
            delivered = 0
            for slot, st in good:
                a = int(A[slot])
                budget = st.request.max_new_tokens
                before = min(len(st.out), budget)
                st.out.extend(int(m) for m in d_k[slot, :a])
                st.out_times.extend(float(t) for t in d_t[slot, :a])
                st.out.append(int(new_k[slot]))
                st.out_times.append(float(new_t[slot]))
                st.pending = int(new_k[slot])
                st.t_pend = float(new_t[slot])
                st.round_idx += 1
                st.drafted += gamma
                st.accepted += a
                st.rounds += 1
                # the over-budget tail is trimmed at retire (out and
                # out_times must stay aligned); count delivered within it
                delivered += min(len(st.out), budget) - before
                self.pool_t.truncate(slot, len0_t[slot] + 1 + a)
                self.pool_d.truncate(slot, len0_d[slot] + 1 + a)
                self._stream(st, before)
            self._stats.tokens += delivered
            self._stats.drafted += gamma * len(good)
            self._stats.accepted += int(sum(int(A[s]) for s, _ in good))
            self._stats.target_forwards += 1
            self._stats.draft_forwards += gamma
            self._note_group_round(alive)
            return self._quarantine(alive, okl)

        return _InflightRound((packed_i, packed_f), commit)

    def _tpp_ar_dispatch(self, alive) -> _InflightRound:
        """Dispatch one committed event per alive slot (paged pool)."""
        len0 = {}
        for slot, _ in alive:
            len0[slot] = int(self.pool_t.lens[slot])
            self.pool_t.cow_for_append(slot)
            self.pool_t.ensure_blocks(slot, len0[slot] + 1)
        t_pend, k_pend, keys, ridx = self._tpp_round_inputs(alive)
        fn = tpp_rounds.tpp_ar_round_paged_fn(self.cfg_t, self.policy,
                                              self.max_len)
        pg_t, packed_i, new_t = fn(
            self.params_t, self.pool_t.pages, self.pool_t.device_tables(),
            self.pool_t.device_lens(), t_pend, k_pend, keys, ridx)
        self.pool_t.pages = pg_t

        def commit(host) -> List[ServeResult]:
            pk_i, new_t = host
            new_k, okl = pk_i[:, 0], pk_i[:, 1].astype(bool)
            good = [(s, st) for s, st in alive if bool(okl[s])]
            for slot, st in good:
                before = min(len(st.out), st.request.max_new_tokens)
                self.pool_t.truncate(slot, len0[slot] + 1)
                st.out.append(int(new_k[slot]))
                st.out_times.append(float(new_t[slot]))
                st.pending = int(new_k[slot])
                st.t_pend = float(new_t[slot])
                st.round_idx += 1
                st.rounds += 1
                self._stream(st, before)
            self._stats.tokens += len(good)
            self._stats.target_forwards += 1
            self._note_group_round(alive)
            return self._quarantine(alive, okl)

        return _InflightRound((packed_i, new_t), commit)

    def fanout_headroom(self, prompt_len: int, max_new_tokens: int) -> int:
        """How many members of ONE fan-out group over a shared
        ``prompt_len`` history/prompt the pools could admit right now —
        the wave size the forecast executor submits. Charges the first
        member its full lifetime reservation, every further member only
        the unshared tail past the forked prefix (+2 boundary
        copy-on-write pages), against the free list plus synchronously
        evictable cache pages net of standing reservations; capped at
        ``max_batch``, floored at 1 (a single member is admissible by
        construction, so a wave always makes progress — an optimistic
        estimate merely defers its surplus members to the next steps)."""
        if self.kv_layout != "paged":
            return self.max_batch
        total = prompt_len + max_new_tokens + (
            self._tpp_margin if self.domain == "tpp" else self._margin)
        k = self.max_batch
        pools = [self.pool_t] + ([self.pool_d]
                                 if self.pool_d is not None else [])
        for pool in pools:
            first = pool._blocks_for(min(total, pool.capacity))
            sib = max(1, first - pool._blocks_for(prompt_len) + 2)
            avail = pool._headroom() - pool._shortfall()
            k_pool = 1 if avail < first else 1 + (avail - first) // sib
            k = min(k, k_pool)
        return max(1, min(k, self.max_batch))

    def _note_group_round(self, alive) -> None:
        """Per-group forward-sharing accounting: this round was ONE
        batched target forward; credit it to every fan-out group with a
        member aboard, and count the member-rounds it covered."""
        counts: Dict[int, int] = {}
        for _, st in alive:
            g = st.request.prefix_group
            if g is not None:
                counts[g] = counts.get(g, 0) + 1
        for g, c in counts.items():
            self._stats.group_forwards[g] = \
                self._stats.group_forwards.get(g, 0) + 1
            self._stats.group_member_rounds[g] = \
                self._stats.group_member_rounds.get(g, 0) + c

    def _round_inputs(self, alive):
        S = self.max_batch
        pending = np.zeros((S,), np.int32)
        ridx = np.zeros((S,), np.int32)
        temps = np.ones((S,), np.float32)
        active = np.zeros((S,), bool)
        keys = [_as_key(0)] * S
        for slot, st in alive:
            pending[slot] = st.pending
            ridx[slot] = st.round_idx
            temps[slot] = st.request.temperature
            active[slot] = True
            keys[slot] = _as_key(st.request.rng)
        if self.faults is not None:
            bad = self.faults.nan_lane_slot(self.scheduler.step_idx)
            if bad is not None and active[bad]:
                # poison ONE lane's temperature: its log-softmax goes
                # NaN; the per-lane math (vmapped rows, softmax over the
                # vocab axis) never lets it touch another lane
                temps[bad] = np.nan
                self.faults.note_nan_injected(self.scheduler.step_idx,
                                              self)
        out = (jnp.asarray(pending), jnp.stack(keys), jnp.asarray(ridx),
               jnp.asarray(temps), jnp.asarray(active))
        if self.rules is None:
            return out
        # place the per-slot vectors over the data axis so the jitted
        # round sees every operand pre-sharded (no host-side broadcast)
        return tuple(
            jax.device_put(a, self.rules.sharding(
                ("batch",) + (None,) * (a.ndim - 1), dims=tuple(a.shape)))
            for a in out)

    def _clamped_gamma(self, alive) -> int:
        """The policy's window, clamped so the round never drafts past
        (a) the largest remaining budget among alive slots — a round
        delivers at most gamma+1 tokens, so drafting more is pure waste
        — and (b) a non-ring KV buffer's capacity: the models' slot
        indexing wraps modulo the buffer, so writing beyond it would
        silently overwrite the prompt's entries.

        With ``fixed_window`` the policy window is returned untouched:
        submit-time validation plus the per-request margin reservation
        guarantee the pinned window always fits (both layouts), and
        skipping the batch-dependent clamp is exactly what makes every
        stream independent of batch composition."""
        gamma = self.draft_policy.gamma(self._policy_state)
        if self.fixed_window:
            return gamma
        # a deferred first token is already committed as far as the
        # budget is concerned (the staging schedule has it in `out` by
        # round time); count it or the window drifts from staging
        max_remaining = max(
            st.request.max_new_tokens - len(st.out)
            - (1 if st.first_pending else 0)
            for _, st in alive)
        gamma = min(gamma, max(1, max_remaining - 1))
        for cfg, pool in ((self.cfg_t, self.pool_t),
                          (self.cfg_d, self.pool_d)):
            if self.kv_layout == "paged":
                # same bound as the dense pos buffer (capacity == the
                # dense max_len), so both layouts pick identical windows
                smax = pool.capacity
                head = smax - 1 - max(int(pool.lens[s]) for s, _ in alive)
                gamma = min(gamma, max(1, head))
            elif (rollback_kind(cfg) != "replay"
                    and cfg.sliding_window == 0 and "pos" in pool.tree):
                smax = pool.tree["pos"].shape[-1]
                lens = np.asarray(pool.lens)
                head = smax - 1 - max(int(lens[s]) for s, _ in alive)
                gamma = min(gamma, max(1, head))
        if self.kv_layout == "paged":
            # under page pressure the BATCH window (max over alive
            # budgets) can transiently over-ask a short-budget slot's
            # lifetime reservation; shrink it to what the free list can
            # back. Admission reservations guarantee gamma=1 always
            # fits, so this terminates with progress — it only ever
            # fires on under-provisioned pools with mixed budgets
            def short(pool, g):
                need = sum(
                    pool._blocks_for(min(int(pool.lens[s]) + 1 + g,
                                         pool.capacity))
                    - int(pool.n_blocks[s]) + pool._cow_pending(s)
                    for s, _ in alive)
                return need > pool._headroom()
            while gamma > 1 and (short(self.pool_t, gamma) or
                                 short(self.pool_d, gamma)):
                gamma -= 1
        return gamma

    def _sd_dispatch(self, alive) -> _InflightRound:
        gamma = self._clamped_gamma(alive)
        pending, keys, ridx, temps, active = self._round_inputs(alive)
        fn = _sd_round_fn(self.cfg_t, self.cfg_d, gamma)
        pt_ckpt, pd_ckpt = self.pool_t.tree, self.pool_d.tree
        pt_out, pd_out, packed = fn(
            self.params_t, self.params_d, pt_ckpt, pd_ckpt, pending, keys,
            ridx, temps, active)

        def commit(out) -> List[ServeResult]:
            d_toks = out[:, :gamma]
            A, extra = out[:, gamma], out[:, gamma + 1]
            okl = out[:, gamma + 2].astype(bool)
            good = [(s, st) for s, st in alive if bool(okl[s])]
            commits = {}
            delivered = 0
            for slot, st in good:
                a = int(A[slot])
                toks = [int(st.pending)] + [int(t) for t in d_toks[slot, :a]]
                commits[slot] = (toks, a == gamma)
                before = len(st.out)
                st.out.extend(toks[1:] + [int(extra[slot])])
                st.pending = int(extra[slot])
                st.round_idx += 1
                st.drafted += gamma
                st.accepted += a
                st.rounds += 1
                if len(st.out) > st.request.max_new_tokens:
                    del st.out[st.request.max_new_tokens:]
                delivered += len(st.out) - before
                self._stream(st, before)
            # quarantined lanes never enter `commits`, so the replay
            # families skip their re-extend and the mask families' rolled
            # slots are simply never read again (admission overwrites)
            self.pool_t.tree = self._rolled_pool(
                self.cfg_t, self.params_t, pt_ckpt, pt_out, commits)
            self.pool_d.tree = self._rolled_pool(
                self.cfg_d, self.params_d, pd_ckpt, pd_out, commits)
            acc_sum = int(sum(int(A[s]) for s, _ in good))
            # one policy update per request, as in single-request serving —
            # a batch-aggregate (gamma*n, sum A) would only ever grow the
            # window when EVERY slot fully accepts, collapsing gamma under
            # real mixed traffic
            for slot, _ in good:
                self._policy_state = self.draft_policy.update(
                    self._policy_state, gamma, int(A[slot]))
            self._stats.tokens += delivered
            self._stats.drafted += gamma * len(good)
            self._stats.accepted += acc_sum
            self._stats.target_forwards += 1
            # gamma batched draft forwards produce the round's gamma draft
            # distributions; the trailing extend only maintains the draft
            # cache and is not a drafting forward (same convention as the
            # host loops' `drafted` counter in sampling/loops.py, so for a
            # single-slot engine draft_forwards == drafted exactly)
            self._stats.draft_forwards += gamma
            self._note_group_round(alive)
            return self._quarantine(alive, okl)

        return _InflightRound(packed, commit)

    def _quarantine(self, alive, okl) -> List[ServeResult]:
        """Retire every lane whose round health flag came back False
        (non-finite logits): ONE structured per-request failure, while
        the lanes that shared the batch commit untouched — the per-lane
        quarantine of the failure-semantics contract."""
        out: List[ServeResult] = []
        for slot, st in alive:
            if not bool(okl[slot]):
                out.append(self._retire(
                    slot, status="failed",
                    error=f"non-finite logits in round {st.round_idx}"))
        return out

    def _sd_dispatch_paged(self, alive) -> _InflightRound:
        """Dispatch one paged propose-verify round: grow block tables
        for the window's writes and launch the jitted paged round
        (spec-verify kernel attention). Commit/rollback stays host-side
        block-table truncation, driven by ONE packed [S, gamma+3] fetch
        (d_toks ‖ A ‖ extra ‖ ok) instead of four per-array
        transfers."""
        gamma = self._clamped_gamma(alive)
        len0_t, len0_d = {}, {}
        for slot, _ in alive:
            len0_t[slot] = int(self.pool_t.lens[slot])
            len0_d[slot] = int(self.pool_d.lens[slot])
            # write barrier: the round writes from lens onward, so a
            # shared boundary page (fork / adopted cache prefix) is
            # copied before the batched forward touches it
            self.pool_t.cow_for_append(slot)
            self.pool_d.cow_for_append(slot)
            self.pool_t.ensure_blocks(slot, len0_t[slot] + gamma + 1)
            self.pool_d.ensure_blocks(slot, len0_d[slot] + gamma + 1)
        pending, keys, ridx, temps, _ = self._round_inputs(alive)
        pending = self._inject_deferred(pending)
        fn = _sd_round_paged_fn(self.cfg_t, self.cfg_d, gamma, self.policy,
                                self.max_len)
        pg_t, pg_d, packed = fn(
            self.params_t, self.params_d, self.pool_t.pages,
            self.pool_d.pages, self.pool_t.device_tables(),
            self.pool_t.device_lens(), self.pool_d.device_tables(),
            self.pool_d.device_lens(), pending, keys, ridx, temps)
        self.pool_t.pages, self.pool_d.pages = pg_t, pg_d

        def commit(out) -> List[ServeResult]:
            d_toks = out[:, :gamma]
            A, extra = out[:, gamma], out[:, gamma + 1]
            okl = out[:, gamma + 2].astype(bool)
            good = [(s, st) for s, st in alive if bool(okl[s])]
            delivered = 0
            for slot, st in good:
                a = int(A[slot])
                before = len(st.out)
                st.out.extend([int(t) for t in d_toks[slot, :a]]
                              + [int(extra[slot])])
                st.pending = int(extra[slot])
                st.round_idx += 1
                st.drafted += gamma
                st.accepted += a
                st.rounds += 1
                if len(st.out) > st.request.max_new_tokens:
                    del st.out[st.request.max_new_tokens:]
                delivered += len(st.out) - before
                # rollback == truncation: surplus pages return to the
                # free list; the stale K/V past the committed length is
                # causally invisible until the next round overwrites it
                self.pool_t.truncate(slot, len0_t[slot] + 1 + a)
                self.pool_d.truncate(slot, len0_d[slot] + 1 + a)
                self._stream(st, before)
            for slot, _ in good:
                self._policy_state = self.draft_policy.update(
                    self._policy_state, gamma, int(A[slot]))
            self._stats.tokens += delivered
            self._stats.drafted += gamma * len(good)
            self._stats.accepted += int(sum(int(A[s]) for s, _ in good))
            self._stats.target_forwards += 1
            self._stats.draft_forwards += gamma
            self._note_group_round(alive)
            return self._quarantine(alive, okl)

        return _InflightRound(packed, commit)

    def _ar_dispatch_paged(self, alive) -> _InflightRound:
        len0 = {}
        for slot, _ in alive:
            len0[slot] = int(self.pool_t.lens[slot])
            self.pool_t.cow_for_append(slot)
            self.pool_t.ensure_blocks(slot, len0[slot] + 1)
        pending, keys, ridx, temps, _ = self._round_inputs(alive)
        pending = self._inject_deferred(pending)
        fn = _ar_round_paged_fn(self.cfg_t, self.policy, self.max_len)
        pg_t, packed = fn(self.params_t, self.pool_t.pages,
                          self.pool_t.device_tables(),
                          self.pool_t.device_lens(), pending, keys, ridx,
                          temps)
        self.pool_t.pages = pg_t

        def commit(out) -> List[ServeResult]:
            tok, okl = out[:, 0], out[:, 1].astype(bool)
            good = [(s, st) for s, st in alive if bool(okl[s])]
            for slot, st in good:
                before = len(st.out)
                self.pool_t.truncate(slot, len0[slot] + 1)
                st.out.append(int(tok[slot]))
                st.pending = int(tok[slot])
                st.round_idx += 1
                st.rounds += 1
                self._stream(st, before)
            self._stats.tokens += len(good)
            self._stats.target_forwards += 1
            self._note_group_round(alive)
            return self._quarantine(alive, okl)

        return _InflightRound(packed, commit)

    def _rolled_pool(self, cfg, params, ckpt_tree, out_tree, commits):
        """Final pool for this round. Mask families were rolled back
        inside the jitted round; replay families re-extend each active
        slot's committed tokens from the round-entry checkpoint (the
        fully-accepted case reuses the post-forward state directly)."""
        if rollback_kind(cfg) != "replay":
            return out_tree
        ext1 = _single_extend_fn(cfg)
        tree = ckpt_tree
        for slot, (toks, fully_accepted) in commits.items():
            if fully_accepted:
                cache = jax.tree.map(lambda p: p[slot], out_tree)
            else:
                cache = jax.tree.map(lambda p: p[slot], ckpt_tree)
                _, cache = ext1(params, cache,
                                jnp.asarray(toks, jnp.int32)[None, :])
            tree = jax.tree.map(lambda p, c: p.at[slot].set(c), tree, cache)
        return tree

    def _ar_dispatch(self, alive) -> _InflightRound:
        pending, keys, ridx, temps, active = self._round_inputs(alive)
        fn = _ar_round_fn(self.cfg_t)
        pt_out, packed = fn(self.params_t, self.pool_t.tree, pending,
                            keys, ridx, temps, active)

        def commit(out) -> List[ServeResult]:
            tok, okl = out[:, 0], out[:, 1].astype(bool)
            self.pool_t.tree = pt_out
            good = [(s, st) for s, st in alive if bool(okl[s])]
            for slot, st in good:
                before = len(st.out)
                st.out.append(int(tok[slot]))
                st.pending = int(tok[slot])
                st.round_idx += 1
                st.rounds += 1
                self._stream(st, before)
            self._stats.tokens += len(good)
            self._stats.target_forwards += 1
            self._note_group_round(alive)
            return self._quarantine(alive, okl)

        return _InflightRound(packed, commit)

    def _retire(self, slot: int, status: str = "ok",
                error: Optional[str] = None) -> ServeResult:
        """Vacate ``slot`` and build its terminal result. Every status
        frees the same resources (slot, refcounted pages, fork-source
        anchor role); only an "ok" retirement donates prompt pages to
        the prefix cache or counts toward completions/goodput — a
        failed lane's pages may hold poisoned K/V, and a cancelled or
        expired request's prefill may be partial."""
        st = self.scheduler.retire(slot)
        req = st.request
        if self.kv_layout == "paged":
            src = (self._fork_sources.get(req.prefix_group)
                   if req.prefix_group is not None else None)
            if src is not None and src["state"] is st:
                del self._fork_sources[req.prefix_group]
            if (status == "ok" and self.prefix_cache is not None
                    and not req.extra):
                # donate the FULL prompt pages into the radix cache:
                # full prompt pages are provably never rewritten or
                # COWed (writes only land past the prompt), so their
                # K/V is exactly what a cold prefill would produce.
                # insert() retains new nodes' pages, turning the
                # free_slot below into an ownership transfer
                full = req.prompt_len // self.pool_t.page
                if full > 0:
                    pages = {"t": [int(self.pool_t.tables[slot, b])
                                   for b in range(full)]}
                    if self.pool_d is not None:
                        pages["d"] = [int(self.pool_d.tables[slot, b])
                                      for b in range(full)]
                    if req.is_tpp:
                        keys_arr = tpp_history_key(*self._tpp_enc(req))
                    else:
                        keys_arr = req.prompt_np()
                    self.prefix_cache.insert(keys_arr, pages)
            # finish returns the slot's (unshared) pages to the free
            # list; shared pages just drop one reference
            self.pool_t.free_slot(slot)
            if self.pool_d is not None:
                self.pool_d.free_slot(slot)
        late = (req.deadline_s is not None
                and time.perf_counter() - st.submit_t > req.deadline_s)
        if status == "ok":
            self._stats.requests_completed += 1
            if req.is_tpp or req.prefix_group is not None:
                self._stats.rollouts += 1
            if late:
                # finished, but past its deadline: still "ok" (the
                # tokens are valid) yet excluded from goodput
                self._stats.deadline_misses += 1
        elif status == "failed":
            self._stats.failed += 1
        if req.is_tpp:
            # trim to the budget, then to the horizon: event times are
            # strictly increasing, so `t <= t_end` keeps a prefix (the
            # samplers' finalize_seq convention)
            marks = np.asarray(st.out[:req.max_new_tokens], np.int32)
            etimes = np.asarray(st.out_times[:req.max_new_tokens],
                                np.float32)
            if req.t_end is not None:
                keep = int(np.searchsorted(etimes, np.float32(req.t_end),
                                           side="right"))
                marks, etimes = marks[:keep], etimes[:keep]
            res = ServeResult(
                request_id=req.request_id, tokens=marks,
                prompt_len=req.prompt_len,
                drafted=st.drafted, accepted=st.accepted, rounds=st.rounds,
                ttft_rounds=st.ttft_rounds, ttft_s=st.ttft_s,
                prefix_hit_tokens=st.prefix_hit_tokens, times=etimes,
                status=status, error=error)
        else:
            res = ServeResult(
                request_id=req.request_id,
                tokens=np.asarray(st.out[:req.max_new_tokens], np.int32),
                prompt_len=req.prompt_len,
                drafted=st.drafted, accepted=st.accepted, rounds=st.rounds,
                ttft_rounds=st.ttft_rounds, ttft_s=st.ttft_s,
                prefix_hit_tokens=st.prefix_hit_tokens,
                status=status, error=error)
        if status == "ok" and not late:
            self._stats.goodput_tokens += res.n
        return res
