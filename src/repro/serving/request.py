"""Request/result types of the serving API.

A ``ServeRequest`` is everything the engine needs to generate one
sequence: the prompt, a token budget, a temperature, and a per-request
rng key. The rng contract is the serving analogue of the sampling
engine's seed handling: every random draw a request consumes is derived
from ``fold_in(request.rng, round_idx)`` only — never from the slot the
scheduler happened to place it in or from the other requests sharing the
batch — so a request's output distribution is independent of batch
composition (the property the batched-vs-single equivalence test pins).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

_REQUEST_IDS = itertools.count()


def _as_key(rng) -> jax.Array:
    """Accept a PRNGKey or a plain int seed."""
    if isinstance(rng, (int, np.integer)):
        # repro: ignore[rng-raw-prngkey] -- THE sanctioned seed->key boundary: every request-supplied int seed enters the key space here
        return jax.random.PRNGKey(int(rng))
    return rng


@dataclass
class ServeRequest:
    """One generation request.

    prompt          : [P] int32 token ids.
    max_new_tokens  : generation budget (>= 1; the first new token is
                      sampled from the prefill logits, the rest via the
                      engine's draft/verify rounds).
    temperature     : per-request softmax temperature.
    rng             : PRNGKey or int seed; the request's private stream.
    extra           : optional extra prefill-batch fields (e.g.
                      ``enc_frames`` for encoder-decoder families).
    priority        : scheduling weight (higher admits sooner under the
                      scheduler's "priority" policy; FIFO/SJF ignore
                      it). Never affects the sampled tokens — only WHEN
                      a request is admitted.
    prefix_group    : scenario fan-out group id (set by
                      ``ServingEngine.submit(fanout=K)``). Requests in
                      one group share a prompt; the engine admits the
                      prefix once and FORKS the group's other slots
                      onto the same copy-on-write KV pages. Never
                      affects the sampled tokens (each member keeps its
                      own rng stream) — only what prefill costs.
    times           : TPP domain only — [P] float32 absolute event times
                      of the history, one per ``prompt`` entry (the
                      prompt holds the marks). Setting ``times`` flips
                      the request into the event-sequence domain:
                      ``max_new_tokens`` becomes the max-events budget
                      and generation also stops once the pending event
                      passes ``t_end``. An EMPTY history is legal here
                      (the rollout starts from the BOS sentinel).
    t_end           : TPP domain only — absolute forecast-horizon end;
                      ``None`` leaves the budget as the only stop.
    deadline_s      : wall-clock completion deadline in seconds from
                      submission; ``None`` = none. A request past its
                      deadline is retired with ``status="deadline"``
                      and whatever tokens it committed (queued requests
                      expire without running). Never affects the tokens
                      a surviving request samples — only how long the
                      engine keeps working on it.
    max_wall_rounds : engine-step budget from submission (counts EVERY
                      step since submit — queue wait, prefill and
                      decode alike); ``None`` = none. The round-count
                      analogue of ``deadline_s`` for deterministic
                      tests and step-metered deployments.
    on_tokens       : optional streaming callback
                      ``on_tokens(request_id, tokens: List[int])``, fed
                      at every engine commit with the newly committed
                      tokens in commit order; the concatenation of all
                      chunks a request receives is a prefix of its
                      final ``ServeResult.tokens``. Runs mid-commit on
                      the engine thread — it must not call back into
                      the engine. Never affects the sampled tokens.
    """

    prompt: Any
    max_new_tokens: int
    temperature: float = 1.0
    rng: Any = 0
    extra: Optional[Dict[str, Any]] = None
    priority: int = 0
    prefix_group: Optional[int] = None
    times: Optional[Any] = None
    t_end: Optional[float] = None
    deadline_s: Optional[float] = None
    max_wall_rounds: Optional[int] = None
    on_tokens: Optional[Any] = field(default=None, repr=False,
                                     compare=False)
    request_id: int = field(default_factory=lambda: next(_REQUEST_IDS))
    # lazily cached host copy of ``prompt`` (see ``prompt_np``)
    _prompt_np: Optional[np.ndarray] = field(default=None, repr=False,
                                             compare=False)

    def __post_init__(self):
        self.prompt = jnp.asarray(self.prompt, jnp.int32)
        if self.prompt.ndim != 1:
            raise ValueError("ServeRequest.prompt must be 1-D [P]")
        if self.times is not None:
            self.times = np.asarray(self.times, np.float32).reshape(-1)
            if self.times.shape[0] != self.prompt.shape[0]:
                raise ValueError("ServeRequest.times must match the prompt "
                                 "(one event time per mark)")
        elif self.prompt.shape[0] < 1:
            raise ValueError("ServeRequest.prompt must hold >= 1 token")
        if self.t_end is not None and self.times is None:
            raise ValueError("t_end only applies to TPP requests (times=)")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError("deadline_s must be > 0 (or None)")
        if self.max_wall_rounds is not None and self.max_wall_rounds < 1:
            raise ValueError("max_wall_rounds must be >= 1 (or None)")
        self.rng = _as_key(self.rng)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    def prompt_np(self) -> np.ndarray:
        """Host-side copy of the prompt, fetched once and cached.

        Every host consumer (prefill staging, prefix-cache matching,
        retire-time cache keys) reads this instead of pulling the
        device array per use — and the async loop's overlap window can
        warm it while a round is still computing on device."""
        if self._prompt_np is None:
            self._prompt_np = np.asarray(self.prompt)
        return self._prompt_np

    @property
    def is_tpp(self) -> bool:
        return self.times is not None


#: Terminal request statuses a ``ServeResult`` can carry. Partial
#: tokens committed before a non-"ok" retirement are still returned
#: (and are a bitwise PREFIX of what the request would have produced —
#: the per-request rng contract survives every failure path).
RESULT_STATUSES = ("ok", "failed", "cancelled", "deadline", "shed")


@dataclass(frozen=True)
class ServeResult:
    """Per-request outcome with acceptance accounting.

    ``status`` is the request's terminal state (``RESULT_STATUSES``):
    "ok" (budget/horizon reached), "failed" (round retries exhausted or
    this lane's logits went non-finite — ``error`` says which),
    "cancelled" (``ServingEngine.cancel``), "deadline" (``deadline_s``
    / ``max_wall_rounds`` exceeded), "shed" (dropped from the queue
    under overload). Failures are per-request results, never
    exceptions out of ``ServingEngine.run()``.
    """

    request_id: int
    tokens: np.ndarray      # [n] int32 generated tokens
    prompt_len: int
    drafted: int            # draft tokens proposed for this request
    accepted: int           # draft tokens accepted by verification
    rounds: int             # propose-verify rounds this request rode in
    ttft_rounds: int = 0    # engine steps from submission to first token
    ttft_s: float = 0.0     # wall seconds from submission to first token
    prefix_hit_tokens: int = 0  # prompt tokens served from shared pages
                                # (prefix-cache hit or fan-out fork)
                                # instead of being prefilled
    times: Optional[np.ndarray] = None  # TPP domain: [n] float32 absolute
                                        # event times of the generated
                                        # events (tokens holds the marks)
    status: str = "ok"                  # terminal state, RESULT_STATUSES
    error: Optional[str] = None         # status == "failed": the cause

    @property
    def n(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / max(1, self.drafted)


@dataclass
class EngineStats:
    """Engine-level throughput counters, accumulated across ``step()``s.

    ``target_forwards`` counts the batched verify/decode rounds — the
    quantity the paper's speedup divides by (prefills are tracked
    separately, as in the single-request accounting). ``prefills``
    counts requests whose prompt finished prefilling; ``prefill_tokens``
    is the prompt-token figure that makes prefill throughput honest
    (``prefill_tokens / prefill_s``), accumulated by both the chunked
    paged admission and the dense-staging fallback.

    ``prefix_lookups``/``prefix_hits``/``prefix_hit_tokens`` count
    prefix-sharing work: lookups are admissions that consulted shared
    state (the radix cache, or a fan-out group's live source), hits are
    admissions that adopted at least one shared page, and hit tokens
    are the prompt tokens those admissions did NOT have to prefill.

    ``rollouts`` counts completed scenario rollouts — TPP event
    sequences and fan-out group members — the numerator of the
    forecasting workload's headline ``rollouts_per_sec``.
    ``group_forwards``/``group_member_rounds`` account forward sharing
    per fan-out group: for group g, ``group_forwards[g]`` is the number
    of batched target forwards that served >= 1 member and
    ``group_member_rounds[g]`` the member-rounds those forwards covered,
    so ``group_member_rounds[g] / group_forwards[g]`` is the average
    number of siblings sharing each forward (the quantity the grouped
    scheduling policy maximizes).
    """

    requests_completed: int = 0
    tokens: int = 0
    drafted: int = 0
    accepted: int = 0
    target_forwards: int = 0     # batched verify/decode rounds
    draft_forwards: int = 0      # batched draft steps
    prefills: int = 0            # requests fully prefilled
    prefill_tokens: int = 0      # prompt (+prefix) tokens prefilled
    prefill_s: float = 0.0       # wall seconds spent in prefill work
    wall_s: float = 0.0
    prefix_lookups: int = 0      # admissions that consulted shared state
    prefix_hits: int = 0         # ... that adopted shared pages
    prefix_hit_tokens: int = 0   # prompt tokens skipped via sharing
    rollouts: int = 0            # completed scenario rollouts
    group_forwards: Dict[int, int] = field(default_factory=dict)
    group_member_rounds: Dict[int, int] = field(default_factory=dict)
    # failure-semantics counters (``requests_completed`` counts "ok"
    # retirements only; the other terminal statuses count here)
    retries: int = 0             # request-rounds re-run after a failure
    failed: int = 0              # requests retired status="failed"
    cancellations: int = 0       # requests retired status="cancelled"
    deadline_misses: int = 0     # deadline/round-budget expiries, plus
                                 # "ok" completions that landed late
    shed: int = 0                # requests dropped under overload
    faults_injected: int = 0     # FaultPlan injections that fired
    goodput_tokens: int = 0      # tokens delivered by "ok" requests
                                 # WITHIN their deadline
    # per-phase wall breakdown of ``step()`` (milliseconds): device_ms
    # is time blocked on the batched device fetch, overlap_ms is host
    # work hidden inside the double-buffer window while the round
    # computes, host_ms is the remaining (non-overlapped) host time —
    # so overlap_ms > 0 is the observable proof the async loop overlaps
    host_ms: float = 0.0
    device_ms: float = 0.0
    overlap_ms: float = 0.0
    handoffs: int = 0            # prefill->decode KV-page handoffs
                                 # (disaggregated engine)

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / max(1, self.drafted)

    @property
    def tokens_per_forward(self) -> float:
        """Committed tokens per batched target forward (AR == ~1)."""
        return self.tokens / max(1, self.target_forwards)

    @property
    def tokens_per_sec(self) -> float:
        return self.tokens / max(1e-9, self.wall_s)

    @property
    def prefill_tokens_per_sec(self) -> float:
        return self.prefill_tokens / max(1e-9, self.prefill_s)

    @property
    def prefix_hit_rate(self) -> float:
        return self.prefix_hits / max(1, self.prefix_lookups)

    @property
    def rollouts_per_sec(self) -> float:
        return self.rollouts / max(1e-9, self.wall_s)

    @property
    def goodput(self) -> float:
        """Completed-in-deadline tokens per second — the overload
        metric: shed/failed/expired work contributes nothing, so a
        saturated engine maximizes this by finishing what it admits
        rather than admitting everything."""
        return self.goodput_tokens / max(1e-9, self.wall_s)

    def group_sharing(self, gid: int) -> float:
        """Average members sharing each of group ``gid``'s forwards."""
        return (self.group_member_rounds.get(gid, 0)
                / max(1, self.group_forwards.get(gid, 0)))

    def describe(self) -> str:
        return (f"requests={self.requests_completed} tokens={self.tokens} "
                f"target_fwds={self.target_forwards} "
                f"alpha={self.acceptance_rate:.2f} "
                f"tok/fwd={self.tokens_per_forward:.2f} "
                f"tok/s={self.tokens_per_sec:.1f} "
                f"prefill_tok={self.prefill_tokens} "
                f"prefill_tok/s={self.prefill_tokens_per_sec:.1f} "
                f"prefix_hit_rate={self.prefix_hit_rate:.2f} "
                f"prefix_hit_tok={self.prefix_hit_tokens} "
                f"retries={self.retries} failed={self.failed} "
                f"cancelled={self.cancellations} "
                f"deadline_misses={self.deadline_misses} shed={self.shed} "
                f"faults={self.faults_injected} "
                f"goodput_tok_s={self.goodput:.1f} "
                f"host_ms={self.host_ms:.1f} "
                f"device_ms={self.device_ms:.1f} "
                f"overlap_ms={self.overlap_ms:.1f} "
                f"handoffs={self.handoffs}")
