"""Disaggregated prefill/decode serving over one paged KV pool.

Production serving splits prompt processing (prefill: long, compute-
bound, bursty) from token generation (decode: short steps, latency-
bound) so neither starves the other. This module layers that split on
the unified ``ServingEngine``:

  - a ``PrefillWorker`` owns the first ``prefill_slots`` scheduler
    slots; every admission lands there and streams its prompt into the
    paged pool in chunks (the PR 5 chunked-admission path, unchanged);
  - a ``DecodeWorker`` owns the remaining slots; only its slots ever
    ride decode rounds;
  - a completed prompt moves between them through the ``HandoffQueue``
    as a **block-table transfer**: the destination slot retains the
    source slot's page ids and the source releases them
    (``PagedKVCachePool.transfer_slot``) — net refcounts unchanged,
    free list untouched, zero K/V bytes copied. Pages have been the
    unit of ownership since PR 6, so the "transfer" is bookkeeping.

The handoff barrier is a chaos fault point: a ``handoff_error``
``FaultSpec`` (``serving/faults.py``) models a prefill worker dying
mid-transfer. The fault fires BEFORE any ownership moves, so the retry
contract is the round-retry contract: the parked request re-attempts
the handoff on a later step with its pages still on the prefill slot
and its rng stream untouched — survivors stay bitwise, and a request
whose retry budget is spent retires ``status="failed"`` with zero
leaked pages.

Determinism: the handoff delays WHEN a request's first decode round
runs, never WHAT it samples — the first token is still the
``fold_in(rng, 0)`` draw from the prompt's last-position logits
(sampled at handoff, riding that step's round as a lazy device scalar),
and every later draw comes from ``fold_in(rng, round_idx)``. Under
``method="ar"``, or ``method="sd"`` with ``fixed_window=True`` (no
batch-composition-dependent window clamp), the disaggregated engine's
committed streams are bitwise the unified engine's.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from .engine import ServingEngine
from .faults import InjectedFault
from .request import ServeResult
from .scheduler import PREFILLING, SlotState

__all__ = ["Handoff", "HandoffQueue", "PrefillWorker", "DecodeWorker",
           "DisaggServingEngine"]


@dataclass
class Handoff:
    """One completed prompt parked for prefill→decode transfer.

    ``slot`` is the prefill-worker slot still owning the pages;
    ``row`` is the prompt's last-position logits as a LAZY device row
    (the first-token draw happens at adoption, not here — a retried
    handoff must not have consumed any randomness)."""

    slot: int
    state: SlotState
    row: Any


class HandoffQueue:
    """FIFO of prompts awaiting a decode slot. Host-side bookkeeping
    only — the KV pages stay exactly where the prefill worker wrote
    them until ``transfer_slot`` moves the block-table references."""

    def __init__(self):
        self._q: List[Handoff] = []

    def push(self, h: Handoff) -> None:
        self._q.append(h)

    def peek(self) -> Handoff:
        return self._q[0]

    def pop(self) -> Handoff:
        return self._q.pop(0)

    def discard(self, state: SlotState) -> bool:
        """Drop a parked entry by its slot state (cancellation/expiry
        of a request that never reached a decode slot)."""
        for i, h in enumerate(self._q):
            if h.state is state:
                del self._q[i]
                return True
        return False

    def clear(self) -> None:
        self._q.clear()

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)


@dataclass(frozen=True)
class PrefillWorker:
    """Owns the admission slots: prompts stream into the pool here and
    never ride a decode round while seated on this worker."""

    slots: Tuple[int, ...]
    name: str = "prefill-0"

    def owns(self, slot: int) -> bool:
        return slot in self.slots


@dataclass(frozen=True)
class DecodeWorker:
    """Owns the decode slots: every draft/verify round batches over
    (a subset of) these, and only these."""

    slots: Tuple[int, ...]
    name: str = "decode-0"

    def owns(self, slot: int) -> bool:
        return slot in self.slots


class DisaggServingEngine(ServingEngine):
    """``ServingEngine`` with admission pinned to a prefill worker's
    slots and completed prompts handed to the decode worker by
    block-table transfer (see module docstring).

    ``prefill_slots``: how many of ``max_batch`` slots the prefill
    worker owns (the rest decode). Token domain, paged layout, chunked
    admission only — the disaggregation point IS the chunked-prefill
    completion hook."""

    def __init__(self, *args, prefill_slots: int = 1, **kw):
        kw.setdefault("prefill_chunk", 32)
        super().__init__(*args, **kw)
        if self.domain != "token":
            raise ValueError("DisaggServingEngine serves the token domain "
                             "(TPP prefill has no logits row to hand off)")
        if self.kv_layout != "paged" or self.prefill_chunk is None:
            raise ValueError("disaggregated serving needs the paged layout "
                             "with chunked admission (prefill_chunk)")
        if not (1 <= prefill_slots < self.max_batch):
            raise ValueError(
                f"prefill_slots must be in [1, max_batch) = "
                f"[1, {self.max_batch}), got {prefill_slots}")
        self.prefill_worker = PrefillWorker(
            slots=tuple(range(prefill_slots)))
        self.decode_worker = DecodeWorker(
            slots=tuple(range(prefill_slots, self.max_batch)))
        self._admit_slots = self.prefill_worker.slots
        self._handoffs = HandoffQueue()
        # the handoff retry budget is SEPARATE from the round-retry
        # dict: a parked request still counts as PREFILLING, and the
        # engine clears round retries for prefilling states after every
        # clean prefill step — which would silently refill a dying
        # worker's budget
        self._handoff_retries: Dict[int, int] = {}

    def reset(self, force: bool = False) -> None:
        super().reset(force)
        self._handoffs.clear()
        self._handoff_retries.clear()

    # -- the prefill side: park instead of decode ---------------------------
    def _on_prompt_complete(self, slot: int, st: SlotState, row) -> None:
        """A prefill-worker slot finished its prompt: park it (phase
        stays PREFILLING, so it neither rides rounds nor retires) and
        queue the handoff. No randomness is consumed here — the first
        token is drawn when a decode slot adopts the pages, so a
        retried handoff replays nothing."""
        assert st.phase == PREFILLING
        self._handoffs.push(Handoff(slot=slot, state=st, row=row))

    # -- the handoff barrier ------------------------------------------------
    def _drain_handoffs(self) -> List[ServeResult]:
        """Move parked prompts into free decode slots, oldest first.
        Runs at the top of every step (before prefill), so a prompt
        completing in step k starts decoding in step k+1 — one step of
        handoff latency, zero extra device syncs. The fault barrier
        sits BEFORE any ownership movement: a ``handoff_error`` here
        leaves the queue, the pages and the rng stream untouched, and
        the retry next step is bitwise the un-failed handoff."""
        out: List[ServeResult] = []
        while self._handoffs:
            free = [i for i in self.decode_worker.slots
                    if self.scheduler.slots[i] is None]
            if not free:
                break
            if self.faults is not None:
                try:
                    self.faults.maybe_raise_handoff_error(
                        self.scheduler.step_idx, self)
                except InjectedFault as e:
                    out.extend(self._on_handoff_failure(e))
                    break
            h = self._handoffs.pop()
            self._adopt_handoff(h, free[0])
        return out

    def _on_handoff_failure(self, exc: Exception) -> List[ServeResult]:
        """The prefill worker died at the barrier: charge the HEAD
        request's retry budget (it is the one whose transfer failed)
        and leave everything else queued. Past the budget it retires
        ``status="failed"`` from its prefill slot — pages freed there,
        nothing leaked, no other stream perturbed."""
        h = self._handoffs.peek()
        rid = h.state.request.request_id
        n = self._handoff_retries.get(rid, 0) + 1
        if n > self.max_round_retries:
            self._handoff_retries.pop(rid, None)
            return [self._retire(
                h.slot, status="failed",
                error=f"handoff failed after {n - 1} retries: {exc}")]
        self._handoff_retries[rid] = n
        self._stats.retries += 1
        return []

    def _adopt_handoff(self, h: Handoff, dst: int) -> None:
        """Commit one handoff: reseat the slot state, transfer the
        block tables (refcount retain into ``dst``, release from the
        prefill slot — zero K/V copy), then run the unified engine's
        prompt-completion hook on the DECODE slot, which samples the
        ``fold_in(rng, 0)`` first token as a lazy device scalar riding
        this step's round."""
        st = h.state
        self._handoff_retries.pop(st.request.request_id, None)
        self.scheduler.slots[h.slot] = None
        st.slot = dst
        self.scheduler.slots[dst] = st
        self.pool_t.transfer_slot(h.slot, dst)
        if self.pool_d is not None:
            self.pool_d.transfer_slot(h.slot, dst)
        g = st.request.prefix_group
        if g is not None:
            src = self._fork_sources.get(g)
            if src is not None and src["state"] is st:
                src["slot"] = dst
        self._stats.handoffs += 1
        ServingEngine._on_prompt_complete(self, dst, st, h.row)

    # -- lifecycle ----------------------------------------------------------
    def _retire(self, slot: int, status: str = "ok",
                error: Optional[str] = None) -> ServeResult:
        """A parked request can retire straight off its prefill slot
        (cancel / deadline / spent handoff retries): purge its queue
        entry first so the drain never adopts a vacated state."""
        st = self.scheduler.slots[slot]
        if st is not None:
            self._handoffs.discard(st)
            self._handoff_retries.pop(st.request.request_id, None)
        return super()._retire(slot, status=status, error=error)
