"""Pallas TPU kernel: blocked causal FlashAttention (prefill / verify).

Grid: (B, H, nq, nk) — nk is the innermost (sequential) dimension; the
online-softmax running state (m, l, acc) lives in VMEM scratch and is
re-initialized at ik == 0 and flushed to the output at ik == nk - 1.

Block shapes are MXU-aligned: q [bq, Dh], k/v [bk, Dh] with bq/bk
multiples of 128 on real hardware (tests use smaller tiles under
interpret=True, where alignment is not enforced).

Masking uses absolute positions (q_pos [B,Sq], kv_pos [B,Sk]); invalid
cache slots carry kv_pos = INT32_MAX. Sliding windows and tanh soft-cap
are supported to serve recurrentgemma's local attention.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, qp_ref, kp_ref, out_ref,
            m_scr, l_scr, acc_scr, *, scale, window, softcap, nk):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    q = q_ref[0, :, 0, :].astype(jnp.float32)        # [bq, Dh]
    k = k_ref[0, :, 0, :].astype(jnp.float32)        # [bk, Dh]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    qp = qp_ref[0, :]                                # [bq]
    kp = kp_ref[0, :]                                # [bk]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    mask = kp[None, :] <= qp[:, None]
    if window > 0:
        mask &= kp[None, :] > qp[:, None] - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.where(mask, jnp.exp(s - m_safe[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    acc_scr[...] = (acc_scr[...] * corr[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _flush():
        l = l_scr[...]
        safe = jnp.maximum(l, 1e-30)
        out = acc_scr[...] / safe[:, None]
        out = jnp.where((l > 0)[:, None], out, 0.0)
        out_ref[0, :, 0, :] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "bq", "bk",
                                             "interpret"))
def flash_attention_pallas(q, k, v, q_pos, kv_pos, *, window: int = 0,
                           softcap: float = 0.0, bq: int = 128,
                           bk: int = 128, interpret: bool = True):
    """q: [B,Sq,H,Dh], k/v: [B,Sk,KV,Dh] -> [B,Sq,H,Dh] (fwd only)."""
    B, Sq, H, Dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    pq, pk = (-Sq) % bq, (-Sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pq)), constant_values=-1)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pk)),
                         constant_values=jnp.iinfo(jnp.int32).max)
    Sqp, Skp = q.shape[1], k.shape[1]
    nq, nk = Sqp // bq, Skp // bk
    grid = (B, H, nq, nk)
    kern = functools.partial(_kernel, scale=1.0 / math.sqrt(Dh),
                             window=window, softcap=softcap, nk=nk)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, Dh), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, bk, 1, Dh),
                         lambda b, h, iq, ik, G=G: (b, ik, h // G, 0)),
            pl.BlockSpec((1, bk, 1, Dh),
                         lambda b, h, iq, ik, G=G: (b, ik, h // G, 0)),
            pl.BlockSpec((1, bq), lambda b, h, iq, ik: (b, iq)),
            pl.BlockSpec((1, bk), lambda b, h, iq, ik: (b, ik)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, Dh),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sqp, H, Dh), q.dtype),
        scratch_shapes=[
            # VMEM scratch: online-softmax running state
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, q_pos, kv_pos)
    return out[:, :Sq]
