"""Public kernel entry points.

Each op dispatches between the Pallas TPU kernel and the pure-jnp
reference. On this CPU container the Pallas kernels execute in
``interpret=True`` mode inside the tests; the model code defaults to the
jnp path (``use_pallas=False``) so that dry-run lowering produces plain
XLA HLO.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref


def flash_attention(q, k, v, q_pos, kv_pos, *, window: int = 0,
                    softcap: float = 0.0, bq: int = 512, bk: int = 512,
                    use_pallas: bool = False, interpret: bool = True):
    """Blocked causal attention (prefill / verify path)."""
    if use_pallas:
        from .flash_attention import flash_attention_pallas
        return flash_attention_pallas(q, k, v, q_pos, kv_pos, window=window,
                                      softcap=softcap, bq=bq, bk=bk,
                                      interpret=interpret)
    return ref.flash_attention_ref(q, k, v, q_pos, kv_pos, window, softcap,
                                   bq, bk)


def decode_attention(q, k, v, q_pos, kv_pos, *, window: int = 0,
                     softcap: float = 0.0, bk: int = 512,
                     use_pallas: bool = False, interpret: bool = True):
    """Single-token GQA decode attention over a KV cache. q: [B, H, Dh]."""
    if use_pallas:
        from .decode_attention import decode_attention_pallas
        return decode_attention_pallas(q, k, v, q_pos, kv_pos, window=window,
                                       softcap=softcap, bk=bk,
                                       interpret=interpret)
    return ref.decode_attention_ref(q, k, v, q_pos, kv_pos, window=window,
                                    softcap=softcap)


def lognorm_mix_logpdf(tau, log_w, mu, sigma, *, use_pallas: bool = False,
                       interpret: bool = True):
    """Fused log-normal-mixture log-density (paper Sec. 4.2 decoder)."""
    if use_pallas:
        from .lognorm_mix import lognorm_mix_logpdf_pallas
        return lognorm_mix_logpdf_pallas(tau, log_w, mu, sigma,
                                         interpret=interpret)
    return ref.lognorm_mix_logpdf_ref(tau, log_w, mu, sigma)


def lognorm_mix_logsf(tau, log_w, mu, sigma):
    return ref.lognorm_mix_logsf_ref(tau, log_w, mu, sigma)


def naive_attention(q, k, v, q_pos, kv_pos, *, window: int = 0,
                    softcap: float = 0.0):
    return ref.naive_attention(q, k, v, q_pos, kv_pos, window=window,
                               softcap=softcap)


def selective_scan(dt, Bc, Cc, u, A, D, h0, *, use_pallas: bool = False,
                   interpret: bool = True):
    """Fused Mamba selective scan over one chunk (states stay in VMEM)."""
    if use_pallas:
        from .selective_scan import selective_scan_pallas
        return selective_scan_pallas(dt, Bc, Cc, u, A, D, h0,
                                     interpret=interpret)
    return ref.selective_scan_ref(dt, Bc, Cc, u, A, D, h0)
