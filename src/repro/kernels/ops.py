"""Public kernel entry points.

Each op dispatches between the Pallas TPU kernel and the pure-jnp
reference, governed by a ``KernelPolicy`` (``kernels.policy``): pass
``policy=`` to choose pallas-vs-ref / compiled-vs-interpret / block
sizes in one object — the model configs carry one
(``cfg.kernel_policy``) so a whole compiled program agrees. The legacy
``use_pallas``/``interpret`` kwargs remain for direct callers and mean
exactly what they did.

Block sizes are validated and auto-rounded to the hardware alignment
(warning once per call site) instead of failing deep inside
``pallas_call`` lowering.

Autodiff note: the Pallas kernels are forward-only. Training paths
(``forward``/``loss_fn``/``loglik``) must stay on the references, which
carry custom VJPs where needed.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .policy import PALLAS, REF, KernelPolicy, validate_block_size


def _dispatch(policy, use_pallas, interpret, default_backend="pallas"):
    """(use_pallas, interpret, resolved_policy|None) for an op call."""
    if policy is None:
        return use_pallas, interpret, None
    pol = policy.resolve(default_backend=default_backend)
    return pol.use_pallas, pol.interpret, pol


def flash_attention(q, k, v, q_pos, kv_pos, *, window: int = 0,
                    softcap: float = 0.0, bq: int = 512, bk: int = 512,
                    use_pallas: bool = False, interpret: bool = True,
                    policy: KernelPolicy | None = None):
    """Blocked causal attention (prefill / long-chunk path)."""
    use_pallas, interpret, pol = _dispatch(policy, use_pallas, interpret)
    if use_pallas:
        if pol is not None:
            bq, bk = pol.bq, pol.bk
        bq = validate_block_size("flash_attention", "bq", bq,
                                 total=q.shape[1])
        bk = validate_block_size("flash_attention", "bk", bk,
                                 total=k.shape[1])
        from .flash_attention import flash_attention_pallas
        return flash_attention_pallas(q, k, v, q_pos, kv_pos, window=window,
                                      softcap=softcap, bq=bq, bk=bk,
                                      interpret=interpret)
    return ref.flash_attention_ref(q, k, v, q_pos, kv_pos, window, softcap,
                                   bq, bk)


def decode_attention(q, k, v, q_pos, kv_pos, *, window: int = 0,
                     softcap: float = 0.0, bk: int = 512,
                     use_pallas: bool = False, interpret: bool = True,
                     policy: KernelPolicy | None = None):
    """Single-token GQA decode attention over a KV cache. q: [B, H, Dh]."""
    use_pallas, interpret, pol = _dispatch(policy, use_pallas, interpret)
    if use_pallas:
        if pol is not None:
            bk = pol.bk
        bk = validate_block_size("decode_attention", "bk", bk,
                                 total=k.shape[1])
        from .decode_attention import decode_attention_pallas
        return decode_attention_pallas(q, k, v, q_pos, kv_pos, window=window,
                                       softcap=softcap, bk=bk,
                                       interpret=interpret)
    return ref.decode_attention_ref(q, k, v, q_pos, kv_pos, window=window,
                                    softcap=softcap)


def spec_verify_attention(q, k_pages, v_pages, block_tables, lens, *,
                          window: int = 0, softcap: float = 0.0,
                          max_kv: int = 0,
                          policy: KernelPolicy | None = None):
    """Chunk-query attention over a paged KV cache — the speculative
    verify (C = gamma+1) and the chunked-prefill path (C = chunk) run
    through this one entry point, so both follow the same policy.

    q: [S, C, H, Dh] (C chunk queries at positions
    lens[s]..lens[s]+C-1, K/V already written into the pages);
    k/v_pages: [P, page, KV, Dh]; block_tables: [S, NB]; lens: [S].

    Chunks longer than the policy's ``bq`` run query-tiled (per-query
    math unchanged — each query sweeps the same blocks in the same
    order); decode-sized chunks keep the single-tile grid bitwise.

    ``max_kv`` only affects the reference path: it slices the gathered
    cache to that length so the result is bitwise what the same dense
    cache produces (the paged==dense equivalence contract).
    """
    use_pallas, interpret, pol = _dispatch(policy, False, True)
    if use_pallas:
        C = q.shape[1]
        bq = pol.bq
        if C > bq:
            # tiled path only: align the requested tile (warn-once)
            # instead of failing inside pallas_call lowering
            bq = validate_block_size("spec_verify_attention", "bq", bq,
                                     total=C)
        from .spec_verify_attention import spec_verify_attention_pallas
        return spec_verify_attention_pallas(q, k_pages, v_pages,
                                            block_tables, lens,
                                            window=window, softcap=softcap,
                                            interpret=interpret,
                                            bq=bq if C > bq else 0)
    from .spec_verify_attention import spec_verify_attention_ref
    return spec_verify_attention_ref(q, k_pages, v_pages, block_tables,
                                     lens, window=window, softcap=softcap,
                                     max_kv=max_kv)


def spec_verify_attention_seq(q, k, v, start, *, window: int = 0,
                              softcap: float = 0.0,
                              policy: KernelPolicy | None = None):
    """Dense single-sequence spec-verify (the TPP multi-query verify /
    decode path; vmap-safe). q: [C, H, Dh]; k/v: [N, H, Dh] with slot ==
    position; start: scalar int32. Pallas-only entry — ref callers keep
    their einsum attention."""
    pol = (policy if policy is not None else PALLAS).resolve()
    bk = validate_block_size("spec_verify_attention_seq", "bk", pol.bk,
                             total=k.shape[0])
    from .spec_verify_attention import spec_verify_attention_seq_pallas
    return spec_verify_attention_seq_pallas(q, k, v, start, window=window,
                                            softcap=softcap, bk=bk,
                                            interpret=pol.interpret)


def lognorm_mix_logpdf(tau, log_w, mu, sigma, *, use_pallas: bool = False,
                       interpret: bool = True,
                       policy: KernelPolicy | None = None):
    """Fused log-normal-mixture log-density (paper Sec. 4.2 decoder)."""
    use_pallas, interpret, pol = _dispatch(policy, use_pallas, interpret)
    if use_pallas:
        bn = validate_block_size("lognorm_mix_logpdf", "bn",
                                 pol.bn if pol is not None else 256)
        from .lognorm_mix import lognorm_mix_logpdf_pallas
        return lognorm_mix_logpdf_pallas(tau, log_w, mu, sigma, bn=bn,
                                         interpret=interpret)
    return ref.lognorm_mix_logpdf_ref(tau, log_w, mu, sigma)


def lognorm_mix_logsf(tau, log_w, mu, sigma, *, use_pallas: bool = False,
                      interpret: bool = True,
                      policy: KernelPolicy | None = None):
    """Fused log-survival log(1 - G(tau)) of the mixture (Eq. 2 tail /
    thinning upper bound)."""
    use_pallas, interpret, pol = _dispatch(policy, use_pallas, interpret)
    if use_pallas:
        bn = validate_block_size("lognorm_mix_logsf", "bn",
                                 pol.bn if pol is not None else 256)
        from .lognorm_mix import lognorm_mix_logsf_pallas
        return lognorm_mix_logsf_pallas(tau, log_w, mu, sigma, bn=bn,
                                        interpret=interpret)
    return ref.lognorm_mix_logsf_ref(tau, log_w, mu, sigma)


def naive_attention(q, k, v, q_pos, kv_pos, *, window: int = 0,
                    softcap: float = 0.0):
    return ref.naive_attention(q, k, v, q_pos, kv_pos, window=window,
                               softcap=softcap)


def selective_scan(dt, Bc, Cc, u, A, D, h0, *, use_pallas: bool = False,
                   interpret: bool = True,
                   policy: KernelPolicy | None = None):
    """Fused Mamba selective scan over one chunk (states stay in VMEM)."""
    use_pallas, interpret, _ = _dispatch(policy, use_pallas, interpret)
    if use_pallas:
        from .selective_scan import selective_scan_pallas
        return selective_scan_pallas(dt, Bc, Cc, u, A, D, h0,
                                     interpret=interpret)
    return ref.selective_scan_ref(dt, Bc, Cc, u, A, D, h0)
