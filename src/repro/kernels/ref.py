"""Pure-jnp reference oracles for every Pallas kernel, plus the
memory-efficient (flash) attention used as the CPU/compile path.

Conventions shared with the kernels:
  q:  [B, Sq, H, Dh]  (H = G * KV query heads)
  k,v:[B, Sk, KV, Dh]
  q_pos:  [B, Sq] int32 absolute positions
  kv_pos: [B, Sk] int32 absolute positions; INVALID_POS marks unwritten
          cache slots (masked out because INVALID_POS > any query pos).
Masking rule: key visible iff kv_pos <= q_pos and (window == 0 or
kv_pos > q_pos - window).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

INVALID_POS = jnp.iinfo(jnp.int32).max
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# naive attention (the oracle of oracles)
# ---------------------------------------------------------------------------

def naive_attention(q, k, v, q_pos, kv_pos, *, window: int = 0,
                    softcap: float = 0.0):
    B, Sq, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qf = q.reshape(B, Sq, KV, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32))
    s = s / math.sqrt(Dh)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    qp = q_pos[:, None, None, :, None]
    kp = kv_pos[:, None, None, None, :]
    mask = kp <= qp
    if window > 0:
        mask &= kp > qp - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no visible key produce uniform garbage; zero them instead
    any_visible = jnp.any(mask, axis=-1, keepdims=True)
    p = jnp.where(any_visible, p, 0.0)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# flash attention, pure jnp, O(S) memory, custom VJP
# ---------------------------------------------------------------------------

def _pad_to(x, axis, mult, value=0):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _mask_block(qp, kp, window):
    # qp: [bq], kp: [bk] -> [bq, bk] bool
    m = kp[None, :] <= qp[:, None]
    if window > 0:
        m &= kp[None, :] > qp[:, None] - window
    return m


def _flash_fwd_impl(q, k, v, q_pos, kv_pos, window, softcap, bq, bk):
    """Returns (out [B,Sq,H,Dh], lse [B,KV,G,Sq])."""
    B, Sq0, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(Dh)

    q = _pad_to(q, 1, bq)
    q_pos = _pad_to(q_pos, 1, bq, value=-1)  # -1 => padded query rows see no key
    k = _pad_to(k, 1, bk)
    v = _pad_to(v, 1, bk)
    kv_pos = _pad_to(kv_pos, 1, bk, value=INVALID_POS)
    Sq, Sk = q.shape[1], k.shape[1]
    nq, nk = Sq // bq, Sk // bk

    qb = q.reshape(B, nq, bq, KV, G, Dh).astype(jnp.float32)
    kb = k.reshape(B, nk, bk, KV, Dh).astype(jnp.float32)
    vb = v.reshape(B, nk, bk, KV, Dh).astype(jnp.float32)
    qpb = q_pos.reshape(B, nq, bq)
    kpb = kv_pos.reshape(B, nk, bk)

    def q_block(qi, qpi):
        # qi [B,bq,KV,G,Dh], qpi [B,bq]
        def kv_step(carry, blk):
            m, l, acc = carry
            kj, vj, kpj = blk
            s = jnp.einsum("bqkgd,bskd->bkgqs", qi, kj) * scale
            if softcap > 0:
                s = jnp.tanh(s / softcap) * softcap
            mask = jax.vmap(_mask_block, in_axes=(0, 0, None))(qpi, kpj, window)
            mask = mask[:, None, None, :, :]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
            p = jnp.where(mask, jnp.exp(s - m_safe[..., None]), 0.0)
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum("bkgqs,bskd->bkgqd", p, vj)
            return (m_new, l, acc), None

        m0 = jnp.full((B, KV, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, bq), jnp.float32)
        a0 = jnp.zeros((B, KV, G, bq, Dh), jnp.float32)
        (m, l, acc), _ = lax.scan(
            kv_step, (m0, l0, a0),
            (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4),
             kpb.transpose(1, 0, 2)))
        safe_l = jnp.maximum(l, 1e-30)
        out = acc / safe_l[..., None]
        out = jnp.where((l > 0)[..., None], out, 0.0)
        lse = jnp.where(l > 0, m + jnp.log(safe_l), NEG_INF)
        return out, lse  # [B,KV,G,bq,Dh], [B,KV,G,bq]

    outs, lses = lax.map(lambda t: q_block(t[0], t[1]),
                         (qb.transpose(1, 0, 2, 3, 4, 5),
                          qpb.transpose(1, 0, 2)))
    # outs: [nq, B, KV, G, bq, Dh] -> [B, Sq, H, Dh]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, Dh)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, KV, G, Sq)
    return out[:, :Sq0].astype(q.dtype), lse[..., :Sq0]


@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def flash_attention_ref(q, k, v, q_pos, kv_pos, window: int = 0,
                        softcap: float = 0.0, bq: int = 512, bk: int = 512):
    out, _ = _flash_fwd_impl(q, k, v, q_pos, kv_pos, window, softcap, bq, bk)
    return out


def _flash_fwd(q, k, v, q_pos, kv_pos, window, softcap, bq, bk):
    out, lse = _flash_fwd_impl(q, k, v, q_pos, kv_pos, window, softcap, bq, bk)
    return out, (q, k, v, q_pos, kv_pos, out, lse)


def _flash_bwd(window, softcap, bq, bk, res, dout):
    q, k, v, q_pos, kv_pos, out, lse = res
    B, Sq0, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(Dh)

    qp = _pad_to(q_pos, 1, bq, value=-1)
    kp = _pad_to(kv_pos, 1, bk, value=INVALID_POS)
    qf = _pad_to(q, 1, bq).astype(jnp.float32)
    kf = _pad_to(k, 1, bk).astype(jnp.float32)
    vf = _pad_to(v, 1, bk).astype(jnp.float32)
    dof = _pad_to(dout, 1, bq).astype(jnp.float32)
    of = _pad_to(out, 1, bq).astype(jnp.float32)
    lsef = _pad_to(lse, 3, bq, value=NEG_INF)
    Sq, Sk = qf.shape[1], kf.shape[1]
    nq, nk = Sq // bq, Sk // bk

    # D_i = rowsum(dO * O) per query position: [B, KV, G, Sq]
    Dvec = jnp.einsum("bqhd,bqhd->bhq", dof, of).reshape(B, KV, G, Sq)

    qb = qf.reshape(B, nq, bq, KV, G, Dh).transpose(1, 0, 2, 3, 4, 5)
    dob = dof.reshape(B, nq, bq, KV, G, Dh).transpose(1, 0, 2, 3, 4, 5)
    qpb = qp.reshape(B, nq, bq).transpose(1, 0, 2)
    lseb = lsef.reshape(B, KV, G, nq, bq).transpose(3, 0, 1, 2, 4)
    Db = Dvec.reshape(B, KV, G, nq, bq).transpose(3, 0, 1, 2, 4)
    kb = kf.reshape(B, nk, bk, KV, Dh).transpose(1, 0, 2, 3, 4)
    vb = vf.reshape(B, nk, bk, KV, Dh).transpose(1, 0, 2, 3, 4)
    kpb = kp.reshape(B, nk, bk).transpose(1, 0, 2)

    def dq_acc_slice_add(dq_acc, dqi, idx, bq):
        cur = lax.dynamic_slice(dq_acc, (0, idx * bq, 0, 0, 0),
                                (dq_acc.shape[0], bq) + dq_acc.shape[2:])
        return cur + dqi

    def kv_block(carry, blk):
        dq_acc = carry
        kj, vj, kpj = blk

        def q_step(inner, qblk):
            dk, dv, dq_acc = inner
            qi, doi, qpi, lsei, Di, idx = qblk
            s = jnp.einsum("bqkgd,bskd->bkgqs", qi, kj) * scale
            if softcap > 0:
                t = jnp.tanh(s / softcap)
                s_c = t * softcap
                dcap = 1.0 - t * t
            else:
                s_c = s
                dcap = 1.0
            mask = jax.vmap(_mask_block, in_axes=(0, 0, None))(qpi, kpj, window)
            mask = mask[:, None, None, :, :]
            s_c = jnp.where(mask, s_c, NEG_INF)
            # clamp exponent: rows with lse=NEG_INF are fully masked anyway
            p = jnp.where(mask,
                          jnp.exp(jnp.minimum(s_c - lsei[..., None], 30.0)),
                          0.0)                                  # [B,KV,G,bq,bk]
            dp = jnp.einsum("bqkgd,bskd->bkgqs", doi, vj)
            ds = p * (dp - Di[..., None]) * dcap * scale
            dk = dk + jnp.einsum("bkgqs,bqkgd->bskd", ds, qi)
            dv = dv + jnp.einsum("bkgqs,bqkgd->bskd", p, doi)
            dqi = jnp.einsum("bkgqs,bskd->bqkgd", ds, kj)
            dq_acc = lax.dynamic_update_slice(
                dq_acc, dq_acc_slice_add(dq_acc, dqi, idx, bq), (0, idx * bq, 0, 0, 0))
            return (dk, dv, dq_acc), None

        dk0 = jnp.zeros_like(kj)
        dv0 = jnp.zeros_like(vj)
        idxs = jnp.arange(nq)
        (dk, dv, dq_acc), _ = lax.scan(
            q_step, (dk0, dv0, dq_acc), (qb, dob, qpb, lseb, Db, idxs))
        return dq_acc, (dk, dv)

    dq0 = jnp.zeros((B, Sq, KV, G, Dh), jnp.float32)
    dq, dkvs = lax.scan(kv_block, dq0, (kb, vb, kpb))
    dkb, dvb = dkvs                                   # [nk, B, bk, KV, Dh]
    dk = dkb.transpose(1, 0, 2, 3, 4).reshape(B, Sk, KV, Dh)[:, :k.shape[1]]
    dv = dvb.transpose(1, 0, 2, 3, 4).reshape(B, Sk, KV, Dh)[:, :v.shape[1]]
    dq = dq.reshape(B, Sq, H, Dh)[:, :Sq0]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None)


flash_attention_ref.defvjp(_flash_fwd, _flash_bwd)


# ---------------------------------------------------------------------------
# decode attention (single query step over a KV cache) — oracle
# ---------------------------------------------------------------------------

def decode_attention_ref(q, k, v, q_pos, kv_pos, *, window: int = 0,
                         softcap: float = 0.0):
    """q: [B, H, Dh] single-position query. Thin wrapper over naive."""
    out = naive_attention(q[:, None], k, v, q_pos[:, None], kv_pos,
                          window=window, softcap=softcap)
    return out[:, 0]


# ---------------------------------------------------------------------------
# log-normal mixture — oracle (paper Sec. 4.2 decoder)
# ---------------------------------------------------------------------------

LOG_SQRT_2PI = 0.5 * math.log(2.0 * math.pi)


def lognorm_mix_logpdf_ref(tau, log_w, mu, sigma):
    """log g(tau) for a log-normal mixture.

    tau: [...], log_w/mu/sigma: [..., M] broadcastable against tau[..., None].
    Returns log-density with the same shape as tau.  Computed via
    logsumexp over components in f32.
    """
    lt = jnp.log(jnp.maximum(tau, 1e-30))[..., None].astype(jnp.float32)
    z = (lt - mu.astype(jnp.float32)) / sigma.astype(jnp.float32)
    comp = (log_w.astype(jnp.float32) - 0.5 * z * z
            - jnp.log(sigma.astype(jnp.float32)) - LOG_SQRT_2PI - lt)
    return jax.scipy.special.logsumexp(comp, axis=-1)


def lognorm_mix_logsf_ref(tau, log_w, mu, sigma):
    """log (1 - G(tau)) — survival function of the mixture (for Eq. 2).

    Uses log_ndtr for asymptotically-stable tails (erfc underflows f32
    around z ~ 13 and its log becomes -inf -> NaN gradients).
    """
    lt = jnp.log(jnp.maximum(tau, 1e-30))[..., None].astype(jnp.float32)
    z = (lt - mu.astype(jnp.float32)) / sigma.astype(jnp.float32)
    log_sf_comp = jax.scipy.special.log_ndtr(-z)
    return jax.scipy.special.logsumexp(
        log_w.astype(jnp.float32) + log_sf_comp, axis=-1)


# ---------------------------------------------------------------------------
# selective scan (mamba) — oracle
# ---------------------------------------------------------------------------

def selective_scan_ref(dt, Bc, Cc, u, A, D, h0):
    """Discretized selective-SSM recurrence (one chunk).

    dt, u: [B, C, di]; Bc, Cc: [B, C, N]; A: [di, N]; D: [di];
    h0: [B, di, N].  Returns (y [B, C, di], h_last [B, di, N]), f32.

      h_t = exp(dt_t A) h_{t-1} + (dt_t u_t) B_t
      y_t = <h_t, C_t> + D u_t
    """
    f32 = jnp.float32
    dt = dt.astype(f32)
    u = u.astype(f32)
    dA = jnp.exp(dt[..., None] * A.astype(f32))            # [B,C,di,N]
    dBu = (dt * u)[..., None] * Bc.astype(f32)[:, :, None, :]

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    a_all, b_all = lax.associative_scan(combine, (dA, dBu), axis=1)
    hs = b_all + a_all * h0.astype(f32)[:, None]
    y = jnp.einsum("bcin,bcn->bci", hs, Cc.astype(f32)) \
        + D.astype(f32) * u
    return y, hs[:, -1]
