"""Pallas TPU kernel: chunk-query attention over a PAGED KV cache.

One propose-verify round scores C = gamma+1 query positions per
sequence (the pending token + gamma drafts) against that sequence's
whole KV history; one chunked-prefill step scores C = chunk prompt
positions the same way. Expressing either as a vmapped single-token
extend wastes the MXU (one [1, bk] logits row per step) and re-reads
the cache C times; this kernel processes all C chunk queries x all G
query heads of one KV head together — a [bq*G, page] logits tile per
KV block, with the C axis tiled by ``bq`` for long prefill chunks —
with online-softmax state in VMEM scratch, so the whole chunk is ONE
pass over the cache. Masking is causal on logical positions WITHIN the
chunk too (query i at position lens[s]+i sees keys up to itself), which
is what lets the speculative verify and the prefill chunks share one
kernel.

The KV cache is paged: physical pages ``k_pages/v_pages [P, page, KV,
Dh]`` shared by every sequence, with a per-sequence block table mapping
logical block b to its physical page. The block table is a
scalar-prefetch operand, so the page indirection happens in the
BlockSpec index map (the DMA fetches exactly the pages the sequence
owns — classic paged attention). Logical KV positions are implicit:
entry p of logical block b sits at position b*page + p, which is what
makes rollback a block-table truncation (stale entries beyond the
committed length are causally masked, never rewritten).

Grid: (S, KV, nq, nb) — nb innermost/sequential, scratch
re-initialized at b == 0 and flushed at b == nb - 1 (the query-tile
dim nq sits outside nb, so each tile owns one full sweep over the
cache). Blocks past a query tile's visible horizon are skipped via
``pl.when``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref as _ref

NEG_INF = -1e30


def _kernel(bt_ref, lens_ref, q_ref, k_ref, v_ref, out_ref,
            m_scr, l_scr, acc_scr, *, scale, window, softcap, page, nb,
            bq, G):
    s = pl.program_id(0)
    qb = pl.program_id(2)
    b = pl.program_id(3)
    Dh = q_ref.shape[-1]

    @pl.when(b == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    l0 = lens_ref[s]
    q0 = qb * bq                           # first chunk row of this tile

    # A block contributes iff its first logical position can be visible
    # to the tile's last query (position l0 + q0 + bq - 1).
    @pl.when(b * page <= l0 + q0 + bq - 1)
    def _accumulate():
        q = q_ref[0, :, 0, :, :].astype(jnp.float32).reshape(bq * G, Dh)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # [page, Dh]
        v = v_ref[0, :, 0, :].astype(jnp.float32)

        s_blk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if softcap > 0:
            s_blk = jnp.tanh(s_blk / softcap) * softcap
        row = jax.lax.broadcasted_iota(jnp.int32, (bq * G, page), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (bq * G, page), 1)
        qp = l0 + q0 + row // G            # logical query positions
        kp = b * page + col                # logical key positions
        mask = kp <= qp
        if window > 0:
            mask &= kp > qp - window
        s_blk = jnp.where(mask, s_blk, NEG_INF)

        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s_blk, axis=-1))
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.where(mask, jnp.exp(s_blk - m_safe[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
        m_scr[...] = m_new

    @pl.when(b == nb - 1)
    def _flush():
        l = l_scr[...]
        safe = jnp.maximum(l, 1e-30)
        out = acc_scr[...] / safe[:, None]
        out = jnp.where((l > 0)[:, None], out, 0.0)
        out_ref[0, :, 0, :, :] = out.reshape(bq, G, Dh).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "softcap",
                                             "interpret", "bq"))
def spec_verify_attention_pallas(q, k_pages, v_pages, block_tables, lens, *,
                                 window: int = 0, softcap: float = 0.0,
                                 interpret: bool = True,
                                 bq: int = 0):
    """q: [S, C, H, Dh] — C is ANY chunk length: gamma+1 for the
    speculative verify, the chunk size for paged prefill (causal
    within-chunk masking covers both); k/v_pages: [P, page, KV, Dh];
    block_tables: [S, NB] int32 physical page per logical block;
    lens: [S] int32 committed KV length BEFORE the chunk (queries sit at
    positions lens[s] .. lens[s]+C-1, and their K/V are already written
    into the pages). ``bq`` tiles the query axis (0 = the whole chunk
    in one tile, the decode-round setting); tiling never changes the
    per-query math — each query still sweeps the same blocks in the
    same order — it only bounds the [bq*G, page] logits tile for long
    prefill chunks. Returns [S, C, H, Dh]."""
    S, C, H, Dh = q.shape
    page, KV = k_pages.shape[1], k_pages.shape[2]
    G = H // KV
    NB = block_tables.shape[1]
    bq = C if bq <= 0 else min(bq, C)
    nq = -(-C // bq)
    Cp = nq * bq
    qg = q.reshape(S, C, KV, G, Dh)
    if Cp != C:
        # pad the query axis to a whole number of tiles; the padded
        # rows attend at positions past the chunk (garbage, finite) and
        # are sliced off below
        qg = jnp.pad(qg, ((0, 0), (0, Cp - C), (0, 0), (0, 0), (0, 0)))
    lens = lens.astype(jnp.int32)
    kern = functools.partial(_kernel, scale=1.0 / math.sqrt(Dh),
                             window=window, softcap=softcap, page=page,
                             nb=NB, bq=bq, G=G)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(S, KV, nq, NB),
        in_specs=[
            pl.BlockSpec((1, bq, 1, G, Dh),
                         lambda s, h, qb, b, bt, ln: (s, qb, h, 0, 0)),
            pl.BlockSpec((1, page, 1, Dh),
                         lambda s, h, qb, b, bt, ln: (bt[s, b], 0, h, 0)),
            pl.BlockSpec((1, page, 1, Dh),
                         lambda s, h, qb, b, bt, ln: (bt[s, b], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, G, Dh),
                               lambda s, h, qb, b, bt, ln: (s, qb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq * G,), jnp.float32),
            pltpu.VMEM((bq * G,), jnp.float32),
            pltpu.VMEM((bq * G, Dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, Cp, KV, G, Dh), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lens, qg, k_pages, v_pages)
    return out[:, :C].reshape(S, C, H, Dh)


def spec_verify_attention_ref(q, k_pages, v_pages, block_tables, lens, *,
                              window: int = 0, softcap: float = 0.0,
                              max_kv: int = 0):
    """jnp oracle: gather the pages into a dense cache, run naive
    attention on logical positions.

    ``max_kv`` > 0 slices the gathered cache to exactly that length —
    with it, the result is BITWISE what a dense [S, max_kv] cache of the
    same contents produces (same shapes => same XLA reduction), which is
    what the paged==dense equivalence tests pin.
    """
    S, C, H, Dh = q.shape
    page, KV = k_pages.shape[1], k_pages.shape[2]
    NB = block_tables.shape[1]
    k = k_pages[block_tables].reshape(S, NB * page, KV, Dh)
    v = v_pages[block_tables].reshape(S, NB * page, KV, Dh)
    if max_kv:
        k, v = k[:, :max_kv], v[:, :max_kv]
    Sk = k.shape[1]
    kv_pos = jnp.broadcast_to(jnp.arange(Sk, dtype=jnp.int32), (S, Sk))
    q_pos = lens.astype(jnp.int32)[:, None] + jnp.arange(C, dtype=jnp.int32)
    return _ref.naive_attention(q, k, v, q_pos, kv_pos, window=window,
                                softcap=softcap)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "bk",
                                             "interpret"))
def spec_verify_attention_seq_pallas(q, k, v, start, *, window: int = 0,
                                     softcap: float = 0.0, bk: int = 128,
                                     interpret: bool = True):
    """Dense single-sequence form (the TPP sd verify / decode path).

    q: [C, H, Dh] chunk queries at positions start..start+C-1;
    k/v: [N, H, Dh] dense cache with slot == position (the chunk's K/V
    already written); start: scalar int32. vmap-safe: the cache is
    viewed as an identity-block-table paged pool, so the same kernel
    serves both layouts.
    """
    C, H, Dh = q.shape
    N = k.shape[0]
    bk = min(bk, N)
    pad = (-N) % bk
    if pad:
        k = jnp.pad(k, ((0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, pad), (0, 0), (0, 0)))
    nb = k.shape[0] // bk
    pages_k = k.reshape(nb, bk, H, Dh)
    pages_v = v.reshape(nb, bk, H, Dh)
    bt = jnp.arange(nb, dtype=jnp.int32)[None]
    lens = jnp.asarray(start, jnp.int32).reshape(1)
    out = spec_verify_attention_pallas(q[None], pages_k, pages_v, bt, lens,
                                       window=window, softcap=softcap,
                                       interpret=interpret)
    return out[0]
