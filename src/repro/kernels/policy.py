"""``KernelPolicy``: one frozen knob deciding HOW every kernel entry
point in ``kernels.ops`` executes — Pallas vs the jnp reference, compiled
vs ``interpret=True``, and the block/tile sizes.

The policy is threaded from the user-facing configs (``SamplerSpec``,
``ServingEngine``) through the model configs (``ModelConfig.kernel_policy``
/ ``TPPConfig.kernel_policy``) down to ``kernels.ops``, so callers choose
once and every kernel call in the compiled program agrees. It is a frozen
dataclass — hashable, so configs carrying it stay valid static jit args.

Resolution rules (``resolve()``):

  - ``backend="auto"`` picks **pallas** on a compiled TPU backend and for
    the serving/token hot path on CPU (small slot-count grids run fine in
    ``interpret=True``); the TPP whole-sequence vmap executors resolve
    "auto" to **ref** on CPU — a vmapped interpret-mode kernel serializes
    the batch into the grid loop, so fanning 10k+ lanes through it would
    undo the vmap. Callers wanting Pallas there opt in explicitly
    (``backend="pallas"``), as the parity tests do.
  - ``interpret=None`` means compiled on TPU, interpret elsewhere.

Block sizes are *requests*: ``validate_block_size`` rounds them to the
hardware sublane alignment (and clamps into range) with a once-per-site
warning instead of letting ``pallas_call`` fail on a misaligned
BlockSpec deep inside lowering.
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass
from typing import Optional

import jax

from . import alignment
from .alignment import SUBLANE  # noqa: F401  (re-export: historical home)

BACKENDS = ("auto", "pallas", "ref")


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@dataclass(frozen=True)
class KernelPolicy:
    """How kernel entry points execute.

    backend   : "pallas" | "ref" | "auto" (see module docstring).
    interpret : None = auto (compiled on TPU, interpret elsewhere).
    bq, bk    : query/key block sizes for the attention kernels.
    bn        : row tile for the log-normal-mixture kernels.
    page_size : KV block ("page") size of the paged serving pool.
    """

    backend: str = "auto"
    interpret: Optional[bool] = None
    bq: int = 128
    bk: int = 128
    bn: int = 256
    page_size: int = 16

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, "
                             f"got {self.backend!r}")
        for name in ("bq", "bk", "bn", "page_size"):
            if getattr(self, name) < 1:
                raise ValueError(f"{name} must be >= 1")

    def replace(self, **kw) -> "KernelPolicy":
        return dataclasses.replace(self, **kw)

    def resolve(self, default_backend: str = "pallas") -> "KernelPolicy":
        """Concrete policy: no "auto" backend, no None interpret.

        ``default_backend`` is what "auto" means at this call site when
        not on TPU (on TPU "auto" is always pallas-compiled).
        """
        backend = self.backend
        if backend == "auto":
            backend = "pallas" if on_tpu() else default_backend
        interpret = self.interpret
        if interpret is None:
            interpret = not on_tpu()
        return self.replace(backend=backend, interpret=interpret)

    # -- conveniences consumed by ops.py -----------------------------------
    @property
    def use_pallas(self) -> bool:
        if self.backend == "auto":
            raise ValueError("resolve() the policy before dispatching")
        return self.backend == "pallas"


#: Always the jnp reference path (training / autodiff callers).
REF = KernelPolicy(backend="ref")
#: Always Pallas (interpret off-TPU unless overridden).
PALLAS = KernelPolicy(backend="pallas")


# ---------------------------------------------------------------------------
# block-size validation (satellite: fail loudly + auto-round, not deep
# inside pallas_call lowering)
# ---------------------------------------------------------------------------

_WARNED: set = set()


def _warn_once(key, msg):
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(msg, UserWarning, stacklevel=3)


def validate_block_size(op: str, name: str, value: int, *,
                        total: Optional[int] = None,
                        align: Optional[int] = None) -> int:
    """Round a requested block size to a usable one, warning once.

    - rounds UP to a multiple of ``align`` (default: the knob's entry in
      ``kernels.alignment.BLOCK_PARAM_ALIGN`` — the same table the
      ``pallas-block-align`` lint rule enforces statically; a misaligned
      second-minor block dim fails inside Mosaic otherwise);
    - clamps to ``total`` rounded up to ``align`` (callers pad the array
      to the returned block size, so a block larger than the padded
      extent is just the whole array).
    """
    if align is None:
        align = alignment.alignment_for(name)
    if value < 1:
        raise ValueError(f"{op}: block size {name}={value} must be >= 1")
    rounded = alignment.round_up(value, align)
    if rounded != value:
        _warn_once((op, name, value),
                   f"{op}: block size {name}={value} is not "
                   f"hardware-aligned; auto-rounded up to the sublane "
                   f"multiple {rounded} (use multiples of {align} to "
                   "silence)")
    if total is not None:
        # capping to the (aligned) array extent is the normal small-input
        # case — silent, like the kernels' own min(b, S) clamp
        cap = alignment.round_up(max(total, 1), align)
        rounded = min(rounded, cap)
    return rounded
