"""Pallas TPU kernel: single-token GQA decode attention (flash-decode).

One query position per sequence against a long KV cache. For GQA we
process all G query heads of one KV head together so the [G, bk] logits
tile feeds the MXU; the KV sequence is the innermost sequential grid
dimension with online-softmax state in VMEM scratch.

Grid: (B, KV, nk). q is viewed as [B, KV, G, Dh].
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, qp_ref, kp_ref, out_ref,
            m_scr, l_scr, acc_scr, *, scale, window, softcap, nk):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    q = q_ref[0, 0, :, :].astype(jnp.float32)      # [G, Dh]
    k = k_ref[0, :, 0, :].astype(jnp.float32)      # [bk, Dh]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    qp = qp_ref[0, 0]                              # scalar query position
    kp = kp_ref[0, :]                              # [bk]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # [G,bk]
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    mask = kp <= qp
    if window > 0:
        mask &= kp > qp - window
    s = jnp.where(mask[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.where(mask[None, :], jnp.exp(s - m_safe[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
    acc_scr[...] = (acc_scr[...] * corr[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _flush():
        l = l_scr[...]
        safe = jnp.maximum(l, 1e-30)
        out = acc_scr[...] / safe[:, None]
        out = jnp.where((l > 0)[:, None], out, 0.0)
        out_ref[0, 0, :, :] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "bk",
                                             "interpret"))
def decode_attention_pallas(q, k, v, q_pos, kv_pos, *, window: int = 0,
                            softcap: float = 0.0, bk: int = 512,
                            interpret: bool = True):
    """q: [B,H,Dh]; k/v: [B,Sk,KV,Dh]; q_pos: [B]; kv_pos: [B,Sk]."""
    B, H, Dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    bk = min(bk, Sk)
    pk = (-Sk) % bk
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pk)),
                         constant_values=jnp.iinfo(jnp.int32).max)
    Skp = k.shape[1]
    nk = Skp // bk
    qg = q.reshape(B, KV, G, Dh)
    qp2 = q_pos.reshape(B, 1)
    kern = functools.partial(_kernel, scale=1.0 / math.sqrt(Dh),
                             window=window, softcap=softcap, nk=nk)
    out = pl.pallas_call(
        kern,
        grid=(B, KV, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, Dh), lambda b, h, ik: (b, ik, h, 0)),
            pl.BlockSpec((1, bk, 1, Dh), lambda b, h, ik: (b, ik, h, 0)),
            pl.BlockSpec((1, 1), lambda b, h, ik: (b, 0)),
            pl.BlockSpec((1, bk), lambda b, h, ik: (b, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dh), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, qp2, kv_pos)
    return out.reshape(B, H, Dh)
