"""Hardware block-size alignment spec — the ONE table both the runtime
validator (``kernels.policy.validate_block_size``) and the static
analyzer (``repro.analysis`` rule ``pallas-block-align``) consume.

Keeping the table here, import-free, is deliberate: the analyzer must
be able to read the spec without pulling in jax, and the runtime must
not drift from what the lint rule enforces. Changing an entry changes
BOTH checkers — the analysis test suite pins that property.

TPU tiling background: Mosaic tiles the last two dims of every block as
(sublane, lane) = (8, 128) for f32. A BlockSpec whose second-to-last
dim is not a sublane multiple fails deep inside lowering with an
opaque Mosaic error; ``validate_block_size`` rounds the request up and
warns instead, and the lint rule catches misaligned literals before
they ever reach a device.
"""
from __future__ import annotations

from typing import Optional

#: TPU sublane quantum: the second-to-last dim of an f32 block tile.
SUBLANE = 8

#: TPU lane quantum: the last dim of a block tile.
LANE = 128

#: Alignment required of each ``KernelPolicy`` block-size knob.
#: ``bq``/``bk`` tile the attention query/key axes, ``bn`` the
#: log-normal-mixture row axis — all land as the second-to-last block
#: dim of some kernel operand. ``page_size`` is the paged pools' KV
#: block: inside ``spec_verify_attention`` the page axis is the
#: sublane dim of the [page, Dh] K/V tile, so compiled TPU runs need it
#: sublane-aligned too (interpret-mode tests may use smaller pages; the
#: lint rule's default config scopes the check to ``src/``).
BLOCK_PARAM_ALIGN = {
    "bq": SUBLANE,
    "bk": SUBLANE,
    "bn": SUBLANE,
    "page_size": SUBLANE,
}


def alignment_for(name: str, default: Optional[int] = None) -> int:
    """Required alignment of block-size knob ``name`` (live lookup, so
    tests monkeypatching ``BLOCK_PARAM_ALIGN`` move every consumer)."""
    if default is None:
        default = SUBLANE
    return int(BLOCK_PARAM_ALIGN.get(name, default))


def round_up(value: int, align: int) -> int:
    return ((value + align - 1) // align) * align


def is_aligned(name: str, value: int) -> bool:
    return value % alignment_for(name) == 0
