"""Pallas TPU kernel: fused selective scan (Mamba-1 recurrence).

EXPERIMENTS.md §Perf pair 1 drove falcon-mamba's memory term down 100x by
chunking the scan in pure JAX; this kernel is the recorded "next lever":
inside one chunk it keeps the running state h [bi, N] and the discretized
dA/dBu entirely in VMEM/registers, so the [C, di, N] state tensors never
touch HBM at all — HBM traffic becomes O(C*di + C*N) per chunk instead of
O(C*di*N).

Grid: (B, di/bi) — channel blocks are independent; the time loop runs
sequentially inside the kernel (lax.fori_loop over C).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(dt_ref, b_ref, c_ref, u_ref, a_ref, d_ref, h0_ref,
            y_ref, h_ref, *, C):
    dt = dt_ref[0].astype(jnp.float32)       # [C, bi]
    Bc = b_ref[0].astype(jnp.float32)        # [C, N]
    Cc = c_ref[0].astype(jnp.float32)        # [C, N]
    u = u_ref[0].astype(jnp.float32)         # [C, bi]
    A = a_ref[...].astype(jnp.float32)       # [bi, N]
    D = d_ref[...].astype(jnp.float32)       # [bi]
    h = h0_ref[0].astype(jnp.float32)        # [bi, N]

    def step(t, carry):
        h, y = carry
        dt_t = dt[t][:, None]                # [bi, 1]
        dA = jnp.exp(dt_t * A)               # [bi, N]
        h = dA * h + (dt_t * u[t][:, None]) * Bc[t][None, :]
        y_t = jnp.sum(h * Cc[t][None, :], axis=-1) + D * u[t]
        y = lax.dynamic_update_slice(y, y_t[None, :], (t, 0))
        return h, y

    y0 = jnp.zeros((C, dt.shape[1]), jnp.float32)
    h, y = lax.fori_loop(0, C, step, (h, y0))
    y_ref[0] = y
    h_ref[0] = h


@functools.partial(jax.jit, static_argnames=("bi", "interpret"))
def selective_scan_pallas(dt, Bc, Cc, u, A, D, h0, *, bi: int = 512,
                          interpret: bool = True):
    """dt,u: [B,C,di]; Bc,Cc: [B,C,N]; A: [di,N]; D: [di]; h0: [B,di,N].

    Returns (y [B,C,di] f32, h_last [B,di,N] f32)."""
    B, C, di = dt.shape
    N = Bc.shape[-1]
    bi = min(bi, di)
    pad = (-di) % bi
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, pad)))
        u = jnp.pad(u, ((0, 0), (0, 0), (0, pad)))
        A = jnp.pad(A, ((0, pad), (0, 0)))
        D = jnp.pad(D, ((0, pad),))
        h0 = jnp.pad(h0, ((0, 0), (0, pad), (0, 0)))
    dip = dt.shape[-1]
    grid = (B, dip // bi)
    kern = functools.partial(_kernel, C=C)
    y, h = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, C, bi), lambda b, i: (b, 0, i)),
            pl.BlockSpec((1, C, N), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, C, N), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, C, bi), lambda b, i: (b, 0, i)),
            pl.BlockSpec((bi, N), lambda b, i: (i, 0)),
            pl.BlockSpec((bi,), lambda b, i: (i,)),
            pl.BlockSpec((1, bi, N), lambda b, i: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C, bi), lambda b, i: (b, 0, i)),
            pl.BlockSpec((1, bi, N), lambda b, i: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, C, dip), jnp.float32),
            jax.ShapeDtypeStruct((B, dip, N), jnp.float32),
        ],
        interpret=interpret,
    )(dt, Bc, Cc, u, A, D, h0)
    return y[..., :di], h[:, :di]
