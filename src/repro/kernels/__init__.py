"""Pallas TPU kernels (flash_attention, decode_attention,
spec_verify_attention, lognorm_mix, selective_scan) + jnp oracles.
Import via ``ops`` for dispatch; ``policy.KernelPolicy`` picks
pallas-vs-ref / compiled-vs-interpret / block sizes per call site."""
from . import ops, policy, ref
