"""Pallas TPU kernels (flash_attention, decode_attention, lognorm_mix,
selective_scan) + jnp oracles. Import via ``ops`` for dispatch."""
from . import ops, ref
