"""Pallas TPU kernel: fused log-normal-mixture log-pdf (paper Sec. 4.2).

The decoder evaluates g(tau) at gamma x M points per verify step; the
naive composition is ~7 elementwise HBM round-trips over [N, M]
intermediates. This kernel keeps the whole [bn, M] tile in VMEM and fuses
log / normalize / logsumexp into one pass.

Tiling: grid over N in blocks of ``bn`` (second-minor 8-aligned, minor dim
M lane-aligned to 128 via padding inside the caller when M < 128).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

LOG_SQRT_2PI = 0.5 * math.log(2.0 * math.pi)
NEG_INF = -1e30


def _kernel(tau_ref, log_w_ref, mu_ref, sigma_ref, out_ref):
    tau = tau_ref[...].astype(jnp.float32)              # [bn]
    lw = log_w_ref[...].astype(jnp.float32)             # [bn, M]
    mu = mu_ref[...].astype(jnp.float32)
    sigma = sigma_ref[...].astype(jnp.float32)
    lt = jnp.log(jnp.maximum(tau, 1e-30))[:, None]
    z = (lt - mu) / sigma
    comp = lw - 0.5 * z * z - jnp.log(sigma) - LOG_SQRT_2PI - lt
    m = jnp.max(comp, axis=-1, keepdims=True)
    out = jnp.log(jnp.sum(jnp.exp(comp - m), axis=-1)) + m[:, 0]
    out_ref[...] = out


def _logsf_kernel(tau_ref, log_w_ref, mu_ref, sigma_ref, out_ref):
    """log(1 - G(tau)): mixture survival via log_ndtr (stable tails),
    fused log / normalize / logsumexp in one VMEM pass — the thinning
    upper-bound check evaluates this grid x M wide per proposal."""
    tau = tau_ref[...].astype(jnp.float32)              # [bn]
    lw = log_w_ref[...].astype(jnp.float32)             # [bn, M]
    mu = mu_ref[...].astype(jnp.float32)
    sigma = sigma_ref[...].astype(jnp.float32)
    lt = jnp.log(jnp.maximum(tau, 1e-30))[:, None]
    z = (lt - mu) / sigma
    comp = lw + jax.scipy.special.log_ndtr(-z)
    m = jnp.max(comp, axis=-1, keepdims=True)
    m_safe = jnp.where(m <= -1e30 / 2, 0.0, m)
    out = jnp.log(jnp.maximum(
        jnp.sum(jnp.exp(comp - m_safe), axis=-1), 1e-30)) + m_safe[:, 0]
    out_ref[...] = out


def _rowwise_call(kernel, tau, log_w, mu, sigma, bn, interpret):
    """Shared tiling: flatten to [N] rows, pad to the block size, grid
    over row blocks with the whole [bn, M] tile resident in VMEM."""
    orig_shape = tau.shape
    tau = tau.reshape(-1)
    N = tau.shape[0]
    M = log_w.shape[-1]
    # mix params may be broadcast against tau (one mixture, many taus)
    log_w = jnp.broadcast_to(log_w, orig_shape + (M,)).reshape(N, M)
    mu = jnp.broadcast_to(mu, orig_shape + (M,)).reshape(N, M)
    sigma = jnp.broadcast_to(sigma, orig_shape + (M,)).reshape(N, M)
    bn = min(bn, max(8, N))
    pad = (-N) % bn
    if pad:
        tau = jnp.pad(tau, (0, pad), constant_values=1.0)
        log_w = jnp.pad(log_w, ((0, pad), (0, 0)))
        mu = jnp.pad(mu, ((0, pad), (0, 0)))
        sigma = jnp.pad(sigma, ((0, pad), (0, 0)), constant_values=1.0)
    Np = tau.shape[0]
    grid = (Np // bn,)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn, M), lambda i: (i, 0)),
            pl.BlockSpec((bn, M), lambda i: (i, 0)),
            pl.BlockSpec((bn, M), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Np,), jnp.float32),
        interpret=interpret,
    )(tau, log_w, mu, sigma)
    return out[:N].reshape(orig_shape)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def lognorm_mix_logpdf_pallas(tau, log_w, mu, sigma, *, bn: int = 256,
                              interpret: bool = True):
    """tau: [N]; log_w/mu/sigma: [N, M] -> logpdf [N]."""
    return _rowwise_call(_kernel, tau, log_w, mu, sigma, bn, interpret)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def lognorm_mix_logsf_pallas(tau, log_w, mu, sigma, *, bn: int = 256,
                             interpret: bool = True):
    """tau: [N]; log_w/mu/sigma: [N, M] -> log(1 - G(tau)) [N]."""
    return _rowwise_call(_logsf_kernel, tau, log_w, mu, sigma, bn, interpret)
