"""The four assigned input shapes.

Each shape selects which step function the dry-run lowers:
  - train_4k     -> train_step  (fwd + bwd + Adam update)
  - prefill_32k  -> prefill     (full forward, KV-cache write)
  - decode_32k   -> serve_step  (ONE new token against a seq_len KV cache)
  - long_500k    -> serve_step with the sub-quadratic long-context variant
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def get_shape(name: str) -> InputShape:
    return SHAPES[name]
