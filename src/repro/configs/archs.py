"""The 10 assigned architectures (exact specs from the public pool) plus
reduced smoke variants. Source citations are recorded per config.

One module (rather than 10 one-liner files) defines them all; thin
``src/repro/configs/<id>.py`` re-export modules exist so each architecture
is importable as its own config file per the required layout.
"""
from __future__ import annotations

from .base import ModelConfig

ARCHS = {}


def _reg(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


falcon_mamba_7b = _reg(ModelConfig(
    name="falcon-mamba-7b", family="ssm", num_layers=64, d_model=4096,
    num_heads=0, num_kv_heads=0, head_dim=1, d_ff=0, vocab_size=65024,
    ssm_state=16, d_inner=8192, conv_width=4,
    ssm_chunk=128,   # two-level chunked selective scan (EXPERIMENTS §Perf)
    source="mamba1 arch [arXiv:2410.05355]"))

mistral_nemo_12b = _reg(ModelConfig(
    name="mistral-nemo-12b", family="dense", num_layers=40, d_model=5120,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=14336,
    vocab_size=131072, rope_theta=1e6,
    source="128k ctx [hf:mistralai/Mistral-Nemo-Base-2407]"))

recurrentgemma_9b = _reg(ModelConfig(
    name="recurrentgemma-9b", family="hybrid", num_layers=38, d_model=4096,
    num_heads=16, num_kv_heads=1, head_dim=256, d_ff=12288,
    vocab_size=256000, block_pattern=("rec", "rec", "attn"),
    lru_width=4096, sliding_window=2048, logit_softcap=0.0,
    source="RG-LRU + local attn 1:2 [arXiv:2402.19427]"))

internvl2_26b = _reg(ModelConfig(
    name="internvl2-26b", family="vlm", num_layers=48, d_model=6144,
    num_heads=48, num_kv_heads=8, head_dim=128, d_ff=16384,
    vocab_size=92553, vision_prefix_len=1024,
    source="InternViT + InternLM2 [arXiv:2404.16821] (ViT stubbed)"))

seamless_m4t_medium = _reg(ModelConfig(
    name="seamless-m4t-medium", family="encdec", num_layers=12,
    enc_layers=12, dec_layers=12, d_model=1024, num_heads=16,
    num_kv_heads=16, head_dim=64, d_ff=4096, vocab_size=256206,
    enc_seq_divisor=8, max_enc_len=4096,
    source="enc-dec multimodal [arXiv:2308.11596] (codec stubbed)"))

llama3_405b = _reg(ModelConfig(
    name="llama3-405b", family="dense", num_layers=126, d_model=16384,
    num_heads=128, num_kv_heads=8, head_dim=128, d_ff=53248,
    vocab_size=128256, rope_theta=5e5,
    source="GQA 128k vocab [arXiv:2407.21783]"))

granite_moe_1b = _reg(ModelConfig(
    name="granite-moe-1b-a400m", family="moe", num_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=8, head_dim=64, d_ff=512, vocab_size=49155,
    num_experts=32, num_experts_per_tok=8,
    source="32e top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base]"))

phi35_moe_42b = _reg(ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=6400, vocab_size=32064,
    num_experts=16, num_experts_per_tok=2,
    source="16e top-2 [hf:microsoft/Phi-3.5-MoE-instruct]"))

qwen25_32b = _reg(ModelConfig(
    name="qwen2.5-32b", family="dense", num_layers=64, d_model=5120,
    num_heads=40, num_kv_heads=8, head_dim=128, d_ff=27648,
    vocab_size=152064, qkv_bias=True, rope_theta=1e6,
    source="GQA QKV bias [hf:Qwen/Qwen2.5-0.5B]"))

llama32_1b = _reg(ModelConfig(
    name="llama3.2-1b", family="dense", num_layers=16, d_model=2048,
    num_heads=32, num_kv_heads=8, head_dim=64, d_ff=8192,
    vocab_size=128256, rope_theta=5e5,
    source="small llama3 [hf:meta-llama/Llama-3.2-1B]"))


def get_arch(name: str) -> ModelConfig:
    return ARCHS[name]


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: <=2 layers, d_model<=512, <=4 experts."""
    kw = dict(
        name=cfg.name + "-smoke", num_layers=2, d_model=128,
        d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        dtype="float32", param_dtype="float32", remat=False,
    )
    if cfg.family == "ssm":
        kw.update(d_inner=256, dt_rank=8)
    else:
        kw.update(num_heads=4,
                  num_kv_heads=min(cfg.num_kv_heads, 2) or 1,
                  head_dim=32)
    if cfg.is_moe:
        kw.update(num_experts=4,
                  num_experts_per_tok=min(cfg.num_experts_per_tok, 2),
                  moe_group_size=32)
    if cfg.family == "hybrid":
        kw.update(num_layers=4, lru_width=128, sliding_window=16)
    if cfg.family == "encdec":
        kw.update(enc_layers=2, dec_layers=2, max_enc_len=16)
    if cfg.family == "vlm":
        kw.update(vision_prefix_len=8)
    return cfg.replace(**kw)
