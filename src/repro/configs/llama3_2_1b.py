"""Config for llama3.2-1b (see archs.py for the full spec + citation)."""
from .archs import llama32_1b as CONFIG  # noqa: F401
from .archs import smoke_variant

SMOKE = smoke_variant(CONFIG)
