"""Config for phi3.5-moe-42b-a6.6b (see archs.py for the full spec + citation)."""
from .archs import phi35_moe_42b as CONFIG  # noqa: F401
from .archs import smoke_variant

SMOKE = smoke_variant(CONFIG)
