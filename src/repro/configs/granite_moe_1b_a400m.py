"""Config for granite-moe-1b-a400m (see archs.py for the full spec + citation)."""
from .archs import granite_moe_1b as CONFIG  # noqa: F401
from .archs import smoke_variant

SMOKE = smoke_variant(CONFIG)
