from . import archs, base, shapes
from .archs import ARCHS, get_arch, smoke_variant
from .base import ModelConfig, TPPConfig, paper_draft, paper_target
from .shapes import SHAPES, get_shape
