"""Config for recurrentgemma-9b (see archs.py for the full spec + citation)."""
from .archs import recurrentgemma_9b as CONFIG  # noqa: F401
from .archs import smoke_variant

SMOKE = smoke_variant(CONFIG)
