"""Config for seamless-m4t-medium (see archs.py for the full spec + citation)."""
from .archs import seamless_m4t_medium as CONFIG  # noqa: F401
from .archs import smoke_variant

SMOKE = smoke_variant(CONFIG)
