"""Config for mistral-nemo-12b (see archs.py for the full spec + citation)."""
from .archs import mistral_nemo_12b as CONFIG  # noqa: F401
from .archs import smoke_variant

SMOKE = smoke_variant(CONFIG)
