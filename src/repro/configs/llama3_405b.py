"""Config for llama3-405b (see archs.py for the full spec + citation)."""
from .archs import llama3_405b as CONFIG  # noqa: F401
from .archs import smoke_variant

SMOKE = smoke_variant(CONFIG)
