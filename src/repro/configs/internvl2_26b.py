"""Config for internvl2-26b (see archs.py for the full spec + citation)."""
from .archs import internvl2_26b as CONFIG  # noqa: F401
from .archs import smoke_variant

SMOKE = smoke_variant(CONFIG)
