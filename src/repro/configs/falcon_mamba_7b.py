"""Config for falcon-mamba-7b (see archs.py for the full spec + citation)."""
from .archs import falcon_mamba_7b as CONFIG  # noqa: F401
from .archs import smoke_variant

SMOKE = smoke_variant(CONFIG)
