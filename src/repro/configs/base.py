"""Config dataclasses for every model family in the zoo.

All configs are frozen dataclasses so they can be closed over by jitted
functions and hashed as static arguments.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

from ..kernels.policy import KernelPolicy


@dataclass(frozen=True)
class ModelConfig:
    """Unified architecture config.

    A single config class covers every assigned family; family-specific
    fields are zero/empty when unused.  ``family`` selects the forward
    implementation in ``repro.models.registry``.
    """

    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # --- attention ---
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0          # 0 = full attention (native)
    long_context_window: int = 8192  # window used for the long_500k variant
    logit_softcap: float = 0.0

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_group_size: int = 256        # dispatch group (bounds dispatch tensor)

    # --- SSM (mamba-1) ---
    ssm_state: int = 0
    d_inner: int = 0                 # 0 -> 2 * d_model
    conv_width: int = 4
    dt_rank: int = 0                 # 0 -> d_model // 16
    ssm_chunk: int = 0               # >0: two-level chunked selective scan

    # --- hybrid (recurrentgemma) ---
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    lru_width: int = 0               # 0 -> d_model

    # --- enc-dec (seamless) ---
    enc_layers: int = 0
    dec_layers: int = 0
    enc_seq_divisor: int = 8         # frontend downsampling: enc frames = seq // divisor
    max_enc_len: int = 4096

    # --- vlm ---
    vision_prefix_len: int = 0       # patch embeddings provided by input_specs stub

    # --- numerics / execution ---
    dtype: str = "bfloat16"          # activation dtype
    param_dtype: str = "bfloat16"
    remat: bool = True               # checkpoint each scanned layer in training
    remat_policy: str = "full"       # full | dots (save matmul outputs)
    scan_layers: bool = True
    use_pallas: bool = False         # legacy switch for the TRAINING forward
    # inference-path kernel policy (extend / paged decode / spec-verify):
    # "auto" resolves to Pallas (compiled on TPU, interpret elsewhere)
    kernel_policy: KernelPolicy = KernelPolicy()
    tie_embeddings: bool = False

    # --- provenance ---
    source: str = ""                 # citation for the assigned config

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.family == "ssm" and self.d_inner == 0:
            object.__setattr__(self, "d_inner", 2 * self.d_model)
        if self.family == "ssm" and self.dt_rank == 0:
            object.__setattr__(self, "dt_rank", max(1, self.d_model // 16))
        if self.family == "hybrid" and self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def n_params(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        H, KV, Dh = self.num_heads, self.num_kv_heads, self.head_dim
        emb = V * D * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            di, N, R = self.d_inner, self.ssm_state, self.dt_rank
            per = D * 2 * di + di * self.conv_width + di * (R + 2 * N) \
                + R * di + di * N + di + di * D + 2 * D
            return emb - V * D + V * D + L * per  # ssm has single emb + lm head
        per_attn = D * (H + 2 * KV) * Dh + H * Dh * D
        if self.is_moe:
            per_mlp = D * self.num_experts + self.num_experts * 3 * D * F
        else:
            per_mlp = 3 * D * F
        per = per_attn + per_mlp + 2 * D
        if self.family == "encdec":
            # encoder (self) + decoder (self + cross)
            enc = self.enc_layers * (per_attn + per_mlp + 2 * D)
            dec = self.dec_layers * (2 * per_attn + per_mlp + 3 * D)
            return emb + enc + dec
        if self.family == "hybrid":
            # mix of recurrent and attention temporal blocks
            n_attn = sum(1 for i in range(L) if self._hybrid_kind(i) == "attn")
            n_rec = L - n_attn
            w = self.lru_width
            per_rec = 2 * D * w + w * self.conv_width + 2 * w * w // 16 + 2 * w + w * D
            return emb + n_attn * (per_attn + per_mlp + 2 * D) + n_rec * (per_rec + per_mlp + 2 * D)
        return emb + L * per

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE counts only routed experts)."""
        if not self.is_moe:
            return self.n_params
        D, F, L = self.d_model, self.d_ff, self.num_layers
        dense = self.n_params - L * self.num_experts * 3 * D * F
        return dense + L * self.num_experts_per_tok * 3 * D * F

    def _hybrid_kind(self, i: int) -> str:
        pat = self.block_pattern or ("attn",)
        return pat[i % len(pat)]

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class TPPConfig:
    """Config for the paper's CDF-based Transformer TPP (Sec. 4.2)."""

    name: str = "tpp"
    encoder: str = "thp"             # thp | sahp | attnhp
    num_layers: int = 20             # paper target: 20 layers
    num_heads: int = 8               # paper target: 8 heads
    d_model: int = 64                # paper: D = 64
    d_ff: int = 256
    num_marks: int = 1               # K event types
    num_mix: int = 64                # paper: M = 64 log-normal components
    # AttNHP temporal-encoding hyperparameters (Eq. 29)
    attnhp_m: float = 1.0
    attnhp_M: float = 2000.0
    dtype: str = "float32"
    sigma_min: float = 1e-3          # numerical floor for mixture scales
    sigma_max: float = 10.0
    # inference-path kernel policy. TPP resolves "auto" to the reference
    # off-TPU (the whole-sequence vmap executors fan thousands of lanes
    # through extend; a vmapped interpret-mode kernel would serialize
    # them) and to compiled Pallas on TPU; ``KernelPolicy(backend=
    # "pallas")`` opts in anywhere (the kernel-parity tests do).
    kernel_policy: KernelPolicy = KernelPolicy()

    @property
    def head_dim(self) -> int:
        return self.d_model // self.num_heads

    def replace(self, **kw) -> "TPPConfig":
        return dataclasses.replace(self, **kw)


# Paper's default target/draft pair (Sec. 5: 8-head 20-layer target,
# 1-head 1-layer draft).
def paper_target(encoder: str = "thp", num_marks: int = 1) -> TPPConfig:
    return TPPConfig(name=f"tpp-target-{encoder}", encoder=encoder,
                     num_layers=20, num_heads=8, num_marks=num_marks)


def paper_draft(encoder: str = "thp", num_marks: int = 1) -> TPPConfig:
    return TPPConfig(name=f"tpp-draft-{encoder}", encoder=encoder,
                     num_layers=1, num_heads=1, num_marks=num_marks)
