"""Config for qwen2.5-32b (see archs.py for the full spec + citation)."""
from .archs import qwen25_32b as CONFIG  # noqa: F401
from .archs import smoke_variant

SMOKE = smoke_variant(CONFIG)
