"""Synthetic + real-like datasets (paper App. B).

Synthetic: inhomogeneous Poisson / Hawkes / Multi-Hawkes with the paper's
exact parameters, simulated by thinning.

Real-like: the paper's four real datasets (Taobao/Amazon/Taxi/
StackOverflow) are not downloadable in this offline container; we
substitute multivariate Hawkes processes matching each dataset's
event-type cardinality (K = 17 / 16 / 10 / 22) and a comparable time
scale, under names ``<dataset>_like``. The Table-2 protocol (AR-vs-SD
discrepancy with an AR-vs-AR self-baseline) is unchanged.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core import thinning as thin


@dataclass
class TPPDataset:
    name: str
    num_marks: int
    t_end: float
    train: List[Tuple[np.ndarray, np.ndarray]]
    val: List[Tuple[np.ndarray, np.ndarray]]
    test: List[Tuple[np.ndarray, np.ndarray]]
    process: Optional[thin.PointProcess] = None   # ground truth if known


def _split(seqs, train=0.8, val=0.1):
    n = len(seqs)
    a, b = int(n * train), int(n * (train + val))
    return seqs[:a], seqs[a:b], seqs[b:]


def _random_multihawkes(K: int, seed: int, target_rate: float = 1.0
                        ) -> thin.MultiHawkes:
    """Stable random multivariate Hawkes with K marks."""
    rng = np.random.default_rng(seed)
    mu = rng.uniform(0.3, 1.0, K)
    alpha = rng.uniform(0.0, 1.0, (K, K))
    beta = rng.uniform(1.5, 3.0, (K, K))
    # enforce spectral stability: branching matrix alpha/beta, radius < 0.8
    B = alpha / beta
    radius = max(abs(np.linalg.eigvals(B)).max(), 1e-9)
    alpha *= 0.6 / radius
    mu *= target_rate * K / mu.sum()
    return thin.MultiHawkes(mu=mu, alpha=alpha, beta=beta)


_REAL_LIKE = {
    # name: (K, seed, per-mark base rate)
    "taobao_like": (17, 101, 0.05),
    "amazon_like": (16, 202, 0.05),
    "taxi_like": (10, 303, 0.08),
    "stackoverflow_like": (22, 404, 0.04),
}


def make_dataset(name: str, n_seqs: int = 1000, t_end: float = 100.0,
                 seed: int = 0) -> TPPDataset:
    if name == "poisson":
        proc = thin.InhomPoisson()
    elif name == "hawkes":
        proc = thin.Hawkes()
    elif name == "multihawkes":
        proc = thin.MultiHawkes()
    elif name in _REAL_LIKE:
        K, pseed, rate = _REAL_LIKE[name]
        proc = _random_multihawkes(K, pseed, rate)
    else:
        raise ValueError(name)
    seqs = thin.simulate_dataset(proc, n_seqs, t_end, seed=seed)
    tr, va, te = _split(seqs)
    return TPPDataset(name, proc.num_marks, t_end, tr, va, te, process=proc)


# ---------------------------------------------------------------------------
# padding / batching
# ---------------------------------------------------------------------------

def pad_batch(seqs, max_len: int) -> Dict[str, np.ndarray]:
    """-> {times [B,N], types [B,N], mask [B,N]} float32/int32."""
    B = len(seqs)
    times = np.zeros((B, max_len), np.float32)
    types = np.zeros((B, max_len), np.int32)
    mask = np.zeros((B, max_len), np.float32)
    for i, (t, k) in enumerate(seqs):
        n = min(len(t), max_len)
        times[i, :n] = t[:n]
        types[i, :n] = k[:n]
        mask[i, :n] = 1.0
    return {"times": times, "types": types, "mask": mask}


def batches(seqs, batch_size: int, max_len: int, *, shuffle: bool = True,
            seed: int = 0, drop_last: bool = False):
    order = np.arange(len(seqs))
    if shuffle:
        np.random.default_rng(seed).shuffle(order)
    for i in range(0, len(order), batch_size):
        sel = order[i:i + batch_size]
        if drop_last and len(sel) < batch_size:
            return
        yield pad_batch([seqs[j] for j in sel], max_len)


def max_events(seqs) -> int:
    return max((len(t) for t, _ in seqs), default=1)
