from . import synthetic
from .synthetic import TPPDataset, batches, make_dataset, pad_batch
