"""Decorator registries for sampling strategies and draft policies
(mirrors ``models/registry.py``: names -> implementations, so new methods
plug in without another combinatorial explosion of entrypoints)."""
from __future__ import annotations

from typing import Callable, Dict

_STRATEGIES: Dict[str, object] = {}
_DRAFT_POLICIES: Dict[str, Callable] = {}


def register_strategy(name: str):
    """Class decorator: register a sampling strategy under ``name``.

    A strategy instance provides:
      - ``build_device(spec, bundle) -> fn(rng) -> SeqResult`` — a
        jit/vmap-compatible single-sequence sampler (None if unsupported);
      - ``build_host(spec, bundle) -> fn(rng) -> SeqResult`` — the
        paper-faithful host loop for one sequence.
    """
    def deco(cls):
        _STRATEGIES[name] = cls()
        return cls
    return deco


def get_strategy(name: str):
    if name not in _STRATEGIES:
        raise KeyError(f"no sampling strategy {name!r}; registered: "
                       f"{sorted(_STRATEGIES)}")
    return _STRATEGIES[name]


def strategy_names():
    return sorted(_STRATEGIES)


def register_draft_policy(name: str):
    def deco(cls):
        _DRAFT_POLICIES[name] = cls
        return cls
    return deco


def get_draft_policy(name: str):
    if name not in _DRAFT_POLICIES:
        raise KeyError(f"no draft policy {name!r}; registered: "
                       f"{sorted(_DRAFT_POLICIES)}")
    return _DRAFT_POLICIES[name]


def draft_policy_names():
    return sorted(_DRAFT_POLICIES)
