"""``SamplingEngine``: the one config-driven entrypoint for sampling.

    spec = SamplerSpec(method="sd", execution="vmap", t_end=20.0,
                       gamma=10, max_events=256, batch=64)
    fn = ENGINE.build(spec, cfg_t, params_t, cfg_d, params_d)
    batch = fn(jax.random.PRNGKey(0))        # -> SampleBatch
    print(batch.stats().describe())

Execution lowering:

  host    — python loop per sequence (paper-faithful sync-per-step),
            batch handled by splitting the seed on the host.
  jit     — the strategy's single-sequence lax.while_loop sampler; B=1.
  vmap    — jax.vmap of the jitted sampler over a split seed batch.
  sharded — vmap placed on a real device mesh: params are laid out with
            the model's logical axes through ``distributed/sharding.py``
            rules, the seed batch is sharded over the mesh's data axis,
            and the whole loop is jitted with explicit in/out shardings
            so GSPMD fans whole sequences out across devices. The mesh
            defaults to ``launch.mesh.resolve_sample_mesh()`` (the
            production mesh when 256+ devices are visible, the debug
            mesh on forced host devices); pass ``mesh=`` to
            ``build``/``build_sampler`` to override.

RNG contract: every executor derives lane keys as
``jax.random.split(rng, spec.batch)`` — so host, jit (batch=1), vmap and
sharded execution of the same spec consume identical per-lane streams
and produce identical sequences. With ``spec.fanout=K`` each base lane
fans into K scenario streams ``fold_in(base_lane, k)`` (the serving
engine's fan-out convention), giving ``batch * fanout`` lanes whose
member k is bitwise the fanout=1 run seeded with its folded key.

Built callables are cached per (spec, model-bundle identity, mesh) so
repeated calls reuse compilations.
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import strategies as _strategies  # noqa: F401  (registers builtin strategies)
from .registry import get_strategy
from .result import (SampleBatch, batch_from_mapped, batch_from_seq,
                     stack_seqs)
from .spec import SamplerSpec, SpecError
from .strategies import ModelBundle


class SamplingEngine:
    """Builds spec-driven samplers; caches built callables.

    The cache is LRU-bounded: entries keep their params trees alive (the
    id-based key is only valid while the objects live), so an unbounded
    cache would pin every superseded checkpoint for process lifetime.
    """

    MAX_CACHED = 32

    def __init__(self):
        from collections import OrderedDict
        self._cache = OrderedDict()

    # -- TPP domain --------------------------------------------------------
    def build(self, spec: SamplerSpec, cfg_t, params_t, cfg_d=None,
              params_d=None, mesh=None) -> Callable[..., SampleBatch]:
        """Return ``fn(rng) -> SampleBatch`` for domain="tpp" specs, or
        ``fn(rng, prompt) -> SampleBatch`` for domain="token" specs.

        ``mesh`` only matters for execution="sharded" (and token-domain
        serving on a mesh); ``None`` resolves a default from the visible
        devices at build time."""
        spec.validate()
        if spec.requires_draft and (cfg_d is None or params_d is None):
            raise SpecError(f"method={spec.method!r} needs a draft model "
                            "(cfg_d, params_d)")
        # mesh only affects sharded / token builds; normalizing it out of
        # the key elsewhere keeps one cache entry per (spec, bundle)
        mesh_key = (mesh if spec.execution == "sharded"
                    or spec.domain == "token" else None)
        key = (spec, id(cfg_t), id(params_t), id(cfg_d), id(params_d),
               mesh_key)
        if key not in self._cache:
            if spec.domain == "token":
                fn = self._build_token(spec, cfg_t, params_t, cfg_d,
                                       params_d, mesh)
            else:
                fn = self._build_tpp(spec, cfg_t, params_t, cfg_d, params_d,
                                     mesh)
            # keep the params alive alongside the closure (id keys are
            # only unique while the objects live)
            self._cache[key] = (fn, (cfg_t, params_t, cfg_d, params_d))
            while len(self._cache) > self.MAX_CACHED:
                self._cache.popitem(last=False)
        else:
            self._cache.move_to_end(key)
        return self._cache[key][0]

    def sample(self, spec: SamplerSpec, cfg_t, params_t, rng, cfg_d=None,
               params_d=None, prompt=None, mesh=None) -> SampleBatch:
        """One-shot convenience: build (cached) and call."""
        fn = self.build(spec, cfg_t, params_t, cfg_d, params_d, mesh=mesh)
        if spec.domain == "token":
            if prompt is None:
                raise SpecError("domain='token' sampling needs a prompt")
            return fn(rng, prompt)
        return fn(rng)

    def _build_tpp(self, spec, cfg_t, params_t, cfg_d, params_d, mesh=None):
        strat = get_strategy(spec.method)

        if spec.kernel != "auto":
            # force a kernel backend for EVERY execution of this spec —
            # the configs carry the policy, so host/jit/vmap stay
            # stream-identical under whichever backend is chosen
            from ..kernels.policy import KernelPolicy
            pol = KernelPolicy(backend=spec.kernel)
            cfg_t = cfg_t.replace(kernel_policy=pol)
            if cfg_d is not None:
                cfg_d = cfg_d.replace(kernel_policy=pol)

        if spec.requires_draft and spec.execution != "host":
            from .policies import resolve_policy
            if not resolve_policy(spec).is_static:
                raise SpecError(
                    f"draft_policy={spec.draft_policy!r} adapts gamma "
                    "between rounds; the device executors need a static "
                    "window — use execution='host'")

        rules = None
        if spec.execution == "sharded":
            # Place the params on the mesh BEFORE the strategy closes over
            # them: every leaf is laid out by the model's logical axes
            # through the shared rule set (heads/mlp over "model", with
            # the divisible-or-replicate fallback), so the jitted loop
            # below consumes already-sharded weights.
            from ..distributed.sharding import Rules
            from ..launch.mesh import resolve_sample_mesh
            from ..models.tpp import logical_axes as tpp_logical_axes
            mesh = mesh if mesh is not None else resolve_sample_mesh()
            rules = Rules(mesh, fsdp=False)

            def place(cfg, params):
                return jax.device_put(
                    params, rules.tree_shardings(tpp_logical_axes(cfg),
                                                 params))
            params_t = place(cfg_t, params_t)
            if params_d is not None:
                params_d = place(cfg_d, params_d)

        bundle = ModelBundle(cfg_t, params_t, cfg_d, params_d)

        def lane_keys(rng):
            """[batch * fanout] lane keys: split over base lanes, then
            fold_in over the K scenario streams of each. fanout=1 keeps
            the raw split keys — bitwise the historical streams."""
            base = jax.random.split(rng, spec.batch)
            if spec.fanout == 1:
                return base
            ks = jax.vmap(lambda r: jax.vmap(
                lambda k: jax.random.fold_in(r, k))(
                    jnp.arange(spec.fanout)))(base)
            return ks.reshape((spec.batch * spec.fanout,) + ks.shape[2:])

        n_lanes = spec.batch * spec.fanout

        if spec.execution == "host":
            single = strat.build_host(spec, bundle)

            def host_fn(rng):
                # ALWAYS split (even at batch=1): host lane i and vmap
                # lane i consume the same key, so the two executors agree
                # exactly at every batch size.
                return stack_seqs([single(r) for r in lane_keys(rng)])
            return host_fn

        single = strat.build_device(spec, bundle)
        if single is None:
            raise SpecError(f"method={spec.method!r} has no device "
                            "execution; use execution='host'")
        if spec.execution == "jit":
            # same split-derived stream as lane 0 of the other executors
            return lambda rng: batch_from_seq(
                single(jax.random.split(rng, 1)[0]))

        mapped = jax.vmap(single)
        if spec.execution == "vmap":
            return lambda rng: batch_from_mapped(mapped(lane_keys(rng)))

        # sharded: the vmapped loop jitted with explicit in/out shardings
        # — the seed batch (and therefore every per-lane buffer) is
        # partitioned over the mesh's data axis; params keep the logical
        # placement applied above.
        key0 = jax.random.PRNGKey(0)  # repro: ignore[rng-raw-prngkey] -- shape-only dummy under eval_shape; no random bits are ever drawn from it
        rng_struct = jax.eval_shape(lane_keys, key0)
        in_sh = rules.sharding(
            ("batch",) + (None,) * (len(rng_struct.shape) - 1),
            dims=tuple(rng_struct.shape))
        n_data = rules.rule_axis_size("batch")
        if n_lanes % n_data != 0:
            # report what the fallback actually did: the rules shorten
            # the axis list before giving up, so on a multi-axis batch
            # rule (e.g. ("pod", "data")) the batch may still be
            # partially sharded rather than replicated
            got = in_sh.spec[0]
            actual = ("replicating the seed batch instead of sharding it"
                      if got is None else
                      f"sharding it only over {got!r} instead of the "
                      "full data extent")
            warnings.warn(
                f"sharded execution: batch*fanout={n_lanes} does not "
                f"divide the mesh's data extent ({n_data}); {actual} — "
                f"pad the lane count to a multiple of {n_data} for full "
                "fan-out", UserWarning, stacklevel=3)
        out_struct = jax.eval_shape(mapped, rng_struct)
        out_sh = jax.tree.map(
            lambda s: rules.sharding(
                ("batch",) + (None,) * (len(s.shape) - 1),
                dims=tuple(s.shape)), out_struct)
        jit_mapped = jax.jit(mapped, in_shardings=(in_sh,),
                             out_shardings=out_sh)

        def sharded_fn(rng):
            rngs = jax.device_put(lane_keys(rng), in_sh)
            return batch_from_mapped(jit_mapped(rngs))
        # introspection hooks (tests / benchmarks read these)
        sharded_fn.mesh = mesh
        sharded_fn.rules = rules
        sharded_fn.in_sharding = in_sh
        return sharded_fn

    # -- token domain ------------------------------------------------------
    def _build_token(self, spec, cfg_t, params_t, cfg_d, params_d,
                     mesh=None):
        """Route token serving through the continuous-batching
        ``repro.serving`` engine: ``spec.batch`` KV-cache slots serve
        however many prompts the call provides (a [N, P] prompt array
        with N > batch streams through the scheduler's queue).

        ONE ``ServingEngine`` lives for the whole life of the built
        sampler — repeated calls reset its scheduler/stats but reuse the
        allocated KV pools and every jitted round (the build-cache
        contract); a fresh engine per call would reallocate pools and
        re-dispatch compilations."""
        from ..serving import ServeRequest, ServingEngine
        from .result import SeqResult

        engine = ServingEngine(
            cfg_t, params_t, cfg_d, params_d, method=spec.method,
            max_batch=spec.batch, max_len=spec.max_len,
            gamma=spec.gamma, draft_policy=spec.draft_policy, mesh=mesh,
            kernel=spec.kernel, kv_layout=spec.kv_layout,
            sched=spec.sched,
            prefill_chunk=spec.prefill_chunk or None)

        def token_fn(rng, prompt):
            prompt = jnp.asarray(prompt, jnp.int32)
            # the real cache constraint is prompt + new tokens <= max_len
            # and is only knowable per call
            if prompt.shape[-1] + spec.max_events > spec.max_len:
                raise SpecError(
                    f"prompt length {prompt.shape[-1]} + max_events "
                    f"{spec.max_events} exceeds max_len {spec.max_len}")
            prompts = (prompt[None] if prompt.ndim == 1 else prompt)
            if prompt.ndim == 1 and spec.batch > 1 and spec.fanout == 1:
                # historical convenience: one prompt fills every slot.
                # With fanout > 1 the fan-out itself defines the rollout
                # count, so a single prompt stays a single group
                prompts = jnp.broadcast_to(
                    prompts, (spec.batch,) + prompts.shape[1:])
            n_req = prompts.shape[0]
            # force: a previous call that died mid-run must not brick
            # the sampler — its leftover requests belong to no caller
            engine.reset(force=True)
            # ALWAYS split (same contract as the TPP executors); with
            # fanout=K every prompt becomes one shared-prefix group of
            # K rollouts drawing from fold_in(base, k) — the engine
            # forks the admitted prompt's pages on the paged layout
            rngs = jax.random.split(rng, n_req)
            order = []
            for r, p in zip(rngs, prompts):
                ids = engine.submit(ServeRequest(
                    prompt=p, max_new_tokens=spec.max_events,
                    temperature=spec.temperature, rng=r),
                    fanout=spec.fanout)
                order.extend(ids if isinstance(ids, list) else [ids])
            by_id = {res.request_id: res for res in engine.run()}

            def to_seq(res) -> SeqResult:
                types = jnp.zeros((spec.max_events,), jnp.int32)
                n = min(res.n, spec.max_events)
                if n:
                    types = types.at[:n].set(
                        jnp.asarray(res.tokens[:n], jnp.int32))
                return SeqResult(jnp.zeros((spec.max_events,), jnp.float32),
                                 types, jnp.int32(n), jnp.int32(res.drafted),
                                 jnp.int32(res.accepted),
                                 jnp.int32(res.rounds))
            return stack_seqs([to_seq(by_id[rid]) for rid in order])
        token_fn.engine = engine   # introspection hook (tests assert reuse)
        return token_fn


# Module-level engine: one compilation cache per process.
ENGINE = SamplingEngine()


def build_sampler(spec: SamplerSpec, cfg_t, params_t, cfg_d=None,
                  params_d=None, mesh=None) -> Callable[..., SampleBatch]:
    return ENGINE.build(spec, cfg_t, params_t, cfg_d, params_d, mesh=mesh)


def sample(spec: SamplerSpec, cfg_t, params_t, rng, cfg_d=None,
           params_d=None, prompt=None, mesh=None) -> SampleBatch:
    return ENGINE.sample(spec, cfg_t, params_t, rng, cfg_d, params_d,
                         prompt=prompt, mesh=mesh)
