"""``SamplingEngine``: the one config-driven entrypoint for sampling.

    spec = SamplerSpec(method="sd", execution="vmap", t_end=20.0,
                       gamma=10, max_events=256, batch=64)
    fn = ENGINE.build(spec, cfg_t, params_t, cfg_d, params_d)
    batch = fn(jax.random.PRNGKey(0))        # -> SampleBatch
    print(batch.stats().describe())

Execution lowering:

  host    — python loop per sequence (paper-faithful sync-per-step),
            batch handled by splitting the seed on the host.
  jit     — the strategy's single-sequence lax.while_loop sampler; B=1.
  vmap    — jax.vmap of the jitted sampler over a split seed batch.
  sharded — vmap + the seed batch placed over the device mesh via the
            logical-axis rules in ``distributed/sharding.py`` ("batch"
            maps to the data axis, divisible-or-replicate fallback), so
            the same spec fans whole sequences out across devices.

Built callables are cached per (spec, model-bundle identity) so repeated
calls reuse compilations.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import strategies as _strategies  # noqa: F401  (registers builtin strategies)
from .registry import get_strategy
from .result import (SampleBatch, batch_from_mapped, batch_from_seq,
                     stack_seqs)
from .spec import SamplerSpec, SpecError
from .strategies import ModelBundle


def _data_mesh():
    """1-D mesh over every visible device: whole-sequence fan-out."""
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()), ("data",))


def _shard_rngs(rngs, mesh):
    """Place the seed batch over the mesh's data axis (replicate fallback
    when the batch does not divide the device count)."""
    from ..distributed.sharding import Rules
    rules = Rules(mesh)
    sh = rules.sharding(("batch", None), dims=tuple(rngs.shape))
    return jax.device_put(rngs, sh)


class SamplingEngine:
    """Builds spec-driven samplers; caches built callables.

    The cache is LRU-bounded: entries keep their params trees alive (the
    id-based key is only valid while the objects live), so an unbounded
    cache would pin every superseded checkpoint for process lifetime.
    """

    MAX_CACHED = 32

    def __init__(self):
        from collections import OrderedDict
        self._cache = OrderedDict()

    # -- TPP domain --------------------------------------------------------
    def build(self, spec: SamplerSpec, cfg_t, params_t, cfg_d=None,
              params_d=None) -> Callable[..., SampleBatch]:
        """Return ``fn(rng) -> SampleBatch`` for domain="tpp" specs, or
        ``fn(rng, prompt) -> SampleBatch`` for domain="token" specs."""
        spec.validate()
        if spec.requires_draft and (cfg_d is None or params_d is None):
            raise SpecError(f"method={spec.method!r} needs a draft model "
                            "(cfg_d, params_d)")
        key = (spec, id(cfg_t), id(params_t), id(cfg_d), id(params_d))
        if key not in self._cache:
            if spec.domain == "token":
                fn = self._build_token(spec, cfg_t, params_t, cfg_d, params_d)
            else:
                fn = self._build_tpp(spec, cfg_t, params_t, cfg_d, params_d)
            # keep the params alive alongside the closure (id keys are
            # only unique while the objects live)
            self._cache[key] = (fn, (cfg_t, params_t, cfg_d, params_d))
            while len(self._cache) > self.MAX_CACHED:
                self._cache.popitem(last=False)
        else:
            self._cache.move_to_end(key)
        return self._cache[key][0]

    def sample(self, spec: SamplerSpec, cfg_t, params_t, rng, cfg_d=None,
               params_d=None, prompt=None) -> SampleBatch:
        """One-shot convenience: build (cached) and call."""
        fn = self.build(spec, cfg_t, params_t, cfg_d, params_d)
        if spec.domain == "token":
            if prompt is None:
                raise SpecError("domain='token' sampling needs a prompt")
            return fn(rng, prompt)
        return fn(rng)

    def _build_tpp(self, spec, cfg_t, params_t, cfg_d, params_d):
        strat = get_strategy(spec.method)
        bundle = ModelBundle(cfg_t, params_t, cfg_d, params_d)

        if spec.requires_draft and spec.execution != "host":
            from .policies import resolve_policy
            if not resolve_policy(spec).is_static:
                raise SpecError(
                    f"draft_policy={spec.draft_policy!r} adapts gamma "
                    "between rounds; the device executors need a static "
                    "window — use execution='host'")

        if spec.execution == "host":
            single = strat.build_host(spec, bundle)

            def host_fn(rng):
                rngs = (jax.random.split(rng, spec.batch)
                        if spec.batch > 1 else [rng])
                return stack_seqs([single(r) for r in rngs])
            return host_fn

        single = strat.build_device(spec, bundle)
        if single is None:
            raise SpecError(f"method={spec.method!r} has no device "
                            "execution; use execution='host'")
        if spec.execution == "jit":
            return lambda rng: batch_from_seq(single(rng))

        mapped = jax.vmap(single)
        if spec.execution == "vmap":
            return lambda rng: batch_from_mapped(
                mapped(jax.random.split(rng, spec.batch)))

        # sharded: vmap + seed batch placed over the device mesh; GSPMD
        # propagates the batch partitioning through the whole loop.
        mesh = _data_mesh()
        jit_mapped = jax.jit(mapped)

        def sharded_fn(rng):
            rngs = _shard_rngs(jax.random.split(rng, spec.batch), mesh)
            return batch_from_mapped(jit_mapped(rngs))
        return sharded_fn

    # -- token domain ------------------------------------------------------
    def _build_token(self, spec, cfg_t, params_t, cfg_d, params_d):
        """Route token serving through the continuous-batching
        ``repro.serving`` engine: ``spec.batch`` KV-cache slots serve
        however many prompts the call provides (a [N, P] prompt array
        with N > batch streams through the scheduler's queue)."""
        from ..serving import ServeRequest, ServingEngine
        from .result import SeqResult

        def token_fn(rng, prompt):
            prompt = jnp.asarray(prompt, jnp.int32)
            # the real cache constraint is prompt + new tokens <= max_len
            # and is only knowable per call
            if prompt.shape[-1] + spec.max_events > spec.max_len:
                raise SpecError(
                    f"prompt length {prompt.shape[-1]} + max_events "
                    f"{spec.max_events} exceeds max_len {spec.max_len}")
            prompts = (prompt[None] if prompt.ndim == 1 else prompt)
            if prompt.ndim == 1 and spec.batch > 1:
                prompts = jnp.broadcast_to(
                    prompts, (spec.batch,) + prompts.shape[1:])
            n_req = prompts.shape[0]
            engine = ServingEngine(
                cfg_t, params_t, cfg_d, params_d, method=spec.method,
                max_batch=spec.batch, max_len=spec.max_len,
                gamma=spec.gamma, draft_policy=spec.draft_policy)
            rngs = (jax.random.split(rng, n_req) if n_req > 1 else [rng])
            order = [engine.submit(ServeRequest(
                prompt=p, max_new_tokens=spec.max_events,
                temperature=spec.temperature, rng=r))
                for r, p in zip(rngs, prompts)]
            by_id = {res.request_id: res for res in engine.run()}

            def to_seq(res) -> SeqResult:
                types = jnp.zeros((spec.max_events,), jnp.int32)
                n = min(res.n, spec.max_events)
                if n:
                    types = types.at[:n].set(
                        jnp.asarray(res.tokens[:n], jnp.int32))
                return SeqResult(jnp.zeros((spec.max_events,), jnp.float32),
                                 types, jnp.int32(n), jnp.int32(res.drafted),
                                 jnp.int32(res.accepted),
                                 jnp.int32(res.rounds))
            return stack_seqs([to_seq(by_id[rid]) for rid in order])
        return token_fn


# Module-level engine: one compilation cache per process.
ENGINE = SamplingEngine()


def build_sampler(spec: SamplerSpec, cfg_t, params_t, cfg_d=None,
                  params_d=None) -> Callable[..., SampleBatch]:
    return ENGINE.build(spec, cfg_t, params_t, cfg_d, params_d)


def sample(spec: SamplerSpec, cfg_t, params_t, rng, cfg_d=None,
           params_d=None, prompt=None) -> SampleBatch:
    return ENGINE.sample(spec, cfg_t, params_t, rng, cfg_d, params_d,
                         prompt=prompt)
