"""``SamplingEngine``: the one config-driven entrypoint for sampling.

    spec = SamplerSpec(method="sd", execution="vmap", t_end=20.0,
                       gamma=10, max_events=256, batch=64)
    fn = ENGINE.build(spec, cfg_t, params_t, cfg_d, params_d)
    batch = fn(jax.random.PRNGKey(0))        # -> SampleBatch
    print(batch.stats().describe())

Execution lowering:

  host    — python loop per sequence (paper-faithful sync-per-step),
            batch handled by splitting the seed on the host.
  jit     — the strategy's single-sequence lax.while_loop sampler; B=1.
  vmap    — jax.vmap of the jitted sampler over a split seed batch.
  sharded — vmap + the seed batch placed over the device mesh via the
            logical-axis rules in ``distributed/sharding.py`` ("batch"
            maps to the data axis, divisible-or-replicate fallback), so
            the same spec fans whole sequences out across devices.

Built callables are cached per (spec, model-bundle identity) so repeated
calls reuse compilations.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import strategies as _strategies  # noqa: F401  (registers builtin strategies)
from .registry import get_strategy
from .result import (SampleBatch, batch_from_mapped, batch_from_seq,
                     stack_seqs)
from .spec import SamplerSpec, SpecError
from .strategies import ModelBundle, TokenBundle


def _data_mesh():
    """1-D mesh over every visible device: whole-sequence fan-out."""
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()), ("data",))


def _shard_rngs(rngs, mesh):
    """Place the seed batch over the mesh's data axis (replicate fallback
    when the batch does not divide the device count)."""
    from ..distributed.sharding import Rules
    rules = Rules(mesh)
    sh = rules.sharding(("batch", None), dims=tuple(rngs.shape))
    return jax.device_put(rngs, sh)


class SamplingEngine:
    """Builds spec-driven samplers; caches built callables.

    The cache is LRU-bounded: entries keep their params trees alive (the
    id-based key is only valid while the objects live), so an unbounded
    cache would pin every superseded checkpoint for process lifetime.
    """

    MAX_CACHED = 32

    def __init__(self):
        from collections import OrderedDict
        self._cache = OrderedDict()

    # -- TPP domain --------------------------------------------------------
    def build(self, spec: SamplerSpec, cfg_t, params_t, cfg_d=None,
              params_d=None) -> Callable[..., SampleBatch]:
        """Return ``fn(rng) -> SampleBatch`` for domain="tpp" specs, or
        ``fn(rng, prompt) -> SampleBatch`` for domain="token" specs."""
        spec.validate()
        if spec.requires_draft and (cfg_d is None or params_d is None):
            raise SpecError(f"method={spec.method!r} needs a draft model "
                            "(cfg_d, params_d)")
        key = (spec, id(cfg_t), id(params_t), id(cfg_d), id(params_d))
        if key not in self._cache:
            if spec.domain == "token":
                fn = self._build_token(spec, cfg_t, params_t, cfg_d, params_d)
            else:
                fn = self._build_tpp(spec, cfg_t, params_t, cfg_d, params_d)
            # keep the params alive alongside the closure (id keys are
            # only unique while the objects live)
            self._cache[key] = (fn, (cfg_t, params_t, cfg_d, params_d))
            while len(self._cache) > self.MAX_CACHED:
                self._cache.popitem(last=False)
        else:
            self._cache.move_to_end(key)
        return self._cache[key][0]

    def sample(self, spec: SamplerSpec, cfg_t, params_t, rng, cfg_d=None,
               params_d=None, prompt=None) -> SampleBatch:
        """One-shot convenience: build (cached) and call."""
        fn = self.build(spec, cfg_t, params_t, cfg_d, params_d)
        if spec.domain == "token":
            if prompt is None:
                raise SpecError("domain='token' sampling needs a prompt")
            return fn(rng, prompt)
        return fn(rng)

    def _build_tpp(self, spec, cfg_t, params_t, cfg_d, params_d):
        strat = get_strategy(spec.method)
        bundle = ModelBundle(cfg_t, params_t, cfg_d, params_d)

        if spec.execution == "host":
            single = strat.build_host(spec, bundle)

            def host_fn(rng):
                rngs = (jax.random.split(rng, spec.batch)
                        if spec.batch > 1 else [rng])
                return stack_seqs([single(r) for r in rngs])
            return host_fn

        single = strat.build_device(spec, bundle)
        if single is None:
            raise SpecError(f"method={spec.method!r} has no device "
                            "execution; use execution='host'")
        if spec.execution == "jit":
            return lambda rng: batch_from_seq(single(rng))

        mapped = jax.vmap(single)
        if spec.execution == "vmap":
            return lambda rng: batch_from_mapped(
                mapped(jax.random.split(rng, spec.batch)))

        # sharded: vmap + seed batch placed over the device mesh; GSPMD
        # propagates the batch partitioning through the whole loop.
        mesh = _data_mesh()
        jit_mapped = jax.jit(mapped)

        def sharded_fn(rng):
            rngs = _shard_rngs(jax.random.split(rng, spec.batch), mesh)
            return batch_from_mapped(jit_mapped(rngs))
        return sharded_fn

    # -- token domain ------------------------------------------------------
    def _build_token(self, spec, cfg_t, params_t, cfg_d, params_d):
        from ..models import registry as model_registry
        model_t = model_registry.get_model(cfg_t)
        model_d = (model_registry.get_model(cfg_d)
                   if cfg_d is not None else None)
        strat = get_strategy(f"llm_{spec.method}")
        bundle = TokenBundle(cfg_t, params_t, model_t, cfg_d, params_d,
                             model_d)
        single = strat.build_host(spec, bundle)

        def token_fn(rng, prompt):
            prompt = jnp.asarray(prompt, jnp.int32)
            # the real cache constraint is prompt + new tokens <= max_len
            # and is only knowable per call
            if prompt.shape[-1] + spec.max_events > spec.max_len:
                raise SpecError(
                    f"prompt length {prompt.shape[-1]} + max_events "
                    f"{spec.max_events} exceeds max_len {spec.max_len}")
            if spec.batch == 1 and prompt.ndim == 1:
                return stack_seqs([single(rng, prompt)])
            prompts = (prompt if prompt.ndim == 2
                       else jnp.broadcast_to(prompt, (spec.batch,)
                                             + prompt.shape))
            rngs = jax.random.split(rng, prompts.shape[0])
            return stack_seqs([single(r, p)
                               for r, p in zip(rngs, prompts)])
        return token_fn


# Module-level engine: one compilation cache per process.
ENGINE = SamplingEngine()


def build_sampler(spec: SamplerSpec, cfg_t, params_t, cfg_d=None,
                  params_d=None) -> Callable[..., SampleBatch]:
    return ENGINE.build(spec, cfg_t, params_t, cfg_d, params_d)


def sample(spec: SamplerSpec, cfg_t, params_t, rng, cfg_d=None,
           params_d=None, prompt=None) -> SampleBatch:
    return ENGINE.sample(spec, cfg_t, params_t, rng, cfg_d, params_d,
                         prompt=prompt)
