"""Draft policies: how many events the draft model proposes per round.

The jitted SD loop needs a *static* window length per compiled round, so a
policy exposes ``round_gamma(round_idx)``; FixedGamma returns a constant
(the paper's setting). An adaptive-gamma policy (Leviathan et al. 2023's
lenience analysis, or acceptance-rate feedback) plugs in here by returning
a schedule — the engine compiles one round per distinct gamma and the host
executor can follow the schedule exactly.
"""
from __future__ import annotations

from dataclasses import dataclass

from .registry import register_draft_policy


class DraftPolicy:
    """Interface: per-round draft window length."""

    def round_gamma(self, round_idx: int) -> int:
        raise NotImplementedError

    @property
    def max_gamma(self) -> int:
        """Upper bound on any round's gamma (sizes the fixed buffers)."""
        raise NotImplementedError

    @property
    def is_static(self) -> bool:
        """True if every round uses the same gamma (single compilation)."""
        return False


@register_draft_policy("fixed")
@dataclass(frozen=True)
class FixedGamma(DraftPolicy):
    """The paper's policy: a constant draft window."""
    gamma: int

    def round_gamma(self, round_idx: int) -> int:
        return self.gamma

    @property
    def max_gamma(self) -> int:
        return self.gamma

    @property
    def is_static(self) -> bool:
        return True


def resolve_policy(spec) -> DraftPolicy:
    """Instantiate the spec's draft policy (today: name -> cls(gamma))."""
    from .registry import get_draft_policy
    cls = get_draft_policy(spec.draft_policy)
    return cls(spec.gamma)
