"""Draft policies: how many events/tokens the draft model proposes per
propose-verify round.

A policy is a small pure-functional state machine driven by the HOST
executor (the jitted round body needs a *static* window length, so the
executor compiles one round per distinct gamma and follows the policy's
schedule between device calls):

    state = policy.init_state()
    g = policy.gamma(state)           # window for the next round
    ... run one round with window g ...
    state = policy.update(state, drafted=g, accepted=A)

``FixedGamma`` (the paper's setting) is static — every round uses the
same window, so the device executors (jit/vmap/sharded) can close over
it. ``AdaptiveGamma`` applies Leviathan et al. (2023)'s acceptance
feedback — grow the window after a fully-accepted round, shrink it
after an early rejection — and is therefore host-only.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from .registry import register_draft_policy


class DraftPolicy:
    """Interface: per-round draft window length (host-driven schedule)."""

    # -- stateful schedule (what executors drive) -------------------------
    def init_state(self) -> Any:
        return None

    def gamma(self, state) -> int:
        """Window length for the next round given the policy state."""
        return self.round_gamma(0)

    def update(self, state, drafted: int, accepted: int) -> Any:
        """Fold one round's acceptance outcome into the state."""
        return state

    # -- static views ------------------------------------------------------
    def round_gamma(self, round_idx: int) -> int:
        raise NotImplementedError

    @property
    def max_gamma(self) -> int:
        """Upper bound on any round's gamma (sizes the fixed buffers)."""
        raise NotImplementedError

    @property
    def is_static(self) -> bool:
        """True if every round uses the same gamma (single compilation)."""
        return False


@register_draft_policy("fixed")
@dataclass(frozen=True)
class FixedGamma(DraftPolicy):
    """The paper's policy: a constant draft window."""
    gamma_value: int

    def round_gamma(self, round_idx: int) -> int:
        return self.gamma_value

    def gamma(self, state) -> int:
        return self.gamma_value

    @property
    def max_gamma(self) -> int:
        return self.gamma_value

    @property
    def is_static(self) -> bool:
        return True


@register_draft_policy("adaptive")
@dataclass(frozen=True)
class AdaptiveGamma(DraftPolicy):
    """Acceptance-feedback window (Leviathan et al. 2023, App. on
    choosing gamma): after a round where every draft was accepted the
    window grows by one; after a round with a rejection it shrinks by
    one. ``gamma_value`` caps the window (and sizes the fixed buffers);
    the schedule starts halfway up.

    Adapting gamma never biases the output: the window length of round t
    depends only on rounds < t, and speculative verification is exact
    for every window length, so the sampled distribution stays equal to
    target AR sampling for any schedule.
    """
    gamma_value: int

    def init_state(self) -> int:
        return max(1, (self.gamma_value + 1) // 2)

    def gamma(self, state) -> int:
        return int(min(max(1, state), self.gamma_value))

    def update(self, state, drafted: int, accepted: int) -> int:
        if drafted and accepted >= drafted:
            return min(self.gamma_value, state + 1)
        return max(1, state - 1)

    def round_gamma(self, round_idx: int) -> int:
        return self.init_state()

    @property
    def max_gamma(self) -> int:
        return self.gamma_value

    @property
    def is_static(self) -> bool:
        return self.gamma_value == 1


def resolve_policy_by_name(name: str, gamma: int) -> DraftPolicy:
    """Registry lookup + instantiation (name -> cls(gamma))."""
    from .registry import get_draft_policy
    return get_draft_policy(name)(gamma)


def resolve_policy(spec) -> DraftPolicy:
    """Instantiate the spec's draft policy."""
    return resolve_policy_by_name(spec.draft_policy, spec.gamma)
