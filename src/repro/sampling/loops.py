"""Single-sequence sampling loops (AR — Sec. 4.2; TPP-SD — Sec. 4.3 /
Algorithm 1) plus the shared state-init / finalize helpers that the
host and device execution paths both build on.

Two execution styles share each loop body:

  - host  : python loop, one jitted model call (and one device sync) per
    event / per propose-verify round — the paper-faithful style.
  - device: the whole loop inside one ``lax.while_loop`` (fixed shapes,
    cache rollback by counter) so a full sequence is one device call and
    ``jax.vmap`` batches whole sequences with per-lane lengths.

Everything here operates on a single sequence; the engine's executors
(``engine.py``) handle batching, sharding, and result packaging.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from ..core import speculative as spec
from ..models import tpp
from .result import SeqResult


def bos_event(cfg):
    """Algorithm 1's initial (t_0, k_0): t=0 with the BOS sentinel mark."""
    return jnp.float32(0.0), jnp.int32(cfg.num_marks)


def sample_event(cfg, params, rng, h, t_cur):
    """Draw the next (t, k) from the model heads at history embedding h."""
    r1, r2 = jax.random.split(rng)
    mix = tpp.interval_params(cfg, params, h)
    tau = tpp.sample_interval(r1, mix)
    logits = tpp.type_logits(cfg, params, h)
    k = jax.random.categorical(r2, logits)
    return t_cur + tau, k.astype(jnp.int32)


def event_buffers(size: int):
    """Zeroed fixed-shape (times, types) buffers."""
    return jnp.zeros((size,), jnp.float32), jnp.zeros((size,), jnp.int32)


def finalize_seq(times, types, n, t_end: float, max_events: int,
                 drafted, accepted, rounds) -> SeqResult:
    """Shared epilogue of every loop: count events with ordinal < n that
    landed inside the horizon, truncate buffers to ``max_events``."""
    E = times.shape[0]
    n_eff = jnp.minimum(n, max_events)
    valid = jnp.sum((jnp.arange(E) < n_eff) & (times <= t_end)
                    ).astype(jnp.int32)
    return SeqResult(times[:max_events], types[:max_events], valid,
                     jnp.asarray(drafted, jnp.int32),
                     jnp.asarray(accepted, jnp.int32),
                     jnp.asarray(rounds, jnp.int32))


# ---------------------------------------------------------------------------
# autoregressive sampling
# ---------------------------------------------------------------------------

class ARState(NamedTuple):
    times: jnp.ndarray
    types: jnp.ndarray
    n: jnp.ndarray
    t_last: jnp.ndarray
    h: jnp.ndarray
    cache: dict
    rng: jnp.ndarray


def init_ar_state(cfg, params, rng, max_events: int) -> ARState:
    """Seed the AR loop: BOS in the cache, empty event buffers."""
    t0, k0 = bos_event(cfg)
    cache = tpp.init_cache(cfg, max_events + 2)
    h, cache = tpp.extend(cfg, params, cache, t0[None], k0[None])
    times, types = event_buffers(max_events)
    return ARState(times, types, jnp.int32(0), t0, h[0], cache, rng)


def ar_step(cfg, params, s: ARState) -> ARState:
    """One committed event: sample from the heads, ingest into the cache."""
    rng, r = jax.random.split(s.rng)
    t_new, k_new = sample_event(cfg, params, r, s.h, s.t_last)
    h, cache = tpp.extend(cfg, params, s.cache, t_new[None], k_new[None])
    times = s.times.at[s.n].set(t_new)
    types = s.types.at[s.n].set(k_new)
    return ARState(times, types, s.n + 1, t_new, h[0], cache, rng)


@functools.partial(jax.jit, static_argnums=(0, 3, 4))
def run_ar_device(cfg, params, rng, t_end: float, max_events: int
                  ) -> SeqResult:
    def cond(s: ARState):
        return jnp.logical_and(s.t_last < t_end, s.n < max_events)

    s = lax.while_loop(cond, functools.partial(ar_step, cfg, params),
                       init_ar_state(cfg, params, rng, max_events))
    return finalize_seq(s.times, s.types, s.n, t_end, max_events,
                        jnp.int32(0), jnp.int32(0), s.n)


def run_ar_host(cfg, params, rng, t_end: float, max_events: int,
                step=None) -> SeqResult:
    """Paper-style host loop: one jitted step (and one host sync) per
    generated event.

    Pass a prebuilt ``step`` (jitted ``ar_step`` closure) to reuse its
    compilation across calls — the engine's strategies do."""
    if step is None:
        step = jax.jit(functools.partial(ar_step, cfg, params))
    s = init_ar_state(cfg, params, rng, max_events)
    while float(s.t_last) < t_end and int(s.n) < max_events:
        s = step(s)
    return finalize_seq(s.times, s.types, s.n, t_end, max_events,
                        jnp.int32(0), jnp.int32(0), s.n)


# ---------------------------------------------------------------------------
# TPP-SD (Algorithm 1)
# ---------------------------------------------------------------------------

class SDState(NamedTuple):
    times: jnp.ndarray
    types: jnp.ndarray
    n: jnp.ndarray
    t_pend: jnp.ndarray
    k_pend: jnp.ndarray
    cache_t: dict
    cache_d: dict
    rng: jnp.ndarray
    drafted: jnp.ndarray
    accepted: jnp.ndarray
    rounds: jnp.ndarray


def init_sd_state(cfg_t, cfg_d, rng, gamma: int, max_events: int) -> SDState:
    """Seed the SD loop: BOS pending, both caches empty, buffers sized so
    one full window past ``max_events`` still fits before truncation."""
    t0, k0 = bos_event(cfg_t)
    cache_size = max_events + gamma + 2
    times, types = event_buffers(max_events + gamma + 1)
    return SDState(times, types, jnp.int32(0), t0, k0,
                   tpp.init_cache(cfg_t, cache_size),
                   tpp.init_cache(cfg_d, cache_size),
                   rng, jnp.int32(0), jnp.int32(0), jnp.int32(0))


def draft_window(cfg_d, params_d, rng, cache_d, t_pend, k_pend, gamma):
    """Draft gamma events autoregressively; record densities (Alg.1 l.4-6).

    The pending event is ingested first (it is committed but not yet in
    either cache).
    """
    h, cache_d = tpp.extend(cfg_d, params_d, cache_d, t_pend[None],
                            k_pend[None])

    def step(carry, r):
        h, cache_d, t_cur = carry
        r1, r2 = jax.random.split(r)
        mix = tpp.interval_params(cfg_d, params_d, h)
        tau = tpp.sample_interval(r1, mix)
        logits = jax.nn.log_softmax(tpp.type_logits(cfg_d, params_d, h))
        k = jax.random.categorical(r2, logits).astype(jnp.int32)
        t_new = t_cur + tau
        h2, cache_d = tpp.extend(cfg_d, params_d, cache_d, t_new[None],
                                 k[None])
        out = (tau, k, t_new, mix.log_w, mix.mu, mix.sigma, logits)
        return (h2[0], cache_d, t_new), out

    (h_last, cache_d, _), outs = lax.scan(
        step, (h[0], cache_d, t_pend), jax.random.split(rng, gamma))
    d_tau, d_k, d_t, d_logw, d_mu, d_sigma, d_logits = outs
    d_mix = tpp.MixParams(d_logw, d_mu, d_sigma)
    return cache_d, d_tau, d_k, d_t, d_mix, d_logits


def sd_round(cfg_t, cfg_d, params_t, params_d, gamma, s: SDState) -> SDState:
    """One propose-verify round of Algorithm 1.

    The target's verify forward (``tpp.extend`` with c = gamma+1) and
    the gamma x M accept-ratio densities route through the configs'
    kernel policies — with a Pallas policy the verify attention is the
    ``spec_verify_attention`` multi-query kernel and the densities the
    fused log-normal-mixture kernels."""
    pol_t, pol_d = tpp.resolve_policy(cfg_t), tpp.resolve_policy(cfg_d)
    rng, r_draft, r_ver, r_new1, r_new2, r_new3 = jax.random.split(s.rng, 6)
    # --- draft ---
    cache_d, d_tau, d_k, d_t, d_mix, d_logits = draft_window(
        cfg_d, params_d, r_draft, s.cache_d, s.t_pend, s.k_pend, gamma)
    # --- verify: target processes pending + drafts in ONE parallel forward
    ver_t = jnp.concatenate([s.t_pend[None], d_t])
    ver_k = jnp.concatenate([s.k_pend[None], d_k])
    h_t, cache_t = tpp.extend(cfg_t, params_t, s.cache_t, ver_t, ver_k)
    mix_t_all = tpp.interval_params(cfg_t, params_t, h_t)     # [g+1, M]
    logits_t_all = jax.nn.log_softmax(
        tpp.type_logits(cfg_t, params_t, h_t))                # [g+1, K]
    mix_hist = jax.tree.map(lambda x: x[:gamma], mix_t_all)
    res = spec.verify_events(r_ver, d_tau, d_k,
                             tpp.interval_logpdf(d_mix, d_tau,
                                                 policy=pol_d),
                             d_logits, mix_hist, logits_t_all[:gamma],
                             policy=pol_t)
    A, all_acc = res.num_accepted, res.all_accepted
    Ac = jnp.minimum(A, gamma - 1)

    # --- replacement / bonus event from h at the first non-accepted slot
    mix_A = jax.tree.map(lambda x: x[A], mix_t_all)
    logits_A = logits_t_all[A]
    d_mix_A = jax.tree.map(lambda x: x[Ac], d_mix)
    tau_adj = spec.adjusted_continuous(r_new1, mix_A, d_mix_A)
    tau_direct = tpp.sample_interval(r_new2, mix_A)
    new_tau = jnp.where(all_acc, tau_direct,
                        jnp.where(res.tau_rejected, tau_adj, d_tau[Ac]))
    k_adj = spec.adjusted_discrete(r_new3, logits_A, d_logits[Ac])
    k_direct = jax.random.categorical(jax.random.fold_in(r_new3, 1),
                                      logits_A).astype(jnp.int32)
    new_k = jnp.where(all_acc | res.tau_rejected, k_direct,
                      k_adj.astype(jnp.int32))
    base_t = jnp.where(A > 0, d_t[jnp.maximum(A - 1, 0)], s.t_pend)
    new_t = base_t + new_tau

    # --- commit accepted prefix + the new event
    g_idx = jnp.arange(gamma)
    idx = s.n + g_idx
    times = s.times.at[idx].set(
        jnp.where(g_idx < A, d_t, s.times[idx]))
    types = s.types.at[idx].set(
        jnp.where(g_idx < A, d_k, s.types[idx]))
    times = times.at[s.n + A].set(new_t)
    types = types.at[s.n + A].set(new_k)
    n_new = s.n + A + 1

    # --- cache rollback (mask-by-counter; cache length invariant == n)
    cache_t = tpp.rollback(cache_t, n_new)
    cache_d = tpp.rollback(cache_d, n_new)
    return SDState(times, types, n_new, new_t, new_k, cache_t, cache_d,
                   rng, s.drafted + gamma, s.accepted + A, s.rounds + 1)


@functools.partial(jax.jit, static_argnums=(0, 1, 5, 6, 7))
def run_sd_device(cfg_t, cfg_d, params_t, params_d, rng, t_end: float,
                  gamma: int, max_events: int) -> SeqResult:
    def cond(s: SDState):
        return jnp.logical_and(s.t_pend < t_end, s.n < max_events)

    body = functools.partial(sd_round, cfg_t, cfg_d, params_t, params_d,
                             gamma)
    s = lax.while_loop(cond, body,
                       init_sd_state(cfg_t, cfg_d, rng, gamma, max_events))
    return finalize_seq(s.times, s.types, s.n, t_end, max_events,
                        s.drafted, s.accepted, s.rounds)


def run_sd_host(cfg_t, cfg_d, params_t, params_d, rng, t_end: float,
                gamma: int, max_events: int, round_fn=None) -> SeqResult:
    """Paper-faithful host loop: one device sync per propose-verify round.

    Uses the SAME jitted round function as the device path, so with an
    identical rng the two paths produce identical sequences. Pass a
    prebuilt ``round_fn`` (jitted ``sd_round`` closure) to reuse its
    compilation across calls — the engine's strategies do."""
    if round_fn is None:
        round_fn = jax.jit(functools.partial(sd_round, cfg_t, cfg_d,
                                             params_t, params_d, gamma))
    s = init_sd_state(cfg_t, cfg_d, rng, gamma, max_events)
    while float(s.t_pend) < t_end and int(s.n) < max_events:
        s = round_fn(s)
    return finalize_seq(s.times, s.types, s.n, t_end, max_events,
                        s.drafted, s.accepted, s.rounds)


def run_sd_host_schedule(cfg_t, cfg_d, params_t, params_d, rng, t_end: float,
                         policy, max_events: int, round_fn_for) -> SeqResult:
    """Host SD loop following a draft policy's per-round gamma schedule
    (adaptive window — Leviathan et al. 2023 acceptance feedback).

    Buffers and caches are sized by ``policy.max_gamma`` so every
    compiled round (one per distinct gamma, via ``round_fn_for``) shares
    the same state shapes. Adapting gamma between rounds cannot bias the
    output: round t's window depends only on rounds < t and verification
    is exact for every window length.
    """
    s = init_sd_state(cfg_t, cfg_d, rng, policy.max_gamma, max_events)
    state = policy.init_state()
    while float(s.t_pend) < t_end and int(s.n) < max_events:
        gamma = policy.gamma(state)
        drafted0, accepted0 = int(s.drafted), int(s.accepted)
        s = round_fn_for(gamma)(s)
        state = policy.update(state, int(s.drafted) - drafted0,
                              int(s.accepted) - accepted0)
    return finalize_seq(s.times, s.types, s.n, t_end, max_events,
                        s.drafted, s.accepted, s.rounds)


# ---------------------------------------------------------------------------
# neural CIF thinning (App. D.1 baseline)
# ---------------------------------------------------------------------------

def run_thinning_host(cfg, params, rng, t_end: float, max_events: int, *,
                      safety: float = 2.0, grid: int = 8,
                      horizon: float = 2.0) -> SeqResult:
    """Wrap the App. D.1 thinning baseline into the unified result shape:
    ``drafted`` = proposals, ``accepted`` = kept events, ``rounds`` =
    target forwards (so events_per_forward stays the comparable stat)."""
    from ..core import cif_thinning
    r = cif_thinning.sample_thinning_host(cfg, params, rng, t_end,
                                          max_events, safety=safety,
                                          grid=grid, horizon=horizon)
    return SeqResult(r.times, r.types, r.n, r.proposals, r.n, r.forwards)
