"""``SamplerSpec``: one frozen, hashable config describing a sampling run.

The spec replaces the combinatorial ``sample_{ar,sd}_{host,jit,batch}``
function zoo: method x execution are orthogonal axes, and a spec can be
closed over by jitted functions (frozen dataclass => hashable static arg).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

METHODS = ("ar", "sd", "thinning")
EXECUTIONS = ("host", "jit", "vmap", "sharded")
DOMAINS = ("tpp", "token")
KERNELS = ("auto", "pallas", "ref")
KV_LAYOUTS = ("auto", "paged", "dense")
SCHEDS = ("fifo", "priority", "sjf", "grouped")


class SpecError(ValueError):
    """Invalid ``SamplerSpec`` combination."""


@dataclass(frozen=True)
class ForecastSpec:
    """Long-horizon forecast workload riding a ``SamplerSpec``.

    Attach via ``SamplerSpec(domain="tpp", forecast=ForecastSpec(...))``
    and hand the spec to ``repro.forecast.build_forecaster``: the engine
    samples ``n_rollouts`` continuations of one shared event history in
    pool-sized waves and reduces them on device to per-time-bin event
    count quantiles. ``horizon`` is the forecast window beyond the last
    observed event; the per-rollout event budget and cutoff come from
    the carrying spec's ``max_events``/``t_end`` machinery (the request
    supplies its own absolute ``t_end = t_last + horizon``).
    """

    horizon: float = 10.0
    n_rollouts: int = 1000
    bins: int = 20
    quantiles: tuple = (0.1, 0.25, 0.5, 0.75, 0.9)

    def validate(self) -> "ForecastSpec":
        if self.horizon <= 0:
            raise SpecError(f"forecast horizon must be > 0, "
                            f"got {self.horizon}")
        if self.n_rollouts < 1:
            raise SpecError(f"forecast n_rollouts must be >= 1, "
                            f"got {self.n_rollouts}")
        if self.bins < 1:
            raise SpecError(f"forecast bins must be >= 1, got {self.bins}")
        if not self.quantiles:
            raise SpecError("forecast needs at least one quantile level")
        for q in self.quantiles:
            if not 0.0 <= q <= 1.0:
                raise SpecError(f"forecast quantile {q} outside [0, 1]")
        return self


@dataclass(frozen=True)
class SamplerSpec:
    """What to sample and how to execute it.

    method     : "ar" (autoregressive), "sd" (TPP-SD, Algorithm 1) or
                 "thinning" (neural CIF thinning, App. D.1 baseline).
    execution  : "host"    — python loop, one device sync per step/round
                 "jit"     — whole loop in one lax.while_loop device call
                 "vmap"    — jit + jax.vmap over a batch of seeds
                 "sharded" — vmap on a real device mesh: params placed by
                             the model's logical axes (launch/mesh.py
                             meshes + distributed/sharding.py rules), the
                             seed batch sharded over the data axis, and
                             the loop jitted with explicit in/out
                             shardings (multi-device fan-out; pass
                             ``mesh=`` to ``build_sampler`` to override
                             the resolved default)
    batch      : number of sequences (ignored for execution="jit": 1).
                 For domain="token" this is the serving engine's
                 ``max_batch`` — the number of KV-cache slots the
                 continuous-batching scheduler fills.
    fanout     : scenario rollouts per base lane (K-way fan-out for
                 forecasting queries). Every executor derives the K
                 streams of base lane ``b`` as
                 ``fold_in(split(rng, batch)[b], k)`` — so TPP runs
                 sample ``batch * fanout`` sequences, and token runs
                 submit each prompt to the serving engine with
                 ``fanout=K`` (one shared-prefix group whose members
                 FORK the admitted prompt's KV pages on the paged
                 layout). fanout never changes any member's sampled
                 distribution — member k is bitwise the fanout=1 run
                 seeded with its folded key; only the prefill cost
                 changes.
    gamma      : draft window length for method="sd" (the max window for
                 adaptive policies).
    draft_policy: name in the draft-policy registry — "fixed" (the
                 paper's constant window) or "adaptive" (acceptance-rate
                 feedback, host execution only).
    domain     : "tpp" (continuous-time event sequences) or "token" (the
                 discrete LLM special case served through
                 ``repro.serving``); for "token", max_events is the
                 max-new-tokens budget and t_end is ignored.
    """

    method: str = "sd"
    execution: str = "jit"
    t_end: float = 20.0
    max_events: int = 256
    batch: int = 1
    fanout: int = 1
    gamma: int = 10
    draft_policy: str = "fixed"
    domain: str = "tpp"
    # kernel policy: "auto" = Pallas compiled on TPU; off-TPU the token
    # domain runs Pallas in interpret mode while the TPP executors keep
    # the reference (a vmapped interpret kernel serializes the lane
    # batch). "pallas"/"ref" force a backend for every execution.
    kernel: str = "auto"
    # token-domain knobs
    max_len: int = 256
    temperature: float = 1.0
    # KV layout of the serving engine backing domain="token": "auto"
    # resolves to the paged block-table pool whenever the families
    # support it, falling back to the dense per-slot pool
    kv_layout: str = "auto"
    # admission policy of the serving scheduler ("fifo" is bitwise the
    # historical behavior; "priority" ranks on ServeRequest.priority
    # with aging, "sjf" shortest-job-first). Never changes any
    # request's sampled distribution (per-request rng) — only admission
    # order/latency.
    sched: str = "fifo"
    # stream prompts into the paged pool in chunks of this many tokens
    # (0 = disabled: the dense-staging admission prefill)
    prefill_chunk: int = 0
    # long-horizon forecast workload: TPP-only, runs the request through
    # the SERVING engine (wave-scheduled fan-out) instead of the batch
    # samplers — which is why a forecast spec may also carry the serving
    # knobs (sched/kv_layout/prefill_chunk) that plain TPP specs reject
    forecast: Optional[ForecastSpec] = None
    # thinning-only knobs (App. D.1 adaptive bound)
    thinning_safety: float = 2.0
    thinning_grid: int = 8
    thinning_horizon: float = 2.0

    def replace(self, **kw) -> "SamplerSpec":
        return dataclasses.replace(self, **kw)

    def validate(self) -> "SamplerSpec":
        """Raise ``SpecError`` on an invalid combination; return self."""
        if self.method not in METHODS:
            raise SpecError(f"unknown method {self.method!r}; "
                            f"expected one of {METHODS}")
        if self.execution not in EXECUTIONS:
            raise SpecError(f"unknown execution {self.execution!r}; "
                            f"expected one of {EXECUTIONS}")
        if self.domain not in DOMAINS:
            raise SpecError(f"unknown domain {self.domain!r}; "
                            f"expected one of {DOMAINS}")
        if self.kernel not in KERNELS:
            raise SpecError(f"unknown kernel {self.kernel!r}; "
                            f"expected one of {KERNELS}")
        if self.kv_layout not in KV_LAYOUTS:
            raise SpecError(f"unknown kv_layout {self.kv_layout!r}; "
                            f"expected one of {KV_LAYOUTS}")
        if (self.kv_layout != "auto" and self.domain != "token"
                and self.forecast is None):
            raise SpecError("kv_layout only applies to domain='token' or "
                            "forecast specs (the batch TPP samplers have "
                            "no KV pool)")
        if self.forecast is not None and self.kv_layout == "dense":
            raise SpecError("forecasting forks rollouts onto shared KV "
                            "pages; it requires the paged layout")
        if self.sched not in SCHEDS:
            raise SpecError(f"unknown sched {self.sched!r}; "
                            f"expected one of {SCHEDS}")
        if self.prefill_chunk < 0:
            raise SpecError("prefill_chunk must be >= 0 (0 disables "
                            "chunked admission)")
        if ((self.sched != "fifo" or self.prefill_chunk)
                and self.domain != "token" and self.forecast is None):
            raise SpecError("sched/prefill_chunk only apply to "
                            "domain='token' or forecast specs (the "
                            "serving scheduler)")
        if self.forecast is not None:
            if self.domain != "tpp":
                raise SpecError("forecast is a TPP workload; set "
                                "domain='tpp'")
            if self.method not in ("ar", "sd"):
                raise SpecError("forecast serves method='ar' or 'sd' "
                                "rollouts (thinning is a host-loop "
                                "baseline, not a serving path)")
            self.forecast.validate()
        if self.prefill_chunk and self.kv_layout == "dense":
            raise SpecError("prefill_chunk streams prompts through the "
                            "paged pool; it cannot combine with "
                            "kv_layout='dense'")
        if self.method == "thinning" and self.execution != "host":
            raise SpecError("method='thinning' is host-only (data-dependent "
                            "proposal counts cannot live in a fixed-shape "
                            "device loop)")
        if self.domain == "token":
            if self.method == "thinning":
                raise SpecError("method='thinning' has no token-domain "
                                "analogue")
            if self.execution != "host":
                raise SpecError("domain='token' serving is host-only today")
            if self.max_len < self.max_events:
                raise SpecError("max_len must cover max_events new tokens")
        # forecast specs hand batch/fanout to the SERVING engine (batch =
        # max_batch slots, fan-out is wave-scheduled), so the batch-
        # sampler execution constraints below don't apply to them
        if (self.execution == "jit" and self.batch != 1
                and self.forecast is None):
            raise SpecError("execution='jit' samples a single sequence; use "
                            "execution='vmap' or 'sharded' for batch > 1")
        if self.fanout < 1:
            raise SpecError(f"fanout must be >= 1, got {self.fanout}")
        if (self.execution == "jit" and self.fanout != 1
                and self.forecast is None):
            raise SpecError("execution='jit' samples a single sequence; "
                            "use execution='vmap'/'sharded' (or 'host') "
                            "for fanout > 1")
        if self.t_end <= 0:
            raise SpecError(f"t_end must be > 0, got {self.t_end}")
        if self.max_events < 1:
            raise SpecError(f"max_events must be >= 1, got {self.max_events}")
        if self.batch < 1:
            raise SpecError(f"batch must be >= 1, got {self.batch}")
        if self.method == "sd" and self.gamma < 1:
            raise SpecError(f"gamma must be >= 1 for method='sd', "
                            f"got {self.gamma}")
        return self

    @property
    def requires_draft(self) -> bool:
        return self.method == "sd"
