"""Unified config-driven sampling subsystem (TPP-SD paper Sec. 4).

Public API:

    SamplerSpec    — frozen config: method x execution x sizes
    SamplingEngine — build(spec, cfg_t, params_t[, cfg_d, params_d])
                     -> callable(rng) -> SampleBatch
    ENGINE         — process-wide engine (shared compilation cache)
    build_sampler / sample — conveniences over ENGINE

Strategies ("ar" | "sd" | "thinning") and draft policies ("fixed" |
"adaptive") are decorator-registered; see ``registry.py``. Token-domain
specs are served by the ``repro.serving`` continuous-batching engine.
"""
from .engine import ENGINE, SamplingEngine, build_sampler, sample
from .policies import AdaptiveGamma, DraftPolicy, FixedGamma
from .registry import (draft_policy_names, get_draft_policy, get_strategy,
                       register_draft_policy, register_strategy,
                       strategy_names)
from .result import SampleBatch, SampleStats, SeqResult
from .spec import ForecastSpec, SamplerSpec, SpecError

__all__ = [
    "ENGINE", "SamplingEngine", "build_sampler", "sample",
    "SamplerSpec", "ForecastSpec", "SpecError",
    "SampleBatch", "SampleStats", "SeqResult",
    "DraftPolicy", "FixedGamma", "AdaptiveGamma",
    "register_strategy", "get_strategy", "strategy_names",
    "register_draft_policy", "get_draft_policy", "draft_policy_names",
]
