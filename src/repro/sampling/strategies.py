"""Registered sampling strategies.

A strategy binds a (spec, model bundle) pair to single-sequence sampler
callables returning ``SeqResult``; the engine's executors then lift
those over batches, devices, and meshes. The discrete token domain is
served by ``repro.serving`` instead (see the note at the bottom).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax

from . import loops
from .policies import resolve_policy
from .registry import register_strategy


class ModelBundle(NamedTuple):
    """Target (+ optional draft) model pair handed to ``build``."""
    cfg_t: Any
    params_t: Any
    cfg_d: Optional[Any] = None
    params_d: Optional[Any] = None


@register_strategy("ar")
class ARStrategy:
    """Naive autoregressive sampling (Sec. 4.2): one forward per event."""

    def build_device(self, spec, b: ModelBundle):
        return lambda rng: loops.run_ar_device(
            b.cfg_t, b.params_t, rng, spec.t_end, spec.max_events)

    def build_host(self, spec, b: ModelBundle):
        # jit the step once here so every call through the built sampler
        # (and every lane of a host batch) reuses the compilation
        step = jax.jit(functools.partial(loops.ar_step, b.cfg_t, b.params_t))
        return lambda rng: loops.run_ar_host(
            b.cfg_t, b.params_t, rng, spec.t_end, spec.max_events,
            step=step)


@register_strategy("sd")
class SDStrategy:
    """TPP-SD (Algorithm 1): draft gamma events, verify in one target
    forward, commit the accepted prefix + one replacement/bonus event."""

    def build_device(self, spec, b: ModelBundle):
        policy = resolve_policy(spec)
        gamma = policy.gamma(policy.init_state())
        return lambda rng: loops.run_sd_device(
            b.cfg_t, b.cfg_d, b.params_t, b.params_d, rng, spec.t_end,
            gamma, spec.max_events)

    def build_host(self, spec, b: ModelBundle):
        policy = resolve_policy(spec)
        # one jitted round per distinct window length; the host executor
        # follows the policy's schedule between device calls
        round_fns = {}

        def round_fn_for(gamma: int):
            if gamma not in round_fns:
                round_fns[gamma] = jax.jit(functools.partial(
                    loops.sd_round, b.cfg_t, b.cfg_d, b.params_t,
                    b.params_d, gamma))
            return round_fns[gamma]

        if policy.is_static:
            gamma = policy.gamma(policy.init_state())
            return lambda rng: loops.run_sd_host(
                b.cfg_t, b.cfg_d, b.params_t, b.params_d, rng, spec.t_end,
                gamma, spec.max_events, round_fn=round_fn_for(gamma))
        return lambda rng: loops.run_sd_host_schedule(
            b.cfg_t, b.cfg_d, b.params_t, b.params_d, rng, spec.t_end,
            policy, spec.max_events, round_fn_for)


@register_strategy("thinning")
class ThinningStrategy:
    """Neural CIF thinning (App. D.1): the rejected baseline, kept as the
    structural comparison — every proposal costs a target forward."""

    def build_device(self, spec, b: ModelBundle):
        return None  # data-dependent proposal counts: host-only

    def build_host(self, spec, b: ModelBundle):
        return lambda rng: loops.run_thinning_host(
            b.cfg_t, b.params_t, rng, spec.t_end, spec.max_events,
            safety=spec.thinning_safety, grid=spec.thinning_grid,
            horizon=spec.thinning_horizon)


# The token domain ("llm" special case) is not a registered strategy:
# ``SamplerSpec(domain="token")`` routes through the ``repro.serving``
# continuous-batching engine (see ``SamplingEngine._build_token``).
# ``SamplerSpec(fanout=K)`` applies to BOTH domains: TPP executors fan
# every base lane into K ``fold_in``-derived scenario streams; token
# runs submit each prompt as one shared-prefix group whose members fork
# the admitted prompt's KV pages (copy-on-write) instead of
# re-prefilling — identical streams, near-zero marginal prefill.
