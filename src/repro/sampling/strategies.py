"""Registered sampling strategies.

A strategy binds a (spec, model bundle) pair to single-sequence sampler
callables; the engine's executors then lift those over batches, devices,
and meshes. TPP strategies return ``SeqResult``; token strategies (the
discrete LLM special case served by ``launch/serve.py``) additionally
take the prompt.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import loops
from .policies import resolve_policy
from .registry import register_strategy
from .result import SeqResult


class ModelBundle(NamedTuple):
    """Target (+ optional draft) model pair handed to ``build``."""
    cfg_t: Any
    params_t: Any
    cfg_d: Optional[Any] = None
    params_d: Optional[Any] = None


@register_strategy("ar")
class ARStrategy:
    """Naive autoregressive sampling (Sec. 4.2): one forward per event."""

    def build_device(self, spec, b: ModelBundle):
        return lambda rng: loops.run_ar_device(
            b.cfg_t, b.params_t, rng, spec.t_end, spec.max_events)

    def build_host(self, spec, b: ModelBundle):
        # jit the step once here so every call through the built sampler
        # (and every lane of a host batch) reuses the compilation
        step = jax.jit(functools.partial(loops.ar_step, b.cfg_t, b.params_t))
        return lambda rng: loops.run_ar_host(
            b.cfg_t, b.params_t, rng, spec.t_end, spec.max_events,
            step=step)


@register_strategy("sd")
class SDStrategy:
    """TPP-SD (Algorithm 1): draft gamma events, verify in one target
    forward, commit the accepted prefix + one replacement/bonus event."""

    def build_device(self, spec, b: ModelBundle):
        gamma = resolve_policy(spec).round_gamma(0)
        return lambda rng: loops.run_sd_device(
            b.cfg_t, b.cfg_d, b.params_t, b.params_d, rng, spec.t_end,
            gamma, spec.max_events)

    def build_host(self, spec, b: ModelBundle):
        gamma = resolve_policy(spec).round_gamma(0)
        round_fn = jax.jit(functools.partial(
            loops.sd_round, b.cfg_t, b.cfg_d, b.params_t, b.params_d,
            gamma))
        return lambda rng: loops.run_sd_host(
            b.cfg_t, b.cfg_d, b.params_t, b.params_d, rng, spec.t_end,
            gamma, spec.max_events, round_fn=round_fn)


@register_strategy("thinning")
class ThinningStrategy:
    """Neural CIF thinning (App. D.1): the rejected baseline, kept as the
    structural comparison — every proposal costs a target forward."""

    def build_device(self, spec, b: ModelBundle):
        return None  # data-dependent proposal counts: host-only

    def build_host(self, spec, b: ModelBundle):
        return lambda rng: loops.run_thinning_host(
            b.cfg_t, b.params_t, rng, spec.t_end, spec.max_events,
            safety=spec.thinning_safety, grid=spec.thinning_grid,
            horizon=spec.thinning_horizon)


# ---------------------------------------------------------------------------
# token domain: the discrete LLM special case (Leviathan et al.)
# ---------------------------------------------------------------------------

def _token_result(st, max_events: int) -> SeqResult:
    """Pad ServeStats tokens into the unified fixed-shape result."""
    types = jnp.zeros((max_events,), jnp.int32)
    n = min(int(st.n), max_events)
    if n:
        types = types.at[:n].set(st.tokens[:n])
    return SeqResult(jnp.zeros((max_events,), jnp.float32), types,
                     jnp.int32(n), jnp.int32(st.drafted),
                     jnp.int32(st.accepted), jnp.int32(st.rounds))


class TokenBundle(NamedTuple):
    """Model-zoo bundle: configs + params + registry ModelApi pair."""
    cfg_t: Any
    params_t: Any
    model_t: Any
    cfg_d: Optional[Any] = None
    params_d: Optional[Any] = None
    model_d: Optional[Any] = None


@register_strategy("llm_ar")
class TokenARStrategy:
    def build_device(self, spec, b: TokenBundle):
        return None

    def build_host(self, spec, b: TokenBundle):
        from ..core import llm_sd

        def fn(rng, prompt):
            st = llm_sd.serve_autoregressive(
                b.cfg_t, b.params_t, b.model_t, prompt, rng,
                max_new_tokens=spec.max_events, max_len=spec.max_len,
                temperature=spec.temperature)
            return _token_result(st, spec.max_events)
        return fn


@register_strategy("llm_sd")
class TokenSDStrategy:
    def build_device(self, spec, b: TokenBundle):
        return None

    def build_host(self, spec, b: TokenBundle):
        from ..core import llm_sd
        gamma = resolve_policy(spec).round_gamma(0)

        def fn(rng, prompt):
            st = llm_sd.serve_speculative(
                b.cfg_t, b.cfg_d, b.params_t, b.params_d, b.model_t,
                b.model_d, prompt, rng, max_new_tokens=spec.max_events,
                gamma=gamma, max_len=spec.max_len,
                temperature=spec.temperature)
            return _token_result(st, spec.max_events)
        return fn
