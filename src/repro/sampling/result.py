"""Unified sampling results.

Every execution path of every strategy returns the same two shapes:

  - ``SeqResult``  — one sequence (no batch dim); what the single-sequence
    loops in ``loops.py`` produce and what ``jax.vmap`` maps over.
  - ``SampleBatch`` — the engine's public result: a leading batch dim is
    ALWAYS present (B=1 for single-sequence execution), plus the
    acceptance/round accounting and derived stats computed once here
    instead of at every call site.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, NamedTuple, Tuple

import jax.numpy as jnp
import numpy as np


class SeqResult(NamedTuple):
    """One sampled sequence in fixed-shape buffers (valid prefix = n)."""
    times: jnp.ndarray     # [max_events] float32
    types: jnp.ndarray     # [max_events] int32
    n: jnp.ndarray         # valid count (times <= t_end)
    drafted: jnp.ndarray   # events proposed by the draft model
    accepted: jnp.ndarray  # drafted events accepted by verification
    rounds: jnp.ndarray    # propose-verify rounds (== target forwards)


@dataclass(frozen=True)
class SampleStats:
    """Host-side accounting derived once from a ``SampleBatch``."""
    events: int
    drafted: int
    accepted: int
    rounds: int

    @property
    def acceptance_rate(self) -> float:
        """alpha (paper Sec. 5): accepted / drafted; 0 for non-SD methods."""
        return self.accepted / max(1, self.drafted)

    @property
    def events_per_forward(self) -> float:
        """Events committed per target forward (AR == 1.0 by construction);
        the hardware-independent speedup driver."""
        return self.events / max(1, self.rounds)

    def describe(self) -> str:
        return (f"events={self.events} rounds={self.rounds} "
                f"alpha={self.acceptance_rate:.2f} "
                f"ev/fwd={self.events_per_forward:.2f}")


class SampleBatch(NamedTuple):
    """Batched sampling result: [B, E] buffers with per-lane lengths."""
    times: jnp.ndarray     # [B, max_events] float32
    types: jnp.ndarray     # [B, max_events] int32
    lengths: jnp.ndarray   # [B] int32 valid counts
    drafted: jnp.ndarray   # [B]
    accepted: jnp.ndarray  # [B]
    rounds: jnp.ndarray    # [B]

    # `n` mirrors the legacy SampleResult field so downstream code that
    # reads `.n` keeps working on either type.
    @property
    def n(self) -> jnp.ndarray:
        return self.lengths

    def to_seqs(self) -> List[Tuple[np.ndarray, np.ndarray]]:
        """Ragged view: [(times_i, types_i)] trimmed to each lane's length."""
        times = np.atleast_2d(np.array(self.times))
        types = np.atleast_2d(np.array(self.types))
        ns = np.atleast_1d(np.array(self.lengths))
        return [(times[i, :ns[i]], types[i, :ns[i]]) for i in range(len(ns))]

    def stats(self) -> SampleStats:
        return SampleStats(
            events=int(np.sum(np.array(self.lengths))),
            drafted=int(np.sum(np.array(self.drafted))),
            accepted=int(np.sum(np.array(self.accepted))),
            rounds=int(np.sum(np.array(self.rounds))))


def batch_from_seq(res: SeqResult) -> SampleBatch:
    """Promote a single-sequence result to a B=1 ``SampleBatch``."""
    return SampleBatch(res.times[None], res.types[None], res.n[None],
                       jnp.asarray(res.drafted)[None],
                       jnp.asarray(res.accepted)[None],
                       jnp.asarray(res.rounds)[None])


def batch_from_mapped(res: SeqResult) -> SampleBatch:
    """Re-label a vmapped SeqResult (leaves already carry a batch dim)."""
    return SampleBatch(res.times, res.types, res.n, res.drafted,
                       res.accepted, res.rounds)


def stack_seqs(results: List[SeqResult]) -> SampleBatch:
    """Stack host-loop per-sequence results into one batch."""
    return SampleBatch(
        jnp.stack([r.times for r in results]),
        jnp.stack([r.types for r in results]),
        jnp.stack([jnp.asarray(r.n) for r in results]),
        jnp.stack([jnp.asarray(r.drafted) for r in results]),
        jnp.stack([jnp.asarray(r.accepted) for r in results]),
        jnp.stack([jnp.asarray(r.rounds) for r in results]))
