"""Long-horizon TPP forecasting at fan-out scale.

The forecast subsystem answers "how many events land in each future
time bin, with what uncertainty?" by Monte-Carlo: thousands of sampled
continuations of ONE observed event history, reduced to per-bin count
quantiles. It is the first workload in the repo whose headline metric
is rollouts/s rather than tokens/s or events/s, and it is built
entirely out of the serving engine's primitives:

  - ``Forecaster`` (executor.py) admits the shared history once and
    forks it into successive pool-sized WAVES of copy-on-write fan-out
    groups, so ``n_rollouts`` can exceed the paged pool by orders of
    magnitude while the pool only ever holds one wave;
  - ``ForecastAggregator`` (aggregate.py) folds each wave's event times
    into an on-device per-bin count histogram — an exact sufficient
    statistic, so the host never materializes all rollouts;
  - the "grouped" scheduling policy co-batches wave siblings and the
    TPP-history prefix cache re-serves the history's pages between
    waves.

``build_forecaster`` is the spec-driven entry point:

    spec = SamplerSpec(domain="tpp", method="sd", gamma=4,
                       forecast=ForecastSpec(horizon=8.0,
                                             n_rollouts=2000))
    fc = build_forecaster(spec, cfg_t, params_t, cfg_d, params_d)
    res = fc(history_times, history_marks, rng=0)
    print(res.describe()); print(res.quantiles)
"""
from __future__ import annotations

from typing import Any, Optional

from ..sampling.spec import ForecastSpec, SamplerSpec, SpecError
from ..serving import ServingEngine
from .aggregate import ForecastAggregator
from .executor import Forecaster, ForecastRequest, ForecastResult

__all__ = ["ForecastAggregator", "ForecastRequest", "ForecastResult",
           "Forecaster", "ForecastSpec", "BoundForecaster",
           "build_forecaster"]


class BoundForecaster:
    """A ``Forecaster`` bound to the request shape of one spec:
    call with a history (+ optional per-call overrides) and get a
    ``ForecastResult``. Reuse across calls keeps the engine's jit
    caches warm; the underlying engine/forecaster stay reachable via
    ``.engine``/``.forecaster`` for stats and tests."""

    def __init__(self, forecaster: Forecaster, spec: SamplerSpec):
        self.forecaster = forecaster
        self.spec = spec

    @property
    def engine(self) -> ServingEngine:
        return self.forecaster.engine

    def __call__(self, history_times, history_marks, *, rng: Any = 0,
                 horizon: Optional[float] = None,
                 n_rollouts: Optional[int] = None,
                 collect: bool = False) -> ForecastResult:
        f = self.spec.forecast
        req = ForecastRequest(
            history_times=history_times, history_marks=history_marks,
            horizon=f.horizon if horizon is None else horizon,
            n_rollouts=f.n_rollouts if n_rollouts is None else n_rollouts,
            bins=f.bins, quantiles=tuple(f.quantiles),
            max_events=self.spec.max_events, rng=rng)
        return self.forecaster.forecast(req, collect=collect)


def build_forecaster(spec: SamplerSpec, cfg_t, params_t, cfg_d=None,
                     params_d=None, *, page_size: Optional[int] = None,
                     n_pages: Optional[int] = None) -> BoundForecaster:
    """Build the wave-scheduled forecasting stack a spec describes.

    The spec must carry ``forecast=ForecastSpec(...)`` (and therefore
    ``domain="tpp"``); ``batch`` becomes the engine's ``max_batch`` (the
    per-wave fan-out ceiling), ``max_events`` the per-rollout budget,
    and ``sched`` defaults to the sibling-co-batching "grouped" policy.
    ``page_size``/``n_pages`` pass through to the paged pool — an
    ``n_pages`` that holds only one wave is the designed operating
    point, not an error.
    """
    spec.validate()
    if spec.forecast is None:
        raise SpecError("build_forecaster needs a spec with "
                        "forecast=ForecastSpec(...)")
    engine = ServingEngine(
        cfg_t, params_t, cfg_d, params_d,
        method=spec.method, max_batch=spec.batch, max_len=spec.max_len,
        gamma=spec.gamma, kernel=spec.kernel,
        sched="grouped" if spec.sched == "fifo" else spec.sched,
        prefill_chunk=spec.prefill_chunk or None,
        prefix_cache=True, page_size=page_size, n_pages=n_pages)
    return BoundForecaster(Forecaster(engine), spec)
