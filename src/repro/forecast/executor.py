"""Wave-scheduled scenario fan-out for long-horizon forecasting.

A forecast query wants ``n_rollouts`` Monte-Carlo continuations of ONE
event history — thousands of rollouts, while the paged KV pool holds
tens. The executor closes that gap with WAVES: admit the shared history
once, fork a pool-sized group of siblings onto its copy-on-write pages,
run the wave to retirement, fold its event times into the on-device
aggregator, release every page, and fork the next wave — so the pool
only ever holds one wave and the host only ever holds one wave's times.

Wave sizing asks the engine (``fanout_headroom``) how many siblings the
free list can back right now; the rng contract makes the split exact:
wave w of size K submits with ``fanout_offset = sum of earlier waves``,
so member j globally draws from ``fold_in(rng, j)`` regardless of wave
boundaries — a forecast split into waves commits BITWISE the same
rollouts a single fanout=n_rollouts submission would (the wave-parity
test pins this), and between waves the radix prefix cache re-serves the
history's pages to the next wave's source without re-prefilling.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from .aggregate import ForecastAggregator

__all__ = ["ForecastRequest", "ForecastResult", "Forecaster"]


@dataclass(frozen=True)
class ForecastRequest:
    """One forecast query over a shared event history.

    history_times/history_marks : the observed [P] event history (may be
        empty: forecast from the process start).
    horizon     : forecast window length; rollouts run over
        (t_last, t_last + horizon] where t_last is the last observed
        event time (0 for an empty history).
    n_rollouts  : Monte-Carlo continuations to sample.
    bins        : time bins the horizon is split into.
    quantiles   : per-bin count quantile levels to report.
    max_events  : per-rollout event budget (also the aggregator's count
        ceiling); a rollout stops at whichever of budget/horizon comes
        first.
    rng         : base PRNGKey or int seed; rollout j draws from
        ``fold_in(rng, j)``.
    """

    history_times: Any
    history_marks: Any
    horizon: float
    n_rollouts: int = 1000
    bins: int = 20
    quantiles: Tuple[float, ...] = (0.1, 0.25, 0.5, 0.75, 0.9)
    max_events: int = 64
    rng: Any = 0

    def __post_init__(self):
        object.__setattr__(self, "history_times",
                           np.asarray(self.history_times,
                                      np.float32).reshape(-1))
        object.__setattr__(self, "history_marks",
                           np.asarray(self.history_marks,
                                      np.int32).reshape(-1))
        if self.history_times.shape != self.history_marks.shape:
            raise ValueError("history times/marks length mismatch")
        if self.horizon <= 0 or self.n_rollouts < 1 or self.bins < 1:
            raise ValueError("need horizon > 0, n_rollouts >= 1, "
                             "bins >= 1")

    @property
    def t_last(self) -> float:
        return float(self.history_times[-1]) \
            if self.history_times.size else 0.0


@dataclass(frozen=True)
class ForecastResult:
    """Per-bin count quantiles + fan-out throughput accounting."""

    bin_edges: np.ndarray          # [bins+1] absolute times
    quantile_levels: Tuple[float, ...]
    quantiles: np.ndarray          # [len(levels), bins] count quantiles
    mean: np.ndarray               # [bins] mean event count
    n_rollouts: int
    events: int                    # events sampled across all rollouts
    wave_sizes: List[int]          # fan-out of each wave, in order
    wall_s: float
    rollouts_per_sec: float        # the workload's headline metric
    failed_rollouts: int = 0       # members that stayed failed after the
                                   # executor's per-member retries (their
                                   # streams are absent from the
                                   # aggregate; ``collect`` leaves None)
    rollouts: Optional[List[Tuple[np.ndarray, np.ndarray]]] = field(
        default=None, repr=False)  # collect=True: [(marks, times)] per
                                   # member index — tests only; defeats
                                   # the on-device aggregation otherwise

    @property
    def n_waves(self) -> int:
        return len(self.wave_sizes)

    def describe(self) -> str:
        return (f"rollouts={self.n_rollouts} waves={self.n_waves} "
                f"(sizes {self.wave_sizes[:4]}"
                f"{'...' if self.n_waves > 4 else ''}) "
                f"events={self.events} "
                f"rollouts/s={self.rollouts_per_sec:.1f}"
                + (f" failed={self.failed_rollouts}"
                   if self.failed_rollouts else ""))


class Forecaster:
    """Drives a TPP ``ServingEngine`` through wave-scheduled fan-out.

    The engine must be idle (no queued/active requests) when
    ``forecast`` is called; the call owns the engine until it returns.
    """

    def __init__(self, engine, max_retries: int = 2, loop: str = "sync"):
        if getattr(engine, "domain", None) != "tpp":
            raise ValueError("Forecaster needs a TPP serving engine "
                             "(built from a TPPConfig)")
        if max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if loop not in ("sync", "async"):
            raise ValueError("loop must be 'sync' or 'async'")
        self.engine = engine
        #: "async" drains each wave with the engine's pipelined
        #: ``run_async()`` (bitwise == ``run()``; the host folds the
        #: PREVIOUS wave's aggregation while the device decodes)
        self.loop = loop
        #: per-member resubmission budget: a rollout the engine retired
        #: non-"ok" (injected fault, quarantined lane, cancellation) is
        #: resubmitted alone with ``fanout_offset = member``, which
        #: reproduces its exact ``fold_in(rng, member)`` stream — a
        #: retried rollout folds bitwise what the failure-free wave
        #: would have folded
        self.max_retries = max_retries

    def forecast(self, req: ForecastRequest,
                 collect: bool = False) -> ForecastResult:
        eng = self.engine
        if eng.scheduler.has_work():
            raise RuntimeError("engine busy: forecast() needs a drained "
                               "engine")
        t0 = req.t_last
        t_end = t0 + float(req.horizon)
        plen = int(req.history_marks.shape[0])
        agg = ForecastAggregator(req.bins, t0, t_end, req.max_events)
        rollouts: List[Optional[Tuple[np.ndarray, np.ndarray]]] = \
            [None] * req.n_rollouts if collect else None
        wave_sizes: List[int] = []
        events = 0
        done = 0
        failed: List[int] = []     # member indices retired non-"ok"
        t_start = time.perf_counter()
        while done < req.n_rollouts:
            k = min(eng.fanout_headroom(plen, req.max_events),
                    req.n_rollouts - done)
            ids = eng.submit(prompt=req.history_marks,
                             times=req.history_times, t_end=t_end,
                             max_new_tokens=req.max_events, rng=req.rng,
                             fanout=k, fanout_offset=done)
            member = {rid: done + j for j, rid in enumerate(ids)}
            results = eng.run() if self.loop == "sync" else eng.run_async()
            # fold this wave and forget it: the host buffer is one wave
            # ([K <= max_batch, budget]), never the full fan-out. Only
            # "ok" retirements enter the buffer — the aggregator counts
            # every row as a rollout, so a failed lane's row (even
            # empty) would bias the count distribution; failed members
            # are re-run by the retry pass below instead
            good = [r for r in results if r.ok]
            failed.extend(member[r.request_id]
                          for r in results if not r.ok)
            if good:
                buf = np.zeros((len(good), req.max_events), np.float32)
                nv = np.zeros((len(good),), np.int32)
                for i, r in enumerate(good):
                    buf[i, :r.n] = r.times
                    nv[i] = r.n
                    events += r.n
                    if collect:
                        rollouts[member[r.request_id]] = (r.tokens, r.times)
                agg.fold(buf, nv)
            wave_sizes.append(k)
            done += k
        # per-member retry: resubmitting member j alone at offset j
        # re-derives fold_in(rng, j) — the retried rollout is bitwise
        # the one the failed wave lost
        for _ in range(self.max_retries):
            if not failed:
                break
            still: List[int] = []
            for j in failed:
                ids = eng.submit(prompt=req.history_marks,
                                 times=req.history_times, t_end=t_end,
                                 max_new_tokens=req.max_events,
                                 rng=req.rng, fanout=1, fanout_offset=j)
                results = (eng.run() if self.loop == "sync"
                           else eng.run_async())
                r = results[0] if results else None
                if r is None or not r.ok:
                    still.append(j)
                    continue
                buf = np.zeros((1, req.max_events), np.float32)
                buf[0, :r.n] = r.times
                agg.fold(buf, np.asarray([r.n], np.int32))
                events += r.n
                if collect:
                    rollouts[j] = (r.tokens, r.times)
            failed = still
        wall = time.perf_counter() - t_start
        return ForecastResult(
            bin_edges=agg.bin_edges,
            quantile_levels=tuple(req.quantiles),
            quantiles=agg.quantiles(req.quantiles),
            mean=agg.mean(),
            n_rollouts=req.n_rollouts, events=events,
            wave_sizes=wave_sizes, wall_s=wall,
            rollouts_per_sec=req.n_rollouts / max(1e-9, wall),
            failed_rollouts=len(failed), rollouts=rollouts)
