"""On-device streaming aggregation of forecast rollouts.

A long-horizon forecast query answers "how many events land in each
future time bin, with what uncertainty?" from Monte-Carlo rollouts. At
fan-out scale the naive route — ship every rollout's event times to the
host and quantile over the [n_rollouts, horizon] matrix — moves and
holds O(n_rollouts) data for a result of size O(bins). This module keeps
the reduction on device and EXACT: per wave, a jitted fold bins each
rollout's event times (``tpp.bin_counts``) and scatters the per-bin
event counts into a count histogram ``hist[bin, count]``. Because a
rollout contributes at most ``max_events`` events, the per-bin count is
an integer in [0, max_events] and the histogram is a lossless sufficient
statistic of the per-bin count distribution — any quantile, mean, or
tail probability of "events in bin b" is recovered from it exactly, for
any number of rollouts, in O(bins * max_events) host memory.

Quantiles follow numpy's ``inverted_cdf`` convention: the q-quantile of
n samples is the k-th order statistic with k = max(1, ceil(q*n)) — for
integer count data that is the smallest count c whose CDF reaches k,
read directly off the histogram (``test_forecast.py`` pins equality
against ``np.quantile`` on the concatenated rollouts).
"""
from __future__ import annotations

from typing import Any, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import tpp

__all__ = ["ForecastAggregator"]

_FN_CACHE: Dict[Tuple, Any] = {}


def _fold_fn(bins: int, max_count: int, t0: float, t1: float):
    """Jitted wave fold: (hist [bins, C+1], times [K, E], n_valid [K])
    -> new hist. One scatter-add per wave; nothing per-rollout returns
    to the host."""
    key = ("fold", bins, max_count, float(t0), float(t1))
    if key not in _FN_CACHE:
        def fn(hist, times, n_valid):
            counts = tpp.bin_counts(times, n_valid, t0, t1, bins)
            counts = jnp.clip(counts, 0, max_count)      # [K, bins]
            b_idx = jnp.broadcast_to(jnp.arange(bins), counts.shape)
            return hist.at[b_idx, counts].add(1)
        _FN_CACHE[key] = jax.jit(fn)
    return _FN_CACHE[key]


class ForecastAggregator:
    """Streaming per-bin count histogram over (t0, t1] split into
    ``bins`` equal bins (left-open, matching the samplers' ``t <= t_end``
    horizon test: an event exactly at t1 counts, one exactly at t0 — the
    history's anchor — does not).

    ``fold(times, n_valid)`` ingests one wave of rollouts: ``times``
    [K, E] padded device (or host) event-time buffers, ``n_valid`` [K]
    live lengths. ``max_count`` is the largest per-bin count a single
    rollout can contribute (the engine's max-events budget).
    """

    def __init__(self, bins: int, t0: float, t1: float, max_count: int):
        if bins < 1 or max_count < 1 or not t1 > t0:
            raise ValueError("need bins >= 1, max_count >= 1, t1 > t0")
        self.bins, self.max_count = int(bins), int(max_count)
        self.t0, self.t1 = float(t0), float(t1)
        self.hist = jnp.zeros((self.bins, self.max_count + 1), jnp.int32)
        self.n_rollouts = 0

    @property
    def bin_edges(self) -> np.ndarray:
        return np.linspace(self.t0, self.t1, self.bins + 1)

    def fold(self, times, n_valid) -> None:
        fold = _fold_fn(self.bins, self.max_count, self.t0, self.t1)
        self.hist = fold(self.hist, jnp.asarray(times, jnp.float32),
                         jnp.asarray(n_valid, jnp.int32))
        self.n_rollouts += int(np.asarray(n_valid).shape[0])

    # -- host-side extraction (O(bins * max_count), rollout-free) ----------
    def counts(self) -> np.ndarray:
        """The histogram: counts[b, c] = rollouts with c events in bin b."""
        return np.asarray(self.hist)

    def quantiles(self, qs: Sequence[float]) -> np.ndarray:
        """Per-bin count quantiles [len(qs), bins], ``inverted_cdf``:
        the smallest count whose per-bin CDF reaches max(1, ceil(q*n))."""
        if self.n_rollouts == 0:
            raise ValueError("no rollouts folded yet")
        hist = self.counts()
        cdf = np.cumsum(hist, axis=1)                    # [bins, C+1]
        out = np.zeros((len(qs), self.bins), np.int64)
        for i, q in enumerate(qs):
            if not 0.0 <= q <= 1.0:
                raise ValueError(f"quantile {q} outside [0, 1]")
            k = min(self.n_rollouts,
                    max(1, int(np.ceil(q * self.n_rollouts))))
            out[i] = np.argmax(cdf >= k, axis=1)
        return out

    def mean(self) -> np.ndarray:
        """Per-bin mean event count [bins]."""
        if self.n_rollouts == 0:
            raise ValueError("no rollouts folded yet")
        c = np.arange(self.max_count + 1)
        return (self.counts() * c).sum(axis=1) / self.n_rollouts
