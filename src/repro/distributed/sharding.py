"""Logical-axis sharding rules (MaxText-style).

Every parameter / activation carries a tuple of *logical* axis names; a
``Rules`` object maps logical names to mesh axes, with a
divisible-or-replicate fallback so one rule set covers every architecture
(e.g. kv_heads=8 cannot shard over a 16-way model axis -> replicated, as
MaxText does for small KV head counts).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axis vocabulary.
#   batch      : data-parallel batch dim
#   seq        : sequence dim (unsharded by default; "seq_shard" opt-in)
#   embed      : model width as an *activation* dim (unsharded)
#   p_embed    : model width as a *parameter* dim (FSDP target)
#   vocab      : vocabulary dim
#   heads      : query heads
#   kv_heads   : key/value heads
#   qkv        : per-head feature dim (never sharded by default)
#   mlp        : FFN hidden dim
#   experts    : MoE expert dim
#   inner      : SSM/LRU inner width
#   state      : SSM state dim
#   layers     : scanned-layer leading dim (never sharded)
#   cache_seq  : KV-cache sequence dim

DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),
    "embed": (),
    "p_embed": ("data",),          # FSDP: shard param width over data axis
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "qkv": (),
    "mlp": ("model",),
    "experts": ("model",),
    "inner": ("model",),
    "state": (),
    "layers": (),
    "cache_seq": (),
    "mix": (),
    "marks": (),
}


class Rules:
    def __init__(self, mesh: Mesh, rules: Optional[Dict[str, Tuple[str, ...]]] = None,
                 fsdp: bool = True):
        self.mesh = mesh
        base = dict(DEFAULT_RULES)
        if rules:
            base.update(rules)
        if not fsdp:
            base["p_embed"] = ()
        # Drop mesh axes that don't exist in this mesh (e.g. "pod" on 2D mesh).
        self.rules = {
            k: tuple(a for a in v if a in mesh.axis_names) for k, v in base.items()
        }

    def _axis_size(self, names: Tuple[str, ...]) -> int:
        return int(np.prod([self.mesh.shape[a] for a in names])) if names else 1

    def rule_axis_size(self, name: str) -> int:
        """Product of mesh-axis sizes a logical axis maps to (1 if
        unmapped) — the divisor a dim must satisfy to actually shard."""
        return self._axis_size(self.rules.get(name, ()))

    def spec(self, logical: Sequence[Optional[str]],
             dims: Optional[Sequence[int]] = None) -> P:
        """Map logical axis names (+ optional concrete dims) to a PartitionSpec.

        If ``dims`` is given, any mapping whose mesh-axis product does not
        divide the dim is dropped (replicate fallback) — GSPMD would pad,
        but an even layout keeps memory analysis honest.
        """
        out = []
        used: set = set()
        for i, name in enumerate(logical):
            if name is None:
                out.append(None)
                continue
            axes = tuple(a for a in self.rules.get(name, ()) if a not in used)
            if not axes:
                out.append(None)
                continue
            if dims is not None:
                size = self._axis_size(axes)
                if size == 0 or dims[i] % size != 0:
                    # try progressively shorter prefixes of the rule
                    while axes and (dims[i] % self._axis_size(axes) != 0):
                        axes = axes[:-1]
                    if not axes:
                        out.append(None)
                        continue
            used.update(axes)
            out.append(axes[0] if len(axes) == 1 else axes)
        return P(*out)

    def sharding(self, logical: Sequence[Optional[str]],
                 dims: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical, dims))

    def tree_shardings(self, logical_tree, shape_tree):
        """Build a NamedSharding tree from parallel (logical-axes, shapes) trees."""
        def one(logical, shaped):
            return self.sharding(logical, tuple(shaped.shape))
        return jax.tree.map(one, logical_tree, shape_tree,
                            is_leaf=lambda x: isinstance(x, tuple) and all(
                                isinstance(e, (str, type(None))) for e in x))


def batch_spec(rules: Rules) -> P:
    return rules.spec(("batch", None))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
