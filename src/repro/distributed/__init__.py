from . import sharding
from .sharding import Rules
