"""Evaluation metrics (paper Sec. 5.1).

  - likelihood discrepancy (|L_gt - L_model| synthetic, |L_ar - L_sd| real)
  - KS statistic via the time-rescaling theorem (synthetic)
  - 1-Wasserstein distance on times + EMD on types (real)
  - speedup ratio / acceptance rate accounting
"""
from __future__ import annotations

import math
from typing import List, Sequence, Tuple

import numpy as np

from ..core import thinning as thin


def ks_statistic(z: np.ndarray) -> float:
    """KS statistic of rescaled intervals against Exp(1) (App. A.4)."""
    z = np.sort(np.asarray(z))
    n = len(z)
    if n == 0:
        return 1.0
    F = 1.0 - np.exp(-z)
    ecdf_hi = np.arange(1, n + 1) / n
    ecdf_lo = np.arange(0, n) / n
    return float(np.maximum(np.abs(ecdf_hi - F), np.abs(F - ecdf_lo)).max())


def ks_confidence_band(n: int, alpha: float = 0.05) -> float:
    return 1.36 / math.sqrt(max(n, 1))


def ks_for_samples(proc: thin.PointProcess, seqs) -> float:
    """Pool rescaled intervals over sampled sequences, one KS statistic."""
    zs = [thin.rescaled_intervals(proc, t, k) for t, k in seqs if len(t)]
    if not zs:
        return 1.0
    return ks_statistic(np.concatenate(zs))


def wasserstein_1d(a: np.ndarray, b: np.ndarray) -> float:
    """1-Wasserstein between empirical distributions (sorted coupling)."""
    a, b = np.sort(np.asarray(a, float)), np.sort(np.asarray(b, float))
    n = max(len(a), len(b))
    if len(a) == 0 or len(b) == 0:
        return float("nan")
    q = (np.arange(n) + 0.5) / n
    qa = np.quantile(a, q)
    qb = np.quantile(b, q)
    return float(np.abs(qa - qb).mean())


def type_emd(a: np.ndarray, b: np.ndarray, K: int) -> float:
    """Earth-mover distance between type histograms on the line 0..K-1
    (equals the L1 distance of CDFs for 1-D ground metric |i-j|)."""
    ha = np.bincount(np.asarray(a, int), minlength=K) / max(len(a), 1)
    hb = np.bincount(np.asarray(b, int), minlength=K) / max(len(b), 1)
    return float(np.abs(np.cumsum(ha - hb)).sum())


def mean_gt_loglik(proc: thin.PointProcess, seqs, t_end: float) -> float:
    lls = [thin.ground_truth_loglik(proc, t, k, t_end) for t, k in seqs]
    return float(np.mean(lls)) if lls else float("nan")


def speedup(t_ar: float, t_sd: float) -> float:
    return t_ar / max(t_sd, 1e-12)
