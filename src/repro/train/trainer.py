"""Training loops: the TPP trainer (paper Sec. 5 setup) and the generic
LM trainer used by the architecture smoke tests and the dry-run.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..data import synthetic as ds
from ..models import registry, tpp
from . import optimizer as opt


# ---------------------------------------------------------------------------
# TPP training (maximize Eq. 2 log-likelihood)
# ---------------------------------------------------------------------------

@dataclass
class TPPTrainConfig:
    lr: float = 1e-3
    batch_size: int = 16        # paper: 16
    max_epochs: int = 50
    patience: int = 5           # early stopping on validation NLL
    clip_norm: float = 1.0
    seed: int = 0
    log_every: int = 0


def tpp_nll(cfg, params, batch, t_end):
    ll = jax.vmap(lambda t, k, m: tpp.loglik(cfg, params, t, k, m, t_end))(
        batch["times"], batch["types"], batch["mask"])
    # mean per-event NLL keeps the scale comparable across datasets
    return -jnp.sum(ll) / jnp.maximum(jnp.sum(batch["mask"]), 1.0)


def train_tpp(cfg, dataset: ds.TPPDataset, tcfg: TPPTrainConfig = None,
              params=None, verbose: bool = False):
    """Train a CDF-based Transformer TPP on a dataset. Returns (params,
    history dict)."""
    tcfg = tcfg or TPPTrainConfig()
    rng = jax.random.PRNGKey(tcfg.seed)  # repro: ignore[rng-raw-prngkey] -- training entry point: the root key is derived from the config seed here, once
    if params is None:
        params = tpp.init_params(cfg, rng)
    optim = opt.adam(tcfg.lr, clip_norm=tcfg.clip_norm)
    state = optim.init(params)
    max_len = ds.max_events(dataset.train) + 1

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: tpp_nll(cfg, p, batch, dataset.t_end))(params)
        params, state = optim.update(grads, state, params)
        return params, state, loss

    @jax.jit
    def eval_nll(params, batch):
        return tpp_nll(cfg, params, batch, dataset.t_end)

    best_val = float("inf")
    best_params = params
    bad_epochs = 0
    hist = {"train": [], "val": []}
    for epoch in range(tcfg.max_epochs):
        losses = []
        for batch in ds.batches(dataset.train, tcfg.batch_size, max_len,
                                seed=tcfg.seed + epoch):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, state, loss = step(params, state, batch)
            losses.append(float(loss))
        val_losses = [float(eval_nll(params,
                                     {k: jnp.asarray(v)
                                      for k, v in b.items()}))
                      for b in ds.batches(dataset.val, tcfg.batch_size,
                                          max_len, shuffle=False)]
        tr, va = float(np.mean(losses)), float(np.mean(val_losses))
        hist["train"].append(tr)
        hist["val"].append(va)
        if verbose:
            print(f"  epoch {epoch}: train {tr:.4f} val {va:.4f}")
        if va < best_val - 1e-4:
            best_val, best_params, bad_epochs = va, params, 0
        else:
            bad_epochs += 1
            if bad_epochs >= tcfg.patience:
                break
    return best_params, hist


def model_loglik(cfg, params, seqs, t_end: float, batch_size: int = 64
                 ) -> float:
    """Mean per-sequence model log-likelihood of sampled/test sequences."""
    if not seqs:
        return float("nan")
    max_len = ds.max_events(seqs) + 1
    out, cnt = 0.0, 0
    fn = jax.jit(jax.vmap(
        lambda t, k, m: tpp.loglik(cfg, params, t, k, m, t_end)))
    for batch in ds.batches(seqs, batch_size, max_len, shuffle=False):
        lls = fn(jnp.asarray(batch["times"]), jnp.asarray(batch["types"]),
                 jnp.asarray(batch["mask"]))
        out += float(jnp.sum(lls))
        cnt += len(lls)
    return out / max(cnt, 1)


# ---------------------------------------------------------------------------
# generic LM training step (smoke tests + dry-run)
# ---------------------------------------------------------------------------

def make_train_step(cfg, optim: opt.Adam, seq_rule=None):
    model = registry.get_model(cfg)

    def train_step(params, state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, batch, seq_rule=seq_rule))(params)
        params, state = optim.update(grads, state, params)
        return params, state, loss

    return train_step
