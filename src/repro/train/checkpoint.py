"""Minimal msgpack checkpointing for pytrees of jnp arrays."""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _encode(obj):
    if isinstance(obj, (jnp.ndarray, np.ndarray)):
        arr = np.asarray(obj)
        if arr.dtype == jnp.bfloat16:
            return {"__arr__": arr.astype(np.float32).tobytes(),
                    "dtype": "bfloat16", "shape": list(arr.shape)}
        return {"__arr__": arr.tobytes(), "dtype": str(arr.dtype),
                "shape": list(arr.shape)}
    raise TypeError(type(obj))


def _decode(obj):
    if "__arr__" in obj:
        dt = obj["dtype"]
        if dt == "bfloat16":
            arr = np.frombuffer(obj["__arr__"], np.float32)
            return jnp.asarray(arr.reshape(obj["shape"]), jnp.bfloat16)
        arr = np.frombuffer(obj["__arr__"], np.dtype(dt))
        return jnp.asarray(arr.reshape(obj["shape"]))
    return obj


def save(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    payload = {"structure": str(treedef),
               "leaves": [ _encode(l) for l in leaves ]}
    with open(path, "wb") as f:
        f.write(msgpack.packb(payload, default=_encode))


def load(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), object_hook=_decode,
                                  strict_map_key=False)
    leaves = [_decode(l) if isinstance(l, dict) else l
              for l in payload["leaves"]]
    _, treedef = jax.tree.flatten(like)
    return jax.tree.unflatten(treedef, leaves)
