"""Adam optimizer + schedules (pure JAX pytrees; optax is not available).

The moment dtype follows the parameter dtype by default (bf16 moments for
the bf16 mega-configs keep the dry-run optimizer-state footprint honest;
f32 for the small f32 TPP models)."""
from __future__ import annotations

import math
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


class Adam(NamedTuple):
    init: Callable
    update: Callable


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adam(lr, *, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0, clip_norm: float = 1.0,
         schedule: Optional[Callable] = None) -> Adam:
    """lr: float or callable(step)->lr. Returns (init, update)."""

    def init(params):
        zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x), p)
        return AdamState(jnp.zeros((), jnp.int32), zeros(params),
                         zeros(params))

    def update(grads, state: AdamState, params):
        step = state.step + 1
        lr_t = lr(step) if callable(lr) else lr
        if schedule is not None:
            lr_t = lr_t * schedule(step)
        if clip_norm and clip_norm > 0:
            g_norm = global_norm(grads)
            scale = jnp.minimum(1.0, clip_norm / jnp.maximum(g_norm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m32 = m.astype(jnp.float32)
            v32 = v.astype(jnp.float32)
            m_new = b1 * m32 + (1 - b1) * gf
            v_new = b2 * v32 + (1 - b2) * gf * gf
            mhat = m_new / (1 - b1 ** step.astype(jnp.float32))
            vhat = v_new / (1 - b2 ** step.astype(jnp.float32))
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr_t * delta
            return p_new.astype(p.dtype), m_new.astype(m.dtype), \
                v_new.astype(v.dtype)

        out = jax.tree.map(upd, grads, state.mu, state.nu, params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        return new_params, AdamState(step, new_mu, new_nu)

    return Adam(init, update)


def cosine_warmup(warmup: int, total: int, floor: float = 0.1):
    def sched(step):
        s = step.astype(jnp.float32)
        warm = s / max(1, warmup)
        prog = jnp.clip((s - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(math.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return sched
