from . import checkpoint, optimizer, trainer
