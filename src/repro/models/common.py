"""Shared building blocks for the model zoo (pure JAX, no flax).

Parameters are plain nested dicts of jnp arrays; each family module also
exposes a parallel tree of logical-axis tuples consumed by
``repro.distributed.sharding.Rules``.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Params = Dict
Axes = Dict


def get_dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(rng, shape, in_axis_size, dtype):
    scale = 1.0 / math.sqrt(max(1, in_axis_size))
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


def embed_init(rng, shape, dtype):
    return (jax.random.normal(rng, shape, jnp.float32) * 0.02).astype(dtype)


def stack_layer_init(init_one, rng, num_layers):
    """vmap an init fn over layer index -> stacked [L, ...] params."""
    rngs = jax.random.split(rng, num_layers)
    return jax.vmap(init_one)(rngs)


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # [head_dim/2]


def apply_rope(x, positions, theta: float):
    """x: [B, S, H, Dh]; positions: [B, S] (int)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B,S,Dh/2]
    sin = jnp.sin(angles)[:, :, None, :]
    cos = jnp.cos(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention core
# ---------------------------------------------------------------------------

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu(p, x):
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


def swiglu_init(rng, d_model, d_ff, dtype):
    r1, r2, r3 = jax.random.split(rng, 3)
    return {
        "w_gate": dense_init(r1, (d_model, d_ff), d_model, dtype),
        "w_up": dense_init(r2, (d_model, d_ff), d_model, dtype),
        "w_down": dense_init(r3, (d_ff, d_model), d_ff, dtype),
    }


def swiglu_axes():
    return {"w_gate": ("p_embed", "mlp"), "w_up": ("p_embed", "mlp"),
            "w_down": ("mlp", "p_embed")}


# --- Mixture of Experts (capacity-based top-k dispatch, expert-parallel) ---

def moe_ffn(cfg, p, x):
    """x: [B, S, D] -> (y, aux_loss).

    Classic Mesh-TF / Switch *grouped* capacity dispatch: tokens are
    grouped by batch row so the dispatch tensor is [B, S, E, C] with
    C = ceil(S*K*cf/E) — O(T * S * K) instead of O(T^2). One-hot
    dispatch/combine einsums keep the expert dim shardable over the
    `model` mesh axis (expert parallelism); groups ride the `data` axis.
    Tokens above per-group capacity are dropped (residual passes through).
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    Gs = min(getattr(cfg, "moe_group_size", 256), B * S)
    T = B * S
    pad = (-T) % Gs
    xt = x.reshape(T, D)
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    x = xt.reshape((T + pad) // Gs, Gs, D)                        # groups
    Gm = x.shape[0]
    logits = jnp.einsum("gsd,de->gse", x, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)   # [Gm,Gs,E]
    gate_vals, gate_idx = lax.top_k(probs, K)                     # [Gm,Gs,K]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = max(1, int(math.ceil(Gs * K * cfg.capacity_factor / E)))
    cap = min(cap, Gs * K)
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)       # [Gm,Gs,K,E]
    # queue position of each (s, k) slot within its expert, k-major then s
    flat = onehot.transpose(0, 2, 1, 3).reshape(Gm, K * Gs, E)
    pos = jnp.cumsum(flat, axis=1) - flat                         # [Gm,K*Gs,E]
    pos = pos.reshape(Gm, K, Gs, E).transpose(0, 2, 1, 3)         # [Gm,Gs,K,E]
    keep = (pos < cap) & (onehot > 0)
    pos = jnp.where(keep, pos, 0).astype(jnp.int32)
    cap_oh = (jax.nn.one_hot(pos, cap, dtype=jnp.float32)
              * keep[..., None])                                  # [Gm,Gs,K,E,C]
    dispatch = cap_oh.sum(axis=2)                                 # [Gm,Gs,E,C]
    combine = jnp.einsum("gsk,gskec->gsec",
                         gate_vals.astype(jnp.float32), cap_oh)
    xe = jnp.einsum("gsec,gsd->egcd", dispatch.astype(x.dtype), x)
    g = jnp.einsum("egcd,edf->egcf", xe, p["w_gate"])
    u = jnp.einsum("egcd,edf->egcf", xe, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    ye = jnp.einsum("egcf,efd->egcd", h, p["w_down"])
    y = jnp.einsum("gsec,egcd->gsd", combine.astype(x.dtype), ye)
    # Switch-style load-balance loss
    density = jnp.mean(onehot[:, :, 0, :], axis=(0, 1))           # top-1 fraction
    router_mean = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(density * router_mean)
    y = y.reshape(Gm * Gs, D)[:T]
    return y.reshape(B, S, D), aux


def moe_init(rng, cfg, dtype):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    r0, r1, r2, r3 = jax.random.split(rng, 4)
    return {
        "router": dense_init(r0, (D, E), D, jnp.float32),
        "w_gate": dense_init(r1, (E, D, F), D, dtype),
        "w_up": dense_init(r2, (E, D, F), D, dtype),
        "w_down": dense_init(r3, (E, F, D), F, dtype),
    }


def moe_axes():
    return {"router": ("p_embed", "experts"),
            "w_gate": ("experts", "p_embed", "mlp"),
            "w_up": ("experts", "p_embed", "mlp"),
            "w_down": ("experts", "mlp", "p_embed")}


# ---------------------------------------------------------------------------
# attention params
# ---------------------------------------------------------------------------

def attn_init(rng, cfg, dtype):
    D, H, KV, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    r = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(r[0], (D, H, Dh), D, dtype),
        "wk": dense_init(r[1], (D, KV, Dh), D, dtype),
        "wv": dense_init(r[2], (D, KV, Dh), D, dtype),
        "wo": dense_init(r[3], (H, Dh, D), H * Dh, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, Dh), dtype)
        p["bk"] = jnp.zeros((KV, Dh), dtype)
        p["bv"] = jnp.zeros((KV, Dh), dtype)
    return p


def attn_axes(cfg):
    a = {"wq": ("p_embed", "heads", "qkv"), "wk": ("p_embed", "kv_heads", "qkv"),
         "wv": ("p_embed", "kv_heads", "qkv"), "wo": ("heads", "qkv", "p_embed")}
    if cfg.qkv_bias:
        a.update({"bq": ("heads", "qkv"), "bk": ("kv_heads", "qkv"),
                  "bv": ("kv_heads", "qkv")})
    return a


def attn_qkv(p, x, cfg, positions):
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_out(p, o):
    return jnp.einsum("bshe,hed->bsd", o, p["wo"])
