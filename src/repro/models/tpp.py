"""The paper's CDF-based Transformer TPP (Sec. 4.2).

Encoder: Transformer over event embeddings (type embedding + temporal
encoding). Three encoder variants are supported with their published
temporal encodings and attention forms (App. D.2):

  - thp    : sinusoidal encoding of t (Eq. 27), standard causal MHA
  - sahp   : shifted sinusoidal with learnable frequencies (Eq. 28),
             standard causal MHA
  - attnhp : scaled sinusoidal (Eq. 29), unnormalized Gaussian-kernel
             attention with a +1 denominator and tanh output (Eq. 31),
             Q/K/V computed from concat(1, z(t), h^{l-1}) (Eqs. 32-34)

Decoder: log-normal mixture over the next inter-event interval +
categorical head over the next event type (Sec. 4.2), both read from the
history embedding h(t_i).

All functions are written for a SINGLE sequence (no batch dim) and are
vmapped by the trainer / samplers; this is what lets the fully-jitted
speculative sampler run per-lane lengths under ``jax.vmap``.

Event type ``K`` (== cfg.num_marks) is the BOS sentinel that seeds the
history (Algorithm 1's initial event (t_0, k_0)).
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels import ops
from ..kernels.policy import KernelPolicy
from ..kernels.ref import INVALID_POS
from . import common as cm

NEG_INF = -1e30


def resolve_policy(cfg) -> KernelPolicy:
    """The TPP inference kernel policy. "auto" resolves to the reference
    off-TPU (vmapped interpret-mode kernels serialize the lane batch —
    see ``kernels.policy``) and to compiled Pallas on TPU; an explicit
    backend ("pallas"/"ref") wins either way."""
    return cfg.kernel_policy.resolve(default_backend="ref")


# ---------------------------------------------------------------------------
# temporal encodings (Eqs. 27-29)
# ---------------------------------------------------------------------------

def temporal_encoding(cfg, params, t):
    """t: [...] -> z(t): [..., D]."""
    D = cfg.d_model
    j = jnp.arange(D, dtype=jnp.float32)
    even = (j % 2 == 0)
    jj = jnp.where(even, j, j - 1)            # paired exponent
    t = t[..., None].astype(jnp.float32)
    if cfg.encoder == "thp":
        angle = t / jnp.power(10000.0, jj / D)
        return jnp.where(even, jnp.sin(angle), jnp.cos(angle))
    if cfg.encoder == "sahp":
        w = params["enc_freq"]                # [D] learnable
        angle = j / jnp.power(10000.0, jj / D) + w * t
        return jnp.where(even, jnp.sin(angle), jnp.cos(angle))
    if cfg.encoder == "attnhp":
        m, M = cfg.attnhp_m, cfg.attnhp_M
        angle = t / m * jnp.power(5.0 * M / m, jj / D)
        return jnp.sin(angle)                 # Eq. 29: sin for both parities
    raise ValueError(cfg.encoder)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_params(cfg, rng):
    D, H, Dh, M, K = (cfg.d_model, cfg.num_heads, cfg.head_dim, cfg.num_mix,
                      cfg.num_marks)
    dtype = cm.get_dtype(cfg.dtype)
    rs = jax.random.split(rng, 8)

    qkv_in = 2 * D + 1 if cfg.encoder == "attnhp" else D

    def one_layer(r):
        rr = jax.random.split(r, 6)
        return {
            "ln1": jnp.zeros((D,), dtype),
            "ln2": jnp.zeros((D,), dtype),
            "wq": cm.dense_init(rr[0], (qkv_in, H, Dh), qkv_in, dtype),
            "wk": cm.dense_init(rr[1], (qkv_in, H, Dh), qkv_in, dtype),
            "wv": cm.dense_init(rr[2], (qkv_in, H, Dh), qkv_in, dtype),
            "wo": cm.dense_init(rr[3], (H, Dh, D), H * Dh, dtype),
            "w1": cm.dense_init(rr[4], (D, cfg.d_ff), D, dtype),
            "w2": cm.dense_init(rr[5], (cfg.d_ff, D), cfg.d_ff, dtype),
        }

    params = {
        # K marks + BOS sentinel row
        "embed": cm.embed_init(rs[0], (K + 1, D), dtype),
        "layers": cm.stack_layer_init(one_layer, rs[1], cfg.num_layers),
        "final_ln": jnp.zeros((D,), dtype),
        # decoder (Sec 4.2): E in R^{3D x D}, then V_w/V_mu/V_sigma
        "E": cm.dense_init(rs[2], (D, 3 * D), D, dtype),
        "V_w": cm.dense_init(rs[3], (D, M), D, dtype),
        "b_w": jnp.zeros((M,), dtype),
        "V_mu": cm.dense_init(rs[4], (D, M), D, dtype),
        "b_mu": jnp.zeros((M,), dtype),
        "V_sigma": cm.dense_init(rs[5], (D, M), D, dtype),
        "b_sigma": jnp.zeros((M,), dtype),
        # type head: V2 tanh(V1 h + b1) + b2
        "V_k1": cm.dense_init(rs[6], (D, D), D, dtype),
        "b_k1": jnp.zeros((D,), dtype),
        "V_k2": cm.dense_init(rs[7], (D, K), D, dtype),
        "b_k2": jnp.zeros((K,), dtype),
    }
    if cfg.encoder == "sahp":
        params["enc_freq"] = jnp.ones((D,), jnp.float32) * 0.1
    return params


def logical_axes(cfg):
    layer = {"ln1": ("layers", None), "ln2": ("layers", None),
             "wq": ("layers", None, "heads", "qkv"),
             "wk": ("layers", None, "heads", "qkv"),
             "wv": ("layers", None, "heads", "qkv"),
             "wo": ("layers", "heads", "qkv", None),
             "w1": ("layers", None, "mlp"), "w2": ("layers", "mlp", None)}
    axes = {"embed": ("marks", None), "layers": layer, "final_ln": (None,),
            "E": (None, None), "V_w": (None, "mix"), "b_w": ("mix",),
            "V_mu": (None, "mix"), "b_mu": ("mix",),
            "V_sigma": (None, "mix"), "b_sigma": ("mix",),
            "V_k1": (None, None), "b_k1": (None,),
            "V_k2": (None, "marks"), "b_k2": ("marks",)}
    if cfg.encoder == "sahp":
        axes["enc_freq"] = (None,)
    return axes


# ---------------------------------------------------------------------------
# encoder blocks (single sequence: x [N, D])
# ---------------------------------------------------------------------------

def _qkv_input(cfg, x, z):
    """AttNHP concatenates (1, z(t), h) before Q/K/V (Eqs. 32-34)."""
    if cfg.encoder == "attnhp":
        ones = jnp.ones(x.shape[:-1] + (1,), x.dtype)
        return jnp.concatenate([ones, z.astype(x.dtype), x], axis=-1)
    return x


def _attend(cfg, lp, q, kc, vc, q_idx, kv_idx):
    """q: [c, H, Dh]; kc/vc: [Nc, H, Dh]; idx: event ordinals (int).

    THP/SAHP: softmax attention. AttNHP: f = exp(q.k/sqrt(D)) with
    denominator (1 + sum f) and tanh on the combined output.
    """
    Dh = q.shape[-1]
    s = jnp.einsum("chd,shd->hcs", q.astype(jnp.float32),
                   kc.astype(jnp.float32)) / math.sqrt(Dh)
    mask = kv_idx[None, None, :] <= q_idx[None, :, None]
    if cfg.encoder == "attnhp":
        f = jnp.where(mask, jnp.exp(jnp.minimum(s, 30.0)), 0.0)
        denom = 1.0 + jnp.sum(f, axis=-1, keepdims=True)
        w = f / denom
    else:
        s = jnp.where(mask, s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        w = jnp.where(jnp.any(mask, -1, keepdims=True), w, 0.0)
    o = jnp.einsum("hcs,shd->chd", w, vc.astype(jnp.float32))
    out = jnp.einsum("chd,hdo->co", o, lp["wo"].astype(jnp.float32))
    if cfg.encoder == "attnhp":
        out = jnp.tanh(out)
    return out.astype(q.dtype)


def _layer_kv(cfg, lp, x, z):
    xin = _qkv_input(cfg, cm.rms_norm(x, lp["ln1"]), z)
    k = jnp.einsum("sd,dhe->she", xin, lp["wk"])
    v = jnp.einsum("sd,dhe->she", xin, lp["wv"])
    q = jnp.einsum("sd,dhe->she", xin, lp["wq"])
    return q, k, v


def encode(cfg, params, times, types):
    """Full causal encoding. times/types: [N] -> h: [N, D]."""
    z = temporal_encoding(cfg, params, times)
    x = params["embed"][types].astype(z.dtype) + z
    x = x.astype(cm.get_dtype(cfg.dtype))
    N = x.shape[0]
    idx = jnp.arange(N, dtype=jnp.int32)

    def body(x, lp):
        q, k, v = _layer_kv(cfg, lp, x, z)
        x = x + _attend(cfg, lp, q, k, v, idx, idx)
        xn = cm.rms_norm(x, lp["ln2"])
        x = x + jnp.einsum("sf,fd->sd", jax.nn.gelu(
            jnp.einsum("sd,df->sf", xn, lp["w1"])), lp["w2"])
        return x, None

    x, _ = lax.scan(body, x, params["layers"])
    return cm.rms_norm(x, params["final_ln"])


# ---------------------------------------------------------------------------
# incremental encoding with KV cache (for sampling)
# ---------------------------------------------------------------------------

def init_cache(cfg, max_events: int):
    dtype = cm.get_dtype(cfg.dtype)
    L, H, Dh = cfg.num_layers, cfg.num_heads, cfg.head_dim
    return {"k": jnp.zeros((L, max_events, H, Dh), dtype),
            "v": jnp.zeros((L, max_events, H, Dh), dtype),
            "idx": jnp.full((max_events,), INVALID_POS, jnp.int32),
            "len": jnp.zeros((), jnp.int32)}


def extend(cfg, params, cache, times, types):
    """Append c events; return (h [c, D], new cache).

    This one entry point is decode (c=1) and the speculative verify
    (c = gamma+1, Algorithm 1's parallel target forward). With a Pallas
    policy the multi-query attention against the cache runs through the
    ``spec_verify_attention`` kernel (all c queries in one pass over the
    KV blocks); the reference path keeps the einsum attention. The
    AttNHP encoder's +1-denominator kernel form stays on the reference.

    Correct under rollback either way: slot == ordinal in this cache, so
    a stale entry's position always exceeds any live query position and
    causal masking hides it (the idx buffer encodes the same fact).
    """
    z = temporal_encoding(cfg, params, times)
    x = params["embed"][types].astype(z.dtype) + z
    x = x.astype(cm.get_dtype(cfg.dtype))
    c = x.shape[0]
    start = cache["len"]
    slots = start + jnp.arange(c, dtype=jnp.int32)
    idx_new = cache["idx"].at[slots].set(slots)
    pol = resolve_policy(cfg)
    use_kernel = pol.use_pallas and cfg.encoder != "attnhp"

    def attend(lp, q, kc, vc):
        if use_kernel:
            o = ops.spec_verify_attention_seq(q, kc, vc, start, policy=pol)
            out = jnp.einsum("chd,hdo->co", o.astype(jnp.float32),
                             lp["wo"].astype(jnp.float32))
            return out.astype(q.dtype)
        return _attend(cfg, lp, q, kc, vc, slots, idx_new)

    def body(x, layer_in):
        lp, kc, vc = layer_in
        q, k, v = _layer_kv(cfg, lp, x, z)
        kc = kc.at[slots].set(k.astype(kc.dtype))
        vc = vc.at[slots].set(v.astype(vc.dtype))
        x = x + attend(lp, q, kc, vc)
        xn = cm.rms_norm(x, lp["ln2"])
        x = x + jnp.einsum("sf,fd->sd", jax.nn.gelu(
            jnp.einsum("sd,df->sf", xn, lp["w1"])), lp["w2"])
        return x, (kc, vc)

    x, (k_new, v_new) = lax.scan(body, x,
                                 (params["layers"], cache["k"], cache["v"]))
    h = cm.rms_norm(x, params["final_ln"])
    return h, {"k": k_new, "v": v_new, "idx": idx_new, "len": start + c}


def rollback(cache, new_len):
    """Invalidate every cache entry with ordinal >= new_len (O(1))."""
    idx = jnp.where(cache["idx"] < new_len, cache["idx"], INVALID_POS)
    return {"k": cache["k"], "v": cache["v"], "idx": idx,
            "len": jnp.asarray(new_len, jnp.int32)}


# ---------------------------------------------------------------------------
# paged KV cache (the serving engine's block-table pool)
# ---------------------------------------------------------------------------

def init_kv_pages(cfg, n_pages: int, page_size: int):
    """Paged KV pool for the TPP encoder: {k, v}: [L, P, page, H, Dh].

    The TPP encoder has no GQA (every head keeps its own KV), so the KV
    head axis equals ``cfg.num_heads`` and ``spec_verify_attention``
    runs with group size 1.
    """
    dtype = cm.get_dtype(cfg.dtype)
    L, H, Dh = cfg.num_layers, cfg.num_heads, cfg.head_dim
    return {"k": jnp.zeros((L, n_pages, page_size, H, Dh), dtype),
            "v": jnp.zeros((L, n_pages, page_size, H, Dh), dtype)}


def extend_paged(cfg, params, pages, block_tables, lens, times, types, *,
                 nvalid=None, policy: KernelPolicy = None, max_kv: int = 0):
    """Batched TPP extend over a paged pool: append ``c`` events per
    sequence and return (h [S, c, D], new pages).

    times/types: [S, c] absolute event times / marks written at logical
    positions lens[s]..lens[s]+c-1 through block_tables [S, NB]. This is
    the TPP analogue of ``transformer.extend_paged`` — one entry point
    for decode (c=1), the speculative verify (c=gamma+1) and chunked
    prefill (``nvalid`` masks the tail of a partial chunk; masked
    positions write to the reserved null page 0).

    Restricted to the softmax encoders (thp/sahp): AttNHP's
    +1-denominator attention has no paged-kernel form and stays on the
    dense reference path.
    """
    if cfg.encoder == "attnhp":
        raise ValueError("extend_paged supports the softmax encoders "
                         "(thp/sahp); attnhp serves through the dense "
                         "cache")
    z = temporal_encoding(cfg, params, times)         # [S, c, D]
    x = params["embed"][types].astype(z.dtype) + z
    x = x.astype(cm.get_dtype(cfg.dtype))
    S, c = types.shape
    P, page = pages["k"].shape[1], pages["k"].shape[2]
    NB = block_tables.shape[1]
    H, Dh = pages["k"].shape[3], pages["k"].shape[4]

    lens = lens.astype(jnp.int32)
    positions = lens[:, None] + jnp.arange(c, dtype=jnp.int32)  # [S, c]
    blk_idx = positions // page
    blk = jnp.take_along_axis(block_tables.astype(jnp.int32),
                              jnp.minimum(blk_idx, NB - 1), axis=1)
    keep = blk_idx < NB
    if nvalid is not None:
        keep &= jnp.arange(c, dtype=jnp.int32)[None, :] < nvalid[:, None]
    blk = jnp.where(keep, blk, 0)                     # null page 0
    flat = (blk * page + positions % page).reshape(-1)

    def body(x, layer_in):
        lp, kp, vp = layer_in
        xn = cm.rms_norm(x, lp["ln1"])
        q = jnp.einsum("bsd,dhe->bshe", xn, lp["wq"])
        k = jnp.einsum("bsd,dhe->bshe", xn, lp["wk"])
        v = jnp.einsum("bsd,dhe->bshe", xn, lp["wv"])
        kp = kp.reshape(P * page, H, Dh).at[flat].set(
            k.reshape(S * c, H, Dh).astype(kp.dtype)
        ).reshape(P, page, H, Dh)
        vp = vp.reshape(P * page, H, Dh).at[flat].set(
            v.reshape(S * c, H, Dh).astype(vp.dtype)
        ).reshape(P, page, H, Dh)
        o = ops.spec_verify_attention(q, kp, vp, block_tables, lens,
                                      max_kv=max_kv, policy=policy)
        out = jnp.einsum("bchd,hdo->bco", o.astype(jnp.float32),
                         lp["wo"].astype(jnp.float32)).astype(x.dtype)
        x = x + out
        xn2 = cm.rms_norm(x, lp["ln2"])
        x = x + jnp.einsum("bsf,fd->bsd", jax.nn.gelu(
            jnp.einsum("bsd,df->bsf", xn2, lp["w1"])), lp["w2"])
        return x, (kp, vp)

    x, (k_new, v_new) = lax.scan(body, x,
                                 (params["layers"], pages["k"], pages["v"]))
    h = cm.rms_norm(x, params["final_ln"])
    return h, {"k": k_new, "v": v_new}


def prefill_paged(cfg, params, pages, block_tables, lens, times, types,
                  nvalid, *, policy: KernelPolicy = None, max_kv: int = 0):
    """Chunked history prefill through the paged pool (= ``extend_paged``
    with a per-sequence valid-length mask)."""
    return extend_paged(cfg, params, pages, block_tables, lens, times,
                        types, nvalid=nvalid, policy=policy, max_kv=max_kv)


# ---------------------------------------------------------------------------
# decoder heads (Sec. 4.2)
# ---------------------------------------------------------------------------

class MixParams(NamedTuple):
    log_w: jnp.ndarray   # [..., M] log mixture weights
    mu: jnp.ndarray      # [..., M]
    sigma: jnp.ndarray   # [..., M]


def interval_params(cfg, params, h) -> MixParams:
    e = jnp.einsum("...d,de->...e", h, params["E"])
    e1, e2, e3 = jnp.split(e, 3, axis=-1)
    logit_w = jnp.einsum("...d,dm->...m", e1, params["V_w"]) + params["b_w"]
    log_w = jax.nn.log_softmax(logit_w.astype(jnp.float32), axis=-1)
    mu = (jnp.einsum("...d,dm->...m", e2, params["V_mu"])
          + params["b_mu"]).astype(jnp.float32)
    log_sigma = (jnp.einsum("...d,dm->...m", e3, params["V_sigma"])
                 + params["b_sigma"]).astype(jnp.float32)
    log_sigma = jnp.clip(log_sigma, math.log(cfg.sigma_min),
                         math.log(cfg.sigma_max))
    return MixParams(log_w, mu, jnp.exp(log_sigma))


def type_logits(cfg, params, h):
    t = jnp.tanh(jnp.einsum("...d,de->...e", h, params["V_k1"])
                 + params["b_k1"])
    return (jnp.einsum("...d,dk->...k", t, params["V_k2"])
            + params["b_k2"]).astype(jnp.float32)


def sample_interval(rng, mix: MixParams):
    """App. A.1: z ~ Cat(w), tau = exp(mu_z + sigma_z * eps)."""
    r1, r2 = jax.random.split(rng)
    comp = jax.random.categorical(r1, mix.log_w, axis=-1)
    eps = jax.random.normal(r2, comp.shape)
    mu = jnp.take_along_axis(mix.mu, comp[..., None], -1)[..., 0]
    sigma = jnp.take_along_axis(mix.sigma, comp[..., None], -1)[..., 0]
    return jnp.exp(mu + sigma * eps)


def interval_logpdf(mix: MixParams, tau, policy: KernelPolicy = None):
    """log g(tau). ``policy=None`` keeps the differentiable reference
    (training); inference callers pass ``resolve_policy(cfg)`` to run
    the fused Pallas kernel."""
    return ops.lognorm_mix_logpdf(tau, mix.log_w, mix.mu, mix.sigma,
                                  policy=policy)


def interval_logsf(mix: MixParams, tau, policy: KernelPolicy = None):
    """log(1 - G(tau)). Same policy contract as ``interval_logpdf``."""
    return ops.lognorm_mix_logsf(tau, mix.log_w, mix.mu, mix.sigma,
                                 policy=policy)


# ---------------------------------------------------------------------------
# log likelihood (Eq. 2), single sequence
# ---------------------------------------------------------------------------

def loglik(cfg, params, times, types, mask, t_end):
    """times/types/mask: [N] (positions with mask==0 are padding).

    Returns the CDF-form log-likelihood (Eq. 2) of one sequence on (0, T].
    """
    N = times.shape[0]
    n = jnp.sum(mask).astype(jnp.int32)
    # encoder input: BOS at t=0 followed by the (padded) events
    enc_t = jnp.concatenate([jnp.zeros((1,), times.dtype), times])
    enc_k = jnp.concatenate(
        [jnp.full((1,), cfg.num_marks, jnp.int32), types])
    h = encode(cfg, params, enc_t, enc_k)      # [N+1, D]
    h_hist = h[:-1]                            # h(t_{i-1}) for event i
    prev_t = enc_t[:-1]
    tau = jnp.maximum(times - prev_t, 1e-9)
    mix = interval_params(cfg, params, h_hist)
    lp_tau = interval_logpdf(mix, tau)
    logits = type_logits(cfg, params, h_hist)
    lp_k = jnp.take_along_axis(jax.nn.log_softmax(logits, -1),
                               types[..., None], -1)[..., 0]
    ev_ll = jnp.sum((lp_tau + lp_k) * mask)
    # survival of the tail (no event in (t_N, T]) from h(t_N) = h[n]
    h_last = h[n]
    t_last = jnp.where(n > 0, times[jnp.maximum(n - 1, 0)], 0.0)
    mix_last = interval_params(cfg, params, h_last)
    tail = interval_logsf(mix_last, jnp.maximum(t_end - t_last, 1e-9))
    return ev_ll + tail


# ---------------------------------------------------------------------------
# forecasting helpers: per-time-bin event counts from sampled rollouts
# ---------------------------------------------------------------------------

def bin_counts(times, n_valid, t0, t1, bins: int):
    """Count sampled events per time bin over (t0, t1].

    times: [..., E] padded event-time buffers; n_valid: [...] number of
    live entries per buffer. Returns int32 counts [..., bins] where bin b
    covers (t0 + b*w, t0 + (b+1)*w] with w = (t1 - t0)/bins — the
    half-open-on-the-left convention matches the samplers' ``t <= t_end``
    horizon test, so an event exactly at t1 lands in the last bin and the
    history's anchor event at t0 is excluded.

    This is the device-side reduction the forecast aggregator folds each
    wave through; it never materializes anything per-rollout beyond the
    [..., bins] counts.
    """
    times = jnp.asarray(times, jnp.float32)
    E = times.shape[-1]
    width = (jnp.asarray(t1, jnp.float32) - t0) / bins
    rel = times - t0
    # ceil(rel/width) - 1 maps (t0, t0+w] -> 0 under the left-open rule
    idx = jnp.ceil(rel / width).astype(jnp.int32) - 1
    valid = (jnp.arange(E, dtype=jnp.int32) < n_valid[..., None])
    valid &= (rel > 0) & (idx < bins)
    idx = jnp.clip(idx, 0, bins - 1)
    one = valid.astype(jnp.int32)

    def scatter(i, o):
        return jnp.zeros((bins,), jnp.int32).at[i].add(o)

    flat_idx = idx.reshape((-1, E))
    flat_one = one.reshape((-1, E))
    out = jax.vmap(scatter)(flat_idx, flat_one)
    return out.reshape(times.shape[:-1] + (bins,))
