from . import common, encdec, mamba, registry, rglru, tpp, transformer
from .registry import ModelApi, abstract_params, get_model
