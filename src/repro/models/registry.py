"""Family registry: maps ``cfg.family`` to the implementing module and
exposes a uniform functional API used by the trainer, server, and dry-run.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from . import encdec, mamba, rglru, transformer

_FAMILIES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": mamba,
    "hybrid": rglru,
    "encdec": encdec,
}


class ModelApi(NamedTuple):
    init_params: Any
    logical_axes: Any
    forward: Any
    loss_fn: Any
    init_cache: Any
    cache_axes: Any
    prefill: Any
    extend: Any


def get_model(cfg) -> ModelApi:
    mod = _FAMILIES[cfg.family]
    return ModelApi(
        init_params=lambda rng: mod.init_params(cfg, rng),
        logical_axes=lambda: mod.logical_axes(cfg),
        forward=lambda params, batch, **kw: mod.forward(cfg, params, batch, **kw),
        loss_fn=lambda params, batch, **kw: mod.loss_fn(cfg, params, batch, **kw),
        init_cache=lambda batch_size, max_len, **kw: mod.init_cache(
            cfg, batch_size, max_len, **kw),
        cache_axes=lambda: mod.cache_axes(cfg),
        prefill=lambda params, batch, max_len: mod.prefill(cfg, params, batch,
                                                           max_len),
        extend=lambda params, cache, tokens, **kw: mod.extend(
            cfg, params, cache, tokens, **kw),
    )


def abstract_params(cfg, rng=None):
    """Shape/dtype tree of the params without allocating (for dry-run)."""
    mod = _FAMILIES[cfg.family]
    # repro: ignore[rng-raw-prngkey] -- eval_shape dry-run fallback; the key is abstract and never consumed for randomness
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    return jax.eval_shape(lambda r: mod.init_params(cfg, r), rng)
