"""Mamba-1 selective SSM (falcon-mamba-7b family).

TPU adaptation: the selective scan is executed with
``lax.associative_scan`` (parallel prefix) over the sequence axis instead
of a sequential CUDA kernel — log-depth on the MXU/VPU, shardable over
batch/inner. Decode keeps an O(d_inner x N) recurrent state + a
(conv_width-1) convolution tail; chunked ``extend`` supports speculative
verification (the state checkpoint is the rollback mechanism).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax import lax

from . import common as cm


def _ckpt(cfg, fn):
    """jax.checkpoint with the configured policy."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_params(cfg, rng):
    dtype = cm.get_dtype(cfg.param_dtype)
    D, di, N, R, W = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank,
                      cfg.conv_width)
    r_emb, r_layers, r_head = jax.random.split(rng, 3)

    def one_layer(r):
        rs = jax.random.split(r, 5)
        # S4D-real initialization for A
        A = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))
        r_u = jax.random.fold_in(rs[0], 0)
        r_z = jax.random.fold_in(rs[0], 1)
        return {
            "ln": jnp.zeros((D,), dtype),
            # kept as TWO matrices: a fused [D, 2*di] projection would need
            # a split whose halves straddle `model`-axis shards, costing a
            # collective-permute per layer (EXPERIMENTS.md §Perf pair 1)
            "in_u": cm.dense_init(r_u, (D, di), D, dtype),
            "in_z": cm.dense_init(r_z, (D, di), D, dtype),
            "conv_w": cm.dense_init(rs[1], (di, W), W, dtype),
            "conv_b": jnp.zeros((di,), dtype),
            "x_proj": cm.dense_init(rs[2], (di, R + 2 * N), di, dtype),
            "dt_proj": cm.dense_init(rs[3], (R, di), R, dtype),
            "dt_bias": jnp.full((di,), -4.6, dtype),   # softplus^-1(0.01)
            "A_log": jnp.log(A),
            "D": jnp.ones((di,), dtype),
            "out_proj": cm.dense_init(rs[4], (di, D), di, dtype),
        }

    return {
        "embed": cm.embed_init(r_emb, (cfg.vocab_size, D), dtype),
        "layers": cm.stack_layer_init(one_layer, r_layers, cfg.num_layers),
        "final_norm": jnp.zeros((D,), dtype),
        "lm_head": cm.dense_init(r_head, (D, cfg.vocab_size), D, dtype),
    }


def logical_axes(cfg):
    layer = {
        "ln": ("layers", "p_embed"),
        "in_u": ("layers", "p_embed", "inner"),
        "in_z": ("layers", "p_embed", "inner"),
        "conv_w": ("layers", "inner", None),
        "conv_b": ("layers", "inner"),
        "x_proj": ("layers", "inner", None),
        "dt_proj": ("layers", None, "inner"),
        "dt_bias": ("layers", "inner"),
        "A_log": ("layers", "inner", "state"),
        "D": ("layers", "inner"),
        "out_proj": ("layers", "inner", "p_embed"),
    }
    return {"embed": ("vocab", "embed"), "layers": layer,
            "final_norm": ("p_embed",), "lm_head": ("embed", "vocab")}


# ---------------------------------------------------------------------------
# selective scan
# ---------------------------------------------------------------------------

def _ssm_scan(dA, dBu, h0):
    """h_t = dA_t * h_{t-1} + dBu_t, parallel prefix over axis=1 (S).

    dA, dBu: [B, S, di, N]; h0: [B, di, N]. Returns hs [B, S, di, N].
    """
    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    a_all, b_all = lax.associative_scan(combine, (dA, dBu), axis=1)
    # fold in the initial state: h_t = b_t + (prod a)_t * h0
    return b_all + a_all * h0[:, None]


def _ssm_inner(cfg, p, u, h0):
    """Selective-scan core on a (possibly chunked) span.

    u: [B, c, di] post-conv post-silu (f32). Returns (y [B,c,di] f32,
    h_last [B,di,N] f32)."""
    R, N = cfg.dt_rank, cfg.ssm_state
    f32 = jnp.float32
    proj = jnp.einsum("bci,ie->bce", u.astype(cm.get_dtype(cfg.dtype)),
                      p["x_proj"])
    dt_r, Bc, Cc = jnp.split(proj.astype(f32), [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bcr,ri->bci", dt_r, p["dt_proj"].astype(f32))
        + p["dt_bias"].astype(f32))                        # [B,c,di]
    A = -jnp.exp(p["A_log"].astype(f32))                   # [di, N]
    dA = jnp.exp(dt[..., None] * A)                        # [B,c,di,N]
    dBu = (dt * u)[..., None] * Bc[:, :, None, :]          # [B,c,di,N]
    hs = _ssm_scan(dA, dBu, h0.astype(f32))
    y = jnp.einsum("bcin,bcn->bci", hs, Cc) + p["D"].astype(f32) * u
    return y, hs[:, -1]


def _mamba_mix(cfg, p, x, conv_tail, h0):
    """Core mixer on a chunk. x: [B, c, D] (pre-norm applied by caller).

    conv_tail: [B, W-1, di] previous inputs; h0: [B, di, N].
    Returns (y [B,c,D], new_conv_tail, h_last).

    When ``cfg.ssm_chunk`` divides c, the selective scan runs two-level:
    a sequential ``lax.scan`` over chunks (state carried, chunk body
    rematerialized) with the parallel prefix + output contraction fused
    inside each chunk — the [B,S,di,N] discretized-state tensors never
    exist at full sequence length (EXPERIMENTS.md §Perf pair 1).
    """
    B, c, D = x.shape
    di, N, R, W = cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.conv_width
    f32 = jnp.float32

    u = jnp.einsum("bsd,de->bse", x, p["in_u"])           # [B,c,di]
    z = jnp.einsum("bsd,de->bse", x, p["in_z"])
    # causal depthwise conv with carried tail
    u_ext = jnp.concatenate([conv_tail.astype(u.dtype), u], axis=1)
    idx = jnp.arange(c)[:, None] + jnp.arange(W)[None, :]  # [c, W]
    windows = u_ext[:, idx]                                # [B, c, W, di]
    u = jnp.einsum("bcwi,iw->bci", windows, p["conv_w"]) + p["conv_b"]
    u = jax.nn.silu(u.astype(f32))
    new_tail = u_ext[:, -(W - 1):] if W > 1 else u_ext[:, :0]

    C = cfg.ssm_chunk
    if C and c > C and c % C == 0:
        nch = c // C
        u_ch = u.reshape(B, nch, C, di).transpose(1, 0, 2, 3)

        def chunk_body(h, u_c):
            y_c, h_last = _ssm_inner(cfg, p, u_c, h)
            return h_last, y_c.astype(x.dtype)

        body = _ckpt(cfg, chunk_body) if cfg.remat else chunk_body
        h_last, y_ch = lax.scan(body, h0.astype(f32), u_ch)
        y = y_ch.transpose(1, 0, 2, 3).reshape(B, c, di).astype(f32)
    else:
        y, h_last = _ssm_inner(cfg, p, u, h0)
    y = y * jax.nn.silu(z.astype(f32))
    out = jnp.einsum("bci,id->bcd", y.astype(x.dtype), p["out_proj"])
    return out, new_tail.astype(x.dtype), h_last


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def _run(cfg, params, tokens, cache):
    dtype = cm.get_dtype(cfg.dtype)
    x = params["embed"][tokens].astype(dtype)
    B, c, _ = x.shape

    def scan_body(x, layer_in):
        lp, tail, h0 = layer_in
        y, new_tail, h_last = _mamba_mix(cfg, lp, cm.rms_norm(x, lp["ln"]),
                                         tail, h0)
        return x + y, (new_tail, h_last)

    body = _ckpt(cfg, scan_body) if cfg.remat else scan_body
    if cfg.scan_layers:
        x, (tails, hs) = lax.scan(body, x,
                                  (params["layers"], cache["conv"],
                                   cache["ssm"]))
    else:
        tails, hs = [], []
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, (t, h) = body(x, (lp, cache["conv"][i], cache["ssm"][i]))
            tails.append(t)
            hs.append(h)
        tails = jnp.stack(tails)
        hs = jnp.stack(hs)
    new_cache = {"conv": tails, "ssm": hs, "len": cache["len"] + c}
    x = cm.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"]).astype(jnp.float32)
    return logits, new_cache


def init_cache(cfg, batch_size: int, max_len: int = 0):
    """SSM cache is O(1) in sequence length."""
    dtype = cm.get_dtype(cfg.dtype)
    L, di, N, W = cfg.num_layers, cfg.d_inner, cfg.ssm_state, cfg.conv_width
    return {
        "conv": jnp.zeros((L, batch_size, W - 1, di), dtype),
        "ssm": jnp.zeros((L, batch_size, di, N), jnp.float32),
        "len": jnp.zeros((), jnp.int32),
    }


def cache_axes(cfg):
    return {"conv": ("layers", "batch", None, "inner"),
            "ssm": ("layers", "batch", "inner", "state"),
            "len": ()}


def forward(cfg, params, batch, seq_rule=None):
    B = batch["tokens"].shape[0]
    logits, _ = _run(cfg, params, batch["tokens"], init_cache(cfg, B))
    return logits, jnp.float32(0.0)


def loss_fn(cfg, params, batch, seq_rule=None):
    logits, _ = forward(cfg, params, batch)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is None:
        return -jnp.mean(ll)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def extend(cfg, params, cache, tokens, vision_embeds=None):
    return _run(cfg, params, tokens, cache)


def prefill(cfg, params, batch, max_len: int = 0):
    B = batch["tokens"].shape[0]
    return _run(cfg, params, batch["tokens"], init_cache(cfg, B))
