"""Scanned decoder-only Transformer covering the dense / GQA / MoE / VLM
families (mistral-nemo, llama3-405b, llama3.2-1b, qwen2.5, internvl2-LM,
granite-moe, phi3.5-moe).

Layers are stacked on a leading [L] axis and executed with ``lax.scan`` so
compile time is depth-independent (MaxText-style). ``remat`` checkpoints
each scanned block during training.

API (consumed by ``repro.models.registry``):
  init_params(cfg, rng)            -> params
  logical_axes(cfg)                -> tree of logical-axis tuples
  forward(cfg, params, batch)      -> logits [B,S,V]
  loss_fn(cfg, params, batch)      -> scalar CE (+ MoE aux)
  init_cache(cfg, B, max_len)      -> cache
  cache_axes(cfg)                  -> tree
  prefill(cfg, params, batch, cache) -> (logits, cache)
  extend(cfg, params, cache, tokens) -> (logits [B,c,V], cache)
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels import ops
from ..kernels.ref import INVALID_POS
from . import common as cm


def _ckpt(cfg, fn):
    """jax.checkpoint with the configured policy."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)

FLASH_MIN_LEN = 2048


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_params(cfg, rng):
    dtype = cm.get_dtype(cfg.param_dtype)
    r_emb, r_layers, r_head = jax.random.split(rng, 3)

    def one_layer(r):
        ra, rm = jax.random.split(r)
        p = {
            "ln1": jnp.zeros((cfg.d_model,), dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "attn": cm.attn_init(ra, cfg, dtype),
        }
        if cfg.is_moe:
            p["moe"] = cm.moe_init(rm, cfg, dtype)
        else:
            p["mlp"] = cm.swiglu_init(rm, cfg.d_model, cfg.d_ff, dtype)
        return p

    params = {
        "embed": cm.embed_init(r_emb, (cfg.vocab_size, cfg.d_model), dtype),
        "layers": cm.stack_layer_init(one_layer, r_layers, cfg.num_layers),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = cm.dense_init(
            r_head, (cfg.d_model, cfg.vocab_size), cfg.d_model, dtype)
    return params


def logical_axes(cfg):
    layer = {
        "ln1": ("layers", "p_embed"),
        "ln2": ("layers", "p_embed"),
        "attn": {k: ("layers",) + v for k, v in cm.attn_axes(cfg).items()},
    }
    if cfg.is_moe:
        layer["moe"] = {k: ("layers",) + v for k, v in cm.moe_axes().items()}
    else:
        layer["mlp"] = {k: ("layers",) + v for k, v in cm.swiglu_axes().items()}
    axes = {
        "embed": ("vocab", "embed"),
        "layers": layer,
        "final_norm": ("p_embed",),
    }
    if not cfg.tie_embeddings:
        axes["lm_head"] = ("embed", "vocab")
    return axes


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _window(cfg) -> int:
    return cfg.sliding_window


def _attn_full(cfg, p, x, positions):
    """Self-attention over the chunk itself (train / no-cache path)."""
    q, k, v = cm.attn_qkv(p, x, cfg, positions)
    S = x.shape[1]
    if S >= FLASH_MIN_LEN:
        o = ops.flash_attention(q, k, v, positions, positions,
                                window=_window(cfg), softcap=cfg.logit_softcap,
                                use_pallas=cfg.use_pallas)
    else:
        o = ops.naive_attention(q, k, v, positions, positions,
                                window=_window(cfg),
                                softcap=cfg.logit_softcap)
    return cm.attn_out(p, o)


def _block_train(cfg, p, x, positions, seq_rule=None):
    h = _attn_full(cfg, p["attn"], cm.rms_norm(x, p["ln1"]), positions)
    x = x + h
    if seq_rule is not None:
        x = seq_rule(x)
    xn = cm.rms_norm(x, p["ln2"])
    if cfg.is_moe:
        h, aux = cm.moe_ffn(cfg, p["moe"], xn)
    else:
        h, aux = cm.swiglu(p["mlp"], xn), jnp.float32(0.0)
    x = x + h
    if seq_rule is not None:
        x = seq_rule(x)
    return x, aux


def forward(cfg, params, batch, seq_rule=None):
    """Full causal forward. batch: tokens [B,S] (+ vision_embeds [B,P,D])."""
    dtype = cm.get_dtype(cfg.dtype)
    tokens = batch["tokens"]
    x = params["embed"][tokens].astype(dtype)
    if cfg.family == "vlm":
        ve = batch["vision_embeds"].astype(dtype)
        x = jnp.concatenate([ve, x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(x, lp):
        return _block_train(cfg, lp, x, positions, seq_rule=seq_rule)

    body_fn = _ckpt(cfg, body) if cfg.remat else body
    if cfg.scan_layers:
        x, auxs = lax.scan(body_fn, x, params["layers"])
        aux = jnp.sum(auxs)
    else:
        aux = jnp.float32(0.0)
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, a = body_fn(x, lp)
            aux = aux + a
    x = cm.rms_norm(x, params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
    return logits, aux


def loss_fn(cfg, params, batch, seq_rule=None):
    logits, aux = forward(cfg, params, batch, seq_rule=seq_rule)
    labels = batch["labels"]
    if cfg.family == "vlm":  # loss only over text positions
        logits = logits[:, -labels.shape[1]:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is None:
        ce = -jnp.mean(ll)
    else:
        ce = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return ce + cfg.router_aux_weight * aux if cfg.is_moe else ce


# ---------------------------------------------------------------------------
# KV cache / serving
# ---------------------------------------------------------------------------

def init_cache(cfg, batch_size: int, max_len: int):
    dtype = cm.get_dtype(cfg.dtype)
    L, KV, Dh = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    if cfg.sliding_window > 0:
        max_len = min(max_len, cfg.sliding_window)
    return {
        "k": jnp.zeros((L, batch_size, max_len, KV, Dh), dtype),
        "v": jnp.zeros((L, batch_size, max_len, KV, Dh), dtype),
        "pos": jnp.full((batch_size, max_len), INVALID_POS, jnp.int32),
        "len": jnp.zeros((), jnp.int32),
    }


def cache_axes(cfg):
    return {"k": ("layers", "batch", "cache_seq", "kv_heads", "qkv"),
            "v": ("layers", "batch", "cache_seq", "kv_heads", "qkv"),
            "pos": ("batch", "cache_seq"),
            "len": ()}


def _cache_slots(cfg, cache, start, c):
    """Slot indices (ring-buffer aware) for positions start..start+c-1."""
    Smax = cache["k"].shape[2]
    idx = start + jnp.arange(c, dtype=jnp.int32)
    return jnp.where(jnp.asarray(Smax) > 0, idx % Smax, idx), idx


def extend(cfg, params, cache, tokens, vision_embeds=None):
    """Append c tokens (c >= 1) and return logits for each appended position.

    This one entry point implements prefill (len=0, c=S), decode (c=1) and
    speculative verification (c = gamma + 1).
    """
    dtype = cm.get_dtype(cfg.dtype)
    x = params["embed"][tokens].astype(dtype)
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(dtype), x], axis=1)
    B, c, _ = x.shape
    start = cache["len"]
    slots, positions = _cache_slots(cfg, cache, start, c)
    positions = jnp.broadcast_to(positions, (B, c))
    # If the chunk wraps the ring more than once, only the last Smax entries
    # survive — drop the earlier ones so the scatter has no duplicate slots.
    Smax = cache["k"].shape[2]
    w0 = max(0, c - Smax)            # static
    wslots = slots[w0:]
    pos_new = cache["pos"].at[:, wslots].set(positions[:, w0:])

    ring = cfg.sliding_window > 0

    def scan_body(x, layer_in):
        lp, kc, vc = layer_in
        xn = cm.rms_norm(x, lp["ln1"])
        q, k, v = cm.attn_qkv(lp["attn"], xn, cfg, positions)
        if ring:
            # Ring buffer: writing first would overwrite slots that earlier
            # in-chunk queries still see. Attend over cache ∪ chunk, then
            # write the chunk into its (possibly wrapping) slots.
            ka = jnp.concatenate([kc, k.astype(kc.dtype)], axis=1)
            va = jnp.concatenate([vc, v.astype(vc.dtype)], axis=1)
            pa = jnp.concatenate([cache["pos"], positions], axis=1)
            kc = kc.at[:, wslots].set(k[:, w0:].astype(kc.dtype))
            vc = vc.at[:, wslots].set(v[:, w0:].astype(vc.dtype))
        else:
            kc = kc.at[:, wslots].set(k[:, w0:].astype(kc.dtype))
            vc = vc.at[:, wslots].set(v[:, w0:].astype(vc.dtype))
            ka, va, pa = kc, vc, pos_new
        if c >= FLASH_MIN_LEN:
            o = ops.flash_attention(q, ka, va, positions, pa,
                                    window=_window(cfg),
                                    softcap=cfg.logit_softcap,
                                    use_pallas=cfg.use_pallas)
        else:
            o = ops.naive_attention(q, ka, va, positions, pa,
                                    window=_window(cfg),
                                    softcap=cfg.logit_softcap)
        x = x + cm.attn_out(lp["attn"], o)
        xn = cm.rms_norm(x, lp["ln2"])
        if cfg.is_moe:
            h, _ = cm.moe_ffn(cfg, lp["moe"], xn)
        else:
            h = cm.swiglu(lp["mlp"], xn)
        return x + h, (kc, vc)

    if cfg.scan_layers:
        x, (k_new, v_new) = lax.scan(
            scan_body, x, (params["layers"], cache["k"], cache["v"]))
    else:
        ks, vs = [], []
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, (kc, vc) = scan_body(x, (lp, cache["k"][i], cache["v"][i]))
            ks.append(kc)
            vs.append(vc)
        k_new, v_new = jnp.stack(ks), jnp.stack(vs)
    new_cache = {"k": k_new, "v": v_new, "pos": pos_new, "len": start + c}
    x = cm.rms_norm(x, params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
    if vision_embeds is not None:
        logits = logits[:, vision_embeds.shape[1]:]
    return logits, new_cache


def init_kv_pages(cfg, n_pages: int, page_size: int):
    """Physical page pool shared by every sequence: [L, P, page, KV, Dh].

    No position buffer: entry p of a sequence's logical block b sits at
    position b*page + p, so causal masking on logical positions replaces
    both the rollback pos-rewrite and the unwritten-slot sentinel."""
    dtype = cm.get_dtype(cfg.dtype)
    L, KV, Dh = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((L, n_pages, page_size, KV, Dh), dtype),
            "v": jnp.zeros((L, n_pages, page_size, KV, Dh), dtype)}


def extend_paged(cfg, params, pages, block_tables, lens, tokens, *,
                 policy=None, max_kv: int = 0, nvalid=None):
    """Batched extend over a PAGED KV pool: append ``tokens[s]`` at
    positions ``lens[s]..lens[s]+c-1`` for every sequence in one native
    batch (this replaces the serving engine's vmapped per-slot extend).

    pages        : {"k","v"} [L, P, page, KV, Dh] physical page pool.
    block_tables : [S, NB] int32 physical page of each logical block —
                   rows must already cover lens[s]+c entries.
    lens         : [S] int32 committed lengths before the chunk.
    tokens       : [S, c] int32.
    nvalid       : optional [S] int32 — how many of the c tokens are
                   real per sequence. Padding tokens (and whole lanes
                   with nvalid == 0) write the null page, so a batched
                   chunk call can mix sequences with different chunk
                   lengths (chunked prefill) without touching the pages
                   of lanes that are not participating.

    Returns (logits [S, c, V], new pages). Lengths/allocation/rollback
    are the caller's (host) bookkeeping: commit = advance lens, rollback
    = truncate lens — the stale K/V beyond a truncated length is
    causally invisible and overwritten by the next chunk.

    ``max_kv`` is forwarded to the reference spec-verify path so its
    gathered cache matches a dense [S, max_kv] cache bitwise.
    """
    dtype = cm.get_dtype(cfg.dtype)
    S, c = tokens.shape
    P, page = pages["k"].shape[1], pages["k"].shape[2]
    NB = block_tables.shape[1]
    x = params["embed"][tokens].astype(dtype)
    lens = lens.astype(jnp.int32)
    positions = lens[:, None] + jnp.arange(c, dtype=jnp.int32)   # [S, c]
    blk_idx = positions // page
    blk = jnp.take_along_axis(block_tables.astype(jnp.int32),
                              jnp.minimum(blk_idx, NB - 1), axis=1)
    # Writes with no backing block go to the reserved null page 0: a
    # lane running past its table coverage (idle / mid-prefill slots in
    # a mixed round) and the padding tail of a partial chunk must never
    # corrupt another sequence's pages.
    keep = blk_idx < NB
    if nvalid is not None:
        keep &= jnp.arange(c, dtype=jnp.int32)[None, :] \
            < nvalid.astype(jnp.int32)[:, None]
    blk = jnp.where(keep, blk, 0)
    flat = (blk * page + positions % page).reshape(-1)           # [S*c]

    def scan_body(x, layer_in):
        lp, kp, vp = layer_in
        xn = cm.rms_norm(x, lp["ln1"])
        q, k, v = cm.attn_qkv(lp["attn"], xn, cfg, positions)
        KV, Dh = kp.shape[-2], kp.shape[-1]
        kp = kp.reshape(P * page, KV, Dh).at[flat].set(
            k.reshape(S * c, KV, Dh).astype(kp.dtype)).reshape(
                P, page, KV, Dh)
        vp = vp.reshape(P * page, KV, Dh).at[flat].set(
            v.reshape(S * c, KV, Dh).astype(vp.dtype)).reshape(
                P, page, KV, Dh)
        o = ops.spec_verify_attention(q, kp, vp, block_tables, lens,
                                      window=_window(cfg),
                                      softcap=cfg.logit_softcap,
                                      max_kv=max_kv, policy=policy)
        x = x + cm.attn_out(lp["attn"], o)
        xn = cm.rms_norm(x, lp["ln2"])
        if cfg.is_moe:
            # per-sequence dispatch groups: the same capacity/drop
            # decisions as the dense pool's vmapped batch-1 extends
            h = jax.vmap(
                lambda xs: cm.moe_ffn(cfg, lp["moe"], xs[None])[0][0])(xn)
        else:
            h = cm.swiglu(lp["mlp"], xn)
        return x + h, (kp, vp)

    if cfg.scan_layers:
        x, (k_new, v_new) = lax.scan(
            scan_body, x, (params["layers"], pages["k"], pages["v"]))
    else:
        ks, vs = [], []
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, (kp, vp) = scan_body(x, (lp, pages["k"][i], pages["v"][i]))
            ks.append(kp)
            vs.append(vp)
        k_new, v_new = jnp.stack(ks), jnp.stack(vs)
    x = cm.rms_norm(x, params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
    return logits, {"k": k_new, "v": v_new}


def prefill_paged(cfg, params, pages, block_tables, lens, tokens, nvalid, *,
                  policy=None, max_kv: int = 0):
    """Chunked prompt prefill THROUGH the paged pool (admission path).

    One fixed-size chunk of every prefilling slot's prompt in a single
    natively batched forward: ``tokens [S, c]`` (right-padded),
    ``nvalid [S]`` real token counts (0 for lanes not prefilling),
    ``lens [S]`` prompt tokens already committed. Reuses
    ``extend_paged``'s page-write machinery — padding tokens and
    non-participating lanes write the null page — and its attention: a
    prefill chunk is just a C=c query block with causal within-chunk
    masking, so chunks run on the same spec-verify kernel policy as the
    gamma+1 verify rounds.

    Per-sequence MoE dispatch (inherited from ``extend_paged``) keeps
    each slot's capacity groups independent of its batch-mates. Note
    the chunked == one-shot bitwise guarantee for MoE configs holds
    only while expert capacity never binds (capacity_factor >=
    num_experts / num_experts_per_tok): dropping is a function of the
    dispatch group, and chunking changes the grouping.

    Returns (logits [S, c, V], new pages); row ``nvalid[s] - 1`` of a
    slot's final chunk is the prompt's last-position logits — with
    ``max_kv`` set to the dense capacity it is bitwise what the dense
    staging prefill produces (same masked reduction shapes), which is
    what lets chunked admission commit identical token streams.
    """
    return extend_paged(cfg, params, pages, block_tables, lens, tokens,
                        policy=policy, max_kv=max_kv, nvalid=nvalid)


def rollback(cache, new_len):
    """Roll the cache back to ``new_len`` valid entries (O(1): mask stale
    slots through the position buffer rather than copying k/v)."""
    Smax = cache["k"].shape[2]
    slot = jnp.arange(Smax)[None, :]
    # a slot is valid iff its recorded position < new_len
    pos = jnp.where(cache["pos"] < new_len, cache["pos"], INVALID_POS)
    del slot
    return {"k": cache["k"], "v": cache["v"], "pos": pos,
            "len": jnp.asarray(new_len, jnp.int32)}


def prefill(cfg, params, batch, max_len: int):
    B = batch["tokens"].shape[0]
    cache = init_cache(cfg, B, max_len)
    ve = batch.get("vision_embeds")
    return extend(cfg, params, cache, batch["tokens"], vision_embeds=ve)
