"""Encoder-decoder transformer (seamless-m4t-medium family).

The audio frontend (mel-spectrogram + conv feature extractor) is a stub:
``input_specs`` provides precomputed frame embeddings [B, Se, D]. We
implement the transformer encoder over those frames and the full
autoregressive text decoder (causal self-attention with KV cache +
cross-attention with a static encoder-side cache).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels import ops
from ..kernels.ref import INVALID_POS
from . import common as cm


def _ckpt(cfg, fn):
    """jax.checkpoint with the configured policy."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _enc_layers(cfg):
    return cfg.enc_layers or cfg.num_layers


def _dec_layers(cfg):
    return cfg.dec_layers or cfg.num_layers


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_params(cfg, rng):
    dtype = cm.get_dtype(cfg.param_dtype)
    r_emb, r_enc, r_dec, r_head = jax.random.split(rng, 4)

    def enc_layer(r):
        ra, rm = jax.random.split(r)
        return {"ln1": jnp.zeros((cfg.d_model,), dtype),
                "ln2": jnp.zeros((cfg.d_model,), dtype),
                "attn": cm.attn_init(ra, cfg, dtype),
                "mlp": cm.swiglu_init(rm, cfg.d_model, cfg.d_ff, dtype)}

    def dec_layer(r):
        ra, rx, rm = jax.random.split(r, 3)
        return {"ln1": jnp.zeros((cfg.d_model,), dtype),
                "lnx": jnp.zeros((cfg.d_model,), dtype),
                "ln2": jnp.zeros((cfg.d_model,), dtype),
                "self_attn": cm.attn_init(ra, cfg, dtype),
                "cross_attn": cm.attn_init(rx, cfg, dtype),
                "mlp": cm.swiglu_init(rm, cfg.d_model, cfg.d_ff, dtype)}

    return {
        "embed": cm.embed_init(r_emb, (cfg.vocab_size, cfg.d_model), dtype),
        "enc": cm.stack_layer_init(enc_layer, r_enc, _enc_layers(cfg)),
        "dec": cm.stack_layer_init(dec_layer, r_dec, _dec_layers(cfg)),
        "enc_norm": jnp.zeros((cfg.d_model,), dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "lm_head": cm.dense_init(r_head, (cfg.d_model, cfg.vocab_size),
                                 cfg.d_model, dtype),
    }


def logical_axes(cfg):
    attn = {k: ("layers",) + v for k, v in cm.attn_axes(cfg).items()}
    mlp = {k: ("layers",) + v for k, v in cm.swiglu_axes().items()}
    enc = {"ln1": ("layers", "p_embed"), "ln2": ("layers", "p_embed"),
           "attn": attn, "mlp": mlp}
    dec = {"ln1": ("layers", "p_embed"), "lnx": ("layers", "p_embed"),
           "ln2": ("layers", "p_embed"), "self_attn": attn,
           "cross_attn": attn, "mlp": mlp}
    return {"embed": ("vocab", "embed"), "enc": enc, "dec": dec,
            "enc_norm": ("p_embed",), "final_norm": ("p_embed",),
            "lm_head": ("embed", "vocab")}


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def encode(cfg, params, frames):
    """frames: [B, Se, D] stubbed frontend embeddings -> [B, Se, D]."""
    dtype = cm.get_dtype(cfg.dtype)
    x = frames.astype(dtype)
    B, Se, _ = x.shape
    # bidirectional: all queries at the max position so kp <= qp always holds
    q_pos = jnp.full((B, Se), Se - 1, jnp.int32)
    kv_pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))
    rope_pos = kv_pos

    def body(x, lp):
        xn = cm.rms_norm(x, lp["ln1"])
        q, k, v = cm.attn_qkv(lp["attn"], xn, cfg, rope_pos)
        if Se >= 2048:
            o = ops.flash_attention(q, k, v, q_pos, kv_pos,
                                    use_pallas=cfg.use_pallas)
        else:
            o = ops.naive_attention(q, k, v, q_pos, kv_pos)
        x = x + cm.attn_out(lp["attn"], o)
        x = x + cm.swiglu(lp["mlp"], cm.rms_norm(x, lp["ln2"]))
        return x, None

    body = _ckpt(cfg, body) if cfg.remat else body
    if cfg.scan_layers:
        x, _ = lax.scan(body, x, params["enc"])
    else:
        for i in range(_enc_layers(cfg)):
            x, _ = body(x, jax.tree.map(lambda a: a[i], params["enc"]))
    return cm.rms_norm(x, params["enc_norm"])


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------

def init_cache(cfg, batch_size: int, max_len: int, enc_len: int = 0):
    dtype = cm.get_dtype(cfg.dtype)
    Ld, KV, Dh = _dec_layers(cfg), cfg.num_kv_heads, cfg.head_dim
    if cfg.sliding_window > 0:
        max_len = min(max_len, cfg.sliding_window)
    enc_len = enc_len or cfg.max_enc_len
    return {
        "k": jnp.zeros((Ld, batch_size, max_len, KV, Dh), dtype),
        "v": jnp.zeros((Ld, batch_size, max_len, KV, Dh), dtype),
        "pos": jnp.full((batch_size, max_len), INVALID_POS, jnp.int32),
        "cross_k": jnp.zeros((Ld, batch_size, enc_len, KV, Dh), dtype),
        "cross_v": jnp.zeros((Ld, batch_size, enc_len, KV, Dh), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def cache_axes(cfg):
    kv = ("layers", "batch", "cache_seq", "kv_heads", "qkv")
    return {"k": kv, "v": kv, "pos": ("batch", "cache_seq"),
            "cross_k": kv, "cross_v": kv, "len": ()}


def build_cross_cache(cfg, params, enc_out, cache):
    """Precompute per-layer cross-attention K/V from encoder output."""
    Se = enc_out.shape[1]
    pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32),
                           enc_out.shape[:2])

    def body(_, lp):
        k = jnp.einsum("bsd,dhe->bshe", enc_out, lp["cross_attn"]["wk"])
        v = jnp.einsum("bsd,dhe->bshe", enc_out, lp["cross_attn"]["wv"])
        return None, (k, v)

    if cfg.scan_layers:
        _, (ks, vs) = lax.scan(body, None, params["dec"])
    else:
        outs = [body(None, jax.tree.map(lambda a: a[i], params["dec"]))[1]
                for i in range(_dec_layers(cfg))]
        ks = jnp.stack([o[0] for o in outs])
        vs = jnp.stack([o[1] for o in outs])
    cache = dict(cache)
    cache["cross_k"] = ks.astype(cache["cross_k"].dtype)
    cache["cross_v"] = vs.astype(cache["cross_v"].dtype)
    return cache


def extend(cfg, params, cache, tokens, vision_embeds=None):
    """Decoder step(s): causal self-attn over cache + cross-attn."""
    dtype = cm.get_dtype(cfg.dtype)
    x = params["embed"][tokens].astype(dtype)
    B, c, _ = x.shape
    start = cache["len"]
    Smax = cache["k"].shape[2]
    idx = start + jnp.arange(c, dtype=jnp.int32)
    slots = idx % Smax
    w0 = max(0, c - Smax)
    positions = jnp.broadcast_to(idx, (B, c))
    pc = cache["pos"]
    pos_new = pc.at[:, slots[w0:]].set(positions[:, w0:])
    ring = cfg.sliding_window > 0
    Se = cache["cross_k"].shape[2]
    cross_qpos = jnp.full((B, c), Se - 1, jnp.int32)
    cross_kpos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))

    def scan_body(x, layer_in):
        lp, kc, vc, xk, xv = layer_in
        xn = cm.rms_norm(x, lp["ln1"])
        q, k, v = cm.attn_qkv(lp["self_attn"], xn, cfg, positions)
        if ring:
            ka = jnp.concatenate([kc, k.astype(kc.dtype)], axis=1)
            va = jnp.concatenate([vc, v.astype(vc.dtype)], axis=1)
            pa = jnp.concatenate([pc, positions], axis=1)
        kc = kc.at[:, slots[w0:]].set(k[:, w0:].astype(kc.dtype))
        vc = vc.at[:, slots[w0:]].set(v[:, w0:].astype(vc.dtype))
        if not ring:
            ka, va, pa = kc, vc, pos_new
        if c >= 2048:
            o = ops.flash_attention(q, ka, va, positions, pa,
                                    window=cfg.sliding_window,
                                    use_pallas=cfg.use_pallas)
        else:
            o = ops.naive_attention(q, ka, va, positions, pa,
                                    window=cfg.sliding_window)
        x = x + cm.attn_out(lp["self_attn"], o)
        # cross attention (bidirectional over encoder frames)
        xn = cm.rms_norm(x, lp["lnx"])
        qx = jnp.einsum("bsd,dhe->bshe", xn, lp["cross_attn"]["wq"])
        ox = ops.naive_attention(qx, xk, xv, cross_qpos, cross_kpos)
        x = x + cm.attn_out(lp["cross_attn"], ox)
        x = x + cm.swiglu(lp["mlp"], cm.rms_norm(x, lp["ln2"]))
        return x, (kc, vc)

    body = _ckpt(cfg, scan_body) if cfg.remat else scan_body
    if cfg.scan_layers:
        x, (k_new, v_new) = lax.scan(
            body, x, (params["dec"], cache["k"], cache["v"],
                      cache["cross_k"], cache["cross_v"]))
    else:
        ks, vs = [], []
        for i in range(_dec_layers(cfg)):
            blk = jax.tree.map(lambda a: a[i],
                               (params["dec"], cache["k"], cache["v"],
                                cache["cross_k"], cache["cross_v"]))
            x, (kc, vc) = body(x, blk)
            ks.append(kc)
            vs.append(vc)
        k_new, v_new = jnp.stack(ks), jnp.stack(vs)
    new_cache = dict(cache)
    new_cache.update({"k": k_new, "v": v_new, "pos": pos_new,
                      "len": start + c})
    x = cm.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"]).astype(jnp.float32)
    return logits, new_cache


def prefill(cfg, params, batch, max_len: int):
    """Encode frames, build the cross cache, then decoder-prefill tokens."""
    B = batch["tokens"].shape[0]
    enc_out = encode(cfg, params, batch["enc_frames"])
    cache = init_cache(cfg, B, max_len, enc_len=enc_out.shape[1])
    cache = build_cross_cache(cfg, params, enc_out, cache)
    return extend(cfg, params, cache, batch["tokens"])


def forward(cfg, params, batch, seq_rule=None):
    logits, _ = prefill(cfg, params, batch, max_len=batch["tokens"].shape[1])
    return logits, jnp.float32(0.0)


def loss_fn(cfg, params, batch, seq_rule=None):
    logits, _ = forward(cfg, params, batch)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is None:
        return -jnp.mean(ll)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
