"""RecurrentGemma / Griffin-style hybrid: RG-LRU recurrent blocks + local
sliding-window MQA attention in a (rec, rec, attn) pattern.

TPU adaptation: the RG-LRU linear recurrence runs as a parallel prefix
(``lax.associative_scan``), the local attention uses the shared ring-buffer
KV cache (window-bounded, O(W) decode). Layers are scanned in super-blocks
of the repeating pattern (MaxText-style stacked params); the remainder of
``num_layers`` modulo the pattern is unrolled as a tail.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels import ops
from ..kernels.ref import INVALID_POS
from . import common as cm


def _ckpt(cfg, fn):
    """jax.checkpoint with the configured policy."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)

FINAL_SOFTCAP = 30.0
LRU_C = 8.0


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def _rec_init(rng, cfg, dtype):
    D, w, W = cfg.d_model, cfg.lru_width, cfg.conv_width
    r = jax.random.split(rng, 6)
    # Lambda init so that a = exp(-c*softplus(L)*r) has decay in (.9, .999)
    lam = jax.random.uniform(r[5], (w,), jnp.float32, 0.001, 0.1)
    lam = jnp.log(jnp.exp(-jnp.log(lam) / LRU_C) - 1.0)  # softplus^-1
    return {
        "ln": jnp.zeros((D,), dtype),
        "in_x": cm.dense_init(r[0], (D, w), D, dtype),
        "in_gate": cm.dense_init(r[1], (D, w), D, dtype),
        "conv_w": cm.dense_init(r[2], (w, W), W, dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": cm.dense_init(r[3], (w, w), w, dtype),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": cm.dense_init(r[4], (w, w), w, dtype),
        "b_i": jnp.zeros((w,), jnp.float32),
        "lam": lam,
        "out": cm.dense_init(jax.random.fold_in(rng, 7), (w, D), w, dtype),
    }


def _rec_axes():
    return {"ln": ("p_embed",), "in_x": ("p_embed", "inner"),
            "in_gate": ("p_embed", "inner"), "conv_w": ("inner", None),
            "conv_b": ("inner",), "w_a": ("inner", "inner"),
            "b_a": ("inner",), "w_i": ("inner", "inner"), "b_i": ("inner",),
            "lam": ("inner",), "out": ("inner", "p_embed")}


def _mlp_init(rng, cfg, dtype):
    return {"ln": jnp.zeros((cfg.d_model,), dtype),
            **cm.swiglu_init(rng, cfg.d_model, cfg.d_ff, dtype)}


def _mlp_axes():
    return {"ln": ("p_embed",), **cm.swiglu_axes()}


def _attn_init(rng, cfg, dtype):
    return {"ln": jnp.zeros((cfg.d_model,), dtype),
            **cm.attn_init(rng, cfg, dtype)}


def _attn_axes(cfg):
    return {"ln": ("p_embed",), **cm.attn_axes(cfg)}


def _pattern(cfg):
    pat = cfg.block_pattern or ("rec", "rec", "attn")
    n_super = cfg.num_layers // len(pat)
    tail = tuple(pat[i] for i in range(cfg.num_layers - n_super * len(pat)))
    return pat, n_super, tail


def init_params(cfg, rng):
    dtype = cm.get_dtype(cfg.param_dtype)
    pat, n_super, tail = _pattern(cfg)
    r_emb, r_sup, r_tail, r_head = jax.random.split(rng, 4)

    def one_super(r):
        out = {}
        for j, kind in enumerate(pat):
            rj = jax.random.fold_in(r, j)
            r1, r2 = jax.random.split(rj)
            out[f"mix{j}"] = (_rec_init(r1, cfg, dtype) if kind == "rec"
                              else _attn_init(r1, cfg, dtype))
            out[f"mlp{j}"] = _mlp_init(r2, cfg, dtype)
        return out

    params = {
        "embed": cm.embed_init(r_emb, (cfg.vocab_size, cfg.d_model), dtype),
        "super": cm.stack_layer_init(one_super, r_sup, n_super),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "lm_head": cm.dense_init(r_head, (cfg.d_model, cfg.vocab_size),
                                 cfg.d_model, dtype),
    }
    for j, kind in enumerate(tail):
        rj = jax.random.fold_in(r_tail, j)
        r1, r2 = jax.random.split(rj)
        params[f"tail_mix{j}"] = (_rec_init(r1, cfg, dtype) if kind == "rec"
                                  else _attn_init(r1, cfg, dtype))
        params[f"tail_mlp{j}"] = _mlp_init(r2, cfg, dtype)
    return params


def logical_axes(cfg):
    pat, n_super, tail = _pattern(cfg)
    sup = {}
    for j, kind in enumerate(pat):
        mix = _rec_axes() if kind == "rec" else _attn_axes(cfg)
        sup[f"mix{j}"] = {k: ("layers",) + v for k, v in mix.items()}
        sup[f"mlp{j}"] = {k: ("layers",) + v for k, v in _mlp_axes().items()}
    axes = {"embed": ("vocab", "embed"), "super": sup,
            "final_norm": ("p_embed",), "lm_head": ("embed", "vocab")}
    for j, kind in enumerate(tail):
        axes[f"tail_mix{j}"] = _rec_axes() if kind == "rec" else _attn_axes(cfg)
        axes[f"tail_mlp{j}"] = _mlp_axes()
    return axes


# ---------------------------------------------------------------------------
# RG-LRU recurrent mixer
# ---------------------------------------------------------------------------

def _rglru_mix(cfg, p, x, conv_tail, h0):
    """x: [B,c,D] normed input. Returns (y, new_conv_tail, h_last)."""
    B, c, _ = x.shape
    w, W = cfg.lru_width, cfg.conv_width
    f32 = jnp.float32
    u = jnp.einsum("bsd,dw->bsw", x, p["in_x"])
    gate = jnp.einsum("bsd,dw->bsw", x, p["in_gate"])
    # causal depthwise conv with carried tail
    u_ext = jnp.concatenate([conv_tail.astype(u.dtype), u], axis=1)
    idx = jnp.arange(c)[:, None] + jnp.arange(W)[None, :]
    u_conv = jnp.einsum("bcwi,iw->bci", u_ext[:, idx].transpose(0, 1, 2, 3),
                        p["conv_w"]) + p["conv_b"]
    new_tail = u_ext[:, -(W - 1):] if W > 1 else u_ext[:, :0]

    r = jax.nn.sigmoid(jnp.einsum("bci,ij->bcj", u_conv, p["w_a"]).astype(f32)
                       + p["b_a"])
    i = jax.nn.sigmoid(jnp.einsum("bci,ij->bcj", u_conv, p["w_i"]).astype(f32)
                       + p["b_i"])
    log_a = -LRU_C * jax.nn.softplus(p["lam"]) * r          # [B,c,w]
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * i * u_conv.astype(f32)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    a_all, b_all = lax.associative_scan(combine, (a, gated), axis=1)
    hs = b_all + a_all * h0.astype(f32)[:, None]
    y = hs.astype(x.dtype) * jax.nn.gelu(gate.astype(f32)).astype(x.dtype)
    out = jnp.einsum("bcw,wd->bcd", y, p["out"])
    return out, new_tail.astype(x.dtype), hs[:, -1]


# ---------------------------------------------------------------------------
# local attention mixer (ring cache)
# ---------------------------------------------------------------------------

def _attn_mix(cfg, p, x, positions, kc, vc, pc):
    """x normed. kc/vc: [B,W,KV,Dh] ring cache (or None for fresh chunks)."""
    q, k, v = cm.attn_qkv(p, x, cfg, positions)
    window = cfg.sliding_window
    if kc is None:
        o = (ops.flash_attention(q, k, v, positions, positions, window=window,
                                 softcap=cfg.logit_softcap,
                                 use_pallas=cfg.use_pallas)
             if x.shape[1] >= 2048 else
             ops.naive_attention(q, k, v, positions, positions, window=window,
                                 softcap=cfg.logit_softcap))
        return cm.attn_out(p, o), k, v
    ka = jnp.concatenate([kc, k.astype(kc.dtype)], axis=1)
    va = jnp.concatenate([vc, v.astype(vc.dtype)], axis=1)
    pa = jnp.concatenate([pc, positions], axis=1)
    o = (ops.flash_attention(q, ka, va, positions, pa, window=window,
                             softcap=cfg.logit_softcap,
                             use_pallas=cfg.use_pallas)
         if x.shape[1] >= 2048 else
         ops.naive_attention(q, ka, va, positions, pa, window=window,
                             softcap=cfg.logit_softcap))
    return cm.attn_out(p, o), k, v


def _write_ring(kc, k, slots, w0):
    return kc.at[:, slots[w0:]].set(k[:, w0:].astype(kc.dtype))


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_cache(cfg, batch_size: int, max_len: int = 0):
    dtype = cm.get_dtype(cfg.dtype)
    pat, n_super, tail = _pattern(cfg)
    W = cfg.sliding_window or 2048
    wv, cw = cfg.lru_width, cfg.conv_width
    KV, Dh = cfg.num_kv_heads, cfg.head_dim
    n_rec_per = sum(1 for k in pat if k == "rec")
    cache = {
        "attn_k": jnp.zeros((n_super, batch_size, W, KV, Dh), dtype),
        "attn_v": jnp.zeros((n_super, batch_size, W, KV, Dh), dtype),
        "pos": jnp.full((batch_size, W), INVALID_POS, jnp.int32),
        "rec_conv": jnp.zeros((n_super, n_rec_per, batch_size, cw - 1, wv),
                              dtype),
        "rec_h": jnp.zeros((n_super, n_rec_per, batch_size, wv), jnp.float32),
        "len": jnp.zeros((), jnp.int32),
    }
    n_rec_tail = sum(1 for k in tail if k == "rec")
    if n_rec_tail:
        cache["tail_conv"] = jnp.zeros((n_rec_tail, batch_size, cw - 1, wv),
                                       dtype)
        cache["tail_h"] = jnp.zeros((n_rec_tail, batch_size, wv), jnp.float32)
    n_attn_tail = len(tail) - n_rec_tail
    if n_attn_tail:
        cache["tail_attn_k"] = jnp.zeros((n_attn_tail, batch_size, W, KV, Dh),
                                         dtype)
        cache["tail_attn_v"] = jnp.zeros((n_attn_tail, batch_size, W, KV, Dh),
                                         dtype)
    return cache


def cache_axes(cfg):
    pat, n_super, tail = _pattern(cfg)
    axes = {"attn_k": ("layers", "batch", "cache_seq", "kv_heads", "qkv"),
            "attn_v": ("layers", "batch", "cache_seq", "kv_heads", "qkv"),
            "pos": ("batch", "cache_seq"),
            "rec_conv": ("layers", None, "batch", None, "inner"),
            "rec_h": ("layers", None, "batch", "inner"),
            "len": ()}
    if any(k == "rec" for k in tail):
        axes["tail_conv"] = (None, "batch", None, "inner")
        axes["tail_h"] = (None, "batch", "inner")
    if any(k == "attn" for k in tail):
        axes["tail_attn_k"] = (None, "batch", "cache_seq", "kv_heads", "qkv")
        axes["tail_attn_v"] = (None, "batch", "cache_seq", "kv_heads", "qkv")
    return axes


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------

def _block(cfg, mix_p, mlp_p, kind, x, positions, attn_cache, rec_cache):
    """One (mixer + mlp) residual pair. Returns (x, new_attn, new_rec)."""
    xn = cm.rms_norm(x, mix_p["ln"])
    new_attn = new_rec = None
    if kind == "rec":
        tail, h0 = rec_cache
        y, new_tail, h_last = _rglru_mix(cfg, mix_p, xn, tail, h0)
        new_rec = (new_tail, h_last)
    else:
        kc, vc, pc = attn_cache
        y, k, v = _attn_mix(cfg, mix_p, xn, positions, kc, vc, pc)
        new_attn = (k, v)
    x = x + y
    x = x + cm.swiglu(mlp_p, cm.rms_norm(x, mlp_p["ln"]))
    return x, new_attn, new_rec


def _run(cfg, params, tokens, cache):
    dtype = cm.get_dtype(cfg.dtype)
    pat, n_super, tail = _pattern(cfg)
    x = params["embed"][tokens].astype(dtype)
    B, c, _ = x.shape
    fresh = cache is None
    if fresh:
        cache = init_cache(cfg, B)
        W = cache["attn_k"].shape[2]
        start = jnp.zeros((), jnp.int32)
    else:
        W = cache["attn_k"].shape[2]
        start = cache["len"]
    idx = start + jnp.arange(c, dtype=jnp.int32)
    slots = idx % W
    w0 = max(0, c - W)
    positions = jnp.broadcast_to(idx, (B, c))
    pc = cache["pos"]
    pos_new = pc.at[:, slots[w0:]].set(positions[:, w0:])

    rec_ids = [j for j, k in enumerate(pat) if k == "rec"]

    def super_body(x, layer_in):
        lp, kc, vc, rconv, rh = layer_in
        new_k = new_v = None
        new_conv, new_h = [], []
        ri = 0
        for j, kind in enumerate(pat):
            attn_c = (None, None, None) if (fresh and kind == "attn") else \
                (kc, vc, pc)
            rec_c = (rconv[ri], rh[ri]) if kind == "rec" else None
            x, na, nr = _block(cfg, lp[f"mix{j}"], lp[f"mlp{j}"], kind, x,
                               positions, attn_c, rec_c)
            if kind == "rec":
                new_conv.append(nr[0])
                new_h.append(nr[1])
                ri += 1
            else:
                k, v = na
                kc = _write_ring(kc, k, slots, w0)
                vc = _write_ring(vc, v, slots, w0)
                new_k, new_v = kc, vc
        return x, (new_k, new_v, jnp.stack(new_conv), jnp.stack(new_h))

    body = _ckpt(cfg, super_body) if cfg.remat else super_body
    if cfg.scan_layers:
        x, (ks, vs, convs, hs) = lax.scan(
            body, x, (params["super"], cache["attn_k"], cache["attn_v"],
                      cache["rec_conv"], cache["rec_h"]))
    else:
        outs = []
        for i in range(n_super):
            blk = jax.tree.map(lambda a: a[i],
                               (params["super"], cache["attn_k"],
                                cache["attn_v"], cache["rec_conv"],
                                cache["rec_h"]))
            x, o = body(x, blk)
            outs.append(o)
        ks, vs, convs, hs = (jnp.stack([o[j] for o in outs])
                             for j in range(4))

    new_cache = {"attn_k": ks, "attn_v": vs, "rec_conv": convs, "rec_h": hs,
                 "pos": pos_new, "len": start + c}

    # tail layers (unrolled)
    ti_rec = ti_attn = 0
    for j, kind in enumerate(tail):
        if kind == "rec":
            rec_c = (cache["tail_conv"][ti_rec], cache["tail_h"][ti_rec])
            x, _, nr = _block(cfg, params[f"tail_mix{j}"],
                              params[f"tail_mlp{j}"], kind, x, positions,
                              None, rec_c)
            new_cache.setdefault("tail_conv", cache["tail_conv"])
            new_cache.setdefault("tail_h", cache["tail_h"])
            new_cache["tail_conv"] = new_cache["tail_conv"].at[ti_rec].set(nr[0])
            new_cache["tail_h"] = new_cache["tail_h"].at[ti_rec].set(nr[1])
            ti_rec += 1
        else:
            kc = cache["tail_attn_k"][ti_attn]
            vc = cache["tail_attn_v"][ti_attn]
            attn_c = (None, None, None) if fresh else (kc, vc, pc)
            x, na, _ = _block(cfg, params[f"tail_mix{j}"],
                              params[f"tail_mlp{j}"], kind, x, positions,
                              attn_c, None)
            k, v = na
            new_cache.setdefault("tail_attn_k", cache["tail_attn_k"])
            new_cache.setdefault("tail_attn_v", cache["tail_attn_v"])
            new_cache["tail_attn_k"] = new_cache["tail_attn_k"].at[ti_attn].set(
                _write_ring(kc, k, slots, w0))
            new_cache["tail_attn_v"] = new_cache["tail_attn_v"].at[ti_attn].set(
                _write_ring(vc, v, slots, w0))
            ti_attn += 1

    x = cm.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"]).astype(jnp.float32)
    logits = jnp.tanh(logits / FINAL_SOFTCAP) * FINAL_SOFTCAP
    return logits, new_cache


def forward(cfg, params, batch, seq_rule=None):
    logits, _ = _run(cfg, params, batch["tokens"], None)
    return logits, jnp.float32(0.0)


def loss_fn(cfg, params, batch, seq_rule=None):
    logits, _ = forward(cfg, params, batch)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is None:
        return -jnp.mean(ll)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def extend(cfg, params, cache, tokens, vision_embeds=None):
    return _run(cfg, params, tokens, cache)


def prefill(cfg, params, batch, max_len: int = 0):
    cache = init_cache(cfg, batch["tokens"].shape[0])
    return _run(cfg, params, batch["tokens"], cache)
