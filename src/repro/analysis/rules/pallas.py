"""``pallas-block-align``: static checking of Pallas kernel hygiene
against the SAME alignment table the runtime validator uses.

``kernels.alignment.BLOCK_PARAM_ALIGN`` is the single source of truth:
``kernels.policy.validate_block_size`` rounds misaligned requests at
runtime (warn-once), and this rule catches them at lint time — plus the
shapes the runtime path can't see until lowering:

- literal ``BlockSpec`` block shapes whose second-to-last dim is not a
  sublane multiple (Mosaic fails on these deep inside lowering);
- ``grid`` arity vs ``index_map`` arity, including the
  ``num_scalar_prefetch`` operands a ``PrefetchScalarGridSpec``
  appends to every index map's signature;
- literal ``bq``/``bk``/``bn``/``page_size`` keyword arguments anywhere
  in shipping code (``KernelPolicy(...)``, op entry points, engine
  constructors) that violate the table.

The table import is LIVE (module attribute lookup at check time), so a
test monkeypatching ``BLOCK_PARAM_ALIGN`` moves this rule and the
runtime validator together — the shared-spec pin in the test suite.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from ...kernels import alignment
from ..astutil import const_int, dotted_name, lambda_arity, literal_int_tuple
from ..core import FileContext, Finding, Rule, register

_GRID_SPECS = ("PrefetchScalarGridSpec", "GridSpec")


def _ends_with(name: Optional[str], leaf: str) -> bool:
    return name is not None and (name == leaf or name.endswith("." + leaf))


@register
class PallasBlockAlign(Rule):
    id = "pallas-block-align"
    description = ("BlockSpec shapes, grid arity and bq/bk/bn/page_size "
                   "literals checked against kernels.alignment — the "
                   "table validate_block_size enforces at runtime")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        grid_parents = set()
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            if _ends_with(name, "pallas_call") or any(
                    _ends_with(name, g) for g in _GRID_SPECS):
                grid_parents.add(id(node))
                yield from self._check_grid(ctx, node)
            if _ends_with(name, "BlockSpec"):
                yield from self._check_blockspec(ctx, node)
            yield from self._check_knob_literals(ctx, node, name)

    # -- literal knob kwargs ----------------------------------------------
    def _check_knob_literals(self, ctx, call: ast.Call,
                             name: Optional[str]) -> Iterator[Finding]:
        for kw in call.keywords:
            if kw.arg not in alignment.BLOCK_PARAM_ALIGN:
                continue
            v = const_int(kw.value)
            if v is None or v < 1:
                continue
            align = alignment.alignment_for(kw.arg)
            if v % align != 0:
                yield ctx.finding(
                    self.id, kw.value,
                    f"block-size knob {kw.arg}={v} is not a multiple of "
                    f"{align} (kernels.alignment.BLOCK_PARAM_ALIGN"
                    f"[{kw.arg!r}]); validate_block_size would round it "
                    f"up to {alignment.round_up(v, align)} at runtime — "
                    "use an aligned value so the compiled block shape is "
                    "what you asked for")

    # -- BlockSpec literal shapes -----------------------------------------
    def _check_blockspec(self, ctx, call: ast.Call) -> Iterator[Finding]:
        if not call.args:
            return
        dims = literal_int_tuple(call.args[0])
        if dims is None or len(dims) < 2:
            return
        v = dims[-2]
        # size-1 dims are squeezed by Mosaic and legal at any position
        if v is not None and v > 1 and v % alignment.SUBLANE != 0:
            yield ctx.finding(
                self.id, call.args[0],
                f"BlockSpec second-to-last block dim {v} is not a "
                f"multiple of the sublane quantum "
                f"{alignment.SUBLANE} (kernels.alignment.SUBLANE); "
                "Mosaic rejects this block shape during lowering")

    # -- grid arity vs index_map arity ------------------------------------
    def _check_grid(self, ctx, call: ast.Call) -> Iterator[Finding]:
        grid_n: Optional[int] = None
        prefetch = 0
        for kw in call.keywords:
            if kw.arg == "grid":
                if isinstance(kw.value, (ast.Tuple, ast.List)):
                    grid_n = len(kw.value.elts)
                elif const_int(kw.value) is not None:
                    grid_n = 1
            elif kw.arg == "num_scalar_prefetch":
                p = const_int(kw.value)
                prefetch = p if p is not None else 0
        if grid_n is None:
            return
        want = grid_n + prefetch
        for sub in ast.walk(call):
            if not isinstance(sub, ast.Call):
                continue
            if not _ends_with(dotted_name(sub.func), "BlockSpec"):
                continue
            index_map = None
            if len(sub.args) > 1:
                index_map = sub.args[1]
            else:
                index_map = next((k.value for k in sub.keywords
                                  if k.arg == "index_map"), None)
            if index_map is None:
                continue
            arity = lambda_arity(index_map)
            if arity is not None and arity != want:
                yield ctx.finding(
                    self.id, index_map,
                    f"index_map takes {arity} arg(s) but the grid has "
                    f"{grid_n} dim(s)"
                    + (f" plus {prefetch} scalar-prefetch operand(s)"
                       if prefetch else "")
                    + f" — expected {want}")
