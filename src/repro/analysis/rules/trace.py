"""Trace-safety rules.

``trace-unsafe-branch``: Python ``if``/``while``/``assert`` (or
``bool()``/``int()``/``float()``/``.item()``) on a likely-tracer value
inside a function that jax traces — the classic shape of the pre-PR-3
host-vs-vmap RNG mismatch, where host-only control flow silently
diverged from the compiled program.

``host-sync-in-hot-path``: ``np.*`` coercion, ``time.*``, printing or
``.block_until_ready()`` inside the jitted round/step functions —
each one either breaks tracing outright or forces a device sync in the
middle of the serving hot loop. (``jax.debug.print`` is trace-safe and
not flagged.) Also flags the HOST-side shape of the same bug:
per-element ``np.asarray(x[i])`` / ``x[i].item()`` /
``jax.device_get(x[i])`` inside a ``for`` loop — one device sync per
slot where a single batched fetch of the packed array would do. The
per-element narrowing is deliberate: ``np.asarray(whole_array)``
outside or inside a loop is one transfer and stays legal.

Traced-function detection is shared, module-local and intraprocedural:

- defs decorated with ``jax.jit``/``vmap``/``partial(jax.jit, ...)``;
- defs/lambdas passed to ``jit``/``vmap``/``pmap``/``grad``/``scan``/
  ``while_loop``/``cond``/``fori_loop``/``switch``/``pallas_call``/
  ``checkpoint``/``shard_map`` (incl. through ``functools.partial``);
- defs nested inside a traced function;
- module-local functions CALLED from a traced function (one closure:
  the shared ``_draft_tokens``/``_sd_verdict`` helpers are traced
  because the jitted rounds call them).

Static (non-tracer) values: params named like configs
(cfg/config/spec/policy/...), params in ``static_argnums``/
``static_argnames``, keyword-only params (the Pallas-kernel
convention: grid/scale statics are bound keyword-only via partial),
and anything derived only from ``.shape``/``.ndim``/``.dtype``/
``len()``/``isinstance()``.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from ..astutil import FunctionLike, dotted_name
from ..core import FileContext, Finding, Rule, register

#: transform callables that trace their function argument(s); the value
#: is the positional index/indices of the traced function argument.
_TRANSFORM_FN_ARGS = {
    "jit": (0,), "vmap": (0,), "pmap": (0,), "grad": (0,),
    "value_and_grad": (0,), "checkpoint": (0,), "remat": (0,),
    "pallas_call": (0,), "shard_map": (0,),
    "scan": (0,), "while_loop": (0, 1), "fori_loop": (2,),
    "cond": (1, 2), "switch": (),       # switch: list arg handled apart
}

_TRANSFORM_PREFIXES = ("jax.", "jax.lax.", "lax.", "pl.", "pltpu.",
                       "pallas.", "jax.experimental.pallas.", "")

#: parameter names that are configs/hosts, never tracers — including the
#: repo's kernel-knob convention (block sizes / window / softcap are
#: always static python ints threaded from KernelPolicy)
_STATIC_PARAM_NAMES = {"self", "cls", "cfg", "config", "spec", "policy",
                       "mesh", "rules", "model", "models", "tcfg",
                       "optim", "cfg_t", "cfg_d",
                       "interpret", "window", "softcap", "scale",
                       "bn", "bq", "bk", "nb", "page", "page_size",
                       "kernel", "gamma", "chunk"}

#: attributes whose access yields a static (python) value
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding"}

#: builtins whose result is static regardless of the argument
_STATIC_CALLS = {"len", "isinstance", "issubclass", "getattr", "hasattr",
                 "type", "callable", "id", "repr", "str"}


def _transform_name(name: Optional[str]) -> Optional[str]:
    """"scan" for "jax.lax.scan" etc., None for non-transform calls."""
    if name is None:
        return None
    for prefix in _TRANSFORM_PREFIXES:
        if name.startswith(prefix):
            tail = name[len(prefix):]
            if tail in _TRANSFORM_FN_ARGS:
                return tail
    return None


class TracedInfo:
    __slots__ = ("node", "static", "why")

    def __init__(self, node, why: str, static: Optional[Set[str]] = None):
        self.node = node
        self.static: Set[str] = set(static or ())
        self.why = why


def _param_list(fn) -> List[str]:
    a = fn.args
    return [p.arg for p in (*a.posonlyargs, *a.args)]


def _kwonly_params(fn) -> Set[str]:
    if isinstance(fn, ast.Lambda):
        return {p.arg for p in fn.args.kwonlyargs}
    return {p.arg for p in fn.args.kwonlyargs}


def _statics_from_jit_kwargs(keywords, fn) -> Set[str]:
    """static_argnums / static_argnames of a jit(...) call, resolved to
    parameter names of ``fn`` when possible."""
    out: Set[str] = set()
    params = _param_list(fn) if isinstance(fn, FunctionLike) else []
    for kw in keywords:
        if kw.arg == "static_argnames":
            for el in ast.walk(kw.value):
                if isinstance(el, ast.Constant) and isinstance(el.value, str):
                    out.add(el.value)
        elif kw.arg == "static_argnums":
            for el in ast.walk(kw.value):
                if isinstance(el, ast.Constant) and isinstance(
                        el.value, int) and 0 <= el.value < len(params):
                    out.add(params[el.value])
    return out


def find_traced_functions(ctx: FileContext) -> Dict[int, TracedInfo]:
    """id(node) -> TracedInfo for every function the module traces."""
    tree = ctx.tree
    by_name: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            by_name.setdefault(node.name, []).append(node)

    traced: Dict[int, TracedInfo] = {}

    def mark(fn_expr, why: str, jit_keywords=()) -> None:
        """Mark the function an expression refers to as traced."""
        statics: Set[str] = set()
        if isinstance(fn_expr, ast.Call):
            # functools.partial(f, **static_kw) -> f with kw static
            name = dotted_name(fn_expr.func)
            if name in ("functools.partial", "partial") and fn_expr.args:
                statics = {kw.arg for kw in fn_expr.keywords
                           if kw.arg is not None}
                mark_with_statics(fn_expr.args[0], why, statics,
                                  jit_keywords)
            return
        mark_with_statics(fn_expr, why, statics, jit_keywords)

    def mark_with_statics(fn_expr, why, statics, jit_keywords) -> None:
        nodes: List[ast.AST] = []
        if isinstance(fn_expr, ast.Lambda):
            nodes = [fn_expr]
        elif isinstance(fn_expr, ast.Name):
            nodes = by_name.get(fn_expr.id, [])
        for n in nodes:
            info = traced.setdefault(id(n), TracedInfo(n, why))
            info.static |= statics | _kwonly_params(n)
            info.static |= _statics_from_jit_kwargs(jit_keywords, n)

    # ---- pass 1: decorators + transform call sites
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                tname = _transform_name(dotted_name(dec))
                kws = ()
                if tname is None and isinstance(dec, ast.Call):
                    inner = dotted_name(dec.func)
                    if inner in ("functools.partial", "partial") and dec.args:
                        tname = _transform_name(dotted_name(dec.args[0]))
                        kws = dec.keywords
                    else:
                        tname = _transform_name(inner)
                        kws = dec.keywords
                if tname is not None:
                    info = traced.setdefault(
                        id(node), TracedInfo(node, f"@{tname}"))
                    info.static |= _kwonly_params(node)
                    info.static |= _statics_from_jit_kwargs(kws, node)
        if isinstance(node, ast.Call):
            tname = _transform_name(dotted_name(node.func))
            if tname is None:
                continue
            for idx in _TRANSFORM_FN_ARGS[tname]:
                if idx < len(node.args):
                    mark(node.args[idx], f"passed to {tname}",
                         node.keywords if tname == "jit" else ())
            if tname == "switch" and len(node.args) > 1 and isinstance(
                    node.args[1], (ast.Tuple, ast.List)):
                for el in node.args[1].elts:
                    mark(el, "passed to switch")

    # ---- pass 2: fixpoint over nesting + module-local calls
    def body_calls(fn) -> Set[str]:
        return {n.func.id for n in ast.walk(fn)
                if isinstance(n, ast.Call) and isinstance(n.func, ast.Name)}

    def nested_defs(fn) -> List[ast.AST]:
        out = []
        for n in ast.walk(fn):
            if n is not fn and isinstance(
                    n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out.append(n)
        return out

    changed = True
    while changed:
        changed = False
        for info in list(traced.values()):
            fn = info.node
            if isinstance(fn, ast.Lambda):
                continue
            for sub in nested_defs(fn):
                if id(sub) not in traced:
                    traced[id(sub)] = TracedInfo(
                        sub, f"nested in traced '{getattr(fn, 'name', '?')}'",
                        _kwonly_params(sub))
                    changed = True
            for called in body_calls(fn):
                for n in by_name.get(called, []):
                    if id(n) not in traced:
                        traced[id(n)] = TracedInfo(
                            n, f"called from traced "
                               f"'{getattr(fn, 'name', '?')}'",
                            _kwonly_params(n))
                        changed = True
    return traced


def _dyn_names(node: ast.AST) -> Set[str]:
    """Names whose runtime VALUE the expression depends on — names that
    only appear under static accessors (.shape, len(), isinstance(),
    `is None` tests) are excluded."""
    if isinstance(node, ast.Attribute):
        if node.attr in _STATIC_ATTRS:
            return set()
        return _dyn_names(node.value)
    if isinstance(node, ast.Call):
        name = dotted_name(node.func)
        if name in _STATIC_CALLS:
            return set()
        out: Set[str] = set()
        for child in ast.iter_child_nodes(node):
            out |= _dyn_names(child)
        return out
    if isinstance(node, ast.Compare):
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return set()
        out = _dyn_names(node.left)
        for c in node.comparators:
            out |= _dyn_names(c)
        return out
    if isinstance(node, ast.Name):
        return {node.id}
    if isinstance(node, ast.Lambda):
        return set()
    out = set()
    for child in ast.iter_child_nodes(node):
        out |= _dyn_names(child)
    return out


class _TaintWalker:
    """One traced function: propagate param taint through assignments,
    flag dynamic control flow / host coercions on tainted names."""

    def __init__(self, rule, ctx: FileContext, info: TracedInfo,
                 inherited: Set[str]):
        self.rule = rule
        self.ctx = ctx
        self.info = info
        fn = info.node
        params = set(_param_list(fn)) if not isinstance(fn, ast.Lambda) \
            else {p.arg for p in fn.args.args}
        self.tainted: Set[str] = (params - info.static
                                  - _STATIC_PARAM_NAMES) | set(inherited)
        self.findings: List[Finding] = []

    def run(self) -> List[Finding]:
        body = (self.info.node.body
                if not isinstance(self.info.node, ast.Lambda)
                else [ast.Expr(value=self.info.node.body)])
        self._block(body)
        return self.findings

    def _block(self, stmts) -> None:
        for st in stmts:
            self._stmt(st)

    def _tainted_in(self, expr) -> Set[str]:
        return _dyn_names(expr) & self.tainted

    def _stmt(self, st) -> None:
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return      # visited as its own traced function (nested)
        if isinstance(st, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            if st.value is not None:
                self._expr(st.value)
                hot = self._tainted_in(st.value)
                targets = (st.targets if isinstance(st, ast.Assign)
                           else [st.target])
                for t in targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name):
                            if hot:
                                self.tainted.add(n.id)
                            else:
                                self.tainted.discard(n.id)
            return
        if isinstance(st, (ast.If, ast.While)):
            hot = self._tainted_in(st.test)
            if hot:
                kind = "if" if isinstance(st, ast.If) else "while"
                self.findings.append(self.ctx.finding(
                    self.rule.id, st,
                    f"Python `{kind}` on likely-tracer value(s) "
                    f"{_fmt(hot)} inside traced function "
                    f"{_fname(self.info)} ({self.info.why}); use lax.cond/"
                    "lax.while_loop/jnp.where or hoist the decision to a "
                    "static argument"))
            self._expr(st.test)
            self._block(st.body)
            self._block(st.orelse)
            return
        if isinstance(st, ast.Assert):
            hot = self._tainted_in(st.test)
            if hot:
                self.findings.append(self.ctx.finding(
                    self.rule.id, st,
                    f"`assert` on likely-tracer value(s) {_fmt(hot)} "
                    f"inside traced function {_fname(self.info)} "
                    f"({self.info.why}); asserts on tracers either fail "
                    "at trace time or silently vanish — use "
                    "checkify/debug.check or assert on static shapes"))
            return
        if isinstance(st, ast.For):
            self._expr(st.iter)
            self._block(st.body)
            self._block(st.orelse)
            return
        for child in ast.iter_child_nodes(st):
            if isinstance(child, ast.expr):
                self._expr(child)
            elif isinstance(child, ast.stmt):
                self._stmt(child)
            elif isinstance(child, (ast.withitem, ast.excepthandler)):
                for sub in ast.iter_child_nodes(child):
                    if isinstance(sub, ast.stmt):
                        self._stmt(sub)
                    elif isinstance(sub, ast.expr):
                        self._expr(sub)

    def _expr(self, node) -> None:
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            name = dotted_name(sub.func)
            if name in ("bool", "int", "float") and sub.args:
                hot = self._tainted_in(sub.args[0])
                if hot:
                    self.findings.append(self.ctx.finding(
                        self.rule.id, sub,
                        f"`{name}()` forces concretization of "
                        f"likely-tracer value(s) {_fmt(hot)} inside "
                        f"traced function {_fname(self.info)} "
                        f"({self.info.why})"))
            elif isinstance(sub.func, ast.Attribute) \
                    and sub.func.attr == "item":
                hot = self._tainted_in(sub.func.value)
                if hot:
                    self.findings.append(self.ctx.finding(
                        self.rule.id, sub,
                        f"`.item()` on likely-tracer value(s) {_fmt(hot)} "
                        f"inside traced function {_fname(self.info)} "
                        f"({self.info.why})"))


def _fname(info: TracedInfo) -> str:
    return repr(getattr(info.node, "name", "<lambda>"))


def _fmt(names: Set[str]) -> str:
    return ", ".join(sorted(names))


def _walk_traced(ctx: FileContext):
    """(info, inherited_taint) pairs, outer functions before nested, so
    nested closures inherit the parent's tainted names."""
    traced = find_traced_functions(ctx)
    inherited: Dict[int, Set[str]] = {}
    order: List[TracedInfo] = []

    def visit(node, parent_taint: Set[str]):
        for child in ast.iter_child_nodes(node):
            info = traced.get(id(child)) if isinstance(
                child, FunctionLike) else None
            if info is not None:
                w = _TaintWalker.__new__(_TaintWalker)  # taint preview only
                _TaintWalker.__init__(w, _NULL_RULE, ctx, info, parent_taint)
                inherited[id(child)] = set(parent_taint)
                order.append(info)
                visit(child, set(w.tainted))
            else:
                visit(child, parent_taint)

    visit(ctx.tree, set())
    for info in order:
        yield info, inherited[id(info.node)]


class _NullRule:
    id = "null"


_NULL_RULE = _NullRule()


@register
class TraceUnsafeBranch(Rule):
    id = "trace-unsafe-branch"
    description = ("Python control flow or concretization on "
                   "likely-tracer values inside jit/vmap/scan/"
                   "pallas_call bodies")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        seen = set()
        for info, inherited in _walk_traced(ctx):
            for f in _TaintWalker(self, ctx, info, inherited).run():
                if (f.line, f.col) not in seen:
                    seen.add((f.line, f.col))
                    yield f


@register
class HostSyncInHotPath(Rule):
    id = "host-sync-in-hot-path"
    description = ("numpy coercion / time.* / print / "
                   "block_until_ready inside jitted round or step "
                   "functions; per-element device->host transfers in "
                   "host loops")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        traced = find_traced_functions(ctx)
        traced_nodes: Set[int] = set()
        seen = set()
        for info in traced.values():
            for node in ast.walk(info.node):
                traced_nodes.add(id(node))
                if id(node) in seen:
                    continue
                seen.add(id(node))
                if not isinstance(node, ast.Call):
                    continue
                name = dotted_name(node.func)
                if name is None:
                    continue
                bad = None
                if name.startswith(("np.", "numpy.")):
                    bad = (f"{name}() coerces a tracer to a host numpy "
                           "value")
                elif name.startswith("time."):
                    bad = (f"{name}() measures host time inside the "
                           "compiled program (it times tracing, not "
                           "compute)")
                elif name == "print":
                    bad = ("print() runs at trace time only; use "
                           "jax.debug.print for runtime values")
                elif name.endswith(".block_until_ready"):
                    bad = (".block_until_ready() forces a device sync "
                           "inside the hot path")
                elif name in ("jax.device_get", "device_get"):
                    bad = (f"{name}() pulls device values to the host "
                           "inside the hot path")
                if bad is not None:
                    yield ctx.finding(
                        self.id, node,
                        f"{bad} — inside traced function "
                        f"{_fname(info)} ({info.why})")
        yield from self._host_loop_scan(ctx, traced_nodes)

    def _host_loop_scan(self, ctx: FileContext,
                        traced_nodes: Set[int]) -> Iterator[Finding]:
        """Flag per-ELEMENT device->host transfers inside host ``for``
        loops: ``np.asarray(x[i])``, ``x[i].item()`` and
        ``jax.device_get(x[i])`` each force one device sync per
        iteration (per slot, in the serving engine's commit loops) —
        pack the outputs and fetch the whole array once instead. Only
        subscripted arguments are flagged: a whole-array ``asarray``
        is a single transfer and stays legal wherever it sits."""
        flagged = set()
        for loop in ast.walk(ctx.tree):
            if not isinstance(loop, ast.For) or id(loop) in traced_nodes:
                continue
            for sub in ast.walk(loop):
                if (not isinstance(sub, ast.Call)
                        or id(sub) in traced_nodes
                        or id(sub) in flagged):
                    continue
                name = dotted_name(sub.func)
                if (name in ("np.asarray", "numpy.asarray",
                             "jax.device_get", "device_get")
                        and sub.args
                        and isinstance(sub.args[0], ast.Subscript)):
                    flagged.add(id(sub))
                    yield ctx.finding(
                        self.id, sub,
                        f"{name}() on a subscript inside a host loop — "
                        "one device sync per element; batch into a "
                        "single packed fetch outside the loop")
                elif (isinstance(sub.func, ast.Attribute)
                        and sub.func.attr == "item"
                        and isinstance(sub.func.value, ast.Subscript)):
                    flagged.add(id(sub))
                    yield ctx.finding(
                        self.id, sub,
                        ".item() on a subscript inside a host loop — "
                        "one device sync per element; batch into a "
                        "single packed fetch outside the loop")
