"""RNG-stream discipline rules.

Every bitwise-parity pin in this repo (batched == single, paged ==
dense, fork == independent, wave == single-submit) is a statement about
WHICH ``jax.random`` stream each consumer draws from. Two invariants
keep those statements true:

- a key value feeds exactly ONE consuming ``jax.random.*`` call;
  further draws come from ``split``/``fold_in`` derivations
  (``rng-key-reuse``);
- the library never manufactures root keys: engines derive every
  stream from the caller's request key, so the same request replays the
  same tokens no matter how it is batched, paged, forked or waved
  (``rng-raw-prngkey`` — root construction is sanctioned only at entry
  points: tests, examples, benchmarks, ``repro.launch``).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Tuple

from ..astutil import FunctionLike, const_int, dotted_name, unwrap_transform
from ..core import FileContext, Finding, Rule, register

#: jax.random.* callees that DERIVE or construct keys rather than
#: consuming a stream — fold_in(key, i) over distinct data is the
#: sanctioned many-streams-from-one-parent pattern.
NON_CONSUMING = {"split", "fold_in", "PRNGKey", "key", "key_data",
                 "wrap_key_data", "clone", "key_impl", "bits"}

#: expressions whose value is a fresh key (or batch of keys)
KEY_PRODUCERS = {"PRNGKey", "key", "split", "fold_in"}

#: parameter names assumed to carry a PRNG key
KEY_PARAM_NAMES = {"rng", "key", "rng_key", "prng_key", "base_key",
                   "subkey", "sub_key"}

_RANDOM_PREFIXES = ("jax.random.", "jrandom.", "jr.")


def _random_callee(name: Optional[str]) -> Optional[str]:
    """"categorical" for "jax.random.categorical", else None."""
    if name is None:
        return None
    for p in _RANDOM_PREFIXES:
        if name.startswith(p):
            return name[len(p):]
    return None


def _key_ref(node: ast.AST) -> Optional[str]:
    """A trackable reference: a bare name or a constant-indexed name."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Subscript) and isinstance(node.value, ast.Name):
        idx = const_int(node.slice)
        if idx is not None:
            return f"{node.value.id}[{idx}]"
    return None


def _is_key_expr(node: ast.AST) -> bool:
    if isinstance(node, ast.Subscript):
        return _is_key_expr(node.value)
    if isinstance(node, ast.Call):
        name, _ = unwrap_transform(node)
        fn = _random_callee(name)
        return fn in KEY_PRODUCERS
    return False


class _KeyState:
    """Per-scope abstract state: ref -> (line, consumer) | None."""

    def __init__(self):
        self.refs: Dict[str, Optional[Tuple[int, str]]] = {}

    def copy(self) -> "_KeyState":
        out = _KeyState()
        out.refs = dict(self.refs)
        return out

    def merge(self, other: "_KeyState") -> None:
        for ref, c in other.refs.items():
            if c is not None:
                self.refs[ref] = c
            elif ref not in self.refs:
                self.refs[ref] = None


@register
class RngKeyReuse(Rule):
    id = "rng-key-reuse"
    description = ("a PRNG key value flows into two consuming "
                   "jax.random.* calls without an intervening "
                   "split/fold_in")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        self._out: List[Finding] = []
        self._seen: set = set()
        self._ctx = ctx
        # module top-level statements form one scope (nested defs are
        # their own scopes, visited below)
        self._run_scope(ctx.tree.body, params=())
        for node in ast.walk(ctx.tree):
            if isinstance(node, FunctionLike) and not isinstance(
                    node, ast.Lambda):
                self._run_scope(node.body, params=_param_names(node))
        return iter(self._out)

    # -- scope driver ------------------------------------------------------
    def _run_scope(self, body, params: Tuple[str, ...]) -> None:
        state = _KeyState()
        for p in params:
            if p.lower() in KEY_PARAM_NAMES:
                state.refs[p] = None
        self._exec_block(body, state)

    def _exec_block(self, stmts, state: _KeyState) -> None:
        for st in stmts:
            self._exec_stmt(st, state)

    def _exec_stmt(self, st, state: _KeyState) -> None:
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef)):
            return                      # separate scope
        if isinstance(st, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = st.value
            if value is not None:
                self._scan_expr(value, state)
            targets = (st.targets if isinstance(st, ast.Assign)
                       else [st.target])
            for t in targets:
                self._bind(t, value, state,
                           aug=isinstance(st, ast.AugAssign))
            return
        if isinstance(st, ast.If):
            self._scan_expr(st.test, state)
            s1, s2 = state.copy(), state.copy()
            self._exec_block(st.body, s1)
            self._exec_block(st.orelse, s2)
            # a branch that terminates (return/raise/...) contributes
            # nothing to the fall-through state: `if flag: return
            # normal(rng)` followed by `return uniform(rng)` is one
            # consumer per path, not a reuse
            state.refs = {}
            if not _terminates(st.body):
                state.merge(s1)
            if not _terminates(st.orelse):
                state.merge(s2)
            return
        if isinstance(st, (ast.For, ast.While)):
            self._scan_expr(st.iter if isinstance(st, ast.For) else st.test,
                            state)
            if isinstance(st, ast.For):
                self._bind(st.target, None, state)
            # two passes: the second catches keys consumed once per
            # iteration without being re-derived inside the loop body
            self._exec_block(st.body, state)
            self._exec_block(st.body, state)
            self._exec_block(st.orelse, state)
            return
        if isinstance(st, ast.With):
            for item in st.items:
                self._scan_expr(item.context_expr, state)
            self._exec_block(st.body, state)
            return
        if isinstance(st, ast.Try):
            self._exec_block(st.body, state)
            for h in st.handlers:
                self._exec_block(h.body, state)
            self._exec_block(st.orelse, state)
            self._exec_block(st.finalbody, state)
            return
        for child in ast.iter_child_nodes(st):
            if isinstance(child, ast.expr):
                self._scan_expr(child, state)

    def _bind(self, target, value, state: _KeyState, aug=False) -> None:
        if isinstance(target, ast.Name):
            fresh = value is not None and not aug and _is_key_expr(value)
            # rebinding clears the name and any tracked elements of it
            for ref in [r for r in state.refs
                        if r == target.id
                        or r.startswith(target.id + "[")]:
                del state.refs[ref]
            if fresh:
                state.refs[target.id] = None
        elif isinstance(target, (ast.Tuple, ast.List)):
            fresh = value is not None and not aug and _is_key_expr(value)
            for el in target.elts:
                self._bind(el, value if fresh else None, state)
        elif isinstance(target, ast.Subscript):
            ref = _key_ref(target)
            if ref is not None and ref in state.refs:
                del state.refs[ref]

    # -- expression scan ---------------------------------------------------
    def _scan_expr(self, node: ast.AST, state: _KeyState) -> None:
        if isinstance(node, ast.Lambda):
            return                      # separate scope
        if isinstance(node, ast.Call):
            name, call = unwrap_transform(node)
            fn = _random_callee(name)
            if fn is not None and fn not in NON_CONSUMING:
                arg = None
                if call.args:
                    arg = call.args[0]
                else:
                    arg = next((kw.value for kw in call.keywords
                                if kw.arg == "key"), None)
                ref = _key_ref(arg) if arg is not None else None
                if ref is not None and ref in state.refs:
                    self._consume(ref, fn, arg, state)
            for child in ast.iter_child_nodes(node):
                self._scan_expr(child, state)
            return
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.expr, ast.keyword, ast.arguments)):
                self._scan_expr(child, state)

    def _consume(self, ref: str, fn: str, node, state: _KeyState) -> None:
        prev = state.refs[ref]
        if prev is None:
            state.refs[ref] = (node.lineno, fn)
            return
        key = (ref, node.lineno, fn)
        if key in self._seen:
            return
        self._seen.add(key)
        p_line, p_fn = prev
        where = (f"already consumed by jax.random.{p_fn} at line {p_line}"
                 if p_line != node.lineno else
                 f"consumed once per loop iteration by jax.random.{p_fn}")
        self._out.append(self._ctx.finding(
            self.id, node,
            f"PRNG key {ref!r} reused by jax.random.{fn} ({where}); "
            f"split() or fold_in() a fresh key per consumer"))


def _terminates(stmts) -> bool:
    """True if control never falls off the end of this block."""
    return bool(stmts) and isinstance(
        stmts[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break))


def _param_names(fn) -> Tuple[str, ...]:
    a = fn.args
    return tuple(p.arg for p in
                 (*a.posonlyargs, *a.args, *a.kwonlyargs))


@register
class RngRawPRNGKey(Rule):
    id = "rng-raw-prngkey"
    description = ("raw PRNGKey construction outside sanctioned entry "
                   "points (tests, launchers, examples, benchmarks)")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            if name is None:
                continue
            is_raw = (name.endswith(".PRNGKey") or name == "PRNGKey"
                      or name in ("jax.random.key", "jrandom.key",
                                  "jr.key"))
            if is_raw:
                yield ctx.finding(
                    self.id, node,
                    f"{name}(...) constructs a root PRNG key inside the "
                    "library; engines must derive streams from the "
                    "request key (ServeRequest.rng + fold_in) — root "
                    "keys are sanctioned only in tests/, examples/, "
                    "benchmarks/ and repro.launch")
