"""``refcount-pairing``: page-ownership discipline in the serving tier.

``PagedKVCachePool`` pages are refcounted; the PR 6 fuzz suite pins the
global invariant (refcounts == actual owners, free list exact) but only
for the schedules it generates. Statically, the bug shape that slips
through review is a NEW ``retain`` call site with no path that ever
gives the reference back — the page leaks until reset.

A ``.retain(...)`` call site is considered paired when its enclosing
scope (the class that contains it, else the module) also contains a
release path — a ``.release(...)``, ``.free_slot(...)`` or
``.truncate(...)`` call or a method of one of those names — or when the
enclosing function is a sanctioned ownership-transfer point
(``AnalysisConfig.ownership_transfer_methods``: ``insert``/``adopt``/
``donate``/``fork`` hand the reference to a new owner whose own
lifecycle releases it).

The rule also flags direct ``refcount`` array mutation outside the
class that owns the counter (the one defining both ``retain`` and
``release``): bypassing the API skips the free-list bookkeeping the
fuzz invariants are stated over.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional

from ..astutil import dotted_name
from ..core import FileContext, Finding, Rule, register

_RELEASERS = {"release", "free_slot", "truncate"}


def _enclosing(stack: List[ast.AST], kinds) -> Optional[ast.AST]:
    for node in reversed(stack):
        if isinstance(node, kinds):
            return node
    return None


def _attr_calls(tree: ast.AST, names) -> List[ast.Call]:
    return [n for n in ast.walk(tree)
            if isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute)
            and n.func.attr in names]


def _defines_method(scope: ast.AST, names) -> bool:
    return any(isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
               and n.name in names for n in ast.walk(scope))


class _Stacker(ast.NodeVisitor):
    """Walk with an ancestor stack (class/function nesting)."""

    def __init__(self):
        self.stack: List[ast.AST] = []
        self.hits: List[tuple] = []     # (node, stack snapshot)

    def visit(self, node):
        self.stack.append(node)
        try:
            self.inspect(node)
            super().generic_visit(node)
        finally:
            self.stack.pop()

    def inspect(self, node):
        raise NotImplementedError


class _RetainFinder(_Stacker):
    def inspect(self, node):
        if isinstance(node, ast.Call) and isinstance(
                node.func, ast.Attribute) and node.func.attr == "retain":
            self.hits.append((node, list(self.stack[:-1])))


class _RefcountMutFinder(_Stacker):
    def inspect(self, node):
        target = None
        if isinstance(node, ast.AugAssign):
            target = node.target
        elif isinstance(node, ast.Assign) and len(node.targets) == 1:
            target = node.targets[0]
        if target is None:
            return
        if isinstance(target, ast.Subscript):
            target = target.value
        name = dotted_name(target)
        if name is not None and name.split(".")[-1] == "refcount":
            self.hits.append((node, list(self.stack[:-1])))


@register
class RefcountPairing(Rule):
    id = "refcount-pairing"
    description = ("retain without a reachable release/free_slot/"
                   "ownership-transfer; direct refcount mutation "
                   "outside the owning class")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        transfers = set(ctx.config.ownership_transfer_methods)

        finder = _RetainFinder()
        finder.visit(ctx.tree)
        for call, stack in finder.hits:
            fn = _enclosing(stack, (ast.FunctionDef, ast.AsyncFunctionDef))
            if fn is not None and fn.name in transfers:
                continue
            scope = _enclosing(stack, ast.ClassDef) or ctx.tree
            paired = (_attr_calls(scope, _RELEASERS)
                      or _defines_method(scope, _RELEASERS))
            if not paired:
                where = ("class " + scope.name
                         if isinstance(scope, ast.ClassDef) else "module")
                yield ctx.finding(
                    self.id, call,
                    f".retain() call with no release path in the same "
                    f"{where}: no .release()/.free_slot()/.truncate() "
                    "call or method — the page reference leaks until "
                    "pool reset. Release it, or do the retain inside a "
                    f"sanctioned transfer method ({sorted(transfers)})")

        mut = _RefcountMutFinder()
        mut.visit(ctx.tree)
        for node, stack in mut.hits:
            scope = _enclosing(stack, ast.ClassDef)
            owner = (scope is not None
                     and _defines_method(scope, {"retain"})
                     and _defines_method(scope, {"release"}))
            if not owner:
                yield ctx.finding(
                    self.id, node,
                    "direct refcount mutation outside the class that "
                    "defines retain()/release(): bypassing the API "
                    "skips free-list bookkeeping (the fuzz-suite "
                    "invariants are stated over retain/release)")
