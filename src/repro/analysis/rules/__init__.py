"""Rule modules — importing this package registers every rule."""
from . import pallas, refcount, rng, trace  # noqa: F401
