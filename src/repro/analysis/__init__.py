"""``repro.analysis`` — repo-native static analysis.

An AST lint pass for the invariants the test suite can only sample:
RNG-stream discipline (one fold_in/split per consumer, no raw root
keys in the library), trace safety (no Python control flow on tracers
inside jitted rounds, no host syncs in the hot path), Pallas kernel
hygiene (block shapes and grid arity on the shared
``kernels.alignment`` table), and refcounted-page ownership pairing.

Run it::

    python -m repro.analysis src tests benchmarks examples

Suppress an intentional exception ON the offending line (the reason is
mandatory)::

    x = jax.random.PRNGKey(0)  # repro: ignore[rng-raw-prngkey] -- why

See ``repro.analysis.config.DEFAULT_CONFIG`` for where each rule runs.
"""
from .config import AnalysisConfig, DEFAULT_CONFIG, RulePaths, \
    unrestricted_config
from .core import (RULES, AnalysisReport, FileContext, Finding, Rule,
                   register, run_analysis)
from .output import render_json, render_sarif, render_text

__all__ = [
    "AnalysisConfig", "AnalysisReport", "DEFAULT_CONFIG", "FileContext",
    "Finding", "RULES", "Rule", "RulePaths", "register", "run_analysis",
    "render_json", "render_sarif", "render_text", "unrestricted_config",
]
