"""Core of the repo-native static-analysis pass.

The analyzer is an AST-walking lint framework specialised to THIS
codebase's invariants — the ones every bitwise-parity pin hangs off
(one ``fold_in``/``split`` per consumer, no Python control flow on
tracers inside jitted rounds, Pallas block shapes on the shared
alignment table, refcounted pages never retained without a release
path). Rules register themselves into ``RULES``; ``run_analysis``
parses each file once and hands a ``FileContext`` to every rule whose
per-file config admits the path.

Suppressions are inline comments::

    pool.retain(pid)  # repro: ignore[refcount-pairing] -- donated to cache

The rule id goes in brackets (comma-separate several), and the reason
after ``--`` is MANDATORY: an ignore without a written justification is
itself reported (rule ``analysis-bare-ignore``). A suppression comment
on its own line applies to the next code line.
"""
from __future__ import annotations

import ast
import io
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Tuple

from .config import AnalysisConfig, DEFAULT_CONFIG

__all__ = ["Finding", "Suppression", "FileContext", "Rule", "RULES",
           "register", "AnalysisReport", "run_analysis", "iter_py_files",
           "BARE_IGNORE"]

BARE_IGNORE = "analysis-bare-ignore"

_IGNORE_RE = re.compile(
    r"#\s*repro:\s*ignore\[(?P<ids>[a-z0-9_,\s-]+)\]"
    r"(?:\s*--\s*(?P<reason>\S.*))?")


@dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str
    path: str
    line: int
    col: int
    message: str
    severity: str = "error"      # "error" | "warning"

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule}: {self.message}")


@dataclass
class Suppression:
    """A parsed ``# repro: ignore[...]`` comment."""

    path: str
    line: int                    # line the suppression APPLIES to
    comment_line: int            # line the comment sits on
    rules: Tuple[str, ...]
    reason: Optional[str]
    used: bool = False


class FileContext:
    """One parsed file, shared by every rule that runs on it."""

    def __init__(self, path: str, source: str,
                 config: Optional[AnalysisConfig] = None):
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.config = config if config is not None else DEFAULT_CONFIG
        self.suppressions = _parse_suppressions(path, source)

    def finding(self, rule: str, node, message: str,
                severity: str = "error") -> Finding:
        return Finding(rule=rule, path=self.path,
                       line=getattr(node, "lineno", 1),
                       col=getattr(node, "col_offset", 0) + 1,
                       message=message, severity=severity)


def _code_line_after(comment_line: int, source_lines: List[str]) -> int:
    """A standalone suppression comment governs the next code line."""
    for i in range(comment_line, len(source_lines)):
        text = source_lines[i].strip()        # i is 0-based line i+1
        if text and not text.startswith("#"):
            return i + 1
    return comment_line


def _parse_suppressions(path: str, source: str) -> List[Suppression]:
    out: List[Suppression] = []
    lines = source.splitlines()
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        comments = [(t.start[0], t.string) for t in tokens
                    if t.type == tokenize.COMMENT]
    except tokenize.TokenizeError:
        comments = [(i + 1, ln[ln.index("#"):]) for i, ln in
                    enumerate(lines) if "#" in ln]
    for lineno, text in comments:
        m = _IGNORE_RE.search(text)
        if not m:
            continue
        ids = tuple(s.strip() for s in m.group("ids").split(",") if s.strip())
        standalone = lines[lineno - 1].lstrip().startswith("#")
        applies = (_code_line_after(lineno, lines) if standalone else lineno)
        out.append(Suppression(path=path, line=applies, comment_line=lineno,
                               rules=ids, reason=m.group("reason")))
    return out


class Rule:
    """Base class: subclasses set ``id``/``description`` and implement
    ``check``. Registration is explicit via ``@register``."""

    id: str = ""
    description: str = ""
    severity: str = "error"

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        raise NotImplementedError


RULES: Dict[str, Rule] = {}


def register(cls):
    if not cls.id:
        raise ValueError(f"rule {cls.__name__} has no id")
    if cls.id in RULES:
        raise ValueError(f"duplicate rule id {cls.id!r}")
    RULES[cls.id] = cls()
    return cls


@dataclass
class AnalysisReport:
    findings: List[Finding] = field(default_factory=list)
    suppressed: List[Tuple[Finding, Suppression]] = field(
        default_factory=list)
    files: List[str] = field(default_factory=list)
    errors: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.findings and not self.errors

    def summary(self) -> str:
        return (f"{len(self.findings)} finding(s), "
                f"{len(self.suppressed)} suppressed, "
                f"{len(self.files)} file(s) analyzed"
                + (f", {len(self.errors)} file error(s)" if self.errors
                   else ""))


def iter_py_files(paths: Iterable[str]) -> Iterator[Path]:
    seen = set()
    for p in paths:
        path = Path(p)
        files = (sorted(path.rglob("*.py")) if path.is_dir() else [path])
        for f in files:
            if f.suffix == ".py" and f not in seen:
                seen.add(f)
                yield f


def _relpath(f: Path) -> str:
    try:
        return f.resolve().relative_to(Path.cwd().resolve()).as_posix()
    except ValueError:
        return f.as_posix()


def _apply_suppressions(ctx: FileContext, found: List[Finding],
                        report: AnalysisReport,
                        rule_ids: List[str]) -> None:
    by_line: Dict[int, List[Suppression]] = {}
    for sup in ctx.suppressions:
        by_line.setdefault(sup.line, []).append(sup)
    for f in found:
        sup = next((s for s in by_line.get(f.line, ())
                    if f.rule in s.rules), None)
        if sup is not None and sup.reason:
            sup.used = True
            report.suppressed.append((f, sup))
        elif sup is not None:
            # a reasonless ignore does NOT suppress — it surfaces both
            # the original finding and the bare-ignore one below
            report.findings.append(f)
        else:
            report.findings.append(f)
    if BARE_IGNORE in rule_ids and ctx.config.applies(BARE_IGNORE, ctx.path):
        for sup in ctx.suppressions:
            if not sup.reason:
                report.findings.append(Finding(
                    rule=BARE_IGNORE, path=ctx.path, line=sup.comment_line,
                    col=1, severity="warning",
                    message="suppression without a written justification: "
                            "use '# repro: ignore[rule-id] -- reason'"))
            elif not set(sup.rules) & set(RULES):
                unknown = ", ".join(sorted(set(sup.rules) - set(RULES)))
                report.findings.append(Finding(
                    rule=BARE_IGNORE, path=ctx.path, line=sup.comment_line,
                    col=1, severity="warning",
                    message=f"suppression names unknown rule(s): {unknown}"))


def run_analysis(paths: Iterable[str],
                 config: Optional[AnalysisConfig] = None,
                 rules: Optional[Iterable[str]] = None) -> AnalysisReport:
    """Run every registered rule over ``paths`` (files or directories).

    ``config`` defaults to the repo policy (``config.DEFAULT_CONFIG``);
    ``rules`` restricts to a subset of rule ids.
    """
    from . import rules as _rules_pkg  # noqa: F401  (registers rules)

    config = config if config is not None else DEFAULT_CONFIG
    rule_ids = (list(rules) if rules is not None
                else list(RULES) + [BARE_IGNORE])
    unknown = [r for r in rule_ids if r != BARE_IGNORE and r not in RULES]
    if unknown:
        raise ValueError(f"unknown rule id(s): {unknown}")
    report = AnalysisReport()
    for f in iter_py_files(paths):
        rel = _relpath(f)
        try:
            ctx = FileContext(rel, f.read_text(encoding="utf-8"),
                              config=config)
        except (SyntaxError, UnicodeDecodeError) as e:
            report.errors.append(f"{rel}: {e}")
            continue
        report.files.append(rel)
        found: List[Finding] = []
        for rid in rule_ids:
            if rid == BARE_IGNORE:
                continue
            if not config.applies(rid, rel):
                continue
            found.extend(RULES[rid].check(ctx))
        _apply_suppressions(ctx, found, report, rule_ids)
    report.findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return report
