"""Report renderers: human text, JSON, and SARIF 2.1.0.

SARIF is what the CI ``lint`` job uploads — GitHub's code-scanning UI
and most editors ingest it directly, so a rule hit lands as an
annotation on the PR line that introduced it.
"""
from __future__ import annotations

import json
from typing import Dict

from .core import RULES, AnalysisReport

SARIF_VERSION = "2.1.0"
SARIF_SCHEMA = ("https://raw.githubusercontent.com/oasis-tcs/sarif-spec/"
                "master/Schemata/sarif-schema-2.1.0.json")


def render_text(report: AnalysisReport) -> str:
    lines = [f.render() for f in report.findings]
    for err in report.errors:
        lines.append(f"error: {err}")
    if report.suppressed:
        lines.append("")
        lines.append("suppressed:")
        for f, sup in report.suppressed:
            lines.append(f"  {f.render()}  [reason: {sup.reason}]")
    lines.append("")
    lines.append(report.summary())
    return "\n".join(lines)


def render_json(report: AnalysisReport) -> str:
    def fdict(f) -> Dict:
        return {"rule": f.rule, "path": f.path, "line": f.line,
                "col": f.col, "severity": f.severity, "message": f.message}

    return json.dumps({
        "findings": [fdict(f) for f in report.findings],
        "suppressed": [{**fdict(f), "reason": s.reason,
                        "suppressed_at": s.comment_line}
                       for f, s in report.suppressed],
        "files": report.files,
        "errors": report.errors,
        "summary": report.summary(),
    }, indent=2)


def render_sarif(report: AnalysisReport) -> str:
    rules = [{
        "id": rid,
        "shortDescription": {"text": rule.description or rid},
        "defaultConfiguration": {
            "level": "error" if rule.severity == "error" else "warning"},
    } for rid, rule in sorted(RULES.items())]
    # the meta-rule (bare ignore) is emitted by the framework itself
    rules.append({
        "id": "analysis-bare-ignore",
        "shortDescription": {
            "text": "suppression comment without a written justification"},
        "defaultConfiguration": {"level": "warning"},
    })
    results = [{
        "ruleId": f.rule,
        "level": f.severity,
        "message": {"text": f.message},
        "locations": [{
            "physicalLocation": {
                "artifactLocation": {"uri": f.path},
                "region": {"startLine": f.line, "startColumn": f.col},
            },
        }],
    } for f in report.findings]
    results += [{
        "ruleId": f.rule,
        "level": "note",
        "message": {"text": f"[suppressed: {s.reason}] {f.message}"},
        "suppressions": [{"kind": "inSource",
                          "justification": s.reason or ""}],
        "locations": [{
            "physicalLocation": {
                "artifactLocation": {"uri": f.path},
                "region": {"startLine": f.line, "startColumn": f.col},
            },
        }],
    } for f, s in report.suppressed]
    doc = {
        "$schema": SARIF_SCHEMA,
        "version": SARIF_VERSION,
        "runs": [{
            "tool": {"driver": {
                "name": "repro.analysis",
                "informationUri": "https://example.invalid/repro-analysis",
                "rules": rules,
            }},
            "results": results,
        }],
    }
    return json.dumps(doc, indent=2)


RENDERERS = {"text": render_text, "json": render_json,
             "sarif": render_sarif}
